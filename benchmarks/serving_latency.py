"""Serving latency benchmark: micro-batching vs single-request dispatch.

Measures the p50/p99 latency and sustained QPS of the online serving
service (repro.serving) across the two latency-budget knobs:

* ``single``           — max_batch=1 (every request is its own dispatch;
                         the no-batching baseline);
* ``batchN_waitW``     — micro-batching at flush size N / wait budget W;
* ``train_concurrent`` — the best batched config while a trainer thread
                         steps the SAME backend under the state cell lock
                         (the honest serve-while-train number).

``--check`` pins the tentpole claim: micro-batching must clear >= 2x the
single-request QPS while holding p99 under ``--p99-budget-ms``, and the
concurrent run must stay within the staleness bound (sync tables read 0
stale steps).

    PYTHONPATH=src python benchmarks/serving_latency.py --check --fast
"""
from __future__ import annotations

import argparse
import sys
import threading

import jax
import jax.numpy as jnp

from repro.launch.cluster import small_ctr_trainer
from repro.serving import (ServingConfig, ServingService, StateCell,
                           TrafficModel)

CONFIGS = [(4, 2.0), (8, 2.0), (16, 5.0)]   # (max_batch, max_wait_ms)


def _service(trainer, state, max_batch, max_wait_ms):
    cell = StateCell(state, 0)
    return cell, ServingService(
        trainer, cell, ServingConfig(max_batch=max_batch,
                                     max_wait_ms=max_wait_ms))


def _drive(svc, reqs, n_threads: int = 4):
    """Hammer the service from ``n_threads`` closed-loop clients; returns
    the service's own metrics dict."""
    chunk = max(len(reqs) // n_threads, 1)

    def worker(lo):
        for r in reqs[lo: lo + chunk]:
            svc.predict(r)

    threads = [threading.Thread(target=worker, args=(i * chunk,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return svc.metrics()


def run(requests: int = 256, steps: int = 0, results: dict | None = None):
    """benchmarks/run.py entry — CSV rows (name, us, derived).

    ``steps`` > 0 adds the serve-while-train row with that many concurrent
    trainer steps (0 sizes it off the request count)."""
    trainer, ds = small_ctr_trainer(mode="sync", backend="host_lru")
    sampler = ds.sampler(16, seed=0)
    first = {k: jnp.asarray(v) for k, v in next(sampler).items()}
    state = trainer.init(jax.random.PRNGKey(0), first)
    traffic = TrafficModel.for_dataset(ds, n_users=10_000)
    reqs = [r for _, r in traffic.requests(requests, seed=1)]
    warm = [r for _, r in traffic.requests(
        max(requests // 8, 8), seed=2)]

    rows, out = [], {}

    def measure(name, max_batch, max_wait_ms, train_steps=0):
        cell, svc = _service(trainer, state, max_batch, max_wait_ms)
        with svc:
            _drive(svc, warm)              # compile + cache warmup
        cell, svc = _service(trainer, state, max_batch, max_wait_ms)
        trainer_thread = None
        if train_steps:
            def train_loop():
                s = state
                for t in range(train_steps):
                    b = {k: jnp.asarray(v)
                         for k, v in next(sampler).items()}
                    with cell.lock:
                        s, _ = trainer.step(s, b)
                        cell.publish(s, t + 1)
            trainer_thread = threading.Thread(target=train_loop)
        with svc:
            if trainer_thread is not None:
                trainer_thread.start()
            m = _drive(svc, reqs)
            if trainer_thread is not None:
                trainer_thread.join()
        out[name] = m
        stale = max((v for k, v in m.items()
                     if k.endswith("/stale_steps")), default=0.0)
        rows.append((
            f"serving_latency/{name}",
            1e6 / max(m["serving/qps"], 1e-9),
            f"qps={m['serving/qps']:.1f} p50={m['serving/p50_ms']:.2f}ms "
            f"p99={m['serving/p99_ms']:.2f}ms "
            f"fill={m.get('serving/field_00/batch_fill', 0.0):.2f} "
            f"stale_max={stale:.0f}"))
        return m

    measure("single", 1, 0.0)
    for mb, mw in CONFIGS:
        measure(f"batch{mb}_wait{mw:g}", mb, mw)
    best = max((n for n in out if n.startswith("batch")),
               key=lambda n: out[n]["serving/qps"])
    mb, mw = next((c for c in CONFIGS
                   if f"batch{c[0]}_wait{c[1]:g}" == best))
    measure("train_concurrent", mb, mw,
            train_steps=steps or max(requests // 32, 4))

    if results is not None:
        results.update(out)
        results["best"] = best
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--steps", type=int, default=0,
                    help="concurrent trainer steps for the serve-while-"
                         "train row (0 = requests/32)")
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke sizing")
    ap.add_argument("--p99-budget-ms", type=float, default=250.0)
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless micro-batching >= 2x single-"
                         "request QPS at bounded p99, and the concurrent "
                         "run holds the sync staleness bound")
    args = ap.parse_args()
    requests = 64 if args.fast else args.requests
    results: dict = {}
    rows = run(requests=requests, steps=args.steps, results=results)
    print("name,us_per_call,derived")
    for n, us, derived in rows:
        print(f"{n},{us:.1f},{derived}")
    # repo root on the path so this also works as `python benchmarks/...`
    import os
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks.report import save_bench
    flat = {}
    for k, v in results.items():
        if isinstance(v, dict):
            flat.update({f"{k}/{kk}": vv for kk, vv in v.items()})
        else:
            flat[k] = v
    save_bench("serving_latency", rows, flat)
    if args.check:
        single = results["single"]["serving/qps"]
        best = results[results["best"]]
        speedup = best["serving/qps"] / max(single, 1e-9)
        conc = results["train_concurrent"]
        stale = max((v for k, v in conc.items()
                     if k.endswith("/stale_steps")), default=0.0)
        fails = []
        if speedup < 2.0:
            fails.append(f"micro-batching QPS {best['serving/qps']:.1f} < "
                         f"2x single-request {single:.1f}")
        if best["serving/p99_ms"] > args.p99_budget_ms:
            fails.append(f"p99 {best['serving/p99_ms']:.1f}ms exceeds "
                         f"budget {args.p99_budget_ms:.0f}ms")
        if stale > 0:
            fails.append(f"sync tables read {stale:.0f} stale steps "
                         "during concurrent training (bound is 0)")
        if fails:
            for f in fails:
                print(f"FAIL: {f}", file=sys.stderr)
            raise SystemExit(1)
        print(f"OK: batching {speedup:.1f}x single-request QPS, p99 "
              f"{best['serving/p99_ms']:.1f}ms <= "
              f"{args.p99_budget_ms:.0f}ms, concurrent stale_max=0")


if __name__ == "__main__":
    main()
