"""Paper Table 2 + Figure 7 analog: final AUC per training mode (hybrid /
sync / async) on the synthetic CTR benchmark family. The claim under test:
hybrid ~ sync (gap < ~0.005 here), async visibly worse."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import adapters
from repro.core.hybrid import PersiaTrainer, TrainMode
from repro.data.ctr import CTRDataset
from repro.optim.optimizers import OptConfig

DATASETS = {
    "taobao": CTRDataset("taobao", n_rows=8_000, n_fields=8, ids_per_field=4,
                         n_dense=8, zipf_a=1.3),
    "avazu": CTRDataset("avazu", n_rows=16_000, n_fields=16, ids_per_field=4,
                        n_dense=4, zipf_a=1.2),
    "criteo": CTRDataset("criteo", n_rows=32_000, n_fields=26,
                         ids_per_field=2, n_dense=13, zipf_a=1.1),
}

MODES = {
    "hybrid": TrainMode.hybrid(4),
    "sync": TrainMode.sync(),
    "async": TrainMode.async_(8, 8),
}


def _cfg(ds: CTRDataset) -> ModelConfig:
    return ModelConfig(name=f"{ds.name}-dlrm", arch_type="recsys",
                       n_id_fields=ds.n_fields,
                       ids_per_field=ds.ids_per_field, emb_dim=16,
                       emb_rows=ds.n_rows, n_dense_features=ds.n_dense,
                       mlp_dims=(128, 64, 32))


def train_mode(ds: CTRDataset, mode: TrainMode, steps=120, batch=512,
               seed=0, curve=False):
    cfg = _cfg(ds)
    adapter = adapters.recsys_adapter(cfg, lr=5e-2,
                                      field_rows=ds.field_rows())
    trainer = PersiaTrainer(adapter, mode, OptConfig(kind="adam", lr=5e-3))
    it = ds.sampler(batch, seed=seed)
    ev = ds.sampler(2048, seed=4242)
    eval_batch = {k: jnp.asarray(v) for k, v in next(ev).items()}
    b0 = {k: jnp.asarray(v) for k, v in next(it).items()}
    state = trainer.init(jax.random.PRNGKey(seed), b0)

    def eval_auc():
        preds = trainer.predict(state, eval_batch)
        return adapters.auc(np.asarray(eval_batch["labels"]),
                            np.asarray(preds))

    t0 = time.perf_counter()
    points = []
    for s in range(steps):
        b = {k: jnp.asarray(v) for k, v in next(it).items()}
        state, m = trainer.step(state, b)
        if curve and (s + 1) % 20 == 0:
            points.append((s + 1, eval_auc()))
    wall = time.perf_counter() - t0
    return eval_auc(), wall, points


def run(steps=120):
    rows = []
    for ds_name, ds in DATASETS.items():
        aucs = {}
        for mode_name, mode in MODES.items():
            auc, wall, _ = train_mode(ds, mode, steps=steps)
            aucs[mode_name] = auc
            rows.append((f"convergence/{ds_name}/{mode_name}",
                         wall / steps * 1e6,
                         f"auc={auc:.4f}"))
        gap_h = aucs["sync"] - aucs["hybrid"]
        gap_a = aucs["sync"] - aucs["async"]
        rows.append((f"convergence/{ds_name}/gaps", 0.0,
                     f"sync-hybrid={gap_h:+.4f} sync-async={gap_a:+.4f}"))
    return rows
