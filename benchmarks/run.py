"""Benchmark harness — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (plus a roofline section read from
the dry-run artifacts when present).

  convergence   Table 2 / Fig 7 — final AUC per mode
  end_to_end    Fig 6          — time/steps to target AUC
  scalability   Fig 3 / Fig 8  — phase Gantt + throughput-vs-K composition
  capacity      Fig 9          — throughput vs table scale, LRU tier, 100T
  compression   §4.2.3         — blockscale fp16 + lossless index dedup
  staleness     Thm 1          — tau & alpha sweeps vs the bound
  pipeline      Fig 4-5        — serial vs async-pipelined execution
  shard_scaling §4.1           — prepare fault-in latency vs PS shards
  dedup         §4.2.3         — worker-side batch dedup vs occurrence path
  remote_ps     §4.1           — in-process vs multi-process PS, wire bytes
  serving_latency §1/§4        — online serving p50/p99/QPS vs micro-batch
  cache_tiers   §4.2.2         — admission hit-rate, disk tier, prefetch
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import traceback

SUITES = ["compression", "scalability", "capacity", "convergence",
          "staleness", "end_to_end", "pipeline", "shard_scaling", "dedup",
          "remote_ps", "serving_latency", "cache_tiers", "emb_backward"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of suites")
    ap.add_argument("--fast", action="store_true",
                    help="shrink step counts (CI smoke)")
    args, _ = ap.parse_known_args()
    suites = args.only.split(",") if args.only else SUITES

    print("name,us_per_call,derived")
    failures = 0
    for name in suites:
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            kwargs = {}
            if args.fast and name in ("convergence", "staleness"):
                kwargs["steps"] = 40
            if args.fast and name == "pipeline":
                kwargs["steps"] = 8
            if args.fast and name == "shard_scaling":
                kwargs["steps"] = 5
            if args.fast and name == "dedup":
                kwargs["steps"] = 5
            if args.fast and name == "remote_ps":
                kwargs["steps"] = 5
            if args.fast and name == "emb_backward":
                kwargs["steps"] = 5
            if args.fast and name == "serving_latency":
                kwargs["requests"] = 64
            # cache_tiers keeps its default steps even under --fast: the
            # admission sketch needs ~100 steps of stream to warm past
            # its threshold, and the suite is cheap at that length
            if args.fast and name == "end_to_end":
                kwargs["target"] = 0.60
            rows = mod.run(**kwargs)
            for n, us, derived in rows:
                print(f"{n},{us:.1f},{derived}")
            sys.stdout.flush()
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},0.0,ERROR {type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)

    # roofline summary from the dry-run artifact, if present
    path = os.path.join(os.path.dirname(__file__), "..", "results",
                        "dryrun_matrix.json")
    if os.path.exists(path):
        rows = json.load(open(path))
        for r in rows:
            if r.get("status") == "ok" and r.get("mesh") == "16x16":
                print(f"roofline/{r['arch']}/{r['shape']},0.0,"
                      f"compute_s={r['compute_s']:.4f} "
                      f"memory_s={r['memory_s']:.4f} "
                      f"collective_s={r['collective_s']:.4f} "
                      f"dominant={r['dominant']}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
