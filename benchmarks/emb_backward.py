"""Fused embedding backward + blockscale cold-row storage (ISSUE 9).

Three measurements, one per tentpole claim:

* ``fused_vs_decomposed`` — the SAME put stream (dedup plans + occurrence
  grads at a dup-heavy CTR shape) is applied through the one-pass fused
  backward (``_hybrid_plan`` / ``_put_plan``, kernels/fused_backward.py:
  segment-sum + adagrad + queue payload in a single dispatch) and through
  the decomposed three-dispatch base path (``plan_segment_sum`` then
  ``_hybrid_unique``). States and queues must stay bit-equal; reported
  speedup plus the STRUCTURAL win: the decomposed path materializes the
  unique-width grad buffer between dispatches (one write + one read of
  cap x dim fp32 crossing the dispatch boundary), the fused pass never
  builds it.
* ``pallas_kernel`` — the Pallas kernel vs the jnp oracle at the same
  shape (interpret mode on CPU — Mosaic TPU is the deployment target, so
  timing is indicative; the closeness check is the load-bearing part).
* ``store_dtype`` — two identical host_lru hybrid training runs at
  ``dim=64``, fp32 vs blockscale16 cold rows (core/lru.py codec): row
  payload bytes must drop >= 1.9x while eval AUC moves <= 2e-3.
* ``tuned_host`` — a malloc-churn microbenchmark (the host put path's
  gather/scatter temporaries) run in two subprocesses: stock env vs the
  ``--tuned-host`` profile (launch/hostenv.py). Quantifies the free
  tcmalloc win; reports ``tcmalloc=absent`` and ratio ~1.0 when the lib
  is not installed (graceful no-op).

    PYTHONPATH=src python benchmarks/emb_backward.py --steps 40 --check

``--check`` enforces the PR bar: fused/decomposed bit-equality AND the
structural intermediate-bytes ratio >= 1.2x everywhere; the >= 1.2x
step-time bar only where the Pallas kernel actually compiles (TPU — the
CPU oracle fallback is exempt); storage payload >= 1.9x at <= 2e-3 AUC
delta.
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import adapters
from repro.core import backend as BK
from repro.core import dedup as D
from repro.core.dedup import DedupPlan
from repro.core.embedding_ps import EmbeddingSpec
from repro.core.hybrid import PersiaTrainer, TrainMode
from repro.data.ctr import CTRDataset
from repro.optim.optimizers import OptConfig

B, L, DIM = 256, 16, 32          # n_occ = 4096 put occurrences per step
ROWS, TAU, DUP = 8192, 3, 8      # ids drawn from a pool of n_occ/DUP keys
STORE_DIM = 64                   # the storage A/B dim (>= 2 codec blocks
STORE_ROWS = 4 * 2048            # never hit at 64 -- one scale per row)


def _plans(steps: int, seed: int = 0):
    """Pre-built (plan, grads) puts so plan construction stays outside
    the clock."""
    rng = np.random.default_rng(seed)
    pool = B * L // DUP
    cap = D.dedup_cap(B * L, ROWS)
    out = []
    for _ in range(steps):
        ids = rng.integers(-1, pool, (B, L))
        u_pad, inv, _, _ = D.make_plan(ids, ROWS, cap, floor=8)
        out.append((DedupPlan(dev=jnp.asarray(u_pad, jnp.int32),
                              inv=jnp.asarray(inv, jnp.int32)),
                    jnp.asarray(rng.standard_normal(
                        (B, L, DIM)).astype(np.float32))))
    return out, cap


def _decomposed_hybrid(b, state, queue, plan, grads):
    """The pre-fusion three-dispatch path: segment-sum to unique width,
    then the queue-push + apply dispatch re-reads that buffer."""
    g_u = D.plan_segment_sum(plan.inv, grads, int(plan.dev.shape[0]))
    return b._hybrid_unique(state, queue, plan.dev, g_u)


def _tree_bitequal(a, b) -> bool:
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _backward_ab(steps: int):
    """-> (fused_us, decomposed_us, bitequal, cap)."""
    spec = EmbeddingSpec(rows=ROWS, dim=DIM, lr=5e-2, staleness=TAU,
                         backend="dense")
    b = BK.DenseBackend(spec)
    puts, cap = _plans(steps + 2)
    sf = so = b.init(jax.random.PRNGKey(0))
    qf = b.queue_init((B, L))
    qo = jax.tree.map(jnp.copy, qf)
    for plan, grads in puts[:2]:            # compile outside the clock
        sf, qf, _ = b.hybrid_update(sf, qf, plan, grads)
        so, qo, _ = _decomposed_hybrid(b, so, qo, plan, grads)
    bitequal = _tree_bitequal((sf, qf), (so, qo))

    t0 = time.perf_counter()
    for plan, grads in puts[2:]:
        sf, qf, _ = b.hybrid_update(sf, qf, plan, grads)
    jax.block_until_ready(sf)
    fused_us = (time.perf_counter() - t0) / steps * 1e6

    t0 = time.perf_counter()
    for plan, grads in puts[2:]:
        so, qo, _ = _decomposed_hybrid(b, so, qo, plan, grads)
    jax.block_until_ready(so)
    dec_us = (time.perf_counter() - t0) / steps * 1e6
    return fused_us, dec_us, bitequal and _tree_bitequal((sf, qf), (so, qo)), \
        cap


def _pallas_row():
    """Kernel-vs-oracle closeness + indicative timing (cf. the
    dedup/unique_bag row). The push payload is bit-exact; table/acc sit in
    the documented ~1e-7 reduction-order class, hence allclose."""
    from repro.kernels import ops, ref
    rng = np.random.default_rng(0)
    R, Dm, U, n_occ = 512, DIM, 64, 256
    table = jnp.asarray(rng.standard_normal((R, Dm)).astype(np.float32))
    acc = jnp.asarray(rng.random(R).astype(np.float32))
    inv = jnp.asarray(rng.integers(-1, U, n_occ), jnp.int32)
    grads = jnp.asarray(rng.standard_normal((n_occ, Dm)).astype(np.float32))
    apply_idx = jnp.asarray(
        np.concatenate([rng.permutation(R)[:U // 2], [-1] * (U - U // 2)]),
        jnp.int32)
    apply_g = jnp.asarray(rng.standard_normal((U, Dm)).astype(np.float32))
    want = ref.fused_backward_ref(table, acc, inv, grads, apply_idx,
                                  apply_g, cap=U, lr=5e-2, eps=1e-8)
    got = ops.fused_backward(table, acc, inv, grads, apply_idx, apply_g,
                             lr=5e-2, eps=1e-8)
    for w, g in zip(want, got):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=2e-6, atol=2e-6)
    t0 = time.perf_counter()
    for _ in range(3):
        jax.block_until_ready(
            ops.fused_backward(table, acc, inv, grads, apply_idx, apply_g,
                               lr=5e-2, eps=1e-8))
    us = (time.perf_counter() - t0) / 3 * 1e6
    return ("emb_backward/pallas_kernel", us,
            f"kernel~=oracle(2e-6) R={R} D={Dm} U={U} n_occ={n_occ} "
            f"interpret={jax.default_backend() != 'tpu'}")


def _store_run(store_dtype: str, steps: int):
    """-> (per-step losses, eval AUC, payload bytes, steps/s) for a
    host_lru hybrid run whose cold rows live in ``store_dtype``."""
    ds = CTRDataset("embbw", n_rows=STORE_ROWS, n_fields=4, ids_per_field=2,
                    n_dense=13)
    cfg = ModelConfig(name="embbw", arch_type="recsys", n_id_fields=4,
                      ids_per_field=2, emb_dim=STORE_DIM, emb_rows=STORE_ROWS,
                      n_dense_features=13, mlp_dims=(64, 32), n_tasks=1)
    coll = adapters.ctr_collection(cfg, lr=5e-2, field_rows=ds.field_rows())
    coll = coll.with_backend("host_lru", 256).with_store_dtype(store_dtype)
    adapter = adapters.recsys_adapter(cfg, field_rows=ds.field_rows(),
                                      collection=coll)
    tr = PersiaTrainer(adapter, TrainMode.hybrid(2),
                       OptConfig(kind="adam", lr=1e-3))
    it = ds.sampler(64)
    bs = [{k: jnp.asarray(v) for k, v in next(it).items()}
          for _ in range(steps)]
    st = tr.init(jax.random.PRNGKey(0), bs[0])
    t0 = time.perf_counter()
    losses = []
    for bt in bs:
        st, m = tr.decomposed_step(st, bt)
        losses.append(np.float32(m["loss"]))
    jax.block_until_ready(st.emb)
    sps = steps / (time.perf_counter() - t0)
    ev = {k: jnp.asarray(v) for k, v in next(ds.sampler(2048, seed=7)).items()}
    a = adapters.auc(np.asarray(ev["labels"]),
                     np.asarray(tr.predict(st, ev)))
    payload = sum(bk.store.payload_bytes() for bk in tr.backends.values())
    return losses, a, payload, sps


_CHURN = r"""
import numpy as np, time
rng = np.random.default_rng(0)
pool = rng.standard_normal((1 << 15, 64)).astype(np.float32)
idx = rng.integers(0, 1 << 15, (160, 4096))
t0 = time.perf_counter()
for i in range(160):
    rows = pool[idx[i]]              # fancy gather -> fresh 1MB buffer
    upd = rows * 0.5 + 1.0           # two more full-width temporaries
    pool[idx[i]] = upd
print(time.perf_counter() - t0)
"""


def _tuned_host_row():
    """Stock vs tuned-host env on the malloc-churn shape of the host put
    path, each in its own subprocess (LD_PRELOAD only binds at start)."""
    from repro.launch.hostenv import find_tcmalloc, tuned_env
    lib = find_tcmalloc()
    base = {k: v for k, v in os.environ.items() if k != "LD_PRELOAD"}
    tuned = dict(base, **tuned_env())
    if lib:
        tuned["LD_PRELOAD"] = lib

    def once(env):
        out = subprocess.run([sys.executable, "-c", _CHURN], env=env,
                             capture_output=True, text=True, check=True)
        return float(out.stdout.strip().splitlines()[-1])

    once(base), once(tuned)           # warm the page cache both ways
    t_base = min(once(base) for _ in range(3))
    t_tuned = min(once(tuned) for _ in range(3))
    ratio = t_base / t_tuned
    return ("emb_backward/tuned_host", t_tuned * 1e6,
            f"stock={t_base*1e3:.1f}ms tuned={t_tuned*1e3:.1f}ms "
            f"speedup={ratio:.2f}x tcmalloc="
            f"{'present' if lib else 'absent'}")


def run(steps: int = 40, results: dict | None = None):
    """benchmarks/run.py entry — CSV rows (name, us, derived). Pass a dict
    as ``results`` to also receive the --check inputs."""
    fused_us, dec_us, bitequal, cap = _backward_ab(steps)
    # the decomposed path writes then re-reads the unique-width grad
    # buffer across its dispatch boundary; the fused pass never builds it
    inter = 2 * cap * DIM * 4
    rows = [(
        "emb_backward/fused_vs_decomposed", fused_us,
        f"fused={fused_us:.0f}us decomposed={dec_us:.0f}us "
        f"speedup={dec_us / fused_us:.2f}x bitequal={bitequal} "
        f"intermediate_bytes={inter} vs 0 cap={cap}")]
    rows.append(_pallas_row())

    l16, auc16, pay16, sps16 = _store_run("blockscale16", steps)
    l32, auc32, pay32, _ = _store_run("fp32", steps)
    pay_ratio = pay32 / pay16
    auc_delta = abs(auc32 - auc16)
    rows.append((
        "emb_backward/store_dtype", 1e6 / sps16,
        f"payload={pay16} vs fp32 {pay32} ({pay_ratio:.2f}x) "
        f"auc={auc16:.4f} vs {auc32:.4f} (delta={auc_delta:.4f}) "
        f"loss_delta={max(abs(a - b) for a, b in zip(l16, l32)):.2e} "
        f"dim={STORE_DIM}"))
    rows.append(_tuned_host_row())

    if results is not None:
        results.update(speedup=dec_us / fused_us, bitequal=bitequal,
                       inter_ratio=inter / 1.0, pay_ratio=pay_ratio,
                       auc_delta=auc_delta,
                       kernel_active=jax.default_backend() == "tpu")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless fused==decomposed bit-exact, "
                         "structural intermediate-bytes >= 1.2x, storage "
                         "payload >= 1.9x at <= 2e-3 AUC delta (and "
                         ">= 1.2x step time where the Pallas kernel "
                         "compiles — the CPU oracle fallback is exempt)")
    args = ap.parse_args()
    results: dict = {}
    rows = run(args.steps, results)
    print("name,us_per_call,derived")
    for n, us, derived in rows:
        print(f"{n},{us:.1f},{derived}")
    # repo root on the path so this also works as `python benchmarks/...`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks.report import save_bench
    save_bench("emb_backward", rows, results)
    if args.check:
        ok = True
        if not results["bitequal"]:
            print("FAIL: fused backward diverges from the decomposed path",
                  file=sys.stderr)
            ok = False
        if results["inter_ratio"] < 1.2:
            print(f"FAIL: intermediate-bytes ratio "
                  f"{results['inter_ratio']:.2f}x < 1.2x", file=sys.stderr)
            ok = False
        if results["kernel_active"] and results["speedup"] < 1.2:
            print(f"FAIL: fused step-time speedup {results['speedup']:.2f}x "
                  "< 1.2x with the Pallas kernel active", file=sys.stderr)
            ok = False
        if results["pay_ratio"] < 1.9:
            print(f"FAIL: blockscale16 payload ratio "
                  f"{results['pay_ratio']:.2f}x < 1.9x at dim {STORE_DIM}",
                  file=sys.stderr)
            ok = False
        if results["auc_delta"] > 2e-3:
            print(f"FAIL: blockscale16 AUC delta {results['auc_delta']:.4f} "
                  "> 2e-3", file=sys.stderr)
            ok = False
        if not ok:
            raise SystemExit(1)
        print(f"OK: bit-equal; speedup {results['speedup']:.2f}x "
              f"(kernel_active={results['kernel_active']}); payload "
              f"{results['pay_ratio']:.2f}x; AUC delta "
              f"{results['auc_delta']:.4f}")


if __name__ == "__main__":
    main()
