"""Sharded embedding-PS scaling (paper §4.1): prepare-phase fault-in
latency vs shard count on a miss-heavy out-of-core workload.

The ShardedBackend router (core/backend.py) faults each PS shard in
concurrently under per-shard locks — the claim is that host-side fault-in
latency drops near-linearly with shards. This benchmark pins that: a
host_lru CTR trainer with a device cache far smaller than the table and
near-uniform id traffic (so most unique ids miss every step) runs the same
step stream at 1 / 2 / 4 shards, with a *simulated* per-row host fetch
latency injected into every shard's ``LRUEmbeddingStore.read_rows`` (a
stand-in for the PS-node RAM/RPC path; ``time.sleep`` releases the GIL, so
it overlaps exactly as a real remote fetch would). Reported per shard
count: prepare-phase ms/step, end-to-end steps/s, and the shard
load-imbalance gauge.

Runs standalone (the CI smoke invocation) or under benchmarks/run.py:

    PYTHONPATH=src python benchmarks/shard_scaling.py --steps 5
    PYTHONPATH=src python benchmarks/shard_scaling.py --check   # >= 1.5x bar
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import adapters
from repro.core import backend as BK
from repro.core.hybrid import PersiaTrainer, TrainMode
from repro.data.ctr import CTRDataset
from repro.optim.optimizers import OptConfig

N_FIELDS, ROWS_PER_FIELD, DIM = 2, 65536, 16
CACHE_ROWS = 4096                  # device cache << table: out-of-core
BATCH = 512
IDS_PER_FIELD = 4
# simulated host fetch latency per faulted row. Chosen so the simulated
# host tier dominates the prepare phase (as it does in a real deployment,
# where the fetch crosses an RPC to a PS node) rather than this process's
# fixed per-dispatch overhead, which a single-device simulation cannot
# parallelize away.
SIM_US_PER_ROW = 150.0
SHARD_COUNTS = (1, 2, 4)


def _trainer(shards: int) -> tuple[CTRDataset, PersiaTrainer]:
    ds = CTRDataset("shardscale", n_rows=N_FIELDS * ROWS_PER_FIELD,
                    n_fields=N_FIELDS, ids_per_field=IDS_PER_FIELD,
                    n_dense=8, zipf_a=1.05)    # near-uniform: miss-heavy
    cfg = ModelConfig(name="shardscale", arch_type="recsys",
                      n_id_fields=N_FIELDS, ids_per_field=IDS_PER_FIELD,
                      emb_dim=DIM,
                      emb_rows=N_FIELDS * ROWS_PER_FIELD, n_dense_features=8,
                      mlp_dims=(64, 32), n_tasks=1)
    coll = adapters.ctr_collection(cfg, lr=5e-2, field_rows=ds.field_rows())
    coll = coll.with_backend("host_lru", CACHE_ROWS)
    if shards > 1:
        coll = coll.with_shards(shards)
    adapter = adapters.recsys_adapter(cfg, field_rows=ds.field_rows(),
                                      collection=coll)
    return ds, PersiaTrainer(adapter, TrainMode.hybrid(2),
                             OptConfig(kind="adam", lr=1e-3))


def _host_stores(trainer: PersiaTrainer):
    for bk in trainer.backends.values():
        inner = BK.unwrap(bk)
        subs = (inner.shard_backends
                if isinstance(inner, BK.ShardedBackend) else [inner])
        for sub in subs:
            yield sub.store


def _inject_fault_latency(trainer: PersiaTrainer, us_per_row: float):
    """Wrap every shard store's read_rows with a sleep proportional to the
    rows fetched — the per-shard simulated host latency. Sleeps overlap
    across the router's fault-in threads, serial code pays them in full."""
    for store in _host_stores(trainer):
        orig = store.read_rows

        def slow(ids, _orig=orig, _us=us_per_row):
            time.sleep(np.size(ids) * _us * 1e-6)
            return _orig(ids)

        store.read_rows = slow


def _time_prepares(trainer: PersiaTrainer, acc: list):
    """Accumulate wall time spent inside every table's prepare (the
    fault-in phase) into acc[0]."""
    for bk in trainer.backends.values():
        orig = bk.prepare

        def timed(state, ids, *a, _orig=orig, **kw):
            t0 = time.perf_counter()
            out = _orig(state, ids, *a, **kw)
            acc[0] += time.perf_counter() - t0
            return out

        bk.prepare = timed


def measure(shards: int, steps: int):
    """-> (prepare_ms_per_step, steps_per_s, imbalance, total_faults)."""
    ds, tr = _trainer(shards)
    it = ds.sampler(BATCH)
    batches = [{k: jnp.asarray(v) for k, v in next(it).items()}
               for _ in range(steps)]
    # compile pass: replay the EXACT measurement batches once from a cold
    # state, so every pow2 fault-bucket shape the timed run will hit is
    # already compiled; then re-init back to the same cold state
    state = tr.init(jax.random.PRNGKey(0), batches[0])
    for b in batches:
        state, _ = tr.decomposed_step(state, b)
    state = tr.init(jax.random.PRNGKey(0), batches[0])
    _inject_fault_latency(tr, SIM_US_PER_ROW)
    prep = [0.0]
    _time_prepares(tr, prep)
    m = {}
    t0 = time.perf_counter()
    for b in batches:
        state, m = tr.decomposed_step(state, b)
    jax.block_until_ready(state.dense)
    wall = time.perf_counter() - t0
    imb = max((float(v) for k, v in m.items() if k.endswith("/imbalance")),
              default=1.0)
    faults = sum(int(sub.faults) for bk in tr.backends.values()
                 for sub in (BK.unwrap(bk).shard_backends
                             if isinstance(BK.unwrap(bk), BK.ShardedBackend)
                             else [BK.unwrap(bk)]))
    return prep[0] / steps * 1e3, steps / wall, imb, faults


def run(steps: int = 30, results: dict | None = None):
    """benchmarks/run.py entry — CSV rows (name, us, derived). Pass a dict
    as ``results`` to also receive {shards: prepare_ms_per_step}."""
    rows = []
    for shards in SHARD_COUNTS:
        prep_ms, steps_s, imb, faults = measure(shards, steps)
        if results is not None:
            results[shards] = prep_ms
        rows.append((
            f"shard_scaling/host_lru/x{shards}", prep_ms * 1e3,
            f"prepare={prep_ms:.2f}ms/step steps_per_s={steps_s:.1f} "
            f"imbalance={imb:.2f} faults={faults} "
            f"sim_latency={SIM_US_PER_ROW:.0f}us/row cache={CACHE_ROWS}"))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless 4 shards cut the prepare "
                         "phase >= 1.5x vs 1 shard under simulated host "
                         "latency")
    args = ap.parse_args()
    results: dict = {}
    rows = run(args.steps, results)
    print("name,us_per_call,derived")
    for n, us, derived in rows:
        print(f"{n},{us:.1f},{derived}")
    # repo root on the path so this also works as `python benchmarks/...`
    import os
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks.report import save_bench
    save_bench("shard_scaling", rows,
               {f"shards{k}": v for k, v in results.items()})
    if args.check:
        speedup = results[1] / results[4]
        if speedup < 1.5:
            print(f"FAIL: 4-shard prepare speedup {speedup:.2f}x < 1.5x",
                  file=sys.stderr)
            raise SystemExit(1)
        print(f"OK: 4-shard prepare speedup {speedup:.2f}x >= 1.5x")


if __name__ == "__main__":
    main()
