"""Paper Figure 3 (Gantt) + Figure 8 (scaling) analog.

One CPU cannot overlap anything, so we do what the paper's Gantt chart does:
measure the five phases of one training iteration separately —
  E  embedding lookup (get)        F  NN forward
  B  NN backward                   S  dense gradient synchronisation
  U  embedding update (put)
— then compose the per-iteration makespan of each execution mode:

  fully sync    : E + F + B + S + U            (everything serial)
  fully async   : max(F + B, E, U)             (E, S, U all hidden; no S)
  hybrid (raw)  : F + B + S                    (E, U hidden)
  hybrid (opt)  : F + max(B, S)                (S overlapped with B too)

S is modelled with a ring-allreduce cost over K workers at the paper's
100 Gbps fabric; E/U carry a PS round-trip with the same bandwidth. That
yields throughput-vs-K curves (Fig 8) from measured compute phases.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.convergence import DATASETS, _cfg
from repro.core import adapters
from repro.core.hybrid import PersiaTrainer, TrainMode
from repro.optim.optimizers import OptConfig, make_optimizer
from repro.utils import tree_bytes

BW_BYTES_S = 100e9 / 8            # paper cluster: 100 Gbps
LAT_S = 20e-6


def _time(fn, *args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def measure_phases(ds, batch=512, seed=0):
    cfg = _cfg(ds)
    adapter = adapters.recsys_adapter(cfg, lr=5e-2,
                                      field_rows=ds.field_rows())
    opt_init, opt_update = make_optimizer(OptConfig(kind="adam", lr=5e-3))
    trainer = PersiaTrainer(adapter, TrainMode.sync(),
                            (opt_init, opt_update))
    coll = trainer.collection
    it = ds.sampler(batch, seed=seed)
    b = {k: jnp.asarray(v) for k, v in next(it).items()}
    state = trainer.init(jax.random.PRNGKey(0), b)
    ids = adapter.emb_ids(b)

    lookup = jax.jit(lambda st, idd: coll.lookup(st, idd))
    acts = lookup(state.emb, ids)

    def fwd(dense, acts, b):
        return adapter.loss(dense, acts, b)[0]

    fwd_j = jax.jit(fwd)
    grad_j = jax.jit(jax.grad(fwd, argnums=(0, 1)))
    dgrads, agrads = grad_j(state.dense, acts, b)
    upd_j = jax.jit(lambda d, g, o: opt_update(d, g, o, lr=None))
    put_j = jax.jit(lambda st, idd, g: coll.apply_put(st, idd, g))

    t_E = _time(lookup, state.emb, ids)
    t_F = _time(fwd_j, state.dense, acts, b)
    t_FB = _time(grad_j, state.dense, acts, b)
    t_B = max(t_FB - t_F, 1e-9)
    t_opt = _time(upd_j, state.dense, dgrads, state.opt)
    t_U = _time(put_j, state.emb, ids, agrads)

    dense_bytes = tree_bytes(state.dense)
    emb_act_bytes = sum(a.size * a.dtype.itemsize for a in acts.values())
    return dict(E=t_E, F=t_F, B=t_B, OPT=t_opt, U=t_U,
                dense_bytes=dense_bytes, emb_act_bytes=emb_act_bytes,
                batch=batch)


def makespans(ph, K):
    """Per-iteration time per mode at K workers (per-worker batch fixed)."""
    S = 2 * (K - 1) / max(K, 1) * ph["dense_bytes"] / BW_BYTES_S + LAT_S
    # PS round trip for embedding activations/grads
    ps = ph["emb_act_bytes"] / BW_BYTES_S + LAT_S
    E, F, B, U = ph["E"] + ps, ph["F"], ph["B"], ph["U"] + ps
    return {
        "sync": E + F + B + S + ph["OPT"] + U,
        "async": max(F + B, E, U),
        "hybrid_raw": F + B + S + ph["OPT"],
        "hybrid_opt": F + max(B, S) + ph["OPT"],
    }


def run():
    rows = []
    ds = DATASETS["criteo"]
    ph = measure_phases(ds)
    rows.append(("scalability/phases", ph["F"] * 1e6,
                 f"E={ph['E']*1e3:.2f}ms F={ph['F']*1e3:.2f}ms "
                 f"B={ph['B']*1e3:.2f}ms U={ph['U']*1e3:.2f}ms "
                 f"opt={ph['OPT']*1e3:.2f}ms"))
    base = None
    for K in (1, 2, 4, 8, 16, 32, 64):
        ms = makespans(ph, K)
        thr = {m: K * ph["batch"] / t for m, t in ms.items()}
        if base is None:
            base = thr
        rows.append((f"scalability/K={K}", ms["hybrid_opt"] * 1e6,
                     " ".join(f"{m}={thr[m]:,.0f}/s" for m in ms)))
    ms64 = makespans(ph, 64)
    rows.append(("scalability/speedup@64", 0.0,
                 f"hybrid_vs_sync={ms64['sync']/ms64['hybrid_opt']:.2f}x "
                 f"async_vs_hybrid={ms64['hybrid_opt']/ms64['async']:.2f}x"))
    return rows
