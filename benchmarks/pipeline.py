"""Paper Fig. 4–5 analog: serial vs pipelined execution of the hybrid
trainer.

For each backend (``dense`` device PS, ``host_lru`` out-of-core) the same
decomposed step stream runs twice — serially through
``PersiaTrainer.run`` and through the five-stage ``PipelinedTrainer`` —
and we report steps/sec plus the speedup. The host tier's latency is
*simulated*: the per-step dense compute time is measured first and the same
amount is injected as ``prepare``-stage latency via ``delay_fn`` (a stand-in
for the embedding-PS RPC + host fault-in the paper hides behind the dense
pass). The serial loop pays that latency on the critical path; the pipeline
overlaps it with the dense stage, which is exactly the paper's claim.

Runs standalone (the CI smoke invocation) or under benchmarks/run.py:

    PYTHONPATH=src python benchmarks/pipeline.py --steps 5
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import adapters
from repro.core.hybrid import PersiaTrainer, TrainMode
from repro.core.pipeline import PipelinedTrainer
from repro.data.ctr import CTRDataset
from repro.optim.optimizers import OptConfig

N_FIELDS, ROWS_PER_FIELD, DIM = 4, 4096, 16


def _dataset() -> CTRDataset:
    return CTRDataset("pipe", n_rows=N_FIELDS * ROWS_PER_FIELD,
                      n_fields=N_FIELDS, ids_per_field=2, n_dense=13)


def _trainer(backend: str, tau: int = 3) -> tuple[CTRDataset, PersiaTrainer]:
    ds = _dataset()
    cfg = ModelConfig(name="pipe", arch_type="recsys", n_id_fields=N_FIELDS,
                      ids_per_field=2, emb_dim=DIM,
                      emb_rows=N_FIELDS * ROWS_PER_FIELD, n_dense_features=13,
                      mlp_dims=(1024, 512, 256), n_tasks=1)
    coll = adapters.ctr_collection(cfg, lr=5e-2, field_rows=ds.field_rows())
    if backend != "dense":
        coll = coll.with_backend(backend, ROWS_PER_FIELD // 2)
    adapter = adapters.recsys_adapter(cfg, field_rows=ds.field_rows(),
                                      collection=coll)
    return ds, PersiaTrainer(adapter, TrainMode.hybrid(tau),
                             OptConfig(kind="adam", lr=1e-3))


def _batches(ds: CTRDataset, n: int, batch: int = 128):
    it = ds.sampler(batch)
    return [{k: jnp.asarray(v) for k, v in next(it).items()}
            for _ in range(n)]


def compare(backend: str, steps: int, host_latency_s: float,
            max_inflight: int = 4):
    """(serial steps/s, pipelined steps/s, speedup) with ``host_latency_s``
    injected into the prepare stage of BOTH runs."""
    def delay(stage: str, _idx: int) -> float:
        return host_latency_s if stage == "prepare" else 0.0

    ds, tr_s = _trainer(backend)
    bs = _batches(ds, steps + 4)
    st = tr_s.init(jax.random.PRNGKey(0), bs[0])
    st, _ = tr_s.run(st, bs[:4])                 # compile outside the clock
    t0 = time.perf_counter()
    st, _ = tr_s.run(st, bs[4:], delay_fn=delay)
    jax.block_until_ready(st.dense)
    serial_s = (time.perf_counter() - t0) / steps

    _, tr_p = _trainer(backend)
    engine = PipelinedTrainer(tr_p, max_inflight=max_inflight)
    st = engine.init(jax.random.PRNGKey(0), bs[0])
    st, _ = engine.run(st, bs[:4])
    t0 = time.perf_counter()
    st, _ = engine.run(st, bs[4:], delay_fn=delay)
    jax.block_until_ready(st.dense)
    pipe_s = (time.perf_counter() - t0) / steps
    return 1.0 / serial_s, 1.0 / pipe_s, serial_s / pipe_s, engine


def run(steps: int = 30, speedups: dict | None = None):
    """benchmarks/run.py entry — CSV rows (name, us, derived). Pass a dict
    as ``speedups`` to also receive {row_name: speedup_float}."""
    rows = []
    for backend in ("dense", "host_lru"):
        # the nolat pass doubles as the latency calibration: the simulated
        # host latency for the hostlat pass is one serial step — the regime
        # the paper targets (memory-bound embedding path comparable to the
        # compute-bound dense path, so overlap is what throughput buys)
        lat = 0.0
        for tag in ("nolat", "hostlat"):
            ser, pipe, speedup, engine = compare(backend, steps, lat)
            pm = engine.pipeline_metrics()
            if speedups is not None:
                speedups[f"pipeline/{backend}/{tag}"] = speedup
            rows.append((
                f"pipeline/{backend}/{tag}", 1e6 / pipe,
                f"serial={ser:.1f}steps/s pipelined={pipe:.1f}steps/s "
                f"speedup={speedup:.2f}x latency={lat*1e3:.1f}ms "
                f"prepare_busy={pm['pipeline/prepare/busy_s']:.2f}s "
                f"dense_busy={pm['pipeline/dense/busy_s']:.2f}s"))
            lat = 1.0 / ser
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless the pipelined host_lru run "
                         "with simulated host latency is >= 1.3x serial")
    args = ap.parse_args()
    speedups: dict = {}
    rows = run(args.steps, speedups)
    print("name,us_per_call,derived")
    for n, us, derived in rows:
        print(f"{n},{us:.1f},{derived}")
    # repo root on the path so this also works as `python benchmarks/...`
    import os
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks.report import save_bench
    save_bench("pipeline", rows, speedups)
    if args.check:
        speedup = speedups["pipeline/host_lru/hostlat"]
        if speedup < 1.3:
            print(f"FAIL: pipelined host_lru speedup {speedup:.2f}x < 1.3x",
                  file=sys.stderr)
            raise SystemExit(1)
        print(f"OK: pipelined host_lru speedup {speedup:.2f}x >= 1.3x")


if __name__ == "__main__":
    main()
