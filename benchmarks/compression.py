"""Paper §4.2.3 compression benchmarks: lossy blockscale fp16 (Pallas
kernel, interpret mode on CPU) error/latency + bytes saved, and lossless
index compression ratio on Zipf-distributed multi-hot batches."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import time_call
from repro.core import compression as C
from repro.kernels import ops


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    for n in (1 << 12, 1 << 16):
        v = jax.random.normal(key, (n, 128)) * jnp.exp(
            jax.random.normal(key, (n, 1)) * 3)
        us_c = time_call(ops.blockscale_compress, v)
        comp, scales = ops.blockscale_compress(v)
        us_d = time_call(ops.blockscale_decompress, comp, scales)
        back = ops.blockscale_decompress(comp, scales)
        rel = float(jnp.max(jnp.abs(back - v))
                    / jnp.maximum(jnp.max(jnp.abs(v)), 1e-30))
        raw = v.size * 4
        compressed = comp.size * 2 + scales.size * 4
        rows.append((f"compression/blockscale_n={n}", us_c,
                     f"decomp_us={us_d:.0f} max_rel_err={rel:.2e} "
                     f"ratio={raw/compressed:.2f}x"))
    # uniform fp16 vs blockscale on a wide-dynamic-range put (paper's case)
    v = jnp.concatenate([jnp.full((128,), 3e4), jnp.full((128,), 3e-6)])
    ours = np.asarray(ops.blockscale_roundtrip(v.reshape(2, 128)))
    unif = np.asarray(v.astype(jnp.float16).astype(jnp.float32))
    e_ours = np.max(np.abs(ours.reshape(-1) - np.asarray(v))
                    / np.abs(np.asarray(v)))
    e_unif = np.max(np.abs(unif - np.asarray(v)) / np.abs(np.asarray(v)))
    rows.append(("compression/nonuniform_vs_uniform", 0.0,
                 f"blockscale_rel={e_ours:.2e} uniform_fp16_rel={e_unif:.2e}"))

    rng = np.random.default_rng(0)
    for a in (1.1, 1.5, 2.0):
        ids = (rng.zipf(a, (4096, 8)) % 100_000).astype(np.int64)
        ratio = C.index_compression_ratio(ids)
        rows.append((f"compression/index_zipf{a}", 0.0,
                     f"lossless_ratio={ratio:.2f}x"))
    # on-device dedup put aggregation win
    ids = jnp.asarray((rng.zipf(1.3, 8192) % 2048).astype(np.int32))
    g = jnp.ones((8192, 32), jnp.float32)
    us = time_call(lambda i, gg: C.dedup_put(i, gg, capacity=2048), ids, g)
    u, _ = C.dedup_put(ids, g, capacity=2048)
    uniq = int(jnp.sum(u >= 0))
    rows.append(("compression/dedup_put", us,
                 f"rows_sent={uniq}/{ids.size} "
                 f"traffic_saving={ids.size/max(uniq,1):.2f}x"))
    return rows
