"""Paper §4.2.3 compression benchmarks: lossy blockscale fp16 (Pallas
kernel, interpret mode on CPU) error/latency + bytes saved, lossless
index compression ratio on Zipf-distributed multi-hot batches, and the
CompressedWireBackend end-to-end: bytes moved + AUC with and without the
compressed wire through PersiaTrainer's decomposed pipeline."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import time_call
from repro.core import compression as C
from repro.kernels import ops


def wire_backend_end_to_end(steps: int = 60, batch: int = 256):
    """Train the same CTR model with backend='dense' and 'dense+compressed';
    report the measured wire bytes-moved ratio and both AUCs (the lossy
    blockscale wire is designed to be AUC-neutral)."""
    from repro.configs.base import ModelConfig
    from repro.core import adapters
    from repro.core.hybrid import PersiaTrainer, TrainMode
    from repro.data.ctr import CTRDataset
    from repro.optim.optimizers import OptConfig

    ds = CTRDataset("wire", n_rows=40_000, n_fields=8, ids_per_field=4,
                    n_dense=8)
    cfg = ModelConfig(name="wire", arch_type="recsys", n_id_fields=8,
                      ids_per_field=4, emb_dim=32, emb_rows=40_000,
                      n_dense_features=8, mlp_dims=(64, 32))

    def train(backend):
        coll = adapters.ctr_collection(cfg, lr=5e-2,
                                       field_rows=ds.field_rows())
        coll = coll.with_backend(backend)
        adapter = adapters.recsys_adapter(cfg, field_rows=ds.field_rows(),
                                          collection=coll)
        trainer = PersiaTrainer(adapter, TrainMode.hybrid(2),
                                OptConfig(kind="adam", lr=5e-3))
        it = ds.sampler(batch)
        b = {k: jnp.asarray(v) for k, v in next(it).items()}
        state = trainer.init(jax.random.PRNGKey(0), b)
        raw = wire = 0.0
        for _ in range(steps):
            b = {k: jnp.asarray(v) for k, v in next(it).items()}
            state, m = trainer.decomposed_step(state, b)
            raw += sum(float(v) for k, v in m.items()
                       if k.startswith("wire/") and k.endswith("bytes_raw"))
            wire += sum(float(v) for k, v in m.items()
                        if k.startswith("wire/") and k.endswith("bytes_wire"))
        eb = {k: jnp.asarray(v) for k, v in next(ds.sampler(2048,
                                                            seed=9)).items()}
        preds = trainer.predict(state, eb)
        a = adapters.auc(np.asarray(eb["labels"]), np.asarray(preds))
        return raw, wire, a

    raw, wire, auc_c = train("dense+compressed")
    _, _, auc_d = train("dense")
    return raw, wire, auc_c, auc_d


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    for n in (1 << 12, 1 << 16):
        v = jax.random.normal(key, (n, 128)) * jnp.exp(
            jax.random.normal(key, (n, 1)) * 3)
        us_c = time_call(ops.blockscale_compress, v)
        comp, scales = ops.blockscale_compress(v)
        us_d = time_call(ops.blockscale_decompress, comp, scales)
        back = ops.blockscale_decompress(comp, scales)
        rel = float(jnp.max(jnp.abs(back - v))
                    / jnp.maximum(jnp.max(jnp.abs(v)), 1e-30))
        raw = v.size * 4
        compressed = comp.size * 2 + scales.size * 4
        rows.append((f"compression/blockscale_n={n}", us_c,
                     f"decomp_us={us_d:.0f} max_rel_err={rel:.2e} "
                     f"ratio={raw/compressed:.2f}x"))
    # uniform fp16 vs blockscale on a wide-dynamic-range put (paper's case)
    v = jnp.concatenate([jnp.full((128,), 3e4), jnp.full((128,), 3e-6)])
    ours = np.asarray(ops.blockscale_roundtrip(v.reshape(2, 128)))
    unif = np.asarray(v.astype(jnp.float16).astype(jnp.float32))
    e_ours = np.max(np.abs(ours.reshape(-1) - np.asarray(v))
                    / np.abs(np.asarray(v)))
    e_unif = np.max(np.abs(unif - np.asarray(v)) / np.abs(np.asarray(v)))
    rows.append(("compression/nonuniform_vs_uniform", 0.0,
                 f"blockscale_rel={e_ours:.2e} uniform_fp16_rel={e_unif:.2e}"))

    rng = np.random.default_rng(0)
    for a in (1.1, 1.5, 2.0):
        ids = (rng.zipf(a, (4096, 8)) % 100_000).astype(np.int64)
        ratio = C.index_compression_ratio(ids)
        rows.append((f"compression/index_zipf{a}", 0.0,
                     f"lossless_ratio={ratio:.2f}x"))
    # on-device dedup put aggregation win
    ids = jnp.asarray((rng.zipf(1.3, 8192) % 2048).astype(np.int32))
    g = jnp.ones((8192, 32), jnp.float32)
    us = time_call(lambda i, gg: C.dedup_put(i, gg, capacity=2048), ids, g)
    u, _ = C.dedup_put(ids, g, capacity=2048)
    uniq = int(jnp.sum(u >= 0))
    rows.append(("compression/dedup_put", us,
                 f"rows_sent={uniq}/{ids.size} "
                 f"traffic_saving={ids.size/max(uniq,1):.2f}x"))
    # the CompressedWireBackend end-to-end: measured bytes moved + AUC parity
    raw, wire, auc_c, auc_d = wire_backend_end_to_end()
    rows.append(("compression/wire_backend_e2e", 0.0,
                 f"bytes_moved_reduction={raw/max(wire,1.0):.2f}x "
                 f"auc_compressed={auc_c:.4f} auc_dense={auc_d:.4f} "
                 f"auc_delta={abs(auc_c-auc_d):.4f}"))
    return rows
