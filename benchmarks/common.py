"""Shared benchmark plumbing."""
from __future__ import annotations

import time

import jax


def time_call(fn, *args, warmup=2, iters=5):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6          # us


def row(name: str, us: float, derived: str) -> tuple:
    return (name, us, derived)
