"""Paper Figure 9 analog: training throughput must stay flat as the
embedding table scales (Criteo-Syn family, up to 100T parameters).

Device side: per-step time of the hybrid step while the device-resident
table grows 64x — lookups are O(batch), not O(rows), so the curve is flat.
Out-of-core side: the SAME model trained through PersiaTrainer with the
``host_lru`` storage backend — logical rows grow 8..64x past a fixed device
cache, faults/write-backs move rows over the host boundary, and the
device-resident bytes stay constant while host-resident bytes grow.
Host side: raw LRUEmbeddingStore get/put throughput vs working-set size,
plus the 100T deployment arithmetic (rows x dim x fp32 across 30 PS nodes,
as in the paper's GCP run).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import adapters
from repro.core.hybrid import PersiaTrainer, TrainMode
from repro.core.lru import LRUEmbeddingStore
from repro.data.ctr import CTRDataset, criteo_syn_rows
from repro.optim.optimizers import OptConfig


def _syn_trainer(rows: int, backend: str = "dense", cache_rows: int = 0,
                 n_fields: int = 26, tau: int = 2):
    ds = CTRDataset("syn", n_rows=rows, n_fields=n_fields, ids_per_field=2,
                    n_dense=13)
    cfg = ModelConfig(name="syn", arch_type="recsys", n_id_fields=n_fields,
                      ids_per_field=2, emb_dim=16, emb_rows=rows,
                      n_dense_features=13, mlp_dims=(128, 64))
    coll = adapters.ctr_collection(cfg, field_rows=ds.field_rows())
    coll = coll.with_backend(backend, cache_rows or None)
    adapter = adapters.recsys_adapter(cfg, field_rows=ds.field_rows(),
                                      collection=coll)
    trainer = PersiaTrainer(adapter, TrainMode.hybrid(tau),
                            OptConfig(kind="adam", lr=1e-3))
    return ds, trainer


def step_time_for_rows(rows: int, batch=512, iters=5, backend="dense",
                       cache_rows=0, n_fields=26):
    ds, trainer = _syn_trainer(rows, backend, cache_rows, n_fields)
    it = ds.sampler(batch)
    b = {k: jnp.asarray(v) for k, v in next(it).items()}
    state = trainer.init(jax.random.PRNGKey(0), b)
    # decomposed pipeline — the runtime-faithful path (separate get / dense /
    # put dispatches; host_lru additionally runs the host fault-in phase)
    state, _ = trainer.decomposed_step(state, b)
    jax.block_until_ready(state.emb)
    t0 = time.perf_counter()
    for _ in range(iters):
        b = {k: jnp.asarray(v) for k, v in next(it).items()}
        state, _ = trainer.decomposed_step(state, b)
    jax.block_until_ready(state.emb)
    return (time.perf_counter() - t0) / iters, trainer, state


def out_of_core_rows(scale: int, cache_rows=12_500, batch=512, n_fields=8):
    """Train with logical rows = scale x cache_rows per field through the
    host_lru backend; report step time, fault traffic and residency split."""
    rows = scale * cache_rows * n_fields
    dt, trainer, state = step_time_for_rows(
        rows, batch=batch, iters=5, backend="host_lru",
        cache_rows=cache_rows, n_fields=n_fields)
    dev = host = faults = wbacks = 0
    for n in trainer.collection.names:
        bk = trainer.backends[n]
        dev += bk.device_bytes(state.emb[n])
        host += bk.host_bytes()
        faults += bk.faults
        wbacks += bk.writebacks
    return dt, dev, host, faults, wbacks


def lru_throughput(capacity: int, n_ops=20_000, dim=32) -> float:
    store = LRUEmbeddingStore(capacity, dim=dim)
    rng = np.random.default_rng(0)
    ids = rng.zipf(1.3, n_ops) % (capacity * 4)
    t0 = time.perf_counter()
    chunk = 512
    for i in range(0, n_ops, chunk):
        store.get(ids[i: i + chunk])
    return n_ops / (time.perf_counter() - t0)


def run():
    rows = []
    base = None
    for r in (100_000, 400_000, 1_600_000, 6_400_000):
        t, _, _ = step_time_for_rows(r)
        if base is None:
            base = t
        rows.append((f"capacity/device_rows={r}", t * 1e6,
                     f"step={t*1e3:.2f}ms ratio_to_smallest={t/base:.2f}"))
    # out-of-core: logical rows grow 8x..32x past a FIXED device cache —
    # the host_lru backend keeps device bytes flat while host bytes grow
    base_ooc = None
    for scale in (8, 16, 32):
        t, dev, host, faults, wbacks = out_of_core_rows(scale)
        if base_ooc is None:
            base_ooc = t
        rows.append((
            f"capacity/host_lru_rows={scale}x_cache", t * 1e6,
            f"step={t*1e3:.2f}ms ratio_to_8x={t/base_ooc:.2f} "
            f"device_res={dev/2**20:.1f}MiB host_res={host/2**20:.1f}MiB "
            f"faults={faults} writebacks={wbacks}"))
    for cap in (10_000, 100_000, 1_000_000):
        thr = lru_throughput(cap)
        rows.append((f"capacity/lru_cap={cap}", 1e6 / thr,
                     f"{thr:,.0f} gets/s"))
    # 100T deployment arithmetic (paper's GCP topology: 30 x 12TB PS nodes)
    rows_100t = criteo_syn_rows(100.0)
    # fp32 vectors + one adagrad scalar per ROW (the array-list item layout)
    bytes_total = rows_100t * (128 * 4 + 4)
    per_node = bytes_total / 30
    rows.append(("capacity/100T_arithmetic", 0.0,
                 f"rows={rows_100t:.3e} bytes={bytes_total/2**40:.0f}TiB "
                 f"per_PS_node={per_node/2**40:.1f}TiB_of_12TiB"))
    return rows
