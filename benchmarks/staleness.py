"""Theorem 1 validation: convergence vs staleness tau and vs ID frequency
alpha. The bound says the staleness penalty scales like tau * alpha / T —
so (a) quality degrades slowly in tau, and (b) degradation is stronger when
alpha is large (uniform/hot ids) than in the Zipf alpha<<1 regime."""
from __future__ import annotations

import numpy as np

from benchmarks.convergence import train_mode
from repro.core.hybrid import TrainMode
from repro.core.theory import estimate_alpha, hybrid_rate_bound
from repro.data.ctr import CTRDataset


def _global_ids(ds: CTRDataset, batch) -> np.ndarray:
    """Per-field local ids -> one global id space (for alpha estimation)."""
    ids = batch["ids"]                                    # (B, F, L), -1 pad
    offs = (np.arange(ds.n_fields) * ds.rows_per_field)[None, :, None]
    return np.where(ids >= 0, ids + offs, -1).reshape(ids.shape[0], -1)


def run(steps=150, seeds=(0, 1)):
    rows = []
    ds = CTRDataset("stale", n_rows=4_000, n_fields=8, ids_per_field=4,
                    n_dense=8, zipf_a=1.3)
    # empirical alpha of this dataset
    it = ds.sampler(512)
    batches = [_global_ids(ds, next(it)) for _ in range(4)]
    alpha = estimate_alpha(batches, ds.rows_per_field * ds.n_fields)
    aucs = {}
    for tau in (0, 1, 2, 4, 8, 16):
        mode = TrainMode("hybrid", tau, 0)
        accs = []
        wall = 0.0
        for sd in seeds:
            a, w, _ = train_mode(ds, mode, steps=steps, seed=sd)
            accs.append(a)
            wall += w
        auc = float(np.mean(accs))
        wall /= len(seeds)
        aucs[tau] = auc
        bound = hybrid_rate_bound(steps, sigma=1.0, tau=tau, alpha=alpha)
        rows.append((f"staleness/tau={tau}", wall / steps * 1e6,
                     f"auc={auc:.4f} bound_stale_frac="
                     f"{bound['stale_fraction']:.4f} alpha={alpha:.4f}"))
    drop_small = aucs[0] - aucs[4]
    drop_large = aucs[0] - aucs[16]
    rows.append(("staleness/summary", 0.0,
                 f"auc_drop_tau4={drop_small:+.4f} "
                 f"auc_drop_tau16={drop_large:+.4f}"))

    # alpha sweep: hotter ids (smaller id space / flatter zipf) hurt more
    for a, nrows in ((1.05, 16_000), (1.5, 1_000), (3.0, 64)):
        dsa = CTRDataset("a", n_rows=nrows, n_fields=8, ids_per_field=4,
                         n_dense=8, zipf_a=a)
        it = dsa.sampler(512)
        batches = [_global_ids(dsa, next(it)) for _ in range(4)]
        alpha_e = estimate_alpha(batches, dsa.rows_per_field * dsa.n_fields)
        auc0 = float(np.mean([train_mode(dsa, TrainMode("hybrid", 0, 0),
                                         steps=steps, seed=sd)[0]
                              for sd in seeds]))
        auc8 = float(np.mean([train_mode(dsa, TrainMode("hybrid", 8, 0),
                                         steps=steps, seed=sd)[0]
                              for sd in seeds]))
        rows.append((f"staleness/alpha={alpha_e:.3f}", 0.0,
                     f"auc_tau0={auc0:.4f} auc_tau8={auc8:.4f} "
                     f"drop={auc0-auc8:+.4f}"))
    return rows
