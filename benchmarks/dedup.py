"""Worker-side batch dedup (paper §4.2.3): unique-width vs occurrence-width
data path at controlled duplication factors.

A CTR batch's multi-hot ids repeat heavily; the dedup plan (core/dedup.py)
makes the worker gather/queue/put ONE row per unique id. For each dup
factor in {1, 4, 16} this benchmark draws batches whose ids come from a
pool of ``n_occ / dup`` hot keys (each table sized at 2x the pool — the
small-cardinality hot fields where dedup bites), then runs the SAME stream
through two trainers:

* ``dedup``   — the default unique-width path (per-batch DedupPlan;
  lookups gather the pow2 bucket of the unique count, puts are
  segment-summed before the staleness queue);
* ``nodedup`` — ``batch_dedup=False``, the occurrence-width PR-4 path.

Reported per dup factor: steps/s both ways, the speedup, the staleness
queue bytes both ways (tau copies of the put width — the hybrid
algorithm's biggest transient) and the measured dup factor from the step
metrics. A ``unique_bag`` row times the fused Pallas gather+inverse+pool
kernel against its unfused jnp oracle at the dup-16 shape.

    PYTHONPATH=src python benchmarks/dedup.py --steps 20 --check

``--check`` enforces the PR bar: at dup factor 16, >= 1.3x steps/s OR
>= 2x queue-bytes reduction (the queue ratio is structural — the dedup cap
vs the occurrence width — so it holds at any step count).
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import adapters
from repro.core.hybrid import PersiaTrainer, TrainMode
from repro.optim.optimizers import OptConfig

B, L, F, DIM = 256, 16, 2, 32        # n_occ = B * L = 4096 per table
TAU = 3
DUPS = (1, 4, 16)


def _rows_for(dup: int) -> int:
    """Table rows = 2x the hot-key pool: the dedup cap (min(n_occ, rows)
    rounded to 1024) narrows the queues exactly when the table's
    cardinality is below the batch's occurrence count."""
    return max((B * L // dup) * 2, 64)


def _batches(dup: int, n: int, seed: int = 0):
    """Batches whose ids hit a pool of n_occ/dup keys — measured dup
    factor ~= dup. dup=1 draws without replacement (all-distinct)."""
    rng = np.random.default_rng(seed)
    pool = B * L // dup
    rows = _rows_for(dup)
    out = []
    for _ in range(n):
        if dup == 1:
            ids = np.stack([rng.choice(rows, B * L, replace=False)
                            for _ in range(F)], 1).reshape(B, F, L)
        else:
            ids = rng.integers(0, pool, (B, F, L))
        out.append({
            "ids": jnp.asarray(ids, jnp.int32),
            "dense": jnp.asarray(rng.standard_normal((B, 13)), jnp.float32),
            "labels": jnp.asarray(rng.random((B, 1)) < 0.3, jnp.float32),
        })
    return out


def _trainer(dup: int, batch_dedup: bool) -> PersiaTrainer:
    rows = _rows_for(dup)
    cfg = ModelConfig(name="dedup", arch_type="recsys", n_id_fields=F,
                      ids_per_field=L, emb_dim=DIM, emb_rows=F * rows,
                      n_dense_features=13, mlp_dims=(512, 256), n_tasks=1)
    coll = adapters.ctr_collection(cfg, lr=5e-2, field_rows=(rows,) * F)
    adapter = adapters.recsys_adapter(cfg, field_rows=(rows,) * F,
                                      collection=coll)
    return PersiaTrainer(adapter, TrainMode.hybrid(TAU),
                         OptConfig(kind="adam", lr=1e-3),
                         batch_dedup=batch_dedup)


def _queue_bytes(state) -> int:
    return sum(int(x.size) * x.dtype.itemsize
               for q in state.emb_queue.values() if q is not None
               for x in jax.tree.leaves(q))


def _run_one(dup: int, batch_dedup: bool, steps: int):
    """-> (steps/s, queue_bytes, measured dup factor)."""
    tr = _trainer(dup, batch_dedup)
    bs = _batches(dup, steps + 4)
    st = tr.init(jax.random.PRNGKey(0), bs[0])
    for b in bs[:4]:                      # compile outside the clock
        st, m = tr.decomposed_step(st, b)
    t0 = time.perf_counter()
    for b in bs[4:]:
        st, m = tr.decomposed_step(st, b)
    jax.block_until_ready(st.emb)
    dt = time.perf_counter() - t0
    measured = float(np.mean([m[k] for k in m if k.endswith("dup_factor")])) \
        if batch_dedup else float(dup)
    return steps / dt, _queue_bytes(st), measured


def _unique_bag_row():
    """Fused Pallas unique_bag vs the unfused jnp oracle (interpret mode on
    CPU — the Mosaic TPU compiler is the deployment target, so the timing
    is indicative; the equality check is the load-bearing part)."""
    from repro.kernels import ops, ref
    rng = np.random.default_rng(0)
    V, D, b, bag = 256, 128, 16, 8
    table = jnp.asarray(rng.standard_normal((V, D)).astype(np.float32))
    dev = jnp.asarray(np.concatenate([rng.permutation(V)[:32],
                                      [-1] * 32]), jnp.int32)
    inv = jnp.asarray(rng.integers(-1, 32, (b, bag)), jnp.int32)
    want = ref.unique_bag_ref(table, dev, inv)
    got = ops.unique_bag(table, dev, inv)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    t0 = time.perf_counter()
    for _ in range(3):
        ops.unique_bag(table, dev, inv).block_until_ready()
    us = (time.perf_counter() - t0) / 3 * 1e6
    return ("dedup/unique_bag", us,
            f"kernel==oracle B={b} bag={bag} V={V} D={D}")


def run(steps: int = 20, results: dict | None = None):
    """benchmarks/run.py entry — CSV rows (name, us, derived). Pass a dict
    as ``results`` to also receive {dup: (speedup, queue_ratio)}."""
    rows = [_unique_bag_row()]
    for dup in DUPS:
        sps_new, qb_new, measured = _run_one(dup, True, steps)
        sps_old, qb_old, _ = _run_one(dup, False, steps)
        speedup = sps_new / sps_old
        qratio = qb_old / max(qb_new, 1)
        if results is not None:
            results[dup] = (speedup, qratio)
        rows.append((
            f"dedup/dup{dup}", 1e6 / sps_new,
            f"dedup={sps_new:.1f}steps/s nodedup={sps_old:.1f}steps/s "
            f"speedup={speedup:.2f}x queue_bytes={qb_new} vs {qb_old} "
            f"({qratio:.1f}x) measured_dup={measured:.1f}"))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless dup=16 shows >= 1.3x steps/s "
                         "or >= 2x queue-bytes reduction")
    args = ap.parse_args()
    results: dict = {}
    rows = run(args.steps, results)
    print("name,us_per_call,derived")
    for n, us, derived in rows:
        print(f"{n},{us:.1f},{derived}")
    # repo root on the path so this also works as `python benchmarks/...`
    import os
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks.report import save_bench
    save_bench("dedup", rows,
               {f"dup{k}": f"speedup={v[0]:.3f}x qratio={v[1]:.3f}x"
                for k, v in results.items()})
    if args.check:
        speedup, qratio = results[16]
        if speedup < 1.3 and qratio < 2.0:
            print(f"FAIL: dup=16 speedup {speedup:.2f}x < 1.3x AND "
                  f"queue-bytes reduction {qratio:.2f}x < 2x",
                  file=sys.stderr)
            raise SystemExit(1)
        print(f"OK: dup=16 speedup {speedup:.2f}x, queue-bytes reduction "
              f"{qratio:.2f}x")


if __name__ == "__main__":
    main()
