"""Multi-process PS honesty benchmark: what the RPC hop actually costs.

Runs the same small CTR model three ways —

* ``inprocess``      — backends in the trainer process (the upper bound);
* ``multiproc_raw``  — 2 PS subprocesses, raw fp32 wire payloads;
* ``multiproc_lossy``— 2 PS subprocesses, blockscale-fp16 wire payloads

— and reports steps/s plus total bytes-on-wire (every client's
``bytes_sent + bytes_recv``, so framing, ids and acks are all counted,
not just tensor payloads).

``--check`` pins the wire codec's honesty bar: compression must recover
>= 2x the *RPC envelope* — the bytes the RPC hop adds beyond the tensor
payload (ids, message keys, framing, acks). The envelope is solved from
the two measured totals under the codec's structural model (fp16 +
per-block fp32 scales halve the compressible payload):

    W_raw = E + P,  W_lossy = E + P/2   =>   E = 2*W_lossy - W_raw

and the bar is ``W_raw - W_lossy >= 2 * E`` — i.e. turning compression
on saves at least twice what the RPC envelope costs.

    PYTHONPATH=src python benchmarks/remote_ps.py --steps 20 --check
"""
from __future__ import annotations

import argparse
import sys
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.launch.cluster import small_ctr_trainer, spawn_ps
from repro.net.elastic import ElasticPSCluster

N_PS = 2
DIM = 32          # payload-dominated traffic: 32 fp32 per row vs 4B of id
WARMUP = 2


def _model(seed: int = 0):
    return small_ctr_trainer(mode="sync", backend="dense", dim=DIM,
                             seed=seed)


def _batches(ds, n: int, batch: int = 16, seed: int = 0):
    it = ds.sampler(batch, seed=seed)
    return [{k: jnp.asarray(v) for k, v in next(it).items()}
            for _ in range(n)]


def _wire_bytes(trainer) -> int:
    total = 0
    for bk in trainer.backends.values():
        for sub in bk.shard_backends:
            total += sub._client.bytes_sent + sub._client.bytes_recv
    return total


def _inprocess(steps: int) -> float:
    trainer, ds = _model()
    bs = _batches(ds, steps + WARMUP)
    state = trainer.init(jax.random.PRNGKey(0), bs[0])
    for b in bs[:WARMUP]:
        state, _ = trainer.decomposed_step(state, b)
    jax.block_until_ready(state.dense)
    t0 = time.perf_counter()
    for b in bs[WARMUP:]:
        state, _ = trainer.decomposed_step(state, b)
    jax.block_until_ready(state.dense)
    return steps / (time.perf_counter() - t0)


def _multiproc(steps: int, lossy: bool):
    """-> (steps/s, wire bytes over the timed steps)."""
    trainer, ds = _model()
    workdir = tempfile.mkdtemp(prefix="remote_ps_bench_")
    members, cluster = [], None
    try:
        members = [spawn_ps(workdir, i) for i in range(N_PS)]
        cluster = ElasticPSCluster(trainer, members)
        cluster.connect(lossy=lossy)
        bs = _batches(ds, steps + WARMUP)
        state = trainer.init(jax.random.PRNGKey(0), bs[0])
        for b in bs[:WARMUP]:
            state, _ = cluster.step(state, b)
        b0 = _wire_bytes(trainer)
        t0 = time.perf_counter()
        for b in bs[WARMUP:]:
            state, _ = cluster.step(state, b)
        dt = time.perf_counter() - t0
        return steps / dt, _wire_bytes(trainer) - b0
    finally:
        if cluster is not None:
            cluster.close()
        for m in members:
            if m.proc is not None and m.proc.poll() is None:
                m.proc.kill()
                m.proc.wait()


def run(steps: int = 20, results: dict | None = None):
    """benchmarks/run.py entry — CSV rows (name, us, derived)."""
    sps_in = _inprocess(steps)
    sps_raw, w_raw = _multiproc(steps, lossy=False)
    sps_lossy, w_lossy = _multiproc(steps, lossy=True)
    saved = w_raw - w_lossy
    envelope = max(2 * w_lossy - w_raw, 1)
    if results is not None:
        results["saved"], results["envelope"] = saved, envelope
    return [
        ("remote_ps/inprocess", 1e6 / sps_in, f"{sps_in:.1f}steps/s"),
        ("remote_ps/multiproc_raw", 1e6 / sps_raw,
         f"{sps_raw:.1f}steps/s wire_bytes={w_raw} "
         f"({w_raw // steps}B/step) slowdown="
         f"{sps_in / sps_raw:.1f}x vs inprocess"),
        ("remote_ps/multiproc_lossy", 1e6 / sps_lossy,
         f"{sps_lossy:.1f}steps/s wire_bytes={w_lossy} "
         f"({w_lossy // steps}B/step) saved={saved} "
         f"envelope~{envelope} recovery={saved / envelope:.1f}x"),
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless compression saves >= 2x the "
                         "RPC envelope bytes")
    args = ap.parse_args()
    results: dict = {}
    rows = run(args.steps, results)
    print("name,us_per_call,derived")
    for n, us, derived in rows:
        print(f"{n},{us:.1f},{derived}")
    if args.check:
        saved, envelope = results["saved"], results["envelope"]
        if saved < 2 * envelope:
            print(f"FAIL: compression saved {saved}B, < 2x the RPC "
                  f"envelope (~{envelope}B)", file=sys.stderr)
            raise SystemExit(1)
        print(f"OK: compression saved {saved}B, "
              f"{saved / envelope:.1f}x the RPC envelope (~{envelope}B)")


if __name__ == "__main__":
    main()
