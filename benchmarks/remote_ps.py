"""Remote-PS transport benchmark: what the pipelined wire path buys, and
what the RPC hop honestly costs.

Real PS subprocesses (spawned through ``repro.launch.cluster.spawn_ps``,
the same path the launcher uses) host the same small CTR model five ways:

* ``inprocess``        — backends in the trainer process (the upper bound);
* ``blocking @rtt``    — ``pipelined=False``: the pre-pipelining wire, one
  synchronous round-trip per (table x shard x phase) op, under a
  server-injected per-op reply delay (a synthetic network RTT);
* ``pipelined @rtt``   — the coalesced windowed transport under the same
  injected RTT: puts and prepares ride one ``step_ops`` frame per
  endpoint and ack asynchronously inside the tau-bounded window, so only
  the lookups (whose activations the step must consume) still pay the RTT;
* ``remote_raw/lossy`` — no injected RTT, dense/sync (payload-dominated
  traffic, as in the blocking era), raw fp32 vs blockscale-fp16 payloads,
  for the wire-envelope honesty bar.

Round-trips are *measured, not modeled*: every client counts frames at
the transport (``frames_sent``), deduped by connection (tables sharing an
endpoint share one pooled connection), so the coalescing claim is a
counted drop in frames/step.

Bit-exactness bars (``--check``):

* sync and hybrid(tau) training over the pipelined wire reproduce the
  in-process losses bit for bit (no injected RTT — latency never changes
  the numbers, only when they move);
* a kill-a-shard drill in sync mode stays bit-exact THROUGH the elastic
  reshard: the window is drained (every put acked, and every acked put
  spooled before its ack) before the kill lands, so recovery loses
  nothing — the drill pins "no acked put is ever lost";
* the same drill in hybrid mode reshards with puts still in flight; the
  dead shard's bounded-staleness queue (<= tau pending updates) is the
  paper's tolerated in-flight loss, so the bar there is zero lost ACKED
  rows and finite continued training, with the loss delta reported.

    PYTHONPATH=src python benchmarks/remote_ps.py --steps 8 --check
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.cluster import small_ctr_trainer, spawn_ps
from repro.net.elastic import ElasticPSCluster
from repro.net.remote import connect_remote_backends

N_PS = 2
DIM = 32          # payload-dominated traffic: 32 fp32 per row vs 4B of id
WARMUP = 2
RTT = 0.02        # injected per-op reply delay for the transport bars


def _batches(ds, n: int, batch: int = 16, seed: int = 0):
    it = ds.sampler(batch, seed=seed)
    return [{k: jnp.asarray(v) for k, v in next(it).items()}
            for _ in range(n)]


def _clients(trainer):
    """Distinct RpcClients (tables sharing an endpoint share ONE pooled
    connection, so counters must be deduped by identity)."""
    seen = {}
    for bk in trainer.backends.values():
        for sub in getattr(bk, "shard_backends", None) or [bk]:
            seen[id(sub._client)] = sub._client
    return list(seen.values())


def _frames(trainer) -> int:
    return sum(c.frames_sent for c in _clients(trainer))


def _wire_bytes(trainer) -> int:
    return sum(c.bytes_sent + c.bytes_recv for c in _clients(trainer))


def _spawn(n: int, reply_delay: float = 0.0):
    """n real PS shard processes in a fresh workdir (port-file handshake,
    per-shard spools — exactly the launcher's path)."""
    workdir = tempfile.mkdtemp(prefix="remote_ps_bench_")
    return [spawn_ps(workdir, i, reply_delay=reply_delay) for i in range(n)]


def _reap(members):
    for m in members:
        if m.proc is not None and m.proc.poll() is None:
            m.proc.kill()
            m.proc.wait()


def _drain(trainer, state):
    for n, st in state.emb.items():
        trainer.backends[n].sync(st)


def _inprocess(steps: int, mode: str = "hybrid",
               backend: str = "host_lru"):
    """-> (steps/s, final loss) of the in-process reference."""
    trainer, ds = small_ctr_trainer(mode=mode, backend=backend, dim=DIM)
    bs = _batches(ds, steps + WARMUP)
    state = trainer.init(jax.random.PRNGKey(0), bs[0])
    m = {}
    for b in bs[:WARMUP]:
        state, m = trainer.decomposed_step(state, b)
    jax.block_until_ready(state.dense)
    t0 = time.perf_counter()
    for b in bs[WARMUP:]:
        state, m = trainer.decomposed_step(state, b)
    jax.block_until_ready(state.dense)
    return steps / (time.perf_counter() - t0), float(np.float32(m["loss"]))


def _remote(steps: int, mode: str = "hybrid", backend: str = "host_lru",
            pipelined: bool = True, reply_delay: float = 0.0,
            lossy: bool = False):
    """-> (steps/s, final loss, frames/step, wire bytes) over PS
    subprocesses, timed past warmup with the transport counters deltaed."""
    members = _spawn(N_PS, reply_delay=reply_delay)
    trainer, ds = small_ctr_trainer(mode=mode, backend=backend, dim=DIM)
    try:
        connect_remote_backends(trainer, [m.endpoint for m in members],
                                lossy=lossy, pipelined=pipelined)
        bs = _batches(ds, steps + WARMUP)
        state = trainer.init(jax.random.PRNGKey(0), bs[0])
        m = {}
        for b in bs[:WARMUP]:
            state, m = trainer.decomposed_step(state, b)
        _drain(trainer, state)
        f0, b0 = _frames(trainer), _wire_bytes(trainer)
        t0 = time.perf_counter()
        for b in bs[WARMUP:]:
            state, m = trainer.decomposed_step(state, b)
        _drain(trainer, state)
        dt = time.perf_counter() - t0
        return (steps / dt, float(np.float32(m["loss"])),
                (_frames(trainer) - f0) / steps, _wire_bytes(trainer) - b0)
    finally:
        for bk in trainer.backends.values():
            bk.close()
        _reap(members)


def _kill_drill(steps: int, mode: str, drain_before_kill: bool):
    """Train over 3 spooling PS shard processes, SIGKILL shard 1 mid-run,
    recover by elastic reshard, finish. -> (final loss, lost acked rows)."""
    members = _spawn(3)
    trainer, ds = small_ctr_trainer(mode=mode, backend="host_lru", dim=DIM)
    cluster = None
    try:
        cluster = ElasticPSCluster(trainer, members, max_recoveries=2,
                                   ping_timeout=0.5)
        cluster.connect(timeout=2.0, retries=1, backoff=0.05)
        bs = _batches(ds, steps)
        state = trainer.init(jax.random.PRNGKey(0), bs[0])
        m = {}
        kill_at = max(2, steps // 2)
        for t, b in enumerate(bs):
            if t == kill_at:
                if drain_before_kill:
                    # close the window: every put acked, and every acked
                    # put spooled before its ack — the sync drill's
                    # bit-exactness hinges on the kill losing nothing
                    # that was acknowledged
                    _drain(trainer, state)
                proc = cluster.members[1].proc
                proc.kill()
                proc.wait()
            state, m = cluster.step(state, b)
        lost = sum(sum(e["lost_rows"].values()) for e in cluster.events
                   if e["kind"] == "reshard")
        return float(np.float32(m["loss"])), lost
    finally:
        if cluster is not None:
            cluster.close()
        _reap(members)


def run(steps: int = 8, results: dict | None = None):
    """benchmarks/run.py entry — CSV rows (name, us, derived)."""
    res = results if results is not None else {}

    # -- throughput under injected RTT: blocking vs pipelined ---------------
    sps_in, loss_in_hyb = _inprocess(steps)
    sps_blk, loss_blk, fps_blk, _ = _remote(steps, pipelined=False,
                                            reply_delay=RTT)
    sps_pip, loss_pip, fps_pip, _ = _remote(steps, pipelined=True,
                                            reply_delay=RTT)
    res["speedup"] = sps_pip / sps_blk
    res["frames_per_step_blocking"] = fps_blk
    res["frames_per_step_pipelined"] = fps_pip
    res["bitexact_transport"] = bool(np.float32(loss_blk)
                                     == np.float32(loss_pip))

    # -- bit-exactness vs in-process, sync and hybrid(tau) ------------------
    _, loss_rem_hyb, _, _ = _remote(steps)
    _, loss_in_sync = _inprocess(steps, mode="sync")
    _, loss_rem_sync, _, _ = _remote(steps, mode="sync")
    res["bitexact_hybrid"] = bool(np.float32(loss_rem_hyb)
                                  == np.float32(loss_in_hyb))
    res["bitexact_sync"] = bool(np.float32(loss_rem_sync)
                                == np.float32(loss_in_sync))

    # -- kill-a-shard drills ------------------------------------------------
    # the in-process reference consumes steps+2+WARMUP batches end to end;
    # the drill (which has no warmup split) must see the exact same stream
    _, loss_in_sync_k = _inprocess(steps + 2, mode="sync")
    loss_kill_sync, lost_sync = _kill_drill(steps + 2 + WARMUP, "sync",
                                            drain_before_kill=True)
    loss_kill_hyb, lost_hyb = _kill_drill(steps + 2 + WARMUP, "hybrid",
                                          drain_before_kill=False)
    res["bitexact_sync_through_kill"] = bool(
        np.float32(loss_kill_sync) == np.float32(loss_in_sync_k))
    res["lost_acked_rows"] = lost_sync + lost_hyb
    res["hybrid_kill_finite"] = bool(np.isfinite(loss_kill_hyb))
    hyb_delta = abs(loss_kill_hyb - loss_in_sync_k)

    # -- wire-envelope honesty bar (raw vs lossy payloads, no RTT) ----------
    # dense/sync, as in the blocking era: put+get payloads dominate, with
    # no fault-in id traffic (pure envelope) diluting the codec's savings
    _, _, _, w_raw = _remote(steps, mode="sync", backend="dense")
    _, _, _, w_lossy = _remote(steps, mode="sync", backend="dense",
                               lossy=True)
    saved = w_raw - w_lossy
    envelope = max(2 * w_lossy - w_raw, 1)
    res["saved"], res["envelope"] = saved, envelope

    return [
        ("remote_ps/inprocess", 1e6 / sps_in, f"{sps_in:.1f}steps/s"),
        ("remote_ps/blocking_rtt", 1e6 / sps_blk,
         f"{sps_blk:.2f}steps/s rtt={RTT*1e3:.0f}ms "
         f"frames/step={fps_blk:.1f}"),
        ("remote_ps/pipelined_rtt", 1e6 / sps_pip,
         f"{sps_pip:.2f}steps/s rtt={RTT*1e3:.0f}ms "
         f"frames/step={fps_pip:.1f} speedup={res['speedup']:.2f}x "
         f"bitexact_vs_blocking={res['bitexact_transport']}"),
        ("remote_ps/bitexact", 0.0,
         f"sync={res['bitexact_sync']} hybrid={res['bitexact_hybrid']}"),
        ("remote_ps/kill_drill", 0.0,
         f"sync_bitexact_through_reshard={res['bitexact_sync_through_kill']}"
         f" lost_acked_rows={res['lost_acked_rows']} "
         f"hybrid_recovered={res['hybrid_kill_finite']} "
         f"hybrid_loss_delta={hyb_delta:.2e} (tau-bounded tolerated loss)"),
        ("remote_ps/wire_raw", 0.0,
         f"wire_bytes={w_raw} ({w_raw // steps}B/step)"),
        ("remote_ps/wire_lossy", 0.0,
         f"wire_bytes={w_lossy} ({w_lossy // steps}B/step) saved={saved} "
         f"envelope~{envelope} recovery={saved / envelope:.1f}x"),
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless pipelined >= 1.5x blocking "
                         "steps/s under injected RTT with fewer frames/"
                         "step, sync+hybrid remote losses are bit-exact "
                         "with in-process (sync also through a kill-a-"
                         "shard reshard, zero acked rows lost), and "
                         "compression saves >= 2x the RPC envelope")
    args = ap.parse_args()
    results: dict = {}
    rows = run(args.steps, results)
    print("name,us_per_call,derived")
    for n, us, derived in rows:
        print(f"{n},{us:.1f},{derived}")
    # repo root on the path so this also works as `python benchmarks/...`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks.report import save_bench
    save_bench("remote_ps", rows, results)
    if args.check:
        ok = True
        if results["speedup"] < 1.5:
            print(f"FAIL: pipelined only {results['speedup']:.2f}x the "
                  "blocking transport (< 1.5x)", file=sys.stderr)
            ok = False
        if results["frames_per_step_pipelined"] >= \
                results["frames_per_step_blocking"]:
            print("FAIL: coalescing did not reduce frames/step "
                  f"({results['frames_per_step_pipelined']:.1f} vs "
                  f"{results['frames_per_step_blocking']:.1f})",
                  file=sys.stderr)
            ok = False
        for key in ("bitexact_transport", "bitexact_sync", "bitexact_hybrid",
                    "bitexact_sync_through_kill", "hybrid_kill_finite"):
            if not results[key]:
                print(f"FAIL: {key} does not hold", file=sys.stderr)
                ok = False
        if results["lost_acked_rows"] != 0:
            print(f"FAIL: {results['lost_acked_rows']} acked rows lost "
                  "across the kill drills", file=sys.stderr)
            ok = False
        if results["saved"] < 2 * results["envelope"]:
            print(f"FAIL: compression saved {results['saved']}B, < 2x the "
                  f"RPC envelope (~{results['envelope']}B)", file=sys.stderr)
            ok = False
        if not ok:
            raise SystemExit(1)
        print(f"OK: pipelined {results['speedup']:.2f}x blocking "
              f"({results['frames_per_step_pipelined']:.1f} vs "
              f"{results['frames_per_step_blocking']:.1f} frames/step), "
              "bit-exact sync/hybrid (sync through kill-reshard, 0 acked "
              f"rows lost), compression {results['saved']}B saved "
              f"({results['saved'] / results['envelope']:.1f}x envelope)")


if __name__ == "__main__":
    main()
