"""Frequency-aware multi-tier cache (ROADMAP item 1): admission hit-rate,
three-tier parity, and prefetch throughput.

Three measurements, one per tentpole claim:

* ``admission`` — the SAME skewed id stream (a resident hot head drawn
  Zipf-style plus a one-touch uniform scan tail — the scan-resistance
  pattern that defeats recency-only caches) is replayed through two
  ``host_lru`` backends at EQUAL device slots: plain LRU with
  ``cache_rows = C`` vs the admission-sketch config with
  ``cache_rows = C - B`` main slots plus ``bypass_rows = B`` scratch
  slots. The sketch serves one-touch ids from the bypass region instead
  of letting them evict hot residents, so its hit rate must be higher at
  identical device bytes. Reported: hit rate and prepare-stream steps/s
  both ways, plus admit/bypass/promote counters.
* ``three_tier`` — a short hybrid training run through ``host_lru+disk``
  (host LRU over the mmap tier, core/mmap_store.py) vs plain
  ``host_lru``: when the working set fits, per-step losses must be
  bit-equal — the disk tier changes where cold rows live, never what
  they contain.
* ``prefetch`` — the six-stage ``PipelinedTrainer`` with ``prefetch=2``
  vs ``prefetch=0`` under simulated host fault-in latency, both at
  ``max_inflight=1`` (the exact-serial-staleness setting, where the
  inflight window forbids prepare/dense overlap): the prefetch stage
  faults step t+k's unique rows AHEAD of the window while t trains, so
  the fault latency leaves the critical path without widening the put
  staleness.

* ``store_dtype`` — the same dim-32 hybrid run with fp32 vs blockscale16
  cold rows (``EmbeddingSpec.store_dtype``, the core/lru.py codec):
  host-row payload bytes vs the per-step loss drift.

    PYTHONPATH=src python benchmarks/cache_tiers.py --steps 120 --check

``--check`` enforces the PR bar: admission hit-rate strictly above plain
LRU at equal device slots, three-tier losses bit-equal to host_lru, AND
blockscale16 payload >= 1.8x smaller at <= 2e-3 loss delta.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import adapters
from repro.core.backend import create_backend
from repro.core.embedding_ps import EmbeddingSpec
from repro.core.hybrid import PersiaTrainer, TrainMode
from repro.core.pipeline import PipelinedTrainer
from repro.data.ctr import CTRDataset
from repro.optim.optimizers import OptConfig

ROWS, DIM = 20_000, 32
DEV_SLOTS = 2048                 # equal device budget for both configs
BYPASS = 512                     # admission: 1536 main + 512 bypass
HOT_POOL = 1400                  # hot head ~ the main region (the regime
BATCH = 1024                     # where one-touch traffic hurts plain LRU)
HOT_FRAC = 0.65
ADMIT_THRESHOLD = 12.0           # above the sketch's collision noise at
                                 # this traffic, below any hot id's count


def _stream(steps: int, seed: int = 0):
    """Per-step id batches: ``HOT_FRAC`` of draws from a Zipf-ranked hot
    pool of ``HOT_POOL`` ids, the rest one-touch uniform over all rows."""
    rng = np.random.default_rng(seed)
    pool = rng.permutation(ROWS)[:HOT_POOL]
    n_hot = int(BATCH * HOT_FRAC)
    out = []
    for _ in range(steps):
        hot = pool[rng.zipf(1.05, n_hot) % HOT_POOL]
        cold = rng.integers(0, ROWS, BATCH - n_hot)
        out.append(np.concatenate([hot, cold]))
    return out


def _spec(admission: bool) -> EmbeddingSpec:
    if admission:
        return EmbeddingSpec(rows=ROWS, dim=DIM, backend="host_lru",
                             cache_rows=DEV_SLOTS - BYPASS,
                             bypass_rows=BYPASS,
                             admit_threshold=ADMIT_THRESHOLD)
    return EmbeddingSpec(rows=ROWS, dim=DIM, backend="host_lru",
                         cache_rows=DEV_SLOTS)


def _replay(admission: bool, batches) -> tuple[float, float, "object"]:
    """-> (hit_rate, steps/s, backend) over the prepare fault stream."""
    bk = create_backend(_spec(admission))
    state = bk.init(jax.random.PRNGKey(0))
    state, _ = bk.prepare(state, batches[0])       # warm outside the clock
    t0 = time.perf_counter()
    for ids in batches[1:]:
        state, _ = bk.prepare(state, ids)
    dt = time.perf_counter() - t0
    hit_rate = bk.hits / max(bk.hits + bk.faults, 1)
    return hit_rate, (len(batches) - 1) / dt, bk


def _parity_losses(backend: str, steps: int, cache_rows: int = 512,
                   store_dtype: str = "fp32", dim: int = 16):
    ds = CTRDataset("tiers", n_rows=4 * 1024, n_fields=4, ids_per_field=2,
                    n_dense=13)
    cfg = ModelConfig(name="tiers", arch_type="recsys", n_id_fields=4,
                      ids_per_field=2, emb_dim=dim, emb_rows=4 * 1024,
                      n_dense_features=13, mlp_dims=(64, 32), n_tasks=1)
    coll = adapters.ctr_collection(cfg, lr=5e-2, field_rows=ds.field_rows())
    coll = coll.with_backend(backend, cache_rows)
    if store_dtype != "fp32":
        coll = coll.with_store_dtype(store_dtype)
    adapter = adapters.recsys_adapter(cfg, field_rows=ds.field_rows(),
                                      collection=coll)
    tr = PersiaTrainer(adapter, TrainMode.hybrid(2),
                       OptConfig(kind="adam", lr=1e-3))
    it = ds.sampler(64)
    bs = [{k: jnp.asarray(v) for k, v in next(it).items()}
          for _ in range(steps)]
    st = tr.init(jax.random.PRNGKey(0), bs[0])
    t0 = time.perf_counter()
    losses = []
    for b in bs:
        st, m = tr.decomposed_step(st, b)
        losses.append(np.float32(m["loss"]))
    jax.block_until_ready(st.emb)
    payload = sum(bk.store.payload_bytes() for bk in tr.backends.values())
    return losses, steps / (time.perf_counter() - t0), payload


def _prefetch_rate(prefetch: int, steps: int, fault_ms: float = 5.0):
    ds = CTRDataset("pfetch", n_rows=4 * 4096, n_fields=4, ids_per_field=2,
                    n_dense=13)
    cfg = ModelConfig(name="pfetch", arch_type="recsys", n_id_fields=4,
                      ids_per_field=2, emb_dim=16, emb_rows=4 * 4096,
                      n_dense_features=13, mlp_dims=(512, 256), n_tasks=1)
    coll = adapters.ctr_collection(cfg, lr=5e-2, field_rows=ds.field_rows())
    coll = coll.with_backend("host_lru", 2048)
    adapter = adapters.recsys_adapter(cfg, field_rows=ds.field_rows(),
                                      collection=coll)
    tr = PersiaTrainer(adapter, TrainMode.hybrid(3),
                       OptConfig(kind="adam", lr=1e-3))
    # max_inflight=1 is the exact-serial-staleness setting: the inflight
    # window forbids any prepare/dense overlap, so the fault-in latency
    # is only hideable by the prefetch stage running AHEAD of the window
    engine = PipelinedTrainer(tr, max_inflight=1, prefetch=prefetch)
    it = ds.sampler(128)
    bs = [{k: jnp.asarray(v) for k, v in next(it).items()}
          for _ in range(steps + 4)]

    def delay(stage: str, _idx: int) -> float:
        # charge the simulated host fault-in to whichever stage faults:
        # the prefetch stage when enabled, else the prepare stage
        faulting = "prefetch" if prefetch > 0 else "prepare"
        return fault_ms / 1e3 if stage == faulting else 0.0

    st = engine.init(jax.random.PRNGKey(0), bs[0])
    st, _ = engine.run(st, bs[:4])                 # compile outside the clock
    t0 = time.perf_counter()
    st, _ = engine.run(st, bs[4:], delay_fn=delay)
    jax.block_until_ready(st.dense)
    return steps / (time.perf_counter() - t0)


def run(steps: int = 120, results: dict | None = None):
    """benchmarks/run.py entry — CSV rows (name, us, derived). Pass a dict
    as ``results`` to also receive the --check inputs."""
    batches = _stream(steps)
    hr_adm, sps_adm, bk_adm = _replay(True, batches)
    hr_lru, sps_lru, _ = _replay(False, batches)
    rows = [(
        "cache_tiers/admission", 1e6 / sps_adm,
        f"hit_rate={hr_adm:.3f} vs plain_lru={hr_lru:.3f} "
        f"({sps_adm:.0f} vs {sps_lru:.0f} prepares/s) dev_slots={DEV_SLOTS} "
        f"admits={bk_adm.admits} bypasses={bk_adm.bypasses} "
        f"promotes={bk_adm.promotes}")]

    par_steps = max(min(steps // 10, 12), 4)
    disk_l, sps_disk, _ = _parity_losses("host_lru+disk", par_steps)
    lru_l, sps_base, _ = _parity_losses("host_lru", par_steps)
    bitequal = disk_l == lru_l
    rows.append((
        "cache_tiers/three_tier", 1e6 / sps_disk,
        f"losses_bitequal={bitequal} over {par_steps} hybrid steps "
        f"({sps_disk:.1f} vs host_lru {sps_base:.1f} steps/s)"))

    # store_dtype capacity row (ISSUE 9 prong B): the SAME dim-32 hybrid
    # run with fp32 vs blockscale16 cold rows — payload must shrink
    # >= 1.8x while the training trajectory barely moves
    bs_l, sps_bs, pay_bs = _parity_losses(
        "host_lru", par_steps, store_dtype="blockscale16", dim=DIM)
    f32_l, _, pay_f32 = _parity_losses("host_lru", par_steps, dim=DIM)
    pay_ratio = pay_f32 / pay_bs
    loss_delta = max(abs(a - b) for a, b in zip(bs_l, f32_l))
    rows.append((
        "cache_tiers/store_dtype", 1e6 / sps_bs,
        f"payload={pay_bs} vs fp32 {pay_f32} ({pay_ratio:.2f}x) "
        f"loss_delta={loss_delta:.2e} over {par_steps} hybrid steps "
        f"dim={DIM}"))

    pf_steps = max(min(steps // 6, 16), 4)
    # discarded warm-up: the backend's fault-apply jits are module-level
    # and compile per pow2-bucket shape, so whichever measured run goes
    # first would otherwise pay the compiles inside its clock
    _prefetch_rate(0, pf_steps)
    sps_pf = _prefetch_rate(2, pf_steps)
    sps_nopf = _prefetch_rate(0, pf_steps)
    rows.append((
        "cache_tiers/prefetch", 1e6 / sps_pf,
        f"prefetch2={sps_pf:.1f}steps/s prefetch0={sps_nopf:.1f}steps/s "
        f"speedup={sps_pf / sps_nopf:.2f}x (5ms simulated fault-in)"))

    if results is not None:
        results.update(hit_admission=hr_adm, hit_plain=hr_lru,
                       bitequal=bitequal, pay_ratio=pay_ratio,
                       loss_delta=float(loss_delta))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless admission hit-rate beats "
                         "plain LRU at equal device slots AND three-tier "
                         "losses are bit-equal to host_lru")
    args = ap.parse_args()
    results: dict = {}
    rows = run(args.steps, results)
    print("name,us_per_call,derived")
    for n, us, derived in rows:
        print(f"{n},{us:.1f},{derived}")
    # repo root on the path so this also works as `python benchmarks/...`
    import os
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks.report import save_bench
    save_bench("cache_tiers", rows, results)
    if args.check:
        ok = True
        if results["hit_admission"] <= results["hit_plain"]:
            print(f"FAIL: admission hit-rate {results['hit_admission']:.3f} "
                  f"<= plain LRU {results['hit_plain']:.3f} at equal device "
                  "slots", file=sys.stderr)
            ok = False
        if not results["bitequal"]:
            print("FAIL: three-tier losses diverge from host_lru",
                  file=sys.stderr)
            ok = False
        if results["pay_ratio"] < 1.8:
            print(f"FAIL: blockscale16 payload ratio "
                  f"{results['pay_ratio']:.2f}x < 1.8x at dim {DIM}",
                  file=sys.stderr)
            ok = False
        if results["loss_delta"] > 2e-3:
            print(f"FAIL: blockscale16 loss delta "
                  f"{results['loss_delta']:.2e} > 2e-3", file=sys.stderr)
            ok = False
        if not ok:
            raise SystemExit(1)
        print(f"OK: admission hit-rate {results['hit_admission']:.3f} > "
              f"plain {results['hit_plain']:.3f}; three-tier bit-equal; "
              f"blockscale16 payload {results['pay_ratio']:.2f}x at "
              f"loss delta {results['loss_delta']:.2e}")


if __name__ == "__main__":
    main()
