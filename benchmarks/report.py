"""Render the dry-run artifacts into the EXPERIMENTS.md roofline tables.

  PYTHONPATH=src python -m benchmarks.report > results/roofline_tables.md
"""
from __future__ import annotations

import json
import os

HERE = os.path.join(os.path.dirname(__file__), "..", "results")


def load(name):
    path = os.path.join(HERE, name)
    return json.load(open(path)) if os.path.exists(path) else []


def _scalar(v):
    import numpy as np
    if isinstance(v, (bool, np.bool_)):
        return bool(v)
    if isinstance(v, (int, np.integer)):
        return int(v)
    if isinstance(v, (float, np.floating)):
        return float(v)
    return str(v)


def save_bench(name, rows, results=None):
    """Persist one benchmark's CSV rows (+ its --check inputs) to
    ``results/BENCH_<name>.json`` — the perf-trajectory file set the CI
    smoke accumulates run over run."""
    os.makedirs(HERE, exist_ok=True)
    blob = {"rows": [{"name": n, "us": float(us), "derived": d}
                     for n, us, d in rows]}
    if results:
        blob["results"] = {k: _scalar(v) for k, v in results.items()}
    path = os.path.join(HERE, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(blob, f, indent=1)
    return path


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def dryrun_table(rows, mesh):
    out = [
        "| arch | shape | status | args GiB/dev | temp GiB/dev | "
        "collectives GiB/dev (AR/AG/RS/A2A) | note |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['status']} | | | "
                       f"| {r.get('note') or r.get('error','')[:90]} |")
            continue
        c = r["collectives"]
        coll = (f"{c['total']/2**30:.1f} "
                f"({c['all-reduce']/2**30:.0f}/{c['all-gather']/2**30:.0f}/"
                f"{c['reduce-scatter']/2**30:.0f}/{c['all-to-all']/2**30:.0f})")
        out.append(
            f"| {r['arch']} | {r['shape']} | ok | "
            f"{fmt_bytes(r['argument_bytes_per_device'])} | "
            f"{fmt_bytes(r['temp_bytes_per_device'])} | {coll} | "
            f"{r.get('note','')} |")
    return "\n".join(out)


def roofline_table(rows):
    out = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL_FLOPS | useful frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["mesh"] != "16x16" or r["status"] != "ok":
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"**{r['dominant']}** | {r['model_flops']:.2e} | "
            f"{r['useful_flops_frac']:.3f} |")
    return "\n".join(out)


def delta_table(base, opt):
    """§Perf: per-case before/after for the three roofline terms."""
    def key(r):
        return (r["arch"], r["shape"], r["mesh"])
    b = {key(r): r for r in base if r["status"] == "ok"}
    out = [
        "| arch | shape | peak GiB (base->opt) | memory s (base->opt) | "
        "collective s (base->opt) |",
        "|---|---|---|---|---|",
    ]
    for r in opt:
        if r["mesh"] != "16x16" or r["status"] != "ok":
            continue
        k = key(r)
        if k not in b:
            continue
        rb = b[k]
        pk_b = (rb["argument_bytes_per_device"]
                + rb["temp_bytes_per_device"]) / 2**30
        pk_o = (r["argument_bytes_per_device"]
                + r["temp_bytes_per_device"]) / 2**30
        out.append(
            f"| {r['arch']} | {r['shape']} | {pk_b:.1f} -> {pk_o:.1f} | "
            f"{rb['memory_s']:.2f} -> {r['memory_s']:.2f} | "
            f"{rb['collective_s']:.2f} -> {r['collective_s']:.2f} |")
    return "\n".join(out)


def main():
    opt = load("dryrun_matrix.json")
    base = load("dryrun_matrix_baseline.json")
    print("## Dry-run 16x16 (single pod, 256 chips)\n")
    print(dryrun_table(opt, "16x16"))
    print("\n## Dry-run 2x16x16 (two pods, 512 chips)\n")
    print(dryrun_table(opt, "2x16x16"))
    print("\n## Roofline (single pod)\n")
    print(roofline_table(opt))
    if base:
        print("\n## Baseline -> optimized deltas\n")
        print(delta_table(base, opt))


if __name__ == "__main__":
    main()
