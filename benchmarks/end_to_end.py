"""Paper Figure 6 analog: wall-clock time for each mode to reach a target
test AUC on the CTR benchmarks. On one CPU the async/hybrid *hardware*
advantage (overlap) cannot manifest — what this measures is the statistical
side: steps-to-target and the per-step cost of each mode's bookkeeping. The
hardware side is composed in scalability.py from measured phase times."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.convergence import DATASETS, MODES, _cfg
from repro.core import adapters
from repro.core.hybrid import PersiaTrainer
from repro.optim.optimizers import OptConfig


def time_to_auc(ds, mode, target=0.70, max_steps=400, batch=512, seed=0):
    cfg = _cfg(ds)
    adapter = adapters.recsys_adapter(cfg, lr=5e-2,
                                      field_rows=ds.field_rows())
    trainer = PersiaTrainer(adapter, mode, OptConfig(kind="adam", lr=5e-3))
    it = ds.sampler(batch, seed=seed)
    ev = ds.sampler(2048, seed=4242)
    eval_batch = {k: jnp.asarray(v) for k, v in next(ev).items()}
    b0 = {k: jnp.asarray(v) for k, v in next(it).items()}
    state = trainer.init(jax.random.PRNGKey(seed), b0)
    # warm the jit out of the timing
    state, _ = trainer.step(state, b0)
    t0 = time.perf_counter()
    for s in range(max_steps):
        b = {k: jnp.asarray(v) for k, v in next(it).items()}
        state, _ = trainer.step(state, b)
        if (s + 1) % 20 == 0:
            preds = trainer.predict(state, eval_batch)
            auc = adapters.auc(np.asarray(eval_batch["labels"]),
                               np.asarray(preds))
            if auc >= target:
                return s + 1, time.perf_counter() - t0, auc
    return max_steps, time.perf_counter() - t0, auc


def run(target=0.68):
    rows = []
    ds = DATASETS["taobao"]
    for mode_name, mode in MODES.items():
        steps, wall, auc = time_to_auc(ds, mode, target=target)
        rows.append((f"end_to_end/taobao/{mode_name}", wall * 1e6 / steps,
                     f"steps_to_auc{target}={steps} wall={wall:.1f}s "
                     f"final_auc={auc:.4f}"))
    return rows
