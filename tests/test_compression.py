"""Compression layer: lossless index roundtrip (exact), lossy blockscale
error bounds, on-device put dedup vs oracle — paper §4.2.3."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:        # optional dep: property tests get fixed sweeps
    HAVE_HYPOTHESIS = False

from repro.core import compression as C


def _index_lossless_case(B, L, rows):
    rng = np.random.default_rng(B * 31 + L)
    ids = rng.integers(0, rows, (B, L))
    lens = rng.integers(0, L + 1, B)
    ids = np.where(np.arange(L)[None] < lens[:, None], ids, -1)
    u, off, smp = C.compress_index_batch(ids)
    back = C.decompress_index_batch(u, off, smp, B, L)
    # multiset equality per sample
    for i in range(B):
        a = sorted(x for x in ids[i] if x >= 0)
        b = sorted(x for x in back[i] if x >= 0)
        assert a == b


if HAVE_HYPOTHESIS:
    @settings(deadline=None, max_examples=30)
    @given(st.integers(1, 40), st.integers(1, 8), st.integers(2, 500))
    def test_index_compression_lossless(B, L, rows):
        _index_lossless_case(B, L, rows)
else:
    @pytest.mark.parametrize("B,L,rows", [(1, 1, 2), (7, 8, 500),
                                          (40, 3, 13)])
    def test_index_compression_lossless(B, L, rows):
        _index_lossless_case(B, L, rows)


def _index_wellformed_case(B, L, rows, density_seed):
    """Wire-format invariants the decoder relies on: unique ids sorted and
    deduplicated, offsets monotone and spanning every kept entry, sample
    indices uint16 and in range, and every (sample, id) pair accounted for
    exactly once."""
    rng = np.random.default_rng(density_seed)
    ids = rng.integers(0, rows, (B, L))
    ids = np.where(rng.random((B, L)) < 0.3, -1, ids)          # padding
    u, off, smp = C.compress_index_batch(ids)
    assert u.dtype == np.int64 and off.dtype == np.uint32
    assert smp.dtype == np.uint16
    assert (np.diff(u) > 0).all()                              # sorted, deduped
    assert off[0] == 0 and off[-1] == smp.size
    assert (np.diff(off.astype(np.int64)) >= 1).all()          # no empty id
    assert smp.size == int((ids >= 0).sum())
    if smp.size:
        assert int(smp.max()) < B
    # each unique id's sample list is exactly the rows containing it
    for ui, s, e in zip(u, off[:-1], off[1:]):
        want = sorted(np.nonzero((ids == ui).any(axis=1))[0].tolist())
        got = sorted(set(smp[s:e].tolist()))
        assert got == want


if HAVE_HYPOTHESIS:
    @settings(deadline=None, max_examples=30)
    @given(st.integers(1, 48), st.integers(1, 8), st.integers(2, 200),
           st.integers(0, 10_000))
    def test_index_compression_wire_wellformed(B, L, rows, density_seed):
        _index_wellformed_case(B, L, rows, density_seed)
else:
    @pytest.mark.parametrize("B,L,rows,seed", [(1, 1, 2, 0), (9, 8, 11, 3),
                                               (48, 4, 200, 7)])
    def test_index_compression_wire_wellformed(B, L, rows, seed):
        _index_wellformed_case(B, L, rows, seed)


def test_index_compression_rejects_oversized_batch():
    """Sample indices are uint16 on the wire: batches past 65535 must fail
    loudly (a bare assert would vanish under `python -O`)."""
    ids = np.zeros((65536, 1), np.int64)
    with pytest.raises(ValueError, match="65535"):
        C.compress_index_batch(ids)
    # the boundary itself is legal
    u, off, smp = C.compress_index_batch(np.zeros((65535, 1), np.int64))
    assert smp.dtype == np.uint16 and int(smp.max()) == 65534


def test_index_compression_ratio_gt1_on_skewed():
    rng = np.random.default_rng(0)
    ids = rng.zipf(1.5, (1024, 8)) % 1000            # heavy repeats
    assert C.index_compression_ratio(ids) > 1.0


def _blockscale_roundtrip_case(seed):
    rng = np.random.default_rng(seed)
    v = (rng.standard_normal(rng.integers(1, 400))
         * 10 ** rng.uniform(-4, 4)).astype(np.float32)
    out = np.asarray(C.blockscale_roundtrip(jnp.asarray(v)))
    linf_blocks = np.abs(v).max()
    assert np.all(np.abs(out - v) <= linf_blocks * 2 ** -10 + 1e-20)


if HAVE_HYPOTHESIS:
    @settings(deadline=None, max_examples=25)
    @given(st.integers(0, 10_000))
    def test_blockscale_jnp_roundtrip(seed):
        _blockscale_roundtrip_case(seed)
else:
    @pytest.mark.parametrize("seed", [0, 17, 4242, 9999])
    def test_blockscale_jnp_roundtrip(seed):
        _blockscale_roundtrip_case(seed)


def test_blockscale_beats_uniform_fp16_on_wide_range():
    """The paper's point: per-block scaling preserves small blocks that a
    uniform fp32->fp16 cast would denormalise/flush."""
    v = np.concatenate([np.full(128, 1e5, np.float32),
                        np.full(128, 1e-6, np.float32)])
    ours = np.asarray(C.blockscale_roundtrip(jnp.asarray(v)))
    uniform = np.asarray(jnp.asarray(v).astype(jnp.float16)
                         .astype(jnp.float32))
    err_ours = np.abs(ours - v) / np.abs(v)
    err_unif = np.abs(uniform - v) / np.abs(v)
    assert err_ours.max() < 1e-3
    assert err_unif[128:].max() > 1e-2            # small block wrecked


def test_dedup_put_aggregates():
    ids = jnp.array([5, 3, 5, -1, 3, 9], jnp.int32)
    g = jnp.arange(6, dtype=jnp.float32)[:, None] * jnp.ones((1, 4))
    u, s = C.dedup_put(ids, g, capacity=8)
    got = {int(i): np.asarray(row) for i, row in zip(u, s) if i >= 0}
    assert set(got) == {3, 5, 9}
    np.testing.assert_allclose(got[5], (0 + 2) * np.ones(4))
    np.testing.assert_allclose(got[3], (1 + 4) * np.ones(4))
    np.testing.assert_allclose(got[9], 5 * np.ones(4))


def _dedup_put_case(T, rows):
    rng = np.random.default_rng(T * 7 + rows)
    ids = jnp.asarray(rng.integers(-1, rows, T).astype(np.int32))
    g = jnp.asarray(rng.standard_normal((T, 3)).astype(np.float32))
    u, s = C.dedup_put(ids, g, capacity=T)
    # oracle via numpy
    want = {}
    for i, gi in zip(np.asarray(ids), np.asarray(g)):
        if i >= 0:
            want[int(i)] = want.get(int(i), np.zeros(3)) + gi
    got = {int(i): np.asarray(r) for i, r in zip(u, s) if i >= 0}
    assert set(got) == set(want)
    for k in want:
        np.testing.assert_allclose(got[k], want[k], atol=1e-5)


if HAVE_HYPOTHESIS:
    @settings(deadline=None, max_examples=25)
    @given(st.integers(1, 64), st.integers(2, 32))
    def test_dedup_put_property(T, rows):
        _dedup_put_case(T, rows)
else:
    @pytest.mark.parametrize("T,rows", [(1, 2), (16, 5), (64, 32)])
    def test_dedup_put_property(T, rows):
        _dedup_put_case(T, rows)
