"""EmbeddingBackend protocol (core/backend.py): dense PS vs host-LRU
out-of-core parity, eviction/write-back behavior, the compressed wire's
bytes-moved accounting, and full checkpoint round-trips (vectors + adagrad
accumulators + LRU recency order)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import adapters, embedding_ps as PS
from repro.core.backend import (CompressedWireBackend, DenseBackend,
                                HostLRUBackend, create_backend,
                                parse_backend_name)
from repro.core.collection import EmbeddingCollection
from repro.core.embedding_ps import EmbeddingSpec
from repro.core.hybrid import PersiaTrainer, TrainMode
from repro.data.ctr import CTRDataset
from repro.optim.optimizers import OptConfig

F, RPF, D = 3, 128, 8      # fields x rows-per-field x dim

CFG = ModelConfig(name="bk", arch_type="recsys", n_id_fields=F,
                  ids_per_field=3, emb_dim=D, emb_rows=F * RPF,
                  n_dense_features=4, mlp_dims=(16,), n_tasks=1)
DS = CTRDataset("bk", n_rows=F * RPF, n_fields=F, ids_per_field=3, n_dense=4)


def _batches(n, batch=32):
    it = DS.sampler(batch)
    return [{k: jnp.asarray(v) for k, v in next(it).items()}
            for _ in range(n)]


def _trainer(backend, cache_rows=None, tau=2):
    coll = adapters.ctr_collection(CFG, lr=5e-2, field_rows=DS.field_rows())
    coll = coll.with_backend(backend, cache_rows)
    ad = adapters.recsys_adapter(CFG, field_rows=DS.field_rows(),
                                 collection=coll)
    return PersiaTrainer(ad, TrainMode.hybrid(tau),
                         OptConfig(kind="adam", lr=5e-3))


def _probe_all_rows(trainer, state):
    """Bit-exact full-table view through the backend's own lookup path,
    chunked so host-LRU caches smaller than the table can stream it."""
    out = {}
    for n in trainer.collection.names:
        bk = trainer.backends[n]
        chunk = getattr(bk, "cache_rows", None) or RPF
        chunk = getattr(getattr(bk, "inner", None), "cache_rows", chunk)
        rows = []
        for lo in range(0, RPF, chunk):
            ids = jnp.arange(lo, min(lo + chunk, RPF), dtype=jnp.int32)
            st, dev = bk.prepare(state.emb[n], ids)
            state.emb = {**state.emb, n: st}
            acts, _ = bk.lookup(st, dev)
            rows.append(np.asarray(acts))
        out[n] = np.concatenate(rows)
    return out


# ---------------------------------------------------------------------------
# factory / spec validation
# ---------------------------------------------------------------------------

def test_backend_name_parsing():
    assert parse_backend_name("dense") == ("dense", False)
    assert parse_backend_name(None) == ("dense", False)
    assert parse_backend_name("host_lru") == ("host_lru", False)
    assert parse_backend_name("dense+compressed") == ("dense", True)
    assert parse_backend_name("host_lru+compressed") == ("host_lru", True)
    assert parse_backend_name("compressed") == ("dense", True)
    for bad in ("sparse", "host_lru+gzip", "dense+"):
        with pytest.raises(ValueError):
            parse_backend_name(bad)


def test_backend_factory_and_spec_validation():
    spec = EmbeddingSpec(rows=64, dim=4, mode="full")
    assert isinstance(create_backend(spec), DenseBackend)
    b = create_backend(dataclasses.replace(spec, backend="host_lru",
                                           cache_rows=16))
    assert isinstance(b, HostLRUBackend)
    c = create_backend(dataclasses.replace(spec,
                                           backend="host_lru+compressed",
                                           cache_rows=16))
    assert isinstance(c, CompressedWireBackend)
    assert isinstance(c.inner, HostLRUBackend)
    with pytest.raises(ValueError, match="cache_rows"):
        create_backend(dataclasses.replace(spec, backend="host_lru"))
    # collections fail fast on hostile backend strings
    with pytest.raises(ValueError, match="backend"):
        EmbeddingCollection.single(
            "t", dataclasses.replace(spec, backend="nope"))


def test_dense_backend_is_the_ps_unchanged():
    spec = EmbeddingSpec(rows=64, dim=4, mode="full", optimizer="adagrad",
                         lr=0.1)
    b = create_backend(spec)
    key = jax.random.PRNGKey(3)
    st_a, st_b = b.init(key), PS.ps_init(key, spec)
    np.testing.assert_array_equal(np.asarray(st_a["table"]),
                                  np.asarray(st_b["table"]))
    ids = jnp.asarray([0, 5, -1, 63, 5], jnp.int32)
    acts, m = b.lookup(st_a, ids)
    assert m == {}
    np.testing.assert_array_equal(np.asarray(acts),
                                  np.asarray(PS.lookup(st_b, spec, ids)))
    g = jnp.ones((5, 4), jnp.float32)
    new_a, _ = b.apply_put(st_a, ids, g)
    new_b = PS.apply_put(st_b, spec, ids, g)
    np.testing.assert_array_equal(np.asarray(new_a["table"]),
                                  np.asarray(new_b["table"]))
    np.testing.assert_array_equal(np.asarray(new_a["acc"]),
                                  np.asarray(new_b["acc"]))


# ---------------------------------------------------------------------------
# host-LRU: parity, out-of-core training, queue guard
# ---------------------------------------------------------------------------

def test_host_lru_bit_exact_with_dense_when_working_set_fits():
    """cache_rows == rows: nothing ever evicts, so the out-of-core tier must
    reproduce the dense PS bit for bit through BOTH pipelines (tau=2)."""
    batches = _batches(6)
    td, th = _trainer("dense"), _trainer("host_lru", cache_rows=RPF)
    tf = _trainer("host_lru", cache_rows=RPF)
    sd = td.init(jax.random.PRNGKey(0), batches[0])
    sh = th.init(jax.random.PRNGKey(0), batches[0])
    sf = tf.init(jax.random.PRNGKey(0), batches[0])
    for b in batches:
        sd, md = td.decomposed_step(sd, b)
        sh, mh = th.decomposed_step(sh, b)
        sf, _ = tf.step(sf, b)                       # fused path
    assert float(md["loss"]) == float(mh["loss"])
    rows_d, rows_h = _probe_all_rows(td, sd), _probe_all_rows(th, sh)
    rows_f = _probe_all_rows(tf, sf)
    for n in rows_d:
        np.testing.assert_array_equal(rows_d[n], rows_h[n], err_msg=n)
        np.testing.assert_array_equal(rows_d[n], rows_f[n], err_msg=n)
    # eval agrees too (and faults rows without desyncing the slot maps)
    np.testing.assert_allclose(float(td.eval(sd, batches[0])["loss"]),
                               float(th.eval(sh, batches[0])["loss"]))


def test_host_lru_trains_beyond_device_cache():
    """The acceptance scenario: logical rows 8x the device cache, training
    end-to-end through decomposed_step with real evictions/write-backs."""
    cache = RPF // 8
    # narrow batches so the per-step working set fits the small cache
    it = DS.sampler(4)
    batches = [{k: jnp.asarray(v) for k, v in next(it).items()}
               for _ in range(10)]
    tr = _trainer("host_lru", cache_rows=cache, tau=1)
    state = tr.init(jax.random.PRNGKey(0), batches[0])
    t0 = _probe_all_rows(tr, state)
    for b in batches:
        state, m = tr.decomposed_step(state, b)
    assert np.isfinite(float(m["loss"]))
    name = tr.collection.names[0]
    bk = tr.backends[name]
    assert bk.spec.rows == 8 * bk.cache_rows
    assert bk.faults > cache            # refaulted rows => out-of-core traffic
    assert bk.writebacks > 0            # dirty rows went back to the host
    t1 = _probe_all_rows(tr, state)
    assert any(not np.array_equal(t0[n], t1[n]) for n in t0)
    # device cache holds cache_rows slots; host store holds all logical rows
    assert bk.device_bytes(state.emb[name]) < bk.host_bytes()


def test_host_lru_rejects_oversized_working_set():
    tr = _trainer("host_lru", cache_rows=4, tau=0)
    b = _batches(1, batch=64)[0]
    state = tr.init(jax.random.PRNGKey(0), b)
    with pytest.raises(ValueError, match="working set"):
        tr.decomposed_step(state, b)


def test_host_lru_stale_put_to_recycled_slot_is_dropped():
    """tau-stale puts whose cache slot was recycled for another row must be
    dropped (the paper's tolerated lost put), not applied to the new row."""
    spec = EmbeddingSpec(rows=4, dim=2, mode="full", optimizer="sgd", lr=1.0,
                         staleness=1, backend="host_lru", cache_rows=2)
    bk = create_backend(spec)
    state = bk.init(jax.random.PRNGKey(0))
    queue = bk.queue_init((2,))              # fixed put width: 2 ids/step
    g = jnp.full((2, 2), 7.0)
    state, dev = bk.prepare(state, np.array([0, -1]))
    state, queue, _ = bk.hybrid_update(state, queue, dev, g)   # queued put(0)
    # fault ids 1,2 into the 2-slot cache: id 0 must get evicted
    state, dev12 = bk.prepare(state, np.array([1, 2]))
    assert 0 not in bk._slot_for_id
    before = np.asarray(state["table"]).copy()
    zero = jnp.zeros((2, 2))
    # the pop of put(0) happens here; its slot now belongs to id 1 or 2
    state, queue, _ = bk.hybrid_update(state, queue, dev12, zero)
    np.testing.assert_array_equal(np.asarray(state["table"]), before)
    # control: without the recycle, the tau=1 put lands on id 0's row
    bk2 = create_backend(dataclasses.replace(spec, cache_rows=4))
    st2 = bk2.init(jax.random.PRNGKey(0))
    q2 = bk2.queue_init((2,))
    st2, dev0 = bk2.prepare(st2, np.array([0, -1]))
    st2, q2, _ = bk2.hybrid_update(st2, q2, dev0, g)
    st2, dev0 = bk2.prepare(st2, np.array([0, -1]))
    row_before = np.asarray(bk2.lookup(st2, dev0)[0][0]).copy()
    st2, q2, _ = bk2.hybrid_update(st2, q2, dev0, jnp.zeros((2, 2)))
    st2, dev0 = bk2.prepare(st2, np.array([0, -1]))
    row_after = np.asarray(bk2.lookup(st2, dev0)[0][0])
    np.testing.assert_allclose(row_after, row_before - 7.0, atol=1e-6)


# ---------------------------------------------------------------------------
# checkpoint round-trip (vectors + acc + LRU recency order)
# ---------------------------------------------------------------------------

def test_host_lru_checkpoint_roundtrip_bit_identical(tmp_path):
    it = DS.sampler(8)
    batches = [{k: jnp.asarray(v) for k, v in next(it).items()}
               for _ in range(7)]
    cache = RPF // 4

    def make():
        return _trainer("host_lru", cache_rows=cache, tau=2)

    tr_a = make()
    state = tr_a.init(jax.random.PRNGKey(0), batches[0])
    for b in batches[:4]:
        state, _ = tr_a.decomposed_step(state, b)
    tr_a.save(str(tmp_path), state)
    for b in batches[4:]:
        state, _ = tr_a.decomposed_step(state, b)

    tr_b = make()
    resumed = tr_b.restore(str(tmp_path))
    assert int(resumed.step) == 4
    # the host tier came back: store contents AND recency order
    name = tr_a.collection.names[0]
    ba, bb = tr_a.backends[name], tr_b.backends[name]
    assert bb.store.size == ba.store.size
    for b in batches[4:]:
        resumed, _ = tr_b.decomposed_step(resumed, b)

    # identical continuation: device caches, host stores, recency, counters
    for n in tr_a.collection.names:
        x, y = tr_a.backends[n], tr_b.backends[n]
        assert x.recency_order() == y.recency_order(), n
        assert (x.faults, x.writebacks) == (y.faults, y.writebacks), n
        sa, sb = x.store.serialize(), y.store.serialize()
        for k in sa:
            np.testing.assert_array_equal(sa[k], sb[k], err_msg=f"{n}/{k}")
    rows_a = _probe_all_rows(tr_a, state)
    rows_b = _probe_all_rows(tr_b, resumed)
    for n in rows_a:
        np.testing.assert_array_equal(rows_a[n], rows_b[n], err_msg=n)


def test_host_lru_restore_rejects_mismatches(tmp_path):
    tr = _trainer("host_lru", cache_rows=RPF // 4, tau=0)
    b = _batches(1, batch=8)[0]
    state = tr.init(jax.random.PRNGKey(0), b)
    tr.save(str(tmp_path), state)
    # different cache geometry is refused
    tr2 = _trainer("host_lru", cache_rows=RPF // 2, tau=0)
    with pytest.raises(ValueError, match="cache_rows"):
        tr2.restore(str(tmp_path))
    # a dense trainer cannot adopt a host_lru checkpoint
    td = _trainer("dense", tau=0)
    with pytest.raises(ValueError, match="backend"):
        td.restore(str(tmp_path))
    # ... nor the reverse
    td.save(str(tmp_path / "dense"), td.init(jax.random.PRNGKey(0), b))
    tr3 = _trainer("host_lru", cache_rows=RPF // 4, tau=0)
    with pytest.raises(ValueError, match="backend"):
        tr3.restore(str(tmp_path / "dense"))


# ---------------------------------------------------------------------------
# compressed wire
# ---------------------------------------------------------------------------

def test_compressed_wire_reduces_bytes_and_stays_close():
    """Acceptance: >= 1.8x bytes-moved reduction at AUC-neutral settings
    (blockscale fp16 max rel err ~2^-11, so training stays close to the
    uncompressed run)."""
    batches = _batches(6)
    tc = _trainer("dense+compressed")
    td = _trainer("dense")
    sc = tc.init(jax.random.PRNGKey(0), batches[0])
    sd = td.init(jax.random.PRNGKey(0), batches[0])
    raw = wire = 0.0
    for b in batches:
        sc, m = tc.decomposed_step(sc, b)
        sd, _ = td.decomposed_step(sd, b)
        raw += sum(float(v) for k, v in m.items()
                   if k.startswith("wire/") and k.endswith("bytes_raw"))
        wire += sum(float(v) for k, v in m.items()
                    if k.startswith("wire/") and k.endswith("bytes_wire"))
    assert raw / wire >= 1.8, f"wire ratio {raw / wire:.2f}x < 1.8x"
    pc = np.asarray(tc.predict(sc, batches[0]))
    pd = np.asarray(td.predict(sd, batches[0]))
    np.testing.assert_allclose(pc, pd, atol=5e-2)


def test_compressed_wire_over_host_lru_and_kernel_path():
    batches = _batches(4, batch=16)
    tr = _trainer("host_lru+compressed", cache_rows=RPF, tau=1)
    state = tr.init(jax.random.PRNGKey(0), batches[0])
    for b in batches:
        state, m = tr.decomposed_step(state, b)
    assert any(k.endswith("put_bytes_wire") for k in m)
    assert np.isfinite(float(m["loss"]))
    # the Pallas kernel path is selectable per spec
    spec = EmbeddingSpec(rows=32, dim=16, mode="full",
                         backend="dense+compressed", wire_kernel=True)
    bk = create_backend(spec)
    st = bk.init(jax.random.PRNGKey(0))
    acts, m = bk.lookup(st, jnp.arange(8, dtype=jnp.int32))
    assert np.isfinite(np.asarray(acts)).all()
    with pytest.raises(ValueError, match="block"):
        create_backend(dataclasses.replace(spec, wire_block=64))


def test_compressed_queue_holds_deduped_puts():
    """The staleness queue lives PS-side, after the wire: what gets queued
    is the losslessly deduped put (one summed row per unique id)."""
    spec = EmbeddingSpec(rows=16, dim=4, mode="full", optimizer="sgd",
                         staleness=1, backend="dense+compressed")
    bk = create_backend(spec)
    state = bk.init(jax.random.PRNGKey(0))
    queue = bk.queue_init((6,))
    ids = jnp.asarray([3, 3, 5, 5, 5, -1], jnp.int32)
    g = jnp.ones((6, 4), jnp.float32)
    state, queue, m = bk.hybrid_update(state, queue, ids, g)
    qids = np.asarray(queue["ids"][0])
    assert sorted(qids[qids >= 0].tolist()) == [3, 5]      # deduped
    qg = {int(i): np.asarray(row) for i, row in
          zip(queue["ids"][0], queue["grads"][0]) if i >= 0}
    np.testing.assert_allclose(qg[3], 2 * np.ones(4), rtol=1e-3)
    np.testing.assert_allclose(qg[5], 3 * np.ones(4), rtol=1e-3)
    assert float(m["put_bytes_wire"]) < float(m["put_bytes_raw"])
