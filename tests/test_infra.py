"""Infrastructure-layer tests: HLO cost walker, partition rules, input
specs, data generators, config registry."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
from repro.data.ctr import CTRDataset
from repro.data.lm import lm_batches
from repro.launch import hlo_cost, input_specs as IS
from repro.sharding import partition as PART


# ---------------------------------------------------------------------------
# HLO cost walker
# ---------------------------------------------------------------------------

def test_walker_multiplies_scan_trip_count():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    txt = jax.jit(f).lower(x, w).compile().as_text()
    st = hlo_cost.analyze(txt)
    assert st["flops"] == pytest.approx(2 * 128 * 256 * 256 * 10, rel=0.01)


def test_walker_counts_nested_scans():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        out, _ = jax.lax.scan(outer, x, None, length=4)
        return out

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    txt = jax.jit(f).lower(x, w).compile().as_text()
    st = hlo_cost.analyze(txt)
    assert st["flops"] == pytest.approx(2 * 64 * 64 * 64 * 12, rel=0.01)


def test_walker_shape_parse():
    b, e = hlo_cost._shape_bytes_elems("(f32[2,3]{1,0}, bf16[4])")
    assert e == 10 and b == 2 * 3 * 4 + 4 * 2


# ---------------------------------------------------------------------------
# Partition rules
# ---------------------------------------------------------------------------

def test_partition_rules_shapes():
    params = {
        "stack": {"0": {"mixer": {"wq": jnp.zeros((4, 64, 128)),
                                  "wo": jnp.zeros((4, 128, 64))},
                        "ffn": {"wg": jnp.zeros((4, 8, 64, 128))}}},
        "lm_head": jnp.zeros((64, 1024)),
        "final_norm": {"w": jnp.zeros((64,))},
    }
    specs = PART.dense_param_specs(params)
    assert specs["stack"]["0"]["mixer"]["wq"] == P(None, "data", "model")
    assert specs["stack"]["0"]["mixer"]["wo"] == P(None, "model", "data")
    # stacked MoE experts: (repeats, E, d_in, d_out)
    assert specs["stack"]["0"]["ffn"]["wg"] == P(None, "model", "data", None)
    assert specs["lm_head"] == P("data", "model")
    assert specs["final_norm"]["w"] == P(None)


def test_to_shardings_divisibility_guard():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    leaf = jax.ShapeDtypeStruct((7, 64), jnp.float32)   # 7 % 16 != 0
    out = PART._guard(P("data", "model"), FakeMesh(), leaf)
    assert out == P(None, "model")
    leaf2 = jax.ShapeDtypeStruct((32, 13), jnp.float32)  # 13 % 16 != 0
    assert PART._guard(P("data", "model"), FakeMesh(), leaf2) == \
        P("data", None)


# ---------------------------------------------------------------------------
# input specs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_cover_model_inputs(arch):
    cfg = get_config(arch)
    tr = IS.train_inputs(cfg, INPUT_SHAPES["train_4k"])
    assert tr["tokens"].shape == (256, 4096)
    if cfg.is_encdec or cfg.n_memory_tokens:
        assert "memory" in tr
    dec = IS.decode_inputs(cfg, INPUT_SHAPES["decode_32k"])
    assert dec["tokens"].shape == (128, 1)
    pre = IS.prefill_inputs(cfg, INPUT_SHAPES["prefill_32k"])
    assert pre["tokens"].shape == (32, 32768)


# ---------------------------------------------------------------------------
# data generators
# ---------------------------------------------------------------------------

def test_ctr_generator_statistics():
    ds = CTRDataset("t", n_rows=10_000, n_fields=8, ids_per_field=4,
                    n_dense=4)
    b = next(ds.sampler(2048))
    ids = b["ids"]
    assert ids.shape == (2048, 8, 4)
    valid = ids[ids >= 0]
    assert valid.min() >= 0 and valid.max() < 10_000
    # zipf skew: top-1% of ids should carry a large share of traffic
    counts = np.bincount(valid, minlength=10_000)
    top = np.sort(counts)[::-1]
    assert top[:100].sum() > 0.2 * counts.sum()
    # labels not degenerate
    assert 0.02 < b["labels"].mean() < 0.98


def test_ctr_planted_signal_learnable():
    """The planted logistic truth must be recoverable: empirical label rate
    differs between samples containing a hot id vs not."""
    ds = CTRDataset("t", n_rows=1_000, n_fields=4, ids_per_field=4,
                    n_dense=2)
    b = next(ds.sampler(8192))
    y = b["labels"][:, 0]
    assert y.std() > 0.1


def test_lm_generator_markov_structure():
    it = lm_batches(vocab_size=64, batch=16, seq_len=32, branch=4)
    b = next(it)
    assert b["tokens"].shape == (16, 32)
    # successor entropy bounded: each token has <= 4 frequent successors
    pairs = {}
    for row_t, row_y in zip(b["tokens"], b["targets"]):
        for a, c in zip(row_t, row_y):
            pairs.setdefault(int(a), set()).add(int(c))
    # with 5% noise a few extras are possible; check the bulk
    sizes = sorted(len(v) for v in pairs.values())
    assert sizes[len(sizes) // 2] <= 6


def test_registry_roundtrip():
    for a in ARCH_IDS:
        cfg = get_config(a)
        red = get_config(a, reduced=True)
        assert red.d_model <= 256
        assert red.n_layers <= cfg.n_layers
        kinds = {(b.mixer, b.ffn) for b in cfg.pattern}
        red_kinds = {(b.mixer, b.ffn) for b in red.pattern}
        assert red_kinds <= kinds or not cfg.pattern
