"""Per-assigned-architecture smoke tests: a REDUCED variant of the same
family (<=3 scanned layers, d_model<=256, <=4 experts) runs one hybrid train
step AND one decode step on CPU; asserts output shapes + finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.core import adapters, embedding_ps as PS, hybrid
from repro.core.hybrid import TrainMode
from repro.models import transformer as T
from repro.optim.optimizers import OptConfig, make_optimizer


def _batch_for(cfg, B=2, S=16):
    rng = np.random.default_rng(0)
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                               jnp.int32),
         "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                jnp.int32),
         "mask": jnp.ones((B, S), jnp.float32)}
    if cfg.is_encdec:
        e = cfg.encoder
        b["memory"] = jnp.asarray(
            rng.standard_normal((B, e.n_memory_tokens, e.d_memory)) * 0.1,
            jnp.float32)
    elif cfg.n_memory_tokens:
        b["memory"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_memory_tokens, cfg.d_memory)) * 0.1,
            jnp.float32)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_train_step(arch):
    cfg = get_config(arch, reduced=True).replace(capacity_factor=4.0)
    adapter = adapters.lm_adapter(cfg)
    opt_init, opt_update = make_optimizer(OptConfig(kind="adam", lr=1e-3))
    mode = TrainMode.hybrid(min(cfg.emb_staleness, 2) or 1)
    batch = _batch_for(cfg)
    state, spec = hybrid.init_train_state(adapter, mode, opt_init,
                                          jax.random.PRNGKey(0), batch)
    step = jax.jit(hybrid.make_train_step(adapter, spec, mode, opt_update))
    state, metrics = step(state, batch)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"])), arch
    for leaf in jax.tree.leaves(state["dense"]):
        assert bool(jnp.isfinite(leaf).all()), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_decode_step(arch):
    cfg = get_config(arch, reduced=True).replace(capacity_factor=4.0)
    if arch == "whisper_medium":
        pass  # decode supported (32k shape); 500k skip documented
    key = jax.random.PRNGKey(0)
    dense = T.init_dense(cfg, key)
    spec = PS.EmbeddingSpec(rows=cfg.vocab_size, dim=cfg.d_model)
    emb = PS.ps_init(key, spec)
    B, CAP = 2, 24
    mlen = cfg.encoder.n_memory_tokens if cfg.is_encdec \
        else cfg.n_memory_tokens
    caches = T.cache_init(cfg, B, CAP, jnp.float32, memory_len=mlen)
    tok = jnp.zeros((B, 1), jnp.int32)
    acts = PS.lookup(emb, spec, tok)
    logits, caches = T.decode_step(cfg, dense, acts, caches)
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits[..., : cfg.vocab_size]).all()), arch
    logits2, _ = T.decode_step(cfg, dense, acts, caches)
    assert bool(jnp.isfinite(logits2[..., : cfg.vocab_size]).all()), arch


def test_all_archs_have_exact_assigned_dims():
    """The full configs carry the exact assigned hyperparameters."""
    want = {
        "deepseek_v2_lite_16b": dict(d_model=2048, n_heads=16,
                                     vocab_size=102400, kv_lora_rank=512,
                                     n_experts=64, moe_top_k=6,
                                     moe_d_ff=1408, n_shared_experts=2),
        "qwen3_14b": dict(d_model=5120, n_heads=40, n_kv_heads=8,
                          d_ff=17408, vocab_size=151936, qk_norm=True),
        "deepseek_v2_236b": dict(d_model=5120, n_heads=128,
                                 vocab_size=102400, kv_lora_rank=512,
                                 q_lora_rank=1536, n_experts=160,
                                 moe_top_k=6, moe_d_ff=1536),
        "phi3_mini_3_8b": dict(d_model=3072, n_heads=32, n_kv_heads=32,
                               d_ff=8192, vocab_size=32064),
        "mamba2_1_3b": dict(d_model=2048, ssm_state=128, vocab_size=50280),
        "llama_3_2_vision_90b": dict(d_model=8192, n_heads=64, n_kv_heads=8,
                                     d_ff=28672, vocab_size=128256),
        "deepseek_coder_33b": dict(d_model=7168, n_heads=56, n_kv_heads=8,
                                   d_ff=19200, vocab_size=32256),
        "jamba_v0_1_52b": dict(d_model=4096, n_heads=32, n_kv_heads=8,
                               d_ff=14336, vocab_size=65536, n_experts=16,
                               moe_top_k=2),
        "whisper_medium": dict(d_model=1024, n_heads=16, d_ff=4096,
                               vocab_size=51865),
        "granite_3_2b": dict(d_model=2048, n_heads=32, n_kv_heads=8,
                             d_ff=8192, vocab_size=49155),
    }
    layers = {"deepseek_v2_lite_16b": 27, "qwen3_14b": 40,
              "deepseek_v2_236b": 60, "phi3_mini_3_8b": 32,
              "mamba2_1_3b": 48, "llama_3_2_vision_90b": 100,
              "deepseek_coder_33b": 62, "jamba_v0_1_52b": 32,
              "whisper_medium": 24, "granite_3_2b": 40}
    for arch, dims in want.items():
        cfg = get_config(arch)
        for k, v in dims.items():
            assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)
        assert cfg.n_layers == layers[arch], (arch, cfg.n_layers)
