"""Embedding PS semantics: lookup/put vs a dense oracle, uniform-shuffle
balance, bounded-staleness queue behaviour (Assumption 1: t - D(t) = tau)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:        # optional dep: only the property test needs it
    HAVE_HYPOTHESIS = False

from repro.core import embedding_ps as PS


def _spec(**kw):
    base = dict(rows=64, dim=8, mode="model", optimizer="sgd", lr=0.5,
                staleness=0)
    base.update(kw)
    return PS.EmbeddingSpec(**base)


def test_lookup_returns_rows_and_masks_invalid():
    spec = _spec()
    st_ = PS.ps_init(jax.random.PRNGKey(0), spec)
    ids = jnp.array([0, 5, -1, 63, 64], jnp.int32)   # 64 out of range
    out = PS.lookup(st_, spec, ids)
    pos = PS.shuffle_pos(jnp.array([0, 5, 63]), 64)
    np.testing.assert_allclose(out[0], st_["table"][pos[0]])
    np.testing.assert_allclose(out[1], st_["table"][pos[1]])
    assert jnp.all(out[2] == 0) and jnp.all(out[4] == 0)
    np.testing.assert_allclose(out[3], st_["table"][pos[2]])


def test_put_sgd_matches_oracle():
    spec = _spec(optimizer="sgd", lr=0.1)
    st_ = PS.ps_init(jax.random.PRNGKey(1), spec)
    ids = jnp.array([3, 3, 7, -1], jnp.int32)
    grads = jnp.asarray(np.random.default_rng(0)
                        .standard_normal((4, 8)).astype(np.float32))
    new = PS.apply_put(st_, spec, ids, grads)
    # oracle: duplicate ids accumulate, -1 dropped
    before3 = PS.lookup(st_, spec, jnp.array([3]))[0]
    after3 = PS.lookup(new, spec, jnp.array([3]))[0]
    np.testing.assert_allclose(after3, before3 - 0.1 * (grads[0] + grads[1]),
                               atol=1e-5)
    before7 = PS.lookup(st_, spec, jnp.array([7]))[0]
    after7 = PS.lookup(new, spec, jnp.array([7]))[0]
    np.testing.assert_allclose(after7, before7 - 0.1 * grads[2], atol=1e-5)


def test_adagrad_put_scales_by_accumulator():
    spec = _spec(optimizer="adagrad", lr=1.0, eps=0.0)
    st_ = PS.ps_init(jax.random.PRNGKey(1), spec)
    ids = jnp.array([3], jnp.int32)
    g = jnp.ones((1, 8))
    new = PS.apply_put(st_, spec, ids, g)
    # acc = mean(g^2) = 1 -> step = g / sqrt(1) = 1
    d = PS.lookup(st_, spec, ids)[0] - PS.lookup(new, spec, ids)[0]
    np.testing.assert_allclose(d, jnp.ones(8), atol=1e-5)
    new2 = PS.apply_put(new, spec, ids, g)
    d2 = PS.lookup(new, spec, ids)[0] - PS.lookup(new2, spec, ids)[0]
    np.testing.assert_allclose(d2, jnp.ones(8) / np.sqrt(2), atol=1e-5)


def test_uniform_shuffle_balances_hot_range():
    """Paper §4.2.3: a contiguous hot feature group spreads over shards."""
    rows = 4096
    n_shards = 16
    ids = jnp.arange(256)              # one hot 'feature group'
    pos = np.asarray(PS.shuffle_pos(ids, rows))
    shard_of = pos // (rows // n_shards)
    counts = np.bincount(shard_of, minlength=n_shards)
    assert counts.max() <= 3 * max(counts.mean(), 1)


if HAVE_HYPOTHESIS:
    @settings(deadline=None, max_examples=15)
    @given(st.integers(0, 1 << 20), st.integers(4, 1000))
    def test_shuffle_pos_in_range(i, rows):
        p = int(PS.shuffle_pos(jnp.array([i]), rows)[0])
        assert 0 <= p < rows
else:
    @pytest.mark.parametrize("i,rows", [(0, 4), (1, 7), (123_456, 1000),
                                        ((1 << 20) - 1, 997)])
    def test_shuffle_pos_in_range(i, rows):
        p = int(PS.shuffle_pos(jnp.array([i]), rows)[0])
        assert 0 <= p < rows


# ---------------------------------------------------------------------------
# staleness queue: lookup at t must see updates through t - tau exactly
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tau", [1, 2, 4])
def test_queue_delays_updates_by_tau(tau):
    spec = _spec(optimizer="sgd", lr=1.0, staleness=tau)
    state = PS.ps_init(jax.random.PRNGKey(0), spec)
    table0 = state["table"].copy()
    queue = PS.queue_init(spec, (1,), spec.dim)
    target = jnp.array([5], jnp.int32)
    for t in range(2 * tau + 2):
        g = jnp.full((1, spec.dim), float(t + 1))
        state, queue = PS.hybrid_emb_update(state, queue, spec, target, g)
        got = PS.lookup(state, spec, target)[0]
        # applied puts are those from steps <= t - tau:
        applied = sum(s + 1 for s in range(t - tau + 1)) if t >= tau else 0.0
        want = PS.lookup({"table": table0}, spec, target)[0] - applied
        np.testing.assert_allclose(got, want, atol=1e-4,
                                   err_msg=f"t={t} tau={tau}")


def test_tau_zero_is_synchronous():
    spec = _spec(optimizer="sgd", lr=1.0, staleness=0)
    state = PS.ps_init(jax.random.PRNGKey(0), spec)
    before = PS.lookup(state, spec, jnp.array([1]))[0]
    state, q = PS.hybrid_emb_update(state, None, spec, jnp.array([1]),
                                    jnp.ones((1, spec.dim)))
    after = PS.lookup(state, spec, jnp.array([1]))[0]
    np.testing.assert_allclose(before - after, jnp.ones(spec.dim), atol=1e-5)
