"""Optimizers + checkpointing + theory calculator."""
import os

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_checkpoint, save_checkpoint, CheckpointManager
from repro.core.theory import estimate_alpha, hybrid_rate_bound, optimal_lr
from repro.optim.optimizers import (adam_init, adam_update,
                                    linear_warmup_cosine,
                                    sgd_init, sgd_update)


def test_sgd_momentum_matches_formula():
    p = {"w": jnp.array([1.0, 2.0])}
    g = {"w": jnp.array([0.5, -0.5])}
    st = sgd_init(p, momentum=0.9)
    p1, st = sgd_update(p, g, st, lr=0.1, momentum=0.9)
    np.testing.assert_allclose(p1["w"], [1 - 0.05, 2 + 0.05])
    p2, st = sgd_update(p1, g, st, lr=0.1, momentum=0.9)
    m2 = 0.9 * 0.5 + 0.5
    np.testing.assert_allclose(p2["w"][0], p1["w"][0] - 0.1 * m2, rtol=1e-6)


def test_adam_converges_on_quadratic():
    p = {"w": jnp.array([5.0, -3.0])}
    st = adam_init(p)
    for _ in range(300):
        g = {"w": 2 * p["w"]}
        p, st = adam_update(p, g, st, lr=0.05)
    assert float(jnp.max(jnp.abs(p["w"]))) < 0.05


def test_grad_clip_equals_prescaled():
    """Clipping to c is identical to feeding grads scaled by c/||g||
    (adam itself is scale-invariant, so compare against that oracle)."""
    p = {"w": jnp.array([1.0, -2.0, 3.0])}
    g = {"w": jnp.array([3.0, 4.0, 0.0])}       # norm 5
    p1, s1 = adam_update(p, g, adam_init(p), lr=0.1, grad_clip=1.0)
    g_scaled = {"w": g["w"] / 5.0}
    p2, s2 = adam_update(p, g_scaled, adam_init(p), lr=0.1, grad_clip=0.0)
    np.testing.assert_allclose(p1["w"], p2["w"], rtol=1e-6)


def test_lr_schedule():
    s = jnp.arange(0, 100)
    lr = linear_warmup_cosine(s, base_lr=1.0, warmup=10, total=100)
    assert float(lr[0]) == 0.0
    assert abs(float(lr[10]) - 1.0) < 1e-6
    assert float(lr[99]) < 0.01


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "nested": {"b": np.ones(4, np.int32)},
            "lst": [np.zeros(2), np.ones(2)]}
    emb = {"table": np.random.default_rng(0).standard_normal((8, 4))
           .astype(np.float32)}
    save_checkpoint(str(tmp_path), 7, tree, emb)
    step, dense, emb2 = load_checkpoint(str(tmp_path))
    assert step == 7
    np.testing.assert_array_equal(dense["a"], tree["a"])
    np.testing.assert_array_equal(dense["nested"]["b"], tree["nested"]["b"])
    np.testing.assert_array_equal(dense["lst"][1], tree["lst"][1])
    np.testing.assert_array_equal(emb2["table"], emb["table"])


def test_checkpoint_manager_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every=1, keep=2)
    for s in range(5):
        mgr.maybe_save(s, {"w": np.zeros(2)})
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 2 and steps[-1].endswith("00000004")


def test_theory_bound_monotone_in_tau_and_alpha():
    b0 = hybrid_rate_bound(1000, sigma=1.0, tau=0, alpha=0.1)
    b5 = hybrid_rate_bound(1000, sigma=1.0, tau=5, alpha=0.1)
    assert b5["total"] > b0["total"]
    ba = hybrid_rate_bound(1000, sigma=1.0, tau=5, alpha=1.0)
    assert ba["staleness_term"] > b5["staleness_term"]
    # alpha << 1 => staleness negligible vs sgd term (the paper's claim)
    b = hybrid_rate_bound(10_000, sigma=1.0, tau=5, alpha=1e-3)
    assert b["stale_fraction"] < 0.01


def test_optimal_lr_decreasing_in_tau():
    assert optimal_lr(1000, 1.0, 0, 0.1) > optimal_lr(1000, 1.0, 10, 0.1)


def test_estimate_alpha():
    b1 = np.array([[0, 1, -1], [0, 2, 3]])
    b2 = np.array([[0, 4, -1], [5, 6, 7]])
    a = estimate_alpha([b1, b2], n_rows=8)
    assert abs(a - 3 / 4) < 1e-9        # id 0 in 3 of 4 samples
