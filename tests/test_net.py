"""The PS wire + RPC layer (repro/net/wire.py, rpc.py): framing over real
sockets, array-tree codec roundtrips, numpy-vs-jnp blockscale bit parity,
request timeout/retry/unavailable semantics, remote-error propagation, and
at-most-once replay suppression for mutating ops."""
import socket
import threading
import time

import numpy as np
import pytest

from repro.core import compression as C
from repro.net import wire
from repro.net.rpc import PSUnavailableError, RpcClient, RpcError, RpcServer


# ---------------------------------------------------------------------------
# framing
# ---------------------------------------------------------------------------

def test_frame_roundtrip_over_socketpair():
    a, b = socket.socketpair()
    payload = b"x" * 100_000
    try:
        t = threading.Thread(target=wire.send_frame, args=(a, payload))
        t.start()
        got = wire.recv_frame(b)
        t.join()
        assert got == payload
    finally:
        a.close()
        b.close()


def test_frame_bad_magic_and_short_read():
    a, b = socket.socketpair()
    try:
        a.sendall(b"NOPE" + (8).to_bytes(8, "little"))
        with pytest.raises(wire.WireError, match="magic"):
            wire.recv_frame(b)
        # a peer dying mid-frame is a short read, never a garbage parse
        a.sendall(wire.MAGIC + (100).to_bytes(8, "little") + b"abc")
        a.close()
        with pytest.raises(wire.WireError, match="mid-frame"):
            wire.recv_frame(b)
    finally:
        b.close()


# ---------------------------------------------------------------------------
# array-tree codec
# ---------------------------------------------------------------------------

def test_codec_roundtrip_nested_tree():
    tree = {
        "a": np.arange(12, dtype=np.int64).reshape(3, 4),
        "b": {"c": np.float32(0).reshape(()) + 1.5,
              "empty": np.zeros((0, 8), np.float32),
              "f16": np.arange(6, dtype=np.float16)},
        "scalars": [1, 2.5, "name", None, True, False],
        "tup": (np.int32(7), [np.ones(3, np.float64)]),
    }
    out = wire.decode(wire.encode(tree))
    assert isinstance(out["tup"], tuple)           # tuples survive
    assert isinstance(out["scalars"], list)
    assert out["scalars"] == [1, 2.5, "name", None, True, False]
    assert out["scalars"][4] is True and out["scalars"][5] is False
    np.testing.assert_array_equal(out["a"], tree["a"])
    assert out["a"].dtype == np.int64
    np.testing.assert_array_equal(out["b"]["f16"], tree["b"]["f16"])
    assert out["b"]["empty"].shape == (0, 8)
    assert out["b"]["empty"].dtype == np.float32
    np.testing.assert_array_equal(out["tup"][1][0], np.ones(3, np.float64))


def test_codec_decoded_arrays_are_owned():
    # decode() must copy out of the receive buffer: the arrays outlive it
    src = np.arange(100, dtype=np.float32)
    out = wire.decode(wire.encode({"x": src}))
    assert out["x"].flags["WRITEABLE"]
    out["x"][0] = -1.0
    assert src[0] == 0.0


def test_codec_rejects_object_arrays():
    with pytest.raises(wire.WireError, match="object"):
        wire.encode({"bad": np.array([object()])})


def test_spec_dict_roundtrip():
    from repro.core.embedding_ps import EmbeddingSpec
    spec = EmbeddingSpec(rows=64, dim=8, backend="host_lru", cache_rows=16,
                         staleness=2)
    out = wire.spec_from_dict(wire.decode(wire.encode(
        wire.spec_to_dict(spec))))
    assert out == spec


# ---------------------------------------------------------------------------
# blockscale wire: numpy mirror == jnp reference, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(7,), (16, 8), (3, 128), (130,)])
def test_np_blockscale_matches_jnp_reference(shape):
    rng = np.random.default_rng(0)
    v = (rng.standard_normal(shape)
         * 10.0 ** rng.integers(-4, 4, shape)).astype(np.float32)
    comp_np, scale_np, _ = wire.np_blockscale_compress(v, block=128)
    comp_j, scale_j, _ = C.blockscale_compress(v, block=128)
    np.testing.assert_array_equal(comp_np, np.asarray(comp_j))
    np.testing.assert_array_equal(scale_np, np.asarray(scale_j).reshape(-1))
    # and the decompressed values match the jnp roundtrip exactly
    out_np = wire.np_blockscale_decompress(comp_np, scale_np, shape)
    np.testing.assert_array_equal(out_np, np.asarray(
        C.blockscale_roundtrip(v, block=128)))


def test_lossy_pack_roundtrip_and_sizes():
    v = np.random.default_rng(1).standard_normal((40, 8)).astype(np.float32)
    p = wire.lossy_pack(v, block=128)
    out = wire.lossy_unpack(p)
    assert out.shape == v.shape
    np.testing.assert_allclose(out, v, rtol=2e-3, atol=1e-6)
    # fp16 payload + fp32 per-block scales: roughly half the raw bytes
    assert wire.payload_nbytes(p) < v.nbytes
    # raw arrays pass through unpack untouched
    np.testing.assert_array_equal(wire.lossy_unpack(v), v)
    assert wire.payload_nbytes(v) == v.nbytes


# ---------------------------------------------------------------------------
# RPC semantics
# ---------------------------------------------------------------------------

def _echo_server(extra=None):
    calls = {"n": 0}

    def bump(**kw):
        calls["n"] += 1
        return {"n": calls["n"], **kw}

    handlers = {"ping": lambda: {"pong": True},
                "echo": lambda **kw: kw,
                "bump": bump,
                "boom": lambda: (_ for _ in ()).throw(
                    ValueError("handler exploded"))}
    if extra:
        handlers.update(extra)
    srv = RpcServer(handlers, mutating_ops={"bump"}).start()
    return srv, calls


def test_rpc_call_and_remote_error():
    srv, _ = _echo_server()
    try:
        c = RpcClient("127.0.0.1", srv.port, timeout=5.0, retries=0)
        out = c.call("echo", x=np.arange(5, dtype=np.int32), s="hi")
        np.testing.assert_array_equal(out["x"], np.arange(5, dtype=np.int32))
        assert out["s"] == "hi"
        assert c.ping()
        # handler exceptions come back typed, the server stays up
        with pytest.raises(RpcError, match="ValueError: handler exploded"):
            c.call("boom")
        with pytest.raises(RpcError, match="unknown rpc op"):
            c.call("nope")
        assert c.call("echo", ok=1)["ok"] == 1       # still serving
        assert c.bytes_sent > 0 and c.bytes_recv > 0
        c.close()
    finally:
        srv.stop()


def test_rpc_unavailable_after_retries(free_port):
    c = RpcClient("127.0.0.1", free_port(), timeout=0.5, retries=1,
                  backoff=0.01)
    with pytest.raises(PSUnavailableError, match="after 2 attempts"):
        c.call("ping")
    assert c.ping() is False


def test_rpc_reconnects_after_server_restart():
    srv, _ = _echo_server()
    port = srv.port
    c = RpcClient("127.0.0.1", port, timeout=5.0, retries=4, backoff=0.05)
    assert c.call("echo", a=1)["a"] == 1
    srv.stop()
    # same port comes back (retrying the bind out of TIME_WAIT, as a
    # restarted PS would): the client's retry loop must reconnect
    # transparently
    deadline = time.time() + 10.0
    while True:
        try:
            srv2 = RpcServer({"echo": lambda **kw: kw}, port=port).start()
            break
        except OSError:
            if time.time() > deadline:
                raise
            time.sleep(0.05)
    try:
        assert c.call("echo", a=2)["a"] == 2
    finally:
        c.close()
        srv2.stop()


def test_rpc_replay_suppression_applies_mutations_once():
    srv, calls = _echo_server()
    try:
        c = RpcClient("127.0.0.1", srv.port, timeout=5.0, retries=0)
        r1 = c.call("bump", _mutating=True, tag="a")
        assert (r1["n"], calls["n"]) == (1, 1)
        # replay the exact same (client, seq) — as a retry after a lost
        # reply would: the cached ack comes back, the handler does NOT run
        payload = wire.encode({"op": "bump", "args": {"tag": "a"},
                               "seq": 1, "client": c._client_id})
        reply = wire.decode(srv._dispatch(payload))
        assert reply["ok"]["n"] == 1
        assert calls["n"] == 1                        # not re-applied
        # a NEW seq applies normally
        assert c.call("bump", _mutating=True)["n"] == 2
        assert calls["n"] == 2
        c.close()
    finally:
        srv.stop()


def test_rpc_concurrent_clients():
    srv, calls = _echo_server()
    errs = []

    def worker(i):
        try:
            c = RpcClient("127.0.0.1", srv.port, timeout=10.0, retries=0)
            for j in range(20):
                out = c.call("echo", i=i, j=j)
                assert (out["i"], out["j"]) == (i, j)
            c.close()
        except Exception as e:                        # noqa: BLE001
            errs.append(e)

    try:
        ts = [threading.Thread(target=worker, args=(i,)) for i in range(4)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert not errs
    finally:
        srv.stop()
