"""The PS wire + RPC layer (repro/net/wire.py, rpc.py): framing over real
sockets (legacy + rid-tagged zero-copy), array-tree codec roundtrips,
numpy-vs-jnp blockscale bit parity, request timeout/retry/unavailable
semantics, remote-error propagation, pipelined out-of-order completion,
op coalescing, and at-most-once replay suppression for mutating ops
(including retried in-flight seqs after a dropped reply)."""
import socket
import threading
import time

import numpy as np
import pytest

from repro.core import compression as C
from repro.net import wire
from repro.net.rpc import PSUnavailableError, RpcClient, RpcError, RpcServer


# ---------------------------------------------------------------------------
# framing
# ---------------------------------------------------------------------------

def test_frame_roundtrip_over_socketpair():
    a, b = socket.socketpair()
    payload = b"x" * 100_000
    try:
        t = threading.Thread(target=wire.send_frame, args=(a, payload))
        t.start()
        got = wire.recv_frame(b)
        t.join()
        assert got == payload
    finally:
        a.close()
        b.close()


def test_frame_bad_magic_and_short_read():
    a, b = socket.socketpair()
    try:
        a.sendall(b"NOPE" + (8).to_bytes(8, "little"))
        with pytest.raises(wire.WireError, match="magic"):
            wire.recv_frame(b)
        # a peer dying mid-frame is a short read, never a garbage parse
        a.sendall(wire.MAGIC + (100).to_bytes(8, "little") + b"abc")
        a.close()
        with pytest.raises(wire.WireError, match="mid-frame"):
            wire.recv_frame(b)
    finally:
        b.close()


def test_tagged_frame_scatter_gather_roundtrip():
    # the pipelined transport's framing: rid in the header, payload sent
    # as a buffer list via sendmsg, received into a reusable buffer
    a, b = socket.socketpair()
    buf = wire.RecvBuffer(initial=16)        # force growth
    tree = {"x": np.arange(1000, dtype=np.float32), "tag": "hello"}
    parts = wire.encode_parts(tree)
    try:
        t = threading.Thread(target=wire.send_frame_parts,
                             args=(a, 42, parts))
        t.start()
        rid, view = wire.recv_frame_tagged(b, buf)
        t.join()
        assert rid == 42
        out = wire.decode(view)
        assert out["tag"] == "hello"
        np.testing.assert_array_equal(out["x"], tree["x"])
        # decoded arrays are owned — reusing the buffer can't corrupt them
        wire.send_frame_parts(a, 43, wire.encode_parts({"y": 0}))
        wire.recv_frame_tagged(b, buf)
        np.testing.assert_array_equal(out["x"], tree["x"])
    finally:
        a.close()
        b.close()


# ---------------------------------------------------------------------------
# array-tree codec
# ---------------------------------------------------------------------------

def test_codec_roundtrip_nested_tree():
    tree = {
        "a": np.arange(12, dtype=np.int64).reshape(3, 4),
        "b": {"c": np.float32(0).reshape(()) + 1.5,
              "empty": np.zeros((0, 8), np.float32),
              "f16": np.arange(6, dtype=np.float16)},
        "scalars": [1, 2.5, "name", None, True, False],
        "tup": (np.int32(7), [np.ones(3, np.float64)]),
    }
    out = wire.decode(wire.encode(tree))
    assert isinstance(out["tup"], tuple)           # tuples survive
    assert isinstance(out["scalars"], list)
    assert out["scalars"] == [1, 2.5, "name", None, True, False]
    assert out["scalars"][4] is True and out["scalars"][5] is False
    np.testing.assert_array_equal(out["a"], tree["a"])
    assert out["a"].dtype == np.int64
    np.testing.assert_array_equal(out["b"]["f16"], tree["b"]["f16"])
    assert out["b"]["empty"].shape == (0, 8)
    assert out["b"]["empty"].dtype == np.float32
    np.testing.assert_array_equal(out["tup"][1][0], np.ones(3, np.float64))


def test_codec_decoded_arrays_are_owned():
    # decode() must copy out of the receive buffer: the arrays outlive it
    src = np.arange(100, dtype=np.float32)
    out = wire.decode(wire.encode({"x": src}))
    assert out["x"].flags["WRITEABLE"]
    out["x"][0] = -1.0
    assert src[0] == 0.0


def test_codec_rejects_object_arrays():
    with pytest.raises(wire.WireError, match="object"):
        wire.encode({"bad": np.array([object()])})


def test_spec_dict_roundtrip():
    from repro.core.embedding_ps import EmbeddingSpec
    spec = EmbeddingSpec(rows=64, dim=8, backend="host_lru", cache_rows=16,
                         staleness=2)
    out = wire.spec_from_dict(wire.decode(wire.encode(
        wire.spec_to_dict(spec))))
    assert out == spec


# ---------------------------------------------------------------------------
# blockscale wire: numpy mirror == jnp reference, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(7,), (16, 8), (3, 128), (130,)])
def test_np_blockscale_matches_jnp_reference(shape):
    rng = np.random.default_rng(0)
    v = (rng.standard_normal(shape)
         * 10.0 ** rng.integers(-4, 4, shape)).astype(np.float32)
    comp_np, scale_np, _ = wire.np_blockscale_compress(v, block=128)
    comp_j, scale_j, _ = C.blockscale_compress(v, block=128)
    np.testing.assert_array_equal(comp_np, np.asarray(comp_j))
    np.testing.assert_array_equal(scale_np, np.asarray(scale_j).reshape(-1))
    # and the decompressed values match the jnp roundtrip exactly
    out_np = wire.np_blockscale_decompress(comp_np, scale_np, shape)
    np.testing.assert_array_equal(out_np, np.asarray(
        C.blockscale_roundtrip(v, block=128)))


def test_lossy_pack_roundtrip_and_sizes():
    v = np.random.default_rng(1).standard_normal((40, 8)).astype(np.float32)
    p = wire.lossy_pack(v, block=128)
    out = wire.lossy_unpack(p)
    assert out.shape == v.shape
    np.testing.assert_allclose(out, v, rtol=2e-3, atol=1e-6)
    # fp16 payload + fp32 per-block scales: roughly half the raw bytes
    assert wire.payload_nbytes(p) < v.nbytes
    # raw arrays pass through unpack untouched
    np.testing.assert_array_equal(wire.lossy_unpack(v), v)
    assert wire.payload_nbytes(v) == v.nbytes


# ---------------------------------------------------------------------------
# RPC semantics
# ---------------------------------------------------------------------------

def _echo_server(extra=None):
    calls = {"n": 0}

    def bump(**kw):
        calls["n"] += 1
        return {"n": calls["n"], **kw}

    handlers = {"ping": lambda: {"pong": True},
                "echo": lambda **kw: kw,
                "bump": bump,
                "boom": lambda: (_ for _ in ()).throw(
                    ValueError("handler exploded"))}
    if extra:
        handlers.update(extra)
    srv = RpcServer(handlers, mutating_ops={"bump"}).start()
    return srv, calls


def test_rpc_call_and_remote_error():
    srv, _ = _echo_server()
    try:
        c = RpcClient("127.0.0.1", srv.port, timeout=5.0, retries=0)
        out = c.call("echo", x=np.arange(5, dtype=np.int32), s="hi")
        np.testing.assert_array_equal(out["x"], np.arange(5, dtype=np.int32))
        assert out["s"] == "hi"
        assert c.ping()
        # handler exceptions come back typed, the server stays up
        with pytest.raises(RpcError, match="ValueError: handler exploded"):
            c.call("boom")
        with pytest.raises(RpcError, match="unknown rpc op"):
            c.call("nope")
        assert c.call("echo", ok=1)["ok"] == 1       # still serving
        assert c.bytes_sent > 0 and c.bytes_recv > 0
        c.close()
    finally:
        srv.stop()


def test_rpc_unavailable_after_retries(free_port):
    c = RpcClient("127.0.0.1", free_port(), timeout=0.5, retries=1,
                  backoff=0.01)
    with pytest.raises(PSUnavailableError, match="after 2 attempts"):
        c.call("ping")
    assert c.ping() is False


def test_rpc_reconnects_after_server_restart():
    srv, _ = _echo_server()
    port = srv.port
    c = RpcClient("127.0.0.1", port, timeout=5.0, retries=4, backoff=0.05)
    assert c.call("echo", a=1)["a"] == 1
    srv.stop()
    # same port comes back (retrying the bind out of TIME_WAIT, as a
    # restarted PS would): the client's retry loop must reconnect
    # transparently
    deadline = time.time() + 10.0
    while True:
        try:
            srv2 = RpcServer({"echo": lambda **kw: kw}, port=port).start()
            break
        except OSError:
            if time.time() > deadline:
                raise
            time.sleep(0.05)
    try:
        assert c.call("echo", a=2)["a"] == 2
    finally:
        c.close()
        srv2.stop()


def test_rpc_replay_suppression_applies_mutations_once():
    srv, calls = _echo_server()
    try:
        c = RpcClient("127.0.0.1", srv.port, timeout=5.0, retries=0)
        r1 = c.call("bump", _mutating=True, tag="a")
        assert (r1["n"], calls["n"]) == (1, 1)
        # replay the exact same (client, seq) — as a retry after a lost
        # reply would: the cached ack comes back, the handler does NOT run
        reply = wire.decode(b"".join(srv._dispatch(
            {"op": "bump", "args": {"tag": "a"},
             "seq": 1, "client": c._client_id})))
        assert reply["ok"]["n"] == 1
        assert calls["n"] == 1                        # not re-applied
        # a NEW seq applies normally
        assert c.call("bump", _mutating=True)["n"] == 2
        assert calls["n"] == 2
        c.close()
    finally:
        srv.stop()


def test_rpc_replay_window_covers_all_inflight_seqs():
    # a pipelined client may retry ANY of its in-flight seqs after a lost
    # reply, not just the latest — the server's replay cache must hold a
    # window of recent seqs per client
    srv, calls = _echo_server()
    try:
        c = RpcClient("127.0.0.1", srv.port, timeout=5.0, retries=0)
        futs = [c.call_async("bump", _mutating=True) for _ in range(5)]
        assert [c.result(f)["n"] for f in futs] == [1, 2, 3, 4, 5]
        assert calls["n"] == 5
        for seq in (1, 3, 5):                        # old AND new seqs
            reply = wire.decode(b"".join(srv._dispatch(
                {"op": "bump", "args": {}, "seq": seq,
                 "client": c._client_id})))
            assert reply["ok"]["n"] == seq           # the cached reply
        assert calls["n"] == 5                       # nothing re-applied
        c.close()
    finally:
        srv.stop()


def test_rpc_retried_mutation_after_dropped_reply_not_double_applied():
    # end-to-end: the handler applies, then the connection dies before the
    # reply ships (a killed/partitioned link). The client reconnects and
    # resends the same seq; the server must answer from the replay cache.
    holder = {}

    def bump_cut(**kw):
        holder["calls"]["n"] += 1
        if holder["calls"]["n"] == 1:
            for conn in list(holder["srv"]._conns):  # sever BEFORE reply
                try:
                    conn.shutdown(socket.SHUT_RDWR)
                except OSError:
                    pass
        return {"n": holder["calls"]["n"]}

    srv, calls = _echo_server({"bump_cut": bump_cut})
    holder["srv"], holder["calls"] = srv, calls
    try:
        c = RpcClient("127.0.0.1", srv.port, timeout=5.0, retries=3,
                      backoff=0.02)
        out = c.call("bump_cut", _mutating=True)
        assert out["n"] == 1                         # the FIRST apply's ack
        assert calls["n"] == 1                       # not double-applied
        assert c.call("echo", ok=1)["ok"] == 1       # connection recovered
        c.close()
    finally:
        srv.stop()


def test_rpc_pipelined_out_of_order_completion():
    ev = threading.Event()

    def slow():
        ev.wait(5.0)
        return {"slow": True}

    srv = RpcServer({"slow": slow, "echo": lambda **kw: kw,
                     "ping": lambda: {}},
                    concurrent_ops={"slow", "ping"}).start()
    try:
        c = RpcClient("127.0.0.1", srv.port, timeout=10.0, retries=0)
        f_slow = c.call_async("slow")
        futs = [c.call_async("echo", i=i) for i in range(8)]
        # the fast requests complete while the slow one is still running
        assert [c.result(f)["i"] for f in futs] == list(range(8))
        assert not f_slow.done()
        ev.set()
        assert c.result(f_slow)["slow"] is True
        c.close()
    finally:
        ev.set()
        srv.stop()


def test_rpc_coalesced_ops_ride_one_frame():
    srv, calls = _echo_server()
    try:
        c = RpcClient("127.0.0.1", srv.port, timeout=5.0, retries=0)
        c.call("echo", warm=1)                       # connection up
        before = c.frames_sent
        f1 = c.coalesce("echo", table="a", x=1)
        f2 = c.coalesce("bump", _mutating=True, table="b")
        f3 = c.coalesce("boom")
        c.flush()
        assert c.result(f1)["x"] == 1
        assert c.result(f2)["n"] == 1
        with pytest.raises(RpcError, match="handler exploded"):
            c.result(f3)                 # sub-op error isolated to its slot
        assert calls["n"] == 1
        assert c.frames_sent == before + 1           # ONE frame for all 3
        # a direct call flushes buffered sub-ops first (order preserved)
        f4 = c.coalesce("bump", _mutating=True, table="b")
        out = c.call("bump", _mutating=True)
        assert c.result(f4)["n"] == 2 and out["n"] == 3
        c.close()
    finally:
        srv.stop()


def test_rpc_sockets_set_nodelay():
    srv, _ = _echo_server()
    try:
        c = RpcClient("127.0.0.1", srv.port, timeout=5.0, retries=0)
        assert c.call("echo", a=1)["a"] == 1
        assert c._sock.getsockopt(socket.IPPROTO_TCP,
                                  socket.TCP_NODELAY) != 0
        server_conns = list(srv._conns)
        assert server_conns, "server should hold the live connection"
        for conn in server_conns:
            assert conn.getsockopt(socket.IPPROTO_TCP,
                                   socket.TCP_NODELAY) != 0
        c.close()
    finally:
        srv.stop()


def test_rpc_server_stop_joins_handler_threads():
    srv, _ = _echo_server()
    clients = [RpcClient("127.0.0.1", srv.port, timeout=5.0, retries=0)
               for _ in range(3)]
    for c in clients:
        assert c.call("echo", a=1)["a"] == 1
    threads = list(srv._threads) + [srv._accept_thread]
    assert any(t.is_alive() for t in threads)
    srv.stop()
    for t in threads:
        assert not t.is_alive(), f"{t.name} leaked past stop()"
    for c in clients:
        c.close()
    # the port is actually free again: rebind immediately
    srv2 = RpcServer({"echo": lambda **kw: kw}, port=srv.port).start()
    srv2.stop()


def test_rpc_concurrent_clients():
    srv, calls = _echo_server()
    errs = []

    def worker(i):
        try:
            c = RpcClient("127.0.0.1", srv.port, timeout=10.0, retries=0)
            for j in range(20):
                out = c.call("echo", i=i, j=j)
                assert (out["i"], out["j"]) == (i, j)
            c.close()
        except Exception as e:                        # noqa: BLE001
            errs.append(e)

    try:
        ts = [threading.Thread(target=worker, args=(i,)) for i in range(4)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert not errs
    finally:
        srv.stop()
