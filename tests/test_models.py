"""Model-zoo behaviour: prefill/decode consistency per family, SSD vs naive
recurrence, flash attention vs naive oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import BlockCfg, ModelConfig
from repro.core import embedding_ps as PS
from repro.models import mamba2 as M2
from repro.models import transformer as T
from repro.models.flash import flash_attention
from repro.models.layers import _attn_naive


def _consistency(cfg, S=12, extra=3, atol=3e-5):
    key = jax.random.PRNGKey(0)
    dense = T.init_dense(cfg, key)
    spec = PS.EmbeddingSpec(rows=cfg.vocab_size, dim=cfg.d_model)
    emb = PS.ps_init(key, spec)
    tokens = jax.random.randint(key, (2, S + extra), 0, cfg.vocab_size)
    acts = PS.lookup(emb, spec, tokens)
    mem = None
    if cfg.is_encdec:
        mem = jax.random.normal(key, (2, cfg.encoder.n_memory_tokens,
                                      cfg.encoder.d_memory)) * 0.1
    elif cfg.n_memory_tokens:
        mem = jax.random.normal(key, (2, cfg.n_memory_tokens,
                                      cfg.d_memory)) * 0.1
    pos = jnp.arange(S + extra)[None].repeat(2, 0)
    memory = T.encode(cfg, dense, mem) if cfg.is_encdec else mem
    h, _ = T.forward(cfg, dense, acts, pos, memory)
    full = (h @ dense["lm_head"]).astype(jnp.float32)
    logits, caches = T.prefill(cfg, dense, acts[:, :S], memory=mem,
                               max_len=S + extra)
    diffs = [float(jnp.max(jnp.abs(logits[:, 0] - full[:, S - 1])))]
    for i in range(extra):
        logits, caches = T.decode_step(cfg, dense, acts[:, S + i: S + i + 1],
                                       caches)
        diffs.append(float(jnp.max(jnp.abs(logits[:, 0, : cfg.vocab_size]
                                           - full[:, S + i, : cfg.vocab_size]))))
    assert max(diffs) < atol, diffs


GQA = ModelConfig(name="gqa", d_model=64, n_heads=4, n_kv_heads=2,
                  head_dim=16, d_ff=128, vocab_size=128, qk_norm=True,
                  pattern=(BlockCfg("gqa", "dense"),), pattern_repeats=2)
MLA = ModelConfig(name="mla", d_model=64, n_heads=4, head_dim=16,
                  rope_head_dim=8, v_head_dim=16, kv_lora_rank=32,
                  q_lora_rank=24, d_ff=128, vocab_size=128,
                  pattern=(BlockCfg("mla", "moe"),), pattern_repeats=2,
                  n_experts=4, moe_top_k=2, moe_d_ff=64, n_shared_experts=1,
                  capacity_factor=8.0, prologue=(BlockCfg("mla", "dense"),))
SSM = ModelConfig(name="ssm", d_model=64, n_heads=0, n_kv_heads=0,
                  head_dim=16, d_ff=0, vocab_size=128, ssm_state=16,
                  ssm_head_dim=16, ssm_chunk=4,
                  pattern=(BlockCfg("mamba2", "none"),), pattern_repeats=2)
HYBRID = ModelConfig(name="hyb", d_model=64, n_heads=4, n_kv_heads=2,
                     head_dim=16, d_ff=128, vocab_size=128, ssm_state=16,
                     ssm_head_dim=16, ssm_chunk=4, n_experts=4, moe_top_k=2,
                     moe_d_ff=64, capacity_factor=8.0,
                     pattern=(BlockCfg("mamba2", "dense"),
                              BlockCfg("gqa", "moe")), pattern_repeats=2)
VLM = ModelConfig(name="vlm", d_model=64, n_heads=4, n_kv_heads=2,
                  head_dim=16, d_ff=128, vocab_size=128, n_memory_tokens=8,
                  pattern=(BlockCfg("gqa", "dense"),
                           BlockCfg("cross_attn", "dense")),
                  pattern_repeats=2)
_ENC = ModelConfig(name="enc", d_model=48, n_heads=4, n_kv_heads=4,
                   head_dim=12, d_ff=96, ffn_act="gelu", norm="layernorm",
                   n_memory_tokens=10, d_memory=16,
                   pattern=(BlockCfg("gqa", "dense"),), pattern_repeats=2)
ENCDEC = ModelConfig(name="whisper", d_model=48, n_heads=4, n_kv_heads=4,
                     head_dim=12, d_ff=96, ffn_act="gelu", norm="layernorm",
                     vocab_size=128, encoder=_ENC,
                     pattern=(BlockCfg("gqa", "dense", cross=True),),
                     pattern_repeats=2)
SLIDING = GQA.replace(sliding_window=6, qk_norm=False, name="sliding")


@pytest.mark.parametrize("cfg", [GQA, MLA, SSM, HYBRID, VLM, ENCDEC, SLIDING],
                         ids=lambda c: c.name)
def test_prefill_decode_consistency(cfg):
    _consistency(cfg)


def test_sliding_window_ring_long():
    """Decode far beyond the window with a ring cache == full forward."""
    cfg = SLIDING.replace(pattern_repeats=1)
    _consistency(cfg, S=16, extra=8)


# ---------------------------------------------------------------------------
# SSD chunked == naive recurrence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S", [7, 32, 61])
def test_ssd_matches_recurrence(S):
    cfg = SSM
    key = jax.random.PRNGKey(S)
    p = M2.mamba2_init(key, cfg)
    x = jax.random.normal(key, (2, S, cfg.d_model)) * 0.5
    y1 = M2.mamba2_forward(p, cfg, x)
    y2 = M2.mamba2_reference_scan(p, cfg, x)
    np.testing.assert_allclose(y1, y2, atol=2e-5)


def test_ssd_state_handoff():
    cfg = SSM
    key = jax.random.PRNGKey(9)
    p = M2.mamba2_init(key, cfg)
    x = jax.random.normal(key, (2, 13, cfg.d_model)) * 0.5
    _, st = M2.mamba2_forward(p, cfg, x, return_state=True)
    xn = jax.random.normal(jax.random.PRNGKey(1), (2, 1, cfg.d_model)) * 0.5
    yd, _ = M2.mamba2_decode(p, cfg, xn, st)
    yfull = M2.mamba2_reference_scan(p, cfg, jnp.concatenate([x, xn], 1))
    np.testing.assert_allclose(yd[:, 0], yfull[:, -1], atol=2e-5)


# ---------------------------------------------------------------------------
# flash attention vs naive
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("causal,window,triangle",
                         [(True, 0, False), (True, 9, False),
                          (False, 0, False), (True, 0, True)])
def test_flash_matches_naive(causal, window, triangle, monkeypatch):
    import repro.models.flash as F
    monkeypatch.setattr(F, "TRIANGLE", triangle)
    F._make_flash.cache_clear()
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, 37, 2, 3, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 37, 2, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 37, 2, 16))

    def f(q, k, v):
        return flash_attention(q, k, v, scale=0.25, causal=causal,
                               window=window, qblk=16, kblk=16)

    def n(q, k, v):
        return _attn_naive(q, k, v, scale=0.25, causal=causal, window=window,
                           q_offset=0)

    np.testing.assert_allclose(f(q, k, v), n(q, k, v), atol=2e-6)
    gf = jax.grad(lambda *a: jnp.sum(jnp.sin(f(*a))), argnums=(0, 1, 2))(q, k, v)
    gn = jax.grad(lambda *a: jnp.sum(jnp.sin(n(*a))), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gf, gn):
        np.testing.assert_allclose(a, b, atol=2e-5)


def test_training_step_decreases_loss_tiny_lm():
    """A tiny LM learns the synthetic Markov data (loss drops)."""
    from repro.core import adapters, hybrid
    from repro.core.hybrid import TrainMode
    from repro.data.lm import lm_batches
    from repro.optim.optimizers import OptConfig, make_optimizer

    cfg = GQA.replace(vocab_size=64)
    adapter = adapters.lm_adapter(cfg, lr=0.2)
    opt_init, opt_update = make_optimizer(OptConfig(kind="adam", lr=3e-3))
    it = lm_batches(64, 8, 32, seed=0)
    batch = {k: jnp.asarray(v) for k, v in next(it).items()}
    state, spec = hybrid.init_train_state(adapter, TrainMode.hybrid(2),
                                          opt_init, jax.random.PRNGKey(0),
                                          batch)
    step = jax.jit(hybrid.make_train_step(adapter, spec, TrainMode.hybrid(2),
                                          opt_update))
    losses = []
    for _ in range(30):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses
