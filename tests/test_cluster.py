"""The multi-process launcher (repro/launch/cluster.py): a real
trainer + k PS subprocess run over the RPC wire, and the kill-a-shard
drill — SIGKILL one shard mid-run, reshard its spooled rows onto the
survivors, keep training."""
import os

import numpy as np
import pytest

from repro.launch.cluster import run_cluster
from repro.launch.shards import parse_emb_shards, shards_for_table


def test_emb_shards_grammar_is_shared_across_launchers():
    assert parse_emb_shards(4) == 4
    assert parse_emb_shards("4") == 4
    assert parse_emb_shards(None) == 1
    assert parse_emb_shards(" field_00=4, field_02=2") == \
        {"field_00": 4, "field_02": 2}
    with pytest.raises(ValueError, match="expected 'table=k'"):
        parse_emb_shards("field_00=")
    with pytest.raises(ValueError):
        parse_emb_shards("nope")
    assert shards_for_table(4, "vocab") == 4
    assert shards_for_table({"vocab": 2}, "vocab") == 2
    assert shards_for_table({"other": 2}, "vocab") == 1


@pytest.mark.timeout(240)
def test_cluster_smoke_two_ps(tmp_path):
    res = run_cluster(steps=5, n_ps=2, workdir=str(tmp_path))
    assert res["steps"] == 5
    assert res["members"] == 2
    assert np.isfinite(res["loss"])
    assert res["steps_per_s"] > 0
    # a clean run never reshards
    assert not [e for e in res["events"] if e["kind"] == "reshard"]
    # every shard published its port and spooled applied state
    for i in range(2):
        assert os.path.isdir(tmp_path / f"ps{i}.spool")


@pytest.mark.timeout(240)
def test_cluster_kill_a_shard_reshards_onto_survivors(tmp_path):
    res = run_cluster(steps=10, n_ps=3, kill_shard=1, kill_at=4,
                      workdir=str(tmp_path))
    assert res["members"] == 2
    resh = [e for e in res["events"] if e["kind"] == "reshard"]
    assert resh and resh[0]["dead"] == [1]
    assert resh[0]["k"] == 2
    # applied puts were spooled before their ack: the kill loses at most
    # in-flight work, never applied rows
    assert res["lost_rows"] and all(v == 0
                                    for v in res["lost_rows"].values())
    assert np.isfinite(res["loss"])
