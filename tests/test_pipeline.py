"""PipelinedTrainer (core/pipeline.py): max_inflight=1 determinism vs the
serial decomposed step for every mode x backend, the bounded-staleness
backpressure invariant under seeded random stage delays, ordered/lossless
put application, stage-failure propagation, per-stage metrics, and the
HostLRUBackend.prepare thread-safety regression."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import adapters
from repro.core.backend import create_backend
from repro.core.embedding_ps import EmbeddingSpec
from repro.core.hybrid import PersiaTrainer, TrainMode
from repro.core.pipeline import (PipelinedTrainer, PipelineStageError,
                                 STAGES)
from repro.data.ctr import CTRDataset
from repro.optim.optimizers import OptConfig

F, RPF, D = 3, 128, 8      # fields x rows-per-field x dim

CFG = ModelConfig(name="pl", arch_type="recsys", n_id_fields=F,
                  ids_per_field=3, emb_dim=D, emb_rows=F * RPF,
                  n_dense_features=4, mlp_dims=(16,), n_tasks=1)
DS = CTRDataset("pl", n_rows=F * RPF, n_fields=F, ids_per_field=3, n_dense=4)


def _batches(n, batch=32, seed=0):
    it = DS.sampler(batch, seed=seed)
    return [{k: jnp.asarray(v) for k, v in next(it).items()}
            for _ in range(n)]


def _trainer(backend="dense", cache_rows=None, mode=None):
    coll = adapters.ctr_collection(CFG, lr=5e-2, field_rows=DS.field_rows())
    if backend != "dense":
        coll = coll.with_backend(backend, cache_rows)
    ad = adapters.recsys_adapter(CFG, field_rows=DS.field_rows(),
                                 collection=coll)
    return PersiaTrainer(ad, mode or TrainMode.hybrid(3),
                         OptConfig(kind="adam", lr=5e-3))


def _assert_states_equal(sa, sb, exact=True):
    cmp = (np.testing.assert_array_equal if exact
           else lambda a, b: np.testing.assert_allclose(a, b, atol=1e-5))
    for n in sa.emb:
        cmp(np.asarray(sa.emb[n]["table"]), np.asarray(sb.emb[n]["table"]))
        if "acc" in sa.emb[n]:
            cmp(np.asarray(sa.emb[n]["acc"]), np.asarray(sb.emb[n]["acc"]))
    for a, b in zip(jax.tree.leaves(sa.dense), jax.tree.leaves(sb.dense)):
        cmp(np.asarray(a), np.asarray(b))
    assert int(sa.step) == int(sb.step)


# ---------------------------------------------------------------------------
# determinism: max_inflight=1 == serial decomposed_step, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.timeout(240)
@pytest.mark.parametrize("backend,cache", [("dense", None),
                                           ("host_lru", RPF)],
                         ids=["dense", "host_lru"])
@pytest.mark.parametrize("mode", [TrainMode.sync(), TrainMode.hybrid(3),
                                  TrainMode.async_(3, 3)],
                         ids=["sync", "hybrid", "async"])
def test_inflight1_bit_exact_with_serial(backend, cache, mode):
    """The determinism contract: one permit pins the exact serial dispatch
    order, so 25 pipelined steps equal 25 decomposed_step calls bit for
    bit — dense params, every table, adagrad accs, losses."""
    batches = _batches(25)
    ta = _trainer(backend, cache, mode)
    sa = ta.init(jax.random.PRNGKey(0), batches[0])
    sa, ms_a = ta.run(sa, batches)
    tb = _trainer(backend, cache, mode)
    engine = PipelinedTrainer(tb, max_inflight=1)
    sb, ms_b = engine.run(tb.init(jax.random.PRNGKey(0), batches[0]),
                          batches)
    assert len(ms_a) == len(ms_b) == 25
    assert [float(m["loss"]) for m in ms_a] == \
        [float(m["loss"]) for m in ms_b]
    _assert_states_equal(sa, sb)


@pytest.mark.timeout(240)
def test_deep_pipeline_trains_and_preserves_order():
    """max_inflight > 1: results arrive complete and in batch order, puts
    apply FIFO per table, and the run still learns (loss finite)."""
    batches = _batches(20)
    tr = _trainer("host_lru", RPF)
    engine = PipelinedTrainer(tr, max_inflight=4)
    state = engine.init(jax.random.PRNGKey(0), batches[0])
    state, ms = engine.run(state, batches)
    assert len(ms) == 20
    assert engine.applied_order == list(range(20))     # no drop, no reorder
    assert all(np.isfinite(float(m["loss"])) for m in ms)
    assert int(state.step) == 20
    # the engine is reusable: a second run continues from the final state
    state, ms2 = engine.run(state, _batches(5, seed=7))
    assert len(ms2) == 5 and int(state.step) == 25


# ---------------------------------------------------------------------------
# stress: random stage delays, staleness invariant, failure propagation
# ---------------------------------------------------------------------------

@pytest.mark.timeout(240)
@pytest.mark.parametrize("seed", [0, 1])
def test_stress_random_delays_hold_invariants(seed):
    """Seeded random per-stage sleeps skew every stage's relative speed;
    the bounded-staleness invariant (outstanding puts <= min(max_inflight,
    tau) per table), order preservation and loss parity with a clean run
    must all survive the skew."""
    rng = np.random.default_rng(seed)
    delays = {(s, i): float(rng.uniform(0, 0.004))
              for s in STAGES for i in range(16)}

    def delay_fn(stage, idx):
        return delays.get((stage, idx), 0.0)

    batches = _batches(16)
    tau, inflight = 2, 3
    tr = _trainer("host_lru", RPF, TrainMode.hybrid(tau))
    engine = PipelinedTrainer(tr, max_inflight=inflight, delay_fn=delay_fn)
    state = engine.run(engine.init(jax.random.PRNGKey(0), batches[0]),
                       batches)[0]
    assert engine.applied_order == list(range(16))
    for n, peak in engine.max_outstanding.items():
        assert 1 <= peak <= min(inflight, tau), (n, peak)
    assert int(state.step) == 16
    # delays change timing only, never results: an undelayed pipelined run
    # with the same window reaches the identical staleness interleavings?
    # no — interleavings may differ with inflight>1; what must match is the
    # serial reference when the window is 1:
    tr1 = _trainer("host_lru", RPF, TrainMode.hybrid(tau))
    e1 = PipelinedTrainer(tr1, max_inflight=1, delay_fn=delay_fn)
    s1 = e1.run(e1.init(jax.random.PRNGKey(0), batches[0]), batches)[0]
    tr2 = _trainer("host_lru", RPF, TrainMode.hybrid(tau))
    s2, _ = tr2.run(tr2.init(jax.random.PRNGKey(0), batches[0]), batches)
    _assert_states_equal(s1, s2)


@pytest.mark.timeout(120)
@pytest.mark.parametrize("stage", ["loader", "prepare", "lookup", "dense",
                                   "put"])
def test_stage_exception_surfaces_without_hanging(stage):
    """A failure in ANY stage must abort the whole pipeline and re-raise
    from run() promptly (stop-event-aware queue waits), naming the stage."""
    batches = _batches(12)

    def delay_fn(s, idx):
        if s == stage and idx == 4:
            raise RuntimeError(f"injected-{stage}")
        return 0.0

    tr = _trainer("dense")
    engine = PipelinedTrainer(tr, max_inflight=3, delay_fn=delay_fn)
    state = engine.init(jax.random.PRNGKey(0), batches[0])
    t0 = time.monotonic()
    with pytest.raises(PipelineStageError, match=stage) as ei:
        engine.run(state, batches)
    assert time.monotonic() - t0 < 60
    assert ei.value.stage == stage and ei.value.step == 4
    assert isinstance(ei.value.original, RuntimeError)


@pytest.mark.timeout(120)
def test_sync_tables_never_read_past_unapplied_put():
    """tau=0 forces the put window to 1 even with a deep pipeline: sync
    semantics admit no pipeline-induced staleness, so inflight=4 sync must
    stay bit-exact with the serial sync run."""
    batches = _batches(12)
    ta = _trainer("dense", mode=TrainMode.sync())
    sa, _ = ta.run(ta.init(jax.random.PRNGKey(0), batches[0]), batches)
    tb = _trainer("dense", mode=TrainMode.sync())
    engine = PipelinedTrainer(tb, max_inflight=4)
    assert all(engine.put_window(n) == 1 for n in tb.collection.names)
    sb, _ = engine.run(engine.init(jax.random.PRNGKey(0), batches[0]),
                       batches)
    for n in engine.max_outstanding:
        assert engine.max_outstanding[n] == 1
    _assert_states_equal(sa, sb)


# ---------------------------------------------------------------------------
# metrics and guardrails
# ---------------------------------------------------------------------------

@pytest.mark.timeout(120)
def test_pipeline_metrics_schema_and_occupancy():
    batches = _batches(8)
    tr = _trainer("host_lru", RPF)
    engine = PipelinedTrainer(
        tr, max_inflight=3,
        delay_fn=lambda s, i: 0.003 if s == "prepare" else 0.0)
    engine.run(engine.init(jax.random.PRNGKey(0), batches[0]), batches)
    pm = engine.pipeline_metrics()
    for stage in STAGES:
        assert pm[f"pipeline/{stage}/busy_s"] >= 0.0
        assert 0.0 <= pm[f"pipeline/{stage}/occupancy"] <= 1.0 + 1e-6
        assert pm[f"pipeline/{stage}/items"] == 8.0
    for stage in ("prepare", "lookup", "dense", "put"):
        assert pm[f"pipeline/{stage}/queue_depth_max"] <= 3.0
    assert pm["pipeline/prepare/busy_s"] >= 8 * 0.003
    assert pm["pipeline/steps"] == 8.0 and pm["pipeline/steps_per_s"] > 0
    for n in tr.collection.names:
        assert pm[f"pipeline/outstanding_puts_max/{n}"] >= 1.0


def test_engine_rejects_bad_construction():
    with pytest.raises(TypeError, match="PersiaTrainer"):
        PipelinedTrainer(object())
    tr = _trainer()
    with pytest.raises(ValueError, match="max_inflight"):
        PipelinedTrainer(tr, max_inflight=0)


@pytest.mark.timeout(120)
def test_run_steps_cap_and_delegated_surface(tmp_path):
    batches = _batches(10)
    tr = _trainer("dense", mode=TrainMode.hybrid(2))
    engine = PipelinedTrainer(tr, max_inflight=2)
    state = engine.init(jax.random.PRNGKey(0), batches[0])
    state, ms = engine.run(state, batches, steps=6)
    assert len(ms) == 6 and int(state.step) == 6
    # the delegated serial surface keeps working on the pipelined state
    m = engine.eval(state, batches[0])
    assert np.isfinite(float(m["loss"]))
    engine.save(str(tmp_path), state)
    restored = engine.restore(str(tmp_path))
    assert int(restored.step) == 6
    state2, _ = engine.run(restored, batches[6:])
    assert int(state2.step) == 10


# ---------------------------------------------------------------------------
# slot pinning: deep pipelines must never fault-recycle in-flight rows
# ---------------------------------------------------------------------------

def test_host_lru_pinned_slots_survive_fault_in():
    """While a batch is in flight (pinned), a later fault-in must evict
    around its slots — or raise when it can't — never recycle them."""
    spec = EmbeddingSpec(rows=64, dim=4, mode="full", optimizer="sgd",
                         backend="host_lru", cache_rows=8)
    bk = create_backend(spec)
    state = bk.init(jax.random.PRNGKey(0))
    state, dev0 = bk.prepare(state, np.arange(0, 6))        # batch 0: 6 slots
    bk.pin_slots(dev0)
    # 2 unpinned slots remain; a 2-id disjoint batch fits around the pins
    state, dev1 = bk.prepare(state, np.array([10, 11]))
    assert not set(np.asarray(dev1).tolist()) & \
        set(np.asarray(dev0).tolist())
    for i in range(6):                          # batch 0 still resident
        assert bk._slot_for_id[i] == int(np.asarray(dev0)[i])
    # ... but a batch needing more than the unpinned residue must raise,
    # not silently recycle pinned rows (batch 1's slots are unpinned, so 2
    # are evictable; 3 disjoint ids need one pinned victim -> refused)
    with pytest.raises(ValueError, match="pinned"):
        bk.prepare(state, np.array([20, 21, 22]))
    bk.unpin_slots(dev0)
    state, _ = bk.prepare(state, np.array([20, 21, 22]))    # now fine
    assert bk._pin_count.sum() == 0


@pytest.mark.timeout(240)
def test_deep_pipeline_pins_inflight_rows_host_lru():
    """A deep pipeline with a slow put stage keeps several batches in
    flight; with a cache sized near one batch's working set the engine
    must either run correctly (pins make later fault-ins evict around
    in-flight rows) or fail loudly — and with a roomy cache the run must
    stay consistent with sequential application of every batch."""
    batches = _batches(10, batch=8)
    tr = _trainer("host_lru", RPF, TrainMode.hybrid(2))
    engine = PipelinedTrainer(
        tr, max_inflight=3,
        delay_fn=lambda s, i: 0.02 if s == "put" else 0.0)
    state, ms = engine.run(engine.init(jax.random.PRNGKey(0), batches[0]),
                           batches)
    assert len(ms) == 10
    assert engine.applied_order == list(range(10))
    for n in tr.collection.names:                  # every pin released
        assert tr.backends[n]._pin_count.sum() == 0, n


# ---------------------------------------------------------------------------
# HostLRUBackend.prepare thread-safety regression (satellite fix)
# ---------------------------------------------------------------------------

@pytest.mark.timeout(120)
def test_host_lru_prepare_is_thread_safe():
    """Two threads hammering prepare on one backend: the slot bookkeeping
    must stay an exact bijection and never raise. Before the RLock fix the
    interleaved dict/array mutation corrupts the slot map (two ids on one
    slot) or dies with 'dictionary changed size during iteration'."""
    spec = EmbeddingSpec(rows=512, dim=4, mode="full", optimizer="sgd",
                         backend="host_lru", cache_rows=96)
    bk = create_backend(spec)
    state0 = bk.init(jax.random.PRNGKey(0))
    errors = []
    go = threading.Event()

    def hammer(seed):
        rng = np.random.default_rng(seed)
        go.wait()
        try:
            for _ in range(60):
                ids = rng.integers(0, spec.rows, 24)
                _, dev = bk.prepare(state0, ids)
                dev = np.asarray(dev)
                assert ((dev >= 0) & (dev < spec.cache_rows)).all()
        except Exception as e:   # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=hammer, args=(s,)) for s in (1, 2)]
    for t in threads:
        t.start()
    go.set()
    for t in threads:
        t.join(timeout=60)
    assert not errors, errors
    # bijection: id->slot and slot->id agree, no slot serves two ids
    assert len(set(bk._slot_for_id.values())) == len(bk._slot_for_id)
    for k, s in bk._slot_for_id.items():
        assert int(bk._id_for_slot[s]) == k
    occupied = {int(s) for s in np.nonzero(bk._id_for_slot >= 0)[0]}
    assert occupied == set(bk._slot_for_id.values())
