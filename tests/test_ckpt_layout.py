"""checkpoint_shard_layout: per-table PS shard counts read straight off
a saved checkpoint's embedding blob, without a trainer — plain tables,
shard-tagged tables, mixed checkpoints, and the named failure modes for
corrupt or truncated saves."""
import numpy as np
import pytest

from repro.checkpoint.ckpt import checkpoint_shard_layout, save_checkpoint


def _sub_blob(rows=4, dim=2):
    return {"table": np.zeros((rows, dim), np.float32),
            "acc": np.zeros((rows,), np.float32)}


def _sharded_blob(k, rows=8, dim=2):
    return {"shard_meta": np.asarray([k, rows, dim], np.int64),
            "shards": {f"s{s}": _sub_blob(rows // k, dim)
                       for s in range(k)}}


def _save(tmp_path, emb_tables, step=0):
    dense = {"w": np.zeros((3,), np.float32)}
    emb = None if emb_tables is None else {"emb": emb_tables}
    save_checkpoint(str(tmp_path), step, dense, emb)
    return str(tmp_path)


def test_layout_plain_tables(tmp_path):
    d = _save(tmp_path, {"a": _sub_blob(), "b": _sub_blob()})
    assert checkpoint_shard_layout(d) == {"a": 1, "b": 1}


def test_layout_mixed_plain_and_sharded(tmp_path):
    d = _save(tmp_path, {"plain": _sub_blob(),
                         "two": _sharded_blob(2),
                         "three": _sharded_blob(3, rows=9, dim=2)})
    assert checkpoint_shard_layout(d) == {"plain": 1, "two": 2, "three": 3}


def test_layout_no_embedding_blob_is_named(tmp_path):
    d = _save(tmp_path, None)
    with pytest.raises(ValueError, match="no per-table embedding"):
        checkpoint_shard_layout(d)


def test_layout_missing_shards_entry_is_corrupt(tmp_path):
    blob = _sharded_blob(2)
    del blob["shards"]
    d = _save(tmp_path, {"t": blob})
    with pytest.raises(ValueError, match="missing its 'shards'"):
        checkpoint_shard_layout(d)


def test_layout_missing_shard_meta_is_corrupt(tmp_path):
    blob = _sharded_blob(2)
    del blob["shard_meta"]
    d = _save(tmp_path, {"t": blob})
    with pytest.raises(ValueError, match="missing its 'shard_meta'"):
        checkpoint_shard_layout(d)


@pytest.mark.parametrize("meta", [
    np.asarray([2, 8], np.int64),             # wrong arity
    np.asarray([0, 8, 2], np.int64),          # n_shards < 1
    np.asarray([2.0, 8.0, 2.0], np.float32),  # non-integer dtype
])
def test_layout_corrupt_shard_meta(tmp_path, meta):
    blob = _sharded_blob(2)
    blob["shard_meta"] = meta
    d = _save(tmp_path, {"t": blob})
    with pytest.raises(ValueError, match="corrupt shard_meta"):
        checkpoint_shard_layout(d)


def test_layout_shard_count_mismatch(tmp_path):
    blob = _sharded_blob(3, rows=9)
    del blob["shards"]["s1"]                  # meta says 3, blob holds 2
    d = _save(tmp_path, {"t": blob})
    with pytest.raises(ValueError, match="declares 3 shards"):
        checkpoint_shard_layout(d)
