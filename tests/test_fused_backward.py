"""Fused embedding backward + store_dtype, backend/trainer level (ISSUE 9).

The one-pass ``_put_plan`` / ``_hybrid_plan`` fused path (the new default,
jnp oracle) must be BIT-exact vs the decomposed segment-sum-then-apply
dispatches it replaced, across optimizer x staleness x backend — same
sweep discipline as test_dedup.py. The Pallas kernel flag sits in the
documented ~1e-7 reduction-order class, hence allclose. store_dtype gets
trainer-level trajectory-closeness plus spec validation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import adapters
from repro.core import backend as BK
from repro.core import dedup as D
from repro.core.dedup import DedupPlan
from repro.core.embedding_ps import EmbeddingSpec
from repro.core.hybrid import PersiaTrainer, TrainMode
from repro.data.ctr import CTRDataset
from repro.optim.optimizers import OptConfig


def _tree_eq(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _plan(rng, rows, cap, shape=(4, 6)):
    ids = rng.integers(-1, rows, shape)
    u_pad, inv, counts, _ = D.make_plan(ids, rows, cap, floor=8)
    return DedupPlan(dev=jnp.asarray(u_pad, jnp.int32),
                     inv=jnp.asarray(inv, jnp.int32)), counts, u_pad


def _decomposed_put(b, state, plan, grads):
    g_u = D.plan_segment_sum(plan.inv, grads, int(plan.dev.shape[0]))
    return b._put_unique(state, plan.dev, g_u)


def _decomposed_hybrid(b, state, queue, plan, grads):
    g_u = D.plan_segment_sum(plan.inv, grads, int(plan.dev.shape[0]))
    return b._hybrid_unique(state, queue, plan.dev, g_u)


@pytest.mark.parametrize("opt,tau", [("adagrad", 0), ("adagrad", 3),
                                     ("sgd", 0), ("sgd", 3)])
def test_dense_fused_matches_decomposed(opt, tau):
    rng = np.random.default_rng(hash((opt, tau)) % 2**31)
    spec = EmbeddingSpec(rows=257, dim=16, optimizer=opt, lr=3e-2,
                         staleness=tau, backend="dense")
    b = BK.DenseBackend(spec)
    state = b.init(jax.random.PRNGKey(0))
    queue = b.queue_init((4, 6))
    q2 = None if queue is None else jax.tree.map(jnp.copy, queue)
    for step in range(5):
        cap = D.dedup_cap(24, spec.rows)
        plan, _, _ = _plan(rng, spec.rows, cap)
        grads = jnp.asarray(
            rng.standard_normal((4, 6, 16)).astype(np.float32))
        st1, q1, _ = b.hybrid_update(state, queue, plan, grads)
        st2, q2, _ = _decomposed_hybrid(b, state, q2, plan, grads)
        _tree_eq(st1, st2)
        _tree_eq(q1, q2)
        sp1, _ = b.apply_put(state, plan, grads)
        sp2, _ = _decomposed_put(b, state, plan, grads)
        _tree_eq(sp1, sp2)
        state, queue = st1, q1


@pytest.mark.parametrize("opt,tau", [("adagrad", 2), ("adagrad", 0),
                                     ("sgd", 2)])
def test_host_lru_fused_matches_decomposed(opt, tau):
    rng = np.random.default_rng(hash((opt, tau, 1)) % 2**31)
    spec = EmbeddingSpec(rows=300, dim=16, optimizer=opt, lr=3e-2,
                         staleness=tau, backend="host_lru", cache_rows=64)
    b, b2 = BK.HostLRUBackend(spec), BK.HostLRUBackend(spec)
    state, state2 = b.init(jax.random.PRNGKey(1)), b2.init(
        jax.random.PRNGKey(1))
    queue = b.queue_init((4, 6))
    q2 = None if queue is None else jax.tree.map(jnp.copy, queue)
    for step in range(5):
        cap = D.dedup_cap(24, b.dedup_rows())
        ids = rng.integers(-1, spec.rows, (4, 6))
        u_pad, inv, counts, _ = D.make_plan(ids, spec.rows, cap, floor=8)
        state, dev_u = b.prepare(state, u_pad, assume_unique=True,
                                 counts=counts)
        state2, dev_u2 = b2.prepare(state2, u_pad, assume_unique=True,
                                    counts=counts)
        np.testing.assert_array_equal(np.asarray(dev_u), np.asarray(dev_u2))
        plan = DedupPlan(dev=jnp.asarray(dev_u, jnp.int32),
                         inv=jnp.asarray(inv, jnp.int32))
        grads = jnp.asarray(
            rng.standard_normal((4, 6, 16)).astype(np.float32))
        st1, q1, _ = b.hybrid_update(state, queue, plan, grads)
        st2, q2, _ = _decomposed_hybrid(b2, state2, q2, plan, grads)
        _tree_eq(st1, st2)
        _tree_eq(q1, q2)
        state, queue, state2 = st1, q1, st2


def test_backward_kernel_flag_matches_oracle():
    """backward_kernel=True routes through the Pallas kernel — same
    trajectory as the oracle default to reduction-order tolerance."""
    rng = np.random.default_rng(7)
    mk = lambda kernel: EmbeddingSpec(rows=257, dim=16, lr=3e-2,
                                      staleness=3, backend="dense",
                                      backward_kernel=kernel)
    bk, bo = BK.DenseBackend(mk(True)), BK.DenseBackend(mk(False))
    state_k = bk.init(jax.random.PRNGKey(2))
    state_o = jax.tree.map(jnp.copy, state_k)
    qk = bk.queue_init((4, 6))
    qo = jax.tree.map(jnp.copy, qk)
    for step in range(4):
        cap = D.dedup_cap(24, 257)
        plan, _, _ = _plan(rng, 257, cap)
        grads = jnp.asarray(
            rng.standard_normal((4, 6, 16)).astype(np.float32))
        state_k, qk, _ = bk.hybrid_update(state_k, qk, plan, grads)
        state_o, qo, _ = bo.hybrid_update(state_o, qo, plan, grads)
    for x, y in zip(jax.tree.leaves((state_k, qk)),
                    jax.tree.leaves((state_o, qo))):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=2e-6, atol=2e-6)


# ---------------------------------------------------------------------------
# store_dtype at trainer level
# ---------------------------------------------------------------------------

def _trainer(store_dtype):
    ds = CTRDataset("fbw", n_rows=2 * 1024, n_fields=2, ids_per_field=2,
                    n_dense=13)
    cfg = ModelConfig(name="fbw", arch_type="recsys", n_id_fields=2,
                      ids_per_field=2, emb_dim=32, emb_rows=2 * 1024,
                      n_dense_features=13, mlp_dims=(32, 16), n_tasks=1)
    coll = adapters.ctr_collection(cfg, lr=5e-2, field_rows=ds.field_rows())
    coll = coll.with_backend("host_lru", 256)
    if store_dtype != "fp32":
        coll = coll.with_store_dtype(store_dtype)
    adapter = adapters.recsys_adapter(cfg, field_rows=ds.field_rows(),
                                      collection=coll)
    return ds, PersiaTrainer(adapter, TrainMode.hybrid(2),
                             OptConfig(kind="adam", lr=1e-3))


def test_trainer_store_dtype_trajectory_close():
    """blockscale16 cold rows move the hybrid training trajectory by at
    most the codec's quantisation noise — far under the 2e-3 bar the
    benchmarks pin."""
    losses = {}
    for sd in ("fp32", "blockscale16"):
        ds, tr = _trainer(sd)
        it = ds.sampler(32)
        bs = [{k: jnp.asarray(v) for k, v in next(it).items()}
              for _ in range(6)]
        st = tr.init(jax.random.PRNGKey(0), bs[0])
        out = []
        for bt in bs:
            st, m = tr.decomposed_step(st, bt)
            out.append(float(m["loss"]))
        losses[sd] = out
    delta = max(abs(a - b) for a, b in
                zip(losses["fp32"], losses["blockscale16"]))
    assert delta < 2e-3, delta


def test_trainer_store_dtype_payload_shrinks():
    _, tr32 = _trainer("fp32")
    _, tr16 = _trainer("blockscale16")
    b = {"ids": jnp.zeros((4, 2, 2), jnp.int32),
         "dense": jnp.zeros((4, 13)), "labels": jnp.zeros((4, 1))}
    tr32.init(jax.random.PRNGKey(0), b)
    tr16.init(jax.random.PRNGKey(0), b)
    p32 = sum(bk.store.payload_bytes() for bk in tr32.backends.values())
    p16 = sum(bk.store.payload_bytes() for bk in tr16.backends.values())
    assert p32 / p16 > 1.8                       # dim 32: 128 B vs 68 B/row


def test_dense_rejects_blockscale():
    """Dense tables are device-resident — there is no host store to
    compress; the spec must fail fast."""
    spec = EmbeddingSpec(rows=64, dim=8, backend="dense",
                         store_dtype="blockscale16")
    with pytest.raises(ValueError, match="store_dtype"):
        BK.DenseBackend(spec)


def test_bad_store_dtype_rejected():
    spec = EmbeddingSpec(rows=64, dim=8, backend="host_lru", cache_rows=16,
                         store_dtype="fp8")
    with pytest.raises(ValueError, match="store_dtype"):
        BK.HostLRUBackend(spec)


def test_hostenv_tuned_env_pure_and_idempotent():
    """tuned_env is a pure dict: merges caller XLA_FLAGS, never doubles
    the host-device pin, and carries the tcmalloc/TF silencers."""
    from repro.launch import hostenv
    env = hostenv.tuned_env(4, "--foo")
    assert env["XLA_FLAGS"] == \
        "--foo --xla_force_host_platform_device_count=4"
    again = hostenv.tuned_env(1, env["XLA_FLAGS"])
    assert again["XLA_FLAGS"] == env["XLA_FLAGS"]
    assert env["TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD"] == "60000000000"
    assert env["TF_CPP_MIN_LOG_LEVEL"] == "4"
    # find_tcmalloc never raises — None (graceful no-op) or a real path
    lib = hostenv.find_tcmalloc()
    assert lib is None or hostenv.os.path.exists(lib)
