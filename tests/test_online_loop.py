"""Online loop over the multi-process PS (repro/serving x repro/net):
serve-while-train against REMOTE embedding backends — a reader thread
hammering the atomic ``read_rows`` RPC during training sees bit-exactly
the serial trajectory, the staleness gauge holds its bound over the wire,
and the launch/online driver closes the loop end to end (in-process and
``--ps`` subprocess modes). Runs in the multiprocess CI job."""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import adapters
from repro.core.hybrid import PersiaTrainer, TrainMode
from repro.data.ctr import CTRDataset
from repro.net import connect_remote_backends
from repro.net.ps_server import PSServer
from repro.optim.optimizers import OptConfig
from repro.serving import (ServingConfig, ServingService, StateCell,
                           TrafficModel)

F, RPF, D = 2, 64, 8

CFG = ModelConfig(name="olp", arch_type="recsys", n_id_fields=F,
                  ids_per_field=3, emb_dim=D, emb_rows=F * RPF,
                  n_dense_features=4, mlp_dims=(16,), n_tasks=1)
DS = CTRDataset("olp", n_rows=F * RPF, n_fields=F, ids_per_field=3,
                n_dense=4)


def _trainer(backend="dense", mode=None, tau=2, cache_rows=40):
    coll = adapters.ctr_collection(CFG, lr=5e-2, field_rows=DS.field_rows())
    if backend != "dense":
        coll = coll.with_backend(backend, cache_rows)
    ad = adapters.recsys_adapter(CFG, field_rows=DS.field_rows(),
                                 collection=coll)
    return PersiaTrainer(ad, mode or TrainMode.sync(),
                         OptConfig(kind="adam", lr=5e-3))


def _batches(n, batch=16, seed=0):
    it = DS.sampler(batch, seed=seed)
    return [{k: jnp.asarray(v) for k, v in next(it).items()}
            for _ in range(n)]


@pytest.fixture
def servers():
    started = []

    def make(n):
        for _ in range(n):
            started.append(PSServer().start())
        return started[-n:]

    yield make
    for s in started:
        s.stop()


def _np_acts(acts):
    return {n: np.asarray(a) for n, a in acts.items()}


@pytest.mark.parametrize("backend,n_ps", [("dense", 1), ("dense", 2),
                                          ("host_lru", 2)])
def test_remote_serve_while_train_is_serial(servers, backend, n_ps):
    """Readers hammering the remote ``read_rows`` RPC during remote
    training observe, at every published step, bit-exactly the rows an
    uninterrupted IN-PROCESS run produces at that step (sync mode: the
    remote serve path must hold staleness 0 bit-exactly)."""
    steps = 4
    bs = _batches(steps + 1)
    probe = bs[0]

    ref_trainer = _trainer(backend)
    s = ref_trainer.init(jax.random.PRNGKey(0), bs[0])
    ref = {0: _np_acts(ref_trainer.serve_lookup(s, probe)[0])}
    for t in range(steps):
        s, _ = ref_trainer.decomposed_step(s, bs[t + 1])
        ref[t + 1] = _np_acts(ref_trainer.serve_lookup(s, probe)[0])

    trainer = _trainer(backend)
    connect_remote_backends(
        trainer, [("127.0.0.1", sv.port) for sv in servers(n_ps)])
    state = trainer.init(jax.random.PRNGKey(0), bs[0])
    cell = StateCell(state, 0)
    errors, checked = [], [0]
    done = threading.Event()

    def reader():
        while not done.is_set():
            with cell.lock:
                snap, t = cell.snapshot()
                acts = _np_acts(trainer.serve_lookup(snap, probe)[0])
            for n, a in acts.items():
                if not np.array_equal(a, ref[t][n]):
                    errors.append((t, n))
            checked[0] += 1

    th = threading.Thread(target=reader)
    th.start()
    st = state
    for t in range(steps):
        with cell.lock:
            st, _ = trainer.decomposed_step(st, bs[t + 1])
            cell.publish(st, t + 1)
    done.set()
    th.join()
    assert not errors, f"remote reader saw non-serial rows at {errors[:5]}"
    assert checked[0] > 0
    with cell.lock:
        final = _np_acts(trainer.serve_lookup(st, probe)[0])
    for n, a in final.items():
        np.testing.assert_array_equal(a, ref[steps][n])


def test_remote_staleness_gauge_sync_zero(servers):
    """The serving staleness gauge over the wire: sync tables read 0
    stale steps even while the trainer streams puts to the PS."""
    trainer = _trainer("dense", mode=TrainMode.sync())
    connect_remote_backends(
        trainer, [("127.0.0.1", sv.port) for sv in servers(1)])
    bs = _batches(5)
    state = trainer.init(jax.random.PRNGKey(0), bs[0])
    cell = StateCell(state, 0)
    tm = TrafficModel.for_dataset(DS, n_users=500)
    reqs = [r for _, r in tm.requests(12)]
    with ServingService(trainer, cell, ServingConfig(4, 2.0)) as svc:
        stop = threading.Event()

        def client():
            i = 0
            while not stop.is_set():
                svc.predict(reqs[i % len(reqs)])
                i += 1

        th = threading.Thread(target=client)
        th.start()
        s = state
        for t in range(4):
            with cell.lock:
                s, _ = trainer.decomposed_step(s, bs[t + 1])
                cell.publish(s, t + 1)
        stop.set()
        th.join()
        m = svc.metrics()
    for n in trainer.collection.names:
        assert m[f"serving/{n}/stale_steps"] == 0.0
    assert m["serving/requests"] > 0


def test_run_online_in_process():
    from repro.launch.online import run_online
    res = run_online(steps=6, mode="hybrid", backend="host_lru", tau=2,
                     batch=8, max_batch=4, n_clients=2,
                     requests_per_client=12, n_users=500, seed=0)
    assert res["steps"] == 6
    assert res["served"] > 0
    assert res["feedback"]["put"] == res["served"]
    sv = res["serving"]
    for n in ("field_00", "field_01"):
        assert sv[f"serving/{n}/stale_steps"] <= 2
    assert sv["serving/requests"] == res["served"]


def test_run_online_with_ps_subprocesses(tmp_path):
    from repro.launch.online import run_online
    res = run_online(steps=4, mode="sync", backend="dense", batch=8,
                     max_batch=4, n_clients=1, requests_per_client=8,
                     n_users=500, n_ps=2, seed=0,
                     workdir=str(tmp_path))
    assert res["steps"] == 4 and res["served"] == 8
    for k, v in res["serving"].items():
        if k.endswith("/stale_steps"):
            assert v == 0.0
