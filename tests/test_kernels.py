"""Per-kernel validation: shape/dtype sweeps + hypothesis properties, all
against the pure-jnp ref.py oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:        # optional dep: property tests get a fixed sweep
    HAVE_HYPOTHESIS = False

from repro.kernels import ops, ref
from repro.kernels import blockscale as bs


# ---------------------------------------------------------------------------
# blockscale
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rows", [256, 512, 1024])
def test_blockscale_matches_ref(rows):
    key = jax.random.PRNGKey(rows)
    v = jax.random.normal(key, (rows, 128)) * jnp.exp(
        jax.random.normal(key, (rows, 1)) * 4)
    c, s = ops.blockscale_compress(v)
    cr, sr = ref.blockscale_compress_ref(v)
    assert jnp.all(c == cr)
    np.testing.assert_allclose(s, sr, rtol=1e-6)
    out = ops.blockscale_decompress(c, s)
    np.testing.assert_allclose(out, ref.blockscale_decompress_ref(cr, sr),
                               rtol=1e-6)


def _blockscale_error_bound_case(a, b, logscale):
    """Property: per-block relative error <= fp16 quantisation of the
    block's L_inf (the paper's non-uniform-mapping guarantee)."""
    rng = np.random.default_rng(a * 1000 + b)
    v = (rng.standard_normal((a, b)) * np.exp(logscale)).astype(np.float32)
    out = np.asarray(ops.blockscale_roundtrip(jnp.asarray(v)))
    linf = np.abs(v).max() if v.size else 0.0
    # fp16 has 11 mantissa bits; values scaled to ~kappa so relative
    # error per element is <= linf * 2^-10 (conservative)
    assert np.all(np.abs(out - v) <= linf * 2 ** -10 + 1e-12)


if HAVE_HYPOTHESIS:
    @settings(deadline=None, max_examples=25)
    @given(st.integers(1, 5), st.integers(1, 300), st.floats(-8, 8))
    def test_blockscale_roundtrip_error_bound(a, b, logscale):
        _blockscale_error_bound_case(a, b, logscale)
else:
    @pytest.mark.parametrize("a,b,logscale",
                             [(1, 1, 0.0), (2, 37, -8.0), (5, 300, 8.0),
                              (3, 128, 3.5)])
    def test_blockscale_roundtrip_error_bound(a, b, logscale):
        _blockscale_error_bound_case(a, b, logscale)


def test_blockscale_zero_block():
    v = jnp.zeros((256, 128))
    out = ops.blockscale_roundtrip(v)
    assert jnp.all(out == 0)


# ---------------------------------------------------------------------------
# embedding_bag
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("V,D,B,L", [(64, 128, 4, 6), (128, 256, 8, 3),
                                     (32, 128, 1, 1), (256, 128, 16, 12)])
def test_embedding_bag_sweep(V, D, B, L):
    key = jax.random.PRNGKey(V + D + B + L)
    table = jax.random.normal(key, (V, D))
    ids = jax.random.randint(key, (B, L), -3, V)
    got = ops.embedding_bag(table, ids)
    want = ref.embedding_bag_ref(table, ids)
    np.testing.assert_allclose(got, want, atol=1e-6)


def test_embedding_bag_bf16():
    key = jax.random.PRNGKey(0)
    table = jax.random.normal(key, (64, 128)).astype(jnp.bfloat16)
    ids = jax.random.randint(key, (4, 5), -1, 64)
    got = ops.embedding_bag(table, ids)
    want = ref.embedding_bag_ref(table, ids)
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32), atol=1e-1)


def test_embedding_bag_all_padding():
    table = jnp.ones((16, 128))
    ids = jnp.full((2, 3), -1, jnp.int32)
    assert jnp.all(ops.embedding_bag(table, ids) == 0)


def _embedding_bag_case(B, L, V):
    rng = np.random.default_rng(B * 100 + L * 10 + V)
    table = jnp.asarray(rng.standard_normal((V, 128)).astype(np.float32))
    ids = jnp.asarray(rng.integers(-2, V, (B, L)).astype(np.int32))
    got = ops.embedding_bag(table, ids)
    want = ref.embedding_bag_ref(table, ids)
    np.testing.assert_allclose(got, want, atol=1e-5)


if HAVE_HYPOTHESIS:
    @settings(deadline=None, max_examples=20)
    @given(st.integers(1, 8), st.integers(1, 10), st.integers(8, 64))
    def test_embedding_bag_property(B, L, V):
        _embedding_bag_case(B, L, V)
else:
    @pytest.mark.parametrize("B,L,V", [(1, 1, 8), (4, 7, 33), (8, 10, 64)])
    def test_embedding_bag_property(B, L, V):
        _embedding_bag_case(B, L, V)


# ---------------------------------------------------------------------------
# unique_bag (worker-side batch dedup: fused gather + inverse + sum pool)
# ---------------------------------------------------------------------------

def _unique_bag_inputs(V, D, B, L, U, seed):
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.standard_normal((V, D)).astype(np.float32))
    n_live = max(U // 2, 1)                      # half the plan is padding
    dev = np.full(U, -1, np.int32)
    dev[:n_live] = rng.permutation(V)[:n_live]
    inv = rng.integers(-1, U, (B, L))            # hits padding slots too
    return table, jnp.asarray(dev, jnp.int32), jnp.asarray(inv, jnp.int32)


@pytest.mark.parametrize("V,D,B,L,U", [(64, 128, 4, 6, 16),
                                       (128, 256, 8, 3, 32),
                                       (32, 128, 1, 1, 4),
                                       (256, 128, 16, 12, 64)])
def test_unique_bag_sweep(V, D, B, L, U):
    table, dev, inv = _unique_bag_inputs(V, D, B, L, U, V + B + L)
    got = ops.unique_bag(table, dev, inv)
    want = ref.unique_bag_ref(table, dev, inv)
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_unique_bag_all_duplicates():
    """Every occurrence of the bag resolves to the SAME unique position —
    the hot-key regime batch dedup exists for: the pool must be L * row."""
    table = jnp.asarray(np.arange(8 * 128, dtype=np.float32).reshape(8, 128))
    dev = jnp.asarray([5, -1, -1, -1], jnp.int32)
    inv = jnp.zeros((2, 7), jnp.int32)           # all 14 occurrences -> u=0
    out = ops.unique_bag(table, dev, inv)
    np.testing.assert_allclose(out, np.tile(np.asarray(table[5]) * 7,
                                            (2, 1)), atol=1e-4)


def test_unique_bag_all_padding():
    """inv=-1 (multi-hot padding) and dev=-1 (plan padding) both pool to
    exact zeros."""
    table = jnp.ones((16, 128))
    dev = jnp.full((4,), -1, jnp.int32)
    assert jnp.all(ops.unique_bag(table, dev,
                                  jnp.full((2, 3), -1, jnp.int32)) == 0)
    # inv points at live positions of an all-padding plan
    assert jnp.all(ops.unique_bag(table, dev,
                                  jnp.zeros((2, 3), jnp.int32)) == 0)


def test_unique_bag_matches_unfused_plan_lookup():
    """The kernel computes exactly pool(scatter(gather(table, dev), inv)) —
    the three-step jnp lowering of the dedup-plan lookup."""
    from repro.core import dedup as D_
    rng = np.random.default_rng(3)
    V, D, B, L = 64, 128, 8, 5
    table = jnp.asarray(rng.standard_normal((V, D)).astype(np.float32))
    ids = rng.integers(-1, V, (B, L))
    u_pad, inv, _, _ = D_.make_plan(ids, V, D_.dedup_cap(B * L, V), floor=4)
    dev = jnp.asarray(u_pad, jnp.int32)
    inv = jnp.asarray(inv, jnp.int32)
    acts_u = table[jnp.clip(dev, 0)] * (dev >= 0)[:, None]
    want = jnp.sum(D_.plan_scatter(acts_u, inv), axis=1)
    got = ops.unique_bag(table, dev, inv)
    np.testing.assert_allclose(got, want, atol=1e-5)


# ---------------------------------------------------------------------------
# embedding_sgd
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("T", [1, 4, 17])
def test_embedding_sgd(T):
    key = jax.random.PRNGKey(T)
    table = jax.random.normal(key, (64, 128))
    # unique ids (kernel contract: pre-deduped puts)
    ids = jnp.asarray(np.random.default_rng(T).permutation(64)[:T],
                      jnp.int32)
    ids = ids.at[0].set(-1) if T > 2 else ids
    grads = jax.random.normal(key, (T, 128))
    got = ops.embedding_sgd(table, ids, grads, lr=0.05)
    want = ref.embedding_sgd_ref(table, ids, grads, lr=0.05)
    np.testing.assert_allclose(got, want, atol=1e-6)


# ---------------------------------------------------------------------------
# flash attention (Pallas fwd kernel vs jnp oracle)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("causal,window,dtype",
                         [(True, 0, jnp.float32), (True, 24, jnp.float32),
                          (False, 0, jnp.float32), (True, 0, jnp.bfloat16)])
def test_flash_kernel_matches_naive(causal, window, dtype):
    from repro.kernels.flash_attention import flash_attention_fwd
    from repro.models.layers import _attn_naive
    key = jax.random.PRNGKey(0)
    B, Hq, Hkv, S, Dh = 2, 4, 2, 64, 32
    q = jax.random.normal(key, (B, Hq, S, Dh)).astype(dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, Hkv, S, Dh)).astype(dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, Hkv, S, Dh)).astype(dtype)
    o, lse = flash_attention_fwd(q, k, v, scale=0.2, causal=causal,
                                 window=window, qblk=16, kblk=16,
                                 interpret=True)
    qg = q.reshape(B, Hkv, Hq // Hkv, S, Dh).transpose(0, 3, 1, 2, 4)
    on = _attn_naive(qg, k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
                     scale=0.2, causal=causal, window=window, q_offset=0)
    on = on.transpose(0, 2, 3, 1, 4).reshape(B, Hq, S, Dh)
    atol = 1e-5 if dtype == jnp.float32 else 0.04
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(on, np.float32), atol=atol)


@pytest.mark.parametrize("S,qblk,kblk", [(128, 32, 64), (96, 16, 32)])
def test_flash_kernel_block_shapes(S, qblk, kblk):
    from repro.kernels.flash_attention import flash_attention_fwd
    from repro.models.layers import _attn_naive
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (1, 2, S, 16))
    k = jax.random.normal(jax.random.PRNGKey(4), (1, 2, S, 16))
    v = jax.random.normal(jax.random.PRNGKey(5), (1, 2, S, 16))
    o, _ = flash_attention_fwd(q, k, v, scale=0.25, qblk=qblk, kblk=kblk,
                               interpret=True)
    qg = q.transpose(0, 2, 1, 3)[:, :, :, None]
    on = _attn_naive(qg, k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
                     scale=0.25, causal=True, window=0, q_offset=0)
    on = on[:, :, :, 0].transpose(0, 2, 1, 3)
    np.testing.assert_allclose(o, on, atol=1e-5)


def test_embedding_sgd_untouched_rows_preserved():
    table = jnp.arange(64 * 128, dtype=jnp.float32).reshape(64, 128)
    ids = jnp.array([5], jnp.int32)
    grads = jnp.ones((1, 128))
    out = ops.embedding_sgd(table, ids, grads, lr=1.0)
    assert jnp.all(out[6:] == table[6:])
    assert jnp.all(out[:5] == table[:5])
    np.testing.assert_allclose(out[5], table[5] - 1.0)


# ---------------------------------------------------------------------------
# fused_backward (one-pass dedup segment-sum + adagrad apply + queue payload)
# ---------------------------------------------------------------------------

def _fused_backward_case(R, Dm, U, n_occ, seed, apply_self=False):
    """Kernel vs jnp oracle. The queue payload (pure segment-sum) is
    bit-exact; table/acc sit in the documented ~1e-7 reduction-order
    class, hence allclose."""
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.standard_normal((R, Dm)).astype(np.float32))
    acc = jnp.asarray(rng.random(R).astype(np.float32))
    inv = jnp.asarray(rng.integers(-1, U, n_occ), jnp.int32)
    grads = jnp.asarray(rng.standard_normal((n_occ, Dm)).astype(np.float32))
    n_live = max(U // 2, 1)                      # half the plan is padding
    apply_idx = np.full(U, -1, np.int32)
    apply_idx[:n_live] = rng.permutation(R)[:n_live]
    apply_idx = jnp.asarray(apply_idx)
    apply_g = jnp.zeros((U, Dm)) if apply_self else jnp.asarray(
        rng.standard_normal((U, Dm)).astype(np.float32))
    want = ref.fused_backward_ref(table, acc, inv, grads, apply_idx,
                                  apply_g, cap=U, lr=5e-2, eps=1e-8,
                                  apply_self=apply_self)
    got = ops.fused_backward(table, acc, inv, grads, apply_idx, apply_g,
                             lr=5e-2, eps=1e-8, apply_self=apply_self)
    np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(want[2]))
    for g, w in zip(got[:2], want[:2]):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=2e-6, atol=2e-6)


@pytest.mark.parametrize("R,Dm,U,n_occ,apply_self",
                         [(64, 16, 8, 24, False), (128, 32, 16, 96, False),
                          (257, 64, 32, 128, True), (32, 8, 4, 4, True)])
def test_fused_backward_sweep(R, Dm, U, n_occ, apply_self):
    _fused_backward_case(R, Dm, U, n_occ, R + n_occ, apply_self)


if HAVE_HYPOTHESIS:
    @settings(deadline=None, max_examples=20)
    @given(st.integers(8, 80), st.sampled_from([8, 16, 32, 64]),
           st.sampled_from([4, 8, 16, 32]), st.integers(1, 128),
           st.booleans())
    def test_fused_backward_property(R, Dm, U, n_occ, apply_self):
        _fused_backward_case(R, Dm, U, n_occ, R * 7 + n_occ, apply_self)
else:
    @pytest.mark.parametrize("R,Dm,U,n_occ,apply_self",
                             [(8, 8, 4, 1, False), (80, 64, 32, 128, True),
                              (33, 16, 8, 50, False)])
    def test_fused_backward_property(R, Dm, U, n_occ, apply_self):
        _fused_backward_case(R, Dm, U, n_occ, R * 7 + n_occ, apply_self)


def test_fused_backward_all_padding():
    """inv=-1 (padding occurrences) and apply_idx=-1 (plan padding) leave
    the table/acc untouched and push exact zeros."""
    table = jnp.ones((16, 8))
    acc = jnp.ones((16,))
    got = ops.fused_backward(
        table, acc, jnp.full((6,), -1, jnp.int32), jnp.ones((6, 8)),
        jnp.full((4,), -1, jnp.int32), jnp.ones((4, 8)),
        lr=0.1, eps=1e-8)
    assert jnp.all(got[0] == table) and jnp.all(got[1] == acc)
    assert jnp.all(got[2] == 0)


def test_fused_backward_ref_sgd():
    """acc=None selects plain SGD: applied rows move by exactly
    -lr * summed grad, untouched rows are preserved bit-exact."""
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.standard_normal((32, 8)).astype(np.float32))
    inv = jnp.asarray([0, 0, 1, -1], jnp.int32)
    grads = jnp.asarray(rng.standard_normal((4, 8)).astype(np.float32))
    apply_idx = jnp.asarray([5, 9, -1], jnp.int32)
    new_t, new_acc, push = ref.fused_backward_ref(
        table, None, inv, grads, apply_idx, None, cap=3, lr=0.5, eps=1e-8,
        apply_self=True)
    assert new_acc is None
    np.testing.assert_array_equal(np.asarray(push[0]),
                                  np.asarray(grads[0] + grads[1]))
    np.testing.assert_array_equal(np.asarray(push[1]), np.asarray(grads[2]))
    np.testing.assert_array_equal(np.asarray(new_t[5]),
                                  np.asarray(table[5] - 0.5 * push[0]))
    np.testing.assert_array_equal(np.asarray(new_t[9]),
                                  np.asarray(table[9] - 0.5 * push[1]))
    untouched = np.setdiff1d(np.arange(32), [5, 9])
    np.testing.assert_array_equal(np.asarray(new_t[untouched]),
                                  np.asarray(table[untouched]))


# ---------------------------------------------------------------------------
# embedding_sgd duplicate-id contract (ISSUE 9 satellite)
# ---------------------------------------------------------------------------

def test_embedding_sgd_duplicate_ids_raise():
    """Since the PR-5 unique path, puts are pre-aggregated: occurrence-width
    ids must fail loudly instead of silently last-write-winning."""
    table = jnp.ones((16, 8))
    ids = jnp.asarray([3, 3, 7], jnp.int32)
    grads = jnp.ones((3, 8))
    with pytest.raises(ValueError, match="unique"):
        ops.embedding_sgd(table, ids, grads, lr=0.1)


def test_embedding_sgd_assume_unique_skips_guard():
    table = jnp.ones((16, 8))
    ids = jnp.asarray([3, 3, 7], jnp.int32)
    grads = jnp.ones((3, 8))
    out = ops.embedding_sgd(table, ids, grads, lr=0.1, assume_unique=True)
    assert out.shape == table.shape


def test_embedding_sgd_padding_duplicates_allowed():
    """-1 padding repeats freely — only valid ids are checked."""
    table = jnp.ones((16, 8))
    ids = jnp.asarray([-1, -1, 5], jnp.int32)
    grads = jnp.zeros((3, 8))
    out = ops.embedding_sgd(table, ids, grads, lr=0.1)
    assert jnp.all(out == table)
