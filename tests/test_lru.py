"""LRU embedding store (paper §4.2.2 array-list design) vs a reference
OrderedDict implementation, including serialize/deserialize = memory copy."""
from collections import OrderedDict

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:        # optional dep: property test gets a fixed sweep
    HAVE_HYPOTHESIS = False

from repro.core.lru import LRUEmbeddingStore


class RefLRU:
    def __init__(self, cap):
        self.cap = cap
        self.d = OrderedDict()

    def get(self, ids):
        out = []
        for i in ids:
            i = int(i)
            if i not in self.d:
                if len(self.d) >= self.cap:
                    self.d.popitem(last=False)
                self.d[i] = True
            else:
                self.d.move_to_end(i)
            out.append(i)
        return out

    def keys(self):
        return set(self.d)


def _lru_eviction_case(seq, cap):
    store = LRUEmbeddingStore(cap, dim=4)
    ref = RefLRU(cap)
    for i in seq:
        store.get(np.array([i]))
        ref.get([i])
    assert set(store.index) == ref.keys()


if HAVE_HYPOTHESIS:
    @settings(deadline=None, max_examples=20)
    @given(st.lists(st.integers(0, 30), min_size=1, max_size=200),
           st.integers(2, 12))
    def test_lru_eviction_matches_reference(seq, cap):
        _lru_eviction_case(seq, cap)
else:
    @pytest.mark.parametrize("seed,n,cap", [(0, 1, 2), (1, 50, 5),
                                            (2, 200, 12)])
    def test_lru_eviction_matches_reference(seed, n, cap):
        seq = np.random.default_rng(seed).integers(0, 31, n).tolist()
        _lru_eviction_case(seq, cap)


class RefValueLRU:
    """Dict model of the store's full contract: recency + vector + acc."""

    def __init__(self, cap, dim):
        self.cap, self.dim = cap, dim
        self.d = OrderedDict()          # id -> [vec, acc]

    def read(self, ids, store_v, store_a):
        """Mirror read_rows: verify hits, adopt the store's values on miss
        (the store initialises misses from its private rng)."""
        for i, (key, v, a) in enumerate(zip(ids, store_v, store_a)):
            key = int(key)
            if key in self.d:
                np.testing.assert_allclose(v, self.d[key][0], rtol=1e-6,
                                           err_msg=f"vec id={key} pos={i}")
                np.testing.assert_allclose(a, self.d[key][1], rtol=1e-6,
                                           err_msg=f"acc id={key} pos={i}")
                self.d.move_to_end(key)
            else:
                if len(self.d) >= self.cap:
                    self.d.popitem(last=False)
                self.d[key] = [np.array(v, np.float32), np.float32(a)]

    def put(self, ids, grads, lr, eps):
        """Mirror LRUEmbeddingStore.put: sequential per-row adagrad,
        last-writer-wins, missing ids dropped, recency untouched."""
        for key, g in zip(ids, grads):
            key = int(key)
            if key not in self.d:
                continue
            g = np.asarray(g, np.float32)
            acc = np.float32(self.d[key][1] + np.mean(g * g))
            self.d[key][1] = acc
            self.d[key][0] = np.float32(
                self.d[key][0] - lr * g / np.sqrt(acc + eps))

    def write(self, ids, vecs, accs):
        for key, v, a in zip(ids, vecs, accs):
            key = int(key)
            if key not in self.d and len(self.d) >= self.cap:
                self.d.popitem(last=False)
            if key in self.d:
                self.d.move_to_end(key)
            self.d[key] = [np.array(v, np.float32), np.float32(a)]


def _lru_value_model_case(ops, cap, dim=3, lr=0.1, eps=1e-8):
    """Drive an op sequence through store and model; values, optimizer
    accumulators, residency and recency must agree throughout."""
    store = LRUEmbeddingStore(cap, dim=dim, seed=11)
    ref = RefValueLRU(cap, dim)
    rng = np.random.default_rng(5)
    for kind, ids in ops:
        ids = np.asarray(ids, np.int64)
        if kind == "get":
            v, a = store.read_rows(ids)
            ref.read(ids, v, a)
        elif kind == "put":
            g = rng.standard_normal((len(ids), dim)).astype(np.float32)
            store.put(ids, g, lr=lr, eps=eps)
            ref.put(ids, g, lr, eps)
        else:                       # write (the cache write-back path)
            v = rng.standard_normal((len(ids), dim)).astype(np.float32)
            a = rng.random(len(ids)).astype(np.float32)
            store.write_rows(ids, v, a)
            ref.write(ids, v, a)
        assert set(store.index) == set(ref.d)
        assert store.recency_ids() == list(reversed(ref.d))
    for key, (v, a) in ref.d.items():
        got_v, got_a = store.read_rows(np.array([key]))
        np.testing.assert_allclose(got_v[0], v, rtol=1e-6)
        np.testing.assert_allclose(got_a[0], a, rtol=1e-6)


def _random_ops(rng, n_ops, id_range):
    kinds = rng.choice(["get", "put", "write"], n_ops, p=[0.5, 0.3, 0.2])
    return [(k, rng.integers(0, id_range, rng.integers(1, 6)).tolist())
            for k in kinds]


if HAVE_HYPOTHESIS:
    _op = st.tuples(st.sampled_from(["get", "put", "write"]),
                    st.lists(st.integers(0, 24), min_size=1, max_size=6))

    @settings(deadline=None, max_examples=30)
    @given(st.lists(_op, min_size=1, max_size=40), st.integers(2, 10))
    def test_lru_values_match_dict_model(ops, cap):
        """get/put/evict/write-back sequences keep vectors AND adagrad
        accumulators consistent with an OrderedDict reference."""
        _lru_value_model_case(ops, cap)
else:
    @pytest.mark.parametrize("seed,n,cap", [(0, 10, 2), (1, 40, 5),
                                            (2, 120, 10)])
    def test_lru_values_match_dict_model(seed, n, cap):
        rng = np.random.default_rng(seed)
        _lru_value_model_case(_random_ops(rng, n, 25), cap)


def test_vectors_stable_across_hits():
    store = LRUEmbeddingStore(8, dim=4)
    v1 = store.get(np.array([3])).copy()
    store.get(np.array([1, 2]))
    v2 = store.get(np.array([3]))
    np.testing.assert_array_equal(v1, v2)


def test_eviction_reinitialises():
    store = LRUEmbeddingStore(2, dim=4, seed=0)
    v1 = store.get(np.array([1])).copy()
    store.get(np.array([2, 3]))          # evicts 1
    assert 1 not in store.index
    assert store.evictions == 1
    back = store.get(np.array([1]))      # re-fault: freshly initialised
    assert not np.array_equal(back[0], v1[0])


def test_put_applies_adagrad():
    store = LRUEmbeddingStore(4, dim=4)
    v0 = store.get(np.array([7])).copy()
    g = np.ones((1, 4), np.float32)
    store.put(np.array([7]), g, lr=1.0, eps=0.0)
    v1 = store.get(np.array([7]))
    np.testing.assert_allclose(v1, v0 - 1.0, atol=1e-6)


def test_put_on_missing_id_is_noop():
    store = LRUEmbeddingStore(4, dim=4)
    store.put(np.array([42]), np.ones((1, 4), np.float32))
    assert 42 not in store.index


def test_eviction_counter_tracks_every_eviction():
    """Deterministic eviction accounting: the counter must advance once per
    evicted entry — batched and single gets alike."""
    store = LRUEmbeddingStore(3, dim=2)
    store.get(np.array([1, 2, 3]))           # fills, no eviction
    assert store.evictions == 0
    store.get(np.array([4]))                 # evicts 1 (LRU)
    assert store.evictions == 1
    store.get(np.array([1, 5]))              # evicts 2 then 3
    assert store.evictions == 3
    assert set(store.index) == {4, 1, 5}
    store.get(np.array([4, 1, 5]))           # all hits: no eviction
    assert store.evictions == 3


def test_batched_get_recency_matches_sequential():
    """The numpy-batched hit path must leave the identical recency order a
    per-id sequence of gets would."""
    rng = np.random.default_rng(7)
    seq = rng.integers(0, 20, 120)
    a = LRUEmbeddingStore(8, dim=4, seed=1)
    b = LRUEmbeddingStore(8, dim=4, seed=1)
    for i in range(0, len(seq), 6):          # batched (hits + misses mixed)
        a.get(seq[i: i + 6])
    for i in seq:                            # one id at a time
        b.get(np.array([i]))
    assert a.recency_ids() == b.recency_ids()
    assert a.evictions == b.evictions


def test_recency_order_survives_serialize_roundtrip():
    store = LRUEmbeddingStore(6, dim=2, seed=3)
    store.get(np.array([5, 1, 9, 1, 7]))
    back = LRUEmbeddingStore.deserialize(store.serialize())
    assert back.recency_ids() == store.recency_ids() == [7, 1, 9, 5]


def test_write_and_read_rows_roundtrip():
    store = LRUEmbeddingStore(8, dim=4)
    v = np.arange(8, dtype=np.float32).reshape(2, 4)
    acc = np.array([0.5, 2.0], np.float32)
    store.write_rows(np.array([10, 11]), v, acc)
    got_v, got_a = store.read_rows(np.array([10, 11]))
    np.testing.assert_array_equal(got_v, v)
    np.testing.assert_array_equal(got_a, acc)
    assert store.recency_ids()[0] == 11


def test_preload_bulk_load_order_and_values():
    store = LRUEmbeddingStore(16, dim=2)
    ids = np.array([3, 8, 5])
    v = np.arange(6, dtype=np.float32).reshape(3, 2)
    store.preload(ids, v, np.array([1.0, 2.0, 3.0]))
    assert store.recency_ids() == [5, 8, 3]          # last preloaded = MRU
    got_v, got_a = store.read_rows(np.array([8]))
    np.testing.assert_array_equal(got_v[0], [2.0, 3.0])
    assert got_a[0] == 2.0
    with pytest.raises(ValueError, match="empty"):
        store.preload(ids, v)


def test_serialize_roundtrip():
    store = LRUEmbeddingStore(8, dim=4, seed=1)
    store.get(np.arange(12))              # with evictions
    store.put(np.array([10]), np.ones((1, 4), np.float32))
    blob = store.serialize()
    back = LRUEmbeddingStore.deserialize(blob)
    assert set(back.index) == set(store.index)
    np.testing.assert_array_equal(back.vectors[: back.size],
                                  store.vectors[: store.size])
    # behaviourally identical afterwards — rng state round-trips, so even
    # the freshly-initialised miss rows match bit for bit
    a = store.get(np.array([11, 4]))
    b = back.get(np.array([11, 4]))
    np.testing.assert_array_equal(a, b)
    assert set(store.index) == set(back.index)


def test_deserialize_roundtrips_rng_state():
    """Regression: deserialize used to rebuild the store with a fresh
    seed-derived RNG, so the first post-restore miss drew different init
    vectors than the original store would have."""
    store = LRUEmbeddingStore(8, dim=4, seed=5)
    store.get(np.arange(6))                   # advance the init RNG
    back = LRUEmbeddingStore.deserialize(store.serialize())
    a = store.get(np.array([100]))            # brand-new id on both sides
    b = back.get(np.array([100]))
    np.testing.assert_array_equal(a[0], b[0])


def test_deserialize_roundtrips_init_scale_and_recency_flag():
    store = LRUEmbeddingStore(8, dim=4, seed=2, init_scale=0.5,
                              track_recency=False)
    store.get(np.array([1, 2, 3]))
    back = LRUEmbeddingStore.deserialize(store.serialize())
    assert back.track_recency is False
    assert back._init_scale == 0.5
    a = store.get(np.array([200]))
    b = back.get(np.array([200]))
    np.testing.assert_array_equal(a[0], b[0])


def test_deserialize_accepts_pre_cfg_blobs():
    """Blobs written before store_cfg/rng_state existed must still load
    (defaults apply: fresh RNG, recency tracking on)."""
    store = LRUEmbeddingStore(8, dim=4, seed=1)
    store.get(np.arange(10))
    blob = store.serialize()
    del blob["store_cfg"]
    del blob["rng_state"]
    back = LRUEmbeddingStore.deserialize(blob)
    assert set(back.index) == set(store.index)
    assert back.track_recency is True
    np.testing.assert_array_equal(back.vectors[: back.size],
                                  store.vectors[: store.size])


# ---------------------------------------------------------------------------
# blockscale16 storage dtype (ISSUE 9: cold rows compressed at rest)
# ---------------------------------------------------------------------------

def _bs_roundtrip_case(n, dim, logscale, seed=0):
    """Property: the storage codec's per-element error is bounded by the
    row-block L_inf times fp16 quantisation (same bound as the wire
    codec — it IS the same mapping, one scale per 128-wide block)."""
    from repro.core.lru import bs_compress_rows, bs_decompress_rows
    rng = np.random.default_rng(seed)
    v = (rng.standard_normal((n, dim)) * np.exp(logscale)).astype(np.float32)
    comp, scale = bs_compress_rows(v)
    assert comp.shape == v.shape and comp.dtype == np.float16
    assert scale.shape == (n, -(-dim // 128))
    out = bs_decompress_rows(comp, scale)
    linf = np.abs(v).max(axis=1, keepdims=True) if v.size else 0.0
    assert np.all(np.abs(out - v) <= linf * 2 ** -10 + 1e-12)


if HAVE_HYPOTHESIS:
    @settings(deadline=None, max_examples=25)
    @given(st.integers(1, 40), st.integers(1, 300), st.floats(-8, 8))
    def test_blockscale_storage_roundtrip_bound(n, dim, logscale):
        _bs_roundtrip_case(n, dim, logscale, seed=n * 1000 + dim)
else:
    @pytest.mark.parametrize("n,dim,logscale",
                             [(1, 1, 0.0), (7, 37, -8.0), (40, 300, 8.0),
                              (3, 128, 3.5), (5, 129, 0.0)])
    def test_blockscale_storage_roundtrip_bound(n, dim, logscale):
        _bs_roundtrip_case(n, dim, logscale, seed=n * 1000 + dim)


@pytest.mark.parametrize("dim", [4, 32, 100, 128, 130, 256])
def test_blockscale_store_read_your_writes(dim):
    """First touch (miss-path init) and every later read must agree —
    the store decompresses exactly what it compressed."""
    store = LRUEmbeddingStore(64, dim, store_dtype="blockscale16")
    ids = np.arange(20, dtype=np.int64)
    vecs = np.random.default_rng(dim).standard_normal(
        (20, dim)).astype(np.float32)
    store.preload(ids, vecs)
    v1, _ = store.read_rows(ids)
    v2, _ = store.read_rows(ids)
    np.testing.assert_array_equal(v1, v2)
    # lossy but bounded
    assert np.max(np.abs(v1 - vecs)) <= np.abs(vecs).max() * 2 ** -10


def test_blockscale_store_payload_halves():
    """dim 32: fp32 payload 128 B/row vs blockscale16 64+4 — the capacity
    claim the cache_tiers benchmark pins at >= 1.8x."""
    f32 = LRUEmbeddingStore(64, 32)
    b16 = LRUEmbeddingStore(64, 32, store_dtype="blockscale16")
    assert f32.payload_bytes() == 64 * 32 * 4
    assert b16.payload_bytes() == 64 * (32 * 2 + 4)
    assert f32.payload_bytes() / b16.payload_bytes() > 1.8


def test_blockscale_store_serialize_cross_format():
    """Checkpoints carry portable fp32 vectors + the raw fp16 payload:
    matching-dtype restore is bit-exact, cross-format restores re-encode
    (both directions load)."""
    rng = np.random.default_rng(3)
    ids = np.arange(16, dtype=np.int64)
    vecs = rng.standard_normal((16, 24)).astype(np.float32)
    b16 = LRUEmbeddingStore(32, 24, store_dtype="blockscale16")
    b16.preload(ids, vecs)
    blob = b16.serialize()
    assert blob["vectors"].dtype == np.float32
    same = LRUEmbeddingStore.deserialize(blob)
    assert same.store_dtype == "blockscale16"
    np.testing.assert_array_equal(same.read_rows(ids)[0],
                                  b16.read_rows(ids)[0])
    # blockscale blob -> fp32 store: loads the decompressed fp32 rows
    as_f32 = LRUEmbeddingStore.deserialize(blob, store_dtype="fp32")
    np.testing.assert_array_equal(as_f32.read_rows(ids)[0],
                                  b16.read_rows(ids)[0])
    # fp32 blob -> blockscale16 store: re-encodes on load
    f32 = LRUEmbeddingStore(32, 24)
    f32.preload(ids, vecs)
    as_b16 = LRUEmbeddingStore.deserialize(f32.serialize(),
                                           store_dtype="blockscale16")
    np.testing.assert_array_equal(as_b16.read_rows(ids)[0],
                                  b16.read_rows(ids)[0])


def test_store_dtype_validated():
    with pytest.raises(ValueError, match="store_dtype"):
        LRUEmbeddingStore(8, 4, store_dtype="fp8")
