"""Worker-side batch dedup (core/dedup.py): bit-exactness of the
unique-width lookup/queue/put path vs the occurrence-width PR-4 path
(sync/hybrid/async x dense/host_lru x shards x pipeline inflight),
narrowed-queue checkpoint round-trips (incl. old full-width blob
migration), the consolidated dedup capacity rule, and plan invariants."""
import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import adapters
from repro.core import backend as BK
from repro.core import dedup as D
from repro.core import embedding_ps as PS
from repro.core.compression import dedup_put
from repro.core.dedup import dedup_cap, make_plan
from repro.core.hybrid import PersiaTrainer, TrainMode
from repro.core.pipeline import PipelinedTrainer
from repro.data.ctr import CTRDataset
from repro.optim.optimizers import OptConfig

F, RPF, DIM = 2, 64, 8

CFG = ModelConfig(name="dd", arch_type="recsys", n_id_fields=F,
                  ids_per_field=3, emb_dim=DIM, emb_rows=F * RPF,
                  n_dense_features=4, mlp_dims=(16,), n_tasks=1)
DS = CTRDataset("dd", n_rows=F * RPF, n_fields=F, ids_per_field=3, n_dense=4)

MODES = {"sync": TrainMode.sync(), "hybrid": TrainMode.hybrid(3),
         "async": TrainMode.async_(3, 3)}


def _batches(n, batch=16, seed=None):
    it = DS.sampler(batch, seed=seed)
    return [{k: jnp.asarray(v) for k, v in next(it).items()}
            for _ in range(n)]


def _trainer(mode, backend="dense", shards=1, dedup=True, cache=None):
    coll = adapters.ctr_collection(CFG, lr=5e-2, field_rows=DS.field_rows())
    if backend != "dense":
        coll = coll.with_backend(backend, cache or RPF)
    if shards != 1:
        coll = coll.with_shards(shards)
    ad = adapters.recsys_adapter(CFG, field_rows=DS.field_rows(),
                                 collection=coll)
    return PersiaTrainer(ad, MODES[mode] if isinstance(mode, str) else mode,
                         OptConfig(kind="adam", lr=5e-3), batch_dedup=dedup)


def _logical_tables(trainer, state):
    """Logical (row-ordered) table+acc per table — slot layouts may differ
    between runs (fault order), logical content must not."""
    out = {}
    for n in trainer.collection.names:
        bk = BK.unwrap(trainer.backends[n])
        spec = trainer.collection[n]
        base = "host_lru" if "host_lru" in (spec.backend or "dense") \
            else "dense"
        blob = bk.state_for_checkpoint(state.emb[n])
        out[n] = BK.extract_logical_rows(blob, spec, base)
    return out


def _assert_logical_equal(ta, sa, tb, sb):
    la, lb = _logical_tables(ta, sa), _logical_tables(tb, sb)
    for n in la:
        np.testing.assert_array_equal(la[n][0], lb[n][0], err_msg=f"{n} vec")
        if la[n][1] is not None:
            np.testing.assert_array_equal(la[n][1], lb[n][1],
                                          err_msg=f"{n} acc")


# ---------------------------------------------------------------------------
# the consolidated dedup capacity rule (one helper, three former mirrors)
# ---------------------------------------------------------------------------

def test_dedup_cap_matches_legacy_rule_and_is_idempotent():
    from repro.utils import round_up
    for n_put in (1, 2, 7, 48, 100, 1024, 1500, 4096, 9999):
        for rows in (1, 3, 64, 512, 1500, 4096, 100_000):
            want = round_up(min(n_put, rows), min(1024, n_put))  # PR-2 rule
            got = dedup_cap(n_put, rows)
            assert got == want, (n_put, rows)
            assert dedup_cap(got, rows) == got, (n_put, rows)  # idempotent
            assert got >= min(n_put, rows)


def test_cap_rule_shared_across_modules():
    """The three former mirrors all route through core/dedup.dedup_cap."""
    assert not hasattr(BK, "_dedup_cap")          # backend mirror deleted
    assert "dedup_cap" in inspect.getsource(PS.apply_put)
    # wire + dense + sharded queue widths all derive from the one rule
    spec = PS.EmbeddingSpec(rows=512, dim=4, mode="full", staleness=2)
    assert BK.create_backend(spec).queue_width(4096) == dedup_cap(4096, 512)
    wire = BK.create_backend(
        PS.EmbeddingSpec(rows=512, dim=4, mode="full", staleness=2,
                         backend="dense+compressed"))
    assert wire.queue_width(4096) == dedup_cap(4096, 512)
    lru = BK.create_backend(
        PS.EmbeddingSpec(rows=512, dim=4, mode="full", staleness=2,
                         backend="host_lru", cache_rows=128))
    assert lru.queue_width(4096) == dedup_cap(4096, 128)


# ---------------------------------------------------------------------------
# plan invariants
# ---------------------------------------------------------------------------

def test_make_plan_roundtrip_and_counts():
    rng = np.random.default_rng(0)
    ids = rng.integers(-2, 40, (8, 5))
    u, inv, counts, info = make_plan(ids, 40, dedup_cap(40, 40))
    valid = (ids >= 0) & (ids < 40)
    # inverse maps every valid occurrence back to its id
    np.testing.assert_array_equal(u[inv[valid]], ids[valid])
    assert np.all(inv[~valid] == -1)
    assert counts.sum() == valid.sum() == info["n_occ"]
    assert (u >= 0).sum() == info["n_unique"]
    assert info["dup_factor"] == pytest.approx(
        info["n_occ"] / info["n_unique"])
    # unique set is exactly np.unique of the valid ids
    np.testing.assert_array_equal(u[u >= 0], np.unique(ids[valid]))


def test_plan_segment_sum_matches_dedup_put_sums():
    """Pre-queue segment-sum == the old post-queue sort-based dedup, row
    for row (the commutation the bit-exactness contract rests on)."""
    rng = np.random.default_rng(1)
    ids = rng.integers(-1, 10, 64)
    g = jnp.asarray(rng.standard_normal((64, 4)).astype(np.float32))
    cap = dedup_cap(64, 10)
    u, inv, _, _ = make_plan(ids, 10, cap)
    g_u = D.plan_segment_sum(jnp.asarray(inv), g, int(u.shape[0]))
    old_u, old_g = dedup_put(jnp.asarray(np.where(ids >= 0, ids, -1),
                                         jnp.int32), g, cap)
    old = {int(i): np.asarray(r) for i, r in zip(old_u, old_g) if i >= 0}
    new = {int(i): np.asarray(r) for i, r in zip(u, g_u) if i >= 0}
    assert set(old) == set(new)
    for k in old:
        np.testing.assert_array_equal(old[k], new[k], err_msg=str(k))


def test_plan_scatter_matches_direct_lookup():
    rng = np.random.default_rng(2)
    table = jnp.asarray(rng.standard_normal((32, 6)).astype(np.float32))
    ids = rng.integers(-1, 32, (4, 5))
    u, inv, _, _ = make_plan(ids, 32, dedup_cap(20, 32))
    dev = jnp.asarray(u, jnp.int32)
    acts_u = table[jnp.clip(dev, 0)] * (dev >= 0)[:, None]
    got = D.plan_scatter(acts_u, jnp.asarray(inv))
    want = table[np.where(ids >= 0, ids, 0)] * \
        jnp.asarray((ids >= 0)[..., None], jnp.float32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# bit-exactness sweep: unique-width path vs the PR-4 occurrence path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["sync", "hybrid", "async"])
@pytest.mark.parametrize("backend,shards", [("dense", 1), ("dense", 4),
                                            ("host_lru", 1),
                                            ("host_lru", 4)])
def test_dedup_bit_exact_vs_occurrence_path(mode, backend, shards):
    batches = _batches(6)
    t_new = _trainer(mode, backend, shards, dedup=True)
    t_old = _trainer(mode, backend, shards, dedup=False)
    s_new = t_new.init(jax.random.PRNGKey(0), batches[0])
    s_old = t_old.init(jax.random.PRNGKey(0), batches[0])
    for b in batches:
        s_new, m_new = t_new.decomposed_step(s_new, b)
        s_old, _ = t_old.decomposed_step(s_old, b)
    _assert_logical_equal(t_new, s_new, t_old, s_old)
    for a, b_ in zip(jax.tree.leaves(s_new.dense),
                     jax.tree.leaves(s_old.dense)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))
    # the dedup gauges only exist on the dedup path
    assert any(k.startswith("dedup/") and k.endswith("dup_factor")
               for k in m_new)


def test_dedup_fused_matches_decomposed_and_eval_parity():
    batches = _batches(5)
    t_f = _trainer("hybrid")
    t_d = _trainer("hybrid")
    s_f = t_f.init(jax.random.PRNGKey(0), batches[0])
    s_d = t_d.init(jax.random.PRNGKey(0), batches[0])
    for b in batches:
        s_f, _ = t_f.step(s_f, b)
        s_d, _ = t_d.decomposed_step(s_d, b)
    for n in s_f.emb:
        np.testing.assert_array_equal(np.asarray(s_f.emb[n]["table"]),
                                      np.asarray(s_d.emb[n]["table"]))
    # eval through plans == eval through the occurrence path
    t_old = _trainer("hybrid", dedup=False)
    s_old = t_old.init(jax.random.PRNGKey(0), batches[0])
    for b in batches:
        s_old, _ = t_old.decomposed_step(s_old, b)
    eb = _batches(1, seed=99)[0]
    m_new, m_old = t_d.eval(s_d, eb), t_old.eval(s_old, eb)
    assert float(m_new["loss"]) == float(m_old["loss"])


@pytest.mark.parametrize("shards", [1, 4])
def test_pipeline_inflight1_bit_exact_and_deep_runs(shards):
    """max_inflight=1 over the plan path == the occurrence-path serial
    trainer; a deep pipeline completes in order with the plan payloads."""
    batches = _batches(8)
    t_old = _trainer("hybrid", "host_lru", shards, dedup=False)
    s_old = t_old.init(jax.random.PRNGKey(0), batches[0])
    for b in batches:
        s_old, _ = t_old.decomposed_step(s_old, b)

    t_new = _trainer("hybrid", "host_lru", shards, dedup=True)
    engine = PipelinedTrainer(t_new, max_inflight=1)
    s_new = engine.init(jax.random.PRNGKey(0), batches[0])
    s_new, ms = engine.run(s_new, batches)
    assert len(ms) == len(batches)
    _assert_logical_equal(t_new, s_new, t_old, s_old)

    t_deep = _trainer("hybrid", "host_lru", shards, dedup=True)
    deep = PipelinedTrainer(t_deep, max_inflight=4)
    s_deep = deep.init(jax.random.PRNGKey(0), batches[0])
    s_deep, ms_deep = deep.run(s_deep, batches)
    assert deep.applied_order == list(range(len(batches)))
    assert all(np.isfinite(float(m["loss"])) for m in ms_deep)
    assert any(k.endswith("dup_factor") for k in ms_deep[0])


# ---------------------------------------------------------------------------
# narrowed queues + checkpoint round-trips (incl. old full-width blobs)
# ---------------------------------------------------------------------------

# a geometry where the cap actually bites: n_occ = 128*16 = 2048 per table,
# rows = 256 -> queue width 1024 (2x narrower than occurrence width)
NCFG = ModelConfig(name="nw", arch_type="recsys", n_id_fields=1,
                   ids_per_field=16, emb_dim=4, emb_rows=256,
                   n_dense_features=2, mlp_dims=(8,), n_tasks=1)
NDS = CTRDataset("nw", n_rows=256, n_fields=1, ids_per_field=16, n_dense=2)


def _narrow_trainer(dedup=True, backend="dense"):
    coll = adapters.ctr_collection(NCFG, lr=5e-2, field_rows=(256,))
    if backend != "dense":
        coll = coll.with_backend(backend, 256)
    ad = adapters.recsys_adapter(NCFG, field_rows=(256,), collection=coll)
    return PersiaTrainer(ad, TrainMode.hybrid(2),
                         OptConfig(kind="adam", lr=5e-3), batch_dedup=dedup)


def _narrow_batches(n, seed=None):
    it = NDS.sampler(128, seed=seed)
    return [{k: jnp.asarray(v) for k, v in next(it).items()}
            for _ in range(n)]


def test_queue_width_is_the_dedup_cap():
    batches = _narrow_batches(1)
    tr = _narrow_trainer(dedup=True)
    st = tr.init(jax.random.PRNGKey(0), batches[0])
    q = st.emb_queue["field_00"]
    assert q["ids"].shape == (2, dedup_cap(128 * 16, 256)) == (2, 1024)
    legacy = _narrow_trainer(dedup=False)
    sl = legacy.init(jax.random.PRNGKey(0), batches[0])
    assert sl.emb_queue["field_00"]["ids"].shape == (2, 2048)


@pytest.mark.parametrize("backend", ["dense", "host_lru"])
def test_old_full_width_queue_blob_migrates_on_restore(tmp_path, backend):
    """A checkpoint written by the occurrence-width trainer (tau pending
    full-width puts in flight) restores into a batch-dedup trainer: the
    queue narrows to the cap and training continues bit-exactly with the
    old trainer's own continuation."""
    batches = _narrow_batches(6)
    t_old = _narrow_trainer(dedup=False, backend=backend)
    s_old = t_old.init(jax.random.PRNGKey(0), batches[0])
    for b in batches[:3]:
        s_old, _ = t_old.decomposed_step(s_old, b)
    t_old.save(str(tmp_path / "ck"), s_old)

    t_new = _narrow_trainer(dedup=True, backend=backend)
    s_new = t_new.restore(str(tmp_path / "ck"))
    q = s_new.emb_queue["field_00"]
    assert np.shape(q["ids"])[1] == 1024          # migrated, was 2048
    # the pending puts survived the migration (filled FIFO, warmup done)
    assert int(np.asarray(q["filled"])) == 2
    for b in batches[3:]:
        s_new, _ = t_new.decomposed_step(s_new, b)
        s_old, _ = t_old.decomposed_step(s_old, b)
    _assert_logical_equal(t_new, s_new, t_old, s_old)


def test_same_geometry_dedup_resume_is_bit_identical(tmp_path):
    batches = _narrow_batches(6)
    t_a = _narrow_trainer(dedup=True)
    s_a = t_a.init(jax.random.PRNGKey(0), batches[0])
    for b in batches[:3]:
        s_a, _ = t_a.decomposed_step(s_a, b)
    t_a.save(str(tmp_path / "ck"), s_a)
    t_b = _narrow_trainer(dedup=True)
    s_b = t_b.restore(str(tmp_path / "ck"))
    # narrow blob into a narrow trainer: no migration, bit-identical queue
    np.testing.assert_array_equal(np.asarray(s_a.emb_queue["field_00"]["ids"]),
                                  np.asarray(s_b.emb_queue["field_00"]["ids"]))
    for b in batches[3:]:
        s_a, _ = t_a.decomposed_step(s_a, b)
        s_b, _ = t_b.decomposed_step(s_b, b)
    for n in s_a.emb:
        np.testing.assert_array_equal(np.asarray(s_a.emb[n]["table"]),
                                      np.asarray(s_b.emb[n]["table"]))


def test_migrate_queue_blob_dedups_each_slot():
    q = {"ids": np.array([[3, 3, 5, -1], [7, -1, 7, 7]], np.int32),
         "grads": np.arange(24, dtype=np.float32).reshape(2, 4, 3),
         "ptr": np.int32(1), "filled": np.int32(2)}
    out = D.migrate_queue_blob(q, 2)
    np.testing.assert_array_equal(out["ids"], [[3, 5], [7, -1]])
    np.testing.assert_array_equal(out["grads"][0, 0],
                                  q["grads"][0, 0] + q["grads"][0, 1])
    np.testing.assert_array_equal(out["grads"][0, 1], q["grads"][0, 2])
    np.testing.assert_array_equal(
        out["grads"][1, 0],
        q["grads"][1, 0] + q["grads"][1, 2] + q["grads"][1, 3])
    assert int(out["ptr"]) == 1 and int(out["filled"]) == 2


# ---------------------------------------------------------------------------
# metrics + host-LRU plan consumption (no second np.unique in the fault path)
# ---------------------------------------------------------------------------

def test_step_metrics_carry_dedup_gauges():
    batches = _batches(2)
    tr = _trainer("hybrid", "host_lru")
    st = tr.init(jax.random.PRNGKey(0), batches[0])
    st, m = tr.step(st, batches[0])
    for n in tr.collection.names:
        assert f"dedup/{n}/dup_factor" in m
        assert f"dedup/{n}/unique_rows" in m
        assert f"dedup/{n}/bytes_saved" in m
        assert m[f"dedup/{n}/dup_factor"] >= 1.0


def test_host_lru_prepare_consumes_plan_uniques():
    """assume_unique skips the backend's own np.unique: feeding the raw
    (duplicated) stream with assume_unique=False and the deduped stream
    with assume_unique=True must produce identical slot maps."""
    spec = PS.EmbeddingSpec(rows=32, dim=4, mode="full",
                            backend="host_lru", cache_rows=16)
    a, b = BK.create_backend(spec), BK.create_backend(spec)
    sa = a.init(jax.random.PRNGKey(0))
    sb = b.init(jax.random.PRNGKey(0))
    ids = np.array([5, 5, 9, 2, 9, -1])
    sa, dev_a = a.prepare(sa, ids)
    uniq = np.unique(ids[ids >= 0])
    sb, dev_b = b.prepare(sb, uniq, assume_unique=True)
    assert a._slot_for_id == b._slot_for_id
    assert a.faults == b.faults == 3


def test_cache_overflow_raises_actionable_error():
    """A batch whose unique working set exceeds the host_lru device cache
    must fail with the raise-cache_rows guidance (the plan's capacity is
    bounded by the cache, so the overflow surfaces at plan time)."""
    spec = PS.EmbeddingSpec(rows=1024, dim=4, mode="full",
                            backend="host_lru", cache_rows=8)
    bk = BK.create_backend(spec)
    st = bk.init(jax.random.PRNGKey(0))
    ids = np.arange(16)          # 16 unique > 8 cache slots
    with pytest.raises(ValueError, match="cache_rows"):
        BK.prepare_all({"t": bk}, {"t": st}, {"t": ids})


def test_sharded_imbalance_gauge_still_sees_occurrence_traffic():
    """Dedup must NOT blind the hot-key gauge: counts ride the plan, so
    routed traffic is still measured per occurrence."""
    coll = adapters.ctr_collection(CFG, lr=5e-2, field_rows=DS.field_rows())
    coll = coll.with_backend("host_lru", RPF).with_shards(4)
    ad = adapters.recsys_adapter(CFG, field_rows=DS.field_rows(),
                                 collection=coll)
    tr = PersiaTrainer(ad, TrainMode.sync(), OptConfig(kind="adam", lr=5e-3))
    rng = np.random.default_rng(0)

    def skewed():
        ids = rng.integers(0, RPF, (16, F, 3))
        ids = np.where(rng.random((16, F, 3)) < 0.9, 7, ids)
        return {"ids": jnp.asarray(ids, jnp.int32),
                "dense": jnp.asarray(rng.standard_normal((16, 4)),
                                     jnp.float32),
                "labels": jnp.asarray(rng.random((16, 1)) < 0.3,
                                      jnp.float32)}

    st = tr.init(jax.random.PRNGKey(0), skewed())
    for _ in range(4):
        st, m = tr.decomposed_step(st, skewed())
    gauges = [v for k, v in m.items() if k.endswith("imbalance")]
    assert gauges and all(float(v) > 2.0 for v in gauges)
