"""Online serving subsystem (repro/serving): the read-only ``read_rows``
path vs the training lookup path, micro-batching bit-exactness and flush
triggers, serve-while-train safety (a reader thread hammering lookups
during training must see exactly the serial trajectory), the staleness
gauge bounds (sync = 0, hybrid <= tau), the Zipf traffic model, the click
feedback queue, and the closed serve -> train -> serve loop beating a
frozen-model control on the same traffic."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import adapters
from repro.core.hybrid import PersiaTrainer, TrainMode
from repro.data.ctr import CTRDataset
from repro.optim.optimizers import OptConfig
from repro.serving import (ClickModel, FeedbackQueue, ServingConfig,
                           ServingService, StateCell, TrafficModel)
from repro.serving.service import ServingStopTimeout, queue_lag

F, RPF, D = 2, 64, 8

CFG = ModelConfig(name="srv", arch_type="recsys", n_id_fields=F,
                  ids_per_field=3, emb_dim=D, emb_rows=F * RPF,
                  n_dense_features=4, mlp_dims=(16,), n_tasks=1)
DS = CTRDataset("srv", n_rows=F * RPF, n_fields=F, ids_per_field=3,
                n_dense=4)

BACKENDS = ["dense", "host_lru", "sharded", "dense+compressed",
            "host_lru+compressed"]


def _trainer(backend="dense", mode=None, tau=2, cache_rows=40):
    coll = adapters.ctr_collection(CFG, lr=5e-2, field_rows=DS.field_rows())
    if backend == "sharded":
        coll = coll.with_shards(2)
    elif backend != "dense":
        coll = coll.with_backend(backend, cache_rows
                                 if "host_lru" in backend else None)
    ad = adapters.recsys_adapter(CFG, field_rows=DS.field_rows(),
                                 collection=coll)
    return PersiaTrainer(ad, mode or TrainMode.hybrid(tau),
                         OptConfig(kind="adam", lr=5e-3))


def _batches(n, batch=16, seed=0):
    it = DS.sampler(batch, seed=seed)
    return [{k: jnp.asarray(v) for k, v in next(it).items()}
            for _ in range(n)]


def _np_acts(acts):
    return {n: np.asarray(a) for n, a in acts.items()}


# ---------------------------------------------------------------------------
# read_rows: the read-only serve path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_read_rows_matches_training_lookup(backend):
    """Serve reads return bit-exactly what the training lookup path
    returns for resident rows — same quantization, same masking."""
    trainer = _trainer(backend)
    bs = _batches(3)
    state = trainer.init(jax.random.PRNGKey(0), bs[0])
    for b in bs:
        state, _ = trainer.step(state, b)
    probe = bs[1]
    # train path: prepare (faults rows in) + lookup
    want = {}
    for n, ids in trainer.adapter.emb_ids(probe).items():
        bk = trainer.backends[n]
        st, dev = bk.prepare(state.emb[n], ids)
        state.emb = {**state.emb, n: st}
        acts, _ = bk.lookup(st, dev)
        want[n] = np.asarray(acts, np.float32)
    acts, info = trainer.serve_lookup(state, probe)
    for i, n in enumerate(trainer.collection.names):
        np.testing.assert_array_equal(np.asarray(acts[n]), want[n])
        fid = np.asarray(probe["ids"])[:, i]
        uniq = np.unique(fid[fid >= 0]).size
        assert info[n]["reads"] == uniq
        assert info[n]["hits"] + info[n]["misses"] == info[n]["reads"]


def test_read_rows_padding_and_out_of_range():
    trainer = _trainer("dense")
    b = _batches(1)[0]
    state = trainer.init(jax.random.PRNGKey(0), b)
    name = trainer.collection.names[0]
    bk = trainer.backends[name]
    rows, info = bk.read_rows(state.emb[name],
                              np.array([[0, -1, RPF + 5]], np.int64))
    assert rows.shape == (1, 3, D)
    np.testing.assert_array_equal(rows[0, 1], np.zeros(D, np.float32))
    np.testing.assert_array_equal(rows[0, 2], np.zeros(D, np.float32))
    assert info["reads"] == 1


def test_read_rows_leaves_host_lru_device_state_untouched():
    """Serve reads must not fault, evict, or reorder the device cache —
    cache misses are answered from the host store directly."""
    trainer = _trainer("host_lru", cache_rows=32)
    bs = _batches(3)
    state = trainer.init(jax.random.PRNGKey(0), bs[0])
    for b in bs:
        state, _ = trainer.step(state, b)
    name = trainer.collection.names[0]
    before_slots = np.asarray(state.emb[name]["slot_ids"]).copy()
    before_table = np.asarray(state.emb[name]["table"]).copy()
    bk = trainer.backends[name]
    all_ids = np.arange(RPF, dtype=np.int64)     # misses guaranteed
    rows, info = bk.read_rows(state.emb[name], all_ids)
    assert info["misses"] > 0 and info["hits"] > 0
    np.testing.assert_array_equal(
        np.asarray(state.emb[name]["slot_ids"]), before_slots)
    np.testing.assert_array_equal(
        np.asarray(state.emb[name]["table"]), before_table)
    assert int(np.asarray(bk._pin_count).sum()) == 0   # pins released


def test_eval_is_side_effect_free_and_matches_trajectory():
    """eval through the serve path must not perturb training: a run with
    interleaved evals matches an uninterrupted clone bit-for-bit."""
    bs = _batches(5)
    t1, t2 = _trainer("host_lru"), _trainer("host_lru")
    s1 = t1.init(jax.random.PRNGKey(0), bs[0])
    s2 = t2.init(jax.random.PRNGKey(0), bs[0])
    for b in bs:
        s1, m1 = t1.step(s1, b)
        t2.eval(s2, bs[0])                       # extra reads
        s2, m2 = t2.step(s2, b)
        t2.eval(s2, bs[-1])
        assert float(m1["loss"]) == float(m2["loss"])
    for a, b_ in zip(jax.tree_util.tree_leaves(s1.dense),
                     jax.tree_util.tree_leaves(s2.dense)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))


# ---------------------------------------------------------------------------
# micro-batching
# ---------------------------------------------------------------------------

def _requests(n, seed=0):
    tm = TrafficModel.for_dataset(DS, n_users=500)
    return [r for _, r in tm.requests(n, seed=seed)]


def test_micro_batched_equals_single_request():
    trainer = _trainer("dense", mode=TrainMode.sync())
    b = _batches(1)[0]
    state = trainer.init(jax.random.PRNGKey(0), b)
    reqs = _requests(12)
    cell = StateCell(state, 0)
    with ServingService(trainer, cell, ServingConfig(1, 0.0)) as svc:
        single = svc.predict_many(reqs)
    with ServingService(trainer, cell, ServingConfig(8, 50.0)) as svc:
        futs = [svc.submit(r) for r in reqs]
        batched = np.stack([f.result(30.0) for f in futs])
    np.testing.assert_array_equal(single, batched)


def test_flush_on_max_batch_not_timeout():
    trainer = _trainer("dense", mode=TrainMode.sync())
    b = _batches(1)[0]
    state = trainer.init(jax.random.PRNGKey(0), b)
    reqs = _requests(4)
    cell = StateCell(state, 0)
    svc = ServingService(trainer, cell,
                         ServingConfig(max_batch=4, max_wait_ms=60_000))
    with svc:
        svc.predict_many(reqs[:4])               # full batch: flushes now
        m = svc.metrics()
    assert m["serving/batches"] == 1
    assert m[f"serving/{trainer.collection.names[0]}/batch_fill"] == 1.0


def test_flush_on_timeout_with_partial_batch():
    trainer = _trainer("dense", mode=TrainMode.sync())
    b = _batches(1)[0]
    state = trainer.init(jax.random.PRNGKey(0), b)
    cell = StateCell(state, 0)
    svc = ServingService(trainer, cell,
                         ServingConfig(max_batch=64, max_wait_ms=30.0))
    with svc:
        p = svc.predict(_requests(1)[0], timeout=30.0)   # alone in queue
        m = svc.metrics()
    assert p.shape == (CFG.n_tasks,)
    assert m["serving/batches"] == 1
    assert m[f"serving/{trainer.collection.names[0]}/batch_fill"] < 1.0


# ---------------------------------------------------------------------------
# serve-while-train: concurrency regression (satellite: reader-safe lookup)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["dense", "host_lru", "sharded"])
def test_concurrent_reader_sees_serial_trajectory(backend):
    """A reader thread hammering serve_lookup during training observes,
    at every published step, bit-exactly the state a serial run produces
    — and never perturbs the training trajectory itself."""
    steps = 6
    bs = _batches(steps + 1)
    probe = bs[0]

    ref_trainer = _trainer(backend)
    s = ref_trainer.init(jax.random.PRNGKey(0), bs[0])
    ref = {0: _np_acts(ref_trainer.serve_lookup(s, probe)[0])}
    for t in range(steps):
        s, _ = ref_trainer.step(s, bs[t + 1])
        ref[t + 1] = _np_acts(ref_trainer.serve_lookup(s, probe)[0])

    trainer = _trainer(backend)
    state = trainer.init(jax.random.PRNGKey(0), bs[0])
    cell = StateCell(state, 0)
    errors, checked = [], [0]
    done = threading.Event()

    def reader():
        while not done.is_set():
            with cell.lock:
                snap, t = cell.snapshot()
                acts = _np_acts(trainer.serve_lookup(snap, probe)[0])
            for n, a in acts.items():
                if not np.array_equal(a, ref[t][n]):
                    errors.append((t, n))
            checked[0] += 1

    threads = [threading.Thread(target=reader) for _ in range(2)]
    for th in threads:
        th.start()
    st = state
    for t in range(steps):
        with cell.lock:
            st, _ = trainer.step(st, bs[t + 1])
            cell.publish(st, t + 1)
    done.set()
    for th in threads:
        th.join()
    assert not errors, f"reader saw non-serial rows at {errors[:5]}"
    assert checked[0] >= steps        # the readers actually overlapped
    with cell.lock:
        final = _np_acts(trainer.serve_lookup(st, probe)[0])
    for n, a in final.items():
        np.testing.assert_array_equal(a, ref[steps][n])


# ---------------------------------------------------------------------------
# staleness gauge (satellite: serving step metrics)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode,tau", [("sync", 0), ("hybrid", 2)])
def test_staleness_gauge_bounds(mode, tau):
    tm = TrainMode.sync() if mode == "sync" else TrainMode.hybrid(tau)
    trainer = _trainer("dense", mode=tm, tau=tau)
    bs = _batches(7)
    state = trainer.init(jax.random.PRNGKey(0), bs[0])
    cell = StateCell(state, 0)
    reqs = _requests(24)
    with ServingService(trainer, cell, ServingConfig(4, 2.0)) as svc:
        stop = threading.Event()

        def client():
            i = 0
            while not stop.is_set():
                svc.predict(reqs[i % len(reqs)])
                i += 1

        th = threading.Thread(target=client)
        th.start()
        s = state
        for t in range(6):
            with cell.lock:
                s, _ = trainer.step(s, bs[t + 1])
                cell.publish(s, t + 1)
        stop.set()
        th.join()
        m = svc.metrics()
    for n in trainer.collection.names:
        stale = m[f"serving/{n}/stale_steps"]
        assert stale <= tau, f"{mode}: {n} read {stale} stale steps > {tau}"
        assert m[f"serving/{n}/hit_rate"] == 1.0   # dense: all resident
    assert m["serving/requests"] > 0


def test_queue_lag_helper():
    assert queue_lag(None, 5, 0) == 0
    q = {"ids": np.zeros((2, 4), np.int32), "grads": 0,
         "ptr": 0, "filled": np.asarray(1)}
    assert queue_lag(q, 5, 2) == 1
    assert queue_lag({"s0": q, "s1": {**q, "filled": np.asarray(2)}},
                     5, 2) == 2
    remote = {"ids": np.zeros((2, 0), np.int32)}    # placeholder: bound
    assert queue_lag(remote, 1, 2) == 1
    assert queue_lag(remote, 9, 2) == 2


# ---------------------------------------------------------------------------
# traffic model
# ---------------------------------------------------------------------------

def test_traffic_is_deterministic_and_in_range():
    tm = TrafficModel.for_dataset(DS, n_users=1000)
    a = [(u, r) for u, r in tm.requests(20, seed=3)]
    b = [(u, r) for u, r in tm.requests(20, seed=3)]
    for (ua, ra), (ub, rb) in zip(a, b):
        assert ua == ub
        np.testing.assert_array_equal(ra["ids"], rb["ids"])
        np.testing.assert_array_equal(ra["dense"], rb["dense"])
    for _, r in a:
        assert r["ids"].shape == (F, DS.ids_per_field)
        assert r["ids"].max() < RPF
        assert (r["ids"] >= 0).any(axis=1).all()   # >= 1 id per field
    # same user, any stream: identical profile
    np.testing.assert_array_equal(tm.request_for(7)["ids"],
                                  tm.request_for(7)["ids"])


def test_traffic_is_zipf_skewed():
    tm = TrafficModel.for_dataset(DS, n_users=100_000)
    uids = tm.user_ids(5000, seed=1)
    top = np.sum(uids < 1000)          # top 1% of the user population
    assert top / len(uids) > 0.3       # carries a dominant traffic share
    assert len(np.unique(uids)) > 100  # but there IS a long tail


# ---------------------------------------------------------------------------
# click feedback
# ---------------------------------------------------------------------------

def test_click_model_matches_dataset_truth():
    click = ClickModel.for_dataset(DS)
    b = next(DS.sampler(32, seed=5))
    p = click.prob(b["ids"], b.get("dense"))
    assert p.shape == (32, CFG.n_tasks)
    assert np.all((p > 0) & (p < 1))
    truth = DS.truth()
    np.testing.assert_array_equal(p, truth.prob(b["ids"], b.get("dense")))
    lab = click.click({"ids": b["ids"][0], "dense": b["dense"][0]})
    assert lab.shape == (CFG.n_tasks,) and set(np.unique(lab)) <= {0.0, 1.0}


def test_feedback_queue_batches_and_starvation():
    fq = FeedbackQueue(batch_size=4)
    reqs = _requests(6)
    click = ClickModel.for_dataset(DS)
    assert fq.next_batch(timeout=0.02) is None      # starved
    for r in reqs:
        fq.put(r, click.click(r))
    batch = fq.next_batch(timeout=1.0)
    assert batch["ids"].shape == (4, F, DS.ids_per_field)
    assert batch["labels"].shape == (4, CFG.n_tasks)
    assert batch["dense"].shape == (4, DS.n_dense)
    assert len(fq) == 2
    assert fq.next_batch(timeout=0.02) is None      # only 2 left
    assert fq.stats["put"] == 6 and fq.stats["dropped"] == 0


def test_feedback_queue_drops_oldest_beyond_capacity():
    fq = FeedbackQueue(batch_size=2, capacity=4)
    for i in range(6):
        fq.put({"ids": np.full((F, 3), i, np.int32)},
               np.zeros(1, np.float32))
    assert fq.stats["dropped"] == 2
    batch = fq.next_batch(timeout=0.5)
    assert batch["ids"][0, 0, 0] == 2               # 0 and 1 were dropped


# ---------------------------------------------------------------------------
# the closed loop (satellite: feedback-loop end-to-end)
# ---------------------------------------------------------------------------

def _closed_loop_logloss(train: bool, steps=50, batch=16, seed=0):
    """Serve -> click -> (optionally train) for ``steps`` rounds; returns
    per-round logloss of the SERVED predictions. Deterministic: traffic,
    clicks and init share seeds, and serving flushes whole bursts."""
    trainer = _trainer("dense", mode=TrainMode.sync())
    tm = TrafficModel.for_dataset(DS, n_users=2000)
    click = ClickModel.for_dataset(DS)
    fq = FeedbackQueue(batch_size=batch)
    first = next(DS.sampler(batch, seed=seed))
    state = trainer.init(jax.random.PRNGKey(seed),
                         {k: jnp.asarray(v) for k, v in first.items()})
    cell = StateCell(state, 0)
    losses = []
    with ServingService(trainer, cell,
                        ServingConfig(max_batch=batch,
                                      max_wait_ms=100.0)) as svc:
        s = state
        for t in range(steps):
            reqs = [r for _, r in tm.requests(batch, seed=1000 + t)]
            preds = svc.predict_many(reqs)
            labels = np.stack([click.click(r) for r in reqs])
            p = np.clip(preds.astype(np.float64), 1e-7, 1 - 1e-7)
            losses.append(float(np.mean(
                -(labels * np.log(p) + (1 - labels) * np.log(1 - p)))))
            if train:
                fq.put_many(reqs, labels)
                fb = fq.next_batch(timeout=1.0)
                assert fb is not None
                b = {k: jnp.asarray(v) for k, v in fb.items()}
                with cell.lock:
                    s, _ = trainer.step(s, b)
                    cell.publish(s, t + 1)
    return np.asarray(losses)


def test_feedback_loop_beats_frozen_control():
    """50 closed-loop rounds: training on served click feedback must beat
    the frozen-model control on the same traffic and the same clicks."""
    online = _closed_loop_logloss(train=True)
    frozen = _closed_loop_logloss(train=False)
    # identical first round: no update has happened yet
    assert online[0] == frozen[0]
    tail = slice(len(online) // 2, None)
    assert online[tail].mean() < frozen[tail].mean() - 0.01, (
        f"online {online[tail].mean():.4f} not better than frozen "
        f"{frozen[tail].mean():.4f}")


def test_feedback_loop_is_deterministic():
    a = _closed_loop_logloss(train=True, steps=8)
    b = _closed_loop_logloss(train=True, steps=8)
    np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# flush-error isolation and stop-timeout (satellite regressions)
# ---------------------------------------------------------------------------

def test_flush_error_fails_request_but_keeps_loop_alive():
    """Regression: a malformed request used to kill the aggregator thread,
    wedging every later future forever. Now the flush resolves its futures
    with the exception, counts it, and keeps serving."""
    trainer = _trainer("dense", mode=TrainMode.sync())
    b = _batches(1)[0]
    state = trainer.init(jax.random.PRNGKey(0), b)
    cell = StateCell(state, 0)
    with ServingService(trainer, cell, ServingConfig(1, 0.0)) as svc:
        bad = svc.submit({"wrong": np.zeros(3, np.int64)})   # no "ids" key
        with pytest.raises(KeyError):
            bad.result(10.0)
        good = svc.predict(_requests(1)[0], timeout=30.0)    # loop survived
        m = svc.metrics()
    assert good.shape == (CFG.n_tasks,)
    assert m["serving/errors"] == 1.0
    assert m["serving/requests"] >= 1       # the good request still served


def test_stop_raises_instead_of_draining_live_queue():
    """Regression: stop() used to drain the queue while the aggregator was
    still wedged inside a flush, racing it for the same requests. Now a
    failed join raises ServingStopTimeout and leaves the queue alone."""
    trainer = _trainer("dense", mode=TrainMode.sync())
    b = _batches(1)[0]
    state = trainer.init(jax.random.PRNGKey(0), b)
    cell = StateCell(state, 0)
    svc = ServingService(trainer, cell,
                         ServingConfig(max_batch=1, max_wait_ms=0.0,
                                       timeout_s=0.3))
    svc.start()
    try:
        with cell.lock:                    # wedge the flush mid-snapshot
            fut = svc.submit(_requests(1)[0])
            deadline = time.monotonic() + 10.0
            while svc._queue and time.monotonic() < deadline:
                time.sleep(0.005)          # loop has taken the batch ...
            assert not svc._queue          # ... and is blocked on the lock
            with pytest.raises(ServingStopTimeout):
                svc.stop()
        # lock released: the wedged flush completes and resolves the future
        np.asarray(fut.result(10.0))
    finally:
        pass
