"""ShardedBackend router (core/backend.py): sharded vs single-shard bit
parity (dense AND host_lru), N->M reshard checkpoint round-trips
(row-exact for N, M in {1, 2, 4}), concurrent two-thread prepare bijection
under the per-shard locks, pinned-slot survival under the deep pipeline,
the hot-key load-imbalance gauge, and shard-mapping validation."""
import dataclasses
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import checkpoint_shard_layout
from repro.configs.base import ModelConfig
from repro.core import adapters
from repro.core import backend as BK
from repro.core.backend import (CompressedWireBackend, DenseBackend,
                                HostLRUBackend, ShardedBackend,
                                create_backend)
from repro.core.embedding_ps import EmbeddingSpec
from repro.core.hybrid import PersiaTrainer, TrainMode
from repro.core.pipeline import PipelinedTrainer
from repro.data.ctr import CTRDataset
from repro.optim.optimizers import OptConfig

F, RPF, D = 2, 64, 8       # fields x rows-per-field x dim

CFG = ModelConfig(name="sh", arch_type="recsys", n_id_fields=F,
                  ids_per_field=3, emb_dim=D, emb_rows=F * RPF,
                  n_dense_features=4, mlp_dims=(16,), n_tasks=1)
DS = CTRDataset("sh", n_rows=F * RPF, n_fields=F, ids_per_field=3, n_dense=4)


def _batches(n, batch=16, seed=None):
    it = DS.sampler(batch, seed=seed)
    return [{k: jnp.asarray(v) for k, v in next(it).items()}
            for _ in range(n)]


def _trainer(backend="dense", cache_rows=None, shards=1, tau=2):
    coll = adapters.ctr_collection(CFG, lr=5e-2, field_rows=DS.field_rows())
    if backend != "dense":
        coll = coll.with_backend(backend, cache_rows)
    if shards != 1:
        coll = coll.with_shards(shards)
    ad = adapters.recsys_adapter(CFG, field_rows=DS.field_rows(),
                                 collection=coll)
    return PersiaTrainer(ad, TrainMode.hybrid(tau),
                         OptConfig(kind="adam", lr=5e-3))


def _probe_all_rows(trainer, state, chunk=8):
    """Logical full-table view through each backend's own prepare+lookup
    path, chunked so small (per-shard) caches can stream it."""
    out = {}
    for n in trainer.collection.names:
        bk = trainer.backends[n]
        rows = []
        for lo in range(0, RPF, chunk):
            ids = jnp.arange(lo, min(lo + chunk, RPF), dtype=jnp.int32)
            st, dev = bk.prepare(state.emb[n], ids)
            state.emb = {**state.emb, n: st}
            acts, _ = bk.lookup(st, dev)
            rows.append(np.asarray(acts))
        out[n] = np.concatenate(rows)
    return out


# ---------------------------------------------------------------------------
# factory: shards=1 stays the plain backend, checkpoint bytes unchanged
# ---------------------------------------------------------------------------

def test_factory_shards1_is_plain_and_router_composes():
    spec = EmbeddingSpec(rows=64, dim=4, mode="full")
    assert isinstance(create_backend(spec), DenseBackend)
    assert isinstance(create_backend(
        dataclasses.replace(spec, emb_shards=4)), ShardedBackend)
    h = create_backend(dataclasses.replace(spec, backend="host_lru",
                                           cache_rows=16, emb_shards=2))
    assert isinstance(h, ShardedBackend)
    assert all(isinstance(s, HostLRUBackend) for s in h.shard_backends)
    # the wire wraps OUTSIDE the router (one wire per table)
    w = create_backend(dataclasses.replace(spec, backend="dense+compressed",
                                           emb_shards=2))
    assert isinstance(w, CompressedWireBackend)
    assert isinstance(w.inner, ShardedBackend)
    with pytest.raises(ValueError, match="shards"):
        ShardedBackend(spec, n_shards=1)
    from repro.core.collection import EmbeddingCollection
    with pytest.raises(ValueError, match="emb_shards"):
        EmbeddingCollection.single(
            "t", dataclasses.replace(spec, emb_shards=0))


def test_shards1_dense_checkpoint_bytes_unchanged(tmp_path):
    """emb_shards=1 must keep the plain dense path — including the exact
    bytes a checkpoint writes (the on-disk format is the compat surface)."""
    b = _batches(1)[0]
    ta = _trainer("dense")            # spec default emb_shards=1
    sa = ta.init(jax.random.PRNGKey(0), b)
    pa = ta.save(str(tmp_path / "a"), sa)
    tb = _trainer("dense")
    sb = tb.init(jax.random.PRNGKey(0), b)
    pb = tb.save(str(tmp_path / "b"), sb)
    raw_a = open(f"{pa}/emb/data.bin", "rb").read()
    raw_b = open(f"{pb}/emb/data.bin", "rb").read()
    assert raw_a == raw_b and len(raw_a) > 0


# ---------------------------------------------------------------------------
# bit parity: k shards == 1 shard, dense and host_lru, all pipelines
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend,cache", [("dense", None),
                                           ("host_lru", RPF)],
                         ids=["dense", "host_lru"])
def test_sharded_bit_parity_with_single_shard(backend, cache):
    """4-shard router == plain backend bit for bit: per-step losses, every
    logical table row, and eval — through both the decomposed and the
    fused pipeline. (Affine routing is a bijection and every row lives in
    exactly one shard, so the math must be identical.)"""
    batches = _batches(6)
    t1, t4 = _trainer(backend, cache), _trainer(backend, cache, shards=4)
    tf = _trainer(backend, cache, shards=4)
    s1 = t1.init(jax.random.PRNGKey(0), batches[0])
    s4 = t4.init(jax.random.PRNGKey(0), batches[0])
    sf = tf.init(jax.random.PRNGKey(0), batches[0])
    for b in batches:
        s1, m1 = t1.decomposed_step(s1, b)
        s4, m4 = t4.decomposed_step(s4, b)
        sf, _ = tf.step(sf, b)                       # fused path
        assert float(m1["loss"]) == float(m4["loss"])
    rows1, rows4 = _probe_all_rows(t1, s1), _probe_all_rows(t4, s4)
    rowsf = _probe_all_rows(tf, sf)
    for n in rows1:
        np.testing.assert_array_equal(rows1[n], rows4[n], err_msg=n)
        np.testing.assert_array_equal(rows1[n], rowsf[n], err_msg=n)
    np.testing.assert_allclose(float(t1.eval(s1, batches[0])["loss"]),
                               float(t4.eval(s4, batches[0])["loss"]))


def test_init_emb_shards_routes_host_backed_tables():
    """PersiaTrainer.init(emb_shards=k) used to raise for host_lru tables;
    it now routes them through the router (and keeps legacy dense
    semantics untouched)."""
    batches = _batches(3)
    tr = _trainer("host_lru", RPF)                  # spec emb_shards=1
    state = tr.init(jax.random.PRNGKey(0), batches[0], emb_shards=2)
    for n in tr.collection.names:
        assert isinstance(tr.backends[n], ShardedBackend)
        assert tr.backends[n].n_shards == 2
    for b in batches:
        state, m = tr.decomposed_step(state, b)
    assert np.isfinite(float(m["loss"]))
    # parity with a spec-sharded trainer: same routing, same numbers
    t2 = _trainer("host_lru", RPF, shards=2)
    s2 = t2.init(jax.random.PRNGKey(0), batches[0])
    for b in batches:
        s2, m2 = t2.decomposed_step(s2, b)
    assert float(m["loss"]) == float(m2["loss"])


# ---------------------------------------------------------------------------
# resharding checkpoints: N-shard save -> M-shard restore, row-exact
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend,cache", [("dense", None),
                                           ("host_lru", RPF // 2)],
                         ids=["dense", "host_lru"])
def test_reshard_checkpoint_roundtrip_row_exact(backend, cache, tmp_path):
    """Save with N shards, restore with M, for N, M in {1, 2, 4}: every
    logical row (including through host-store + device-cache overlay)
    comes back bit-exactly, the shard layout is inspectable on disk, and
    training continues."""
    batches = _batches(3, batch=8)
    for N in (1, 2, 4):
        tN = _trainer(backend, cache, shards=N)
        s = tN.init(jax.random.PRNGKey(0), batches[0])
        for b in batches:
            s, _ = tN.decomposed_step(s, b)
        rows_src = _probe_all_rows(tN, s)
        d = str(tmp_path / f"{backend}_n{N}")
        tN.save(d, s)
        assert all(v == N for v in checkpoint_shard_layout(d).values())
        for M in (1, 2, 4):
            tM = _trainer(backend, cache, shards=M)
            r = tM.restore(d)
            assert int(r.step) == 3
            rows_dst = _probe_all_rows(tM, r)
            for n in rows_src:
                np.testing.assert_array_equal(rows_src[n], rows_dst[n],
                                              err_msg=f"N={N} M={M} {n}")
            if N != M:          # resharded: queues restart empty (warmup)
                for n in tM.collection.names:
                    q = r.emb_queue[n]
                    leaf = q["ids"] if "ids" in q else q["s0"]["ids"]
                    assert int(np.asarray(leaf).max()) == -1
            r, m = tM.decomposed_step(r, batches[0])
            assert np.isfinite(float(m["loss"]))


def test_same_geometry_sharded_restore_is_bit_identical(tmp_path):
    """N == M restore is the non-reshard path: identical continuation,
    matching the plain backend's bit-exact resume contract."""
    batches = _batches(6, batch=8)
    mk = lambda: _trainer("host_lru", RPF // 2, shards=2)  # noqa: E731
    ta = mk()
    s = ta.init(jax.random.PRNGKey(0), batches[0])
    for b in batches[:3]:
        s, _ = ta.decomposed_step(s, b)
    ta.save(str(tmp_path), s)
    for b in batches[3:]:
        s, _ = ta.decomposed_step(s, b)
    tb = mk()
    r = tb.restore(str(tmp_path))
    for n in tb.collection.names:
        assert not BK.unwrap(tb.backends[n]).last_restore_resharded
    for b in batches[3:]:
        r, _ = tb.decomposed_step(r, b)
    rows_a, rows_b = _probe_all_rows(ta, s), _probe_all_rows(tb, r)
    for n in rows_a:
        np.testing.assert_array_equal(rows_a[n], rows_b[n], err_msg=n)


def test_reshard_rejects_cross_backend_and_row_mismatch(tmp_path):
    tr = _trainer("host_lru", RPF // 2, shards=2, tau=0)
    b = _batches(1, batch=8)[0]
    tr.save(str(tmp_path), tr.init(jax.random.PRNGKey(0), b))
    # a dense router cannot adopt a host_lru sharded checkpoint
    td = _trainer("dense", shards=4, tau=0)
    with pytest.raises(ValueError, match="backend"):
        td.restore(str(tmp_path))


# ---------------------------------------------------------------------------
# concurrency: two-thread prepare bijection under the per-shard locks
# ---------------------------------------------------------------------------

@pytest.mark.timeout(120)
def test_sharded_prepare_is_thread_safe():
    """Two threads hammering the router's concurrent prepare: every shard's
    slot bookkeeping must stay an exact bijection, and returned device ids
    must decode into their shard's slot range."""
    spec = EmbeddingSpec(rows=512, dim=4, mode="full", optimizer="sgd",
                         backend="host_lru", cache_rows=192, emb_shards=4)
    bk = create_backend(spec)
    state0 = bk.init(jax.random.PRNGKey(0))
    errors = []
    go = threading.Event()

    def hammer(seed):
        rng = np.random.default_rng(seed)
        go.wait()
        try:
            for _ in range(40):
                ids = rng.integers(0, spec.rows, 24)
                _, dev = bk.prepare(state0, ids)
                dev = np.asarray(dev)
                assert ((dev >= 0) & (dev < bk.dev_rows)).all()
        except Exception as e:   # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=hammer, args=(s,)) for s in (1, 2)]
    for t in threads:
        t.start()
    go.set()
    for t in threads:
        t.join(timeout=60)
    assert not errors, errors
    for s, sub in enumerate(bk.shard_backends):
        assert len(set(sub._slot_for_id.values())) == len(sub._slot_for_id)
        for k, slot in sub._slot_for_id.items():
            assert int(sub._id_for_slot[slot]) == k, (s, k)
        occupied = {int(x) for x in np.nonzero(sub._id_for_slot >= 0)[0]}
        assert occupied == set(sub._slot_for_id.values())


# ---------------------------------------------------------------------------
# pipelined execution over a sharded table
# ---------------------------------------------------------------------------

@pytest.mark.timeout(240)
def test_pipelined_inflight1_bit_exact_over_sharded_host_lru():
    batches = _batches(12)
    ta = _trainer("host_lru", RPF, shards=2)
    sa = ta.init(jax.random.PRNGKey(0), batches[0])
    sa, ms_a = ta.run(sa, batches)
    tb = _trainer("host_lru", RPF, shards=2)
    engine = PipelinedTrainer(tb, max_inflight=1)
    sb, ms_b = engine.run(tb.init(jax.random.PRNGKey(0), batches[0]),
                          batches)
    assert [float(m["loss"]) for m in ms_a] == \
        [float(m["loss"]) for m in ms_b]


@pytest.mark.timeout(240)
def test_deep_pipeline_pins_survive_sharded_eviction_pressure():
    """max_inflight > 1 over a sharded host_lru table with real eviction
    pressure: per-shard pins must keep every in-flight batch's rows
    resident (no wrong-row reads, no dropped puts), order preserved."""
    it = DS.sampler(4)
    batches = [{k: jnp.asarray(v) for k, v in next(it).items()}
               for _ in range(15)]
    tr = _trainer("host_lru", RPF // 2, shards=2, tau=2)
    engine = PipelinedTrainer(tr, max_inflight=3)
    state = engine.init(jax.random.PRNGKey(0), batches[0])
    state, ms = engine.run(state, batches)
    assert len(ms) == 15
    assert engine.applied_order == list(range(15))
    assert all(np.isfinite(float(m["loss"])) for m in ms)
    # hybrid sharded tables charge EVERY shard's window, so the per-table
    # outstanding-puts bound min(max_inflight, tau) must still hold — the
    # staleness-contract regression for per-shard backpressure
    for n, v in engine.max_outstanding.items():
        assert v <= min(3, 2), (n, v)
    faults = sum(int(s.faults)
                 for n in tr.collection.names
                 for s in BK.unwrap(tr.backends[n]).shard_backends)
    assert faults > 0


# ---------------------------------------------------------------------------
# hot-key skew: the load-imbalance gauge fires
# ---------------------------------------------------------------------------

def test_hot_key_skew_fires_imbalance_gauge():
    """90% of the id traffic hammering one key must land on one shard and
    push max/mean traffic well above 1 — the gauge that makes hot-key skew
    visible in step metrics."""
    tr = _trainer("host_lru", RPF, shards=4, tau=0)
    rng = np.random.default_rng(0)
    B, L = 16, 3

    def skewed_batch():
        ids = rng.integers(0, RPF, (B, F, L))
        hot = rng.random((B, F, L)) < 0.9
        ids = np.where(hot, 7, ids)
        return {"ids": jnp.asarray(ids, jnp.int32),
                "dense": jnp.asarray(rng.standard_normal((B, 4)),
                                     jnp.float32),
                "labels": jnp.asarray(rng.random((B, 1)) < 0.3,
                                      jnp.float32)}

    state = tr.init(jax.random.PRNGKey(0), skewed_batch())
    for _ in range(4):
        state, m = tr.decomposed_step(state, skewed_batch())
    gauges = {k: float(v) for k, v in m.items() if k.endswith("imbalance")}
    assert gauges and all(v > 2.0 for v in gauges.values()), gauges
    # per-shard gauges are present for every shard
    name = tr.collection.names[0]
    for s in range(4):
        assert f"shard/{name}/{s}/hit_rate" in m
        assert f"shard/{name}/{s}/faults" in m
        assert f"shard/{name}/{s}/rows" in m
        assert f"shard/{name}/{s}/bytes" in m
    # a balanced stream keeps the gauge near 1
    tb = _trainer("host_lru", RPF, shards=4, tau=0)
    bs = _batches(5, batch=16)
    sb = tb.init(jax.random.PRNGKey(0), bs[0])
    for b in bs:
        sb, mb = tb.decomposed_step(sb, b)
    assert all(float(v) < 2.0 for k, v in mb.items()
               if k.endswith("imbalance"))


# ---------------------------------------------------------------------------
# shard-mapping validation (typo'd table names must fail loudly)
# ---------------------------------------------------------------------------

def test_shard_mapping_validates_table_names():
    coll = adapters.ctr_collection(CFG, lr=5e-2, field_rows=DS.field_rows())
    with pytest.raises(ValueError, match="unknown tables"):
        coll.with_shards({"field_typo": 4})
    with pytest.raises(ValueError, match="unknown tables"):
        coll.init(jax.random.PRNGKey(0), shards={"field_typo": 4})
    with pytest.raises(ValueError, match=">= 1"):
        coll.with_shards({"field_00": 0})
    tr = _trainer("host_lru", RPF)
    with pytest.raises(ValueError, match="unknown tables"):
        tr.init(jax.random.PRNGKey(0), _batches(1)[0],
                emb_shards={"field_typo": 2})
    # a valid mapping shards only the named table
    tr2 = _trainer("host_lru", RPF)
    tr2.init(jax.random.PRNGKey(0), _batches(1)[0],
             emb_shards={"field_00": 2})
    assert isinstance(tr2.backends["field_00"], ShardedBackend)
    assert isinstance(tr2.backends["field_01"], HostLRUBackend)
