"""Frequency-aware multi-tier embedding cache (ROADMAP item 1): the
decayed count-min admission sketch, the bypass/promotion slot mechanics,
the ``+disk`` mmap tier's bit-parity with the two-tier backend, checkpoint
round-trips (same-format, cross-format, and old pre-admission blobs), and
the pipeline prefetch stage's determinism contract."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import adapters
from repro.core.backend import create_backend, parse_backend_name
from repro.core.embedding_ps import EmbeddingSpec
from repro.core.hotness import HotnessSketch
from repro.core.hybrid import PersiaTrainer, TrainMode
from repro.core.pipeline import PipelinedTrainer
from repro.data.ctr import CTRDataset
from repro.optim.optimizers import OptConfig

ROWS, DIM = 512, 8
CACHE, BYPASS = 32, 8


def _backend(backend="host_lru", cache_rows=CACHE, **kw):
    spec = EmbeddingSpec(rows=ROWS, dim=DIM, backend=backend,
                         cache_rows=cache_rows, **kw)
    bk = create_backend(spec)
    return bk, bk.init(jax.random.PRNGKey(0))


def _admission(**kw):
    return _backend(admit_threshold=1.5, bypass_rows=BYPASS, **kw)


# ---------------------------------------------------------------------------
# the hotness sketch
# ---------------------------------------------------------------------------

def test_sketch_counts_occurrences_and_decays():
    sk = HotnessSketch(width=1024, depth=4, decay=0.5, decay_every=10**6)
    sk.update(np.array([3, 7]), counts=np.array([5.0, 1.0]))
    est = sk.estimate(np.array([3, 7, 9, -1]))
    assert est[0] >= 5.0 and est[1] >= 1.0      # count-min: upper bounds
    assert est[3] == 0.0                        # negatives estimate cold
    # decay forgets stale hotness: a once-hot id falls below any threshold
    for _ in range(6):
        sk.age()
    assert sk.estimate(np.array([3]))[0] < 0.1


def test_sketch_serialize_roundtrip_preserves_estimates():
    sk = HotnessSketch(width=256, depth=3, decay=0.5, decay_every=4, seed=9)
    rng = np.random.default_rng(0)
    for _ in range(7):
        sk.update(rng.integers(0, 100, 20))
    back = HotnessSketch.deserialize(sk.serialize())
    probe = np.arange(120)
    np.testing.assert_array_equal(back.estimate(probe), sk.estimate(probe))
    assert back.updates == sk.updates
    # identical future trajectory (same decay phase, same hashes)
    sk.update(np.array([5]))
    back.update(np.array([5]))
    np.testing.assert_array_equal(back.estimate(probe), sk.estimate(probe))


# ---------------------------------------------------------------------------
# admission: bypass slots, promotion, scan resistance
# ---------------------------------------------------------------------------

def test_admission_geometry_and_bypass_then_promote():
    bk, state = _admission()
    assert bk.dev_slots == CACHE + BYPASS
    assert np.asarray(state["table"]).shape == (CACHE + BYPASS, DIM)
    ids = np.arange(4)
    # first sight: estimate 1 < threshold -> served from the bypass region
    state, dev = bk.prepare(state, ids)
    assert np.all(np.asarray(dev) >= CACHE)
    assert bk.cache_metrics() == {"admit": 0.0, "bypass": 4.0,
                                  "promote": 0.0}
    # second sight: estimate 2 >= threshold -> promoted into the main cache
    state, dev = bk.prepare(state, ids)
    assert np.all((np.asarray(dev) >= 0) & (np.asarray(dev) < CACHE))
    assert bk.cache_metrics()["promote"] == 4.0
    assert bk.promotes == 4


def test_once_seen_cold_ids_never_evict_hot_residents():
    bk, state = _admission()
    hot = np.arange(16)
    for _ in range(3):                     # warm: bypassed, then promoted
        state, _ = bk.prepare(state, hot)
    hot_slots = bk._slot_arr[hot].copy()
    assert np.all((hot_slots >= 0) & (hot_slots < CACHE))
    faults_before = bk.faults
    for i in range(5):                     # five distinct one-touch scans
        cold = 100 + BYPASS * i + np.arange(BYPASS)
        state, dev = bk.prepare(state, cold)
        assert np.all(np.asarray(dev) >= CACHE)     # all served from bypass
    np.testing.assert_array_equal(bk._slot_arr[hot], hot_slots)
    state, _ = bk.prepare(state, hot)      # pure hits: no fault, no move
    assert bk.faults == faults_before + 5 * BYPASS
    np.testing.assert_array_equal(bk._slot_arr[hot], hot_slots)


def test_cold_burst_overflows_bypass_into_main():
    """A cold burst wider than the bypass region must still be served —
    the overflow claims main slots instead of raising or dropping."""
    bk, state = _admission()
    burst = 200 + np.arange(BYPASS + 6)
    state, dev = bk.prepare(state, burst)
    dev = np.asarray(dev)
    assert np.all(dev >= 0)
    assert bk.last_bypass == BYPASS and bk.last_admit == 6
    # every id got a distinct slot and the translation is consistent
    assert np.unique(dev).size == burst.size


def test_admission_off_keeps_plain_geometry():
    bk, state = _backend()                 # admit_threshold = 0
    assert bk.dev_slots == CACHE and bk.bypass_rows == 0
    assert bk._sketch is None
    assert np.asarray(state["table"]).shape == (CACHE, DIM)
    assert bk.cache_metrics() == {}


# ---------------------------------------------------------------------------
# the +disk tier
# ---------------------------------------------------------------------------

def test_parse_backend_name_disk_grammar():
    assert parse_backend_name("host_lru+disk") == ("host_lru+disk", False)
    assert parse_backend_name("host_lru+disk+compressed") == \
        ("host_lru+disk", True)
    with pytest.raises(ValueError, match="only stacks under"):
        parse_backend_name("dense+disk")
    with pytest.raises(ValueError, match="unknown backend decorator"):
        parse_backend_name("host_lru+ssd")


def test_three_tier_faults_bit_equal_to_two_tier(tmp_path):
    """The disk tier changes where cold rows live, never what they hold:
    the same fault stream returns identical slots and identical values,
    while the tiered store genuinely spills and promotes."""
    bk2, s2 = _backend("host_lru")
    bk3, s3 = _backend("host_lru+disk", host_rows=64,
                       disk_path=str(tmp_path / "tier"))
    rng = np.random.default_rng(3)
    for _ in range(12):
        ids = rng.integers(0, ROWS, (4, 6))
        s2, d2 = bk2.prepare(s2, ids)
        s3, d3 = bk3.prepare(s3, ids)
        np.testing.assert_array_equal(np.asarray(d2), np.asarray(d3))
        a2, _ = bk2.lookup(s2, d2)
        a3, _ = bk3.lookup(s3, d3)
        np.testing.assert_array_equal(np.asarray(a2), np.asarray(a3))
    assert bk2.faults == bk3.faults
    assert bk3.store.spills > 0            # host tier really evicted
    assert bk3.store.promotions > 0        # and disk rows really faulted up


@pytest.mark.parametrize("backend,extra", [
    ("host_lru", {}),
    ("host_lru+disk", {"host_rows": 64}),
], ids=["two_tier", "three_tier"])
def test_checkpoint_roundtrip_resumes_bit_identically(tmp_path, backend,
                                                      extra):
    if backend.endswith("disk"):
        extra = dict(extra, disk_path=str(tmp_path / "a"))
    bk, state = _admission(backend=backend, **extra)
    rng = np.random.default_rng(1)
    for _ in range(6):
        state, dev = bk.prepare(state, rng.integers(0, ROWS, 12))
        state = bk.apply_put(
            state, dev,
            jnp.asarray(rng.standard_normal((12, DIM)), jnp.float32))[0]
    blob = bk.state_for_checkpoint(state)
    assert ("hotness" in blob["cache_meta"])          # sketch rides along
    assert ("disk" in blob["store"]) == backend.endswith("disk")

    extra2 = dict(extra)
    if backend.endswith("disk"):
        extra2["disk_path"] = str(tmp_path / "b")
    bk2, _ = _admission(backend=backend, **extra2)
    state2 = bk2.restore_from_checkpoint(blob)
    for k in state:
        np.testing.assert_array_equal(np.asarray(state[k]),
                                      np.asarray(state2[k]))
    assert (bk2.faults, bk2.admits, bk2.bypasses, bk2.promotes) == \
        (bk.faults, bk.admits, bk.bypasses, bk.promotes)
    # the two resume on the same trajectory: same stream -> same slots,
    # same admission decisions, same values
    for _ in range(4):
        ids = rng.integers(0, ROWS, 12)
        state, d1 = bk.prepare(state, ids)
        state2, d2 = bk2.prepare(state2, ids)
        np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))
        np.testing.assert_array_equal(np.asarray(state["table"]),
                                      np.asarray(state2["table"]))


def test_old_pre_admission_checkpoint_restores():
    """A blob written before the admission counters existed carries 4
    scalars and no hotness sub-blob; it must restore into a plain
    (admission-off) backend with the new counters zeroed."""
    bk, state = _backend()
    rng = np.random.default_rng(2)
    for _ in range(4):
        state, _ = bk.prepare(state, rng.integers(0, ROWS, 10))
    blob = bk.state_for_checkpoint(state)
    blob["cache_meta"]["scalars"] = blob["cache_meta"]["scalars"][:4]
    blob["cache_meta"].pop("hotness", None)
    bk2, _ = _backend()
    state2 = bk2.restore_from_checkpoint(blob)
    assert (bk2._tick, bk2.faults, bk2.hits) == \
        (bk._tick, bk.faults, bk.hits)
    assert bk2.admits == bk2.bypasses == bk2.promotes == 0
    state2, dev = bk2.prepare(state2, np.arange(6))
    assert np.all(np.asarray(dev) >= 0)


@pytest.mark.parametrize("src,dst", [
    ("host_lru", "host_lru+disk"),
    ("host_lru+disk", "host_lru"),
], ids=["two_into_three", "three_into_two"])
def test_cross_format_restore_is_row_exact(tmp_path, src, dst):
    """Restoring a two-tier blob into a +disk backend (or the reverse)
    rebuilds the configured hierarchy from the blob's logical rows."""
    def kw(name, tag):
        return ({"host_rows": 64, "disk_path": str(tmp_path / tag)}
                if name.endswith("disk") else {})

    bk, state = _backend(src, **kw(src, "src"))
    rng = np.random.default_rng(4)
    for _ in range(6):
        state, dev = bk.prepare(state, rng.integers(0, ROWS, 12))
        state = bk.apply_put(
            state, dev,
            jnp.asarray(rng.standard_normal((12, DIM)), jnp.float32))[0]
    blob = bk.state_for_checkpoint(state)
    bk2, _ = _backend(dst, **kw(dst, "dst"))
    state2 = bk2.restore_from_checkpoint(blob)
    # chunked: a full-table read must fit the 64-row host tier per call
    for lo in range(0, ROWS, 32):
        ids = np.arange(lo, lo + 32)
        want, _ = bk.read_rows(state, ids)
        got, _ = bk2.read_rows(state2, ids)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# pipeline prefetch
# ---------------------------------------------------------------------------

F, RPF = 3, 128
CFG = ModelConfig(name="ct", arch_type="recsys", n_id_fields=F,
                  ids_per_field=3, emb_dim=DIM, emb_rows=F * RPF,
                  n_dense_features=4, mlp_dims=(16,), n_tasks=1)
DS = CTRDataset("ct", n_rows=F * RPF, n_fields=F, ids_per_field=3, n_dense=4)


def _trainer(backend="host_lru", cache_rows=RPF):
    coll = adapters.ctr_collection(CFG, lr=5e-2, field_rows=DS.field_rows())
    coll = coll.with_backend(backend, cache_rows)
    ad = adapters.recsys_adapter(CFG, field_rows=DS.field_rows(),
                                 collection=coll)
    return PersiaTrainer(ad, TrainMode.hybrid(3),
                         OptConfig(kind="adam", lr=5e-3))


def _batches(n, batch=32, seed=0):
    it = DS.sampler(batch, seed=seed)
    return [{k: jnp.asarray(v) for k, v in next(it).items()}
            for _ in range(n)]


@pytest.mark.timeout(240)
def test_prefetch_bit_exact_with_serial_at_inflight_1():
    """prefetch=2 at max_inflight=1 with an eviction-free cache: the
    look-ahead fault-in changes WHEN rows fault, not which rows or what
    the step computes — the run equals the serial trainer bit for bit."""
    batches = _batches(20)
    ta = _trainer()
    sa = ta.init(jax.random.PRNGKey(0), batches[0])
    sa, ms_a = ta.run(sa, batches)
    tb = _trainer()
    engine = PipelinedTrainer(tb, max_inflight=1, prefetch=2)
    sb, ms_b = engine.run(tb.init(jax.random.PRNGKey(0), batches[0]),
                          batches)
    assert [float(m["loss"]) for m in ms_a] == \
        [float(m["loss"]) for m in ms_b]
    for n in sa.emb:
        np.testing.assert_array_equal(np.asarray(sa.emb[n]["table"]),
                                      np.asarray(sb.emb[n]["table"]))
        np.testing.assert_array_equal(np.asarray(sa.emb[n]["acc"]),
                                      np.asarray(sb.emb[n]["acc"]))
    for a, b in zip(jax.tree.leaves(sa.dense), jax.tree.leaves(sb.dense)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    pm = engine.pipeline_metrics()
    assert pm["pipeline/prefetch/items"] == 20.0
    assert pm["pipeline/prepare/busy_s"] <= pm["pipeline/prefetch/busy_s"]


@pytest.mark.timeout(240)
def test_prefetch_deep_pipeline_is_lossless_and_learns(tmp_path):
    """prefetch over the full three-tier stack at max_inflight > 1: all
    puts applied in order, pins released, losses finite."""
    coll = adapters.ctr_collection(CFG, lr=5e-2, field_rows=DS.field_rows())
    coll = coll.with_backend("host_lru+disk", RPF)
    # one mmap directory per table: the store writes fixed file names
    coll = coll.map_specs(lambda n, s: dataclasses.replace(
        s, host_rows=64, disk_path=str(tmp_path / n)))
    ad = adapters.recsys_adapter(CFG, field_rows=DS.field_rows(),
                                 collection=coll)
    tr = PersiaTrainer(ad, TrainMode.hybrid(3),
                       OptConfig(kind="adam", lr=5e-3))
    engine = PipelinedTrainer(tr, max_inflight=3, prefetch=2)
    batches = _batches(12)
    state = engine.init(jax.random.PRNGKey(0), batches[0])
    state, ms = engine.run(state, batches)
    assert len(ms) == 12
    assert engine.applied_order == list(range(12))
    assert all(np.isfinite(float(m["loss"])) for m in ms)
    for bk in tr.backends.values():
        assert int(np.asarray(bk._pin_count).sum()) == 0


def test_prefetch_rejects_negative():
    with pytest.raises(ValueError, match="prefetch"):
        PipelinedTrainer(_trainer(), max_inflight=1, prefetch=-1)
