"""Multi-device correctness of every shard_map path, run in a subprocess
with 8 forced host devices (the main test process keeps 1 device).

Checks sharded == single-device oracle for: embedding PS lookup/put (both
modes), MoE expert parallelism, and the distributed decode attention.
"""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P, NamedSharding

    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)

    from repro.core import embedding_ps as PS
    from repro.models.moe import moe_init, moe_forward
    from repro.configs.base import ModelConfig, BlockCfg

    # ---- embedding PS: model mode ----------------------------------------
    spec = PS.EmbeddingSpec(rows=64, dim=16, mode="model", optimizer="sgd",
                            lr=0.5)
    st = PS.ps_init(jax.random.PRNGKey(0), spec, n_shards=4)
    ids = jnp.asarray(np.random.default_rng(0).integers(-1, 64, (8, 6)),
                      jnp.int32)
    local = PS.lookup(st, spec, ids)                 # no-mesh oracle
    g = jnp.asarray(np.random.default_rng(1)
                    .standard_normal((48, 16)).astype(np.float32))
    st_after_local = PS.apply_put(st, spec, ids.reshape(-1), g)
    with jax.sharding.set_mesh(mesh):
        st_sh = jax.device_put(st, {"table": NamedSharding(mesh, P("model", None))}["table"]) \
            if False else jax.tree.map(lambda x: x, st)
        out = jax.jit(lambda s, i: PS.lookup(s, spec, i))(st, ids)
        st2 = jax.jit(lambda s, i, gg: PS.apply_put(s, spec, i, gg))(
            st, ids.reshape(-1), g)
    np.testing.assert_allclose(np.asarray(out), np.asarray(local), atol=1e-5)
    np.testing.assert_allclose(np.asarray(st2["table"]),
                               np.asarray(st_after_local["table"]), atol=1e-4)
    print("PS model-mode OK")

    # ---- embedding PS: full mode ------------------------------------------
    spec_f = PS.EmbeddingSpec(rows=128, dim=8, mode="full",
                              optimizer="adagrad", lr=0.3)
    stf = PS.ps_init(jax.random.PRNGKey(1), spec_f, n_shards=8)
    idsf = jnp.asarray(np.random.default_rng(2).integers(-1, 128, (16, 4)),
                       jnp.int32)
    gf = jnp.asarray(np.random.default_rng(3)
                     .standard_normal((64, 8)).astype(np.float32))
    local_out = PS.lookup(stf, spec_f, idsf)
    local_put = PS.apply_put(stf, spec_f, idsf.reshape(-1), gf)
    with jax.sharding.set_mesh(mesh):
        outf = jax.jit(lambda s, i: PS.lookup(s, spec_f, i))(stf, idsf)
        stf2 = jax.jit(lambda s, i, gg: PS.apply_put(s, spec_f, i, gg))(
            stf, idsf.reshape(-1), gf)
    np.testing.assert_allclose(np.asarray(outf), np.asarray(local_out),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(stf2["table"]),
                               np.asarray(local_put["table"]), atol=1e-4)
    print("PS full-mode OK")

    # ---- MoE expert parallelism --------------------------------------------
    cfg = ModelConfig(name="m", d_model=32, d_ff=64, n_experts=8,
                      moe_top_k=2, moe_d_ff=64, n_shared_experts=1,
                      capacity_factor=8.0)
    p = moe_init(jax.random.PRNGKey(2), cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 8, 32))
    out_local, aux_local = moe_forward(p, cfg, x)
    with jax.sharding.set_mesh(mesh):
        out_sh, aux_sh = jax.jit(lambda p_, x_: moe_forward(p_, cfg, x_))(p, x)
    np.testing.assert_allclose(np.asarray(out_sh), np.asarray(out_local),
                               atol=2e-5)
    # balance loss is a nonlinear per-shard statistic pmean'd over shards —
    # close to, but not bit-equal with, the global statistic
    np.testing.assert_allclose(float(aux_sh["moe_balance"]),
                               float(aux_local["moe_balance"]), atol=0.05)
    print("MoE OK")

    # ---- MoE all-to-all dispatch == psum dispatch == local -------------------
    import repro.models.moe as MOE
    with jax.sharding.set_mesh(mesh):
        MOE.MOE_DISPATCH = "a2a"
        out_a2a, _ = jax.jit(lambda p_, x_: moe_forward(p_, cfg, x_))(p, x)
        MOE.MOE_DISPATCH = "psum"
    np.testing.assert_allclose(np.asarray(out_a2a), np.asarray(out_local),
                               atol=2e-5)
    ga = jax.jit(jax.grad(
        lambda p_, x_: jnp.sum(moe_forward(p_, cfg, x_)[0] ** 2)))(p, x)
    assert all(bool(jnp.isfinite(t).all()) for t in jax.tree.leaves(ga))
    print("MoE a2a OK")

    # ---- distributed decode attention ---------------------------------------
    from repro.models import layers as L
    cfg_a = ModelConfig(name="a", d_model=64, n_heads=4, n_kv_heads=2,
                        head_dim=16, d_ff=128, vocab_size=64)
    pa = L.gqa_init(jax.random.PRNGKey(4), cfg_a, jnp.float32)
    B, CAP = 4, 32
    cache = L.gqa_cache_init(cfg_a, B, CAP, jnp.float32)
    # pre-fill 7 tokens via local decode (no mesh)
    xs = jax.random.normal(jax.random.PRNGKey(5), (B, 8, 64)) * 0.5
    c_local = cache
    for t in range(8):
        o_local, c_local = L.gqa_decode(pa, cfg_a, xs[:, t:t+1], c_local)
    # same under the mesh (seq-sharded dist path; CAP=32 divisible by 4)
    with jax.sharding.set_mesh(mesh):
        c_sh = cache
        step = jax.jit(lambda p_, x_, c_: L.gqa_decode(p_, cfg_a, x_, c_))
        for t in range(8):
            o_sh, c_sh = step(pa, xs[:, t:t+1], c_sh)
    np.testing.assert_allclose(np.asarray(o_sh), np.asarray(o_local),
                               atol=3e-5)
    np.testing.assert_allclose(np.asarray(c_sh["len"]),
                               np.asarray(c_local["len"]))
    print("dist decode OK")
    print("ALL_OK")
""")


def _run_dist_script(tmp_path, script_text, ok_marker):
    import jax.sharding
    if not (hasattr(jax.sharding, "set_mesh")
            and hasattr(jax.sharding, "AxisType")):
        pytest.skip("installed jax lacks sharding.set_mesh/AxisType "
                    "(needed by the multi-device shard_map paths)")
    script = tmp_path / "dist_check.py"
    script.write_text(script_text)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=560)
    assert ok_marker in res.stdout, res.stdout + "\n" + res.stderr[-3000:]


@pytest.mark.timeout(600)
def test_sharded_paths_match_single_device(tmp_path):
    _run_dist_script(tmp_path, SCRIPT, "ALL_OK")


# ---------------------------------------------------------------------------
# pipeline under a mesh: the threaded engine must match the serial trainer
# when both run with 8 forced host devices and an active global mesh
# ---------------------------------------------------------------------------

SCRIPT_PIPELINE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np

    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)

    from repro.configs.base import ModelConfig
    from repro.core import adapters
    from repro.core.hybrid import PersiaTrainer, TrainMode
    from repro.core.pipeline import PipelinedTrainer
    from repro.data.ctr import CTRDataset
    from repro.optim.optimizers import OptConfig

    CFG = ModelConfig(name="pm", arch_type="recsys", n_id_fields=3,
                      ids_per_field=2, emb_dim=8, emb_rows=192,
                      n_dense_features=4, mlp_dims=(16,), n_tasks=1)
    DS = CTRDataset("pm", n_rows=192, n_fields=3, ids_per_field=2, n_dense=4)
    it = DS.sampler(32)
    batches = [{k: jnp.asarray(v) for k, v in next(it).items()}
               for _ in range(8)]

    def make():
        ad = adapters.recsys_adapter(CFG, lr=5e-2,
                                     field_rows=DS.field_rows())
        return PersiaTrainer(ad, TrainMode.hybrid(2),
                             OptConfig(kind="adam", lr=5e-3))

    with jax.sharding.set_mesh(mesh):
        ta = make()
        sa = ta.init(jax.random.PRNGKey(0), batches[0])
        sa, ms_a = ta.run(sa, batches)
        tb = make()
        engine = PipelinedTrainer(tb, max_inflight=1)
        sb, ms_b = engine.run(tb.init(jax.random.PRNGKey(0), batches[0]),
                              batches)
        # a deeper pipeline must also run to completion under the mesh
        tc = make()
        deep = PipelinedTrainer(tc, max_inflight=3)
        sc, ms_c = deep.run(tc.init(jax.random.PRNGKey(0), batches[0]),
                            batches)
    assert len(ms_b) == len(ms_a) == len(ms_c) == 8
    for n in sa.emb:
        np.testing.assert_allclose(np.asarray(sa.emb[n]["table"]),
                                   np.asarray(sb.emb[n]["table"]),
                                   atol=1e-5, err_msg=n)
    for a, b in zip(jax.tree.leaves(sa.dense), jax.tree.leaves(sb.dense)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    assert all(np.isfinite(float(m["loss"])) for m in ms_c)
    assert deep.applied_order == list(range(8))
    print("PIPE_MESH_OK")
""")


@pytest.mark.timeout(600)
def test_pipeline_under_mesh_matches_serial(tmp_path):
    """The pipelined engine's worker threads dispatch against the same
    global mesh the serial facade sees: max_inflight=1 parity and a deep
    in-order run, both with 8 forced host devices."""
    _run_dist_script(tmp_path, SCRIPT_PIPELINE, "PIPE_MESH_OK")
