import os
import socket
import sys

import pytest

# tests see the real single CPU device (the 512-device override is ONLY for
# the dry-run); keep test jit cache warm across files.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture
def free_port():
    """OS-assigned free TCP port (bind port 0, read it back, release).

    The small race between release and reuse is why the PS servers
    themselves bind port 0 and publish the result; this fixture is for
    tests that must know a port BEFORE the server exists (e.g. dialing an
    endpoint that is guaranteed dead)."""

    def _get() -> int:
        with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
            s.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
            s.bind(("127.0.0.1", 0))
            return s.getsockname()[1]

    return _get
