import os
import sys

# tests see the real single CPU device (the 512-device override is ONLY for
# the dry-run); keep test jit cache warm across files.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
