"""Hybrid trainer semantics: tau=0 == sync bit-exact, async dense delay,
convergence ordering on the synthetic CTR task (paper §6.2 qualitative)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import adapters, embedding_ps as PS, hybrid
from repro.core.hybrid import TrainMode
from repro.data.ctr import CTRDataset
from repro.optim.optimizers import OptConfig, make_optimizer

CFG = ModelConfig(name="t", arch_type="recsys", n_id_fields=4,
                  ids_per_field=3, emb_dim=16, emb_rows=512,
                  n_dense_features=4, mlp_dims=(32, 16), n_tasks=1)
DS = CTRDataset("t", n_rows=512, n_fields=4, ids_per_field=3, n_dense=4)


def _run(mode, n_steps=25, seed=0):
    adapter = adapters.recsys_adapter(CFG, lr=5e-2)
    opt_init, opt_update = make_optimizer(OptConfig(kind="adam", lr=5e-3))
    it = DS.sampler(128, seed=seed)
    batch = {k: jnp.asarray(v) for k, v in next(it).items()}
    state, spec = hybrid.init_train_state(adapter, mode, opt_init,
                                          jax.random.PRNGKey(0), batch)
    step = jax.jit(hybrid.make_train_step(adapter, spec, mode, opt_update))
    losses = []
    for _ in range(n_steps):
        b = {k: jnp.asarray(v) for k, v in next(it).items()}
        state, m = step(state, b)
        losses.append(float(m["loss"]))
    return state, losses


def test_hybrid_tau0_equals_sync_exactly():
    s1, l1 = _run(TrainMode("hybrid", 0, 0))
    s2, l2 = _run(TrainMode.sync())
    np.testing.assert_allclose(l1, l2, rtol=0)
    for a, b in zip(jax.tree.leaves(s1["dense"]), jax.tree.leaves(s2["dense"])):
        np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(s1["emb"]["table"], s2["emb"]["table"])


def test_all_modes_learn():
    for mode in [TrainMode.sync(), TrainMode.hybrid(3), TrainMode.async_(3, 3)]:
        _, losses = _run(mode, n_steps=40)
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.02, \
            (mode.name, losses[:5], losses[-5:])


def test_hybrid_close_to_sync_async_worse():
    """Qualitative Table 2: |hybrid - sync| small; async trails."""
    _, ls = _run(TrainMode.sync(), n_steps=60)
    _, lh = _run(TrainMode.hybrid(3), n_steps=60)
    _, la = _run(TrainMode.async_(5, 5), n_steps=60)
    s, h, a = (np.mean(x[-10:]) for x in (ls, lh, la))
    assert abs(h - s) < 0.05
    assert a >= s - 0.01


def test_emb_grads_flow_through_queue():
    """After tau warmup steps the table must have changed."""
    adapter = adapters.recsys_adapter(CFG, lr=5e-2)
    opt_init, opt_update = make_optimizer(OptConfig(kind="adam", lr=5e-3))
    it = DS.sampler(64)
    batch = {k: jnp.asarray(v) for k, v in next(it).items()}
    mode = TrainMode.hybrid(2)
    state, spec = hybrid.init_train_state(adapter, mode, opt_init,
                                          jax.random.PRNGKey(0), batch)
    t0 = state["emb"]["table"].copy()
    step = jax.jit(hybrid.make_train_step(adapter, spec, mode, opt_update))
    state, _ = step(state, batch)
    state, _ = step(state, batch)
    assert jnp.all(state["emb"]["table"] == t0)        # still queued
    state, _ = step(state, batch)
    assert not jnp.all(state["emb"]["table"] == t0)    # first put applied


def test_decomposed_matches_fused():
    """The decomposed (3-dispatch, donated) pipeline computes the same
    updates as the fused train step."""
    adapter = adapters.recsys_adapter(CFG, lr=5e-2)
    opt_init, opt_update = make_optimizer(OptConfig(kind="adam", lr=5e-3))
    mode = TrainMode.hybrid(2)
    it = DS.sampler(64)
    batches = [{k: jnp.asarray(v) for k, v in next(it).items()}
               for _ in range(6)]
    s1, spec = hybrid.init_train_state(adapter, mode, opt_init,
                                       jax.random.PRNGKey(0), batches[0])
    s2, _ = hybrid.init_train_state(adapter, mode, opt_init,
                                    jax.random.PRNGKey(0), batches[0])
    fused = jax.jit(hybrid.make_train_step(adapter, spec, mode, opt_update))
    fns = hybrid.make_decomposed_fns(adapter, spec, mode, opt_update)
    for b in batches:
        s1, m1 = fused(s1, b)
        s2, m2 = hybrid.decomposed_train_step(fns, s2, b, adapter)
    np.testing.assert_allclose(np.asarray(s1["emb"]["table"]),
                               np.asarray(s2["emb"]["table"]), atol=1e-5)
    for a, b_ in zip(jax.tree.leaves(s1["dense"]),
                     jax.tree.leaves(s2["dense"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=1e-5)


def test_eval_step_runs():
    adapter = adapters.recsys_adapter(CFG)
    opt_init, _ = make_optimizer(OptConfig())
    it = DS.sampler(32)
    batch = {k: jnp.asarray(v) for k, v in next(it).items()}
    state, spec = hybrid.init_train_state(adapter, TrainMode.sync(), opt_init,
                                          jax.random.PRNGKey(0), batch)
    ev = jax.jit(hybrid.make_eval_step(adapter, spec))
    m = ev(state, batch)
    assert np.isfinite(float(m["loss"]))


def test_auc_metric():
    labels = np.array([1, 0, 1, 0, 1])
    assert adapters.auc(labels, np.array([.9, .1, .8, .2, .7])) == 1.0
    assert adapters.auc(labels, np.array([.1, .9, .2, .8, .3])) == 0.0
    assert abs(adapters.auc(labels, np.full(5, 0.5)) - 0.5) < 1e-9
