"""Hybrid trainer semantics through the PersiaTrainer facade: tau=0 == sync
bit-exact, async dense delay, convergence ordering on the synthetic CTR task
(paper §6.2 qualitative). The CTR model trains one embedding table per ID
feature field (the multi-table EmbeddingCollection path)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import adapters, hybrid
from repro.core.hybrid import PersiaTrainer, TrainMode
from repro.data.ctr import CTRDataset
from repro.optim.optimizers import OptConfig

CFG = ModelConfig(name="t", arch_type="recsys", n_id_fields=4,
                  ids_per_field=3, emb_dim=16, emb_rows=512,
                  n_dense_features=4, mlp_dims=(32, 16), n_tasks=1)
DS = CTRDataset("t", n_rows=512, n_fields=4, ids_per_field=3, n_dense=4)


def _trainer(mode):
    adapter = adapters.recsys_adapter(CFG, lr=5e-2)
    return PersiaTrainer(adapter, mode, OptConfig(kind="adam", lr=5e-3))


def _run(mode, n_steps=25, seed=0):
    trainer = _trainer(mode)
    it = DS.sampler(128, seed=seed)
    batch = {k: jnp.asarray(v) for k, v in next(it).items()}
    state = trainer.init(jax.random.PRNGKey(0), batch)
    losses = []
    for _ in range(n_steps):
        b = {k: jnp.asarray(v) for k, v in next(it).items()}
        state, m = trainer.step(state, b)
        losses.append(float(m["loss"]))
    return state, losses


def _tables(state):
    return {n: np.asarray(st["table"]) for n, st in state.emb.items()}


def test_hybrid_tau0_equals_sync_exactly():
    s1, l1 = _run(TrainMode("hybrid", 0, 0))
    s2, l2 = _run(TrainMode.sync())
    np.testing.assert_allclose(l1, l2, rtol=0)
    for a, b in zip(jax.tree.leaves(s1.dense), jax.tree.leaves(s2.dense)):
        np.testing.assert_array_equal(a, b)
    t1, t2 = _tables(s1), _tables(s2)
    assert set(t1) == set(t2) and len(t1) == CFG.n_id_fields
    for n in t1:
        np.testing.assert_array_equal(t1[n], t2[n])


def test_all_modes_learn():
    for mode in [TrainMode.sync(), TrainMode.hybrid(3), TrainMode.async_(3, 3)]:
        _, losses = _run(mode, n_steps=40)
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.02, \
            (mode.name, losses[:5], losses[-5:])


def test_hybrid_close_to_sync_async_worse():
    """Qualitative Table 2: |hybrid - sync| small; async trails."""
    _, ls = _run(TrainMode.sync(), n_steps=60)
    _, lh = _run(TrainMode.hybrid(3), n_steps=60)
    _, la = _run(TrainMode.async_(5, 5), n_steps=60)
    s, h, a = (np.mean(x[-10:]) for x in (ls, lh, la))
    assert abs(h - s) < 0.05
    assert a >= s - 0.01


def test_emb_grads_flow_through_queue():
    """After tau warmup steps every table must have changed."""
    trainer = _trainer(TrainMode.hybrid(2))
    it = DS.sampler(64)
    batch = {k: jnp.asarray(v) for k, v in next(it).items()}
    state = trainer.init(jax.random.PRNGKey(0), batch)
    t0 = _tables(state)
    step = jax.jit(trainer.train_step)         # no donation: t0 stays alive
    state, _ = step(state, batch)
    state, _ = step(state, batch)
    for n, t in _tables(state).items():
        assert np.array_equal(t, t0[n]), n     # still queued
    state, _ = step(state, batch)
    for n, t in _tables(state).items():
        assert not np.array_equal(t, t0[n]), n  # first put applied


@pytest.mark.parametrize("mode", [TrainMode.hybrid(2),
                                  TrainMode.async_(2, 2)],
                         ids=["hybrid", "async"])
def test_decomposed_matches_fused(mode):
    """The decomposed (3-dispatch, donated) pipeline computes the same
    updates as the fused train step — including the async dense-delay
    queue."""
    it = DS.sampler(64)
    batches = [{k: jnp.asarray(v) for k, v in next(it).items()}
               for _ in range(6)]
    trainer = _trainer(mode)
    s1 = trainer.init(jax.random.PRNGKey(0), batches[0])
    s2 = trainer.init(jax.random.PRNGKey(0), batches[0])
    for b in batches:
        s1, m1 = trainer.step(s1, b)
        s2, m2 = trainer.decomposed_step(s2, b)
    assert set(m1) == set(m2)          # same metric schema in both pipelines
    t1, t2 = _tables(s1), _tables(s2)
    for n in t1:
        np.testing.assert_allclose(t1[n], t2[n], atol=1e-5)
    for a, b_ in zip(jax.tree.leaves(s1.dense), jax.tree.leaves(s2.dense)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=1e-5)
    if mode.dense_staleness > 0:
        for a, b_ in zip(jax.tree.leaves(s1.dense_queue),
                         jax.tree.leaves(s2.dense_queue)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       atol=1e-5)


def test_eval_step_runs():
    trainer = _trainer(TrainMode.sync())
    it = DS.sampler(32)
    batch = {k: jnp.asarray(v) for k, v in next(it).items()}
    state = trainer.init(jax.random.PRNGKey(0), batch)
    m = trainer.eval(state, batch)
    assert np.isfinite(float(m["loss"]))


def test_legacy_free_functions_reject_multi_table():
    """The pre-collection shims only serve single-table adapters."""
    adapter = adapters.recsys_adapter(CFG)
    with pytest.raises(ValueError, match="PersiaTrainer"):
        hybrid.init_train_state(adapter, TrainMode.sync(), lambda p: {},
                                jax.random.PRNGKey(0))


def test_auc_metric():
    labels = np.array([1, 0, 1, 0, 1])
    assert adapters.auc(labels, np.array([.9, .1, .8, .2, .7])) == 1.0
    assert adapters.auc(labels, np.array([.1, .9, .2, .8, .3])) == 0.0
    assert abs(adapters.auc(labels, np.full(5, 0.5)) - 0.5) < 1e-9
