"""EmbeddingCollection + PersiaTrainer semantics.

* multi-table lookup/update parity against an equivalent single flat table
  (per-field tables are a partition of one big id space);
* heterogeneous per-table (rows, dim, optimizer, staleness) end-to-end
  training in both fused and decomposed modes;
* full-state checkpoint round-trip: resumed training is bit-identical to an
  uninterrupted run, including the adagrad accumulators and queues.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import adapters, embedding_ps as PS
from repro.core.collection import EmbeddingCollection
from repro.core.embedding_ps import EmbeddingSpec
from repro.core.hybrid import PersiaTrainer, TrainMode
from repro.data.ctr import CTRDataset
from repro.optim.optimizers import OptConfig

F, R, D = 4, 64, 8          # fields x rows-per-field x dim


def _uniform_collection(optimizer="sgd", lr=0.5, staleness=0):
    return EmbeddingCollection.from_dict({
        f"f{i}": EmbeddingSpec(rows=R, dim=D, optimizer=optimizer, lr=lr,
                               staleness=staleness)
        for i in range(F)})


def test_collection_registry_basics():
    coll = _uniform_collection()
    assert coll.names == ("f0", "f1", "f2", "f3")
    assert len(coll) == F and "f2" in coll
    assert coll["f1"].rows == R
    assert coll.total_rows == F * R
    assert coll.total_params == F * R * D
    taued = coll.with_staleness(5)
    assert all(s.staleness == 5 for _, s in taued.items())
    with pytest.raises(KeyError):
        coll["nope"]
    states = coll.init(jax.random.PRNGKey(0))
    with pytest.raises(KeyError):
        coll.lookup(states, {"ghost": jnp.zeros((2,), jnp.int32)})


def test_collection_rejects_codec_hostile_names():
    spec = EmbeddingSpec(rows=8, dim=4)
    for bad in ("", "a/b", "0", "42"):
        with pytest.raises(ValueError, match="table name"):
            EmbeddingCollection.single(bad, spec)
    with pytest.raises(ValueError, match="duplicate"):
        EmbeddingCollection((("a", spec), ("a", spec)))


def test_init_requires_batch_example_for_stale_modes():
    adapter = adapters.recsys_adapter(HET_CFG, collection=HET)
    trainer = PersiaTrainer(adapter, TrainMode.hybrid(3))
    with pytest.raises(ValueError, match="batch_example"):
        trainer.init(jax.random.PRNGKey(0))
    # fully synchronous trainers can still init without a batch
    sync = PersiaTrainer(adapter, TrainMode.sync())
    state = sync.init(jax.random.PRNGKey(0))
    assert all(q is None for q in state.emb_queue.values())


def _flat_equivalent(field_states):
    """Build the single flat table holding the same row values: global id
    i*R + j lands where the flat uniform shuffle puts it."""
    flat_spec = EmbeddingSpec(rows=F * R, dim=D, optimizer="sgd", lr=0.5)
    table = np.zeros((F * R, D), np.float32)
    for i, st in enumerate(field_states.values()):
        gpos = np.asarray(PS.shuffle_pos(jnp.arange(R) + i * R, F * R))
        lpos = np.asarray(PS.shuffle_pos(jnp.arange(R), R))
        table[gpos] = np.asarray(st["table"])[lpos]
    return flat_spec, {"table": jnp.asarray(table)}


def test_multi_table_lookup_parity_with_flat_table():
    coll = _uniform_collection()
    states = coll.init(jax.random.PRNGKey(7))
    flat_spec, flat_state = _flat_equivalent(states)

    rng = np.random.default_rng(0)
    ids = rng.integers(-1, R, (16, F, 3)).astype(np.int32)
    per_field = {f"f{i}": jnp.asarray(ids[:, i]) for i in range(F)}
    acts = coll.lookup(states, per_field)

    offs = (np.arange(F) * R)[None, :, None]
    flat_ids = np.where(ids >= 0, ids + offs, -1).astype(np.int32)
    flat_acts = PS.lookup(flat_state, flat_spec, jnp.asarray(flat_ids))

    for i in range(F):
        np.testing.assert_allclose(np.asarray(acts[f"f{i}"]),
                                   np.asarray(flat_acts[:, i]), atol=1e-6)


def test_multi_table_update_parity_with_flat_table():
    coll = _uniform_collection()
    states = coll.init(jax.random.PRNGKey(7))
    flat_spec, flat_state = _flat_equivalent(states)

    rng = np.random.default_rng(1)
    ids = rng.integers(-1, R, (8, F, 3)).astype(np.int32)
    grads = rng.standard_normal((8, F, 3, D)).astype(np.float32)
    per_field_ids = {f"f{i}": jnp.asarray(ids[:, i]) for i in range(F)}
    per_field_g = {f"f{i}": jnp.asarray(grads[:, i]) for i in range(F)}
    new_states = coll.apply_put(states, per_field_ids, per_field_g)

    offs = (np.arange(F) * R)[None, :, None]
    flat_ids = np.where(ids >= 0, ids + offs, -1).astype(np.int32)
    new_flat = PS.apply_put(flat_state, flat_spec,
                            jnp.asarray(flat_ids).reshape(-1),
                            jnp.asarray(grads).reshape(-1, D))

    # every row of every field must match the flat table's updated row
    probe = {f"f{i}": jnp.arange(R, dtype=jnp.int32) for i in range(F)}
    after = coll.lookup(new_states, probe)
    flat_probe = jnp.asarray(
        np.concatenate([np.arange(R) + i * R for i in range(F)])
        .astype(np.int32))
    flat_after = PS.lookup(new_flat, flat_spec, flat_probe)
    for i in range(F):
        np.testing.assert_allclose(np.asarray(after[f"f{i}"]),
                                   np.asarray(flat_after[i * R:(i + 1) * R]),
                                   atol=1e-5)


# ---------------------------------------------------------------------------
# heterogeneous tables end-to-end (the acceptance scenario)
# ---------------------------------------------------------------------------

HET = EmbeddingCollection.from_dict({
    "user": EmbeddingSpec(rows=128, dim=16, optimizer="adagrad", lr=5e-2,
                          staleness=0),
    "item": EmbeddingSpec(rows=64, dim=8, optimizer="sgd", lr=1e-2,
                          staleness=2),
    "ctx": EmbeddingSpec(rows=32, dim=4, optimizer="adagrad", lr=5e-2,
                         staleness=4),
})
HET_CFG = ModelConfig(name="het", arch_type="recsys", n_id_fields=3,
                      ids_per_field=3, emb_dim=0, emb_rows=0,
                      n_dense_features=4, mlp_dims=(32, 16), n_tasks=1)


def _het_batches(n, batch=32, seed=0):
    rng = np.random.default_rng(seed)
    rows = [HET[n_].rows for n_ in HET.names]
    out = []
    for _ in range(n):
        ids = np.stack([rng.integers(-1, r, (batch, 3)) for r in rows],
                       axis=1).astype(np.int32)
        out.append({
            "ids": jnp.asarray(ids),
            "dense": jnp.asarray(rng.standard_normal((batch, 4))
                                 .astype(np.float32)),
            "labels": jnp.asarray((rng.random((batch, 1)) < 0.3)
                                  .astype(np.float32)),
        })
    return out


def _het_trainer():
    adapter = adapters.recsys_adapter(HET_CFG, collection=HET)
    return PersiaTrainer(adapter, TrainMode.hybrid(1),
                         OptConfig(kind="adam", lr=5e-3),
                         per_table_staleness=True)


def test_train_and_eval_paths_agree_on_unsorted_names():
    """Regression: jax re-sorts dict pytrees at jit/grad flatten boundaries,
    so the multi-table concat order must not depend on dict insertion order
    (HET's names are deliberately not lexicographically sorted)."""
    trainer = _het_trainer()
    b = _het_batches(1, seed=11)[0]
    state = trainer.init(jax.random.PRNGKey(2), b)
    m_eval = trainer.eval(state, b)                    # eval path (no grad)
    _, m_train = jax.jit(trainer.train_step)(state, b)  # grad path
    np.testing.assert_allclose(float(m_eval["loss"]),
                               float(m_train["loss"]), rtol=1e-6)
    preds = trainer.predict(state, b)
    assert np.isfinite(np.asarray(preds)).all()


def test_heterogeneous_tables_train_fused_and_decomposed():
    trainer = _het_trainer()
    # per-table staleness survives the trainer (no mode-wide override)
    assert [trainer.collection[n].staleness for n in HET.names] == [0, 2, 4]
    batches = _het_batches(7)
    s_f = trainer.init(jax.random.PRNGKey(0), batches[0])
    s_d = trainer.init(jax.random.PRNGKey(0), batches[0])
    assert s_f.emb_queue["user"] is None          # sync table: no queue
    assert s_f.emb_queue["item"]["ids"].shape[0] == 2
    assert s_f.emb_queue["ctx"]["ids"].shape[0] == 4
    t0 = {n: np.asarray(st["table"]) for n, st in s_f.emb.items()}

    for b in batches:
        s_f, m_f = trainer.step(s_f, b)
        s_d, m_d = trainer.decomposed_step(s_d, b)
    assert np.isfinite(float(m_f["loss"]))
    # fused == decomposed on every table and the dense stack
    for n in HET.names:
        np.testing.assert_allclose(np.asarray(s_f.emb[n]["table"]),
                                   np.asarray(s_d.emb[n]["table"]),
                                   atol=1e-5)
    for a, b_ in zip(jax.tree.leaves(s_f.dense), jax.tree.leaves(s_d.dense)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=1e-5)
    # every table learned (7 steps > max tau)
    for n in HET.names:
        assert not np.array_equal(np.asarray(s_f.emb[n]["table"]), t0[n]), n


def test_heterogeneous_staleness_delays_per_table():
    trainer = _het_trainer()
    batches = _het_batches(5, seed=3)
    state = trainer.init(jax.random.PRNGKey(1), batches[0])
    t0 = {n: np.asarray(st["table"]) for n, st in state.emb.items()}
    step = jax.jit(trainer.train_step)
    state, _ = step(state, batches[0])
    # tau=0 applies immediately; tau=2 and tau=4 still queued
    assert not np.array_equal(np.asarray(state.emb["user"]["table"]),
                              t0["user"])
    assert np.array_equal(np.asarray(state.emb["item"]["table"]), t0["item"])
    assert np.array_equal(np.asarray(state.emb["ctx"]["table"]), t0["ctx"])
    state, _ = step(state, batches[1])
    state, _ = step(state, batches[2])
    assert not np.array_equal(np.asarray(state.emb["item"]["table"]),
                              t0["item"])          # tau=2 put arrived
    assert np.array_equal(np.asarray(state.emb["ctx"]["table"]), t0["ctx"])


# ---------------------------------------------------------------------------
# checkpoint: save -> restore -> continue == uninterrupted, bit for bit
# ---------------------------------------------------------------------------

def _flatten_named(state):
    flat, _ = jax.tree_util.tree_flatten_with_path(state)
    return {jax.tree_util.keystr(p): np.asarray(x) for p, x in flat}


def test_checkpoint_resume_bit_identical(tmp_path):
    cfg = ModelConfig(name="ck", arch_type="recsys", n_id_fields=4,
                      ids_per_field=3, emb_dim=16, emb_rows=512,
                      n_dense_features=4, mlp_dims=(32, 16), n_tasks=1)
    ds = CTRDataset("ck", n_rows=512, n_fields=4, ids_per_field=3, n_dense=4)
    it = ds.sampler(64)
    batches = [{k: jnp.asarray(v) for k, v in next(it).items()}
               for _ in range(9)]

    def make_trainer():
        adapter = adapters.recsys_adapter(cfg, lr=5e-2)
        return PersiaTrainer(adapter, TrainMode.hybrid(2),
                             OptConfig(kind="adam", lr=5e-3))

    # uninterrupted run: 5 + 4 steps
    tr_a = make_trainer()
    state = tr_a.init(jax.random.PRNGKey(0), batches[0])
    for b in batches[:5]:
        state, _ = tr_a.step(state, b)
    tr_a.save(str(tmp_path), state)
    for b in batches[5:]:
        state, _ = tr_a.step(state, b)

    # interrupted run: restore the step-5 snapshot with a FRESH trainer
    tr_b = make_trainer()
    resumed = tr_b.restore(str(tmp_path))
    assert int(resumed.step) == 5
    # the snapshot carries the adagrad accumulators and queue contents
    assert "acc" in resumed.emb["field_00"]
    assert resumed.emb_queue["field_00"] is not None
    for b in batches[5:]:
        resumed, _ = tr_b.step(resumed, b)

    fa, fb = _flatten_named(state), _flatten_named(resumed)
    assert set(fa) == set(fb)
    for k in fa:
        np.testing.assert_array_equal(fa[k], fb[k], err_msg=k)


def test_restore_rejects_legacy_and_mismatched_checkpoints(tmp_path):
    from repro.checkpoint import save_checkpoint
    adapter = adapters.recsys_adapter(HET_CFG, collection=HET)
    trainer = PersiaTrainer(adapter, TrainMode.sync())
    # legacy checkpoint: raw dense tree, no per-table embedding blob
    save_checkpoint(str(tmp_path / "legacy"), 3, {"w": np.zeros(2)})
    with pytest.raises(ValueError, match="full-state"):
        trainer.restore(str(tmp_path / "legacy"))
    # full-state checkpoint from a different collection: table-name mismatch
    other = adapters.recsys_adapter(
        HET_CFG.replace(n_id_fields=2, emb_rows=64, emb_dim=8))
    tr2 = PersiaTrainer(other, TrainMode.sync())
    b = {"ids": jnp.zeros((4, 2, 3), jnp.int32),
         "dense": jnp.zeros((4, 4)), "labels": jnp.zeros((4, 1))}
    tr2.save(str(tmp_path / "other"), tr2.init(jax.random.PRNGKey(0), b))
    with pytest.raises(ValueError, match="do not match"):
        trainer.restore(str(tmp_path / "other"))
    # same names but a grown table: shape validation catches it
    bigger = HET.map_specs(
        lambda n, s: dataclasses.replace(s, rows=s.rows * 2))
    tr3 = PersiaTrainer(adapters.recsys_adapter(HET_CFG, collection=bigger),
                        TrainMode.sync())
    trainer.save(str(tmp_path / "small"),
                 trainer.init(jax.random.PRNGKey(0), _het_batches(1)[0]))
    with pytest.raises(ValueError, match="collection changed"):
        tr3.restore(str(tmp_path / "small"))
    # sync checkpoint into a tau>0 trainer: queue/mode mismatch is refused
    tr_tau = _het_trainer()          # per-table staleness 0/2/4
    with pytest.raises(ValueError, match="staleness"):
        tr_tau.restore(str(tmp_path / "small"))
    # sync checkpoint into an async trainer: dense-queue mismatch is refused
    tr_async = PersiaTrainer(adapter, TrainMode.async_(0, 2))
    with pytest.raises(ValueError, match="tau_d"):
        tr_async.restore(str(tmp_path / "small"))


def test_ctr_dataset_emits_per_field_local_ids():
    ds = CTRDataset("loc", n_rows=1000, n_fields=8, ids_per_field=4,
                    n_dense=2)
    b = next(ds.sampler(256))
    ids = b["ids"]
    assert ids.shape == (256, 8, 4)
    live = ids[ids >= 0]
    assert live.max() < ds.rows_per_field
    assert ds.field_rows() == (125,) * 8
