"""Multi-process embedding PS (repro/net): training over RemoteBackend /
RemoteShardedBackend against threaded PS servers — bit-exactness with the
in-process backends across sync/hybrid/async x dense/host_lru, the
pipelined engine at max_inflight=1, the lossy wire vs CompressedWireBackend,
checkpoint byte-compat both directions, heartbeat failure detection, and
elastic kill -> reshard -> join membership changes."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import adapters
from repro.core.embedding_ps import EmbeddingSpec
from repro.core.hybrid import PersiaTrainer, TrainMode
from repro.core.pipeline import PipelinedTrainer
from repro.data.ctr import CTRDataset
from repro.net import (ClusterDeadError, ElasticPSCluster, PSMember,
                       PSUnavailableError, RemoteBackend,
                       RemoteShardedBackend, connect_remote_backends,
                       is_ps_failure)
from repro.net.ps_server import PSServer, read_spool
from repro.optim.optimizers import OptConfig

F, RPF, D = 2, 64, 8

CFG = ModelConfig(name="rps", arch_type="recsys", n_id_fields=F,
                  ids_per_field=3, emb_dim=D, emb_rows=F * RPF,
                  n_dense_features=4, mlp_dims=(16,), n_tasks=1)
DS = CTRDataset("rps", n_rows=F * RPF, n_fields=F, ids_per_field=3,
                n_dense=4)


def _batches(n, batch=16, seed=0):
    it = DS.sampler(batch, seed=seed)
    return [{k: jnp.asarray(v) for k, v in next(it).items()}
            for _ in range(n)]


def _trainer(backend="dense", cache_rows=None, mode=None, tau=2):
    coll = adapters.ctr_collection(CFG, lr=5e-2, field_rows=DS.field_rows())
    if backend != "dense":
        coll = coll.with_backend(backend, cache_rows)
    ad = adapters.recsys_adapter(CFG, field_rows=DS.field_rows(),
                                 collection=coll)
    return PersiaTrainer(ad, mode or TrainMode.hybrid(tau),
                         OptConfig(kind="adam", lr=5e-3))


@pytest.fixture
def servers():
    """Threaded PS servers with per-server spool dirs; killed/stopped at
    teardown."""
    started = []

    def make(n, spool_root=None):
        for i in range(n):
            sd = None
            if spool_root is not None:
                sd = os.path.join(str(spool_root), f"ps{i}")
            started.append(PSServer(spool_dir=sd).start())
        return started[-n:]

    yield make
    for s in started:
        s.stop()


def _endpoints(srvs):
    return [("127.0.0.1", s.port) for s in srvs]


def _probe_all_rows(trainer, state, chunk=8):
    out = {}
    for n in trainer.collection.names:
        bk = trainer.backends[n]
        rows = []
        for lo in range(0, RPF, chunk):
            ids = jnp.arange(lo, min(lo + chunk, RPF), dtype=jnp.int32)
            st, dev = bk.prepare(state.emb[n], ids)
            state.emb = {**state.emb, n: st}
            acts, _ = bk.lookup(st, dev)
            rows.append(np.asarray(acts))
        out[n] = np.concatenate(rows)
    return out


def _run(trainer, batches, endpoints=None, lossy=None):
    if endpoints is not None:
        connect_remote_backends(trainer, endpoints, lossy=lossy)
    state = trainer.init(jax.random.PRNGKey(0), batches[0])
    metrics = {}
    for b in batches:
        state, metrics = trainer.decomposed_step(state, b)
    return state, metrics


# ---------------------------------------------------------------------------
# bit-exactness: remote == in-process, per mode x backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", [TrainMode.sync(), TrainMode.hybrid(2),
                                  TrainMode.async_(2, 2)],
                         ids=["sync", "hybrid", "async"])
@pytest.mark.parametrize("backend,cache", [("dense", None),
                                           ("host_lru", 48)])
def test_remote_training_bit_exact(servers, mode, backend, cache):
    bs = _batches(3)
    t_ref = _trainer(backend, cache, mode=mode)
    ref, m_ref = _run(t_ref, bs)
    t = _trainer(backend, cache, mode=mode)
    st, m = _run(t, bs, endpoints=_endpoints(servers(2)))
    assert np.float32(m["loss"]) == np.float32(m_ref["loss"])
    assert jax.tree.all(jax.tree.map(
        lambda a, b: np.array_equal(np.asarray(a), np.asarray(b)),
        st.dense, ref.dense))
    # the full logical tables agree row for row
    rows_ref = _probe_all_rows(t_ref, ref)
    rows = _probe_all_rows(t, st)
    for n in rows:
        np.testing.assert_array_equal(rows[n], rows_ref[n])


def test_remote_pipelined_inflight1_bit_exact(servers):
    bs = _batches(4)
    t0 = _trainer("host_lru", 48)
    s0 = t0.init(jax.random.PRNGKey(0), bs[0])
    s0, ms0 = PipelinedTrainer(t0, max_inflight=1).run(s0, iter(bs))
    t1 = _trainer("host_lru", 48)
    connect_remote_backends(t1, _endpoints(servers(2)))
    s1 = t1.init(jax.random.PRNGKey(0), bs[0])
    s1, ms1 = PipelinedTrainer(t1, max_inflight=1).run(s1, iter(bs))
    assert np.float32(ms1[-1]["loss"]) == np.float32(ms0[-1]["loss"])


def test_remote_sharded_matches_inprocess_sharded(servers):
    bs = _batches(3)
    coll = adapters.ctr_collection(CFG, lr=5e-2, field_rows=DS.field_rows())
    ad = adapters.recsys_adapter(CFG, field_rows=DS.field_rows(),
                                 collection=coll.with_shards(2))
    t0 = PersiaTrainer(ad, TrainMode.hybrid(2), OptConfig(kind="adam",
                                                          lr=5e-3))
    s0, m0 = _run(t0, bs)
    t1 = _trainer("dense")
    s1, m1 = _run(t1, bs, endpoints=_endpoints(servers(2)))
    assert np.float32(m1["loss"]) == np.float32(m0["loss"])


def test_remote_lossy_single_endpoint_matches_compressed_wire(servers):
    bs = _batches(3)
    _, m0 = _run(_trainer("dense+compressed"), bs)
    t1 = _trainer("dense+compressed")      # suffix selects the lossy wire
    (ep,) = _endpoints(servers(1))
    _, m1 = _run(t1, bs, endpoints=[ep])
    assert isinstance(t1.backends[t1.collection.names[0]], RemoteBackend)
    assert np.float32(m1["loss"]) == np.float32(m0["loss"])
    # and the lossy wire differs from the raw one (it really compressed)
    t2 = _trainer("dense")
    _, m2 = _run(t2, bs, endpoints=[ep])
    assert np.float32(m2["loss"]) != np.float32(m1["loss"])


# ---------------------------------------------------------------------------
# checkpoints: remote <-> in-process byte compatibility
# ---------------------------------------------------------------------------

def test_remote_checkpoint_restores_in_process_and_back(servers, tmp_path):
    from repro.checkpoint.ckpt import checkpoint_shard_layout
    bs = _batches(3)
    t0 = _trainer("dense")
    s0, _ = _run(t0, bs, endpoints=_endpoints(servers(2)))
    t0.save(str(tmp_path / "remote_ck"), s0)
    assert checkpoint_shard_layout(str(tmp_path / "remote_ck")) == \
        {n: 2 for n in t0.collection.names}
    # a shard-tagged remote checkpoint restores into an IN-PROCESS trainer
    coll = adapters.ctr_collection(CFG, lr=5e-2, field_rows=DS.field_rows())
    ad = adapters.recsys_adapter(CFG, field_rows=DS.field_rows(),
                                 collection=coll.with_shards(2))
    t1 = PersiaTrainer(ad, TrainMode.hybrid(2), OptConfig(kind="adam",
                                                          lr=5e-3))
    t1.init(jax.random.PRNGKey(1), bs[0])
    s1 = t1.restore(str(tmp_path / "remote_ck"))
    # reference: the same run fully in process
    t2 = PersiaTrainer(
        adapters.recsys_adapter(CFG, field_rows=DS.field_rows(),
                                collection=coll.with_shards(2)),
        TrainMode.hybrid(2), OptConfig(kind="adam", lr=5e-3))
    s2, _ = _run(t2, bs)
    rows1, rows2 = _probe_all_rows(t1, s1), _probe_all_rows(t2, s2)
    for n in rows1:
        np.testing.assert_array_equal(rows1[n], rows2[n])
    # ... and an in-process checkpoint restores into a REMOTE trainer
    t2.save(str(tmp_path / "local_ck"), s2)
    t3 = _trainer("dense")
    connect_remote_backends(t3, _endpoints(servers(2)))
    t3.init(jax.random.PRNGKey(2), bs[0])
    s3 = t3.restore(str(tmp_path / "local_ck"))
    rows3 = _probe_all_rows(t3, s3)
    for n in rows3:
        np.testing.assert_array_equal(rows3[n], rows2[n])


# ---------------------------------------------------------------------------
# validation / failure classification
# ---------------------------------------------------------------------------

def test_remote_backend_validation(servers):
    (srv,) = servers(1)
    spec = EmbeddingSpec(rows=64, dim=8)
    with pytest.raises(ValueError, match="lossy"):
        RemoteBackend(dataclasses.replace(spec, backend="dense+compressed"),
                      ("127.0.0.1", srv.port))
    with pytest.raises(ValueError, match="RemoteShardedBackend"):
        RemoteBackend(dataclasses.replace(spec, emb_shards=2),
                      ("127.0.0.1", srv.port))
    coll3 = adapters.ctr_collection(
        CFG, lr=5e-2, field_rows=DS.field_rows()).with_shards(3)
    ad3 = adapters.recsys_adapter(CFG, field_rows=DS.field_rows(),
                                  collection=coll3)
    t = PersiaTrainer(ad3, TrainMode.hybrid(2),
                      OptConfig(kind="adam", lr=5e-3))
    with pytest.raises(ValueError, match="emb_shards=3"):
        connect_remote_backends(t, _endpoints([srv]))


def test_unavailable_is_named_and_classified(free_port):
    spec = EmbeddingSpec(rows=64, dim=8)
    with pytest.raises(PSUnavailableError) as ei:
        RemoteBackend(spec, ("127.0.0.1", free_port()), timeout=0.3,
                      retries=1, backoff=0.01)
    assert is_ps_failure(ei.value)
    # ... including when wrapped the way XLA callback errors surface
    wrapped = RuntimeError(f"callback failed: {ei.value!r}")
    assert is_ps_failure(wrapped)
    assert not is_ps_failure(ValueError("unrelated"))


# ---------------------------------------------------------------------------
# heartbeats + elastic membership
# ---------------------------------------------------------------------------

def test_heartbeat_detects_killed_server(servers):
    from repro.net.elastic import HeartbeatMonitor
    srvs = servers(2)
    mon = HeartbeatMonitor(_endpoints(srvs), interval=0.05,
                           miss_threshold=2, ping_timeout=0.3)
    assert mon.probe_once() == set()
    srvs[1].kill()
    dead = set()
    for _ in range(4):
        dead = mon.probe_once()
    assert dead == {("127.0.0.1", srvs[1].port)}
    assert any(e["kind"] == "dead" for e in mon.events)


def test_elastic_kill_reshard_join(servers, tmp_path):
    srvs = servers(3, spool_root=tmp_path)
    members = [PSMember("127.0.0.1", s.port, spool_dir=s.spool_dir)
               for s in srvs]
    bs = _batches(6)
    t = _trainer("host_lru", 48)
    cluster = ElasticPSCluster(t, members, max_recoveries=2,
                               ping_timeout=0.5)
    cluster.connect(timeout=1.0, retries=1, backoff=0.05)
    state = t.init(jax.random.PRNGKey(0), bs[0])
    for b in bs[:2]:
        state, _ = cluster.step(state, b)
    # the spool holds every APPLIED put: the kill loses at most in-flight
    assert read_spool(srvs[0].spool_dir, t.collection.names[0]) is not None
    srvs[1].kill()
    for b in bs[2:4]:
        state, m = cluster.step(state, b)
    assert len(cluster.members) == 2
    resh = [e for e in cluster.events if e["kind"] == "reshard"]
    assert resh and resh[0]["dead"] == [1]
    assert all(v == 0 for v in resh[0]["lost_rows"].values())
    assert np.isfinite(float(m["loss"]))
    # elastic JOIN: a fresh member grows the shard set back to 3
    new = PSServer(spool_dir=str(tmp_path / "ps_new")).start()
    srvs.append(new)            # the fixture variable keeps teardown simple
    state = cluster.join(PSMember("127.0.0.1", new.port,
                                  spool_dir=str(tmp_path / "ps_new")), state)
    assert len(cluster.members) == 3
    for name in t.collection.names:
        assert t.backends[name].n_shards == 3
    for b in bs[4:]:
        state, m = cluster.step(state, b)
    assert np.isfinite(float(m["loss"]))


def test_midwindow_shard_kill_reshards_without_losing_acked_puts(
        servers, tmp_path):
    """Kill a shard with windowed puts still in flight (hybrid tau=3 ->
    put_window=3, acks outstanding across steps): the failure classifies
    as a PS failure, recovery discards only the unacked window (the
    paper's tolerated in-flight loss) and reshards from the spools —
    every ACKED put was spooled before its ack, so no rows are lost."""
    srvs = servers(3, spool_root=tmp_path)
    members = [PSMember("127.0.0.1", s.port, spool_dir=s.spool_dir)
               for s in srvs]
    bs = _batches(6)
    t = _trainer("host_lru", 48, tau=3)
    cluster = ElasticPSCluster(t, members, max_recoveries=2,
                               ping_timeout=0.5)
    cluster.connect(timeout=1.0, retries=1, backoff=0.05)
    state = t.init(jax.random.PRNGKey(0), bs[0])
    for b in bs[:3]:
        state, _ = cluster.step(state, b)
    # the windows really are open: steps returned with unacked puts
    # buffered on the wire (tau=3 tables never drain between steps)
    bk0 = t.backends[t.collection.names[0]]
    assert all(sub.put_window == 3 for sub in bk0.shard_backends)
    assert any(len(sub._acks) > 0 for sub in bk0.shard_backends)
    srvs[1].kill()
    for b in bs[3:5]:
        state, m = cluster.step(state, b)
    resh = [e for e in cluster.events if e["kind"] == "reshard"]
    assert resh and resh[0]["dead"] == [1]
    # acked puts were spooled before their ack: nothing acked was lost
    assert all(v == 0 for v in resh[0]["lost_rows"].values())
    assert len(cluster.members) == 2
    assert np.isfinite(float(m["loss"]))
    # and the discarded window did not leak stale futures into the new
    # membership's backends
    for name in t.collection.names:
        for sub in t.backends[name].shard_backends:
            assert sub.endpoint in [m_.endpoint for m_ in cluster.members]


def test_elastic_all_dead_raises_named_error(servers, tmp_path):
    srvs = servers(2, spool_root=tmp_path)
    members = [PSMember("127.0.0.1", s.port, spool_dir=s.spool_dir)
               for s in srvs]
    bs = _batches(2)
    t = _trainer("dense")
    cluster = ElasticPSCluster(t, members, max_recoveries=1,
                               ping_timeout=0.3)
    cluster.connect(timeout=0.5, retries=1, backoff=0.02)
    state = t.init(jax.random.PRNGKey(0), bs[0])
    state, _ = cluster.step(state, bs[0])
    for s in srvs:
        s.kill()
    with pytest.raises(ClusterDeadError):
        cluster.step(state, bs[1])


# ---------------------------------------------------------------------------
# the pipelined wire path: windows, coalescing, the blocking baseline
# ---------------------------------------------------------------------------

def _distinct_clients(trainer):
    """The trainer's distinct RpcClients — tables sharing an endpoint share
    ONE pooled connection, so counters must be deduped by identity."""
    seen = {}
    for bk in trainer.backends.values():
        for sub in getattr(bk, "shard_backends", None) or [bk]:
            seen[id(sub._client)] = sub._client
    return list(seen.values())


def _frames_sent(trainer):
    return sum(c.frames_sent for c in _distinct_clients(trainer))


def test_put_window_derives_from_staleness():
    spec = EmbeddingSpec(rows=64, dim=8)
    sync_spec = dataclasses.replace(spec, staleness=0)
    hyb_spec = dataclasses.replace(spec, staleness=3)
    deep_spec = dataclasses.replace(spec, staleness=100)
    srv = PSServer().start()
    try:
        ep = ("127.0.0.1", srv.port)
        subs = [RemoteBackend(sync_spec, ep, table="a"),
                RemoteBackend(hyb_spec, ep, table="b"),
                RemoteBackend(deep_spec, ep, table="c"),
                RemoteBackend(deep_spec, ep, table="d", put_window=2),
                RemoteBackend(hyb_spec, ep, table="e", pipelined=False)]
        try:
            # sync: 1; hybrid: tau; deep tau: capped; override wins;
            # the blocking baseline is always one synchronous RTT per op
            assert [b.put_window for b in subs] == [1, 3, 8, 2, 1]
        finally:
            for b in subs:
                b.close()
    finally:
        srv.stop()


def test_blocking_baseline_bit_exact_and_coalescing_cuts_frames(servers):
    """The pipelined wire path changes WHEN bytes move, never what they
    say: pipelined=False (per-op synchronous round-trips, the benchmark's
    baseline) and the coalesced windowed path produce identical training,
    while the pipelined path ships far fewer frames (= round-trips)."""
    bs = _batches(4)
    t0 = _trainer("host_lru", 48)
    connect_remote_backends(t0, _endpoints(servers(2)), pipelined=False)
    s0 = t0.init(jax.random.PRNGKey(0), bs[0])
    f0_start = _frames_sent(t0)
    for b in bs:
        s0, m0 = t0.decomposed_step(s0, b)
    for n, st in s0.emb.items():
        t0.backends[n].sync(st)
    f0 = _frames_sent(t0) - f0_start

    t1 = _trainer("host_lru", 48)
    connect_remote_backends(t1, _endpoints(servers(2)))
    s1 = t1.init(jax.random.PRNGKey(0), bs[0])
    f1_start = _frames_sent(t1)
    for b in bs:
        s1, m1 = t1.decomposed_step(s1, b)
    for n, st in s1.emb.items():
        t1.backends[n].sync(st)
    f1 = _frames_sent(t1) - f1_start

    assert np.float32(m1["loss"]) == np.float32(m0["loss"])
    rows0, rows1 = _probe_all_rows(t0, s0), _probe_all_rows(t1, s1)
    for n in rows0:
        np.testing.assert_array_equal(rows1[n], rows0[n])
    # blocking pays one frame per (table x shard x phase) op; coalescing
    # folds every prepare and put into one step_ops frame per endpoint —
    # only the lookups (whose activations must return synchronously)
    # remain per-table frames
    assert f1 <= 0.6 * f0, (f1, f0)


def test_remote_prefetch_pipeline_matches_inprocess(servers):
    """prefetch=2 over remote host_lru tables: the look-ahead fault-ins
    ride the coalesced wire ahead of the inflight window and the result
    stays bit-exact with the identically-configured in-process engine."""
    bs = _batches(5)
    t0 = _trainer("host_lru", RPF)          # eviction-free cache
    s0 = t0.init(jax.random.PRNGKey(0), bs[0])
    e0 = PipelinedTrainer(t0, max_inflight=1, prefetch=2)
    s0, ms0 = e0.run(s0, iter(bs))
    t1 = _trainer("host_lru", RPF)
    connect_remote_backends(t1, _endpoints(servers(2)))
    s1 = t1.init(jax.random.PRNGKey(0), bs[0])
    e1 = PipelinedTrainer(t1, max_inflight=1, prefetch=2)
    s1, ms1 = e1.run(s1, iter(bs))
    assert np.float32(ms1[-1]["loss"]) == np.float32(ms0[-1]["loss"])
    assert e1.pipeline_metrics()["pipeline/prefetch/items"] == float(len(bs))
