"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

KAPPA = 32_768.0


def blockscale_compress_ref(v_blocks):
    """v_blocks: (n, 128) fp32 -> (fp16 (n,128), fp32 scales (n,))."""
    linf = jnp.max(jnp.abs(v_blocks), axis=-1, keepdims=True)
    scale = KAPPA / jnp.maximum(linf, 1e-30)
    return (v_blocks * scale).astype(jnp.float16), scale[:, 0]


def blockscale_decompress_ref(comp, scales):
    return comp.astype(jnp.float32) / scales[:, None]


def embedding_bag_ref(table, ids):
    """table: (V,D); ids: (B,L) with -1 padding -> (B,D) sum pool."""
    safe = jnp.where(ids >= 0, ids, 0)
    rows = table[safe]                                    # (B,L,D)
    w = (ids >= 0).astype(table.dtype)[..., None]
    return jnp.sum(rows * w, axis=1)


def unique_bag_ref(table, dev, inv):
    """table: (V,D); dev: (U,) unique row ids (-1 pad); inv: (B,L)
    occurrence -> unique position (-1 pad) -> (B,D) sum pool of
    table[dev[inv]] — the dedup-plan lookup (gather + inverse scatter +
    bag pool) as one jnp expression."""
    safe_u = jnp.where(inv >= 0, inv, 0)
    rows_ids = dev[safe_u]                                # (B,L)
    valid = (inv >= 0) & (rows_ids >= 0)
    rows = table[jnp.where(valid, rows_ids, 0)]           # (B,L,D)
    return jnp.sum(rows * valid[..., None].astype(table.dtype), axis=1)


def embedding_sgd_ref(table, ids, grads, *, lr):
    """Row-wise SGD scatter-apply; ids -1 are no-ops. Duplicate ids
    accumulate (use dedup_put first for parity with the kernel)."""
    valid = (ids >= 0)
    safe = jnp.where(valid, ids, 0)
    upd = jnp.where(valid[:, None], -lr * grads, 0.0).astype(table.dtype)
    return table.at[safe].add(upd)


def fused_backward_ref(table, acc, inv, grads, apply_idx, apply_g, *,
                       cap, lr, eps, apply_self=False):
    """One-pass embedding backward: segment-sum occurrence grads to unique
    width via the dedup-plan inverse, apply the row-wise adagrad (or sgd)
    update, and emit the queue-ready unique-width grad payload.

    table: (R, D); acc: (R,) adagrad accumulator or None for sgd;
    inv: occurrence -> unique position (-1 pad, any leading shape);
    grads: occurrence grads (matching leading shape, trailing D);
    apply_idx: (cap,) table rows to update this step (-1 = no-op) —
    the staleness queue's popped ids translated to physical rows;
    apply_g: (cap, D) grads to apply at apply_idx, ignored when
    ``apply_self`` routes the freshly summed payload straight into the
    update (the sync / staleness-0 path).

    Returns (table, acc, g_push) with g_push: (cap, D) fp32 — the
    segment-summed payload, bit-identical to
    ``plan_segment_sum(inv, grads, cap)``; the apply is bit-identical to
    ``embedding_ps._apply_sparse``.
    """
    flat = inv.reshape(-1)
    g = grads.reshape(flat.shape[0], -1).astype(jnp.float32)
    safe_u = jnp.where(flat >= 0, flat, cap)
    g_push = jnp.zeros((cap + 1, g.shape[1]), jnp.float32).at[safe_u].add(
        g)[:cap]
    n_rows = table.shape[0]
    g_a = g_push if apply_self else apply_g
    live = (apply_idx >= 0) & (apply_idx < n_rows)
    safe = jnp.clip(apply_idx, 0, n_rows - 1)
    ga = jnp.where(live[:, None], g_a.astype(jnp.float32), 0.0)
    if acc is not None:
        inc = jnp.where(live, jnp.mean(jnp.square(ga), axis=-1), 0.0)
        acc = acc.at[safe].add(inc)
        step = ga * jax.lax.rsqrt(acc[safe] + eps)[:, None]
    else:
        step = ga
    table = table.at[safe].add((-lr * step).astype(table.dtype))
    return table, acc, g_push
