"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract)."""
from __future__ import annotations

import jax.numpy as jnp

KAPPA = 32_768.0


def blockscale_compress_ref(v_blocks):
    """v_blocks: (n, 128) fp32 -> (fp16 (n,128), fp32 scales (n,))."""
    linf = jnp.max(jnp.abs(v_blocks), axis=-1, keepdims=True)
    scale = KAPPA / jnp.maximum(linf, 1e-30)
    return (v_blocks * scale).astype(jnp.float16), scale[:, 0]


def blockscale_decompress_ref(comp, scales):
    return comp.astype(jnp.float32) / scales[:, None]


def embedding_bag_ref(table, ids):
    """table: (V,D); ids: (B,L) with -1 padding -> (B,D) sum pool."""
    safe = jnp.where(ids >= 0, ids, 0)
    rows = table[safe]                                    # (B,L,D)
    w = (ids >= 0).astype(table.dtype)[..., None]
    return jnp.sum(rows * w, axis=1)


def unique_bag_ref(table, dev, inv):
    """table: (V,D); dev: (U,) unique row ids (-1 pad); inv: (B,L)
    occurrence -> unique position (-1 pad) -> (B,D) sum pool of
    table[dev[inv]] — the dedup-plan lookup (gather + inverse scatter +
    bag pool) as one jnp expression."""
    safe_u = jnp.where(inv >= 0, inv, 0)
    rows_ids = dev[safe_u]                                # (B,L)
    valid = (inv >= 0) & (rows_ids >= 0)
    rows = table[jnp.where(valid, rows_ids, 0)]           # (B,L,D)
    return jnp.sum(rows * valid[..., None].astype(table.dtype), axis=1)


def embedding_sgd_ref(table, ids, grads, *, lr):
    """Row-wise SGD scatter-apply; ids -1 are no-ops. Duplicate ids
    accumulate (use dedup_put first for parity with the kernel)."""
    valid = (ids >= 0)
    safe = jnp.where(valid, ids, 0)
    upd = jnp.where(valid[:, None], -lr * grads, 0.0).astype(table.dtype)
    return table.at[safe].add(upd)
