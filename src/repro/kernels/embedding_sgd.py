"""Pallas TPU kernel: fused row-wise embedding update (the PS-side 'put' +
optimizer apply, paper Alg. 1 backward). One grid step per gradient row:
the owning table row is DMA'd to VMEM (driven by scalar-prefetched ids),
updated with row-wise adagrad, and written back in place
(input_output_aliasing) — no dense (V, D) gradient is ever built.

Rows must be pre-aggregated (core.compression.dedup_put or a DedupPlan)
when ids repeat within a put: the kernel reads each table row through an
aliased INPUT block, which does not observe earlier grid steps' output
writes, so duplicate ids in one put would last-write-win and silently
drop gradients. Since PR 5 the unique data path guarantees pre-aggregated
rows — ``check_unique`` turns an occurrence-width call into a loud error
instead (``ops.embedding_sgd`` runs it unless ``assume_unique`` vouches).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def check_unique(ids) -> None:
    """Raise ValueError when concrete ``ids`` contain duplicates among the
    valid (>= 0) entries — the occurrence-width misuse this kernel cannot
    honor. Traced ids (inside jit) are skipped: the check needs host
    values, and the jitted callers are the vetted unique-width paths."""
    if isinstance(ids, jax.core.Tracer):
        return
    host = np.asarray(ids).reshape(-1)
    valid = host[host >= 0]
    if valid.size != np.unique(valid).size:
        uniq, counts = np.unique(valid, return_counts=True)
        dups = uniq[counts > 1][:8]
        raise ValueError(
            "embedding_sgd requires pre-aggregated unique ids (duplicate "
            f"ids last-write-win and drop gradients); got duplicates "
            f"{dups.tolist()} among {valid.size} valid ids. Segment-sum "
            "via a DedupPlan / compression.dedup_put first, or pass "
            "assume_unique=True if the rows are already aggregated.")


def _sgd_kernel(ids_ref, grad_ref, row_ref, out_ref, *, lr: float):
    i = pl.program_id(0)
    valid = (ids_ref[i] >= 0).astype(row_ref.dtype)
    out_ref[...] = row_ref[...] - lr * valid * grad_ref[...]


def embedding_sgd(table: jax.Array, ids: jax.Array, grads: jax.Array, *,
                  lr: float, interpret: bool = False) -> jax.Array:
    """table: (V, D); ids: (T,) int32 (-1 = no-op); grads: (T, D).

    Returns the updated table (aliased in place on TPU).
    """
    T, D = grads.shape
    V, _ = table.shape

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(T,),
        in_specs=[
            pl.BlockSpec((1, D), lambda i, ids_pref: (i, 0)),          # grad
            pl.BlockSpec((1, D),
                         lambda i, ids_pref: (jnp.maximum(ids_pref[i], 0), 0)),
        ],
        out_specs=pl.BlockSpec((1, D),
                               lambda i, ids_pref: (jnp.maximum(ids_pref[i],
                                                                0), 0)),
    )
    return pl.pallas_call(
        functools.partial(_sgd_kernel, lr=lr),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((V, D), table.dtype),
        input_output_aliases={2: 0},      # table (arg idx incl. prefetch) -> out
        interpret=interpret,
    )(ids, grads, table)
