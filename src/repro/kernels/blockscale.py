"""Pallas TPU kernel for Persia §4.2.3 lossy value compression.

Non-uniform fp32 -> fp16: each 128-wide block v is scaled by kappa/||v||_inf
before the cast (decompress divides it back out), so the fp16 mantissa covers
the block's actual dynamic range instead of clipping outliers.

TPU adaptation: data is viewed as (n_blocks, 128) — the 128 lane dimension is
exactly one vreg row, the per-block L_inf reduction is a lane reduction, and
tiles of TILE_ROWS blocks are staged through VMEM. TILE_ROWS is a multiple of
8 (fp32 sublane) and of 16 (fp16 sublane tile) so both dtypes stay aligned.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

KAPPA = 32_768.0
BLOCK = 128          # elements per scale block == one vreg of lanes
TILE_ROWS = 256      # blocks per grid step (multiple of 8 and 16)


def _compress_kernel(v_ref, comp_ref, scale_ref):
    v = v_ref[...]                                     # (TILE_ROWS, BLOCK) f32
    linf = jnp.max(jnp.abs(v), axis=-1, keepdims=True)
    scale = KAPPA / jnp.maximum(linf, 1e-30)
    comp_ref[...] = (v * scale).astype(jnp.float16)
    scale_ref[...] = scale[:, 0]


def _decompress_kernel(comp_ref, scale_ref, out_ref):
    c = comp_ref[...].astype(jnp.float32)
    out_ref[...] = c / scale_ref[...][:, None]


def compress(v_blocks: jax.Array, *, interpret: bool = False):
    """v_blocks: (n_blocks, BLOCK) fp32, n_blocks % TILE_ROWS == 0.

    Returns (comp fp16 (n_blocks, BLOCK), scales fp32 (n_blocks,)).
    """
    n, b = v_blocks.shape
    assert b == BLOCK and n % TILE_ROWS == 0, (n, b)
    grid = (n // TILE_ROWS,)
    return pl.pallas_call(
        _compress_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((TILE_ROWS, BLOCK), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((TILE_ROWS, BLOCK), lambda i: (i, 0)),
                   pl.BlockSpec((TILE_ROWS,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((n, BLOCK), jnp.float16),
                   jax.ShapeDtypeStruct((n,), jnp.float32)],
        interpret=interpret,
    )(v_blocks)


def decompress(comp: jax.Array, scales: jax.Array, *, interpret: bool = False):
    n, b = comp.shape
    assert b == BLOCK and n % TILE_ROWS == 0
    grid = (n // TILE_ROWS,)
    return pl.pallas_call(
        _decompress_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((TILE_ROWS, BLOCK), lambda i: (i, 0)),
                  pl.BlockSpec((TILE_ROWS,), lambda i: (i,))],
        out_specs=pl.BlockSpec((TILE_ROWS, BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, BLOCK), jnp.float32),
        interpret=interpret,
    )(comp, scales)
