"""Pallas TPU kernel: fused embedding backward (paper Alg. 1 PS-side put).

One scalar-prefetch-driven pass over ``n_occ + cap`` grid steps:

* phase A (steps ``0 .. n_occ``) — segment-sum the occurrence-width grads
  into a VMEM accumulator at unique width, driven by the dedup-plan
  inverse (``core.dedup.DedupPlan.inv``); -1 inverse entries (padding)
  are skipped;
* phase B (steps ``n_occ .. n_occ + cap``) — per unique row: emit the
  queue-ready payload row from the VMEM accumulator, and apply the
  row-wise adagrad update to the owning table row in place
  (``input_output_aliases``), reading table/acc THROUGH the output refs
  so repeated physical rows (clipped -1 sentinels) observe each other's
  writes exactly.

No full-width ``(U, D)`` gradient intermediate is ever materialized in
HBM: the decomposed path's segment-sum output and its padded queue copy
both collapse into the single ``(cap, D)`` payload output.

The jnp oracle is ``kernels.ref.fused_backward_ref``; the oracle (the
default wired path — ``EmbeddingSpec.backward_kernel`` opts into this
kernel) is bit-identical to ``core.embedding_ps._apply_sparse`` +
``core.dedup.plan_segment_sum``. The kernel itself matches the oracle to
the fp32 regroup class (~1e-7 relative): XLA tiles the oracle's
``(cap, D)`` row-mean reduction differently from the kernel's per-row
``(1, D)`` reduction, so the adagrad ``mean(g^2)`` sums in a different
order — the payload and table/acc scatter structure are exact.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, inv_ref, grads_ref, applyg_ref, table_in, acc_in,
            table_out, acc_out, push_out, gsum, *, n_occ: int, cap: int,
            n_rows: int, lr: float, eps: float, apply_self: bool):
    del table_in, acc_in                     # aliased: read via the out refs
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        gsum[...] = jnp.zeros_like(gsum)

    u = inv_ref[jnp.minimum(i, n_occ - 1)]

    @pl.when((i < n_occ) & (u >= 0))
    def _accumulate():
        j = jnp.maximum(u, 0)
        gsum[pl.ds(j, 1), :] += grads_ref[...].astype(jnp.float32)

    @pl.when(i >= n_occ)
    def _apply():
        j = jnp.clip(i - n_occ, 0, cap - 1)
        g_row = gsum[pl.ds(j, 1), :]
        push_out[...] = g_row
        row = idx_ref[j]
        live = (row >= 0) & (row < n_rows)
        g_src = g_row if apply_self else applyg_ref[...].astype(jnp.float32)
        g = jnp.where(live, g_src, 0.0)
        inc = jnp.where(live, jnp.mean(jnp.square(g)), 0.0)
        new_acc = acc_out[...] + inc         # out-ref read: fresh on revisit
        acc_out[...] = new_acc
        step = g * jax.lax.rsqrt(new_acc + eps)
        upd = (-lr * step).astype(table_out.dtype)
        # the self-equality select blocks XLA/LLVM from contracting the
        # -lr multiply into an fma with the row add: the decomposed
        # path's scatter-add rounds the product first, and bit-exactness
        # vs that path is the contract (optimization_barrier does not
        # survive interpret-mode lowering)
        upd = jnp.where(upd == upd, upd, jnp.zeros_like(upd))
        table_out[...] = table_out[...] + upd


def fused_backward(table: jax.Array, acc: jax.Array, inv: jax.Array,
                   grads: jax.Array, apply_idx: jax.Array,
                   apply_g: jax.Array, *, lr: float, eps: float,
                   apply_self: bool = False,
                   interpret: bool = False):
    """table: (R, D); acc: (R,) adagrad accumulator; inv: occurrence ->
    unique position (-1 pad, any leading shape); grads: occurrence grads;
    apply_idx: (cap,) physical rows to update (-1 = no-op); apply_g:
    (cap, D) grads applied at apply_idx unless ``apply_self`` routes the
    freshly summed payload into the update (sync / staleness-0).

    Returns (table, acc, g_push) with table/acc aliased in place on TPU
    and g_push: (cap, D) fp32 the queue-ready payload.
    """
    flat = inv.reshape(-1)
    n_occ = int(flat.shape[0])
    g_occ = grads.reshape(n_occ, -1)
    D = int(g_occ.shape[1])
    R = int(table.shape[0])
    cap = int(apply_idx.shape[0])
    acc2 = acc.reshape(R, 1)

    def _row(i, idx_pref, inv_pref):
        j = jnp.clip(i - n_occ, 0, cap - 1)
        return jnp.clip(idx_pref[j], 0, R - 1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_occ + cap,),
        in_specs=[
            pl.BlockSpec((1, D),
                         lambda i, idx_pref, inv_pref:
                         (jnp.minimum(i, n_occ - 1), 0)),          # grads
            pl.BlockSpec((1, D),
                         lambda i, idx_pref, inv_pref:
                         (jnp.clip(i - n_occ, 0, cap - 1), 0)),    # apply_g
            pl.BlockSpec((1, D),
                         lambda i, idx_pref, inv_pref:
                         (_row(i, idx_pref, inv_pref), 0)),        # table
            pl.BlockSpec((1, 1),
                         lambda i, idx_pref, inv_pref:
                         (_row(i, idx_pref, inv_pref), 0)),        # acc
        ],
        out_specs=[
            pl.BlockSpec((1, D),
                         lambda i, idx_pref, inv_pref:
                         (_row(i, idx_pref, inv_pref), 0)),        # table
            pl.BlockSpec((1, 1),
                         lambda i, idx_pref, inv_pref:
                         (_row(i, idx_pref, inv_pref), 0)),        # acc
            pl.BlockSpec((1, D),
                         lambda i, idx_pref, inv_pref:
                         (jnp.clip(i - n_occ, 0, cap - 1), 0)),    # push
        ],
        scratch_shapes=[pltpu.VMEM((cap, D), jnp.float32)],
    )
    new_table, new_acc, g_push = pl.pallas_call(
        functools.partial(_kernel, n_occ=n_occ, cap=cap, n_rows=R,
                          lr=lr, eps=eps, apply_self=apply_self),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((R, D), table.dtype),
            jax.ShapeDtypeStruct((R, 1), acc.dtype),
            jax.ShapeDtypeStruct((cap, D), jnp.float32),
        ],
        input_output_aliases={4: 0, 5: 1},   # arg idx incl. prefetch args
        interpret=interpret,
    )(apply_idx, flat, g_occ, apply_g, table, acc2)
    return new_table, new_acc.reshape(R), g_push
