"""jit'd public wrappers around the Pallas kernels.

On this CPU container the kernels execute through ``interpret=True`` (the
Mosaic TPU compiler is the deployment target); ``INTERPRET`` flips the whole
module, and each wrapper handles padding/reshaping to the kernels' aligned
layouts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import blockscale as _bs
from repro.kernels import embedding_bag as _bag
from repro.kernels import embedding_sgd as _sgd

INTERPRET = jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("block",))
def blockscale_roundtrip(v, block: int = 128):
    """Compress+decompress arbitrary-shaped fp32 v (the comm boundary)."""
    assert block == _bs.BLOCK
    flat = v.reshape(-1)
    n = flat.size
    rows = -(-n // _bs.BLOCK)
    rows_pad = -(-rows // _bs.TILE_ROWS) * _bs.TILE_ROWS
    buf = jnp.zeros((rows_pad * _bs.BLOCK,), jnp.float32).at[:n].set(
        flat.astype(jnp.float32))
    blocks = buf.reshape(rows_pad, _bs.BLOCK)
    comp, scales = _bs.compress(blocks, interpret=INTERPRET)
    out = _bs.decompress(comp, scales, interpret=INTERPRET)
    return out.reshape(-1)[:n].reshape(v.shape)


@jax.jit
def blockscale_compress(v_blocks):
    return _bs.compress(v_blocks, interpret=INTERPRET)


@jax.jit
def blockscale_decompress(comp, scales):
    return _bs.decompress(comp, scales, interpret=INTERPRET)


@jax.jit
def embedding_bag(table, ids):
    """(V,D) x (B,L) -> (B,D) fused gather+pool."""
    return _bag.embedding_bag(table, ids, interpret=INTERPRET)


@jax.jit
def unique_bag(table, dev, inv):
    """(V,D) x (U,) unique dev ids x (B,L) inverse -> (B,D): the dedup-plan
    lookup (unique gather + inverse scatter + bag pool) in one fused pass."""
    from repro.kernels import unique_bag as _ub
    return _ub.unique_bag(table, dev, inv, interpret=INTERPRET)


def embedding_sgd(table, ids, grads, lr: float = 1e-2,
                  assume_unique: bool = False):
    """Row-wise SGD scatter-apply. The kernel last-write-wins on duplicate
    ids, so callers must pass pre-aggregated unique rows; unless
    ``assume_unique`` vouches for that, concrete (non-traced) ids are
    checked and duplicates raise instead of silently dropping grads."""
    if not assume_unique:
        _sgd.check_unique(ids)
    return _embedding_sgd_jit(table, ids, grads, lr)


@functools.partial(jax.jit, static_argnames=("lr",))
def _embedding_sgd_jit(table, ids, grads, lr: float):
    return _sgd.embedding_sgd(table, ids, grads, lr=lr, interpret=INTERPRET)


@functools.partial(jax.jit,
                   static_argnames=("lr", "eps", "apply_self"))
def fused_backward(table, acc, inv, grads, apply_idx, apply_g, *,
                   lr: float, eps: float, apply_self: bool = False):
    """Fused embedding backward: dedup segment-sum + adagrad apply + queue
    payload in one pass -> (table, acc, g_push). Oracle:
    ``ref.fused_backward_ref``."""
    from repro.kernels import fused_backward as _fb
    return _fb.fused_backward(table, acc, inv, grads, apply_idx, apply_g,
                              lr=lr, eps=eps, apply_self=apply_self,
                              interpret=INTERPRET)


@functools.partial(jax.jit, static_argnames=("scale", "causal", "window",
                                             "qblk", "kblk"))
def flash_attention_fwd(q, k, v, scale: float, causal: bool = True,
                        window: int = 0, qblk: int = 256, kblk: int = 256):
    """(B,Hq,S,Dh) x (B,Hkv,S,Dh) -> (o, lse). VMEM-resident accumulators:
    HBM traffic is the roofline minimum (see EXPERIMENTS.md §Perf)."""
    from repro.kernels import flash_attention as _fa
    return _fa.flash_attention_fwd(q, k, v, scale=scale, causal=causal,
                                   window=window, qblk=qblk, kblk=kblk,
                                   interpret=INTERPRET)
