"""Pallas TPU kernel: fused multi-hot embedding gather + sum pool — the
embedding-worker "aggregation" hot spot (paper §4.1 step 4: pool the bag's
rows *before* shipping activations to the NN worker).

TPU adaptation: the GPU pattern (one warp per bag, random-access loads from
HBM) has no direct TPU analogue. Instead the bag ids are *scalar-prefetched*
(pltpu.PrefetchScalarGridSpec) so they are available to the BlockSpec
index_map before the grid step runs — each grid step then DMAs exactly one
table row HBM->VMEM, chosen by ids[i], and accumulates it into the bag's
output row, which stays resident in VMEM across the bag's L steps (output
revisiting). Invalid ids (< 0, padding) are mapped to row 0 and masked by a
0/1 weight inside the kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bag_kernel(ids_ref, table_row_ref, out_ref, *, bag_len: int):
    i = pl.program_id(0)
    # first visit of this output row: zero it
    @pl.when(i % bag_len == 0)
    def _():
        out_ref[...] = jnp.zeros(out_ref.shape, out_ref.dtype)

    valid = (ids_ref[i] >= 0).astype(table_row_ref.dtype)
    out_ref[...] += table_row_ref[...] * valid


def embedding_bag(table: jax.Array, ids: jax.Array, *,
                  interpret: bool = False) -> jax.Array:
    """table: (V, D); ids: (B, L) int32 with -1 padding -> (B, D) sum-pooled.

    D should be a multiple of 128 (lane width) for the non-interpret path.
    """
    B, L = ids.shape
    V, D = table.shape
    flat = ids.reshape(-1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B * L,),
        in_specs=[
            # padding ids (-1) are clamped to row 0 for the DMA; the kernel
            # multiplies that row by 0, so the pool is exact.
            pl.BlockSpec((1, D),
                         lambda i, ids_pref: (jnp.maximum(ids_pref[i], 0), 0)),
        ],
        out_specs=pl.BlockSpec((1, D), lambda i, ids_pref: (i // L, 0)),
    )
    kernel = functools.partial(_bag_kernel, bag_len=L)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), table.dtype),
        interpret=interpret,
    )(flat, table)
