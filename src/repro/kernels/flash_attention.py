"""Pallas TPU flash-attention forward kernel (causal/windowed, GQA).

Why this kernel exists (see EXPERIMENTS.md §Perf): the pure-jnp flash path
carries its (qblk, Dv) fp32 accumulator through a lax.scan, and XLA
round-trips that carry through HBM once per kv block — the dry-run roofline
measures that carry traffic at O(B*H*S^2/kblk) bytes, the dominant memory
term for train_4k/prefill_32k. Here the accumulator lives in VMEM scratch
across the kv grid dimension, so HBM traffic drops to the roofline minimum
(read q,k,v once; write o once).

Grid: (B, Hq, nq, nk) — nk is the innermost (sequential) dimension; output
blocks are revisited across it. Blocks:
  q:   (1, 1, qblk, Dh)   indexed (b, h, qi)
  k/v: (1, 1, kblk, Dh)   indexed (b, h // G, ki)    (GQA: no kv expansion)
  o:   (1, 1, qblk, Dh)   indexed (b, h, qi)
  lse: (1, 1, qblk)       indexed (b, h, qi)          (for a jnp backward)
Masking is additive-bias arithmetic (causal / sliding-window / key-bound).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr, *,
            scale, causal, window, kblk, qblk, nk, sk):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full(m_scr.shape, NEG_INF, jnp.float32)
        l_scr[...] = jnp.zeros(l_scr.shape, jnp.float32)
        acc_scr[...] = jnp.zeros(acc_scr.shape, jnp.float32)

    q = q_ref[0, 0].astype(jnp.float32)                    # (qblk, Dh)
    k = k_ref[0, 0].astype(jnp.float32)                    # (kblk, Dh)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    qpos = qi * qblk + jax.lax.broadcasted_iota(jnp.int32, (qblk, kblk), 0)
    kpos = ki * kblk + jax.lax.broadcasted_iota(jnp.int32, (qblk, kblk), 1)
    mask = kpos < sk
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= qpos - kpos < window
    s = s + jnp.where(mask, 0.0, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_scr[...] * corr + jnp.sum(p, axis=-1)
    v = v_ref[0, 0].astype(jnp.float32)
    acc = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0] = m_scr[...] + jnp.log(l)


def flash_attention_fwd(q, k, v, *, scale, causal=True, window=0,
                        qblk=256, kblk=256, interpret=False):
    """q: (B, Hq, Sq, Dh); k/v: (B, Hkv, Sk, Dh). Returns (o, lse).

    Sq % qblk == 0 and Sk % kblk == 0 (pad at the jnp wrapper level);
    key positions >= the true Sk can be masked via the `sk` bound baked in.
    """
    B, Hq, Sq, Dh = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    G = Hq // Hkv
    assert Sq % qblk == 0 and Sk % kblk == 0
    nq, nk = Sq // qblk, Sk // kblk

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        kblk=kblk, qblk=qblk, nk=nk, sk=Sk)

    grid = (B, Hq, nq, nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, qblk, Dh), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, kblk, Dh),
                         lambda b, h, qi, ki: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, kblk, Dh),
                         lambda b, h, qi, ki: (b, h // G, ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, qblk, Dh), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, qblk), lambda b, h, qi, ki: (b, h, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hq, Sq, Dh), q.dtype),
            jax.ShapeDtypeStruct((B, Hq, Sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((qblk,), jnp.float32),
            pltpu.VMEM((qblk,), jnp.float32),
            pltpu.VMEM((qblk, Dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
