"""Pallas TPU kernel: fused unique-gather + inverse-scatter + sum pool —
the worker-side-dedup lookup hot spot (paper §4.2.3 + §4.1 step 4).

With batch dedup the embedding worker holds the batch as a *dedup plan*:
``dev`` (one device row id per unique id) and ``inv`` (occurrence -> unique
position). The naive lowering materialises the (U, D) unique gather, then
the (B, L, D) inverse scatter, then the (B, D) bag pool — three HBM-sized
intermediates. This kernel fuses all three: the grid walks the B*L
occurrences, each step resolves the double indirection ``dev[inv[i]]`` in
the BlockSpec index_map (both arrays are scalar-prefetched, so the row id
is known before the step runs), DMAs exactly that table row HBM->VMEM and
accumulates it into the bag's output row, which stays VMEM-resident across
the bag's L steps (output revisiting). Nothing unique- or occurrence-width
ever touches HBM.

Invalid occurrences (``inv[i] < 0``, multi-hot padding) and plan padding
(``dev[u] < 0``) are mapped to row 0 for the DMA and masked by a 0/1
weight inside the kernel, so an all-padding bag pools to exact zeros.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _unique_bag_kernel(inv_ref, dev_ref, table_row_ref, out_ref, *,
                       bag_len: int):
    i = pl.program_id(0)

    # first visit of this output row: zero it
    @pl.when(i % bag_len == 0)
    def _():
        out_ref[...] = jnp.zeros(out_ref.shape, out_ref.dtype)

    u = inv_ref[i]
    row = dev_ref[jnp.maximum(u, 0)]
    valid = ((u >= 0) & (row >= 0)).astype(table_row_ref.dtype)
    out_ref[...] += table_row_ref[...] * valid


def unique_bag(table: jax.Array, dev: jax.Array, inv: jax.Array, *,
               interpret: bool = False) -> jax.Array:
    """table: (V, D); dev: (U,) int32 unique row ids (-1 padding);
    inv: (B, L) int32 occurrence -> unique position (-1 padding)
    -> (B, D) sum-pooled bags of ``table[dev[inv[b, l]]]``.

    D should be a multiple of 128 (lane width) for the non-interpret path.
    """
    B, L = inv.shape
    V, D = table.shape
    flat_inv = inv.reshape(-1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B * L,),
        in_specs=[
            # the double indirection happens HERE, on prefetched scalars:
            # padding (inv or dev = -1) is clamped to row 0 for the DMA and
            # the kernel multiplies that row by 0, so the pool is exact.
            pl.BlockSpec(
                (1, D),
                lambda i, inv_pref, dev_pref: (
                    jnp.maximum(dev_pref[jnp.maximum(inv_pref[i], 0)], 0),
                    0)),
        ],
        out_specs=pl.BlockSpec((1, D), lambda i, inv_pref, dev_pref:
                               (i // L, 0)),
    )
    kernel = functools.partial(_unique_bag_kernel, bag_len=L)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), table.dtype),
        interpret=interpret,
    )(flat_inv, dev, table)
