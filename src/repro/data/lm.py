"""Synthetic LM token streams for the assigned-architecture smoke tests and
the ~100M end-to-end training example. A small Markov-chain language over the
vocab gives next-token structure (so loss visibly decreases), generated
on-the-fly with numpy."""
from __future__ import annotations

import numpy as np


def lm_batches(vocab_size: int, batch: int, seq_len: int, *, seed: int = 0,
               order: int = 1, branch: int = 16):
    """Infinite generator of {'tokens', 'targets', 'mask'} batches.

    Each token's successor is drawn from `branch` allowed continuations
    (a sparse deterministic transition structure + noise), so a model can
    reach low loss by learning the table.
    """
    rng = np.random.default_rng(seed)
    succ = rng.integers(0, vocab_size, size=(vocab_size, branch))
    while True:
        toks = np.empty((batch, seq_len + 1), np.int64)
        toks[:, 0] = rng.integers(0, vocab_size, size=batch)
        for t in range(seq_len):
            pick = rng.integers(0, branch, size=batch)
            nxt = succ[toks[:, t], pick]
            noise = rng.random(batch) < 0.05
            nxt = np.where(noise, rng.integers(0, vocab_size, batch), nxt)
            toks[:, t + 1] = nxt
        yield {
            "tokens": toks[:, :-1].astype(np.int32),
            "targets": toks[:, 1:].astype(np.int32),
            "mask": np.ones((batch, seq_len), np.float32),
        }
