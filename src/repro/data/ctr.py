"""Synthetic CTR datasets shaped like the paper's benchmarks.

The real Taobao/Avazu/Criteo logs are not available offline, so we generate
statistically-shaped analogs: Zipfian ID popularity (the regime where the
paper's alpha << 1 assumption holds), multi-hot ID fields, dense Non-ID
features, and a planted logistic ground truth so AUC is a meaningful,
monotone-in-training signal. Scales follow Table 1 of the paper (sparse
rows scaled down by a constant factor; Criteo-Syn keeps the paper's exact
row counts for the capacity dry-runs where nothing is materialised).

Batches carry ``ids`` of shape (B, n_fields, ids_per_field) with *per-field
local* id spaces: field ``i`` indexes its own ``rows_per_field``-row table
(matching the per-field tables that ``adapters.ctr_collection`` builds).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class PlantedTruth:
    """The planted logistic ground truth behind a CTR stream: bucket
    effects over hashed ids + dense-feature effects, squashed through a
    sigmoid with a negative bias (~25% positives at bias=1.0).

    Shared by the offline sampler and the online click-feedback loop
    (repro.serving.feedback): both label examples from the SAME model, so
    a trainer fed served click feedback chases the same target as one fed
    the offline stream."""

    w_buckets: np.ndarray        # (n_fields, 256) hashed-id bucket effects
    w_dense: np.ndarray          # (max(n_dense,1), n_tasks)
    w_field: np.ndarray          # (n_fields, n_tasks)
    bias: float = 1.0            # prob = sigmoid(sig - bias)

    @staticmethod
    def from_seed(seed: int, n_fields: int, n_dense: int,
                  n_tasks: int = 1, bias: float = 1.0) -> "PlantedTruth":
        # draw order is load-bearing: it reproduces the pre-refactor
        # sampler's weights bit-for-bit from the same dataset seed
        truth = np.random.default_rng(seed)
        return PlantedTruth(
            w_buckets=truth.standard_normal((n_fields, 256))
            .astype(np.float32),
            w_dense=truth.standard_normal((max(n_dense, 1), n_tasks))
            .astype(np.float32),
            w_field=truth.standard_normal((n_fields, n_tasks))
            .astype(np.float32),
            bias=float(bias))

    @property
    def n_fields(self) -> int:
        return int(self.w_buckets.shape[0])

    @property
    def n_tasks(self) -> int:
        return int(self.w_field.shape[1])

    def prob(self, ids: np.ndarray, dense: np.ndarray | None = None
             ) -> np.ndarray:
        """True click probability for ``ids`` (B, n_fields, L) with -1
        padding and ``dense`` (B, >= w_dense rows) — (B, n_tasks)."""
        ids = np.asarray(ids, np.int64)
        F = self.n_fields
        mask = ids >= 0
        bucket = self.w_buckets[np.arange(F)[None, :, None],
                                np.where(mask, ids, 0) % 256]
        bucket = np.where(mask, bucket, 0.0)
        sig = (bucket.sum(-1) @ self.w_field) / np.sqrt(F)
        nd = self.w_dense.shape[0]
        if dense is None:
            dense = np.zeros((ids.shape[0], nd), np.float32)
        sig = sig + (np.asarray(dense, np.float32)[:, :nd]
                     @ self.w_dense) / np.sqrt(nd)
        return 1.0 / (1.0 + np.exp(-(sig - self.bias)))


@dataclass(frozen=True)
class CTRDataset:
    name: str
    n_rows: int                 # total embedding rows (sparse id space)
    n_fields: int               # ID-type feature fields
    ids_per_field: int          # multi-hot width
    n_dense: int                # Non-ID features
    n_tasks: int = 1
    zipf_a: float = 1.2         # popularity skew
    seed: int = 0

    @property
    def rows_per_field(self) -> int:
        """Rows of each field's own id space (per-field embedding table)."""
        from repro.utils import default_field_rows
        return default_field_rows(self.n_rows, self.n_fields)

    def field_rows(self) -> tuple[int, ...]:
        """Per-field table row counts, in field order — feed this to
        ``adapters.ctr_collection(..., field_rows=...)``."""
        return (self.rows_per_field,) * self.n_fields

    def truth(self) -> PlantedTruth:
        """The dataset's planted logistic ground truth — keyed to the
        DATASET seed only, so every stream (offline sampler, online click
        feedback, any sample seed) labels from the same model."""
        return PlantedTruth.from_seed(self.seed, self.n_fields,
                                      self.n_dense, self.n_tasks)

    def sampler(self, batch_size: int, *, seed: int | None = None):
        """Infinite generator of batches (online-learning setting, no
        shuffling schema — paper §4.2.4).

        The planted logistic ground truth is keyed to the DATASET seed only
        — every stream (train, eval, any seed) shares one truth; `seed`
        varies just the samples drawn from it."""
        truth = self.truth()
        rng = np.random.default_rng(self.seed if seed is None else seed)
        rows_per_field = self.rows_per_field

        while True:
            # Zipf-ish ids: rejection-free bounded zipf via inverse-cdf approx
            u = rng.random((batch_size, self.n_fields, self.ids_per_field))
            ranks = np.floor(
                ((rows_per_field ** (1 - self.zipf_a) - 1) * u + 1)
                ** (1 / (1 - self.zipf_a)) - 1)
            ranks = np.clip(ranks, 0, rows_per_field - 1).astype(np.int64)
            # per-field LOCAL ids: each field indexes its own embedding
            # table from 0 (the multi-table EmbeddingCollection layout)
            ids = ranks
            # random multi-hot length: pad tail with -1
            lens = rng.integers(1, self.ids_per_field + 1,
                                (batch_size, self.n_fields))
            mask = (np.arange(self.ids_per_field)[None, None, :]
                    < lens[:, :, None])
            ids = np.where(mask, ids, -1)

            dense = rng.standard_normal((batch_size, max(self.n_dense, 1))) \
                .astype(np.float32)
            prob = truth.prob(ids, dense)                  # ~25% positives
            labels = (rng.random((batch_size, self.n_tasks)) < prob) \
                .astype(np.float32)
            batch = {"ids": ids.astype(np.int32),
                     "labels": labels}
            if self.n_dense:
                batch["dense"] = dense[:, : self.n_dense]
            yield batch


# Paper Table 1 scales (sparse rows scaled 1e-3 for the trainable analogs;
# Criteo-Syn rows are the paper's full counts — embedding rows = params/dim,
# dim=128 as in the paper's capacity test).
CTR_BENCHMARKS = {
    # paper: 29M sparse / 12M dense
    "taobao_ad": CTRDataset("taobao_ad", n_rows=29_000, n_fields=8,
                            ids_per_field=4, n_dense=8),
    # paper: 134M sparse
    "avazu_ad": CTRDataset("avazu_ad", n_rows=134_000, n_fields=16,
                           ids_per_field=4, n_dense=4),
    # paper: 540M sparse
    "criteo_ad": CTRDataset("criteo_ad", n_rows=540_000, n_fields=26,
                            ids_per_field=2, n_dense=13),
    # paper: 2T sparse / 34M dense, multi-task
    "kwai_video": CTRDataset("kwai_video", n_rows=2_000_000, n_fields=32,
                             ids_per_field=8, n_dense=16, n_tasks=4),
}


def criteo_syn_rows(trillions: float, dim: int = 128) -> int:
    """Criteo-Syn_k: embedding rows for a `trillions`-parameter table."""
    return int(trillions * 1e12) // dim


def make_ctr_dataset(name: str) -> CTRDataset:
    return CTR_BENCHMARKS[name]
