from repro.data.ctr import CTRDataset, CTR_BENCHMARKS, make_ctr_dataset
from repro.data.lm import lm_batches
