"""DeepSeek-V2-Lite 16B [arXiv:2405.04434]. MLA (kv_lora=512, no q-lora,
rope_head_dim=64), 27 layers (first FFN dense, rest MoE 64 routed top-6 +
2 shared, expert hidden 1408), d_model 2048, 16 heads, vocab 102400."""
from repro.configs.base import BlockCfg, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    arch_type="moe",
    source="arXiv:2405.04434",
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,                 # qk_nope_head_dim
    d_ff=10944,                   # first dense layer's FFN
    vocab_size=102_400,
    prologue=(BlockCfg("mla", "dense"),),
    pattern=(BlockCfg("mla", "moe"),),
    pattern_repeats=26,
    kv_lora_rank=512,
    q_lora_rank=0,
    rope_head_dim=64,
    v_head_dim=128,
    n_experts=64,
    n_shared_experts=2,
    moe_top_k=6,
    moe_d_ff=1408,
    rope_theta=10_000.0,
    emb_staleness=1,
)
