"""Config schema for every architecture the framework can instantiate.

A model is described as an embedding front-end plus a *block program*: a short
pattern of heterogeneous blocks repeated ``pattern_repeats`` times (so the
whole stack lowers as one ``lax.scan`` over stacked parameters — essential to
keep HLO size bounded for 60..100-layer dry-runs), optionally preceded by a
few unscanned prologue blocks (e.g. DeepSeek's first dense FFN layer).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Literal, Optional

Mixer = Literal["gqa", "mla", "mamba2", "cross_attn", "none"]
Ffn = Literal["dense", "moe", "none"]


@dataclass(frozen=True)
class BlockCfg:
    """One block = mixer (attention / SSM / cross-attn) + FFN.

    ``cross=True`` adds a cross-attention sub-block after the mixer (Whisper
    decoder layers: self-attn + cross-attn + FFN)."""
    mixer: Mixer = "gqa"
    ffn: Ffn = "dense"
    cross: bool = False


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    arch_type: str = "dense"          # dense | moe | ssm | hybrid | vlm | audio | recsys
    source: str = ""                   # citation for the config

    # Core dims -------------------------------------------------------------
    d_model: int = 512
    n_heads: int = 8
    n_kv_heads: int = 8
    head_dim: int = 0                  # 0 -> d_model // n_heads
    d_ff: int = 2048
    vocab_size: int = 32000
    max_seq_len: int = 1 << 20

    # Block program ----------------------------------------------------------
    pattern: tuple[BlockCfg, ...] = (BlockCfg(),)
    pattern_repeats: int = 2
    prologue: tuple[BlockCfg, ...] = ()   # unscanned leading blocks

    # Attention --------------------------------------------------------------
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0            # 0 = full attention; >0 = window size
    attn_logit_softcap: float = 0.0

    # MLA (DeepSeek-V2) -------------------------------------------------------
    kv_lora_rank: int = 0
    q_lora_rank: int = 0               # 0 -> direct q projection
    rope_head_dim: int = 64
    v_head_dim: int = 0                # 0 -> head_dim

    # MoE ---------------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0                  # per-expert hidden dim
    capacity_factor: float = 1.25
    router_aux_weight: float = 1e-2
    router_z_weight: float = 1e-3

    # SSM (Mamba-2 / SSD) ------------------------------------------------------
    ssm_state: int = 0                 # N (state dim per head)
    ssm_head_dim: int = 64             # P
    ssm_expand: int = 2                # d_inner = expand * d_model
    ssm_conv_width: int = 4
    ssm_chunk: int = 256               # SSD chunk length

    # Cross-attention (VLM) / encoder-decoder (audio) ---------------------------
    n_memory_tokens: int = 0           # image patches / encoder frames
    d_memory: int = 0                  # 0 -> d_model
    encoder: Optional["ModelConfig"] = None   # for enc-dec (whisper)

    # Activation / norm ----------------------------------------------------------
    ffn_act: str = "swiglu"            # swiglu | gelu
    norm: str = "rmsnorm"              # rmsnorm | layernorm
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    logit_dtype: str = "float32"

    # RecSys (paper's own family) ---------------------------------------------
    # When arch_type == "recsys", the model is an embedding-bag DLRM/FFNN.
    n_id_fields: int = 0               # number of ID-type feature fields
    ids_per_field: int = 8             # multi-hot width per field
    emb_dim: int = 128                 # embedding vector dim (paper: 128)
    emb_rows: int = 0                  # total embedding rows across fields
    n_dense_features: int = 0          # Non-ID features
    mlp_dims: tuple[int, ...] = (4096, 2048, 1024, 512, 256)   # paper's FFNN
    n_tasks: int = 1

    # Persia hybrid-training knobs ----------------------------------------------
    emb_staleness: int = 0             # tau: 0 = fully synchronous embeddings
    emb_optimizer: str = "adagrad"     # row-wise optimizer on the PS shards

    # Lowering knobs ---------------------------------------------------------------
    remat: bool = True                 # activation-checkpoint each scanned layer
    remat_granularity: str = "body"    # 'body' | 'block' (multi-block patterns)
    seq_shard: bool = True             # shard residual stream's seq dim over 'model'

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))
        if self.v_head_dim == 0:
            object.__setattr__(self, "v_head_dim", self.head_dim)
        if self.d_memory == 0:
            object.__setattr__(self, "d_memory", self.d_model)

    # -- derived ---------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """LM-head vocab padded to a TP-friendly multiple (512 covers any
        model-axis width up to 512 and the 128-lane MXU tile)."""
        return -(-self.vocab_size // 512) * 512

    @property
    def n_layers(self) -> int:
        return len(self.prologue) + len(self.pattern) * self.pattern_repeats

    @property
    def is_encdec(self) -> bool:
        return self.encoder is not None

    @property
    def has_attention(self) -> bool:
        blocks = self.prologue + self.pattern
        return any(b.mixer in ("gqa", "mla", "cross_attn") for b in blocks)

    @property
    def subquadratic(self) -> bool:
        """True when a 500k-token decode is tractable (SSM-only or windowed)."""
        blocks = self.prologue + self.pattern
        full_attn = any(b.mixer in ("gqa", "mla") for b in blocks)
        return (not full_attn) or self.sliding_window > 0

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: <=2 scanned layers, d_model<=512, <=4 experts.
        The reduced pattern keeps one block of each distinct kind so every
        mixer/FFN type in the family is exercised."""
        seen, pat = set(), []
        for b in self.pattern:
            key = (b.mixer, b.ffn, b.cross)
            if key not in seen:
                seen.add(key)
                pat.append(b)
            if len(pat) == 3:
                break
        kw: dict = dict(
            d_model=min(self.d_model, 256),
            n_heads=min(self.n_heads, 4) if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            head_dim=64,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 1024),
            pattern_repeats=1,
            pattern=tuple(pat),
            prologue=self.prologue[:1],
        )
        if self.n_experts:
            kw.update(n_experts=4, moe_top_k=min(self.moe_top_k, 2),
                      n_shared_experts=min(self.n_shared_experts, 1),
                      moe_d_ff=min(self.moe_d_ff or self.d_ff, 256))
        if self.kv_lora_rank:
            kw.update(kv_lora_rank=64, q_lora_rank=min(self.q_lora_rank, 64),
                      rope_head_dim=32, v_head_dim=64)
        if self.ssm_state:
            kw.update(ssm_state=16, ssm_head_dim=32, ssm_chunk=32)
        if self.n_memory_tokens:
            kw.update(n_memory_tokens=16)
        kw.update(d_memory=min(self.d_memory, 256))
        if self.encoder is not None:
            # decoder cross-attn consumes the (reduced) encoder's d_model
            enc = self.encoder.reduced()
            kw.update(encoder=enc, d_memory=enc.d_model)
        if self.arch_type == "recsys":
            kw.update(n_id_fields=min(self.n_id_fields, 4), emb_dim=16,
                      emb_rows=min(self.emb_rows, 1024),
                      mlp_dims=(64, 32), n_dense_features=min(self.n_dense_features, 4))
        return self.replace(**kw)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["training", "prefill", "decode"]


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "training"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
