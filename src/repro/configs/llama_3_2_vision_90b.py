"""Llama-3.2-Vision 90B [hf:meta-llama/Llama-3.2-11B-Vision family scaled].
100 layers, d_model 8192, 64H/8kv, d_ff 28672, vocab 128256. Cross-attention
image layers interleaved 1-in-5 (tanh-gated, consuming stub-projected patch
embeddings — the ViT frontend is a stub per the modality carve-out)."""
from repro.configs.base import BlockCfg, ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    arch_type="vlm",
    source="hf:meta-llama/Llama-3.2-11B-Vision",
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128_256,
    pattern=(BlockCfg("gqa", "dense"),
             BlockCfg("gqa", "dense"),
             BlockCfg("gqa", "dense"),
             BlockCfg("gqa", "dense"),
             BlockCfg("cross_attn", "dense")),
    pattern_repeats=20,
    n_memory_tokens=1600,          # 4 tiles x 400 patches (stubbed)
    rope_theta=500_000.0,
    emb_staleness=1,
)
