"""Qwen3-14B [hf:Qwen/Qwen3-8B family]. Dense GQA (40H / 8 kv), qk-norm,
40 layers, d_model 5120, d_ff 17408, vocab 151936."""
from repro.configs.base import BlockCfg, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    arch_type="dense",
    source="hf:Qwen/Qwen3-8B",
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab_size=151_936,
    pattern=(BlockCfg("gqa", "dense"),),
    pattern_repeats=40,
    qk_norm=True,
    rope_theta=1_000_000.0,
    emb_staleness=1,
)
