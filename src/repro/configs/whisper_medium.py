"""Whisper-medium [arXiv:2212.04356]. Encoder-decoder, 24+24 layers,
d_model 1024, 16H, d_ff 4096, GELU, LayerNorm, learned positions, vocab
51865. The mel-spectrogram + conv frontend is a stub: input_specs provides
precomputed frame embeddings (B, 1500, 1024) — the encoder's post-conv
sequence for 30 s of audio."""
from repro.configs.base import BlockCfg, ModelConfig

_ENCODER = ModelConfig(
    name="whisper-medium-encoder",
    arch_type="audio",
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51_865,
    pattern=(BlockCfg("gqa", "dense"),),
    pattern_repeats=24,
    ffn_act="gelu",
    norm="layernorm",
    n_memory_tokens=1500,
    d_memory=1024,
)

CONFIG = ModelConfig(
    name="whisper-medium",
    arch_type="audio",
    source="arXiv:2212.04356",
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51_865,
    pattern=(BlockCfg("gqa", "dense", cross=True),),
    pattern_repeats=24,
    ffn_act="gelu",
    norm="layernorm",
    encoder=_ENCODER,
    emb_staleness=1,
)
