"""DeepSeek-Coder 33B [arXiv:2401.14196]. Llama-arch dense GQA (56H / 8 kv),
62 layers, d_model 7168, d_ff 19200, vocab 32256."""
from repro.configs.base import BlockCfg, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    arch_type="dense",
    source="arXiv:2401.14196",
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=19200,
    vocab_size=32_256,
    pattern=(BlockCfg("gqa", "dense"),),
    pattern_repeats=62,
    rope_theta=100_000.0,
    emb_staleness=1,
)
