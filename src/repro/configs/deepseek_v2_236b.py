"""DeepSeek-V2 236B [arXiv:2405.04434]. MLA (q_lora=1536, kv_lora=512),
60 layers (first FFN dense, rest MoE 160 routed top-6 + 2 shared, expert
hidden 1536), d_model 5120, 128 heads, vocab 102400."""
from repro.configs.base import BlockCfg, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    arch_type="moe",
    source="arXiv:2405.04434",
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,
    d_ff=12288,
    vocab_size=102_400,
    prologue=(BlockCfg("mla", "dense"),),
    pattern=(BlockCfg("mla", "moe"),),
    pattern_repeats=59,
    kv_lora_rank=512,
    q_lora_rank=1536,
    rope_head_dim=64,
    v_head_dim=128,
    n_experts=160,
    n_shared_experts=2,
    moe_top_k=6,
    moe_d_ff=1536,
    rope_theta=10_000.0,
    emb_staleness=1,
)
