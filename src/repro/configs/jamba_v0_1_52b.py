"""Jamba v0.1 52B [arXiv:2403.19887]. Hybrid Mamba+attention 7:1 interleave
(attention at position 4 of each 8-layer block), MoE 16 experts top-2 every
other layer. 32 layers, d_model 4096, 32H/8kv, d_ff 14336, vocab 65536.

Deviation: the SSM mixer is our Mamba-2/SSD implementation (state 128)
rather than Mamba-1 (state 16) — recorded in DESIGN.md."""
from repro.configs.base import BlockCfg, ModelConfig


def _block(i: int) -> BlockCfg:
    mixer = "gqa" if i == 4 else "mamba2"
    ffn = "moe" if i % 2 == 1 else "dense"
    return BlockCfg(mixer, ffn)


CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    arch_type="hybrid",
    source="arXiv:2403.19887",
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65_536,
    pattern=tuple(_block(i) for i in range(8)),
    pattern_repeats=4,
    n_experts=16,
    n_shared_experts=0,
    moe_top_k=2,
    moe_d_ff=14336,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    rope_theta=10_000.0,
    emb_staleness=1,
)
