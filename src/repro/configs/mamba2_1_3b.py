"""Mamba2-1.3B [arXiv:2405.21060]. Attention-free SSD: 48 layers,
d_model 2048, state 128, head_dim 64 (d_inner 4096 -> 64 heads), vocab
50280. No FFN (the SSD mixer is the whole block, as in the paper)."""
from repro.configs.base import BlockCfg, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    arch_type="ssm",
    source="arXiv:2405.21060",
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    head_dim=64,
    d_ff=0,
    vocab_size=50_280,
    pattern=(BlockCfg("mamba2", "none"),),
    pattern_repeats=48,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    emb_staleness=1,
)
