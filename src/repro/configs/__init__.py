"""Config registry: ``get_config('<arch-id>')`` returns the exact assigned
configuration; ``get_config('<arch-id>', reduced=True)`` the smoke variant."""
from __future__ import annotations

import importlib

from repro.configs.base import (BlockCfg, InputShape, INPUT_SHAPES,
                                ModelConfig)

ARCH_IDS = [
    "deepseek_v2_lite_16b",
    "qwen3_14b",
    "deepseek_v2_236b",
    "phi3_mini_3_8b",
    "mamba2_1_3b",
    "llama_3_2_vision_90b",
    "deepseek_coder_33b",
    "jamba_v0_1_52b",
    "whisper_medium",
    "granite_3_2b",
]

RECSYS_IDS = ["taobao_dlrm", "avazu_dlrm", "criteo_dlrm", "kwai_dlrm",
              "criteo_syn"]


def canonical(name: str) -> str:
    return name.replace("-", "_").replace(".", "_")


def get_config(name: str, *, reduced: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    cfg = mod.CONFIG
    return cfg.reduced() if reduced else cfg


def all_arch_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
