"""Granite-3.0 2B [hf:ibm-granite/granite-3.0-2b-base]. Dense GQA
(32H / 8 kv), 40 layers, d_model 2048, d_ff 8192, vocab 49155."""
from repro.configs.base import BlockCfg, ModelConfig

CONFIG = ModelConfig(
    name="granite-3-2b",
    arch_type="dense",
    source="hf:ibm-granite/granite-3.0-2b-base",
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=49_155,
    pattern=(BlockCfg("gqa", "dense"),),
    pattern_repeats=40,
    rope_theta=10_000.0,
    emb_staleness=1,
)
