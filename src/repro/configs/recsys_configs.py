"""The paper's own model family (Table 1): embedding bags + the
4096-2048-1024-512-256 FFNN. Sparse row counts follow Table 1; the three
trainable analogs scale rows by 1e-3 (full counts are used for the
capacity dry-runs where tables are never materialised)."""
from repro.configs.base import ModelConfig


def _dlrm(name, rows, fields, width, dense, tasks=1, tau=3):
    return ModelConfig(
        name=name, arch_type="recsys", source="Persia KDD'22 Table 1",
        n_id_fields=fields, ids_per_field=width, emb_dim=128,
        emb_rows=rows, n_dense_features=dense,
        mlp_dims=(4096, 2048, 1024, 512, 256), n_tasks=tasks,
        emb_staleness=tau,
    )


TAOBAO = _dlrm("taobao-dlrm", 29_000, 8, 4, 8)
AVAZU = _dlrm("avazu-dlrm", 134_000, 16, 4, 4)
CRITEO = _dlrm("criteo-dlrm", 540_000, 26, 2, 13)
KWAI = _dlrm("kwai-dlrm", 2_000_000, 32, 8, 16, tasks=4)


def criteo_syn(trillions: float) -> ModelConfig:
    """Criteo-Syn_k capacity family: `trillions` x 1e12 params at dim 128."""
    rows = int(trillions * 1e12) // 128
    return _dlrm(f"criteo-syn-{trillions}t", rows, 26, 2, 13)
