"""Phi-3-mini 3.8B [arXiv:2404.14219]. Dense MHA (32H / 32 kv), RoPE,
SwiGLU, 32 layers, d_model 3072, d_ff 8192, vocab 32064."""
from repro.configs.base import BlockCfg, ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    arch_type="dense",
    source="arXiv:2404.14219",
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32_064,
    pattern=(BlockCfg("gqa", "dense"),),
    pattern_repeats=32,
    rope_theta=10_000.0,
    emb_staleness=1,
)
