"""Online-learning serving subsystem (paper §1/§3: the recommender serves
live traffic while the trainer continuously updates the same embedding
state, bounded staleness as the native consistency model).

Four pieces close the serve -> train -> serve loop:

* :class:`~repro.serving.service.ServingService` — micro-batched inference
  against the live training backend (flush on ``max_batch`` or
  ``max_wait_ms``), reading embeddings through the read-only
  ``EmbeddingBackend.read_rows`` path.
* :class:`~repro.serving.service.StateCell` — the shared trainer-state
  cell both sides synchronize on.
* :mod:`repro.serving.traffic` — power-law (Zipf) traffic over a simulated
  million-user id distribution, with configurable QPS and arrival jitter.
* :mod:`repro.serving.feedback` — click labels from the planted logistic
  ground truth, queued back into the trainer's input stream.

``repro.launch.online`` drives the whole loop; ``benchmarks/
serving_latency.py`` pins p50/p99/QPS vs the latency-budget knobs.
"""
from repro.serving.feedback import ClickModel, FeedbackQueue
from repro.serving.service import ServingConfig, ServingService, StateCell
from repro.serving.traffic import TrafficGenerator, TrafficModel

__all__ = [
    "ClickModel", "FeedbackQueue", "ServingConfig", "ServingService",
    "StateCell", "TrafficGenerator", "TrafficModel",
]
