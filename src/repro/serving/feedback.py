"""Click feedback: served predictions become labeled training examples.

The online-learning loop of the paper (§1: models must be updated in
real-time, trained and served against the same embedding state) needs a
ground truth to click against. :class:`ClickModel` samples Bernoulli
clicks from the SAME planted logistic model that labels the offline
stream (``CTRDataset.truth()``), so the trainer consuming served feedback
chases the identical target as one reading the offline sampler — the
closed loop is then a pure systems question, not a distribution shift.

:class:`FeedbackQueue` is the serve -> train conduit: serving threads
``put`` labeled examples, the trainer thread ``next_batch``-es fixed-size
training batches off the other end.
"""
from __future__ import annotations

import threading
from collections import deque

import numpy as np

from repro.data.ctr import CTRDataset, PlantedTruth


class ClickModel:
    """Seeded, thread-safe Bernoulli clicks from a planted logistic truth.

    Deterministic as a *sequence*: the i-th label drawn through one
    ClickModel is reproducible, whichever thread draws it (the rng is
    guarded, the draw order is the arrival order)."""

    def __init__(self, truth: PlantedTruth, seed: int = 0):
        self.truth = truth
        self._rng = np.random.default_rng((seed, 17))
        self._lock = threading.Lock()

    @staticmethod
    def for_dataset(ds: CTRDataset, seed: int | None = None) -> "ClickModel":
        return ClickModel(ds.truth(), ds.seed if seed is None else seed)

    def prob(self, ids: np.ndarray, dense: np.ndarray | None = None
             ) -> np.ndarray:
        """(B, n_tasks) true click probabilities for batched requests."""
        return self.truth.prob(ids, dense)

    def click(self, request: dict) -> np.ndarray:
        """Label ONE served request — (n_tasks,) float32 in {0, 1}."""
        ids = np.asarray(request["ids"], np.int64)[None]
        dense = request.get("dense")
        p = self.truth.prob(ids, None if dense is None
                            else np.asarray(dense, np.float32)[None])[0]
        with self._lock:
            u = self._rng.random(p.shape)
        return (u < p).astype(np.float32)


class FeedbackQueue:
    """Bounded conduit of labeled examples from serving into training.

    Serving side: ``put(request, label)`` per served impression (oldest
    examples are dropped once ``capacity`` is exceeded — online learning
    trains on the freshest feedback, backlog is stale by definition).
    Trainer side: ``next_batch(timeout)`` blocks for a full batch in
    sampler format ({ids, labels[, dense]}) or returns None on timeout.
    """

    def __init__(self, batch_size: int, *, capacity: int | None = None):
        self.batch_size = int(batch_size)
        self.capacity = int(capacity) if capacity else 64 * self.batch_size
        self._cond = threading.Condition()
        self._buf: deque = deque(maxlen=self.capacity)
        self._put = 0
        self._dropped = 0
        self._closed = False

    def put(self, request: dict, label: np.ndarray):
        """Enqueue one labeled impression."""
        with self._cond:
            if len(self._buf) == self.capacity:
                self._dropped += 1
            self._buf.append((request, np.asarray(label, np.float32)))
            self._put += 1
            if len(self._buf) >= self.batch_size:
                self._cond.notify_all()

    def put_many(self, requests, labels):
        for req, lab in zip(requests, labels):
            self.put(req, lab)

    def close(self):
        """Wake any blocked trainer; subsequent next_batch drains then
        returns None."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    def __len__(self) -> int:
        with self._cond:
            return len(self._buf)

    @property
    def stats(self) -> dict:
        with self._cond:
            return {"put": self._put, "dropped": self._dropped,
                    "pending": len(self._buf)}

    def next_batch(self, timeout: float | None = 1.0) -> dict | None:
        """Pop ``batch_size`` examples as one training batch, blocking up
        to ``timeout`` seconds for enough feedback; None if starved."""
        with self._cond:
            if not self._cond.wait_for(
                    lambda: len(self._buf) >= self.batch_size
                    or self._closed, timeout=timeout):
                return None
            if len(self._buf) < self.batch_size:
                return None
            pairs = [self._buf.popleft() for _ in range(self.batch_size)]
        ids = np.stack([np.asarray(r["ids"], np.int32) for r, _ in pairs])
        labels = np.stack([lab for _, lab in pairs])
        batch = {"ids": ids, "labels": labels.astype(np.float32)}
        if "dense" in pairs[0][0]:
            batch["dense"] = np.stack(
                [np.asarray(r["dense"], np.float32) for r, _ in pairs])
        return batch
