"""Micro-batched serving against the live training backend.

Many client threads submit single requests; an aggregator thread flushes
them as one micro-batch when either ``max_batch`` requests are queued or
the oldest has waited ``max_wait_ms`` — the paper's serving tier trades a
bounded queueing delay for batched device efficiency ("Understanding
Capacity-Driven Scale-Out Neural Recommendation Inference" grounds the
micro-batching / tail-latency framing).

The flush reads embeddings through ``PersiaTrainer.serve_lookup`` — the
read-only ``EmbeddingBackend.read_rows`` path (no fault-in, no eviction,
slots pinned across the gather) — against the :class:`StateCell` snapshot,
so the SAME backend the trainer writes serves inference, in-process or
remote. Inference and trainer steps serialize on the cell's lock: the
trainer's decomposed step donates its state buffers to XLA, so a serve
read dispatched concurrently against the pre-donation arrays could hit a
deleted buffer — the lock is the happens-before edge that makes snapshot
reads well-defined (and makes the staleness gauge exact: a read under the
lock sees the published step's state, plus whatever lag each table's
bounded-staleness queue holds).
"""
from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import Future
from dataclasses import dataclass

import jax
import numpy as np


class StateCell:
    """Lock-protected holder of the latest published ``(TrainState, step)``.

    The trainer loop runs each step AND the publish under ``cell.lock``;
    the serving flush snapshots, reads and dispatches its predict under
    the same lock. That serializes device dispatch between the two sides —
    required because the trainer's decomposed jits donate the state
    buffers — and pins the snapshot's step for the staleness gauge.
    """

    def __init__(self, state=None, step: int = 0):
        self.lock = threading.RLock()
        self._state = state
        self._step = int(step)

    def publish(self, state, step: int | None = None):
        with self.lock:
            self._state = state
            self._step = int(state.step) if step is None else int(step)

    def snapshot(self):
        """(state, step) of the latest publish."""
        with self.lock:
            return self._state, self._step

    @property
    def step(self) -> int:
        with self.lock:
            return self._step


@dataclass(frozen=True)
class ServingConfig:
    """Latency-budget knobs: flush on whichever comes first."""
    max_batch: int = 16          # flush when this many requests are queued
    max_wait_ms: float = 2.0     # ... or when the oldest waited this long
    timeout_s: float = 30.0      # per-request result timeout
    latency_window: int = 8192   # ring of per-request latencies (p50/p99)


@dataclass
class _Pending:
    request: dict
    future: Future
    t_submit: float


class ServingStopTimeout(RuntimeError):
    """``stop()`` could not confirm the flush loop exited: the queue was
    deliberately NOT drained (the loop may still be flushing it)."""


def queue_lag(q, step: int, tau: int) -> int:
    """Staleness-queue lag of one table: how many steps of applied updates
    the queue is still holding back. In-process queues expose ``filled``
    (live: 0 during warmup, tau at steady state); a remote table's queue is
    PS-side state behind a zero-byte client placeholder, so its lag is
    bounded by ``min(step, tau)``."""
    if q is None or tau <= 0:
        return 0
    if "ids" not in q:                     # sharded router: per-shard queues
        return max((queue_lag(v, step, tau) for v in q.values()), default=0)
    if int(np.prod(q["ids"].shape[1:])) == 0 or "filled" not in q:
        return min(int(step), int(tau))    # remote placeholder: the bound
    return int(q["filled"])


class ServingService:
    """Micro-batch aggregator over a shared trainer/backend.

    >>> cell = StateCell(state, 0)
    >>> svc = ServingService(trainer, cell, ServingConfig(8, 2.0)).start()
    >>> preds = svc.predict({"ids": ids_FL, "dense": dense_nd})
    >>> svc.metrics()["serving/p99_ms"]
    >>> svc.stop()

    Requests are dicts with ``ids`` of shape (n_fields, ids_per_field)
    (int, -1 padded) and optionally ``dense`` (n_dense,). Micro-batches
    are padded to ``max_batch`` with -1 id rows so the predict jit
    compiles once; pad predictions are discarded.
    """

    def __init__(self, trainer, cell: StateCell,
                 config: ServingConfig | None = None):
        if trainer.adapter.predict is None:
            raise ValueError("serving needs an adapter with a predict fn")
        self.trainer = trainer
        self.cell = cell
        self.config = config or ServingConfig()
        self._cond = threading.Condition()
        self._queue: deque[_Pending] = deque()
        self._running = False
        self._thread: threading.Thread | None = None
        self._predict_jit = jax.jit(trainer.adapter.predict)
        self._taus = {n: int(s.staleness)
                      for n, s in trainer.collection.items()}
        self._m_lock = threading.Lock()
        self._lat_ms = deque(maxlen=int(self.config.latency_window))
        self._requests = 0
        self._batches = 0
        self._errors = 0
        self._fill_sum = 0.0
        self._wait_ms_sum = 0.0
        self._t_first = None
        self._t_last = None
        self._tables = {n: {"hits": 0, "reads": 0, "stale_max": 0,
                            "stale_last": 0}
                        for n in trainer.collection.names}

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> "ServingService":
        if self._thread is not None:
            raise RuntimeError("service already started")
        self._running = True
        self._thread = threading.Thread(target=self._loop,
                                        name="serving-flush", daemon=True)
        self._thread.start()
        return self

    def stop(self):
        with self._cond:
            self._running = False
            self._cond.notify_all()
        thread, self._thread = self._thread, None
        if thread is not None:
            thread.join(timeout=self.config.timeout_s)
            if thread.is_alive():
                # the flush loop is stuck mid-flush (a wedged device or a
                # lock the trainer never released). Draining now would
                # race it over the same deque and double-flush — surface
                # the hang instead; queued futures will resolve if the
                # flush ever completes, or time out client-side.
                raise ServingStopTimeout(
                    f"serving flush thread still alive after "
                    f"{self.config.timeout_s}s; {len(self._queue)} queued "
                    "requests left un-drained")
        # the loop is confirmed dead: drain stragglers so no submitted
        # request is ever lost
        while True:
            with self._cond:
                take = [self._queue.popleft()
                        for _ in range(min(len(self._queue),
                                           self.config.max_batch))]
            if not take:
                break
            self._flush(take)

    def __enter__(self) -> "ServingService":
        return self.start()

    def __exit__(self, *exc):
        self.stop()

    # -- client surface ------------------------------------------------------

    def submit(self, request: dict) -> Future:
        """Enqueue one request; the future resolves to its (n_tasks,)
        fp32 prediction once its micro-batch flushes."""
        p = _Pending(request, Future(), time.monotonic())
        with self._cond:
            if not self._running:
                raise RuntimeError("service not running")
            self._queue.append(p)
            self._cond.notify_all()
        with self._m_lock:
            if self._t_first is None:
                self._t_first = p.t_submit
        return p.future

    def predict(self, request: dict, timeout: float | None = None):
        """Blocking single-request predict."""
        return self.submit(request).result(
            timeout or self.config.timeout_s)

    def predict_many(self, requests) -> np.ndarray:
        """Submit a burst and gather all results — (n, n_tasks)."""
        futs = [self.submit(r) for r in requests]
        return np.stack([f.result(self.config.timeout_s) for f in futs])

    # -- aggregator ----------------------------------------------------------

    def _loop(self):
        cfg = self.config
        while True:
            with self._cond:
                while self._running and not self._queue:
                    self._cond.wait(timeout=0.1)
                if not self._running:
                    return
                deadline = self._queue[0].t_submit + cfg.max_wait_ms / 1e3
                while self._running and len(self._queue) < cfg.max_batch:
                    left = deadline - time.monotonic()
                    if left <= 0:
                        break
                    self._cond.wait(timeout=left)
                take = [self._queue.popleft()
                        for _ in range(min(len(self._queue), cfg.max_batch))]
            if take:
                self._flush(take)

    def _pad_batch(self, take: list[_Pending]) -> dict:
        B = self.config.max_batch
        r0 = take[0].request
        ids0 = np.asarray(r0["ids"], np.int32)
        ids = np.full((B,) + ids0.shape, -1, np.int32)
        batch = {"ids": ids}
        if "dense" in r0:
            batch["dense"] = np.zeros(
                (B,) + np.shape(np.asarray(r0["dense"], np.float32)),
                np.float32)
        for i, p in enumerate(take):
            ids[i] = np.asarray(p.request["ids"], np.int32)
            if "dense" in batch:
                batch["dense"][i] = np.asarray(p.request["dense"],
                                               np.float32)
        return batch

    def _flush(self, take: list[_Pending]):
        """Flush one micro-batch. Never raises: a failed lookup/predict
        resolves every waiting future with the exception (a client
        blocked in ``predict`` would otherwise hang until its timeout)
        and counts ``serving/errors`` — the aggregator loop stays alive
        for the next batch."""
        try:
            self._flush_inner(take)
        except Exception as e:   # noqa: BLE001
            with self._m_lock:
                self._errors += 1
            for p in take:
                if not p.future.done():
                    p.future.set_exception(e)

    def _flush_inner(self, take: list[_Pending]):
        t_flush = time.monotonic()
        batch = self._pad_batch(take)
        trainer = self.trainer
        # snapshot + read + predict dispatch all under the cell lock: the
        # trainer cannot donate these buffers mid-read, and the staleness
        # gauge is exact (see module doc)
        with self.cell.lock:
            state, snap_step = self.cell.snapshot()
            acts, read_info = trainer.serve_lookup(state, batch)
            preds = np.asarray(
                self._predict_jit(state.dense, acts, batch), np.float32)
            lags = {n: queue_lag(state.emb_queue.get(n), snap_step,
                                 self._taus[n])
                    for n in self._tables}
            live_step = self.cell.step
        stale = {n: (live_step - snap_step) + lags[n] for n in lags}
        t_done = time.monotonic()
        for i, p in enumerate(take):
            p.future.set_result(preds[i])
        with self._m_lock:
            self._requests += len(take)
            self._batches += 1
            self._fill_sum += len(take) / self.config.max_batch
            for p in take:
                self._wait_ms_sum += (t_flush - p.t_submit) * 1e3
                self._lat_ms.append((t_done - p.t_submit) * 1e3)
            self._t_last = t_done
            for n, t in self._tables.items():
                inf = read_info.get(n, {})
                t["hits"] += int(inf.get("hits", 0))
                t["reads"] += int(inf.get("reads", 0))
                t["stale_last"] = int(stale[n])
                t["stale_max"] = max(t["stale_max"], int(stale[n]))

    # -- metrics -------------------------------------------------------------

    def metrics(self) -> dict:
        """Step-metrics-namespace gauges:
        ``serving/<table>/{hit_rate,stale_steps,batch_fill,wait_ms}`` plus
        the service-wide ``serving/{p50_ms,p99_ms,qps,requests,batches}``.
        ``stale_steps`` is the max observed (trainer step at write minus
        at read, plus the table's queue lag) — sync tables must read 0,
        hybrid tables at most tau."""
        with self._m_lock:
            lat = np.asarray(self._lat_ms, np.float64)
            out = {
                "serving/requests": float(self._requests),
                "serving/batches": float(self._batches),
                "serving/errors": float(self._errors),
                "serving/p50_ms": float(np.percentile(lat, 50))
                if lat.size else 0.0,
                "serving/p99_ms": float(np.percentile(lat, 99))
                if lat.size else 0.0,
            }
            span = ((self._t_last - self._t_first)
                    if (self._t_first is not None
                        and self._t_last is not None) else 0.0)
            out["serving/qps"] = (self._requests / span) if span > 0 else 0.0
            fill = (self._fill_sum / self._batches) if self._batches else 0.0
            wait = (self._wait_ms_sum / self._requests) if self._requests \
                else 0.0
            for n, t in self._tables.items():
                out[f"serving/{n}/hit_rate"] = (
                    t["hits"] / t["reads"]) if t["reads"] else 1.0
                out[f"serving/{n}/stale_steps"] = float(t["stale_max"])
                out[f"serving/{n}/batch_fill"] = fill
                out[f"serving/{n}/wait_ms"] = wait
            return out
