"""Power-law serving traffic: a simulated million-user id distribution.

The paper's motivating deployments serve live recommendation traffic whose
id popularity is sharply Zipfian (§2: the alpha << 1 access-skew regime
that makes caching/staleness tractable at all). This module replays that
shape: each request is drawn from a fixed population of ``n_users``
synthetic users, user popularity follows the same bounded inverse-CDF Zipf
the offline sampler uses, and each user has a deterministic feature
profile — so a hot user hits the same embedding rows on every visit and
the serve-path cache/staleness metrics mean what they would in production.

``TrafficGenerator`` turns the request stream into timed arrivals at a
configurable QPS with multiplicative jitter, for open-loop latency runs.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from repro.data.ctr import CTRDataset


def zipf_ranks(u: np.ndarray, n: int, a: float) -> np.ndarray:
    """Bounded Zipf(a) over [0, n) via the same rejection-free inverse-CDF
    approximation as ``CTRDataset.sampler`` — uniform draws ``u`` in [0,1)
    map to ranks, rank 0 hottest."""
    ranks = np.floor(((n ** (1 - a) - 1) * u + 1) ** (1 / (1 - a)) - 1)
    return np.clip(ranks, 0, n - 1).astype(np.int64)


@dataclass(frozen=True)
class TrafficModel:
    """Deterministic user-population model over a dataset's feature space.

    A user id fully determines the request: ``request_for(uid)`` seeds a
    per-user rng with ``(seed, uid)``, so replaying a uid replays its ids
    and dense features bit-for-bit. The *sequence* of uids is the Zipf
    draw — hot users recur, cold users are near-singletons.
    """

    n_fields: int
    ids_per_field: int
    rows_per_field: int
    n_dense: int
    n_users: int = 1_000_000
    zipf_a: float = 1.2
    seed: int = 0

    @staticmethod
    def for_dataset(ds: CTRDataset, n_users: int = 1_000_000,
                    seed: int | None = None) -> "TrafficModel":
        return TrafficModel(
            n_fields=ds.n_fields, ids_per_field=ds.ids_per_field,
            rows_per_field=ds.rows_per_field, n_dense=ds.n_dense,
            n_users=n_users, zipf_a=ds.zipf_a,
            seed=ds.seed if seed is None else seed)

    def user_ids(self, n: int, *, seed: int = 0) -> np.ndarray:
        """Draw ``n`` visiting users — Zipf over the population, so a few
        user ids dominate (the serving hot set)."""
        rng = np.random.default_rng((self.seed, seed))
        return zipf_ranks(rng.random(n), self.n_users, self.zipf_a)

    def request_for(self, uid: int) -> dict:
        """The user's deterministic feature profile: ``ids`` of shape
        (n_fields, ids_per_field) with -1 multi-hot padding, plus
        ``dense`` (n_dense,) when the dataset has dense features."""
        rng = np.random.default_rng((self.seed, int(uid)))
        # the user's ids are themselves Zipf within each field's table, so
        # hot users and hot rows compound the way production logs do
        ids = zipf_ranks(rng.random((self.n_fields, self.ids_per_field)),
                         self.rows_per_field, self.zipf_a)
        lens = rng.integers(1, self.ids_per_field + 1, self.n_fields)
        mask = np.arange(self.ids_per_field)[None, :] < lens[:, None]
        req = {"ids": np.where(mask, ids, -1).astype(np.int32)}
        if self.n_dense:
            req["dense"] = rng.standard_normal(self.n_dense) \
                .astype(np.float32)
        return req

    def requests(self, n: int, *, seed: int = 0):
        """``n`` (uid, request) pairs in visit order — deterministic in
        (model seed, stream seed)."""
        for uid in self.user_ids(n, seed=seed):
            yield int(uid), self.request_for(int(uid))


@dataclass(frozen=True)
class TrafficGenerator:
    """Open-loop arrival process: target ``qps`` with multiplicative
    ``jitter`` on each inter-arrival gap (0 = strict pacing, 1 = gaps
    uniform in [0, 2/qps))."""

    model: TrafficModel
    qps: float = 200.0
    jitter: float = 0.5
    seed: int = 0

    def arrivals(self, n: int):
        """``n`` (t_offset_s, uid, request) tuples; offsets start at 0 and
        are non-decreasing."""
        rng = np.random.default_rng((self.seed, 1))
        gap = 1.0 / max(self.qps, 1e-9)
        scale = 1.0 + self.jitter * (2.0 * rng.random(n) - 1.0)
        t = np.concatenate([[0.0], np.cumsum(gap * scale)[:-1]])
        for off, (uid, req) in zip(t, self.model.requests(n,
                                                          seed=self.seed)):
            yield float(off), uid, req

    def replay(self, n: int, submit, *, clock=time.monotonic,
               sleep=time.sleep):
        """Pace ``n`` requests in wall-clock time: sleeps to each arrival
        offset and calls ``submit(request)``; returns the submit results
        in arrival order. Falls behind gracefully (never sleeps a negative
        gap) so a slow service degrades to closed-loop."""
        t0 = clock()
        out = []
        for off, _uid, req in self.arrivals(n):
            lag = (t0 + off) - clock()
            if lag > 0:
                sleep(lag)
            out.append(submit(req))
        return out
