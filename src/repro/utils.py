"""Small shared utilities: pytree helpers, sharding helpers, dtype policy."""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

PyTree = Any


def tree_size(tree: PyTree) -> int:
    """Total number of scalar parameters in a pytree."""
    return sum(x.size for x in jax.tree.leaves(tree))


def tree_bytes(tree: PyTree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_paths(tree: PyTree) -> list[str]:
    """Flat list of '/'-joined key paths for a pytree of dicts/lists."""
    paths, _ = zip(*jax.tree_util.tree_flatten_with_path(tree)[0]) if jax.tree.leaves(tree) else ((), ())
    return [jax.tree_util.keystr(p) for p in paths]


def map_with_path(fn: Callable[[str, Any], Any], tree: PyTree) -> PyTree:
    """Map fn(path_str, leaf) over a pytree."""
    def _fn(path, leaf):
        return fn(jax.tree_util.keystr(path), leaf)
    return jax.tree_util.tree_map_with_path(_fn, tree)


# ---------------------------------------------------------------------------
# Sharding helper: apply a constraint only when the abstract mesh in scope
# actually carries the axis names (so model code runs unchanged on a bare CPU).
# ---------------------------------------------------------------------------

def _mesh_axis_names() -> tuple[str, ...]:
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:  # pragma: no cover - very old jax
        return ()
    if mesh is None or getattr(mesh, "empty", True):
        return ()
    return tuple(mesh.axis_names)


def shard(x: jax.Array, *spec: Any) -> jax.Array:
    """with_sharding_constraint(x, P(*spec)) if the axes exist in scope.

    Axis entries may be None, a name, or a tuple of names. Entries whose
    name(s) are not present in the current mesh are dropped to None, so the
    same model code lowers under (data, model), (pod, data, model), or no
    mesh at all. Entries that do not evenly divide the corresponding dim are
    dropped too (e.g. 8 kv heads over a 16-way model axis) — a conflicting
    constraint there would force SPMD full-rematerialisation copies.
    """
    names = _mesh_axis_names()
    if not names:
        return x
    mesh = jax.sharding.get_abstract_mesh()

    def _nshards(entry) -> int:
        if isinstance(entry, (tuple, list)):
            n = 1
            for e in entry:
                n *= mesh.shape[e]
            return n
        return mesh.shape[entry]

    def _filter(entry, dim):
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(e for e in entry if e in names)
            entry = kept if kept else None
        else:
            entry = entry if entry in names else None
        if entry is not None and dim % _nshards(entry) != 0:
            return None
        return entry

    cleaned = tuple(_filter(e, x.shape[i]) for i, e in enumerate(spec))
    if all(c is None for c in cleaned):
        return x
    return jax.lax.with_sharding_constraint(x, P(*cleaned))


def batch_axes() -> tuple[str, ...]:
    """Mesh axes over which the batch is sharded ('pod' first when present)."""
    names = _mesh_axis_names()
    return tuple(n for n in ("pod", "data") if n in names)


def n_batch_shards() -> int:
    axes = batch_axes()
    if not axes:
        return 1
    mesh = jax.sharding.get_abstract_mesh()
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def bspec_axes(dim_size: int):
    """Batch axes tuple if dim_size divides over them, else None (replicate).
    Handles B=1 decode shapes on many-shard meshes."""
    axes = batch_axes()
    if not axes or dim_size % n_batch_shards() != 0:
        return None
    return axes


# ---------------------------------------------------------------------------
# Dtype policy
# ---------------------------------------------------------------------------

class Policy:
    """Mixed-precision policy: param storage / compute / accumulation dtypes."""

    def __init__(self, param_dtype=jnp.float32, compute_dtype=jnp.float32,
                 accum_dtype=jnp.float32):
        self.param_dtype = jnp.dtype(param_dtype)
        self.compute_dtype = jnp.dtype(compute_dtype)
        self.accum_dtype = jnp.dtype(accum_dtype)

    def cast_compute(self, tree: PyTree) -> PyTree:
        return jax.tree.map(
            lambda x: x.astype(self.compute_dtype)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)

    @staticmethod
    def from_name(name: str) -> "Policy":
        if name == "f32":
            return Policy()
        if name == "bf16":
            return Policy(jnp.bfloat16, jnp.bfloat16, jnp.float32)
        if name == "bf16_f32params":
            return Policy(jnp.float32, jnp.bfloat16, jnp.float32)
        raise ValueError(f"unknown policy {name!r}")


def default_field_rows(total_rows: int, n_fields: int) -> int:
    """Rows of each field's id space when one flat row budget is split
    evenly over fields — the single source of the formula shared by
    CTRDataset (id generation) and ctr_collection (table sizing)."""
    return max(total_rows // max(n_fields, 1), 4)


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b
