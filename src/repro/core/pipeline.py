"""Async pipelined execution of the hybrid trainer (paper §4, Fig. 4–5).

Persia's system contribution is not only the hybrid algorithm but its
*pipelined* execution: the embedding get, the dense compute and the
embedding put of different microbatches run concurrently across workers, so
the memory-bound embedding path hides behind the compute-bound dense path.
:class:`~repro.core.hybrid.PersiaTrainer` runs ``prepare → lookup → dense →
put`` strictly serially per batch; this module runs the same four dispatches
(plus the data loader and an optional prefetch stage) as a bounded pipeline:

    loader ──q──▶ prefetch ──q──▶ prepare ──q──▶ lookup ──q──▶ dense ──q──▶ put
    (batches)    (look-ahead     (host fault-in  (jitted)     (jitted,   (jitted,
                  fault-in)       or passthrough)              donated)   donated)

With ``prefetch=k > 0`` the host fault-in moves into the prefetch stage,
which may run up to ``k`` batches AHEAD of the inflight window: step
``t+k``'s unique rows fault host→device while step ``t`` is still in its
dense compute, hiding host-store latency (the disk tier's, in particular)
behind training. Prefetched slots are pinned from the prefetch until the
batch's applied put, so the deeper horizon can never recycle an in-flight
row; ``cache_rows`` must cover the combined ``max_inflight + prefetch``
working set. ``prefetch=0`` (the default) keeps the fault-in inside the
prepare stage — the prefetch stage is a passthrough and dispatch order is
unchanged, bit for bit.

The same overlap extends to *remote* tables (``repro.net.remote``): there
``prepare_all`` submits every table's fault-in as one coalesced
``step_ops`` frame per PS endpoint and collects the replies together, so a
prefetching pipeline holds up to ``k`` remote fault-ins in flight per
endpoint — the PS round-trip hides behind the dense compute exactly like
the disk tier's latency does, and the put path's outstanding-ack window
(bounded by tau) keeps the paper's staleness contract while doing it.

Each stage is a thread; bounded queues carry up to ``max_inflight``
microbatches, so the host ``prepare`` phase (the out-of-core fault-in of the
``host_lru`` backend — the memory-bound leg) of step *t+1* overlaps the
jitted dense step of step *t*. Three invariants are enforced:

* **Bounded staleness, by backpressure.** Per table, the number of puts
  outstanding — batches past their lookup whose ``emb_put`` has not been
  applied — never exceeds ``min(max_inflight, tau)`` (and exactly 1 for
  synchronous tables, tau=0, which must never read past an unapplied put).
  A counting semaphore blocks the lookup stage instead of dropping puts.
  The windows are per (table, PS shard): a sharded table
  (``EmbeddingSpec.emb_shards > 1``) gets one window per shard. For
  *synchronous* sharded tables (tau=0) a batch only consumes windows of
  shards it actually routed ids to — a put is a true no-op on untouched
  shards, so batches touching disjoint shards overlap where a table-wide
  window would serialize them (disjoint shards share no rows). For
  *hybrid* sharded tables (tau>0) every batch charges every shard's
  window: the router advances every shard's FIFO on every put (a queued
  shard-s gradient is applied tau puts later regardless of who routed ids
  to s), so only full-window accounting preserves the hard
  ``tau + min(max_inflight, tau)`` staleness bound.
  Note the pipeline window is *additional* read staleness on top of the
  device-side FIFO's algorithmic tau: a lookup can observe parameters up
  to ``tau + min(max_inflight, tau)`` updates old (bounded by ``2*tau``) —
  the same shape of total asynchrony a real PS deployment has, and still a
  hard bound, but wider than the serial trainer's; set ``max_inflight=1``
  where the exact serial staleness matters.
* **Sequenced table state.** The emb pytree and staleness queues are
  versioned through a single table store: every emb-touching dispatch
  (prepare's fault-in scatter, the lookup snapshot, the donated put) happens
  under the store lock, so puts are applied in batch order, no put is
  dropped by the engine, and a donated buffer is never re-dispatched. The
  dense/opt/optimizer-queue state is owned solely by the dense stage.
  Host-backed tables additionally *pin* each in-flight batch's cache slots
  (prepare → applied put), so a deep pipeline's fault-ins can never recycle
  a row a pending lookup or put still targets; if the combined in-flight
  working set cannot fit the cache, the fault-in raises instead of silently
  reading wrong rows.
* **Fail fast.** Any stage exception stops the pipeline and re-raises from
  ``run()`` as :class:`PipelineStageError` naming the stage and step —
  queues and semaphores are polled against a stop event, so a dead
  downstream stage cannot hang its producers.

With ``max_inflight=1`` the permit cycle (prepare acquires, put releases)
reproduces the serial order of ``PersiaTrainer.decomposed_step`` exactly —
same jitted fns, same dispatch order — so the result is bit-exact with the
serial trainer for every mode and backend; that is the determinism contract
``tests/test_pipeline.py`` pins.

Per-stage timing/occupancy flows out of :meth:`PipelinedTrainer.
pipeline_metrics` as ``pipeline/<stage>/busy_s`` / ``.../queue_depth_*``;
``delay_fn(stage, step) -> seconds`` injects per-stage latency (simulated
host RPCs in ``benchmarks/pipeline.py``, seeded jitter in the stress
tests). ``PersiaTrainer.run`` accepts the same ``delay_fn`` and pays the
delays serially, which is what makes the serial-vs-pipelined benchmark an
apples-to-apples comparison.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any, Callable, Iterable, Optional

from repro.core import backend as BK
from repro.core.dedup import plan_dev
from repro.core.hybrid import PersiaTrainer, TrainState

STAGES = ("loader", "prefetch", "prepare", "lookup", "dense", "put")

_DONE = object()          # end-of-stream sentinel flowing through the queues
_TICK = 0.02              # poll period for stop-aware queue/semaphore waits


class PipelineStageError(RuntimeError):
    """A pipeline stage raised; carries the stage name, step and cause."""

    def __init__(self, stage: str, step: int, original: BaseException):
        super().__init__(
            f"pipeline stage {stage!r} failed at step {step}: "
            f"{type(original).__name__}: {original}")
        self.stage = stage
        self.step = step
        self.original = original


class _StageStats:
    """Per-stage busy time + items + input-queue depth accounting."""

    def __init__(self):
        self.busy_s = 0.0
        self.items = 0
        self.depth_max = 0
        self.depth_sum = 0
        self.depth_samples = 0

    def sample_depth(self, depth: int):
        self.depth_max = max(self.depth_max, depth)
        self.depth_sum += depth
        self.depth_samples += 1


class PipelinedTrainer:
    """Bounded multi-stage pipeline over ``PersiaTrainer.decomposed_fns()``.

    >>> trainer = PersiaTrainer(adapter, TrainMode.hybrid(3), opt)
    >>> engine = PipelinedTrainer(trainer, max_inflight=4)
    >>> state = engine.init(jax.random.PRNGKey(0), batch)     # delegated
    >>> state, metrics = engine.run(state, batches)           # pipelined
    >>> engine.pipeline_metrics()["pipeline/prepare/busy_s"]
    >>> engine.eval(state, batch); engine.save(d, state)      # delegated

    ``init`` / ``eval`` / ``save`` / ``restore`` (and ``step`` /
    ``decomposed_step`` / ``lookup`` / ``predict``) delegate to the wrapped
    trainer, so the engine is a drop-in for the serial facade wherever the
    stream-level ``run`` replaces the per-batch step. ``run()`` owns the
    train state while it executes: don't eval/save concurrently.
    """

    def __init__(self, trainer: PersiaTrainer, max_inflight: int = 4,
                 delay_fn: Optional[Callable[[str, int], float]] = None,
                 prefetch: int = 0):
        if not isinstance(trainer, PersiaTrainer):
            raise TypeError(
                "PipelinedTrainer wraps a PersiaTrainer (build one first); "
                f"got {type(trainer).__name__}")
        if max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1 "
                             f"(got {max_inflight})")
        if prefetch < 0:
            raise ValueError(f"prefetch must be >= 0 (got {prefetch})")
        self.trainer = trainer
        self.max_inflight = int(max_inflight)
        # prefetch > 0 moves the host fault-in (BK.prepare_all + slot
        # pinning) into a dedicated stage that may run up to ``prefetch``
        # batches AHEAD of the inflight window: step t+prefetch's rows
        # fault host->device while step t is still training. The faulted
        # slots stay pinned from prefetch until the batch's applied put,
        # so a deeper horizon can never recycle an in-flight row —
        # ``cache_rows`` must cover the combined (max_inflight + prefetch)
        # working set or the fault-in raises. prefetch=0 keeps the
        # fault-in inside the prepare stage (the pre-prefetch behaviour,
        # bit for bit).
        self.prefetch = int(prefetch)
        self.delay_fn = delay_fn
        self._stats: dict[str, _StageStats] = {}
        self._wall_s = 0.0
        self._steps_done = 0
        self.max_outstanding: dict[str, int] = {}
        self.applied_order: list[int] = []
        self._running = False

    # -- delegated PersiaTrainer surface --------------------------------------

    @property
    def adapter(self):
        return self.trainer.adapter

    @property
    def mode(self):
        return self.trainer.mode

    @property
    def collection(self):
        return self.trainer.collection

    @property
    def backends(self):
        return self.trainer.backends

    def init(self, key, batch_example=None, emb_shards=1) -> TrainState:
        return self.trainer.init(key, batch_example, emb_shards)

    def step(self, state, batch):
        return self.trainer.step(state, batch)

    def decomposed_step(self, state, batch):
        return self.trainer.decomposed_step(state, batch)

    def eval(self, state, batch):
        return self.trainer.eval(state, batch)

    def lookup(self, state, batch):
        return self.trainer.lookup(state, batch)

    def predict(self, state, batch):
        return self.trainer.predict(state, batch)

    def save(self, directory: str, state: TrainState,
             step: int | None = None) -> str:
        return self.trainer.save(directory, state, step)

    def restore(self, directory: str, step: int | None = None) -> TrainState:
        return self.trainer.restore(directory, step)

    # -- the staleness window -------------------------------------------------

    def put_window(self, name: str) -> int:
        """Max puts outstanding (post-lookup, pre-apply) for one table: the
        pipeline may run at most ``tau`` lookups ahead of the last applied
        put (1 for synchronous tables — sync means no un-applied put is
        ever read past), and never more than ``max_inflight``."""
        tau = self.trainer.collection[name].staleness
        return 1 if tau <= 0 else min(self.max_inflight, tau)

    # -- the pipelined loop ---------------------------------------------------

    def run(self, state: TrainState, batches: Iterable[Any],
            steps: int | None = None,
            delay_fn: Optional[Callable[[str, int], float]] = None
            ) -> tuple[TrainState, list[dict]]:
        """Drive ``batches`` (an iterable of batch dicts, optionally capped
        at ``steps``) through the five-stage pipeline; returns the final
        state and the per-step metrics in batch order."""
        if self._running:
            raise RuntimeError("run() is not reentrant: this engine is "
                               "already driving a pipeline")
        delay_fn = delay_fn if delay_fn is not None else self.delay_fn
        trainer = self.trainer
        lookup_fn, dense_step, emb_put = trainer.decomposed_fns()
        adapter, backends = trainer.adapter, trainer.backends
        names = trainer.collection.names

        # shared cells: the table store (emb + staleness queues; every
        # touching dispatch is serialized by store_lock) and the dense cell
        # (owned by the dense stage alone, no lock needed)
        store = {"emb": state.emb, "queues": state.emb_queue}
        store_lock = threading.Lock()
        dense_cell = {"dense": state.dense, "opt": state.opt,
                      "queue": state.dense_queue, "step": state.step}

        stop = threading.Event()
        errors: list[PipelineStageError] = []
        inflight = threading.Semaphore(self.max_inflight)
        # put backpressure is per (table, PS shard): a sharded table gets one
        # window per shard, and a batch only consumes the windows of shards
        # it actually routed ids to — batches touching disjoint shards can
        # overlap where a table-wide window would have serialized them.
        # Unsharded tables have exactly one shard (0), reproducing the old
        # per-table semantics bit for bit.
        windows = {(n, s): threading.Semaphore(self.put_window(n))
                   for n in names
                   for s in range(backends[n].n_put_shards())}
        out_lock = threading.Lock()
        outstanding = {n: 0 for n in names}
        self.max_outstanding = {n: 0 for n in names}
        self.applied_order = []
        # the prefetch horizon: how many batches may sit between
        # prefetch-start and put-applied (the global inflight window plus
        # the look-ahead depth). One semaphore bounds it; with prefetch=0
        # the prefetch stage is a passthrough and the permit is unused.
        prefetch_sem = threading.Semaphore(self.max_inflight + self.prefetch)
        self._stats = {s: _StageStats() for s in STAGES}
        qs = {s: queue.Queue(maxsize=self.max_inflight)
              for s in ("prefetch", "lookup", "dense", "put")}
        # the prepare queue buffers the look-ahead: faulted batches wait
        # here until the inflight window admits them
        qs["prepare"] = queue.Queue(
            maxsize=self.max_inflight + self.prefetch)
        results: list[tuple[int, dict]] = []

        def fail(stage: str, idx: int, exc: BaseException):
            errors.append(PipelineStageError(stage, idx, exc))
            stop.set()

        def sleep_for(stage: str, idx: int):
            if delay_fn is not None:
                d = float(delay_fn(stage, idx))
                if d > 0:
                    time.sleep(d)

        def q_put(stage_to: str, item) -> bool:
            q = qs[stage_to]
            while not stop.is_set():
                try:
                    q.put(item, timeout=_TICK)
                    self._stats[stage_to].sample_depth(q.qsize())
                    return True
                except queue.Full:
                    pass
            return False

        def q_get(stage: str):
            q = qs[stage]
            while not stop.is_set():
                try:
                    return q.get(timeout=_TICK)
                except queue.Empty:
                    pass
            return None

        def acquire(sem: threading.Semaphore) -> bool:
            while not stop.is_set():
                if sem.acquire(timeout=_TICK):
                    return True
            return False

        def loader():
            st = self._stats["loader"]
            idx = 0
            try:
                for batch in batches:
                    if steps is not None and idx >= steps:
                        break
                    if stop.is_set():
                        return
                    t0 = time.perf_counter()
                    sleep_for("loader", idx)
                    st.busy_s += time.perf_counter() - t0
                    st.items += 1
                    if not q_put("prefetch", (idx, batch)):
                        return
                    idx += 1
                q_put("prefetch", _DONE)
            except Exception as e:   # noqa: BLE001
                fail("loader", idx, e)

        def touched_shards(n, dev_ids):
            """(table, shard) windows this batch must charge. Hybrid
            (tau>0) sharded tables charge EVERY shard — their put advances
            every shard's FIFO (see module docstring); sync sharded tables
            charge only the shards the batch routed ids to (no-op puts on
            the rest); unsharded tables are their single shard 0."""
            if n not in dev_ids:
                return (0,)
            bk = backends[n]
            if bk.n_put_shards() > 1 and \
                    trainer.collection[n].staleness > 0:
                return tuple(range(bk.n_put_shards()))
            # a DedupPlan's unique dev ids touch exactly the shards the
            # occurrence stream would (dedup never changes ownership)
            return bk.put_shards(plan_dev(dev_ids[n]))

        def fault_in(batch):
            """The host fault-in: translate ids, fault rows into the
            device caches, pin this batch's cache slots until its put has
            been applied — a later batch's fault-in must not recycle rows
            a pending lookup/put still targets (a plan's unique dev ids
            ARE the batch's slot set: one pin per distinct slot). The
            touched shards are decoded here too, while the dev ids are
            fresh host-built arrays — not between the lookup stage's
            window acquire and its jitted dispatch."""
            ids = adapter.emb_ids(batch)
            with store_lock:
                emb, dev_ids, prep_m = BK.prepare_all(
                    backends, store["emb"], ids)
                store["emb"] = emb
                for n in dev_ids:
                    backends[n].pin_slots(plan_dev(dev_ids[n]))
            touched = {n: touched_shards(n, dev_ids) for n in names}
            return dev_ids, touched, prep_m

        def prefetch_stage():
            # prefetch=0: pure passthrough (no permits, no timing) — the
            # fault-in stays in prepare and dispatch order is unchanged.
            # prefetch>0: fault step t+k's rows while step t trains, ahead
            # of the inflight window but bounded by the prefetch horizon.
            st = self._stats["prefetch"]
            while True:
                item = q_get("prefetch")
                if item is None:
                    return
                if item is _DONE:
                    q_put("prepare", _DONE)
                    return
                if self.prefetch <= 0:
                    if not q_put("prepare", item):
                        return
                    st.items += 1
                    continue
                idx, batch = item
                try:
                    if not acquire(prefetch_sem):
                        return
                    t0 = time.perf_counter()
                    sleep_for("prefetch", idx)
                    dev_ids, touched, prep_m = fault_in(batch)
                    st.busy_s += time.perf_counter() - t0
                    st.items += 1
                    if not q_put("prepare", (idx, batch, dev_ids, touched,
                                             prep_m)):
                        return
                except Exception as e:   # noqa: BLE001
                    fail("prefetch", idx, e)
                    return

        def prepare():
            st = self._stats["prepare"]
            while True:
                item = q_get("prepare")
                if item is None:
                    return
                if item is _DONE:
                    q_put("lookup", _DONE)
                    return
                idx, batch = item[0], item[1]
                try:
                    # the global permit: at most max_inflight batches
                    # between prepare-start and put-applied. With one
                    # permit this pins the exact serial dispatch order.
                    if not acquire(inflight):
                        return
                    t0 = time.perf_counter()
                    sleep_for("prepare", idx)
                    if len(item) == 2:
                        dev_ids, touched, prep_m = fault_in(batch)
                    else:          # already faulted by the prefetch stage
                        _, _, dev_ids, touched, prep_m = item
                    st.busy_s += time.perf_counter() - t0
                    st.items += 1
                    if not q_put("lookup", (idx, batch, dev_ids, touched,
                                            prep_m)):
                        return
                except Exception as e:   # noqa: BLE001
                    fail("prepare", idx, e)
                    return

        def lookup_stage():
            st = self._stats["lookup"]
            while True:
                item = q_get("lookup")
                if item is None:
                    return
                if item is _DONE:
                    q_put("dense", _DONE)
                    return
                idx, batch, dev_ids, touched, prep_m = item
                try:
                    t0 = time.perf_counter()
                    sleep_for("lookup", idx)
                    # staleness backpressure: block (never drop) until every
                    # (table, shard) this batch charges is within its put
                    # window (see touched_shards for what a batch charges)
                    for n in names:
                        for s in touched[n]:
                            if not acquire(windows[(n, s)]):
                                return
                    with out_lock:
                        for n in names:
                            outstanding[n] += 1
                            self.max_outstanding[n] = max(
                                self.max_outstanding[n], outstanding[n])
                    with store_lock:
                        acts, get_m = lookup_fn(store["emb"], dev_ids)
                    st.busy_s += time.perf_counter() - t0
                    st.items += 1
                    if not q_put("dense", (idx, batch, dev_ids, acts, get_m,
                                           touched, prep_m)):
                        return
                except Exception as e:   # noqa: BLE001
                    fail("lookup", idx, e)
                    return

        def dense_stage():
            st = self._stats["dense"]
            while True:
                item = q_get("dense")
                if item is None:
                    return
                if item is _DONE:
                    q_put("put", _DONE)
                    return
                idx, batch, dev_ids, acts, get_m, touched, prep_m = item
                try:
                    t0 = time.perf_counter()
                    sleep_for("dense", idx)
                    d = dense_cell
                    dense, opt, dq, agrads, metrics = dense_step(
                        d["dense"], d["opt"], d["queue"], acts, batch,
                        d["step"])
                    dense_cell.update(dense=dense, opt=opt, queue=dq,
                                      step=d["step"] + 1)
                    st.busy_s += time.perf_counter() - t0
                    st.items += 1
                    if not q_put("put", (idx, dev_ids, agrads,
                                         metrics, get_m, touched, prep_m)):
                        return
                except Exception as e:   # noqa: BLE001
                    fail("dense", idx, e)
                    return

        def put_stage():
            st = self._stats["put"]
            while True:
                item = q_get("put")
                if item is None or item is _DONE:
                    return
                idx, dev_ids, agrads, metrics, get_m, touched, prep_m = item
                try:
                    t0 = time.perf_counter()
                    sleep_for("put", idx)
                    with store_lock:
                        emb, queues, put_m = emb_put(
                            store["emb"], store["queues"], dev_ids, agrads)
                        store["emb"] = emb
                        store["queues"] = queues
                        for n in dev_ids:
                            backends[n].unpin_slots(plan_dev(dev_ids[n]))
                    self.applied_order.append(idx)
                    with out_lock:
                        for n in names:
                            outstanding[n] -= 1
                    for n in names:
                        for s in touched[n]:
                            windows[(n, s)].release()
                    inflight.release()
                    if self.prefetch > 0:
                        prefetch_sem.release()
                    merged = dict(metrics)
                    merged.update(prep_m)
                    merged.update(get_m)
                    merged.update(put_m)
                    merged.update(BK.shard_step_metrics(backends))
                    results.append((idx, merged))
                    st.busy_s += time.perf_counter() - t0
                    st.items += 1
                except Exception as e:   # noqa: BLE001
                    fail("put", idx, e)
                    return

        threads = [
            threading.Thread(target=fn, name=f"pipeline-{name}", daemon=True)
            for name, fn in (("loader", loader),
                             ("prefetch", prefetch_stage),
                             ("prepare", prepare),
                             ("lookup", lookup_stage), ("dense", dense_stage),
                             ("put", put_stage))]
        self._running = True
        t_wall = time.perf_counter()
        try:
            for t in threads:
                t.start()
            for t in threads:
                t.join(timeout=600.0)
            hung = [t.name for t in threads if t.is_alive()]
            if hung and not errors:
                stop.set()
                raise PipelineStageError(
                    hung[0].removeprefix("pipeline-"), -1,
                    TimeoutError("stage did not finish within 600s"))
        finally:
            stop.set()
            # an aborted run may leave batches pinned mid-flight; the
            # backends outlive the run, so drop the pins before handing
            # the trainer back
            for b in backends.values():
                b.reset_pins()
            self._wall_s = time.perf_counter() - t_wall
            self._steps_done = len(results)
            self._running = False
        if errors:
            raise errors[0]

        results.sort(key=lambda r: r[0])
        final = state.replace(
            dense=dense_cell["dense"], opt=dense_cell["opt"],
            dense_queue=dense_cell["queue"], step=dense_cell["step"],
            emb=store["emb"], emb_queue=store["queues"])
        return final, [m for _, m in results]

    # -- per-stage metrics ----------------------------------------------------

    def pipeline_metrics(self) -> dict[str, float]:
        """Timing/occupancy of the last ``run()``: per-stage busy seconds,
        occupancy (busy/wall), items, and input-queue depth stats, plus
        the run-level wall time and steps/s."""
        wall = max(self._wall_s, 1e-9)
        out: dict[str, float] = {
            "pipeline/wall_s": self._wall_s,
            "pipeline/steps": float(self._steps_done),
            "pipeline/steps_per_s": self._steps_done / wall,
            "pipeline/max_inflight": float(self.max_inflight),
            "pipeline/prefetch": float(self.prefetch),
        }
        for stage, st in self._stats.items():
            out[f"pipeline/{stage}/busy_s"] = st.busy_s
            out[f"pipeline/{stage}/occupancy"] = st.busy_s / wall
            out[f"pipeline/{stage}/items"] = float(st.items)
            if stage != "loader":        # stages fed by a bounded queue
                avg = (st.depth_sum / st.depth_samples
                       if st.depth_samples else 0.0)
                out[f"pipeline/{stage}/queue_depth"] = avg
                out[f"pipeline/{stage}/queue_depth_max"] = float(st.depth_max)
        for n, v in self.max_outstanding.items():
            out[f"pipeline/outstanding_puts_max/{n}"] = float(v)
        return out
