"""Sharded embedding parameter server — the TPU-native mapping of Persia's
embedding PS tier (paper §4.1/§4.2).

Two sharding modes:

* ``mode='model'`` — table rows sharded over the ``model`` mesh axis only
  (replicated over batch axes). Used for LM vocab tables. Lookup: each model
  rank gathers its owned rows, ``psum('model')`` combines. Update: per-shard
  dense delta, ``psum`` over batch axes (every replica applies the same
  delta).
* ``mode='full'`` — rows sharded over *all* mesh axes flattened (the 512-way
  "PS node" set). Used for the paper's own massive recsys tables where
  replication over the batch axes is impossible. Lookup: ids are
  ``all_gather``-ed over the batch axes so every PS shard sees every id,
  partial rows are ``psum``-ed over all axes, each batch shard slices its
  tokens back out. Update: row-wise scatter into the locally-owned rows from
  the (already gathered) global id/grad set — the PS shard applying its own
  puts, no extra traffic.

Row placement uses the paper's *uniform shuffle* (§4.2.3 workload balance): a
fixed affine hash permutes row ids before mod-N placement, so hot feature
groups spread evenly across shards.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.utils import _mesh_axis_names, bspec_axes, round_up

# Affine permutation constants (odd multiplier => bijection mod 2^k when padded)
_SHUFFLE_MULT = 1_000_003
_SHUFFLE_ADD = 12_345


@dataclass(frozen=True)
class EmbeddingSpec:
    rows: int                       # logical rows (vocab size / total id space)
    dim: int
    mode: str = "model"             # 'model' | 'full'
    optimizer: str = "adagrad"      # 'adagrad' | 'sgd'
    lr: float = 1e-2
    eps: float = 1e-8
    staleness: int = 0              # tau; 0 = synchronous embedding updates
    dtype: Any = jnp.float32
    # -- storage backend (core/backend.py) ------------------------------------
    # 'dense' | 'host_lru' | 'host_lru+disk', optionally with a
    # '+compressed' wire decorator (e.g. 'host_lru+compressed',
    # 'host_lru+disk+compressed'). 'dense' is the device-resident PS
    # shard; 'host_lru' keeps `rows` host-side behind a device hot-cache
    # of `cache_rows` slots (paper §4.2.2 out-of-core tier); '+disk'
    # stacks a memory-mapped disk tier under a host LRU of `host_rows`,
    # so logical rows can exceed host RAM (core/mmap_store.py).
    backend: str = "dense"
    cache_rows: int = 0             # host_lru: device-resident hot slots
    wire_block: int = 128           # +compressed: blockscale block size
    wire_kernel: bool = False       # +compressed: Pallas kernel vs jnp ref
    # -- fused backward (kernels/fused_backward.py) ---------------------------
    # True routes the plan-driven put through the Pallas fused-backward
    # kernel (segment-sum + adagrad apply + queue payload in one pass);
    # False (default) keeps the jnp oracle on the same fused code path —
    # bit-identical to the decomposed plan_segment_sum + _apply_sparse.
    # Kernel path needs optimizer='adagrad' (falls back to the oracle
    # otherwise) and applies to the single-shard dense / host_lru puts.
    backward_kernel: bool = False
    # -- host-store row format (core/lru.py, core/mmap_store.py) --------------
    # 'fp32' (default) keeps cold host/disk rows at full precision;
    # 'blockscale16' stores them blockscale-compressed (fp16 payload +
    # one fp32 scale per <=128-wide block — the wire codec applied at
    # rest), roughly halving host bytes per row. Rows are decompressed on
    # fault-in and recompressed on write-back, so the device cache and
    # the optimizer math stay fp32. host_lru backends only.
    store_dtype: str = "fp32"
    # -- frequency-aware admission (core/hotness.py) --------------------------
    # > 0 enables the decayed count-min admission filter on host_lru
    # caches: a faulting id whose estimated hotness is below the
    # threshold is served from `bypass_rows` scratch slots instead of
    # claiming (and possibly evicting) a hot cache row. 0 = recency-only
    # admission, bit-identical to the pre-admission backend.
    admit_threshold: float = 0.0
    bypass_rows: int = 0            # scratch slots (0 = cache_rows // 4)
    # -- '+disk' tier sizing (core/mmap_store.py) -----------------------------
    host_rows: int = 0              # host LRU tier rows (0 = rows // 4)
    disk_path: str | None = None    # mmap backing dir (None = tempdir)
    # -- sharded PS router (core/backend.py ShardedBackend) -------------------
    # number of independent embedding-PS shards this table is hash-partitioned
    # over (paper §4.1: each embedding worker owns a partition of every
    # table). 1 = the plain single backend; k > 1 routes ids over k
    # per-shard backends with per-shard stores/locks and concurrent fault-in.
    emb_shards: int = 1
    # -- worker-side batch dedup (core/dedup.py) ------------------------------
    # True (default): the trainer's prepare phase computes a per-batch
    # DedupPlan and the whole lookup/queue/put path runs at unique width
    # (one row per unique id; staleness queues sized at the dedup cap).
    # False: the pre-dedup occurrence-width data path (PR-4 behavior),
    # kept for apples-to-apples benchmarking and old-format checkpoints.
    batch_dedup: bool = True

    def padded_rows(self, n_shards: int) -> int:
        return round_up(self.rows, max(n_shards, 1))


def _axes_for(mode: str) -> tuple[tuple[str, ...], tuple[str, ...]]:
    """(shard_axes, batch_axes) present in the ambient mesh."""
    names = _mesh_axis_names()
    batch = tuple(a for a in ("pod", "data") if a in names)
    if mode == "model":
        shard_axes = ("model",) if "model" in names else ()
    else:
        shard_axes = tuple(a for a in ("pod", "data", "model") if a in names)
    return shard_axes, batch


def _n_shards(shard_axes) -> int:
    if not shard_axes:
        return 1
    mesh = jax.sharding.get_abstract_mesh()
    n = 1
    for a in shard_axes:
        n *= mesh.shape[a]
    return n


def shuffle_pos(ids, padded_rows: int):
    """Uniform-shuffle storage position for a row id."""
    return (ids.astype(jnp.uint32) * _SHUFFLE_MULT + _SHUFFLE_ADD) % padded_rows


def ps_init(key, spec: EmbeddingSpec, n_shards: int = 1, scale: float = 0.02):
    """Embedding PS state: table + row-wise optimizer accumulator."""
    rows = spec.padded_rows(n_shards)
    table = (jax.random.normal(key, (rows, spec.dim), jnp.float32)
             * scale).astype(spec.dtype)
    state = {"table": table}
    if spec.optimizer == "adagrad":
        state["acc"] = jnp.zeros((rows,), jnp.float32)
    return state


def table_spec(spec: EmbeddingSpec) -> P:
    if spec.mode == "model":
        return P("model", None)
    return P(("pod", "data", "model"), None)


# ---------------------------------------------------------------------------
# Lookup (Persia Alg.1 forward: get(x_ID))
# ---------------------------------------------------------------------------

def lookup(state, spec: EmbeddingSpec, ids):
    """ids: (...,) int32 -> (..., dim). Out-of-range ids return zeros
    (used as padding in multi-hot bags)."""
    shape = ids.shape
    flat = ids.reshape(-1)
    shard_axes, batch_axes = _axes_for(spec.mode)
    n = _n_shards(shard_axes)
    rows = spec.padded_rows(n)
    valid = (flat >= 0) & (flat < spec.rows)
    pos = shuffle_pos(jnp.where(valid, flat, 0), rows)

    if n == 1:
        out = state["table"][pos] * valid[:, None].astype(state["table"].dtype)
        return out.reshape(*shape, spec.dim)

    rows_local = rows // n
    baxes = bspec_axes(pos.shape[0])
    bspec = P(baxes)

    if spec.mode == "model":
        @partial(jax.shard_map,
                 in_specs=(P("model", None), bspec, bspec),
                 out_specs=P(baxes, None),
                 check_vma=False)
        def _lk(tbl, pos_blk, valid_blk):
            me = jax.lax.axis_index("model")
            owner = pos_blk // rows_local
            local = pos_blk % rows_local
            mine = (owner == me) & valid_blk
            vals = tbl[local] * mine[:, None].astype(tbl.dtype)
            return jax.lax.psum(vals, "model")

        out = _lk(state["table"], pos, valid)
    else:
        all_axes = shard_axes

        @partial(jax.shard_map,
                 in_specs=(P(all_axes, None), bspec, bspec),
                 out_specs=P(baxes, None),
                 check_vma=False)
        def _lk(tbl, pos_blk, valid_blk):
            me = _flat_index(all_axes)
            # every shard must see every id: gather ids over the batch axes
            if baxes:
                pos_all = jax.lax.all_gather(pos_blk, baxes, tiled=True)
                valid_all = jax.lax.all_gather(valid_blk, baxes, tiled=True)
            else:
                pos_all, valid_all = pos_blk, valid_blk
            owner = pos_all // rows_local
            local = pos_all % rows_local
            mine = (owner == me) & valid_all
            vals = tbl[local] * mine[:, None].astype(tbl.dtype)
            vals = jax.lax.psum(vals, all_axes)                    # (T_glob, D)
            # slice this batch shard's tokens back out
            if baxes:
                t_local = pos_blk.shape[0]
                off = _flat_index(baxes) * t_local
                vals = jax.lax.dynamic_slice(
                    vals, (off, 0), (t_local, vals.shape[1]))
            return vals

        out = _lk(state["table"], pos, valid)

    return out.reshape(*shape, spec.dim)


def _flat_index(axes):
    idx = jnp.int32(0)
    for a in axes:
        idx = idx * jax.lax.axis_size(a) + jax.lax.axis_index(a)
    return idx


def _axes_size(axes):
    n = 1
    for a in axes:
        n *= jax.lax.axis_size(a)
    return n


# ---------------------------------------------------------------------------
# Gradient put + optimizer apply (Persia Alg.1 backward)
# ---------------------------------------------------------------------------

def apply_put(state, spec: EmbeddingSpec, ids, grads, assume_unique=False):
    """Apply activation gradients to the table (put + PS-side optimizer).

    ids: (T,) int32; grads: (T, dim) — gradients of the *looked-up
    activations* (Persia's F^emb'), exactly what NN workers send back.

    ``assume_unique=True`` declares the put pre-deduplicated (the
    worker-side batch-dedup path, core/dedup.py: ids are a DedupPlan's
    unique set, grads already segment-summed) and skips the on-device
    sort-based dedup — the row-sparse apply is exact on unique ids.
    """
    from repro.core.compression import dedup_put
    from repro.core.dedup import dedup_cap
    shard_axes, batch_axes = _axes_for(spec.mode)
    n = _n_shards(shard_axes)
    rows = spec.padded_rows(n)
    flat = ids.reshape(-1)
    grads = grads.reshape(-1, spec.dim)
    valid = (flat >= 0) & (flat < spec.rows)
    pos = shuffle_pos(jnp.where(valid, flat, 0), rows)
    g = jnp.where(valid[:, None], grads, 0.0).astype(jnp.float32)

    # the embedding worker aggregates a put before it crosses the wire
    # (paper §4.1 step 4 + the §4.2.3 lossless index compression): duplicate
    # rows are segment-summed so the gathered put is one row per unique id.
    # ONLY the gather-based paths (full mode / single-shard sparse apply)
    # dedup — model mode's dense-delta scatter aggregates duplicates exactly
    # without a sort (a global jit-level sort of the LM-scale (T, D) put
    # measured +2.7x peak memory; see EXPERIMENTS.md §Perf I13).
    # capacity is rounded up so the deduped arrays still shard over the
    # batch axes on any production mesh (up to 1024 batch shards).
    pos_signed = jnp.where(valid, pos.astype(jnp.int32), -1)

    def _dedup():
        if assume_unique:
            return pos_signed, g
        return dedup_put(pos_signed, g,
                         dedup_cap(int(pos.shape[0]), rows))

    if n == 1:
        pos_u, g_u = _dedup()
        return _apply_sparse(state, spec,
                             jnp.where(pos_u >= 0, pos_u, rows), g_u, rows)

    rows_local = rows // n
    baxes = bspec_axes(pos.shape[0])
    bspec = P(baxes)
    bspec2 = P(baxes, None)

    if spec.mode == "model":
        in_tree = (jax.tree.map(lambda _: P("model", None)
                                if _.ndim == 2 else P("model"), state),
                   bspec, bspec2)

        @partial(jax.shard_map, in_specs=in_tree,
                 out_specs=jax.tree.map(lambda x: P("model", None)
                                        if x.ndim == 2 else P("model"), state),
                 check_vma=False)
        def _put(st, pos_blk, g_blk):
            me = jax.lax.axis_index("model")
            owner = jnp.where(pos_blk >= 0, pos_blk // rows_local, -1)
            local = jnp.where(owner == me, pos_blk % rows_local, rows_local)
            delta = jnp.zeros((rows_local + 1, spec.dim), jnp.float32)
            delta = delta.at[local].add(g_blk)[:rows_local]
            cnt = jnp.zeros((rows_local + 1,), jnp.float32)
            cnt = cnt.at[local].add((owner == me).astype(jnp.float32))[:rows_local]
            if baxes:
                delta = jax.lax.psum(delta, baxes)
                cnt = jax.lax.psum(cnt, baxes)
            return _apply_delta(st, spec, delta, cnt)

        return _put(state, pos_signed, g)

    all_axes = shard_axes
    st_spec = jax.tree.map(lambda x: P(all_axes, None) if x.ndim == 2
                           else P(all_axes), state)

    # the deduped put is what crosses the wire (paper's index compression
    # applied to the gradient traffic): gather over batch shards, each PS
    # shard applies its own rows sparsely
    pos_u, g_u = _dedup()
    baxes = bspec_axes(pos_u.shape[0])
    bspec = P(baxes)
    bspec2 = P(baxes, None)

    @partial(jax.shard_map, in_specs=(st_spec, bspec, bspec2),
             out_specs=st_spec, check_vma=False)
    def _put(st, uniq_blk, g_blk):
        from repro.core.compression import dedup_put as _dedup
        me = _flat_index(all_axes)
        if baxes:
            uniq_all = jax.lax.all_gather(uniq_blk, baxes, tiled=True)
            g_all = jax.lax.all_gather(g_blk, baxes, tiled=True)
            # a row can arrive from several batch shards: aggregate once more
            # so the adagrad accumulator sees one summed put per row
            uniq_all, g_all = _dedup(uniq_all, g_all,
                                     min(int(uniq_all.shape[0]), rows))
        else:
            uniq_all, g_all = uniq_blk, g_blk
        owner = jnp.where(uniq_all >= 0, uniq_all // rows_local, -1)
        local = jnp.where(owner == me, uniq_all % rows_local, rows_local)
        return _apply_sparse(st, spec, local, g_all, rows_local)

    return _put(state, pos_u, g_u)


def _apply_delta(st, spec: EmbeddingSpec, delta, cnt):
    """PS-shard-local optimizer step given a dense per-shard delta
    (model-mode tables: V_local x D is small, psum-friendly)."""
    new = dict(st)
    if spec.optimizer == "adagrad":
        acc = st["acc"] + jnp.mean(jnp.square(delta), axis=-1)
        step = delta * jax.lax.rsqrt(acc + spec.eps)[:, None]
        new["acc"] = acc
    else:
        step = delta
    new["table"] = (st["table"].astype(jnp.float32)
                    - spec.lr * step).astype(st["table"].dtype)
    return new


def _apply_sparse(st, spec: EmbeddingSpec, idx, g, n_rows):
    """Row-sparse optimizer apply: O(#puts), never O(rows).

    idx: (U,) local row indices; entries == n_rows (or any >= n_rows) are
    dropped via a sacrificial padding row. Duplicate rows accumulate — the
    paper's lock-free put semantics (acc sees all increments before the
    scaled step is taken, batch-style adagrad).
    """
    new = dict(st)
    live = (idx >= 0) & (idx < n_rows)
    safe = jnp.clip(idx, 0, n_rows - 1)
    g = jnp.where(live[:, None], g.astype(jnp.float32), 0.0)
    if spec.optimizer == "adagrad":
        inc = jnp.where(live, jnp.mean(jnp.square(g), axis=-1), 0.0)
        acc = st["acc"].at[safe].add(inc)
        new["acc"] = acc
        step = g * jax.lax.rsqrt(acc[safe] + spec.eps)[:, None]
    else:
        step = g
    new["table"] = st["table"].at[safe].add(
        (-spec.lr * step).astype(st["table"].dtype))
    return new


# ---------------------------------------------------------------------------
# Bounded-staleness queue (the async relaxation; Assumption 1, t - D(t) <= tau)
# ---------------------------------------------------------------------------

def queue_init(spec: EmbeddingSpec, put_ids_shape, put_dim):
    """FIFO of tau pending puts. Each slot holds (ids, grads). Grads are
    held in the table's dtype (bf16 on the big configs — the queue is the
    largest transient of the hybrid algorithm at LM scale)."""
    tau = spec.staleness
    if tau <= 0:
        return None
    gdtype = spec.dtype
    return {
        "ids": jnp.full((tau,) + tuple(put_ids_shape), -1, jnp.int32),
        "grads": jnp.zeros((tau,) + tuple(put_ids_shape) + (put_dim,),
                           gdtype),
        "ptr": jnp.zeros((), jnp.int32),
        "filled": jnp.zeros((), jnp.int32),
    }


def queue_push_pop(queue, ids, grads):
    """Push this step's put; pop the put from tau steps ago (or an empty put
    with ids=-1 during warmup, which apply_put treats as a no-op)."""
    ptr = queue["ptr"]
    old_ids = jnp.take(queue["ids"], ptr, axis=0)
    old_grads = jnp.take(queue["grads"], ptr, axis=0)
    tau = queue["ids"].shape[0]
    new_q = {
        "ids": jax.lax.dynamic_update_index_in_dim(
            queue["ids"], ids.astype(jnp.int32), ptr, 0),
        "grads": jax.lax.dynamic_update_index_in_dim(
            queue["grads"], grads.astype(queue["grads"].dtype), ptr, 0),
        "ptr": (ptr + 1) % tau,
        "filled": jnp.minimum(queue["filled"] + 1, tau),
    }
    return new_q, old_ids, old_grads


def hybrid_emb_update(state, queue, spec: EmbeddingSpec, ids, grads):
    """One hybrid-algorithm embedding update: enqueue this step's put, apply
    the (tau-stale) put that pops out. tau=0 applies immediately (sync)."""
    if spec.staleness <= 0 or queue is None:
        return apply_put(state, spec, ids, grads), queue
    queue, old_ids, old_grads = queue_push_pop(queue, ids, grads)
    state = apply_put(state, spec, old_ids, old_grads)
    return state, queue
