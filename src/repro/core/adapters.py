"""ModelAdapter constructors: recsys (the paper's family) and LM (the
assigned architectures) views of the hybrid trainer.

The recsys adapter emits one embedding table per ID feature field (the
paper's heterogeneous feature groups, Table 1); the LM adapter is a
one-table collection over the vocabulary.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.collection import EmbeddingCollection
from repro.core.embedding_ps import EmbeddingSpec
from repro.core.hybrid import ModelAdapter
from repro.models import recsys as R
from repro.models import transformer as T


def field_table_name(i: int) -> str:
    return f"field_{i:02d}"


def ctr_collection(cfg, *, lr=1e-2, dtype=jnp.float32,
                   field_rows=None) -> EmbeddingCollection:
    """Per-field tables from a recsys ModelConfig: ``cfg.emb_rows`` total
    rows split evenly over ``cfg.n_id_fields`` fields (matching
    ``CTRDataset``'s per-field id spaces), each its own full-mode table."""
    from repro.utils import default_field_rows
    F = cfg.n_id_fields
    if field_rows is None:
        field_rows = (default_field_rows(cfg.emb_rows, F),) * F
    assert len(field_rows) == F, (len(field_rows), F)
    return EmbeddingCollection.from_dict({
        field_table_name(i): EmbeddingSpec(
            rows=int(r), dim=cfg.emb_dim, mode="full",
            optimizer=cfg.emb_optimizer, lr=lr,
            staleness=cfg.emb_staleness, dtype=dtype)
        for i, r in enumerate(field_rows)})


def recsys_adapter(cfg, *, lr=1e-2, dtype=jnp.float32,
                   field_rows=None,
                   collection: EmbeddingCollection | None = None
                   ) -> ModelAdapter:
    """Multi-table CTR adapter. ``batch["ids"]`` is (B, F, L) with
    *per-field local* ids (each field indexes its own table from 0); field i
    maps to the collection's i-th table. Pass ``field_rows=ds.field_rows()``
    so the tables are sized by the dataset's actual per-field id spaces, or
    ``collection`` to override the per-field specs entirely (heterogeneous
    rows / dims / optimizers / staleness)."""
    coll = collection if collection is not None \
        else ctr_collection(cfg, lr=lr, dtype=dtype, field_rows=field_rows)
    names = coll.names
    assert len(names) == cfg.n_id_fields, (len(names), cfg.n_id_fields)
    d_in = sum(spec.dim for _, spec in coll.items()) + cfg.n_dense_features

    def emb_ids(b):
        return {n: b["ids"][:, i] for i, n in enumerate(names)}

    def loss(dense, acts, b):
        return R.recsys_loss_tables(cfg, dense, acts, emb_ids(b), b)

    def predict(dense, acts, b):
        return jax.nn.sigmoid(
            R.recsys_forward_tables(cfg, dense, acts, emb_ids(b),
                                    b.get("dense")).astype(jnp.float32))

    return ModelAdapter(
        cfg=cfg,
        collection=coll,
        init_dense=lambda k: R.recsys_init(cfg, k, dtype, d_in=d_in),
        emb_ids=emb_ids,
        loss=loss,
        predict=predict,
    )


def lm_adapter(cfg, *, lr=1e-2, dtype=jnp.float32) -> ModelAdapter:
    coll = EmbeddingCollection.single("vocab", EmbeddingSpec(
        rows=cfg.vocab_size, dim=cfg.d_model, mode="model",
        optimizer=cfg.emb_optimizer, lr=lr,
        staleness=cfg.emb_staleness, dtype=dtype))

    def loss(dense, acts, b):
        return T.lm_loss(cfg, dense, acts["vocab"], b["targets"], b["mask"],
                         b.get("memory"))

    return ModelAdapter(
        cfg=cfg,
        collection=coll,
        init_dense=lambda k: T.init_dense(cfg, k, dtype),
        emb_ids=lambda b: {"vocab": b["tokens"]},
        loss=loss,
    )


# ---------------------------------------------------------------------------
# AUC (host-side, exact via rank statistic)
# ---------------------------------------------------------------------------

def auc(labels: np.ndarray, scores: np.ndarray) -> float:
    """Mann-Whitney AUC; labels/scores flat float arrays."""
    labels = np.asarray(labels).reshape(-1)
    scores = np.asarray(scores).reshape(-1)
    order = np.argsort(scores, kind="mergesort")
    ranks = np.empty_like(order, dtype=np.float64)
    # average ranks for ties
    s_sorted = scores[order]
    ranks[order] = np.arange(1, len(scores) + 1)
    i = 0
    while i < len(s_sorted):
        j = i
        while j + 1 < len(s_sorted) and s_sorted[j + 1] == s_sorted[i]:
            j += 1
        if j > i:
            ranks[order[i:j + 1]] = (i + j) / 2.0 + 1.0
        i = j + 1
    n_pos = labels.sum()
    n_neg = len(labels) - n_pos
    if n_pos == 0 or n_neg == 0:
        return 0.5
    return float((ranks[labels > 0.5].sum() - n_pos * (n_pos + 1) / 2)
                 / (n_pos * n_neg))
