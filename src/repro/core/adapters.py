"""ModelAdapter constructors: recsys (the paper's family) and LM (the
assigned architectures) views of the hybrid trainer."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.embedding_ps import EmbeddingSpec
from repro.core.hybrid import ModelAdapter
from repro.models import recsys as R
from repro.models import transformer as T


def recsys_adapter(cfg, *, lr=1e-2, dtype=jnp.float32) -> ModelAdapter:
    spec = EmbeddingSpec(rows=cfg.emb_rows, dim=cfg.emb_dim, mode="full",
                         optimizer=cfg.emb_optimizer, lr=lr,
                         staleness=cfg.emb_staleness, dtype=dtype)

    def predict(dense, acts, batch):
        return jax.nn.sigmoid(
            R.recsys_forward(cfg, dense, acts, batch["ids"],
                             batch.get("dense")).astype(jnp.float32))

    return ModelAdapter(
        cfg=cfg,
        emb_spec=spec,
        init_dense=lambda k: R.recsys_init(cfg, k, dtype),
        emb_ids=lambda b: b["ids"],
        loss=lambda dense, acts, b: R.recsys_loss(cfg, dense, acts, b),
        predict=predict,
    )


def lm_adapter(cfg, *, lr=1e-2, dtype=jnp.float32) -> ModelAdapter:
    spec = EmbeddingSpec(rows=cfg.vocab_size, dim=cfg.d_model, mode="model",
                         optimizer=cfg.emb_optimizer, lr=lr,
                         staleness=cfg.emb_staleness, dtype=dtype)

    def loss(dense, acts, b):
        return T.lm_loss(cfg, dense, acts, b["targets"], b["mask"],
                         b.get("memory"))

    return ModelAdapter(
        cfg=cfg,
        emb_spec=spec,
        init_dense=lambda k: T.init_dense(cfg, k, dtype),
        emb_ids=lambda b: b["tokens"],
        loss=loss,
    )


# ---------------------------------------------------------------------------
# AUC (host-side, exact via rank statistic)
# ---------------------------------------------------------------------------

def auc(labels: np.ndarray, scores: np.ndarray) -> float:
    """Mann-Whitney AUC; labels/scores flat float arrays."""
    labels = np.asarray(labels).reshape(-1)
    scores = np.asarray(scores).reshape(-1)
    order = np.argsort(scores, kind="mergesort")
    ranks = np.empty_like(order, dtype=np.float64)
    # average ranks for ties
    s_sorted = scores[order]
    ranks[order] = np.arange(1, len(scores) + 1)
    i = 0
    while i < len(s_sorted):
        j = i
        while j + 1 < len(s_sorted) and s_sorted[j + 1] == s_sorted[i]:
            j += 1
        if j > i:
            ranks[order[i:j + 1]] = (i + j) / 2.0 + 1.0
        i = j + 1
    n_pos = labels.sum()
    n_neg = len(labels) - n_pos
    if n_pos == 0 or n_neg == 0:
        return 0.5
    return float((ranks[labels > 0.5].sum() - n_pos * (n_pos + 1) / 2)
                 / (n_pos * n_neg))
