"""Persia §4.2.2 memory management: the embedding-PS LRU cache, implemented
with an *array-list* + hash-map (faithful to the paper's design — pointers
are array indices, not memory addresses, so (de)serialisation is a straight
memory copy and there is no per-entry allocation).

This is the host-side, out-of-core tier: on a real deployment the device
shard is the hot set and this store backs it in PS-node RAM. Here it backs
the capacity benchmark (Criteo-Syn scaling family) and checkpointing.
Each entry holds the embedding vector and its optimizer state (adagrad
accumulator), exactly as the paper stores both in the array item.
"""
from __future__ import annotations

import numpy as np

_NIL = -1
_U64_MASK = (1 << 64) - 1


def rng_state_array(rng: np.random.Generator) -> np.ndarray:
    """PCG64 bit-generator state as 6 uint64 scalars (the two 128-bit
    ints split lo/hi) so a restored store's miss-path init continues the
    exact same random stream."""
    st = rng.bit_generator.state
    s = st["state"]
    return np.array([s["state"] & _U64_MASK,
                     (s["state"] >> 64) & _U64_MASK,
                     s["inc"] & _U64_MASK, (s["inc"] >> 64) & _U64_MASK,
                     int(st["has_uint32"]), int(st["uinteger"])],
                    np.uint64)


def set_rng_state(rng: np.random.Generator, arr: np.ndarray) -> None:
    a = [int(x) for x in np.asarray(arr, np.uint64).reshape(-1)]
    rng.bit_generator.state = {
        "bit_generator": "PCG64",
        "state": {"state": a[0] | (a[1] << 64),
                  "inc": a[2] | (a[3] << 64)},
        "has_uint32": a[4], "uinteger": a[5]}


class LRUEmbeddingStore:
    """Fixed-capacity LRU keyed by int64 id -> (vector, optimizer slot)."""

    def __init__(self, capacity: int, dim: int, seed: int = 0,
                 init_scale: float = 0.02, track_recency: bool = True):
        assert capacity > 0
        self.capacity = capacity
        self.dim = dim
        self._rng = np.random.default_rng(seed)
        self._init_scale = init_scale
        # track_recency=False skips the per-access linked-list touch on the
        # batched read/write paths (allocation order still recorded). The
        # embedding backends run their stores this way: those stores hold
        # ALL logical rows and never evict, so per-access LRU upkeep is
        # pure (GIL-bound) overhead on the fault path — it was the
        # serializing cost that kept concurrent per-shard fault-ins from
        # scaling. Stores that actually evict must keep the default.
        self.track_recency = track_recency
        # array-list: vectors, optimizer state, prev/next indices, keys
        self.vectors = np.zeros((capacity, dim), np.float32)
        self.opt_acc = np.zeros((capacity,), np.float32)
        self.prev = np.full(capacity, _NIL, np.int64)
        self.next = np.full(capacity, _NIL, np.int64)
        self.keys = np.full(capacity, _NIL, np.int64)
        self.index: dict[int, int] = {}     # hash-map: id -> array slot
        self.head = _NIL                    # most-recently used
        self.tail = _NIL                    # least-recently used
        self.size = 0
        self.evictions = 0
        # optional spill hook: called as on_evict(key, vector, opt_acc)
        # with the row ABOUT to be overwritten — the tiered host store
        # (core/mmap_store.py) wires this to its disk tier so an eviction
        # is a demotion, not a loss. Not serialized; owners rewire it.
        self.on_evict = None

    # -- linked-list ops on array indices ------------------------------------
    def _unlink(self, slot: int):
        p, n = self.prev[slot], self.next[slot]
        if p != _NIL:
            self.next[p] = n
        else:
            self.head = n
        if n != _NIL:
            self.prev[n] = p
        else:
            self.tail = p
        self.prev[slot] = self.next[slot] = _NIL

    def _push_front(self, slot: int):
        self.prev[slot] = _NIL
        self.next[slot] = self.head
        if self.head != _NIL:
            self.prev[self.head] = slot
        self.head = slot
        if self.tail == _NIL:
            self.tail = slot

    def _touch(self, slot: int):
        if self.head == slot:
            return
        self._unlink(slot)
        self._push_front(slot)

    def _alloc(self, key: int) -> int:
        if self.size < self.capacity:
            slot = self.size
            self.size += 1
        else:
            slot = self.tail                 # evict LRU
            self._unlink(slot)
            old = int(self.keys[slot])
            if self.on_evict is not None:
                self.on_evict(old, self.vectors[slot], self.opt_acc[slot])
            del self.index[old]
            self.evictions += 1
        self.keys[slot] = key
        self.index[key] = slot
        self._push_front(slot)
        return slot

    def _touch_many(self, slots: list[int]):
        """Touch slots in sequence (later = more recent). Equivalent to
        calling _touch per slot, but deduplicated to the last occurrence so
        the linked-list walk is one unlink+push per distinct slot."""
        seen = set()
        order = []
        for s in reversed(slots):
            if s not in seen:
                seen.add(s)
                order.append(s)
        for s in reversed(order):
            if self.head != s:
                self._unlink(s)
                self._push_front(s)

    def _resolve(self, ids: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Batched id -> slot resolution: (int64 ids, int64 slots, -1 miss)."""
        ids = np.asarray(ids, np.int64).reshape(-1)
        idx = self.index
        slots = np.fromiter((idx.get(k, -1) for k in ids.tolist()),
                            np.int64, len(ids))
        return ids, slots

    # -- public API -------------------------------------------------------------
    def get(self, ids: np.ndarray) -> np.ndarray:
        """Fetch rows (allocating/initialising on miss). ids: (n,) int64."""
        return self.read_rows(ids)[0]

    def read_rows(self, ids: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Batched fetch of (vectors, optimizer accumulators), allocating and
        initialising on miss. The hit path is numpy-batched: one dict sweep
        for slot resolution, one linked-list recency pass, one fancy-indexed
        gather per array. Batches containing misses walk per id — an
        allocation's eviction can invalidate a slot resolved earlier in the
        same batch, so only the all-hit case is safely batchable."""
        ids, slots = self._resolve(ids)
        if slots.size and (slots >= 0).all():
            if self.track_recency:
                self._touch_many(slots.tolist())
            return self.vectors[slots].copy(), self.opt_acc[slots].copy()
        out_v = np.empty((len(ids), self.dim), np.float32)
        out_a = np.empty(len(ids), np.float32)
        for i, key in enumerate(ids.tolist()):
            slot = self.index.get(key)
            if slot is None:
                slot = self._alloc(key)
                self.vectors[slot] = (self._rng.standard_normal(self.dim)
                                      * self._init_scale)
                self.opt_acc[slot] = 0.0
            elif self.track_recency:
                self._touch(slot)
            out_v[i] = self.vectors[slot]
            out_a[i] = self.opt_acc[slot]
        return out_v, out_a

    def put(self, ids: np.ndarray, grads: np.ndarray, lr: float = 1e-2,
            eps: float = 1e-8):
        """Apply gradient rows with the PS-side adagrad (lock-free analog:
        last-writer-wins per row, matching Alg.1's no-lock semantics).
        Unique-id batches take a fully numpy-batched path; batches with
        repeated ids fall back to the sequential per-row semantics."""
        ids, slots = self._resolve(ids)
        grads = np.asarray(grads, np.float32).reshape(len(ids), self.dim)
        live = slots >= 0                    # paper: dropped puts tolerated
        if not live.any():
            return
        l_ids, l_slots, l_g = ids[live], slots[live], grads[live]
        if len(np.unique(l_slots)) == len(l_slots):
            acc = self.opt_acc[l_slots] + np.mean(l_g * l_g, axis=-1)
            self.opt_acc[l_slots] = acc
            self.vectors[l_slots] -= lr * l_g / np.sqrt(acc + eps)[:, None]
            return
        for slot, g in zip(l_slots.tolist(), l_g):
            acc = self.opt_acc[slot] + float(np.mean(g * g))
            self.opt_acc[slot] = acc
            self.vectors[slot] -= lr * g / np.sqrt(acc + eps)

    def write_rows(self, ids: np.ndarray, vectors: np.ndarray,
                   opt_acc: np.ndarray | None = None):
        """Overwrite rows wholesale (the device cache's write-back path: the
        optimizer already ran on device, so values land verbatim). Allocates
        missing ids; batch-vectorized on the hit path; touches recency."""
        ids, slots = self._resolve(ids)
        vectors = np.asarray(vectors, np.float32).reshape(len(ids), self.dim)
        acc = None if opt_acc is None \
            else np.asarray(opt_acc, np.float32).reshape(-1)
        if slots.size and (slots >= 0).all():    # all-hit: fully batched
            self.vectors[slots] = vectors
            if acc is not None:
                self.opt_acc[slots] = acc
            if self.track_recency:
                self._touch_many(slots.tolist())
            return
        for i, key in enumerate(ids.tolist()):   # misses: sequential allocs
            slot = self.index.get(key)
            if slot is None:
                slot = self._alloc(key)
            elif self.track_recency:
                self._touch(slot)
            self.vectors[slot] = vectors[i]
            if acc is not None:
                self.opt_acc[slot] = acc[i]

    def preload(self, ids: np.ndarray, vectors: np.ndarray,
                opt_acc: np.ndarray | None = None):
        """Bulk-load an EMPTY store (the out-of-core backend's init path):
        rows land in slots 0..n-1 with recency = insertion order (last id
        most-recent), all linked-list pointers built vectorized."""
        ids = np.asarray(ids, np.int64).reshape(-1)
        n = len(ids)
        if n == 0:
            return
        if self.size != 0:
            raise ValueError("preload requires an empty store")
        if n > self.capacity:
            raise ValueError(f"preload of {n} rows exceeds capacity "
                             f"{self.capacity}")
        self.vectors[:n] = np.asarray(vectors, np.float32) \
            .reshape(n, self.dim)
        if opt_acc is not None:
            self.opt_acc[:n] = np.asarray(opt_acc, np.float32).reshape(-1)
        self.keys[:n] = ids
        # chain: slot n-1 (inserted last) is MRU head, slot 0 is LRU tail
        self.prev[:n] = np.arange(1, n + 1, dtype=np.int64)
        self.prev[n - 1] = _NIL
        self.next[:n] = np.arange(-1, n - 1, dtype=np.int64)
        self.index = {int(k): i for i, k in enumerate(ids.tolist())}
        self.head, self.tail, self.size = n - 1, 0, n

    def recency_ids(self) -> list[int]:
        """Resident ids most- to least-recently used (test/inspection aid)."""
        out = []
        slot = self.head
        while slot != _NIL:
            out.append(int(self.keys[slot]))
            slot = int(self.next[slot])
        return out

    # -- zero-copy style (de)serialisation ---------------------------------------
    def _rng_state_array(self) -> np.ndarray:
        return rng_state_array(self._rng)

    def _set_rng_state(self, arr: np.ndarray):
        set_rng_state(self._rng, arr)

    def serialize(self) -> dict[str, np.ndarray]:
        """Pure-array snapshot — a memory copy, no pointer chasing."""
        return {
            "vectors": self.vectors[: self.size].copy(),
            "opt_acc": self.opt_acc[: self.size].copy(),
            "prev": self.prev[: self.size].copy(),
            "next": self.next[: self.size].copy(),
            "keys": self.keys[: self.size].copy(),
            "meta": np.array([self.capacity, self.dim, self.head, self.tail,
                              self.size, self.evictions], np.int64),
            # constructor/derived state the 6-scalar meta never carried:
            # a restored store that still faults/evicts must continue the
            # run bit-identically (same init stream, same recency upkeep)
            "store_cfg": np.array([self._init_scale,
                                   float(self.track_recency)], np.float64),
            "rng_state": self._rng_state_array(),
        }

    @classmethod
    def deserialize(cls, blob: dict[str, np.ndarray]) -> "LRUEmbeddingStore":
        cap, dim, head, tail, size, ev = \
            (int(x) for x in np.asarray(blob["meta"]).reshape(-1)[:6])
        cfg = blob.get("store_cfg")
        if cfg is not None:                   # old blobs: 6-scalar meta only
            cfg = np.asarray(cfg, np.float64).reshape(-1)
            store = cls(cap, dim, init_scale=float(cfg[0]),
                        track_recency=bool(cfg[1] != 0.0))
        else:
            store = cls(cap, dim)
        if "rng_state" in blob:
            store._set_rng_state(blob["rng_state"])
        store.vectors[:size] = blob["vectors"]
        store.opt_acc[:size] = blob["opt_acc"]
        store.prev[:size] = blob["prev"]
        store.next[:size] = blob["next"]
        store.keys[:size] = blob["keys"]
        store.head, store.tail, store.size, store.evictions = head, tail, size, ev
        store.index = {int(k): i for i, k in enumerate(blob["keys"])}
        return store
