"""Persia §4.2.2 memory management: the embedding-PS LRU cache, implemented
with an *array-list* + hash-map (faithful to the paper's design — pointers
are array indices, not memory addresses, so (de)serialisation is a straight
memory copy and there is no per-entry allocation).

This is the host-side, out-of-core tier: on a real deployment the device
shard is the hot set and this store backs it in PS-node RAM. Here it backs
the capacity benchmark (Criteo-Syn scaling family) and checkpointing.
Each entry holds the embedding vector and its optimizer state (adagrad
accumulator), exactly as the paper stores both in the array item.
"""
from __future__ import annotations

import numpy as np

_NIL = -1


class LRUEmbeddingStore:
    """Fixed-capacity LRU keyed by int64 id -> (vector, optimizer slot)."""

    def __init__(self, capacity: int, dim: int, seed: int = 0,
                 init_scale: float = 0.02):
        assert capacity > 0
        self.capacity = capacity
        self.dim = dim
        self._rng = np.random.default_rng(seed)
        self._init_scale = init_scale
        # array-list: vectors, optimizer state, prev/next indices, keys
        self.vectors = np.zeros((capacity, dim), np.float32)
        self.opt_acc = np.zeros((capacity,), np.float32)
        self.prev = np.full(capacity, _NIL, np.int64)
        self.next = np.full(capacity, _NIL, np.int64)
        self.keys = np.full(capacity, _NIL, np.int64)
        self.index: dict[int, int] = {}     # hash-map: id -> array slot
        self.head = _NIL                    # most-recently used
        self.tail = _NIL                    # least-recently used
        self.size = 0
        self.evictions = 0

    # -- linked-list ops on array indices ------------------------------------
    def _unlink(self, slot: int):
        p, n = self.prev[slot], self.next[slot]
        if p != _NIL:
            self.next[p] = n
        else:
            self.head = n
        if n != _NIL:
            self.prev[n] = p
        else:
            self.tail = p
        self.prev[slot] = self.next[slot] = _NIL

    def _push_front(self, slot: int):
        self.prev[slot] = _NIL
        self.next[slot] = self.head
        if self.head != _NIL:
            self.prev[self.head] = slot
        self.head = slot
        if self.tail == _NIL:
            self.tail = slot

    def _touch(self, slot: int):
        if self.head == slot:
            return
        self._unlink(slot)
        self._push_front(slot)

    def _alloc(self, key: int) -> int:
        if self.size < self.capacity:
            slot = self.size
            self.size += 1
        else:
            slot = self.tail                 # evict LRU
            self._unlink(slot)
            del self.index[int(self.keys[slot])]
            self.evictions += 1
        self.keys[slot] = key
        self.index[key] = slot
        self._push_front(slot)
        return slot

    # -- public API -------------------------------------------------------------
    def get(self, ids: np.ndarray) -> np.ndarray:
        """Fetch rows (allocating/initialising on miss). ids: (n,) int64."""
        out = np.empty((len(ids), self.dim), np.float32)
        for i, key in enumerate(np.asarray(ids, np.int64)):
            key = int(key)
            slot = self.index.get(key)
            if slot is None:
                slot = self._alloc(key)
                self.vectors[slot] = (self._rng.standard_normal(self.dim)
                                      * self._init_scale)
                self.opt_acc[slot] = 0.0
            else:
                self._touch(slot)
            out[i] = self.vectors[slot]
        return out

    def put(self, ids: np.ndarray, grads: np.ndarray, lr: float = 1e-2,
            eps: float = 1e-8):
        """Apply gradient rows with the PS-side adagrad (lock-free analog:
        last-writer-wins per row, matching Alg.1's no-lock semantics)."""
        for key, g in zip(np.asarray(ids, np.int64), grads):
            key = int(key)
            slot = self.index.get(key)
            if slot is None:
                continue                     # paper: dropped puts tolerated
            acc = self.opt_acc[slot] + float(np.mean(g * g))
            self.opt_acc[slot] = acc
            self.vectors[slot] -= lr * g / np.sqrt(acc + eps)

    # -- zero-copy style (de)serialisation ---------------------------------------
    def serialize(self) -> dict[str, np.ndarray]:
        """Pure-array snapshot — a memory copy, no pointer chasing."""
        return {
            "vectors": self.vectors[: self.size].copy(),
            "opt_acc": self.opt_acc[: self.size].copy(),
            "prev": self.prev[: self.size].copy(),
            "next": self.next[: self.size].copy(),
            "keys": self.keys[: self.size].copy(),
            "meta": np.array([self.capacity, self.dim, self.head, self.tail,
                              self.size, self.evictions], np.int64),
        }

    @classmethod
    def deserialize(cls, blob: dict[str, np.ndarray]) -> "LRUEmbeddingStore":
        cap, dim, head, tail, size, ev = (int(x) for x in blob["meta"])
        store = cls(cap, dim)
        store.vectors[:size] = blob["vectors"]
        store.opt_acc[:size] = blob["opt_acc"]
        store.prev[:size] = blob["prev"]
        store.next[:size] = blob["next"]
        store.keys[:size] = blob["keys"]
        store.head, store.tail, store.size, store.evictions = head, tail, size, ev
        store.index = {int(k): i for i, k in enumerate(blob["keys"])}
        return store
