"""Persia §4.2.2 memory management: the embedding-PS LRU cache, implemented
with an *array-list* + hash-map (faithful to the paper's design — pointers
are array indices, not memory addresses, so (de)serialisation is a straight
memory copy and there is no per-entry allocation).

This is the host-side, out-of-core tier: on a real deployment the device
shard is the hot set and this store backs it in PS-node RAM. Here it backs
the capacity benchmark (Criteo-Syn scaling family) and checkpointing.
Each entry holds the embedding vector and its optimizer state (adagrad
accumulator), exactly as the paper stores both in the array item.
"""
from __future__ import annotations

import numpy as np

_NIL = -1
_U64_MASK = (1 << 64) - 1

# blockscale16 row codec — the wire format (kernels/ref.py) applied at
# rest: fp16 payload + one fp32 scale per <=128-wide block of the row
BS_KAPPA = 32_768.0
BS_BLOCK = 128
STORE_DTYPES = ("fp32", "blockscale16")


def bs_blocks(dim: int) -> int:
    return -(-int(dim) // BS_BLOCK)


def bs_compress_rows(rows: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """(n, dim) fp32 -> ((n, dim) fp16 payload, (n, ceil(dim/128)) fp32
    scales). Per-row blocks; the trailing partial block is padded with
    zeros for the linf only (payload keeps the true width)."""
    rows = np.asarray(rows, np.float32)
    n, dim = rows.shape
    nb = bs_blocks(dim)
    pad = nb * BS_BLOCK - dim
    buf = np.pad(rows, ((0, 0), (0, pad))) if pad else rows
    blk = buf.reshape(n, nb, BS_BLOCK)
    linf = np.max(np.abs(blk), axis=-1)
    scale = (BS_KAPPA / np.maximum(linf, 1e-30)).astype(np.float32)
    comp = (blk * scale[:, :, None]).astype(np.float16)
    return comp.reshape(n, nb * BS_BLOCK)[:, :dim], scale


def bs_decompress_rows(comp: np.ndarray, scale: np.ndarray) -> np.ndarray:
    n, dim = comp.shape
    nb = scale.shape[1]
    pad = nb * BS_BLOCK - dim
    buf = comp.astype(np.float32)
    if pad:
        buf = np.pad(buf, ((0, 0), (0, pad)))
    blk = buf.reshape(n, nb, BS_BLOCK) / scale[:, :, None]
    return blk.reshape(n, nb * BS_BLOCK)[:, :dim]


def rng_state_array(rng: np.random.Generator) -> np.ndarray:
    """PCG64 bit-generator state as 6 uint64 scalars (the two 128-bit
    ints split lo/hi) so a restored store's miss-path init continues the
    exact same random stream."""
    st = rng.bit_generator.state
    s = st["state"]
    return np.array([s["state"] & _U64_MASK,
                     (s["state"] >> 64) & _U64_MASK,
                     s["inc"] & _U64_MASK, (s["inc"] >> 64) & _U64_MASK,
                     int(st["has_uint32"]), int(st["uinteger"])],
                    np.uint64)


def set_rng_state(rng: np.random.Generator, arr: np.ndarray) -> None:
    a = [int(x) for x in np.asarray(arr, np.uint64).reshape(-1)]
    rng.bit_generator.state = {
        "bit_generator": "PCG64",
        "state": {"state": a[0] | (a[1] << 64),
                  "inc": a[2] | (a[3] << 64)},
        "has_uint32": a[4], "uinteger": a[5]}


class LRUEmbeddingStore:
    """Fixed-capacity LRU keyed by int64 id -> (vector, optimizer slot)."""

    def __init__(self, capacity: int, dim: int, seed: int = 0,
                 init_scale: float = 0.02, track_recency: bool = True,
                 store_dtype: str = "fp32"):
        assert capacity > 0
        self.capacity = capacity
        self.dim = dim
        self._rng = np.random.default_rng(seed)
        self._init_scale = init_scale
        # track_recency=False skips the per-access linked-list touch on the
        # batched read/write paths (allocation order still recorded). The
        # embedding backends run their stores this way: those stores hold
        # ALL logical rows and never evict, so per-access LRU upkeep is
        # pure (GIL-bound) overhead on the fault path — it was the
        # serializing cost that kept concurrent per-shard fault-ins from
        # scaling. Stores that actually evict must keep the default.
        self.track_recency = track_recency
        if store_dtype not in STORE_DTYPES:
            raise ValueError(
                f"unknown store_dtype {store_dtype!r}: one of {STORE_DTYPES}")
        self.store_dtype = store_dtype
        # array-list: vectors, optimizer state, prev/next indices, keys.
        # 'blockscale16' keeps the vector payload fp16 with one fp32 scale
        # per <=128-wide block; every read decompresses, every write
        # recompresses (cold rows cost ~half the bytes, the optimizer math
        # upstream stays fp32).
        if store_dtype == "blockscale16":
            self.vectors = np.zeros((capacity, dim), np.float16)
            self.vec_scale = np.zeros((capacity, bs_blocks(dim)), np.float32)
        else:
            self.vectors = np.zeros((capacity, dim), np.float32)
            self.vec_scale = None
        self.opt_acc = np.zeros((capacity,), np.float32)
        self.prev = np.full(capacity, _NIL, np.int64)
        self.next = np.full(capacity, _NIL, np.int64)
        self.keys = np.full(capacity, _NIL, np.int64)
        self.index: dict[int, int] = {}     # hash-map: id -> array slot
        self.head = _NIL                    # most-recently used
        self.tail = _NIL                    # least-recently used
        self.size = 0
        self.evictions = 0
        # optional spill hook: called as on_evict(key, vector, opt_acc)
        # with the row ABOUT to be overwritten — the tiered host store
        # (core/mmap_store.py) wires this to its disk tier so an eviction
        # is a demotion, not a loss. Not serialized; owners rewire it.
        self.on_evict = None

    # -- linked-list ops on array indices ------------------------------------
    def _unlink(self, slot: int):
        p, n = self.prev[slot], self.next[slot]
        if p != _NIL:
            self.next[p] = n
        else:
            self.head = n
        if n != _NIL:
            self.prev[n] = p
        else:
            self.tail = p
        self.prev[slot] = self.next[slot] = _NIL

    def _push_front(self, slot: int):
        self.prev[slot] = _NIL
        self.next[slot] = self.head
        if self.head != _NIL:
            self.prev[self.head] = slot
        self.head = slot
        if self.tail == _NIL:
            self.tail = slot

    def _touch(self, slot: int):
        if self.head == slot:
            return
        self._unlink(slot)
        self._push_front(slot)

    # -- store_dtype-aware payload access ------------------------------------
    def _get_rows(self, slots) -> np.ndarray:
        """Decompressed fp32 vector rows for array-indexable ``slots``."""
        if self.vec_scale is None:
            return np.asarray(self.vectors[slots], np.float32)
        return bs_decompress_rows(self.vectors[slots], self.vec_scale[slots])

    def _set_rows(self, slots, vals):
        vals = np.asarray(vals, np.float32).reshape(-1, self.dim)
        if self.vec_scale is None:
            self.vectors[slots] = vals
        else:
            comp, scale = bs_compress_rows(vals)
            self.vectors[slots] = comp
            self.vec_scale[slots] = scale

    def payload_bytes(self) -> int:
        """Bytes held by the vector payload (the store_dtype-scaled part)."""
        n = self.vectors.nbytes
        if self.vec_scale is not None:
            n += self.vec_scale.nbytes
        return int(n)

    def _alloc(self, key: int) -> int:
        if self.size < self.capacity:
            slot = self.size
            self.size += 1
        else:
            slot = self.tail                 # evict LRU
            self._unlink(slot)
            old = int(self.keys[slot])
            if self.on_evict is not None:
                self.on_evict(old, self._get_rows(np.array([slot]))[0],
                              self.opt_acc[slot])
            del self.index[old]
            self.evictions += 1
        self.keys[slot] = key
        self.index[key] = slot
        self._push_front(slot)
        return slot

    def _touch_many(self, slots: list[int]):
        """Touch slots in sequence (later = more recent). Equivalent to
        calling _touch per slot, but deduplicated to the last occurrence so
        the linked-list walk is one unlink+push per distinct slot."""
        seen = set()
        order = []
        for s in reversed(slots):
            if s not in seen:
                seen.add(s)
                order.append(s)
        for s in reversed(order):
            if self.head != s:
                self._unlink(s)
                self._push_front(s)

    def _resolve(self, ids: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Batched id -> slot resolution: (int64 ids, int64 slots, -1 miss)."""
        ids = np.asarray(ids, np.int64).reshape(-1)
        idx = self.index
        slots = np.fromiter((idx.get(k, -1) for k in ids.tolist()),
                            np.int64, len(ids))
        return ids, slots

    # -- public API -------------------------------------------------------------
    def get(self, ids: np.ndarray) -> np.ndarray:
        """Fetch rows (allocating/initialising on miss). ids: (n,) int64."""
        return self.read_rows(ids)[0]

    def read_rows(self, ids: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Batched fetch of (vectors, optimizer accumulators), allocating and
        initialising on miss. The hit path is numpy-batched: one dict sweep
        for slot resolution, one linked-list recency pass, one fancy-indexed
        gather per array. Batches containing misses walk per id — an
        allocation's eviction can invalidate a slot resolved earlier in the
        same batch, so only the all-hit case is safely batchable."""
        ids, slots = self._resolve(ids)
        if slots.size and (slots >= 0).all():
            if self.track_recency:
                self._touch_many(slots.tolist())
            return self._get_rows(slots), self.opt_acc[slots].copy()
        out_v = np.empty((len(ids), self.dim), np.float32)
        out_a = np.empty(len(ids), np.float32)
        for i, key in enumerate(ids.tolist()):
            slot = self.index.get(key)
            if slot is None:
                slot = self._alloc(key)
                # write-then-read so a fresh row's first touch returns the
                # same (store_dtype round-tripped) value as later reads
                self._set_rows(np.array([slot]),
                               (self._rng.standard_normal(self.dim)
                                * self._init_scale)[None])
                self.opt_acc[slot] = 0.0
            elif self.track_recency:
                self._touch(slot)
            out_v[i] = self._get_rows(np.array([slot]))[0]
            out_a[i] = self.opt_acc[slot]
        return out_v, out_a

    def put(self, ids: np.ndarray, grads: np.ndarray, lr: float = 1e-2,
            eps: float = 1e-8):
        """Apply gradient rows with the PS-side adagrad (lock-free analog:
        last-writer-wins per row, matching Alg.1's no-lock semantics).
        Unique-id batches take a fully numpy-batched path; batches with
        repeated ids fall back to the sequential per-row semantics."""
        ids, slots = self._resolve(ids)
        grads = np.asarray(grads, np.float32).reshape(len(ids), self.dim)
        live = slots >= 0                    # paper: dropped puts tolerated
        if not live.any():
            return
        l_ids, l_slots, l_g = ids[live], slots[live], grads[live]
        if len(np.unique(l_slots)) == len(l_slots):
            acc = self.opt_acc[l_slots] + np.mean(l_g * l_g, axis=-1)
            self.opt_acc[l_slots] = acc
            self._set_rows(l_slots, self._get_rows(l_slots)
                           - lr * l_g / np.sqrt(acc + eps)[:, None])
            return
        for slot, g in zip(l_slots.tolist(), l_g):
            acc = self.opt_acc[slot] + float(np.mean(g * g))
            self.opt_acc[slot] = acc
            sl = np.array([slot])
            self._set_rows(sl, self._get_rows(sl)[0]
                           - lr * g / np.sqrt(acc + eps))

    def write_rows(self, ids: np.ndarray, vectors: np.ndarray,
                   opt_acc: np.ndarray | None = None):
        """Overwrite rows wholesale (the device cache's write-back path: the
        optimizer already ran on device, so values land verbatim). Allocates
        missing ids; batch-vectorized on the hit path; touches recency."""
        ids, slots = self._resolve(ids)
        vectors = np.asarray(vectors, np.float32).reshape(len(ids), self.dim)
        acc = None if opt_acc is None \
            else np.asarray(opt_acc, np.float32).reshape(-1)
        if slots.size and (slots >= 0).all():    # all-hit: fully batched
            self._set_rows(slots, vectors)
            if acc is not None:
                self.opt_acc[slots] = acc
            if self.track_recency:
                self._touch_many(slots.tolist())
            return
        for i, key in enumerate(ids.tolist()):   # misses: sequential allocs
            slot = self.index.get(key)
            if slot is None:
                slot = self._alloc(key)
            elif self.track_recency:
                self._touch(slot)
            self._set_rows(np.array([slot]), vectors[i][None])
            if acc is not None:
                self.opt_acc[slot] = acc[i]

    def preload(self, ids: np.ndarray, vectors: np.ndarray,
                opt_acc: np.ndarray | None = None):
        """Bulk-load an EMPTY store (the out-of-core backend's init path):
        rows land in slots 0..n-1 with recency = insertion order (last id
        most-recent), all linked-list pointers built vectorized."""
        ids = np.asarray(ids, np.int64).reshape(-1)
        n = len(ids)
        if n == 0:
            return
        if self.size != 0:
            raise ValueError("preload requires an empty store")
        if n > self.capacity:
            raise ValueError(f"preload of {n} rows exceeds capacity "
                             f"{self.capacity}")
        self._set_rows(np.arange(n), np.asarray(vectors, np.float32)
                       .reshape(n, self.dim))
        if opt_acc is not None:
            self.opt_acc[:n] = np.asarray(opt_acc, np.float32).reshape(-1)
        self.keys[:n] = ids
        # chain: slot n-1 (inserted last) is MRU head, slot 0 is LRU tail
        self.prev[:n] = np.arange(1, n + 1, dtype=np.int64)
        self.prev[n - 1] = _NIL
        self.next[:n] = np.arange(-1, n - 1, dtype=np.int64)
        self.index = {int(k): i for i, k in enumerate(ids.tolist())}
        self.head, self.tail, self.size = n - 1, 0, n

    def recency_ids(self) -> list[int]:
        """Resident ids most- to least-recently used (test/inspection aid)."""
        out = []
        slot = self.head
        while slot != _NIL:
            out.append(int(self.keys[slot]))
            slot = int(self.next[slot])
        return out

    # -- zero-copy style (de)serialisation ---------------------------------------
    def _rng_state_array(self) -> np.ndarray:
        return rng_state_array(self._rng)

    def _set_rng_state(self, arr: np.ndarray):
        set_rng_state(self._rng, arr)

    def serialize(self) -> dict[str, np.ndarray]:
        """Pure-array snapshot — a memory copy, no pointer chasing.

        ``vectors`` is ALWAYS the decompressed fp32 rows (the portable
        logical payload any store_dtype — and any cross-format reader —
        can restore from); a blockscale16 store additionally snapshots its
        raw fp16 payload + scales so a matching-dtype restore is
        bit-exact (re-compressing a decompressed row can differ by one
        fp16 ulp when the block max re-rounds)."""
        blob = {
            "vectors": self._get_rows(np.arange(self.size)),
            "opt_acc": self.opt_acc[: self.size].copy(),
            "prev": self.prev[: self.size].copy(),
            "next": self.next[: self.size].copy(),
            "keys": self.keys[: self.size].copy(),
            "meta": np.array([self.capacity, self.dim, self.head, self.tail,
                              self.size, self.evictions], np.int64),
            # constructor/derived state the 6-scalar meta never carried:
            # a restored store that still faults/evicts must continue the
            # run bit-identically (same init stream, same recency upkeep);
            # the third slot records the store_dtype (absent = fp32)
            "store_cfg": np.array([self._init_scale,
                                   float(self.track_recency),
                                   float(self.vec_scale is not None)],
                                  np.float64),
            "rng_state": self._rng_state_array(),
        }
        if self.vec_scale is not None:
            blob["vec16"] = self.vectors[: self.size].copy()
            blob["vec16_scale"] = self.vec_scale[: self.size].copy()
        return blob

    @classmethod
    def deserialize(cls, blob: dict[str, np.ndarray],
                    store_dtype: str | None = None) -> "LRUEmbeddingStore":
        """``store_dtype=None`` rebuilds in the blob's recorded format;
        passing 'fp32' / 'blockscale16' restores into that format instead
        (cross-format: the decompressed fp32 ``vectors`` are re-encoded)."""
        cap, dim, head, tail, size, ev = \
            (int(x) for x in np.asarray(blob["meta"]).reshape(-1)[:6])
        cfg = blob.get("store_cfg")
        blob_bs = False
        if cfg is not None:                   # old blobs: 6-scalar meta only
            cfg = np.asarray(cfg, np.float64).reshape(-1)
            blob_bs = cfg.size > 2 and cfg[2] != 0.0
            target = store_dtype or ("blockscale16" if blob_bs else "fp32")
            store = cls(cap, dim, init_scale=float(cfg[0]),
                        track_recency=bool(cfg[1] != 0.0),
                        store_dtype=target)
        else:
            store = cls(cap, dim, store_dtype=store_dtype or "fp32")
        if "rng_state" in blob:
            store._set_rng_state(blob["rng_state"])
        if store.vec_scale is not None and blob_bs and "vec16" in blob:
            store.vectors[:size] = blob["vec16"]        # bit-exact payload
            store.vec_scale[:size] = blob["vec16_scale"]
        else:
            store._set_rows(np.arange(size),
                            np.asarray(blob["vectors"], np.float32))
        store.opt_acc[:size] = blob["opt_acc"]
        store.prev[:size] = blob["prev"]
        store.next[:size] = blob["next"]
        store.keys[:size] = blob["keys"]
        store.head, store.tail, store.size, store.evictions = head, tail, size, ev
        store.index = {int(k): i for i, k in enumerate(blob["keys"])}
        return store
