"""EmbeddingCollection — a registry of named embedding tables.

Persia's production models (paper §4.1, Table 1) are built from many
heterogeneous ID feature groups: different cardinalities, embedding dims,
optimizers and staleness budgets. This module makes that heterogeneity
first-class: a collection maps table *names* to independent
:class:`~repro.core.embedding_ps.EmbeddingSpec` s, and every collection-level
operation (``init`` / ``lookup`` / ``apply_put`` / ``hybrid_update``) fans
out to the per-table PS primitives — so each table keeps its own
uniform-shuffle row placement, dedup-put path and bounded-staleness queue.

All per-table state flows through plain dicts keyed by table name:

    states : {name: {"table": (R, D), "acc": (R,)?}}       (PS shard state)
    ids    : {name: int32 array, any shape, -1 = padding}
    acts   : {name: (*ids.shape, dim) activations}
    queues : {name: staleness FIFO or None}

which keeps everything jit-able, shardable and checkpointable as one pytree.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Iterator, Mapping

import jax

from repro.core import embedding_ps as PS
from repro.core.embedding_ps import EmbeddingSpec


@dataclass(frozen=True)
class EmbeddingCollection:
    """Ordered, immutable registry of named embedding tables."""

    tables: tuple[tuple[str, EmbeddingSpec], ...]

    def __post_init__(self):
        from repro.core.backend import parse_backend_name
        seen = set()
        for n, s in self.tables:
            # names become checkpoint blob paths: '/' would split the path,
            # and all-digit names deserialize as list indices, not keys
            if not n or "/" in n or n.isdigit():
                raise ValueError(
                    f"invalid table name {n!r}: names must be non-empty, "
                    "contain no '/', and not be all digits (they key the "
                    "checkpoint blob paths)")
            if n in seen:
                raise ValueError(f"duplicate table name {n!r}")
            seen.add(n)
            parse_backend_name(s.backend)       # fail fast on bad specs
            if int(s.emb_shards) < 1:
                raise ValueError(
                    f"table {n!r}: emb_shards must be >= 1 "
                    f"(got {s.emb_shards})")

    # -- construction -------------------------------------------------------

    @staticmethod
    def from_dict(specs: Mapping[str, EmbeddingSpec]) -> "EmbeddingCollection":
        return EmbeddingCollection(tuple(specs.items()))

    @staticmethod
    def single(name: str, spec: EmbeddingSpec) -> "EmbeddingCollection":
        return EmbeddingCollection(((name, spec),))

    # -- mapping protocol ----------------------------------------------------

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(n for n, _ in self.tables)

    @property
    def specs(self) -> dict[str, EmbeddingSpec]:
        return dict(self.tables)

    def items(self):
        return self.tables

    def __len__(self) -> int:
        return len(self.tables)

    def __iter__(self) -> Iterator[str]:
        return iter(self.names)

    def __contains__(self, name: str) -> bool:
        return any(n == name for n, _ in self.tables)

    def __getitem__(self, name: str) -> EmbeddingSpec:
        for n, s in self.tables:
            if n == name:
                return s
        raise KeyError(name)

    # -- derived sizes -------------------------------------------------------

    @property
    def total_rows(self) -> int:
        return sum(s.rows for _, s in self.tables)

    @property
    def total_params(self) -> int:
        return sum(s.rows * s.dim for _, s in self.tables)

    # -- spec surgery --------------------------------------------------------

    def map_specs(self, fn: Callable[[str, EmbeddingSpec], EmbeddingSpec]
                  ) -> "EmbeddingCollection":
        return EmbeddingCollection(tuple((n, fn(n, s)) for n, s in self.tables))

    def with_staleness(self, tau: int) -> "EmbeddingCollection":
        """Set every table's staleness to ``tau`` (mode-wide override)."""
        return self.map_specs(
            lambda _, s: dataclasses.replace(s, staleness=tau))

    def with_backend(self, backend: str,
                     cache_rows: int | None = None) -> "EmbeddingCollection":
        """Set every table's storage backend (collection-wide override);
        optionally also the host_lru device-cache size."""
        def fn(_, s):
            kw = {"backend": backend}
            if cache_rows is not None:
                kw["cache_rows"] = cache_rows
            return dataclasses.replace(s, **kw)
        return self.map_specs(fn)

    def with_store_dtype(self, store_dtype: str) -> "EmbeddingCollection":
        """Set every table's host-store row format (``"fp32"`` or the
        blockscale-compressed ``"blockscale16"``, core/lru.py)."""
        return self.map_specs(
            lambda _, s: dataclasses.replace(s, store_dtype=store_dtype))

    def with_backward_kernel(self, on: bool = True) -> "EmbeddingCollection":
        """Toggle the fused Pallas embedding backward on every table
        (kernels/fused_backward.py; off = the jitted jnp oracle)."""
        return self.map_specs(
            lambda _, s: dataclasses.replace(s, backward_kernel=bool(on)))

    def with_shards(self, shards: "int | Mapping[str, int]"
                    ) -> "EmbeddingCollection":
        """Set per-table embedding-PS shard counts (the ShardedBackend
        router, core/backend.py): an int shards every table, a mapping
        shards the named tables and leaves the rest unchanged. Mapping
        keys are validated against the registered table names."""
        self._check_shard_mapping(shards)
        if isinstance(shards, Mapping):
            return self.map_specs(lambda n, s: dataclasses.replace(
                s, emb_shards=int(shards.get(n, s.emb_shards))))
        return self.map_specs(
            lambda _, s: dataclasses.replace(s, emb_shards=int(shards)))

    # -- storage backends ----------------------------------------------------

    def make_backends(self):
        """One EmbeddingBackend per table (core/backend.py). Instances own
        mutable host state (LRU stores, slot maps): every trainer must build
        its own set."""
        from repro.core.backend import make_backends
        return make_backends(self)

    # -- collection-level PS ops ---------------------------------------------

    def _check_shard_mapping(self, shards) -> None:
        if not isinstance(shards, Mapping):
            return
        unknown = set(shards) - set(self.names)
        if unknown:
            raise ValueError(
                f"emb_shards names unknown tables {sorted(unknown)}; "
                f"collection has {list(self.names)}")
        bad = {n: k for n, k in shards.items() if int(k) < 1}
        if bad:
            raise ValueError(f"emb_shards must be >= 1, got {bad}")

    def _shards_for(self, name: str, shards) -> int:
        if isinstance(shards, Mapping):
            # a typo'd table name must fail loudly, not silently run
            # single-sharded (every caller funnels through here)
            self._check_shard_mapping(shards)
            return int(shards.get(name, 1))
        return int(shards)

    def init(self, key, shards: int | Mapping[str, int] = 1,
             scale: float = 0.02) -> dict[str, Any]:
        """Per-table PS state (table + row-wise optimizer accumulator)."""
        keys = jax.random.split(key, max(len(self.tables), 1))
        return {n: PS.ps_init(keys[i], s, self._shards_for(n, shards), scale)
                for i, (n, s) in enumerate(self.tables)}

    def _check_ids(self, ids: Mapping[str, Any]) -> None:
        unknown = set(ids) - set(self.names)
        if unknown:
            raise KeyError(f"ids for unknown tables {sorted(unknown)}; "
                           f"collection has {list(self.names)}")

    def lookup(self, states: Mapping[str, Any], ids: Mapping[str, Any]
               ) -> dict[str, jax.Array]:
        """Batched per-table gets; ids of any shape -> (..., dim) acts."""
        self._check_ids(ids)
        return {n: PS.lookup(states[n], self[n], ids[n]) for n in ids}

    def apply_put(self, states: Mapping[str, Any], ids: Mapping[str, Any],
                  grads: Mapping[str, Any]) -> dict[str, Any]:
        """Apply activation-gradient puts table-by-table (dedup per table)."""
        self._check_ids(ids)
        out = dict(states)
        for n in ids:
            spec = self[n]
            out[n] = PS.apply_put(states[n], spec, ids[n].reshape(-1),
                                  grads[n].reshape(-1, spec.dim))
        return out

    def queue_init(self, ids_shapes: Mapping[str, tuple]) -> dict[str, Any]:
        """Per-table staleness FIFOs (None for synchronous tables)."""
        out = {}
        for n, spec in self.tables:
            shape = ids_shapes.get(n)
            if shape is None or spec.staleness <= 0:
                out[n] = None
                continue
            n_ids = 1
            for s in shape:
                n_ids *= int(s)
            out[n] = PS.queue_init(spec, (n_ids,), spec.dim)
        return out

    def hybrid_update(self, states: Mapping[str, Any],
                      queues: Mapping[str, Any] | None,
                      ids: Mapping[str, Any], grads: Mapping[str, Any]
                      ) -> tuple[dict[str, Any], dict[str, Any]]:
        """One hybrid-algorithm update per table: push this step's put,
        apply the tau-stale put that pops out (tau=0 applies in place)."""
        self._check_ids(ids)
        queues = queues or {}
        new_states = dict(states)
        new_queues = dict(queues)
        for n in ids:
            spec = self[n]
            st, q = PS.hybrid_emb_update(
                states[n], queues.get(n), spec,
                ids[n].reshape(-1), grads[n].reshape(-1, spec.dim))
            new_states[n] = st
            new_queues[n] = q
        return new_states, new_queues
