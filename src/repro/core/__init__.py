"""Persia's primary contribution: the hybrid sync/async training algorithm
and the embedding-PS tier it runs against."""
from repro.core.embedding_ps import (EmbeddingSpec, ps_init, lookup,
                                     apply_put, hybrid_emb_update, queue_init)
from repro.core.collection import EmbeddingCollection
from repro.core.hybrid import (TrainMode, ModelAdapter, PersiaTrainer,
                               TrainState, init_train_state,
                               make_train_step, make_eval_step)
from repro.core.pipeline import PipelinedTrainer, PipelineStageError
