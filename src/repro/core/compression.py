"""Persia §4.2.3 communication compression.

* Lossless index compression: a batch of multi-hot samples is re-encoded as
  a unique-ID keyed map with uint16 sample indices (batch size <= 65535).
  On-device we use the same idea to *aggregate* gradient puts: duplicate ids
  within a put are segment-summed so the PS traffic is one row per unique id.
* Lossy value compression: non-uniform fp32 -> fp16 block scaling. Each block
  v is scaled by kappa / ||v||_inf before the fp16 cast and unscaled after,
  so the fp16 mantissa is spent on the block's actual dynamic range.

The Pallas TPU kernel for the lossy path lives in repro.kernels.blockscale;
this module is the jnp reference implementation + the host-side (numpy)
wire-format used by the compression benchmark.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

KAPPA = 32_768.0   # "relatively large constant scalar" (paper)


# ---------------------------------------------------------------------------
# Lossy blockscale fp16 (jnp reference; oracle for the Pallas kernel)
# ---------------------------------------------------------------------------

def blockscale_compress(v: jax.Array, block: int = 128):
    """v: (..., D) fp32 -> (fp16 blocks, fp32 per-block scales)."""
    orig_shape = v.shape
    flat = v.reshape(-1)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block).astype(jnp.float32)
    linf = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True)
    scale = KAPPA / jnp.maximum(linf, 1e-30)
    comp = (blocks * scale).astype(jnp.float16)
    return comp, scale[:, 0], orig_shape


def blockscale_decompress(comp, scale, orig_shape):
    blocks = comp.astype(jnp.float32) / scale[:, None]
    n = 1
    for s in orig_shape:
        n *= s
    return blocks.reshape(-1)[:n].reshape(orig_shape)


def blockscale_roundtrip(v, block: int = 128):
    c, s, shp = blockscale_compress(v, block)
    return blockscale_decompress(c, s, shp)


# ---------------------------------------------------------------------------
# Lossless index compression (wire format, host-side)
# ---------------------------------------------------------------------------

def compress_index_batch(ids_batch: np.ndarray):
    """ids_batch: (B, L) int64 multi-hot sample ids (−1 = padding).

    Returns (unique_ids int64 (U,), offsets uint32 (U+1,), sample_idx uint16)
    — the paper's hash-map representation: for each unique id, the list of
    samples containing it, with indices stored as uint16 (B <= 65535).
    """
    B, L = ids_batch.shape
    if B > 65535:
        raise ValueError(
            f"compress_index_batch stores sample indices as uint16, so the "
            f"batch size must be <= 65535 (got {B}); split the batch before "
            "encoding")
    samples = np.repeat(np.arange(B, dtype=np.uint16), L)
    flat = ids_batch.reshape(-1)
    keep = flat >= 0
    flat, samples = flat[keep], samples[keep]
    order = np.argsort(flat, kind="stable")
    flat, samples = flat[order], samples[order]
    unique, starts = np.unique(flat, return_index=True)
    offsets = np.concatenate([starts, [flat.size]]).astype(np.uint32)
    return unique.astype(np.int64), offsets, samples


def decompress_index_batch(unique, offsets, samples, batch, width):
    """Inverse of compress_index_batch (padding with −1)."""
    out = np.full((batch, width), -1, dtype=np.int64)
    fill = np.zeros(batch, dtype=np.int64)
    for u, s, e in zip(unique, offsets[:-1], offsets[1:]):
        for smp in samples[s:e]:
            out[smp, fill[smp]] = u
            fill[smp] += 1
    return out


def index_compression_ratio(ids_batch: np.ndarray) -> float:
    """bytes(original int64 list-of-vectors) / bytes(compressed map)."""
    raw = ids_batch.size * 8
    u, off, smp = compress_index_batch(ids_batch)
    comp = u.size * 8 + off.size * 4 + smp.size * 2
    return raw / max(comp, 1)


# ---------------------------------------------------------------------------
# On-device put aggregation (the same dedup idea, jit-able, static shapes)
# ---------------------------------------------------------------------------

def dedup_put(ids, grads, capacity: int):
    """Aggregate duplicate ids in a gradient put.

    ids: (T,) int32 (−1 = padding); grads: (T, D).
    Returns (unique_ids (capacity,), summed_grads (capacity, D)); unused
    slots carry id = −1. capacity should be >= the expected unique count —
    overflow rows are dropped (paper: infrequent lost puts are tolerable).
    """
    T, D = grads.shape
    order = jnp.argsort(jnp.where(ids < 0, jnp.iinfo(jnp.int32).max, ids))
    s_ids = ids[order]
    s_g = grads[order]
    is_new = jnp.concatenate([jnp.ones((1,), bool), s_ids[1:] != s_ids[:-1]])
    is_new &= s_ids >= 0
    group = jnp.cumsum(is_new.astype(jnp.int32)) - 1                # (T,)
    group = jnp.where(s_ids >= 0, group, capacity)
    uniq = jnp.full((capacity + 1,), -1, jnp.int32).at[group].max(
        jnp.where(s_ids >= 0, s_ids, -1))
    summed = jnp.zeros((capacity + 1, D), grads.dtype).at[group].add(s_g)
    return uniq[:capacity], summed[:capacity]
