"""The Persia hybrid training algorithm (paper Alg. 1 + Alg. 2).

One train step =
  (1) lookup: fetch embedding activations for the batch's ID features from
      the (possibly tau-stale) PS tables                     [Alg.1 forward]
  (2) dense forward/backward on the NN-worker side; gradients of the dense
      parameters are combined synchronously (the AllReduce paradigm — under
      GSPMD this is the automatic psum of replicated-param grads over the
      batch axes)                                            [Alg.2]
  (3) gradients *of the embedding activations* (F^emb') are sent back and
      pushed through each table's bounded-staleness queue; the put that pops
      out (from step t - tau) is applied by the PS-side optimizer
                                                             [Alg.1 backward]

Three modes reproduce the paper's comparison:
  * hybrid — emb staleness tau>0, dense sync              (Persia)
  * sync   — tau=0 everywhere                              (XDL-sync analog)
  * async  — emb stale AND dense grads applied tau_d steps late
             (Hogwild-style; XDL-async / aggressive-PaddlePaddle analog)

The public surface is :class:`PersiaTrainer`, a facade over a multi-table
:class:`~repro.core.collection.EmbeddingCollection`: it owns the pytree
:class:`TrainState`, the fused jitted step, the decomposed (3-dispatch,
donated) pipeline, eval, and full-state checkpoint/restore. The module-level
free functions (``init_train_state`` / ``make_train_step`` / ...) are kept as
thin single-table shims for the pre-collection API.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import backend as BK
from repro.core import embedding_ps as PS
from repro.core.collection import EmbeddingCollection
from repro.core.embedding_ps import EmbeddingSpec


@dataclass(frozen=True)
class TrainMode:
    name: str = "hybrid"
    emb_staleness: int = 3
    dense_staleness: int = 0

    @staticmethod
    def hybrid(tau: int = 3) -> "TrainMode":
        return TrainMode("hybrid", tau, 0)

    @staticmethod
    def sync() -> "TrainMode":
        return TrainMode("sync", 0, 0)

    @staticmethod
    def async_(tau: int = 3, tau_dense: int = 3) -> "TrainMode":
        return TrainMode("async", tau, tau_dense)


@dataclass(frozen=True)
class ModelAdapter:
    """Bridges a concrete model family to the hybrid trainer.

    ``emb_ids`` maps a batch to a dict of per-table id arrays keyed by the
    collection's table names; ``loss``/``predict`` receive the matching dict
    of looked-up activations.
    """
    cfg: Any
    collection: EmbeddingCollection
    init_dense: Callable[[jax.Array], Any]
    emb_ids: Callable[[dict], dict[str, jax.Array]]
    loss: Callable[[Any, dict[str, jax.Array], dict], tuple]
    predict: Optional[Callable] = None       # (dense, acts, batch) -> preds

    @property
    def emb_spec(self) -> EmbeddingSpec:
        """Legacy single-table view (pre-collection API)."""
        return _sole_table(self)[1]


# -- the train state ----------------------------------------------------------

@dataclass
class TrainState:
    """Everything one training run owns, as a single registered pytree:
    dense params + optimizer, per-table PS states, per-table staleness
    queues, the async-dense delay queue, and the step counter."""
    dense: Any
    opt: Any
    emb: dict                  # name -> {"table", "acc"?}
    emb_queue: Any             # name -> staleness FIFO | None
    dense_queue: Any           # delay queue for 'async' mode | None
    step: jax.Array

    def replace(self, **kw) -> "TrainState":
        return dataclasses.replace(self, **kw)


jax.tree_util.register_dataclass(
    TrainState,
    data_fields=("dense", "opt", "emb", "emb_queue", "dense_queue", "step"),
    meta_fields=())


# -- dense gradient delay queue (async baseline) ------------------------------

def _dense_queue_init(dense, tau):
    return {
        "grads": jax.tree.map(
            lambda p: jnp.zeros((tau,) + p.shape, jnp.float32), dense),
        "ptr": jnp.zeros((), jnp.int32),
        "filled": jnp.zeros((), jnp.int32),
    }


def _dense_queue_push_pop(queue, grads):
    ptr = queue["ptr"]
    old = jax.tree.map(lambda q: jnp.take(q, ptr, axis=0), queue["grads"])
    new_g = jax.tree.map(
        lambda q, g: jax.lax.dynamic_update_index_in_dim(
            q, g.astype(jnp.float32), ptr, 0),
        queue["grads"], grads)
    n_tau = jax.tree.leaves(queue["grads"])[0].shape[0]
    warm = queue["filled"] < n_tau
    # during warmup apply the fresh grad (queue slot still zero)
    old = jax.tree.map(lambda o, g: jnp.where(warm, g.astype(jnp.float32), o),
                       old, grads)
    return {"grads": new_g, "ptr": (ptr + 1) % n_tau,
            "filled": jnp.minimum(queue["filled"] + 1, n_tau)}, old


def _queue_leaf(q):
    """The (tau, W, ...) 'ids' array of a staleness queue, reaching into
    sharded-router queues ({"s0": {...}, ...}) when needed."""
    if q is None:
        return None
    return q["ids"] if "ids" in q else q["s0"]["ids"]


def _queue_depth(q) -> int:
    ids = _queue_leaf(q)
    return 0 if ids is None else int(ids.shape[0])


def _queue_width(q) -> int:
    ids = _queue_leaf(q)
    if ids is None:
        return 0
    w = 1
    for s in ids.shape[1:]:
        w *= int(s)
    return w


def _migrate_queue_widths(backend, q):
    """Restore-time staleness-queue width migration (worker-side dedup,
    core/dedup.py): the queue width is derived from the blob's own width
    through the backend's capacity rule — idempotent, so blobs already at
    unique width pass through unchanged, while full-width blobs written by
    a pre-dedup (or ``batch_dedup=False``) trainer are re-encoded by
    deduplicating each pending put host-side."""
    import numpy as np
    from repro.core import dedup as DD
    if q is None:
        return None
    if "ids" not in q:                   # sharded router: per-shard queues
        return {k: _migrate_queue_widths(backend, v) for k, v in q.items()}
    saved = int(np.shape(q["ids"])[1])
    new_w = int(backend.queue_width(saved))
    if new_w == saved:
        return q
    return DD.migrate_queue_blob(q, new_w)


def _emb_grad_norm(agrads: dict) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in agrads.values())
    return jnp.sqrt(sq)


# =============================================================================
# PersiaTrainer — the unified facade
# =============================================================================

class PersiaTrainer:
    """One object owning the whole hybrid training loop.

    >>> trainer = PersiaTrainer(adapter, TrainMode.hybrid(3),
    ...                         OptConfig(kind="adam", lr=3e-3))
    >>> state = trainer.init(jax.random.PRNGKey(0), batch)
    >>> state, metrics = trainer.step(state, batch)          # fused, donated
    >>> state, metrics = trainer.decomposed_step(state, batch)  # 3 dispatches
    >>> metrics = trainer.eval(state, batch)
    >>> trainer.save(ckpt_dir, state)                        # full state
    >>> state = trainer.restore(ckpt_dir)                    # bit-identical

    ``opt`` is either an ``OptConfig`` or a pre-built ``(opt_init,
    opt_update)`` pair. By default every table's staleness is overridden by
    ``mode.emb_staleness`` (matching the legacy API); pass
    ``per_table_staleness=True`` to honour each table's own
    ``EmbeddingSpec.staleness`` (heterogeneous update policies).
    """

    def __init__(self, adapter: ModelAdapter, mode: TrainMode | None = None,
                 opt: Any = None, lr_fn=None,
                 per_table_staleness: bool = False,
                 batch_dedup: bool | None = None):
        from repro.optim.optimizers import OptConfig, make_optimizer
        self.adapter = adapter
        self.mode = mode or TrainMode.hybrid()
        if opt is None:
            opt = OptConfig()
        if isinstance(opt, OptConfig):
            self.opt_init, self.opt_update = make_optimizer(opt)
        else:
            self.opt_init, self.opt_update = opt
        self.lr_fn = lr_fn
        if per_table_staleness:
            self.collection = adapter.collection
        else:
            self.collection = adapter.collection.with_staleness(
                self.mode.emb_staleness)
        # batch_dedup=None honours each spec's own flag (default True:
        # the worker-side dedup path, core/dedup.py); an explicit bool
        # overrides every table — False restores the occurrence-width
        # PR-4 data path (benchmarking / old-format checkpoints)
        if batch_dedup is not None:
            self.collection = self.collection.map_specs(
                lambda _, s: dataclasses.replace(s, batch_dedup=batch_dedup))
        # one storage backend per table (core/backend.py): dense PS,
        # host-LRU out-of-core, or either behind the compressed wire
        self.backends = self.collection.make_backends()
        self._needs_prepare = BK.any_requires_prepare(self.backends)
        self._needs_plan = any(s.batch_dedup
                               for _, s in self.collection.items())
        self._fused = None
        self._eval = None
        self._decomposed = None

    # -- init -----------------------------------------------------------------

    def init(self, key, batch_example=None, emb_shards=1) -> TrainState:
        """batch_example: abstract or concrete batch (for queue shapes).
        Required whenever any staleness is in play — without it the queues
        cannot be sized and tau>0 would silently train synchronously.

        ``emb_shards`` (an int or a {table: k} mapping, validated against
        the collection) selects per-table embedding-PS shard counts: dense
        tables keep the legacy meaning (PS row padding for mesh sharding)
        while host-backed tables route through the ShardedBackend router
        (k independent shards, concurrent fault-in) — they used to reject
        shards != 1 outright. Tables whose ``EmbeddingSpec.emb_shards`` is
        already > 1 are routers from construction; the default of 1 here
        never downgrades them."""
        # swap in routers BEFORE drawing state: backends are shared by the
        # cached jitted fns via the self.backends dict, mutated in place
        self.collection._check_shard_mapping(emb_shards)
        for n in self.collection.names:
            self.backends[n] = BK.ensure_shards(
                self.backends[n], self.collection._shards_for(n, emb_shards))
        self._needs_prepare = BK.any_requires_prepare(self.backends)
        max_tau = max((s.staleness for _, s in self.collection.items()),
                      default=0)
        if batch_example is None and \
                (max_tau > 0 or self.mode.dense_staleness > 0):
            raise ValueError(
                "init() needs a batch_example to size the staleness queues "
                f"(emb tau up to {max_tau}, dense tau_d="
                f"{self.mode.dense_staleness})")
        kd, ke = jax.random.split(key)
        dense = self.adapter.init_dense(kd)
        # per-table backend init (same key fan-out as collection.init)
        keys = jax.random.split(ke, max(len(self.collection), 1))
        emb = {n: self.backends[n].init(
            keys[i], self.collection._shards_for(n, emb_shards))
            for i, n in enumerate(self.collection.names)}
        emb_queue = {n: None for n in self.collection.names}
        dense_queue = None
        if batch_example is not None:
            ids = self.adapter.emb_ids(batch_example)
            emb_queue = {n: self.backends[n].queue_init(tuple(a.shape))
                         for n, a in ids.items()}
            for n in self.collection.names:
                emb_queue.setdefault(n, None)
            if self.mode.dense_staleness > 0:
                dense_queue = _dense_queue_init(dense,
                                                self.mode.dense_staleness)
        return TrainState(dense=dense, opt=self.opt_init(dense), emb=emb,
                          emb_queue=emb_queue, dense_queue=dense_queue,
                          step=jnp.zeros((), jnp.int32))

    # -- the host-side prepare phase (batch dedup + out-of-core fault-in) -----
    #
    # Two things happen here, once per step, OUTSIDE jit: (1) worker-side
    # batch dedup (core/dedup.py) — each table's ids are deduplicated to a
    # DedupPlan so the whole traceable path runs at unique width; (2) the
    # out-of-core fault-in for host-backed tables — missing rows load
    # host->device (consuming the plan's already-unique set, no second
    # np.unique), evicted rows write back, ids translate to device ids.
    # Only a trainer whose every table opts out (batch_dedup=False) with no
    # host-backed tables skips the phase entirely — that all-dense legacy
    # path is exactly the pre-dedup program.

    def _prepare(self, state: TrainState, batch):
        """Returns (state-with-faulted-caches, dev_ids-or-None, metrics)."""
        if not (self._needs_prepare or self._needs_plan):
            return state, None, {}
        ids = self.adapter.emb_ids(batch)
        emb, dev_ids, m = BK.prepare_all(self.backends, state.emb, ids)
        return state.replace(emb=emb), dev_ids, m

    # -- fused step (one program, one schedule) -------------------------------

    def train_step(self, state: TrainState, batch, dev_ids=None):
        """The fused step as a pure traceable function (jit it yourself, or
        use :meth:`step` for the cached donated jit). ``dev_ids`` carries
        prepared device ids for host-backed tables; all-dense trainers may
        leave it None."""
        adapter, mode = self.adapter, self.mode
        if dev_ids is None:
            if self._needs_prepare:
                raise ValueError(
                    "this trainer has host-backed (out-of-core) tables: "
                    "the fused step needs prepared device ids — call "
                    "step()/decomposed_step(), which run the host fault-in "
                    "phase, instead of jitting train_step directly")
            dev_ids = adapter.emb_ids(batch)
        acts, get_metrics = BK.lookup_all(self.backends, state.emb,
                                          dev_ids)                # Alg.1 fwd

        def loss_fn(dense, acts_):
            return adapter.loss(dense, acts_, batch)

        (loss, metrics), (dgrads, agrads) = jax.value_and_grad(
            loss_fn, argnums=(0, 1), has_aux=True)(state.dense, acts)

        lr = self.lr_fn(state.step) if self.lr_fn is not None else None

        # ---- dense side (Alg.2): synchronous, or delayed for 'async' ----
        dense_queue = state.dense_queue
        if mode.dense_staleness > 0 and dense_queue is not None:
            dense_queue, dgrads_apply = _dense_queue_push_pop(dense_queue,
                                                              dgrads)
        else:
            dgrads_apply = dgrads
        dense, opt = self.opt_update(state.dense, dgrads_apply, state.opt,
                                     lr=lr)

        # ---- embedding side (Alg.1 bwd): async puts through the queues ----
        emb, emb_queue, put_metrics = BK.put_all(
            self.backends, state.emb, state.emb_queue, dev_ids, agrads)

        metrics = dict(metrics)
        metrics["emb_grad_norm"] = _emb_grad_norm(agrads)
        metrics.update(get_metrics)
        metrics.update(put_metrics)
        return state.replace(dense=dense, opt=opt, emb=emb,
                             emb_queue=emb_queue, dense_queue=dense_queue,
                             step=state.step + 1), metrics

    def step(self, state: TrainState, batch):
        """Fused step through a cached jit; donates ``state``. The host
        prepare phase (batch dedup + out-of-core fault-in) runs before the
        jitted program."""
        state, dev_ids, prep_m = self._prepare(state, batch)
        if self._fused is None:
            self._fused = jax.jit(self.train_step, donate_argnums=(0,))
        state, metrics = self._fused(state, batch, dev_ids)
        metrics.update(prep_m)
        metrics.update(BK.shard_step_metrics(self.backends))
        return state, metrics

    # -- decomposed pipeline ---------------------------------------------------
    #
    # The fused step is what the dry-run lowers (one program, one schedule).
    # At runtime Persia's architecture is *decomposed*: the embedding get,
    # the dense step and the embedding put are separate dispatches (separate
    # RPCs in the paper), which lets the runtime overlap them and — crucially
    # — lets XLA alias the donated PS tables in the put (in-place row
    # scatter, O(#puts) instead of an O(rows) defensive copy).

    def decomposed_fns(self):
        """(lookup_fn, dense_step, emb_put) — separate jitted dispatches."""
        if self._decomposed is not None:
            return self._decomposed
        adapter, mode = self.adapter, self.mode
        backends = self.backends
        lr_fn, opt_update = self.lr_fn, self.opt_update

        @jax.jit
        def lookup_fn(emb_states, dev_ids):
            return BK.lookup_all(backends, emb_states, dev_ids)  # Alg.1 fwd

        @partial(jax.jit, donate_argnums=(0, 1, 2))
        def dense_step(dense, opt, dense_queue, acts, batch, step_no):
            def loss_fn(dense_, acts_):                        # Alg.2
                return adapter.loss(dense_, acts_, batch)

            (loss, metrics), (dgrads, agrads) = jax.value_and_grad(
                loss_fn, argnums=(0, 1), has_aux=True)(dense, acts)
            lr = lr_fn(step_no) if lr_fn is not None else None
            if mode.dense_staleness > 0 and dense_queue is not None:
                dense_queue, dgrads = _dense_queue_push_pop(dense_queue,
                                                            dgrads)
            dense, opt = opt_update(dense, dgrads, opt, lr=lr)
            metrics = dict(metrics)
            metrics["emb_grad_norm"] = _emb_grad_norm(agrads)
            return dense, opt, dense_queue, agrads, metrics

        @partial(jax.jit, donate_argnums=(0, 1))
        def emb_put(emb_states, queues, dev_ids, agrads):      # Alg.1 bwd
            return BK.put_all(backends, emb_states, queues, dev_ids, agrads)

        self._decomposed = (lookup_fn, dense_step, emb_put)
        return self._decomposed

    def decomposed_step(self, state: TrainState, batch):
        """One iteration through the decomposed pipeline (host-driven): the
        out-of-core fault-in (prepare), the embedding get, the dense step
        and the embedding put are separate dispatches."""
        lookup_fn, dense_step, emb_put = self.decomposed_fns()
        state, dev_ids, prep_m = self._prepare(state, batch)
        if dev_ids is None:
            dev_ids = self.adapter.emb_ids(batch)
        acts, get_metrics = lookup_fn(state.emb, dev_ids)
        dense, opt, dense_queue, agrads, metrics = dense_step(
            state.dense, state.opt, state.dense_queue, acts, batch,
            state.step)
        # the put is dispatched without blocking — the async leg of the hybrid
        emb, queues, put_metrics = emb_put(state.emb, state.emb_queue,
                                           dev_ids, agrads)
        metrics = dict(metrics)
        metrics.update(prep_m)
        metrics.update(get_metrics)
        metrics.update(put_metrics)
        # host-side per-shard gauges (hit rates, faults, load imbalance)
        metrics.update(BK.shard_step_metrics(self.backends))
        return state.replace(dense=dense, opt=opt, dense_queue=dense_queue,
                             emb=emb, emb_queue=queues,
                             step=state.step + 1), metrics

    def run(self, state: TrainState, batches, steps: int | None = None,
            delay_fn=None) -> tuple[TrainState, list[dict]]:
        """Serial reference loop: one ``decomposed_step`` per batch
        (optionally capped at ``steps``), returning the final state and the
        per-step metrics. ``delay_fn(stage, step) -> seconds`` injects the
        same per-stage latencies the pipelined engine understands — paid
        serially here, which is what makes ``benchmarks/pipeline.py`` an
        apples-to-apples serial-vs-pipelined comparison."""
        import time
        stages = ("loader", "prepare", "lookup", "dense", "put")
        metrics_list: list[dict] = []
        for idx, batch in enumerate(batches):
            if steps is not None and idx >= steps:
                break
            if delay_fn is not None:
                for stage in stages:
                    d = float(delay_fn(stage, idx))
                    if d > 0:
                        time.sleep(d)
            state, m = self.decomposed_step(state, batch)
            metrics_list.append(m)
        return state, metrics_list

    # -- eval / predict --------------------------------------------------------

    def eval_step(self, state: TrainState, batch, dev_ids=None):
        if dev_ids is None:
            if self._needs_prepare:
                raise ValueError(
                    "this trainer has host-backed (out-of-core) tables: "
                    "eval_step needs prepared device ids — call eval()")
            dev_ids = self.adapter.emb_ids(batch)
        acts, _ = BK.lookup_all(self.backends, state.emb, dev_ids)
        _, metrics = self.adapter.loss(state.dense, acts, batch)
        return metrics

    def serve_lookup(self, state: TrainState, batch):
        """Read-path lookup (``EmbeddingBackend.read_rows``): logical ids
        -> fp32 activations, **without** faulting rows into the device
        cache or touching any backend host state. Host-tier rows are read
        straight from the store; residency is resolved against the passed
        state snapshot, so a serving thread can call this concurrently
        with a trainer stepping on the same backends. Returns ``(acts,
        info)`` with per-table ``{reads, hits, misses}`` read gauges."""
        ids = self.adapter.emb_ids(batch)
        acts, info = {}, {}
        for n, a in ids.items():
            rows, inf = self.backends[n].read_rows(state.emb[n], a)
            acts[n] = jnp.asarray(rows)
            info[n] = inf
        return acts, info

    def eval(self, state: TrainState, batch):
        """Eval on the current tables through the read-only serve path.
        Unlike the pre-serving implementation this never faults rows into
        the device cache — no state mutation, no evictions, no dropped
        queued puts — so eval is perfectly side-effect-free on every
        backend."""
        acts, _ = self.serve_lookup(state, batch)
        if self._eval is None:
            adapter = self.adapter
            self._eval = jax.jit(
                lambda dense, acts_, b: adapter.loss(dense, acts_, b)[1])
        return self._eval(state.dense, acts, batch)

    def lookup(self, state: TrainState, batch):
        acts, _ = self.serve_lookup(state, batch)
        return acts

    def predict(self, state: TrainState, batch):
        if self.adapter.predict is None:
            raise ValueError("adapter has no predict fn")
        acts = self.lookup(state, batch)
        return self.adapter.predict(state.dense, acts, batch)

    # -- checkpoint (full state, paper §4.2.4 policy) --------------------------
    #
    # The dense tree (params + optimizer + delay queue) is saved atomically;
    # the per-table PS states and staleness queues ride in the independent
    # embedding blob. Everything round-trips — including the adagrad
    # accumulators and queue contents — so a restore resumes bit-identically.

    def save(self, directory: str, state: TrainState,
             step: int | None = None) -> str:
        from repro.checkpoint.ckpt import save_checkpoint
        import numpy as np
        step = int(state.step) if step is None else int(step)
        to_np = lambda t: jax.tree.map(np.asarray, t)  # noqa: E731
        dense_tree = {"dense": to_np(state.dense), "opt": to_np(state.opt)}
        if state.dense_queue is not None:
            dense_tree["dense_queue"] = to_np(state.dense_queue)
        # each backend snapshots its own tiers (dense: the PS shard arrays;
        # host_lru: device cache + host store + slot map, recency included)
        emb_tree = {"emb": {n: self.backends[n].state_for_checkpoint(
                        state.emb[n]) for n in state.emb},
                    "emb_queue": to_np(state.emb_queue)}
        return save_checkpoint(directory, step, dense_tree, emb_tree)

    def restore(self, directory: str, step: int | None = None) -> TrainState:
        from repro.checkpoint.ckpt import load_checkpoint
        step_no, dense_tree, emb_tree = load_checkpoint(directory, step)
        if not emb_tree or "emb" not in emb_tree or "dense" not in dense_tree:
            raise ValueError(
                f"checkpoint at {directory!r} is not a PersiaTrainer "
                "full-state snapshot (no per-table embedding blob) — it was "
                "likely written by the legacy save_checkpoint API")
        want, got = set(self.collection.names), set(emb_tree["emb"])
        if want != got:
            raise ValueError(
                f"checkpoint tables {sorted(got)} do not match this "
                f"trainer's collection {sorted(want)}")
        emb = {}
        for n in self.collection.names:
            try:
                emb[n] = self.backends[n].restore_from_checkpoint(
                    emb_tree["emb"][n])
            except ValueError as e:
                raise ValueError(f"checkpoint table {n!r}: {e}") from e
        queues = emb_tree.get("emb_queue", {})
        emb_queue = {n: queues.get(n) for n in self.collection.names}
        for n in self.collection.names:
            tau, q = self.collection[n].staleness, emb_queue[n]
            saved = _queue_depth(q)
            if (tau > 0) != (q is not None) or (q is not None
                                                and saved != tau):
                raise ValueError(
                    f"checkpoint table {n!r} was saved with staleness "
                    f"tau={saved} but this trainer runs tau={tau} — "
                    "restoring across modes would silently drop or bypass "
                    "the pending-put queue; rebuild the trainer with the "
                    "mode the checkpoint was trained under")
        for n in self.collection.names:
            bk = BK.unwrap(self.backends[n])
            if emb_queue[n] is not None and \
                    getattr(bk, "last_restore_resharded", False):
                # the table was resharded on restore: pending queue puts
                # are addressed in the OLD shard geometry (cache slots /
                # per-shard local ids), so they are dropped — the paper's
                # tolerated in-flight loss — and the FIFO restarts empty
                # in the new geometry, replaying its warmup
                emb_queue[n] = bk.queue_init((_queue_width(emb_queue[n]),))
        for n in self.collection.names:
            # old-format (occurrence-width) queue blobs restore into a
            # batch-dedup trainer by re-encoding each pending put at the
            # unique width this trainer runs (host-side dedup; the pops
            # then apply the exact same fp32 updates). Width-stable blobs
            # pass through untouched — same-geometry restores stay
            # bit-identical.
            emb_queue[n] = _migrate_queue_widths(self.backends[n],
                                                 emb_queue[n])
        dq = dense_tree.get("dense_queue")
        tau_d = self.mode.dense_staleness
        dq_depth = 0 if dq is None else \
            int(jax.tree.leaves(dq["grads"])[0].shape[0])
        if (tau_d > 0) != (dq is not None) or dq_depth not in (0, tau_d):
            raise ValueError(
                f"checkpoint was saved with dense staleness tau_d="
                f"{dq_depth} but this trainer runs tau_d={tau_d} — "
                "rebuild the trainer with the mode the checkpoint was "
                "trained under")
        return TrainState(
            dense=dense_tree["dense"], opt=dense_tree["opt"],
            emb=emb, emb_queue=emb_queue,
            dense_queue=dq,
            step=jnp.asarray(step_no, jnp.int32))


# =============================================================================
# Legacy single-table shims (pre-collection free-function API)
# =============================================================================
#
# These keep the original dict-state surface working for adapters whose
# collection holds exactly one table (the LM family). Multi-table models
# must use PersiaTrainer. The step logic is intentionally duplicated rather
# than delegated: the legacy factories receive opt_init and opt_update at
# different call sites, which doesn't map onto one facade construction, and
# freezing the old behavior here keeps the deprecated surface stable until
# its callers are migrated.

def _sole_table(adapter: ModelAdapter) -> tuple[str, EmbeddingSpec]:
    items = adapter.collection.items()
    if len(items) != 1:
        raise ValueError(
            "the legacy free-function API supports single-table adapters "
            f"only (got {len(items)} tables); use PersiaTrainer instead")
    return items[0]


def init_train_state(adapter: ModelAdapter, mode: TrainMode, opt_init,
                     key, batch_example=None, emb_shards: int = 1):
    """batch_example: abstract or concrete batch (for queue shapes)."""
    name, spec0 = _sole_table(adapter)
    kd, ke = jax.random.split(key)
    dense = adapter.init_dense(kd)
    spec = dataclasses.replace(spec0, staleness=mode.emb_staleness)
    emb = PS.ps_init(ke, spec, emb_shards)
    state = {
        "dense": dense,
        "opt": opt_init(dense),
        "emb": emb,
        "emb_queue": None,
        "dense_queue": None,
        "step": jnp.zeros((), jnp.int32),
    }
    if batch_example is not None:
        ids = adapter.emb_ids(batch_example)[name]
        n_ids = 1
        for s in ids.shape:
            n_ids *= s
        if mode.emb_staleness > 0:
            state["emb_queue"] = PS.queue_init(spec, (n_ids,), spec.dim)
        if mode.dense_staleness > 0:
            state["dense_queue"] = _dense_queue_init(dense,
                                                     mode.dense_staleness)
    return state, spec


def make_train_step(adapter: ModelAdapter, spec: EmbeddingSpec,
                    mode: TrainMode, opt_update, lr_fn=None):
    """Returns train_step(state, batch) -> (state, metrics); jit-able,
    lowerable on any mesh. Single-table legacy surface."""
    name, _ = _sole_table(adapter)

    def train_step(state, batch):
        ids = adapter.emb_ids(batch)[name]
        acts = PS.lookup(state["emb"], spec, ids)                 # Alg.1 fwd

        def loss_fn(dense, acts_):
            return adapter.loss(dense, {name: acts_}, batch)

        (loss, metrics), (dgrads, agrads) = jax.value_and_grad(
            loss_fn, argnums=(0, 1), has_aux=True)(state["dense"], acts)

        lr = lr_fn(state["step"]) if lr_fn is not None else None

        # ---- dense side (Alg.2): synchronous, or delayed for 'async' ----
        dense_queue = state["dense_queue"]
        if mode.dense_staleness > 0 and dense_queue is not None:
            dense_queue, dgrads_apply = _dense_queue_push_pop(dense_queue,
                                                              dgrads)
        else:
            dgrads_apply = dgrads
        dense, opt = opt_update(state["dense"], dgrads_apply, state["opt"],
                                lr=lr)

        # ---- embedding side (Alg.1 bwd): async put through the queue ----
        flat_ids = ids.reshape(-1)
        flat_g = agrads.reshape(-1, spec.dim)
        emb, emb_queue = PS.hybrid_emb_update(
            state["emb"], state["emb_queue"], spec, flat_ids, flat_g)

        new_state = {
            "dense": dense, "opt": opt, "emb": emb,
            "emb_queue": emb_queue, "dense_queue": dense_queue,
            "step": state["step"] + 1,
        }
        metrics = dict(metrics)
        metrics["emb_grad_norm"] = jnp.sqrt(
            jnp.sum(jnp.square(flat_g.astype(jnp.float32))))
        return new_state, metrics

    return train_step


def make_decomposed_fns(adapter: ModelAdapter, spec: EmbeddingSpec,
                        mode: TrainMode, opt_update, lr_fn=None):
    name, _ = _sole_table(adapter)

    @jax.jit
    def lookup_fn(emb_state, ids):
        return PS.lookup(emb_state, spec, ids)                 # Alg.1 fwd

    @partial(jax.jit, donate_argnums=(0, 1))
    def dense_step(dense, opt, acts, batch, step_no):          # Alg.2
        def loss_fn(dense_, acts_):
            return adapter.loss(dense_, {name: acts_}, batch)

        (loss, metrics), (dgrads, agrads) = jax.value_and_grad(
            loss_fn, argnums=(0, 1), has_aux=True)(dense, acts)
        lr = lr_fn(step_no) if lr_fn is not None else None
        dense, opt = opt_update(dense, dgrads, opt, lr=lr)
        return dense, opt, agrads, metrics

    @partial(jax.jit, donate_argnums=(0, 1))
    def emb_put(emb_state, queue, ids, agrads):                # Alg.1 bwd
        flat_ids = ids.reshape(-1)
        flat_g = agrads.reshape(-1, spec.dim)
        return PS.hybrid_emb_update(emb_state, queue, spec, flat_ids, flat_g)

    return lookup_fn, dense_step, emb_put


def decomposed_train_step(fns, state, batch, adapter):
    """One iteration through the decomposed pipeline (host-driven)."""
    name, _ = _sole_table(adapter)
    lookup_fn, dense_step, emb_put = fns
    ids = adapter.emb_ids(batch)[name]
    acts = lookup_fn(state["emb"], ids)
    dense, opt, agrads, metrics = dense_step(state["dense"], state["opt"],
                                             acts, batch, state["step"])
    # the put is dispatched without blocking — the async leg of the hybrid
    emb, queue = emb_put(state["emb"], state["emb_queue"], ids, agrads)
    new_state = dict(state)
    new_state.update(dense=dense, opt=opt, emb=emb, emb_queue=queue,
                     step=state["step"] + 1)
    return new_state, metrics


def make_eval_step(adapter: ModelAdapter, spec: EmbeddingSpec):
    name, _ = _sole_table(adapter)

    def eval_step(state, batch):
        ids = adapter.emb_ids(batch)[name]
        acts = PS.lookup(state["emb"], spec, ids)
        _, metrics = adapter.loss(state["dense"], {name: acts}, batch)
        return metrics
    return eval_step
