"""The Persia hybrid training algorithm (paper Alg. 1 + Alg. 2).

One train step =
  (1) lookup: fetch embedding activations for the batch's ID features from
      the (possibly tau-stale) PS table                      [Alg.1 forward]
  (2) dense forward/backward on the NN-worker side; gradients of the dense
      parameters are combined synchronously (the AllReduce paradigm — under
      GSPMD this is the automatic psum of replicated-param grads over the
      batch axes)                                            [Alg.2]
  (3) gradients *of the embedding activations* (F^emb') are sent back and
      pushed through the bounded-staleness queue; the put that pops out
      (from step t - tau) is applied by the PS-side optimizer [Alg.1 backward]

Three modes reproduce the paper's comparison:
  * hybrid — emb staleness tau>0, dense sync              (Persia)
  * sync   — tau=0 everywhere                              (XDL-sync analog)
  * async  — emb stale AND dense grads applied tau_d steps late
             (Hogwild-style; XDL-async / aggressive-PaddlePaddle analog)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import embedding_ps as PS
from repro.core.embedding_ps import EmbeddingSpec


@dataclass(frozen=True)
class TrainMode:
    name: str = "hybrid"
    emb_staleness: int = 3
    dense_staleness: int = 0

    @staticmethod
    def hybrid(tau: int = 3) -> "TrainMode":
        return TrainMode("hybrid", tau, 0)

    @staticmethod
    def sync() -> "TrainMode":
        return TrainMode("sync", 0, 0)

    @staticmethod
    def async_(tau: int = 3, tau_dense: int = 3) -> "TrainMode":
        return TrainMode("async", tau, tau_dense)


@dataclass(frozen=True)
class ModelAdapter:
    """Bridges a concrete model family to the hybrid trainer."""
    cfg: Any
    emb_spec: EmbeddingSpec
    init_dense: Callable[[jax.Array], Any]
    emb_ids: Callable[[dict], jax.Array]          # batch -> ids (any shape)
    loss: Callable[[Any, jax.Array, dict], tuple] # (dense, acts, batch)
    predict: Optional[Callable] = None            # (dense, acts, batch) -> preds


def init_train_state(adapter: ModelAdapter, mode: TrainMode, opt_init,
                     key, batch_example=None, emb_shards: int = 1):
    """batch_example: abstract or concrete batch (for queue shapes)."""
    import dataclasses
    kd, ke = jax.random.split(key)
    dense = adapter.init_dense(kd)
    spec = dataclasses.replace(adapter.emb_spec,
                               staleness=mode.emb_staleness)
    emb = PS.ps_init(ke, spec, emb_shards)
    state = {
        "dense": dense,
        "opt": opt_init(dense),
        "emb": emb,
        "emb_queue": None,
        "dense_queue": None,
        "step": jnp.zeros((), jnp.int32),
    }
    if batch_example is not None:
        ids = adapter.emb_ids(batch_example)
        n_ids = 1
        for s in ids.shape:
            n_ids *= s
        if mode.emb_staleness > 0:
            state["emb_queue"] = PS.queue_init(spec, (n_ids,), spec.dim)
        if mode.dense_staleness > 0:
            state["dense_queue"] = _dense_queue_init(dense,
                                                     mode.dense_staleness)
    return state, spec


# -- dense gradient delay queue (async baseline) ------------------------------

def _dense_queue_init(dense, tau):
    return {
        "grads": jax.tree.map(
            lambda p: jnp.zeros((tau,) + p.shape, jnp.float32), dense),
        "ptr": jnp.zeros((), jnp.int32),
        "filled": jnp.zeros((), jnp.int32),
    }


def _dense_queue_push_pop(queue, grads):
    ptr = queue["ptr"]
    old = jax.tree.map(lambda q: jnp.take(q, ptr, axis=0), queue["grads"])
    new_g = jax.tree.map(
        lambda q, g: jax.lax.dynamic_update_index_in_dim(
            q, g.astype(jnp.float32), ptr, 0),
        queue["grads"], grads)
    n_tau = jax.tree.leaves(queue["grads"])[0].shape[0]
    warm = queue["filled"] < n_tau
    # during warmup apply the fresh grad (queue slot still zero)
    old = jax.tree.map(lambda o, g: jnp.where(warm, g.astype(jnp.float32), o),
                       old, grads)
    return {"grads": new_g, "ptr": (ptr + 1) % n_tau,
            "filled": jnp.minimum(queue["filled"] + 1, n_tau)}, old


# -- the train step ------------------------------------------------------------

def make_train_step(adapter: ModelAdapter, spec: EmbeddingSpec,
                    mode: TrainMode, opt_update, lr_fn=None):
    """Returns train_step(state, batch) -> (state, metrics); jit-able,
    lowerable on any mesh."""

    def train_step(state, batch):
        ids = adapter.emb_ids(batch)
        acts = PS.lookup(state["emb"], spec, ids)                 # Alg.1 fwd

        def loss_fn(dense, acts_):
            return adapter.loss(dense, acts_, batch)

        (loss, metrics), (dgrads, agrads) = jax.value_and_grad(
            loss_fn, argnums=(0, 1), has_aux=True)(state["dense"], acts)

        lr = lr_fn(state["step"]) if lr_fn is not None else None

        # ---- dense side (Alg.2): synchronous, or delayed for 'async' ----
        dense_queue = state["dense_queue"]
        if mode.dense_staleness > 0 and dense_queue is not None:
            dense_queue, dgrads_apply = _dense_queue_push_pop(dense_queue,
                                                              dgrads)
        else:
            dgrads_apply = dgrads
        dense, opt = opt_update(state["dense"], dgrads_apply, state["opt"],
                                lr=lr)

        # ---- embedding side (Alg.1 bwd): async put through the queue ----
        flat_ids = ids.reshape(-1)
        flat_g = agrads.reshape(-1, spec.dim)
        emb, emb_queue = PS.hybrid_emb_update(
            state["emb"], state["emb_queue"], spec, flat_ids, flat_g)

        new_state = {
            "dense": dense, "opt": opt, "emb": emb,
            "emb_queue": emb_queue, "dense_queue": dense_queue,
            "step": state["step"] + 1,
        }
        metrics = dict(metrics)
        metrics["emb_grad_norm"] = jnp.sqrt(
            jnp.sum(jnp.square(flat_g.astype(jnp.float32))))
        return new_state, metrics

    return train_step


# -- decomposed pipeline -----------------------------------------------------
#
# The fused train_step above is what the dry-run lowers (one program, one
# schedule). At runtime Persia's architecture is *decomposed*: the embedding
# get, the dense step and the embedding put are separate dispatches (separate
# RPCs in the paper), which lets the runtime overlap them and — crucially —
# lets XLA alias the donated PS table in the put (in-place row scatter, O(#puts)
# instead of an O(rows) defensive copy).

def make_decomposed_fns(adapter: ModelAdapter, spec: EmbeddingSpec,
                        mode: TrainMode, opt_update, lr_fn=None):
    from repro.core import embedding_ps as _PS

    @jax.jit
    def lookup_fn(emb_state, ids):
        return _PS.lookup(emb_state, spec, ids)                # Alg.1 fwd

    @partial(jax.jit, donate_argnums=(0, 1))
    def dense_step(dense, opt, acts, batch, step_no):          # Alg.2
        def loss_fn(dense_, acts_):
            return adapter.loss(dense_, acts_, batch)

        (loss, metrics), (dgrads, agrads) = jax.value_and_grad(
            loss_fn, argnums=(0, 1), has_aux=True)(dense, acts)
        lr = lr_fn(step_no) if lr_fn is not None else None
        dense, opt = opt_update(dense, dgrads, opt, lr=lr)
        return dense, opt, agrads, metrics

    @partial(jax.jit, donate_argnums=(0, 1))
    def emb_put(emb_state, queue, ids, agrads):                # Alg.1 bwd
        flat_ids = ids.reshape(-1)
        flat_g = agrads.reshape(-1, spec.dim)
        return PS.hybrid_emb_update(emb_state, queue, spec, flat_ids, flat_g)

    return lookup_fn, dense_step, emb_put


def decomposed_train_step(fns, state, batch, adapter):
    """One iteration through the decomposed pipeline (host-driven)."""
    lookup_fn, dense_step, emb_put = fns
    ids = adapter.emb_ids(batch)
    acts = lookup_fn(state["emb"], ids)
    dense, opt, agrads, metrics = dense_step(state["dense"], state["opt"],
                                             acts, batch, state["step"])
    # the put is dispatched without blocking — the async leg of the hybrid
    emb, queue = emb_put(state["emb"], state["emb_queue"], ids, agrads)
    new_state = dict(state)
    new_state.update(dense=dense, opt=opt, emb=emb, emb_queue=queue,
                     step=state["step"] + 1)
    return new_state, metrics


# -- eval step -------------------------------------------------------------------

def make_eval_step(adapter: ModelAdapter, spec: EmbeddingSpec):
    def eval_step(state, batch):
        ids = adapter.emb_ids(batch)
        acts = PS.lookup(state["emb"], spec, ids)
        _, metrics = adapter.loss(state["dense"], acts, batch)
        return metrics
    return eval_step
