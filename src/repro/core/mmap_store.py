"""Disk/mmap embedding tier + the tiered host store that stacks it under
the host LRU (ROADMAP open item 1: logical rows beyond host RAM).

Two classes, both speaking the :class:`~repro.core.lru.LRUEmbeddingStore`
bulk API (``read_rows`` / ``write_rows`` / ``preload`` / ``serialize``)
so the host_lru backend can swap either in without touching its fault
path:

* :class:`MmapEmbeddingStore` — the bottom tier. All ``rows`` logical
  rows of one table live in memory-mapped ``.npy`` files (vectors +
  adagrad accumulators + a liveness byte per row); the id IS the row
  index, so reads/writes are fancy-indexed memmap slices and the OS page
  cache decides what is actually resident. Never-written rows initialise
  on first read from a seeded RNG — the same per-row
  ``standard_normal(dim) * init_scale`` draw, in the same order, as the
  LRU store's miss path, so which tier serves a first touch never
  changes the value.
* :class:`TieredHostStore` — host LRU tier of ``host_rows`` rows over an
  MmapEmbeddingStore of all ``rows``. Reads promote disk rows into the
  host tier; host-tier LRU evictions *spill* to disk through the store's
  ``on_evict`` hook (an eviction is a demotion, never a loss). Selected
  via ``EmbeddingSpec.backend="host_lru+disk"``: the device cache then
  sits on top, making the full hierarchy device-HBM -> host-RAM -> disk,
  the shape Persia §4.2.2 runs at 100T parameters.
"""
from __future__ import annotations

import os
import tempfile

import numpy as np

from repro.core.lru import (LRUEmbeddingStore, STORE_DTYPES, bs_blocks,
                            bs_compress_rows, bs_decompress_rows,
                            rng_state_array, set_rng_state)


class MmapEmbeddingStore:
    """All ``rows`` logical rows of one table, memory-mapped on disk."""

    def __init__(self, rows: int, dim: int, seed: int = 0,
                 init_scale: float = 0.02, path: str | None = None,
                 store_dtype: str = "fp32"):
        assert rows > 0
        self.capacity = int(rows)
        self.dim = int(dim)
        self._rng = np.random.default_rng(seed)
        self._init_scale = float(init_scale)
        if store_dtype not in STORE_DTYPES:
            raise ValueError(
                f"unknown store_dtype {store_dtype!r}: one of {STORE_DTYPES}")
        self.store_dtype = store_dtype
        if path is None:
            self._tmp = tempfile.TemporaryDirectory(prefix="mmap_emb_")
            path = self._tmp.name
        else:
            self._tmp = None
            os.makedirs(path, exist_ok=True)
        self.path = path
        mm = np.lib.format.open_memmap
        # 'blockscale16' maps the vector payload as fp16 + one fp32 scale
        # per <=128-wide block — cold on-disk rows at ~half the bytes
        if store_dtype == "blockscale16":
            self.vectors = mm(os.path.join(path, "vectors.npy"), mode="w+",
                              dtype=np.float16,
                              shape=(self.capacity, self.dim))
            self.vec_scale = mm(os.path.join(path, "vec_scale.npy"),
                                mode="w+", dtype=np.float32,
                                shape=(self.capacity, bs_blocks(self.dim)))
        else:
            self.vectors = mm(os.path.join(path, "vectors.npy"), mode="w+",
                              dtype=np.float32,
                              shape=(self.capacity, self.dim))
            self.vec_scale = None
        self.opt_acc = mm(os.path.join(path, "opt_acc.npy"), mode="w+",
                          dtype=np.float32, shape=(self.capacity,))
        self.live = mm(os.path.join(path, "live.npy"), mode="w+",
                       dtype=np.uint8, shape=(self.capacity,))
        self.size = 0                        # live rows

    def _check_ids(self, ids: np.ndarray) -> np.ndarray:
        ids = np.asarray(ids, np.int64).reshape(-1)
        if ids.size and (ids.min() < 0 or ids.max() >= self.capacity):
            raise ValueError(
                f"mmap store ids must be in [0, {self.capacity}) — the "
                "disk tier is keyed by logical row index")
        return ids

    def _mark_live(self, ids: np.ndarray):
        fresh = ids[self.live[ids] == 0]
        if fresh.size:
            self.live[fresh] = 1
            self.size += int(np.unique(fresh).size)

    # -- store_dtype-aware payload access -----------------------------------

    def _get_rows(self, ids) -> np.ndarray:
        if self.vec_scale is None:
            return np.asarray(self.vectors[ids], np.float32)
        return bs_decompress_rows(np.asarray(self.vectors[ids]),
                                  np.asarray(self.vec_scale[ids]))

    def _set_rows(self, ids, vals):
        vals = np.asarray(vals, np.float32).reshape(-1, self.dim)
        if self.vec_scale is None:
            self.vectors[ids] = vals
        else:
            comp, scale = bs_compress_rows(vals)
            self.vectors[ids] = comp
            self.vec_scale[ids] = scale

    def payload_bytes(self) -> int:
        n = self.vectors.nbytes
        if self.vec_scale is not None:
            n += self.vec_scale.nbytes
        return int(n)

    # -- bulk API (LRUEmbeddingStore-compatible) ----------------------------

    def read_rows(self, ids) -> tuple[np.ndarray, np.ndarray]:
        """Batched fetch, initialising never-written rows from the seeded
        RNG (one ``standard_normal(dim)`` draw per fresh row, in request
        order — the LRU store's exact miss-path stream)."""
        ids = self._check_ids(ids)
        miss = ids[self.live[ids] == 0]
        if miss.size:
            _, first = np.unique(miss, return_index=True)
            for k in miss[np.sort(first)].tolist():
                self._set_rows(np.array([k]),
                               (self._rng.standard_normal(self.dim)
                                * self._init_scale)[None])
                self.opt_acc[k] = 0.0
            self._mark_live(miss)
        return (self._get_rows(ids),
                np.asarray(self.opt_acc[ids], np.float32))

    def write_rows(self, ids, vectors, opt_acc=None):
        ids = self._check_ids(ids)
        self._set_rows(ids, np.asarray(vectors, np.float32)
                       .reshape(len(ids), self.dim))
        if opt_acc is not None:
            self.opt_acc[ids] = np.asarray(opt_acc, np.float32).reshape(-1)
        self._mark_live(ids)

    def preload(self, ids, vectors, opt_acc=None):
        """Bulk-load an EMPTY store (the backend's init path)."""
        if self.size != 0:
            raise ValueError("preload requires an empty store")
        self.write_rows(ids, vectors, opt_acc)

    def disk_bytes(self) -> int:
        return int(self.payload_bytes() + self.opt_acc.nbytes
                   + self.live.nbytes)

    # -- (de)serialisation --------------------------------------------------

    def serialize(self) -> dict[str, np.ndarray]:
        """``vectors`` is always decompressed fp32 (portable across
        store_dtypes); a blockscale16 store adds its raw payload so a
        matching-dtype restore is bit-exact (see LRUEmbeddingStore)."""
        keys = np.nonzero(np.asarray(self.live))[0].astype(np.int64)
        blob = {
            "keys": keys,
            "vectors": self._get_rows(keys),
            "opt_acc": np.asarray(self.opt_acc[keys], np.float32),
            "meta": np.array([self.capacity, self.dim, self.size],
                             np.int64),
            # second slot records the store_dtype (absent/0 = fp32)
            "store_cfg": np.array([self._init_scale,
                                   float(self.vec_scale is not None)],
                                  np.float64),
            "rng_state": rng_state_array(self._rng),
        }
        if self.vec_scale is not None:
            blob["vec16"] = np.asarray(self.vectors[keys])
            blob["vec16_scale"] = np.asarray(self.vec_scale[keys])
        return blob

    @classmethod
    def deserialize(cls, blob, path: str | None = None,
                    store_dtype: str | None = None
                    ) -> "MmapEmbeddingStore":
        rows, dim, _ = (int(x) for x in
                        np.asarray(blob["meta"]).reshape(-1)[:3])
        cfg = np.asarray(blob["store_cfg"], np.float64).reshape(-1)
        blob_bs = cfg.size > 1 and cfg[1] != 0.0
        target = store_dtype or ("blockscale16" if blob_bs else "fp32")
        store = cls(rows, dim, init_scale=float(cfg[0]), path=path,
                    store_dtype=target)
        set_rng_state(store._rng, blob["rng_state"])
        keys = np.asarray(blob["keys"], np.int64)
        if store.vec_scale is not None and blob_bs and "vec16" in blob:
            store.vectors[keys] = np.asarray(blob["vec16"])  # bit-exact
            store.vec_scale[keys] = np.asarray(blob["vec16_scale"])
            store.opt_acc[keys] = np.asarray(blob["opt_acc"], np.float32)
            store._mark_live(keys)
        else:
            store.write_rows(keys,
                             np.asarray(blob["vectors"], np.float32),
                             np.asarray(blob["opt_acc"], np.float32))
        return store


class TieredHostStore:
    """Host LRU tier (``host_rows``, evicting) over a disk tier holding
    all ``rows`` — the lower two levels of the three-tier hierarchy.

    Reads resolve hits from the host tier, promote misses disk -> host
    (which may demote the host tier's LRU tail back to disk via
    ``on_evict``), and always return the freshest copy. The backend's
    fault path and serve-path ``read_rows`` use this unchanged — they
    only ever see the LRU bulk API.
    """

    def __init__(self, rows: int, dim: int, host_rows: int,
                 seed: int = 0, init_scale: float = 0.02,
                 path: str | None = None, store_dtype: str = "fp32"):
        if host_rows < 1:
            raise ValueError(f"host_rows must be >= 1 (got {host_rows})")
        self.capacity = int(rows)            # logical rows (disk tier)
        self.dim = int(dim)
        self.store_dtype = store_dtype
        # the host tier genuinely evicts, so it MUST track recency —
        # unlike the backend's plain all-rows store, which never does
        self.host = LRUEmbeddingStore(min(int(host_rows), int(rows)), dim,
                                      seed=seed, init_scale=init_scale,
                                      track_recency=True,
                                      store_dtype=store_dtype)
        self.disk = MmapEmbeddingStore(rows, dim, seed=seed,
                                       init_scale=init_scale, path=path,
                                       store_dtype=store_dtype)
        self.host.on_evict = self._spill
        self.promotions = 0                  # rows moved disk -> host
        self.spills = 0                      # rows demoted host -> disk

    def _spill(self, key: int, vec: np.ndarray, acc: np.ndarray):
        self.disk.write_rows(np.array([key], np.int64),
                             vec[None, :], np.array([acc], np.float32))
        self.spills += 1

    @property
    def size(self) -> int:
        """Distinct live logical rows across both tiers."""
        keys = self.host.keys[: self.host.size]
        keys = keys[keys >= 0]
        extra = int(np.count_nonzero(
            np.asarray(self.disk.live)[keys] == 0))
        return self.disk.size + extra

    @property
    def evictions(self) -> int:
        return self.host.evictions

    def recency_ids(self) -> list[int]:
        """Host-tier ids most- to least-recently used."""
        return self.host.recency_ids()

    # -- bulk API ------------------------------------------------------------

    def read_rows(self, ids) -> tuple[np.ndarray, np.ndarray]:
        ids = np.asarray(ids, np.int64).reshape(-1)
        if ids.size and np.unique(ids).size > self.host.capacity:
            raise ValueError(
                f"batch of {np.unique(ids).size} unique rows exceeds the "
                f"host tier ({self.host.capacity} rows) — raise "
                "EmbeddingSpec.host_rows or shrink the batch")
        _, slots = self.host._resolve(ids)
        hit = slots >= 0
        out_v = np.empty((len(ids), self.dim), np.float32)
        out_a = np.empty(len(ids), np.float32)
        if hit.any():
            # read (and MRU-touch) hits BEFORE promoting misses, so a
            # promotion-driven eviction can never demote a row this very
            # batch still needs un-read
            out_v[hit], out_a[hit] = self.host.read_rows(ids[hit])
        missing = ids[~hit]
        if missing.size:
            _, first = np.unique(missing, return_index=True)
            m = missing[np.sort(first)]
            d_v, d_a = self.disk.read_rows(m)
            self.host.write_rows(m, d_v, d_a)     # promote; tail spills
            self.promotions += int(m.size)
            order = np.argsort(m, kind="stable")
            sel = order[np.searchsorted(m[order], missing)]
            out_v[~hit] = d_v[sel]
            out_a[~hit] = d_a[sel]
        return out_v, out_a

    def write_rows(self, ids, vectors, opt_acc=None):
        """Writes land in the host tier (the freshest copy); host-tier
        allocations spill the LRU tail to disk as needed."""
        self.host.write_rows(ids, vectors, opt_acc)

    def preload(self, ids, vectors, opt_acc=None):
        """Bulk-load an EMPTY hierarchy: everything lands on disk, the
        host tier starts cold and fills by promotion."""
        if self.host.size != 0 or self.disk.size != 0:
            raise ValueError("preload requires an empty store")
        self.disk.preload(ids, vectors, opt_acc)

    def host_bytes(self) -> int:
        h = self.host
        return int(h.payload_bytes() + h.opt_acc.nbytes + h.prev.nbytes
                   + h.next.nbytes + h.keys.nbytes)

    def payload_bytes(self) -> int:
        """Vector payload bytes across both resident tiers."""
        return int(self.host.payload_bytes() + self.disk.payload_bytes())

    def disk_bytes(self) -> int:
        return self.disk.disk_bytes()

    # -- (de)serialisation --------------------------------------------------

    def serialize(self) -> dict:
        """Three-tier checkpoint sub-blob. ``meta`` keeps the LRU store's
        ``[capacity(=rows), dim, ...]`` head so the backend's restore
        validation reads either format the same way; the ``disk`` key is
        what distinguishes a tiered blob from a plain two-tier one."""
        return {
            "meta": np.array([self.capacity, self.dim, 0, 0, self.size,
                              self.host.evictions], np.int64),
            "tier_meta": np.array([self.host.capacity, self.promotions,
                                   self.spills], np.int64),
            "host": self.host.serialize(),
            "disk": self.disk.serialize(),
        }

    @classmethod
    def deserialize(cls, blob, path: str | None = None,
                    store_dtype: str | None = None) -> "TieredHostStore":
        rows, dim = (int(x) for x in
                     np.asarray(blob["meta"]).reshape(-1)[:2])
        tm = [int(x) for x in np.asarray(blob["tier_meta"]).reshape(-1)]
        store = cls(rows, dim, host_rows=tm[0], path=path,
                    store_dtype=store_dtype or "fp32")
        store.host = LRUEmbeddingStore.deserialize(blob["host"],
                                                   store_dtype=store_dtype)
        store.host.on_evict = store._spill
        store.disk = MmapEmbeddingStore.deserialize(blob["disk"], path=path,
                                                    store_dtype=store_dtype)
        store.store_dtype = store.host.store_dtype
        store.promotions, store.spills = tm[1], tm[2]
        return store
