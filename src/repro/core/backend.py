"""Pluggable embedding storage backends — the memory hierarchy behind the PS.

Persia's 100T-parameter capacity claim (paper §4.2.2/§4.2.3) rests on the
embedding tier being *bigger than device memory*: PS nodes keep tables in
host RAM behind an LRU array-list cache and move rows over a compressed
wire. This module makes that a first-class storage choice: every table in an
:class:`~repro.core.collection.EmbeddingCollection` selects its backend via
``EmbeddingSpec.backend``:

* ``DenseBackend`` — the device-sharded PS of :mod:`repro.core.embedding_ps`
  re-housed behind the protocol, numerically unchanged.
* ``HostLRUBackend`` — the out-of-core tier: a device-resident hot-cache of
  ``spec.cache_rows`` slots backed by a host :class:`LRUEmbeddingStore`
  holding all ``spec.rows`` (vectors **and** adagrad accumulators, the
  paper's array-item layout). ``prepare`` faults missing rows host→device
  and writes evicted dirty rows back, so logical ``rows`` can exceed device
  memory.
* ``CompressedWireBackend`` — a decorator over either backend applying the
  paper's §4.2.3 wire compression: lossless unique-id dedup on puts plus
  lossy blockscale fp16 on get/put payloads, surfacing bytes-moved metrics.
* ``ShardedBackend`` — the sharded parameter-server router (paper §4.1:
  every embedding worker owns a hash partition of every table). Wraps
  ``spec.emb_shards`` independent per-shard backends (dense or host_lru)
  behind this same protocol: deterministic affine-hash ``id -> shard``
  routing, per-shard slot maps / LRU stores / staleness queues / locks, a
  thread-pool ``prepare`` that faults all shards **concurrently** (host
  fault-in latency drops near-linearly with shards on miss-heavy
  workloads), shard-tagged checkpoints that **reshard on restore** (save
  with N shards, restore with M — row-exact), and per-shard traffic/hit
  metrics plus a max/mean load-imbalance gauge. Composable under the
  compressed wire (wire outside, router inside).

All backends speak the worker-side batch-dedup protocol (core/dedup.py):
the trainer's prepare phase hands the traceable ops a per-batch
``DedupPlan`` (unique device ids + occurrence inverse) instead of raw id
arrays, so lookups gather one row per *unique* id and puts are
segment-summed to unique width before they reach the staleness queue —
queue memory, device puts and wire bytes all shrink by the batch's
duplication factor (``EmbeddingSpec.batch_dedup=False`` restores the
occurrence-width PR-4 path).

The protocol splits host-level from traceable ops:

  host-level (never traced; may mutate backend-owned host state):
    ``init / prepare / queue_init / state_for_checkpoint /
    restore_from_checkpoint``
  traceable (pure, jit-safe, operate on *device ids* — raw ids for dense,
  cache-slot indices for host_lru — produced by ``prepare``):
    ``lookup / apply_put / hybrid_update``

``lookup`` returns ``(acts, metrics)`` and the put ops return their updated
state plus a metrics dict (empty except for the compressed wire), so wire
traffic flows out through the trainer's per-step metrics.
"""
from __future__ import annotations

import dataclasses
import math
import os
import threading
from concurrent.futures import ThreadPoolExecutor

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compression as C
from repro.core import dedup as D
from repro.core import embedding_ps as PS
from repro.core.dedup import DedupPlan
from repro.core.embedding_ps import EmbeddingSpec
from repro.core.hotness import HotnessSketch
from repro.core.lru import LRUEmbeddingStore, STORE_DTYPES
from repro.core.mmap_store import TieredHostStore
from repro.utils import round_up


def _prod(shape) -> int:
    return math.prod(int(s) for s in shape)


# dedup capacity + jit-shape bucketing both live in core/dedup.py now —
# one shared rule for the PS apply, the queue sizing, the wire and the
# fault path (a drifted mirror would make one layer drop rows another
# layer still ships)
_pow2_bucket = D.pow2_bucket


# the fault path's device ops, fused and jitted (cached per bucket shape):
# one dispatch per table instead of one per array keeps the host prepare
# phase off the dispatch-overhead treadmill

@jax.jit
def _fault_apply(table, slot_ids, vslots, vecs, ids):
    return (table.at[vslots].set(vecs.astype(table.dtype)),
            slot_ids.at[vslots].set(ids))


@jax.jit
def _fault_apply_acc(table, slot_ids, acc, vslots, vecs, ids, accs):
    return (table.at[vslots].set(vecs.astype(table.dtype)),
            slot_ids.at[vslots].set(ids),
            acc.at[vslots].set(accs))


@jax.jit
def _gather_rows(table, eslots):
    return table[eslots].astype(jnp.float32)


@jax.jit
def _gather_rows_acc(table, acc, eslots):
    return (table[eslots].astype(jnp.float32),
            acc[eslots].astype(jnp.float32))


class EmbeddingBackend:
    """Protocol base. Subclasses own one table's storage (device arrays are
    threaded through as pytrees; anything host-resident lives on ``self``).
    ``requires_prepare`` tells the trainer whether ``prepare`` does real work
    (host fault-in) and therefore must run outside jit every step.

    The traceable ops accept device ids in two forms: a raw id array (the
    pre-dedup occurrence-width path, one row per occurrence) or a
    :class:`~repro.core.dedup.DedupPlan` (the worker-side batch-dedup path:
    ``dev`` unique device ids + ``inv`` occurrence->unique inverse). The
    base class dispatches on the form; subclasses implement the ``_flat``
    (occurrence) and ``_unique`` (plan) variants. With a plan, ``lookup``
    gathers unique rows and scatters through the inverse, and the puts
    segment-sum occurrence grads to unique width ONCE at the outermost
    layer — everything downstream (queues, wire, optimizer apply) runs at
    unique width."""

    spec: EmbeddingSpec
    requires_prepare: bool = False
    # set by restore_from_checkpoint when the restored blob had a different
    # shard geometry than this backend (caches flushed, queues invalidated)
    last_restore_resharded: bool = False

    # -- host-level ----------------------------------------------------------
    def init(self, key, shards: int = 1, scale: float = 0.02):
        raise NotImplementedError

    def prepare(self, state, ids, assume_unique: bool = False, counts=None):
        """(state, ids) -> (state, device_ids). Host-level, once per step.
        ``assume_unique`` marks ids as an already-deduped set (a plan's
        unique ids — backends skip their own np.unique); ``counts`` carries
        the per-unique occurrence counts for traffic accounting."""
        return state, ids

    def prepare_submit(self, state, ids, assume_unique: bool = False,
                       counts=None):
        """Two-phase prepare: submit now, collect later. Returns a thunk
        producing ``(state, device_ids)``. The split exists so a caller
        preparing several tables can submit them all before collecting any
        — remote backends buffer the submit into one coalesced RPC frame
        per endpoint and only the collect waits. The in-process default
        just defers the blocking :meth:`prepare`."""
        return lambda: self.prepare(state, ids, assume_unique, counts)

    def read_rows(self, state, ids):
        """Serve-path read: LOGICAL ids -> ``(rows, info)`` where ``rows``
        is fp32 of shape ``ids.shape + (dim,)`` and ``info`` carries the
        read gauges ``reads`` (unique ids resolved), ``hits`` (served from
        device-resident rows) and ``misses`` (served from the host tier).

        Unlike ``prepare`` + ``lookup`` this is **read-only**: no row is
        faulted into the device cache, no slot is evicted, no host
        bookkeeping changes — so a serving thread can call it concurrently
        with a trainer stepping on the same backend. Host-cached
        implementations resolve residency against the *caller's* state
        snapshot (whose table and slot map can never desync), take the
        backend lock for the host-tier reads, and pin the slots they
        gather from so a concurrent fault-in never recycles a row
        mid-inference. Invalid ids (< 0 or >= rows) read as zero rows.

        The device-resident default gathers through the backend's own
        lookup (every read is a hit)."""
        if self.requires_prepare:
            raise NotImplementedError
        arr = np.asarray(ids, np.int64)
        acts, _ = self._lookup_flat(state, jnp.asarray(arr, jnp.int32))
        flat = arr.reshape(-1)
        n = int(np.unique(flat[(flat >= 0) & (flat < self.spec.rows)]).size)
        rows = np.asarray(acts.astype(jnp.float32)).reshape(
            arr.shape + (self.spec.dim,))
        return rows, {"reads": n, "hits": n, "misses": 0}

    # -- worker-side dedup sizing --------------------------------------------

    def dedup_rows(self) -> int:
        """Upper bound on distinct device ids one batch can produce — the
        denominator of the dedup capacity rule for this backend."""
        return self.spec.rows

    def queue_width(self, n_occ: int) -> int:
        """Width of this table's staleness-queue slots for a batch of
        ``n_occ`` id occurrences: the dedup cap under batch dedup, the raw
        occurrence count on the legacy path."""
        if self.spec.batch_dedup:
            return D.dedup_cap(n_occ, self.dedup_rows())
        return int(n_occ)

    # slot pinning: a pipelined caller pins a batch's device slots between
    # its prepare and its applied put, so a later batch's fault-in cannot
    # recycle rows still in flight. No-ops for device-resident backends
    # (device ids ARE logical ids — nothing is ever recycled).
    def pin_slots(self, dev_ids):
        pass

    def unpin_slots(self, dev_ids):
        pass

    def reset_pins(self):
        pass

    # -- shard introspection (pipelined callers, metrics) --------------------
    # Unsharded backends are one PS "shard": all puts land on shard 0.
    # ShardedBackend overrides both so the pipeline can run per-shard
    # put backpressure and the trainer can surface per-shard metrics.
    def n_put_shards(self) -> int:
        return 1

    def put_shards(self, dev_ids) -> tuple[int, ...]:
        return (0,)

    def shard_metrics(self) -> dict:
        return {}

    def cache_metrics(self) -> dict:
        """Per-step cache-admission gauges (keys are relative: the prepare
        driver prefixes ``cache/<table>/``). Empty for backends without an
        admission policy."""
        return {}

    def queue_init(self, ids_shape):
        raise NotImplementedError

    def state_for_checkpoint(self, state):
        raise NotImplementedError

    def restore_from_checkpoint(self, blob):
        raise NotImplementedError

    # -- traceable -----------------------------------------------------------
    #
    # Public ops dispatch on the dev_ids form (raw array vs DedupPlan);
    # subclasses implement the _flat/_unique variants. The plan path
    # segment-sums occurrence grads to unique width here, exactly once.

    def lookup(self, state, dev_ids):
        if D.is_plan(dev_ids):
            acts_u, m = self._lookup_unique(state, dev_ids.dev)
            return D.plan_scatter(acts_u, dev_ids.inv), m
        return self._lookup_flat(state, dev_ids)

    def apply_put(self, state, dev_ids, grads):
        if D.is_plan(dev_ids):
            return self._put_plan(state, dev_ids, grads)
        return self._put_flat(state, dev_ids, grads)

    def hybrid_update(self, state, queue, dev_ids, grads):
        if D.is_plan(dev_ids):
            return self._hybrid_plan(state, queue, dev_ids, grads)
        return self._hybrid_flat(state, queue, dev_ids, grads)

    def _put_plan(self, state, plan, grads):
        """Plan-driven put. Default: decompose into the plan's segment-sum
        then the unique-width put. Dense/HostLRU override with the fused
        backward (segment-sum + optimizer apply + queue payload in one
        pass, kernels/fused_backward.py); the shard router keeps the
        decomposition (one segment-sum reused across every shard) and the
        compressed wire bypasses this dispatch entirely."""
        g_u = D.plan_segment_sum(plan.inv, grads, int(plan.dev.shape[0]))
        return self._put_unique(state, plan.dev, g_u)

    def _hybrid_plan(self, state, queue, plan, grads):
        g_u = D.plan_segment_sum(plan.inv, grads, int(plan.dev.shape[0]))
        return self._hybrid_unique(state, queue, plan.dev, g_u)

    def _lookup_flat(self, state, dev_ids):
        raise NotImplementedError

    def _lookup_unique(self, state, dev_u):
        """(U,) unique device ids -> ((U, dim) rows, metrics). Default:
        the flat lookup already handles any id shape."""
        return self._lookup_flat(state, dev_u)

    def _put_flat(self, state, dev_ids, grads):
        raise NotImplementedError

    def _put_unique(self, state, dev_u, g_u):
        """Pre-deduped put: (U,) unique device ids + (U, dim) fp32 summed
        grads — no on-device sort/dedup needed."""
        raise NotImplementedError

    def _hybrid_flat(self, state, queue, dev_ids, grads):
        raise NotImplementedError

    def _hybrid_unique(self, state, queue, dev_u, g_u):
        raise NotImplementedError

    # -- capacity accounting (benchmarks) ------------------------------------
    def device_bytes(self, state) -> int:
        return sum(int(x.size) * x.dtype.itemsize
                   for x in jax.tree.leaves(state))

    def host_bytes(self) -> int:
        return 0


def _fused_backward(spec, state, inv, grads, apply_idx, apply_g, *,
                    apply_self=False):
    """One-pass plan-driven put: segment-sum the occurrence grads via the
    dedup-plan inverse, apply the optimizer row-wise at ``apply_idx``
    (-1 = no-op), return ``(new_state, g_push)`` with ``g_push`` the
    queue-ready unique-width payload.

    ``spec.backward_kernel`` selects the Pallas kernel (adagrad only — the
    accumulator update is built into the pass); the default jnp oracle is
    bit-identical to ``plan_segment_sum`` + ``PS._apply_sparse``, so
    flipping the flag off is a no-op numerically.
    """
    if apply_g is None:
        apply_g = jnp.zeros((int(apply_idx.shape[0]), spec.dim),
                            jnp.float32)
    acc = state.get("acc") if spec.optimizer == "adagrad" else None
    if spec.backward_kernel and acc is not None:
        from repro.kernels import ops as K
        table, acc, g_push = K.fused_backward(
            state["table"], acc, inv, grads, apply_idx, apply_g,
            lr=spec.lr, eps=spec.eps, apply_self=apply_self)
    else:
        from repro.kernels import ref as KR
        table, acc, g_push = KR.fused_backward_ref(
            state["table"], acc, inv, grads, apply_idx, apply_g,
            cap=int(apply_idx.shape[0]), lr=spec.lr, eps=spec.eps,
            apply_self=apply_self)
    new = dict(state)
    new["table"] = table
    if acc is not None:
        new["acc"] = acc
    return new, g_push


# ===========================================================================
# DenseBackend — today's device-sharded PS behind the protocol
# ===========================================================================

class DenseBackend(EmbeddingBackend):
    """Device-resident PS shard; every op delegates to embedding_ps with no
    numerical change (device ids ARE the logical ids)."""

    requires_prepare = False

    def __init__(self, spec: EmbeddingSpec):
        if spec.store_dtype != "fp32":
            raise ValueError(
                f"store_dtype={spec.store_dtype!r} compresses cold HOST "
                "rows — the dense backend is fully device-resident; use a "
                "host_lru backend (or drop store_dtype)")
        self.spec = spec

    def init(self, key, shards: int = 1, scale: float = 0.02):
        return PS.ps_init(key, self.spec, shards, scale)

    def queue_init(self, ids_shape):
        if self.spec.staleness <= 0:
            return None
        return self._queue_init_width(self.queue_width(_prod(ids_shape)))

    def _queue_init_width(self, width: int):
        return PS.queue_init(self.spec, (int(width),), self.spec.dim)

    def _lookup_flat(self, state, dev_ids):
        return PS.lookup(state, self.spec, dev_ids), {}

    def _put_flat(self, state, dev_ids, grads):
        return PS.apply_put(state, self.spec, dev_ids.reshape(-1),
                            grads.reshape(-1, self.spec.dim)), {}

    def _put_unique(self, state, dev_u, g_u):
        return PS.apply_put(state, self.spec, dev_u, g_u,
                            assume_unique=True), {}

    def _logical_to_pos(self, ids):
        """Logical id (-1 = no-op) -> physical shuffled row, -1 preserved —
        the assume_unique translation inside PS.apply_put, hoisted so the
        fused pass can scatter rows directly."""
        spec = self.spec
        valid = (ids >= 0) & (ids < spec.rows)
        pos = PS.shuffle_pos(jnp.where(valid, ids, 0), spec.padded_rows(1))
        return jnp.where(valid, pos.astype(jnp.int32), -1)

    def _fusable(self) -> bool:
        # the fused pass is the single-PS-shard sparse apply; mesh-sharded
        # tables keep the decomposed shard_map path
        return PS._n_shards(PS._axes_for(self.spec.mode)[0]) == 1

    def _put_plan(self, state, plan, grads):
        if not self._fusable():
            return super()._put_plan(state, plan, grads)
        new, _ = _fused_backward(self.spec, state, plan.inv, grads,
                                 self._logical_to_pos(plan.dev), None,
                                 apply_self=True)
        return new, {}

    def _hybrid_plan(self, state, queue, plan, grads):
        spec = self.spec
        if spec.staleness <= 0 or queue is None:
            st, m = self._put_plan(state, plan, grads)
            return st, queue, m
        if not self._fusable():
            return super()._hybrid_plan(state, queue, plan, grads)
        # pop the tau-stale put first (it reads the pre-push queue), fuse
        # its apply with this step's segment-sum, then push the fresh
        # payload into the popped slot — the queue_push_pop ordering
        cap = int(queue["ids"].shape[1])
        ptr = queue["ptr"]
        old_ids = jnp.take(queue["ids"], ptr, axis=0)
        old_g = jnp.take(queue["grads"], ptr, axis=0)
        new, g_push = _fused_backward(spec, state, plan.inv, grads,
                                      self._logical_to_pos(old_ids), old_g)
        tau = queue["ids"].shape[0]
        new_q = {
            "ids": jax.lax.dynamic_update_index_in_dim(
                queue["ids"],
                D.pad_axis0(plan.dev.astype(jnp.int32), cap, -1), ptr, 0),
            "grads": jax.lax.dynamic_update_index_in_dim(
                queue["grads"], g_push.astype(queue["grads"].dtype),
                ptr, 0),
            "ptr": (ptr + 1) % tau,
            "filled": jnp.minimum(queue["filled"] + 1, tau),
        }
        return new, new_q, {}

    def _hybrid_flat(self, state, queue, dev_ids, grads):
        spec = self.spec
        flat = dev_ids.reshape(-1)
        g = grads.reshape(-1, spec.dim)
        if spec.staleness <= 0 or queue is None or not spec.batch_dedup:
            # legacy path: occurrence-width queue, dedup at apply time
            st, q = PS.hybrid_emb_update(state, queue, spec, flat, g)
            return st, q, {}
        # unique-width queue: the occurrence put must dedup BEFORE the push
        # (same summed rows the post-queue dedup would produce, so mixing
        # this path with plan-driven steps keeps the queue invariant: every
        # queued put is one row per unique id)
        valid = (flat >= 0) & (flat < spec.rows)
        ids_signed = jnp.where(valid, flat.astype(jnp.int32), -1)
        gm = jnp.where(valid[:, None], g, 0.0).astype(jnp.float32)
        uniq, g_u = C.dedup_put(ids_signed, gm, int(queue["ids"].shape[1]))
        return self._hybrid_unique(state, queue, uniq, g_u)

    def _hybrid_unique(self, state, queue, dev_u, g_u):
        spec = self.spec
        if spec.staleness <= 0 or queue is None:
            st, m = self._put_unique(state, dev_u, g_u)
            return st, queue, m
        cap = int(queue["ids"].shape[1])
        ids_cap = D.pad_axis0(dev_u.astype(jnp.int32), cap, -1)
        g_cap = D.pad_axis0(g_u, cap, 0)
        queue, old_ids, old_g = PS.queue_push_pop(queue, ids_cap, g_cap)
        st = PS.apply_put(state, spec, old_ids, old_g, assume_unique=True)
        return st, queue, {}

    def state_for_checkpoint(self, state):
        return jax.tree.map(np.asarray, state)

    def restore_from_checkpoint(self, blob):
        spec = self.spec
        self.last_restore_resharded = False
        if isinstance(blob, dict) and "shard_meta" in blob:
            # a sharded-router checkpoint restored into a single-shard
            # trainer: gather the logical rows and rebuild (N -> 1 reshard)
            vec, acc = extract_logical_rows(blob, spec, "dense")
            self.last_restore_resharded = True
            return _dense_state_from_logical(spec, spec.rows, vec, acc)
        table = blob.get("table") if isinstance(blob, dict) else None
        if table is None:
            raise ValueError(
                "checkpoint blob has no 'table' — it was not written by the "
                "dense backend (restoring across backends is not supported)")
        if table.shape[1] != spec.dim or table.shape[0] < spec.rows:
            raise ValueError(
                f"checkpoint table has shape {tuple(table.shape)} but this "
                f"table's spec wants >= ({spec.rows}, {spec.dim}) — "
                "collection changed since the save?")
        return blob


# ===========================================================================
# HostLRUBackend — the out-of-core tier (paper §4.2.2)
# ===========================================================================

class HostLRUBackend(EmbeddingBackend):
    """Device hot-cache of ``spec.cache_rows`` slots over a host
    :class:`LRUEmbeddingStore` holding all ``spec.rows``.

    ``prepare`` is the fault path: it resolves the batch's unique ids
    against the slot map, writes the LRU victims' (vector, acc) back to the
    host store, loads the missing rows device-side, and returns the batch
    translated to cache-slot indices. The traceable ops then run entirely on
    the device cache — lookups gather slots, puts apply the PS-side
    optimizer to slots via the same dedup + row-sparse apply as the dense
    backend, so a working set that fits in cache is bit-exact with dense.

    Staleness queues store ``(slot, logical id)`` pairs; a popped put whose
    slot has been recycled for another id since it was enqueued is dropped
    (the paper's tolerated lost put). Note this includes recycling caused by
    *read-path* fault-ins: an eval/lookup batch near the cache's capacity
    can evict a slot with a put still pending in the queue — unlike the
    dense backend, eval is then not perfectly side-effect-free. Alg.1's
    lock-free semantics tolerate the loss; size ``cache_rows`` above the
    combined train+eval working set where that matters.

    The host tier (slot map, clock, LRU store) is guarded by an RLock:
    ``prepare`` may be called from a pipeline's prepare-stage thread while
    another thread (eval, checkpointing) touches the same backend, and the
    slot bookkeeping must stay a bijection under that interleaving. Callers
    are still responsible for sequencing the *device-array* state they
    thread through prepare/put (the pipeline's table-store lock does this).
    """

    requires_prepare = True

    def __init__(self, spec: EmbeddingSpec):
        if spec.cache_rows <= 0:
            raise ValueError(
                "host_lru backend needs EmbeddingSpec.cache_rows > 0 "
                f"(got {spec.cache_rows})")
        if spec.optimizer not in ("adagrad", "sgd"):
            raise ValueError(spec.optimizer)
        if spec.store_dtype not in STORE_DTYPES:
            raise ValueError(
                f"unknown store_dtype {spec.store_dtype!r}: one of "
                f"{STORE_DTYPES}")
        self.spec = spec
        self.cache_rows = int(spec.cache_rows)
        # three-tier variant: the host store becomes a TieredHostStore
        # (host LRU over mmap disk) instead of an all-rows LRU store
        self._disk = "disk" in (spec.backend or "").split("+")
        # frequency-aware admission (MixCache-style): a decayed count-min
        # sketch scores each unique id; ids below admit_threshold are
        # served from BYPASS slots — a small scratch region appended after
        # the main cache — so a once-seen cold id never evicts a hot
        # resident. admit_threshold <= 0 disables the sketch entirely and
        # keeps the pre-admission behaviour bit-identical.
        self.admit_threshold = float(spec.admit_threshold)
        if self.admit_threshold > 0:
            self.bypass_rows = (int(spec.bypass_rows)
                                or max(1, self.cache_rows // 4))
            self._sketch: HotnessSketch | None = HotnessSketch()
        else:
            self.bypass_rows = 0
            self._sketch = None
        self.dev_slots = self.cache_rows + self.bypass_rows
        self.store: LRUEmbeddingStore | TieredHostStore | None = None
        self._lock = threading.RLock()
        self._slot_for_id: dict[int, int] = {}
        # vectorized mirror of _slot_for_id (id -> cache slot, -1 = absent):
        # the per-step id->slot translation is a numpy gather instead of a
        # per-id dict sweep — the dict stays authoritative for the sparse
        # mutations (fault-in adds, eviction deletes) and introspection
        self._slot_arr = np.full(spec.rows, -1, np.int32)
        self._id_for_slot = np.full(self.dev_slots, -1, np.int64)
        self._slot_clock = np.zeros(self.dev_slots, np.int64)
        self._pin_count = np.zeros(self.dev_slots, np.int32)
        self._tick = 0
        self.faults = 0          # rows moved host -> device
        self.writebacks = 0      # rows moved device -> host
        self.hits = 0            # unique ids resolved without a fault
        self.admits = 0          # faults granted a main-cache slot
        self.bypasses = 0        # faults served from the bypass region
        self.promotes = 0        # bypass rows re-admitted once hot
        self.last_admit = 0      # per-step versions of the three above
        self.last_bypass = 0
        self.last_promote = 0

    # -- host-level ----------------------------------------------------------

    def init(self, key, shards: int = 1, scale: float = 0.02):
        if shards != 1:
            raise ValueError(
                "HostLRUBackend is one PS shard; to run a host-backed table "
                f"over {shards} shards set EmbeddingSpec.emb_shards (or pass "
                "emb_shards to PersiaTrainer.init), which routes through the "
                "ShardedBackend router")
        with self._lock:
            return self._init_locked(key, scale)

    def _init_locked(self, key, scale: float):
        spec = self.spec
        # draw the SAME init values the dense backend would, then park them
        # host-side: host row for id i is what a dense lookup of i would
        # read (table[shuffle_pos(i)]) — this is what makes dense and
        # host_lru bit-exact when the working set fits in cache. The draw is
        # pinned to the CPU backend: threefry is backend-deterministic, and
        # a rows x dim table is exactly what must NOT touch device memory
        with jax.default_device(jax.devices("cpu")[0]):
            dense = PS.ps_init(key,
                               dataclasses.replace(spec, backend="dense"),
                               1, scale)
            table = np.asarray(dense["table"], np.float32)
        pos = np.asarray(PS.shuffle_pos(jnp.arange(spec.rows),
                                        spec.padded_rows(1)))
        return self._init_with_rows_locked(np.arange(spec.rows), table[pos])

    def _init_with_rows(self, ids, vecs, accs=None):
        """Fresh run seeded with explicit host rows (the sharded router's
        init/reshard path): ids land in the host store, the device cache
        starts empty, all slot bookkeeping is reset."""
        with self._lock:
            return self._init_with_rows_locked(ids, vecs, accs)

    def _make_store(self):
        """Build the host tier: a plain all-rows LRU store (never evicts —
        skip per-access recency upkeep on the fault path), or, under
        ``+disk``, the tiered host-over-mmap hierarchy whose host tier
        genuinely evicts (spilling to disk)."""
        spec = self.spec
        if self._disk:
            host_rows = int(spec.host_rows) or max(1024, spec.rows // 4)
            return TieredHostStore(spec.rows, spec.dim,
                                   host_rows=host_rows,
                                   path=spec.disk_path,
                                   store_dtype=spec.store_dtype)
        return LRUEmbeddingStore(spec.rows, spec.dim, track_recency=False,
                                 store_dtype=spec.store_dtype)

    def _init_with_rows_locked(self, ids, vecs, accs=None):
        spec = self.spec
        self.store = self._make_store()
        self.store.preload(np.asarray(ids, np.int64),
                           np.asarray(vecs, np.float32), accs)
        # a (re-)init starts a fresh run: drop any previous slot bookkeeping
        self._slot_for_id = {}
        self._slot_arr = np.full(spec.rows, -1, np.int32)
        self._id_for_slot = np.full(self.dev_slots, -1, np.int64)
        self._slot_clock = np.zeros(self.dev_slots, np.int64)
        self._pin_count = np.zeros(self.dev_slots, np.int32)
        self._tick = 0
        self.faults = self.writebacks = self.hits = 0
        self.admits = self.bypasses = self.promotes = 0
        self.last_admit = self.last_bypass = self.last_promote = 0
        if self._sketch is not None:
            self._sketch = HotnessSketch()
        state = {
            "table": jnp.zeros((self.dev_slots, spec.dim), spec.dtype),
            "slot_ids": jnp.full((self.dev_slots,), -1, jnp.int32),
        }
        if spec.optimizer == "adagrad":
            state["acc"] = jnp.zeros((self.dev_slots,), jnp.float32)
        return state

    def prepare(self, state, ids, assume_unique: bool = False, counts=None):
        """Fault the batch's rows into the device cache; translate ids to
        cache-slot indices (-1 for padding / out-of-range). Thread-safe:
        the whole fault-in (slot map + LRU store + clock) is one critical
        section, so concurrent callers see consistent slot bookkeeping.
        ``assume_unique=True`` (the batch-dedup plan path) skips the
        np.unique — the caller already deduped the batch."""
        with self._lock:
            return self._prepare_locked(state, ids, assume_unique, counts)

    def _split_admission(self, missing: np.ndarray,
                         hit_slots: np.ndarray) -> tuple[np.ndarray,
                                                         np.ndarray]:
        """Partition this step's missing ids into (admitted, bypassed) by
        sketch hotness. Bypassed faults are capped by the bypass slots
        actually free this step (unpinned and not holding a row the batch
        also hits) — the overflow is admitted, deterministically from the
        front of the bypass list, so a cold burst can still be served."""
        hot = self._sketch.estimate(missing) >= self.admit_threshold
        admit, bypass = missing[hot], missing[~hot]
        if bypass.size:
            avail = np.ones(self.dev_slots, bool)
            avail[: self.cache_rows] = False
            avail[self._pin_count > 0] = False
            avail[hit_slots] = False
            room = int(np.count_nonzero(avail))
            if bypass.size > room:
                admit = np.concatenate([admit, bypass[room:]])
                bypass = bypass[:room]
        return admit, bypass

    def _prepare_locked(self, state, ids, assume_unique: bool = False,
                        counts=None):
        spec = self.spec
        flat = np.asarray(ids, np.int64).reshape(-1)
        valid = (flat >= 0) & (flat < spec.rows)
        uniq = flat[valid] if assume_unique else np.unique(flat[valid])
        if uniq.size > self.cache_rows:
            raise ValueError(
                f"batch working set ({uniq.size} unique ids) exceeds the "
                f"device cache ({self.cache_rows} slots) — raise "
                "EmbeddingSpec.cache_rows or shrink the batch")
        self._tick += 1
        smap = self._slot_for_id
        if self._sketch is not None:
            c = None
            if counts is not None:
                c = np.asarray(counts, np.float64).reshape(-1)
                c = c[valid] if c.size == flat.size else None
            self._sketch.update(uniq, c)
        uslots = self._slot_arr[uniq].astype(np.int64)
        self.last_admit = self.last_bypass = self.last_promote = 0
        if self._sketch is not None:
            # promote bypass-resident rows that have become hot: write the
            # device copy (the freshest) back to the host store, free the
            # bypass slot, and let the normal fault path re-admit them into
            # the main cache this same step — pinned slots (in-flight
            # pipelined batches) wait for a later step
            in_byp = uslots >= self.cache_rows
            if in_byp.any():
                hot = self._sketch.estimate(uniq) >= self.admit_threshold
                safe = np.clip(uslots, 0, self.dev_slots - 1)
                promo = in_byp & hot & (self._pin_count[safe] == 0)
                if promo.any():
                    state = dict(state)
                    self._evict_slots(uslots[promo], state)
                    uslots[promo] = -1
                    self.last_promote = int(promo.sum())
                    self.promotes += self.last_promote
        hit_slots = uslots[uslots >= 0]
        missing = uniq[uslots < 0]
        self.hits += int(hit_slots.size)
        if missing.size:
            state = dict(state)
            if self._sketch is not None:
                admit, bypass = self._split_admission(missing, hit_slots)
                v_main = self._free_slots(hit_slots, admit.size, state,
                                          hi=self.cache_rows)
                v_byp = self._free_slots(hit_slots, bypass.size, state,
                                         lo=self.cache_rows)
                missing = np.concatenate([admit, bypass])
                victims = np.concatenate([v_main, v_byp])
                self.admits += int(admit.size)
                self.bypasses += int(bypass.size)
                self.last_admit = int(admit.size)
                self.last_bypass = int(bypass.size)
            else:
                victims = self._free_slots(hit_slots, missing.size, state)
                self.admits += int(missing.size)
                self.last_admit = int(missing.size)
            vecs, accs = self.store.read_rows(missing)
            self.faults += missing.size
            # bucket the scatter shape (see _pow2_bucket): pad slots index
            # one past the cache — an out-of-bounds scatter update, which
            # JAX drops — so padding never touches a real row
            m, bucket = missing.size, _pow2_bucket(missing.size)
            pad_slots = np.full(bucket, self.dev_slots, np.int64)
            pad_slots[:m] = victims
            pad_vecs = np.zeros((bucket, spec.dim), np.float32)
            pad_vecs[:m] = vecs
            pad_ids = np.full(bucket, -1, np.int64)
            pad_ids[:m] = missing
            vslots = jnp.asarray(pad_slots, jnp.int32)
            vecs_j = jnp.asarray(pad_vecs, jnp.float32)
            ids_j = jnp.asarray(pad_ids, jnp.int32)
            if "acc" in state:
                pad_accs = np.zeros(bucket, np.float32)
                pad_accs[:m] = accs
                state["table"], state["slot_ids"], state["acc"] = \
                    _fault_apply_acc(state["table"], state["slot_ids"],
                                     state["acc"], vslots, vecs_j, ids_j,
                                     jnp.asarray(pad_accs, jnp.float32))
            else:
                state["table"], state["slot_ids"] = _fault_apply(
                    state["table"], state["slot_ids"], vslots, vecs_j, ids_j)
            for k, s in zip(missing.tolist(), victims.tolist()):
                smap[k] = s
            self._slot_arr[missing] = victims
            self._id_for_slot[victims] = missing
            touched = np.concatenate([hit_slots, victims])
        else:
            touched = hit_slots
        self._slot_clock[touched] = self._tick
        dev = np.where(valid,
                       self._slot_arr[np.where(valid, flat, 0)].astype(
                           np.int64), -1)
        return state, jnp.asarray(dev.reshape(np.shape(ids)), jnp.int32)

    def _free_slots(self, protected: np.ndarray, need: int, state,
                    lo: int = 0, hi: int | None = None):
        """Pick ``need`` victim slots inside ``[lo, hi)`` (the full slot
        pool by default; the admission path carves it into the main cache
        ``[0, cache_rows)`` and the bypass region ``[cache_rows,
        dev_slots)``): empty slots first, then the least-recently-touched
        occupied slots outside the current batch (never a pinned slot —
        those hold rows of in-flight pipelined batches); evicted rows
        (vector + acc) are written back to the host store."""
        if hi is None:
            hi = self.dev_slots
        if need <= 0:
            return np.zeros(0, np.int64)
        in_region = np.zeros(self.dev_slots, bool)
        in_region[lo:hi] = True
        pinned = self._pin_count > 0
        free = np.nonzero((self._id_for_slot < 0) & ~pinned
                          & in_region)[0][:need]
        n_evict = need - free.size
        if n_evict <= 0:
            return free
        cand = in_region.copy()
        cand[self._id_for_slot < 0] = False
        cand[protected] = False
        cand[pinned] = False
        cand_slots = np.nonzero(cand)[0]
        if cand_slots.size < n_evict:
            raise ValueError(
                f"fault-in needs {n_evict} eviction victims but only "
                f"{cand_slots.size} unpinned slots are evictable: the "
                f"combined working set of in-flight pipelined batches "
                f"exceeds the device cache ({hi - lo} slots in "
                f"[{lo}, {hi}), {int(pinned.sum())} pinned) — lower "
                "max_inflight or raise EmbeddingSpec.cache_rows")
        order = np.argsort(self._slot_clock[cand_slots], kind="stable")
        evict = cand_slots[order[:n_evict]]
        self._evict_slots(evict, state)
        return np.concatenate([free, evict])

    def _evict_slots(self, evict: np.ndarray, state):
        """Write the given occupied slots' rows (vector + acc — the device
        copy is the freshest) back to the host store and clear their slot
        bookkeeping. Callers pick the victims; this does the writeback."""
        n_evict = int(evict.size)
        ev_ids = self._id_for_slot[evict]
        # bucketed gather (see _pow2_bucket); pad rows are sliced back off
        idx = np.zeros(_pow2_bucket(n_evict), np.int64)
        idx[:n_evict] = evict
        eslots = jnp.asarray(idx, jnp.int32)
        if "acc" in state:
            vecs_j, accs_j = _gather_rows_acc(state["table"], state["acc"],
                                              eslots)
            accs = np.asarray(accs_j)[:n_evict]
        else:
            vecs_j, accs = _gather_rows(state["table"], eslots), None
        vecs = np.asarray(vecs_j)[:n_evict]
        self.store.write_rows(ev_ids, vecs, accs)
        self.writebacks += n_evict
        for k in ev_ids.tolist():
            del self._slot_for_id[k]
        self._slot_arr[ev_ids] = -1
        self._id_for_slot[evict] = -1

    # -- slot pinning (pipelined callers) ------------------------------------
    #
    # Between a batch's prepare and its applied put, a deep pipeline must
    # keep that batch's cache slots resident: a later batch's fault-in that
    # recycled them would make the pending lookup read the WRONG row (not a
    # stale one) and silently drop the put. Pins are reference counts; a
    # fault-in that cannot find enough unpinned victims raises (the
    # combined in-flight working set must fit the cache).

    def pin_slots(self, dev_ids):
        slots = np.asarray(dev_ids, np.int64).reshape(-1)
        slots = slots[(slots >= 0) & (slots < self.dev_slots)]
        with self._lock:
            np.add.at(self._pin_count, slots, 1)

    def unpin_slots(self, dev_ids):
        slots = np.asarray(dev_ids, np.int64).reshape(-1)
        slots = slots[(slots >= 0) & (slots < self.dev_slots)]
        with self._lock:
            np.subtract.at(self._pin_count, slots, 1)
            np.maximum(self._pin_count, 0, out=self._pin_count)

    def reset_pins(self):
        with self._lock:
            self._pin_count[:] = 0

    # -- serve-path read (read-only, thread-safe) ----------------------------

    def read_rows(self, state, ids):
        """Read rows without faulting them in (see the base-class doc).

        Residency is resolved against the CALLER's state snapshot — its
        ``slot_ids`` array, not the backend's live slot map — so the gather
        and the residency decision come from the same immutable snapshot
        and a concurrent trainer fault-in/evict can never make this read
        return the wrong row. Misses are read straight from the host store
        (under the backend lock), quantized through the cache dtype so a
        served row is bit-identical whether it happens to be cached or
        not. Hit slots are pinned across the gather: on a server whose
        state IS mutated in place between ops (repro.net.ps_server), the
        pin keeps an interleaved fault-in from recycling the slot
        mid-read."""
        spec = self.spec
        arr = np.asarray(ids, np.int64)
        flat = arr.reshape(-1)
        valid = (flat >= 0) & (flat < spec.rows)
        uniq = np.unique(flat[valid])
        slot_of = np.asarray(state["slot_ids"], np.int64)   # slot -> id
        if uniq.size:
            order = np.argsort(slot_of, kind="stable")
            pos = np.clip(np.searchsorted(slot_of, uniq, sorter=order),
                          0, self.dev_slots - 1)
            cand = order[pos]
            hit = slot_of[cand] == uniq
        else:
            cand = np.zeros(0, np.int64)
            hit = np.zeros(0, bool)
        hit_slots = cand[hit]
        missing = uniq[~hit]
        with self._lock:
            if missing.size:
                m_vecs, _ = self.store.read_rows(missing)
                m_vecs = np.asarray(
                    jnp.asarray(m_vecs, jnp.float32).astype(spec.dtype)
                    .astype(jnp.float32))
            else:
                m_vecs = np.zeros((0, spec.dim), np.float32)
            np.add.at(self._pin_count, hit_slots, 1)
        try:
            if hit_slots.size:
                idx = np.zeros(_pow2_bucket(hit_slots.size), np.int64)
                idx[:hit_slots.size] = hit_slots
                h_vecs = np.asarray(_gather_rows(
                    state["table"],
                    jnp.asarray(idx, jnp.int32)))[:hit_slots.size]
            else:
                h_vecs = np.zeros((0, spec.dim), np.float32)
        finally:
            self.unpin_slots(hit_slots)
        rows_u = np.zeros((uniq.size, spec.dim), np.float32)
        rows_u[hit] = h_vecs
        rows_u[~hit] = m_vecs
        out = np.zeros((flat.size, spec.dim), np.float32)
        if uniq.size:
            out[valid] = rows_u[np.searchsorted(uniq, flat[valid])]
        return (out.reshape(arr.shape + (spec.dim,)),
                {"reads": int(uniq.size), "hits": int(hit_slots.size),
                 "misses": int(missing.size)})

    def dedup_rows(self) -> int:
        # a batch's unique set must fit the device cache (prepare raises
        # otherwise), so the cache bounds the distinct device ids too
        return min(self.spec.rows, self.cache_rows)

    def queue_init(self, ids_shape):
        if self.spec.staleness <= 0:
            return None
        return self._queue_init_width(self.queue_width(_prod(ids_shape)))

    def _queue_init_width(self, width: int):
        spec = self.spec
        tau, n_ids = spec.staleness, int(width)
        return {
            "slots": jnp.full((tau, n_ids), -1, jnp.int32),
            "ids": jnp.full((tau, n_ids), -1, jnp.int32),
            "grads": jnp.zeros((tau, n_ids, spec.dim), spec.dtype),
            "ptr": jnp.zeros((), jnp.int32),
            "filled": jnp.zeros((), jnp.int32),
        }

    # -- traceable -----------------------------------------------------------

    def _lookup_flat(self, state, dev_ids):
        shape = dev_ids.shape
        flat = dev_ids.reshape(-1)
        valid = (flat >= 0) & (flat < self.dev_slots)
        safe = jnp.clip(flat, 0, self.dev_slots - 1)
        out = state["table"][safe] * valid[:, None].astype(
            state["table"].dtype)
        return out.reshape(*shape, self.spec.dim), {}

    def _put_flat(self, state, dev_ids, grads):
        spec = self.spec
        flat = dev_ids.reshape(-1)
        grads = grads.reshape(-1, spec.dim)
        valid = (flat >= 0) & (flat < self.dev_slots)
        g = jnp.where(valid[:, None], grads, 0.0).astype(jnp.float32)
        slot_signed = jnp.where(valid, flat.astype(jnp.int32), -1)
        cap = D.dedup_cap(int(flat.shape[0]), self.dev_slots)
        uniq, g_u = C.dedup_put(slot_signed, g, cap)
        return self._put_unique(state, uniq, g_u)

    def _put_unique(self, state, slots_u, g_u):
        new = PS._apply_sparse(
            state, self.spec,
            jnp.where(slots_u >= 0, slots_u, self.dev_slots),
            g_u.astype(jnp.float32), self.dev_slots)
        return new, {}

    def _hybrid_flat(self, state, queue, dev_ids, grads):
        spec = self.spec
        flat = dev_ids.reshape(-1)
        g = grads.reshape(-1, spec.dim)
        if spec.staleness <= 0 or queue is None:
            st, m = self._put_flat(state, flat, g)
            return st, queue, m
        valid = (flat >= 0) & (flat < self.dev_slots)
        if not spec.batch_dedup:
            # legacy path: occurrence-width queue slots
            return self._hybrid_flat_legacy(state, queue, flat, g, valid)
        # unique-width queue: dedup by slot before the push
        gm = jnp.where(valid[:, None], g, 0.0).astype(jnp.float32)
        slot_signed = jnp.where(valid, flat.astype(jnp.int32), -1)
        slots_u, g_u = C.dedup_put(slot_signed, gm,
                                   int(queue["slots"].shape[1]))
        return self._hybrid_unique(state, queue, slots_u, g_u)

    def _hybrid_flat_legacy(self, state, queue, flat, g, valid):
        safe = jnp.clip(flat, 0, self.dev_slots - 1)
        logical = jnp.where(valid, state["slot_ids"][safe], -1)
        queue, old_slots, old_ids, old_g = self._queue_push_pop(
            queue, jnp.where(valid, flat.astype(jnp.int32), -1), logical, g)
        # a tau-stale put only lands if its slot still holds the same row
        old_safe = jnp.clip(old_slots, 0, self.dev_slots - 1)
        still = (old_slots >= 0) & (old_ids >= 0) & \
            (state["slot_ids"][old_safe] == old_ids)
        st, m = self._put_flat(state, jnp.where(still, old_slots, -1), old_g)
        return st, queue, m

    def _put_plan(self, state, plan, grads):
        # plan.dev already IS the (-1-signed) cache-slot vector: fuse the
        # segment-sum with the slot-sparse optimizer apply directly
        new, _ = _fused_backward(self.spec, state, plan.inv, grads,
                                 plan.dev.astype(jnp.int32), None,
                                 apply_self=True)
        return new, {}

    def _hybrid_plan(self, state, queue, plan, grads):
        spec = self.spec
        if spec.staleness <= 0 or queue is None:
            st, m = self._put_plan(state, plan, grads)
            return st, queue, m
        # pop the tau-stale (slot, id, grads) first, drop it if its slot
        # was recycled since the push, fuse its apply with this step's
        # segment-sum, then push the fresh payload at the popped position
        cap = int(queue["slots"].shape[1])
        slots_cap = D.pad_axis0(plan.dev.astype(jnp.int32), cap, -1)
        safe = jnp.clip(slots_cap, 0, self.dev_slots - 1)
        logical = jnp.where(slots_cap >= 0, state["slot_ids"][safe], -1)
        ptr = queue["ptr"]
        old_slots = jnp.take(queue["slots"], ptr, axis=0)
        old_ids = jnp.take(queue["ids"], ptr, axis=0)
        old_g = jnp.take(queue["grads"], ptr, axis=0)
        old_safe = jnp.clip(old_slots, 0, self.dev_slots - 1)
        still = (old_slots >= 0) & (old_ids >= 0) & \
            (state["slot_ids"][old_safe] == old_ids)
        new, g_push = _fused_backward(spec, state, plan.inv, grads,
                                      jnp.where(still, old_slots, -1),
                                      old_g)
        tau = queue["slots"].shape[0]
        new_q = {
            "slots": jax.lax.dynamic_update_index_in_dim(
                queue["slots"], slots_cap, ptr, 0),
            "ids": jax.lax.dynamic_update_index_in_dim(
                queue["ids"], logical.astype(jnp.int32), ptr, 0),
            "grads": jax.lax.dynamic_update_index_in_dim(
                queue["grads"], g_push.astype(queue["grads"].dtype),
                ptr, 0),
            "ptr": (ptr + 1) % tau,
            "filled": jnp.minimum(queue["filled"] + 1, tau),
        }
        return new, new_q, {}

    def _hybrid_unique(self, state, queue, slots_u, g_u):
        spec = self.spec
        if spec.staleness <= 0 or queue is None:
            st, m = self._put_unique(state, slots_u, g_u)
            return st, queue, m
        cap = int(queue["slots"].shape[1])
        slots_cap = D.pad_axis0(slots_u.astype(jnp.int32), cap, -1)
        g_cap = D.pad_axis0(g_u, cap, 0)
        safe = jnp.clip(slots_cap, 0, self.dev_slots - 1)
        logical = jnp.where(slots_cap >= 0, state["slot_ids"][safe], -1)
        queue, old_slots, old_ids, old_g = self._queue_push_pop(
            queue, slots_cap, logical, g_cap)
        old_safe = jnp.clip(old_slots, 0, self.dev_slots - 1)
        still = (old_slots >= 0) & (old_ids >= 0) & \
            (state["slot_ids"][old_safe] == old_ids)
        st, m = self._put_unique(state, jnp.where(still, old_slots, -1),
                                 old_g)
        return st, queue, m

    def _queue_push_pop(self, queue, slots, logical, g):
        """Push (slots, ids, grads); pop the tau-stale entry."""
        ptr = queue["ptr"]
        old_slots = jnp.take(queue["slots"], ptr, axis=0)
        old_ids = jnp.take(queue["ids"], ptr, axis=0)
        old_g = jnp.take(queue["grads"], ptr, axis=0)
        tau = queue["slots"].shape[0]
        new_q = {
            "slots": jax.lax.dynamic_update_index_in_dim(
                queue["slots"], slots, ptr, 0),
            "ids": jax.lax.dynamic_update_index_in_dim(
                queue["ids"], logical.astype(jnp.int32), ptr, 0),
            "grads": jax.lax.dynamic_update_index_in_dim(
                queue["grads"], g.astype(queue["grads"].dtype), ptr, 0),
            "ptr": (ptr + 1) % tau,
            "filled": jnp.minimum(queue["filled"] + 1, tau),
        }
        return new_q, old_slots, old_ids, old_g

    # -- checkpoint ----------------------------------------------------------

    def state_for_checkpoint(self, state):
        """Snapshot ALL tiers: the device cache (so queued slot references
        stay live across restore) and the host store — plain or tiered,
        with its recency order — plus the slot map and (when admission is
        on) the hotness sketch: a restore resumes bit-identically."""
        with self._lock:
            cm = {
                "id_for_slot": self._id_for_slot.copy(),
                "slot_clock": self._slot_clock.copy(),
                "scalars": np.array([self._tick, self.faults,
                                     self.writebacks, self.hits,
                                     self.admits, self.bypasses,
                                     self.promotes],
                                    np.int64),
            }
            if self._sketch is not None:
                cm["hotness"] = self._sketch.serialize()
            return {
                "cache": jax.tree.map(np.asarray, state),
                "store": self.store.serialize(),
                "cache_meta": cm,
            }

    def restore_from_checkpoint(self, blob):
        self.last_restore_resharded = False
        if isinstance(blob, dict) and "shard_meta" in blob:
            # sharded-router checkpoint into a single-shard trainer: gather
            # the logical rows (device caches overlaid on host stores) and
            # rebuild the two tiers (N -> 1 reshard; pending slot-addressed
            # puts are dropped — the paper's tolerated in-flight loss)
            vec, acc = extract_logical_rows(blob, self.spec, "host_lru")
            state = self._init_with_rows(np.arange(self.spec.rows), vec, acc)
            self.last_restore_resharded = True
            return state
        with self._lock:
            return self._restore_locked(blob)

    def _restore_locked(self, blob):
        spec = self.spec
        if not isinstance(blob, dict) or "store" not in blob \
                or "cache" not in blob:
            raise ValueError(
                "checkpoint blob has no host store — it was not written by "
                "the host_lru backend (restoring across backends is not "
                "supported)")
        meta = blob["store"]["meta"]
        cap, dim = int(meta[0]), int(meta[1])
        if cap != spec.rows or dim != spec.dim:
            raise ValueError(
                f"checkpoint host store is ({cap}, {dim}) but this table's "
                f"spec wants ({spec.rows}, {spec.dim}) — collection changed "
                "since the save?")
        cache_tbl = blob["cache"]["table"]
        if cache_tbl.shape[0] != self.dev_slots:
            raise ValueError(
                f"checkpoint device cache has {cache_tbl.shape[0]} slots but "
                f"this table runs {self.dev_slots} "
                f"(cache_rows={self.cache_rows} + "
                f"bypass_rows={self.bypass_rows}) — rebuild the trainer "
                "with the cache geometry the checkpoint was trained under")
        sblob = blob["store"]
        if ("disk" in sblob) == self._disk:
            # matching store format: bit-identical tier restore when the
            # blob's store_dtype matches the spec's; a dtype mismatch
            # re-encodes the blob's fp32 logical rows (both directions)
            if self._disk:
                self.store = TieredHostStore.deserialize(
                    sblob, path=spec.disk_path,
                    store_dtype=spec.store_dtype)
            else:
                self.store = LRUEmbeddingStore.deserialize(
                    sblob, store_dtype=spec.store_dtype)
                self.store.track_recency = False   # backend-owned: see init
        else:
            # cross-format restore (two-tier blob into a +disk backend, or
            # the reverse): rebuild the configured hierarchy from the
            # blob's logical rows — row-exact, tier residency starts fresh
            vec, acc = _store_logical_rows(sblob, spec.rows, spec.dim)
            self.store = self._make_store()
            self.store.preload(np.arange(spec.rows), vec, acc)
        cm = blob["cache_meta"]
        self._pin_count = np.zeros(self.dev_slots, np.int32)
        self._id_for_slot = np.asarray(cm["id_for_slot"], np.int64).copy()
        self._slot_clock = np.asarray(cm["slot_clock"], np.int64).copy()
        scalars = [int(x) for x in cm["scalars"]]
        self._tick, self.faults, self.writebacks = scalars[:3]
        # pre-shard-router checkpoints carry 3 scalars (no hit counter);
        # pre-admission ones carry 4 (no admit/bypass/promote counters)
        self.hits = scalars[3] if len(scalars) > 3 else 0
        self.admits = scalars[4] if len(scalars) > 4 else 0
        self.bypasses = scalars[5] if len(scalars) > 5 else 0
        self.promotes = scalars[6] if len(scalars) > 6 else 0
        self.last_admit = self.last_bypass = self.last_promote = 0
        if self._sketch is not None:
            self._sketch = (HotnessSketch.deserialize(cm["hotness"])
                            if "hotness" in cm else HotnessSketch())
        self._slot_for_id = {
            int(k): int(s)
            for s, k in enumerate(self._id_for_slot.tolist()) if k >= 0}
        self._slot_arr = np.full(spec.rows, -1, np.int32)
        live = np.nonzero(self._id_for_slot >= 0)[0]
        self._slot_arr[self._id_for_slot[live]] = live.astype(np.int32)
        return {k: jnp.asarray(v) for k, v in blob["cache"].items()}

    # -- capacity accounting / inspection ------------------------------------

    def host_bytes(self) -> int:
        s = self.store
        if s is None:
            return 0
        if hasattr(s, "host_bytes"):        # tiered: host-tier arrays only
            return s.host_bytes()
        return int(s.payload_bytes() + s.opt_acc.nbytes + s.prev.nbytes
                   + s.next.nbytes + s.keys.nbytes)

    def cache_metrics(self) -> dict:
        """Per-step admission gauges (empty when the sketch is off)."""
        if self._sketch is None:
            return {}
        return {"admit": float(self.last_admit),
                "bypass": float(self.last_bypass),
                "promote": float(self.last_promote)}

    def recency_order(self) -> list[int]:
        """Host-store ids most- to least-recently used (checkpointed)."""
        return self.store.recency_ids()


# ===========================================================================
# ShardedBackend — the sharded embedding parameter-server router (§4.1)
# ===========================================================================

# Knuth's multiplicative-hash constant (2^32 / phi, odd): the routing premix.
# Distinct from the in-shard placement shuffle so shard choice and row
# placement stay decorrelated.
_ROUTE_MULT = 2_654_435_761
_ROUTE_ADD = 97_531


class _ShardRouting:
    """Deterministic affine-hash ``id -> (shard, local id)`` routing.

    Ids are premixed by a bijective affine map over the padded domain
    ``P = round_up(rows, k)`` (the multiplier is adjusted odd-upwards until
    coprime with P, so the map is a bijection); then ``shard = premix % k``
    and ``local = premix // k``. Bijectivity keeps the per-shard local id
    spaces disjoint and exactly invertible, which is what makes checkpoint
    resharding (save with N shards, restore with M) row-exact.
    """

    def __init__(self, rows: int, k: int):
        self.rows, self.k = int(rows), int(k)
        P = round_up(max(self.rows, self.k), self.k)
        mult = _ROUTE_MULT
        while math.gcd(mult, P) != 1:
            mult += 2
        self.P, self.mult, self.add = P, mult, _ROUTE_ADD % P
        self.sub_rows = P // self.k          # per-shard local id space

    def shard_and_local(self, ids):
        ids = np.asarray(ids, np.int64)
        pre = (ids * self.mult + self.add) % self.P
        return pre % self.k, pre // self.k


def _dense_state_from_logical(spec: EmbeddingSpec, n_rows: int, vec, acc):
    """Build a dense PS state of ``n_rows`` storage rows holding logical row
    ``i`` of ``vec`` at its uniform-shuffle position (the inverse of
    reading a dense table back out row-by-row)."""
    pos = np.asarray(PS.shuffle_pos(jnp.arange(vec.shape[0]), n_rows))
    table = np.zeros((n_rows, vec.shape[1]), vec.dtype)
    table[pos] = vec
    state = {"table": jnp.asarray(table)}
    if spec.optimizer == "adagrad":
        a = np.zeros((n_rows,), np.float32)
        if acc is not None:
            a[pos] = np.asarray(acc, np.float32)
        state["acc"] = jnp.asarray(a)
    return state


def _store_logical_rows(sblob, rows: int, dim: int):
    """Host-store checkpoint sub-blob -> dense ``(vec, acc)`` over all
    ``rows`` logical rows (zeros for never-stored ids). Handles both the
    plain LRU blob and the tiered host+disk blob — for the latter the
    disk tier is laid down first, then the host tier overlaid on top (the
    host copy is the freshest: spills only happen on demotion)."""
    vec = np.zeros((rows, dim), np.float32)
    acc = np.zeros((rows,), np.float32)

    def overlay(b):
        meta = np.asarray(b["meta"], np.int64).reshape(-1)
        # plain LRU meta is [capacity, dim, head, tail, size, evictions];
        # the mmap tier's is just [capacity, dim, size]
        size = int(meta[4]) if meta.size > 4 else int(meta[2])
        keys = np.asarray(b["keys"], np.int64)[:size]
        vec[keys] = np.asarray(b["vectors"], np.float32)[:size]
        acc[keys] = np.asarray(b["opt_acc"], np.float32)[:size]

    if "disk" in sblob:
        overlay(sblob["disk"])
        overlay(sblob["host"])
    else:
        overlay(sblob)
    return vec, acc


def extract_logical_rows(blob, spec: EmbeddingSpec, base: str):
    """Checkpoint blob -> ``(vec, acc)`` in *logical row order*: ``vec[i]``
    is the value a lookup of id ``i`` would return (and ``acc[i]`` its
    optimizer accumulator, or None when the blob carries none).

    Handles all three blob geometries — plain dense (rows read back through
    the uniform shuffle), plain host_lru (host store rows overlaid with the
    device cache, whose copies are the freshest), and shard-tagged router
    blobs (each sub-blob extracted recursively and scattered back through
    the source routing). This is the reshard path: N-shard checkpoints
    restore row-exactly into M-shard trainers for any N, M.
    """
    if isinstance(blob, dict) and "shard_meta" in blob:
        meta = np.asarray(blob["shard_meta"], np.int64).reshape(-1)
        src_k, src_rows = int(meta[0]), int(meta[1])
        if src_rows != spec.rows:
            raise ValueError(
                f"sharded checkpoint holds {src_rows} logical rows but this "
                f"table's spec wants {spec.rows} — collection changed since "
                "the save?")
        routing = _ShardRouting(spec.rows, src_k)
        ids = np.arange(spec.rows)
        own, loc = routing.shard_and_local(ids)
        sub_spec = dataclasses.replace(spec, rows=routing.sub_rows,
                                       emb_shards=1)
        vec = acc = None
        for s in range(src_k):
            sub_blob = blob["shards"][f"s{s}"]
            v_s, a_s = extract_logical_rows(sub_blob, sub_spec, base)
            if vec is None:
                vec = np.zeros((spec.rows, spec.dim), v_s.dtype)
                acc = None if a_s is None \
                    else np.zeros((spec.rows,), np.float32)
            sel = own == s
            vec[sel] = v_s[loc[sel]]
            if acc is not None and a_s is not None:
                acc[sel] = a_s[loc[sel]]
        return vec, acc

    if base == "dense":
        table = blob.get("table") if isinstance(blob, dict) else None
        if table is None:
            raise ValueError(
                "checkpoint blob has no 'table' — it was not written by the "
                "dense backend (restoring across backends is not supported)")
        table = np.asarray(table)
        if table.shape[1] != spec.dim or table.shape[0] < spec.rows:
            raise ValueError(
                f"checkpoint table has shape {tuple(table.shape)} but this "
                f"table's spec wants >= ({spec.rows}, {spec.dim}) — "
                "collection changed since the save?")
        pos = np.asarray(PS.shuffle_pos(jnp.arange(spec.rows),
                                        table.shape[0]))
        acc = blob.get("acc")
        return table[pos], (None if acc is None
                            else np.asarray(acc, np.float32)[pos])

    if not isinstance(blob, dict) or "store" not in blob \
            or "cache" not in blob:
        raise ValueError(
            "checkpoint blob has no host store — it was not written by "
            "the host_lru backend (restoring across backends is not "
            "supported)")
    meta = blob["store"]["meta"]
    cap, dim = int(meta[0]), int(meta[1])
    if cap != spec.rows or dim != spec.dim:
        raise ValueError(
            f"checkpoint host store is ({cap}, {dim}) but this table's "
            f"spec wants ({spec.rows}, {spec.dim}) — collection changed "
            "since the save?")
    vec, acc = _store_logical_rows(blob["store"], spec.rows, spec.dim)
    # the device cache holds the freshest copy of every resident row
    # (write-back only happens on eviction): overlay it over the store,
    # exactly as draining the cache would
    id_for_slot = np.asarray(blob["cache_meta"]["id_for_slot"], np.int64)
    live = np.nonzero(id_for_slot >= 0)[0]
    if live.size:
        cached_ids = id_for_slot[live]
        vec[cached_ids] = np.asarray(blob["cache"]["table"],
                                     np.float32)[live]
        if "acc" in blob["cache"]:
            acc[cached_ids] = np.asarray(blob["cache"]["acc"],
                                         np.float32)[live]
    return vec, acc


class ShardedBackend(EmbeddingBackend):
    """Router over ``n_shards`` independent per-shard backends — the
    embedding-PS tier as a *set of shards* (paper §4.1: capacity and host
    bandwidth scale with the number of embedding workers).

    Each shard is a full Dense/HostLRU backend over its own local id space
    (disjoint by the bijective :class:`_ShardRouting`), with its own lock,
    slot map, LRU store and staleness queue. ``prepare`` fans the batch out
    to all shards through a thread pool, so host-side fault-in runs
    **concurrently** per shard — the per-shard locks replace the old single
    global lock, and miss-heavy prepare latency drops near-linearly with
    shards (``benchmarks/shard_scaling.py``).

    Device ids are shard-encoded: ``dev = shard * stride + local_dev`` with
    one uniform ``stride`` (per-shard cache slots for host_lru, per-shard
    rows for dense), so the traceable ops route by integer division with no
    host round-trip. State/queues are dicts keyed ``"s0".."s{k-1}"``.

    Checkpoints are shard-tagged (``shard_meta`` + per-shard two-tier
    blobs); restore into a different shard count reshards row-exactly via
    :func:`extract_logical_rows` (device caches restart cold and pending
    slot-addressed queue puts are dropped — the paper's tolerated in-flight
    loss, same policy as a worker failover).
    """

    requires_prepare = True
    # floor on the shard count: the in-process router insists on >= 2 (a
    # single shard IS the plain backend); subclasses whose shards live in
    # other processes (repro.net) allow 1 — one PS process is still remote
    min_shards = 2

    def __init__(self, spec: EmbeddingSpec, n_shards: int | None = None):
        base, _ = parse_backend_name(spec.backend)
        if base.startswith("host_lru") and spec.cache_rows <= 0:
            raise ValueError(
                "host_lru backend needs EmbeddingSpec.cache_rows > 0 "
                f"(got {spec.cache_rows})")
        self.spec = spec
        self._base = base
        self._lock = threading.Lock()        # traffic counters only
        self._pool: ThreadPoolExecutor | None = None
        self._configure(int(n_shards if n_shards is not None
                            else spec.emb_shards))

    def _make_sub(self, s: int, sub_spec: EmbeddingSpec) -> EmbeddingBackend:
        """Build shard ``s``'s backend — the hook the remote router
        (repro.net.remote.RemoteShardedBackend) overrides to place each
        shard behind an RPC endpoint instead of in-process."""
        return (HostLRUBackend(sub_spec)
                if self._base.startswith("host_lru")
                else DenseBackend(sub_spec))

    def _configure(self, k: int):
        if k < self.min_shards:
            raise ValueError(
                f"{type(self).__name__} needs >= {self.min_shards} shards "
                f"(got {k}); use the plain backend for a single shard")
        spec = self.spec
        self.n_shards = k
        self._routing = _ShardRouting(spec.rows, k)
        sub_rows = self._routing.sub_rows
        kw = {"backend": self._base, "emb_shards": 1, "rows": sub_rows}
        host = self._base.startswith("host_lru")
        if host:
            # cache_rows stays the table's TOTAL device-cache budget,
            # split evenly across shards — as do the bypass region, the
            # +disk host tier and (when set) the mmap directory
            kw["cache_rows"] = -(-spec.cache_rows // k)
            if spec.bypass_rows:
                kw["bypass_rows"] = -(-int(spec.bypass_rows) // k)
            if spec.host_rows:
                kw["host_rows"] = -(-int(spec.host_rows) // k)
        subs = []
        for s in range(k):
            kws = dict(kw)
            if host and spec.disk_path is not None:
                kws["disk_path"] = os.path.join(spec.disk_path, f"s{s}")
            subs.append(self._make_sub(s, dataclasses.replace(spec, **kws)))
        self.shard_backends = subs
        # device ids are shard-encoded dev = shard*stride + local: for
        # host_lru the local space is the shard's FULL slot pool
        # (cache + bypass), not just its main cache
        self.stride = (subs[0].dev_slots if host else sub_rows)
        self.dev_rows = k * self.stride      # encoded device id space
        self._traffic = np.zeros(k, np.int64)
        if self._pool is not None:
            self._pool.shutdown(wait=False)
            self._pool = None

    def _ensure_pool(self) -> ThreadPoolExecutor:
        if self._pool is None:
            self._pool = ThreadPoolExecutor(max_workers=self.n_shards,
                                            thread_name_prefix="emb-shard")
        return self._pool

    # -- host-level ----------------------------------------------------------

    def init(self, key, shards: int = 1, scale: float = 0.02):
        # shards=1 means "no override": the configured count stands (so
        # PersiaTrainer.init's default never downgrades a spec-sharded
        # table); any other count reconfigures the router before init
        if shards not in (1, self.n_shards):
            self._configure(int(shards))
        spec = self.spec
        ref_spec = dataclasses.replace(spec, backend="dense", emb_shards=1)
        if self._base == "dense":
            ref = PS.ps_init(key, ref_spec, 1, scale)
            table = np.asarray(ref["table"])
        else:
            # same CPU-pinned draw as the plain HostLRUBackend: the full
            # table must not touch device memory
            with jax.default_device(jax.devices("cpu")[0]):
                ref = PS.ps_init(key, ref_spec, 1, scale)
                table = np.asarray(ref["table"], np.float32)
        # logical row i = what a single-shard lookup of i would read; this
        # is what makes the k-shard router bit-exact with the plain backend
        pos = np.asarray(PS.shuffle_pos(jnp.arange(spec.rows),
                                        spec.padded_rows(1)))
        self._traffic = np.zeros(self.n_shards, np.int64)
        return self._sub_states_from_logical(table[pos], None)

    def _sub_states_from_logical(self, vec, acc):
        """Distribute logical rows (and optional accumulators) over the
        shards according to the routing — the shared init/reshard path."""
        r = self._routing
        ids = np.arange(self.spec.rows)
        own, loc = r.shard_and_local(ids)
        states = {}
        for s, sub in enumerate(self.shard_backends):
            sel = own == s
            gl, ll = ids[sel], loc[sel]
            if self._base.startswith("host_lru"):
                states[f"s{s}"] = sub._init_with_rows(
                    ll, np.asarray(vec[gl], np.float32),
                    None if acc is None else acc[gl])
            else:
                sub_vec = np.zeros((r.sub_rows, vec.shape[1]), vec.dtype)
                sub_vec[ll] = vec[gl]
                sub_acc = None
                if acc is not None:
                    sub_acc = np.zeros((r.sub_rows,), np.float32)
                    sub_acc[ll] = acc[gl]
                states[f"s{s}"] = _dense_state_from_logical(
                    sub.spec, r.sub_rows, sub_vec, sub_acc)
        return states

    def dedup_rows(self) -> int:
        return min(self.spec.rows, self.dev_rows)

    def prepare(self, state, ids, assume_unique: bool = False, counts=None):
        return self.prepare_submit(state, ids, assume_unique, counts)()

    def prepare_submit(self, state, ids, assume_unique: bool = False,
                       counts=None):
        """Concurrent per-shard fault-in, two-phase: the batch is split by
        the routing and every shard's prepare is *submitted* (remote
        shards buffer one coalesced RPC into their endpoint's frame;
        in-process shards defer the work); the returned thunk runs the
        per-shard collects on the router's thread pool — each under its
        own shard lock, so host fault-in latency scales down with the
        shard count instead of serializing behind one global lock, and
        shard RPCs wait concurrently. Returns shard-encoded device ids.

        On the batch-dedup path ``ids`` is the plan's unique set (routed
        subsets stay unique, so shards skip their own np.unique) and
        ``counts`` carries per-unique occurrence counts — the traffic /
        imbalance gauges keep measuring the raw id stream, not the
        deduped wire, so hot-key skew stays visible."""
        spec = self.spec
        shape = np.shape(ids)
        flat = np.asarray(ids, np.int64).reshape(-1)
        valid = (flat >= 0) & (flat < spec.rows)
        own_raw, loc = self._routing.shard_and_local(np.where(valid, flat, 0))
        own = np.where(valid, own_raw, -1)
        with self._lock:
            if counts is None:
                self._traffic += np.bincount(own[own >= 0],
                                             minlength=self.n_shards)
            else:
                np.add.at(self._traffic, own[valid],
                          np.asarray(counts, np.int64).reshape(-1)[valid])

        # counts stay positionally aligned: ids not owned by shard s are
        # masked to -1, which the shard's own valid-mask filters
        thunks = [
            self.shard_backends[s].prepare_submit(
                state[f"s{s}"], np.where(own == s, loc, -1),
                assume_unique, counts)
            for s in range(self.n_shards)
        ]

        def collect():
            pool = self._ensure_pool()
            futs = [pool.submit(t) for t in thunks]
            new_state = dict(state)
            devs = np.empty((self.n_shards, flat.size), np.int64)
            for s, f in enumerate(futs):
                st_s, dev_s = f.result()
                new_state[f"s{s}"] = st_s
                devs[s] = np.asarray(dev_s, np.int64).reshape(-1)
            pick = np.where(own >= 0, own, 0)
            local_dev = devs[pick, np.arange(flat.size)]
            out = np.where((own >= 0) & (local_dev >= 0),
                           own * self.stride + local_dev, -1)
            return new_state, jnp.asarray(out.reshape(shape), jnp.int32)
        return collect

    def read_rows(self, state, ids):
        """Serve-path read through the routing: every shard reads its own
        subset concurrently on the router's thread pool (each shard
        pins/reads under its own lock), and the disjoint per-shard rows
        are merged back into occurrence order."""
        spec = self.spec
        arr = np.asarray(ids, np.int64)
        flat = arr.reshape(-1)
        valid = (flat >= 0) & (flat < spec.rows)
        own_raw, loc = self._routing.shard_and_local(np.where(valid, flat, 0))
        own = np.where(valid, own_raw, -1)

        def read_one(s):
            return self.shard_backends[s].read_rows(
                state[f"s{s}"], np.where(own == s, loc, -1))

        pool = self._ensure_pool()
        futs = [pool.submit(read_one, s) for s in range(self.n_shards)]
        out = np.zeros((flat.size, spec.dim), np.float32)
        info = {"reads": 0, "hits": 0, "misses": 0}
        for s, f in enumerate(futs):
            rows, inf = f.result()
            sel = own == s
            out[sel] = rows.reshape(-1, spec.dim)[sel]
            for k in info:
                info[k] += int(inf.get(k, 0))
        return out.reshape(arr.shape + (spec.dim,)), info

    # -- slot pinning / shard introspection ----------------------------------

    def _split_dev(self, dev_ids):
        flat = np.asarray(dev_ids, np.int64).reshape(-1)
        flat = flat[(flat >= 0) & (flat < self.dev_rows)]
        return flat // self.stride, flat % self.stride

    def pin_slots(self, dev_ids):
        own, loc = self._split_dev(dev_ids)
        for s, sub in enumerate(self.shard_backends):
            sel = own == s
            if sel.any():
                sub.pin_slots(loc[sel])

    def unpin_slots(self, dev_ids):
        own, loc = self._split_dev(dev_ids)
        for s, sub in enumerate(self.shard_backends):
            sel = own == s
            if sel.any():
                sub.unpin_slots(loc[sel])

    def reset_pins(self):
        for sub in self.shard_backends:
            sub.reset_pins()

    def n_put_shards(self) -> int:
        return self.n_shards

    def put_shards(self, dev_ids) -> tuple[int, ...]:
        own, _ = self._split_dev(dev_ids)
        return tuple(np.unique(own).tolist())

    def queue_init(self, ids_shape):
        if self.spec.staleness <= 0:
            return None
        # one width for every shard's queue: the ROUTER-level cap — the
        # plan's unique put is pushed into each shard masked to that
        # shard's rows, so every sub-queue must hold the full unique width
        return self._queue_init_width(self.queue_width(_prod(ids_shape)))

    def _queue_init_width(self, width: int):
        return {f"s{s}": sub._queue_init_width(width)
                for s, sub in enumerate(self.shard_backends)}

    # -- traceable -----------------------------------------------------------

    def _local_ids(self, flat, s):
        local = flat - s * self.stride
        return jnp.where((local >= 0) & (local < self.stride), local, -1)

    def _lookup_flat(self, state, dev_ids):
        shape = dev_ids.shape
        flat = dev_ids.reshape(-1)
        total = None
        for s, sub in enumerate(self.shard_backends):
            acts, _ = sub._lookup_flat(state[f"s{s}"],
                                       self._local_ids(flat, s))
            total = acts if total is None else total + acts
        return total.reshape(*shape, self.spec.dim), {}

    def _lookup_unique(self, state, dev_u):
        # every unique id is owned by exactly one shard: the per-shard
        # gathers are disjoint (zeros elsewhere), so the sum is exact
        total = None
        for s, sub in enumerate(self.shard_backends):
            acts, _ = sub._lookup_flat(state[f"s{s}"],
                                       self._local_ids(dev_u, s))
            total = acts if total is None else total + acts
        return total, {}

    def _put_flat(self, state, dev_ids, grads):
        flat = dev_ids.reshape(-1)
        g = grads.reshape(-1, self.spec.dim)
        new = dict(state)
        for s, sub in enumerate(self.shard_backends):
            new[f"s{s}"], _ = sub._put_flat(state[f"s{s}"],
                                            self._local_ids(flat, s), g)
        return new, {}

    def _put_unique(self, state, dev_u, g_u):
        new = dict(state)
        for s, sub in enumerate(self.shard_backends):
            new[f"s{s}"], _ = sub._put_unique(state[f"s{s}"],
                                              self._local_ids(dev_u, s), g_u)
        return new, {}

    def _hybrid_flat(self, state, queue, dev_ids, grads):
        flat = dev_ids.reshape(-1)
        g = grads.reshape(-1, self.spec.dim)
        new_state, new_queue = dict(state), dict(queue or {})
        for s, sub in enumerate(self.shard_backends):
            q = None if queue is None else queue.get(f"s{s}")
            st, q, _ = sub._hybrid_flat(state[f"s{s}"], q,
                                        self._local_ids(flat, s), g)
            new_state[f"s{s}"] = st
            new_queue[f"s{s}"] = q
        if queue is None and all(v is None for v in new_queue.values()):
            return new_state, None, {}
        return new_state, new_queue, {}

    def _hybrid_unique(self, state, queue, dev_u, g_u):
        new_state, new_queue = dict(state), dict(queue or {})
        for s, sub in enumerate(self.shard_backends):
            q = None if queue is None else queue.get(f"s{s}")
            st, q, _ = sub._hybrid_unique(state[f"s{s}"], q,
                                          self._local_ids(dev_u, s), g_u)
            new_state[f"s{s}"] = st
            new_queue[f"s{s}"] = q
        if queue is None and all(v is None for v in new_queue.values()):
            return new_state, None, {}
        return new_state, new_queue, {}

    # -- checkpoint ----------------------------------------------------------

    def state_for_checkpoint(self, state):
        return {
            "shard_meta": np.array([self.n_shards, self.spec.rows,
                                    self.spec.dim], np.int64),
            "shards": {f"s{s}": sub.state_for_checkpoint(state[f"s{s}"])
                       for s, sub in enumerate(self.shard_backends)},
        }

    def restore_from_checkpoint(self, blob):
        self.last_restore_resharded = False
        if isinstance(blob, dict) and "shard_meta" in blob:
            meta = np.asarray(blob["shard_meta"], np.int64).reshape(-1)
            if int(meta[0]) == self.n_shards:
                # same geometry: per-shard bit-identical restore
                out = {}
                for s, sub in enumerate(self.shard_backends):
                    try:
                        out[f"s{s}"] = sub.restore_from_checkpoint(
                            blob["shards"][f"s{s}"])
                    except ValueError as e:
                        raise ValueError(f"shard {s}: {e}") from e
                return out
        vec, acc = extract_logical_rows(blob, self.spec, self._base)
        self.last_restore_resharded = True
        return self._sub_states_from_logical(vec, acc)

    # -- metrics / capacity accounting ---------------------------------------

    def shard_metrics(self) -> dict:
        """Per-shard gauges for the step-metrics dict (keys are relative:
        the trainer prefixes ``shard/<table>/``), plus the max/mean
        load-imbalance gauge over cumulative routed-id traffic."""
        out = {}
        for s, sub in enumerate(self.shard_backends):
            faults = getattr(sub, "faults", 0)
            hits = getattr(sub, "hits", 0)
            looked = hits + faults
            out[f"{s}/hit_rate"] = (hits / looked) if looked else 1.0
            out[f"{s}/faults"] = float(faults)
            store = getattr(sub, "store", None)
            if store is not None:
                out[f"{s}/rows"] = float(store.size)
                out[f"{s}/bytes"] = float(sub.host_bytes())
            else:
                itemsize = jnp.dtype(sub.spec.dtype).itemsize
                out[f"{s}/rows"] = float(sub.spec.rows)
                out[f"{s}/bytes"] = float(sub.spec.rows * sub.spec.dim
                                          * itemsize)
        with self._lock:
            traffic = self._traffic.copy()
        mean = float(traffic.mean()) if traffic.size else 0.0
        out["imbalance"] = (float(traffic.max()) / mean) if mean > 0 else 1.0
        return out

    def cache_metrics(self) -> dict:
        out: dict[str, float] = {}
        for sub in self.shard_backends:
            for k, v in sub.cache_metrics().items():
                out[k] = out.get(k, 0.0) + v
        return out

    def device_bytes(self, state) -> int:
        return sum(sub.device_bytes(state[f"s{s}"])
                   for s, sub in enumerate(self.shard_backends))

    def host_bytes(self) -> int:
        return sum(sub.host_bytes() for sub in self.shard_backends)


# ===========================================================================
# CompressedWireBackend — §4.2.3 wire compression as a decorator
# ===========================================================================

class CompressedWireBackend(EmbeddingBackend):
    """Wraps another backend with the paper's communication compression:
    gradient puts are deduplicated to one row per unique id (lossless) and
    both get and put payloads cross the simulated wire as blockscale fp16
    (lossy, AUC-neutral by design). Per-step bytes-moved metrics surface
    through the trainer's metrics dict as ``wire/<table>/...``."""

    def __init__(self, inner: EmbeddingBackend):
        self.inner = inner
        self.spec = inner.spec
        self._block = int(self.spec.wire_block)
        if self.spec.wire_kernel and self._block != 128:
            raise ValueError("the Pallas blockscale kernel is fixed at "
                             f"block=128 (got wire_block={self._block})")

    @property
    def requires_prepare(self) -> bool:
        return self.inner.requires_prepare

    def _roundtrip(self, v):
        if self.spec.wire_kernel:
            from repro.kernels import ops
            return ops.blockscale_roundtrip(v, block=self._block)
        return C.blockscale_roundtrip(v, block=self._block)

    def _dev_rows(self) -> int:
        if isinstance(self.inner, ShardedBackend):
            return self.inner.dev_rows
        if isinstance(self.inner, HostLRUBackend):
            return self.inner.dev_slots
        return self.spec.rows

    # -- host-level: delegate ------------------------------------------------

    def init(self, key, shards: int = 1, scale: float = 0.02):
        return self.inner.init(key, shards, scale)

    def prepare(self, state, ids, assume_unique: bool = False, counts=None):
        return self.inner.prepare(state, ids, assume_unique, counts)

    def read_rows(self, state, ids):
        # serve reads cross the same lossy wire as training lookups
        rows, info = self.inner.read_rows(state, ids)
        flat = jnp.asarray(rows.reshape(-1, self.spec.dim))
        return (np.asarray(self._roundtrip(flat),
                           np.float32).reshape(rows.shape), info)

    def dedup_rows(self) -> int:
        return self.inner.dedup_rows()

    def queue_width(self, n_occ: int) -> int:
        # the wire ALWAYS dedups its puts (even on the legacy path), so its
        # queue is capped regardless of batch_dedup — the pre-dedup width
        # rule, kept so old wire checkpoints restore without migration
        return D.dedup_cap(n_occ, self._dev_rows())

    def pin_slots(self, dev_ids):
        self.inner.pin_slots(dev_ids)

    def unpin_slots(self, dev_ids):
        self.inner.unpin_slots(dev_ids)

    def reset_pins(self):
        self.inner.reset_pins()

    def n_put_shards(self) -> int:
        return self.inner.n_put_shards()

    def put_shards(self, dev_ids) -> tuple[int, ...]:
        return self.inner.put_shards(dev_ids)

    def shard_metrics(self) -> dict:
        return self.inner.shard_metrics()

    def cache_metrics(self) -> dict:
        return self.inner.cache_metrics()

    @property
    def last_restore_resharded(self) -> bool:
        return self.inner.last_restore_resharded

    def queue_init(self, ids_shape):
        # the queue lives PS-side, AFTER the wire: it holds deduped puts
        if self.spec.staleness <= 0:
            return None
        return self.inner._queue_init_width(
            self.queue_width(_prod(ids_shape)))

    def state_for_checkpoint(self, state):
        return self.inner.state_for_checkpoint(state)

    def restore_from_checkpoint(self, blob):
        return self.inner.restore_from_checkpoint(blob)

    # -- traceable -----------------------------------------------------------

    def lookup(self, state, dev_ids):
        if D.is_plan(dev_ids):
            # the wire ships ONE row per unique id; the inverse scatter to
            # occurrence width happens on the NN-worker side, AFTER the
            # (lossy) wire — so both the bytes moved and the quantisation
            # work shrink by the batch's dup factor
            acts_u, m = self.inner._lookup_unique(state, dev_ids.dev)
            n_raw = int(dev_ids.inv.size) * self.spec.dim
            n_wire = int(acts_u.size)
            acts = D.plan_scatter(self._roundtrip(acts_u), dev_ids.inv)
        else:
            acts, m = self.inner.lookup(state, dev_ids)
            n_raw = n_wire = int(acts.size)
            acts = self._roundtrip(acts)
        blocks = -(-n_wire // self._block)
        m = dict(m)
        m["get_bytes_raw"] = jnp.float32(n_raw * 4)
        m["get_bytes_wire"] = jnp.float32(blocks * self._block * 2
                                          + blocks * 4)
        return acts, m

    def _compress_put(self, dev_ids, grads):
        """(dev_ids | plan, occurrence grads) -> (unique ids, compressed
        unique grads, byte metrics). With a plan the lossless dedup IS the
        plan's segment-sum (no on-device sort); the legacy path keeps the
        sort-based dedup_put."""
        spec = self.spec
        if D.is_plan(dev_ids):
            uniq = dev_ids.dev
            g_u = D.plan_segment_sum(dev_ids.inv, grads,
                                     int(uniq.shape[0]))
            n_put = int(dev_ids.inv.size)
        else:
            flat = dev_ids.reshape(-1).astype(jnp.int32)
            g = grads.reshape(-1, spec.dim).astype(jnp.float32)
            n_put = int(flat.shape[0])
            cap = D.dedup_cap(n_put, self._dev_rows())
            uniq, g_u = C.dedup_put(flat, g, cap)
        g_u = self._roundtrip(g_u)
        n_uniq = jnp.sum(uniq >= 0).astype(jnp.float32)
        n_vals = n_uniq * spec.dim
        metrics = {
            # raw wire: one (int32 id, fp32 row) per put entry, pre-dedup
            "put_bytes_raw": jnp.float32(n_put * (4 + spec.dim * 4)),
            # compressed wire: unique ids + fp16 values + per-block scales
            "put_bytes_wire": n_uniq * 4 + n_vals * 2
            + jnp.ceil(n_vals / self._block) * 4,
        }
        return uniq, g_u, metrics

    def apply_put(self, state, dev_ids, grads):
        uniq, g_u, m = self._compress_put(dev_ids, grads)
        st, m2 = self.inner._put_unique(state, uniq, g_u)
        return st, {**m, **m2}

    def hybrid_update(self, state, queue, dev_ids, grads):
        uniq, g_u, m = self._compress_put(dev_ids, grads)
        st, q, m2 = self.inner._hybrid_unique(state, queue, uniq, g_u)
        return st, q, {**m, **m2}

    # -- capacity accounting -------------------------------------------------

    def device_bytes(self, state) -> int:
        return self.inner.device_bytes(state)

    def host_bytes(self) -> int:
        return self.inner.host_bytes()


# ===========================================================================
# Factory + collection-level drivers
# ===========================================================================

def parse_backend_name(name: str | None) -> tuple[str, bool]:
    """``EmbeddingSpec.backend`` string -> (base, compressed?). Accepted
    forms: ``dense``, ``host_lru``, ``host_lru+disk`` (the three-tier
    hierarchy — ``base`` keeps the ``+disk`` marker), plus a
    ``+compressed`` suffix on any of them (``compressed`` alone means
    ``dense+compressed``)."""
    name = (name or "dense").strip().lower()
    parts = name.split("+")
    base, flags = parts[0], parts[1:]
    wrap = "compressed" in flags
    if base in ("", "compressed"):
        base, wrap, flags = "dense", True, [f for f in flags
                                            if f != "compressed"]
    unknown = [f for f in flags if f not in ("compressed", "disk")]
    if unknown:
        raise ValueError(
            f"unknown backend decorator {unknown[0]!r} in {name!r} "
            "(only '+disk' and '+compressed' exist)")
    if base not in ("dense", "host_lru"):
        raise ValueError(
            f"unknown embedding backend {name!r}: expected 'dense', "
            "'host_lru' or 'host_lru+disk', optionally with a "
            "'+compressed' suffix")
    if "disk" in flags:
        if base != "host_lru":
            raise ValueError(
                f"the '+disk' tier only stacks under 'host_lru' "
                f"(got {name!r})")
        base = "host_lru+disk"
    return base, wrap


def create_backend(spec: EmbeddingSpec) -> EmbeddingBackend:
    """``spec.backend`` -> backend instance (see parse_backend_name).
    ``spec.emb_shards > 1`` routes through the :class:`ShardedBackend`
    router; the compressed wire (when requested) wraps OUTSIDE the router,
    so one wire serves the whole table. ``emb_shards == 1`` returns the
    plain backend — bit- and checkpoint-byte-identical to the pre-router
    code."""
    base, wrap = parse_backend_name(spec.backend)
    if int(spec.emb_shards) > 1:
        backend: EmbeddingBackend = ShardedBackend(spec)
    elif base == "dense":
        backend = DenseBackend(spec)
    else:
        backend = HostLRUBackend(spec)
    return CompressedWireBackend(backend) if wrap else backend


def unwrap(backend: EmbeddingBackend) -> EmbeddingBackend:
    """Strip wire decorators down to the storage backend (plain or router)."""
    while isinstance(backend, CompressedWireBackend):
        backend = backend.inner
    return backend


def ensure_shards(backend: EmbeddingBackend, k: int) -> EmbeddingBackend:
    """Route a backend through a ``k``-shard router (the
    ``PersiaTrainer.init(emb_shards=...)`` path). ``k == 1`` is "no
    override" and returns the backend unchanged — it never downgrades a
    spec-sharded router. Dense backends without ``spec.emb_shards`` keep
    the legacy semantics (``init(shards=k)`` pads the PS rows for mesh
    sharding), so only host-backed tables — which used to raise — and
    existing routers are rebuilt here."""
    if int(k) == 1:
        return backend
    inner = unwrap(backend)
    if isinstance(inner, ShardedBackend):
        if inner.n_shards == int(k):
            return backend
    elif not isinstance(inner, HostLRUBackend):
        return backend                      # dense: legacy ps_init padding
    new_inner = ShardedBackend(
        dataclasses.replace(inner.spec, emb_shards=int(k)))
    return CompressedWireBackend(new_inner) \
        if isinstance(backend, CompressedWireBackend) else new_inner


def make_backends(collection) -> dict[str, EmbeddingBackend]:
    """One backend instance per table (instances own mutable host state, so
    each trainer must build its own set)."""
    return {n: create_backend(s) for n, s in collection.items()}


def any_requires_prepare(backends) -> bool:
    return any(b.requires_prepare for b in backends.values())


def shard_step_metrics(backends) -> dict:
    """Host-side per-shard gauges for the step-metrics dict:
    ``shard/<table>/<k>/{hit_rate,faults,rows,bytes}`` plus the
    ``shard/<table>/imbalance`` max/mean traffic gauge (hot-key skew made
    visible). Empty — and cheap — when no table is sharded."""
    out = {}
    for n, b in backends.items():
        for k, v in b.shard_metrics().items():
            out[f"shard/{n}/{k}"] = v
    return out


def prepare_all(backends, states, ids):
    """Host-level per-table prepare: batch dedup + fault-in + id
    translation, once per (table, batch).

    For tables with ``spec.batch_dedup`` (the default) this computes the
    :class:`~repro.core.dedup.DedupPlan` — np.unique on the host, the
    backend's ``prepare`` consuming the already-unique set (no second
    np.unique in the fault path) — and returns it as the table's dev-ids
    entry; the traceable ops then run at unique width. Legacy tables
    (``batch_dedup=False``) keep the occurrence-width translation.

    Returns ``(new_states, dev_ids, metrics)`` where metrics carries the
    per-table ``dedup/<table>/{dup_factor,unique_rows,bytes_saved}``
    host gauges.

    Runs in two phases over the tables: every table's prepare is
    *submitted* first (``prepare_submit``), then collected — remote
    backends buffer all the submits into one coalesced frame per endpoint
    and the collects' RPC waits overlap, so a k-table trainer pays one
    round-trip per endpoint instead of k."""
    new_states = dict(states)
    dev_ids = {}
    metrics = {}
    submitted = []
    for n in ids:
        b = backends[n]
        spec = b.spec
        if not spec.batch_dedup:
            submitted.append((n, None,
                              b.prepare_submit(states[n], ids[n])))
            continue
        cap = D.dedup_cap(max(int(np.size(ids[n])), 1), b.dedup_rows())
        u_pad, inv, counts, info = D.make_plan(ids[n], spec.rows, cap)
        submitted.append((n, (inv, info),
                          b.prepare_submit(states[n], u_pad,
                                           assume_unique=True,
                                           counts=counts)))
    for n, plan, collect in submitted:
        b = backends[n]
        spec = b.spec
        if plan is None:
            new_states[n], dev_ids[n] = collect()
            for k, v in b.cache_metrics().items():
                metrics[f"cache/{n}/{k}"] = v
            continue
        inv, info = plan
        new_states[n], dev_u = collect()
        dev_ids[n] = DedupPlan(dev=jnp.asarray(dev_u, jnp.int32),
                               inv=jnp.asarray(inv, jnp.int32))
        itemsize = jnp.dtype(spec.dtype).itemsize
        metrics[f"dedup/{n}/dup_factor"] = info["dup_factor"]
        metrics[f"dedup/{n}/unique_rows"] = float(info["n_unique"])
        metrics[f"dedup/{n}/bytes_saved"] = float(
            (info["n_occ"] - info["n_unique"]) * spec.dim * itemsize)
        for k, v in b.cache_metrics().items():
            metrics[f"cache/{n}/{k}"] = v
    return new_states, dev_ids, metrics


def _tag(metrics, name, table_metrics):
    for k, v in table_metrics.items():
        metrics[f"wire/{name}/{k}"] = v


def lookup_all(backends, states, dev_ids):
    """Traceable fan-out of per-table lookups -> (acts, wire metrics)."""
    acts, metrics = {}, {}
    for n in dev_ids:
        if n not in backends:
            raise KeyError(f"ids for unknown table {n!r}; collection has "
                           f"{sorted(backends)}")
        acts[n], m = backends[n].lookup(states[n], dev_ids[n])
        _tag(metrics, n, m)
    return acts, metrics


def put_all(backends, states, queues, dev_ids, grads):
    """Traceable fan-out of per-table hybrid updates (push this step's put,
    apply the tau-stale one) -> (states, queues, wire metrics)."""
    queues = queues or {}
    new_states, new_queues, metrics = dict(states), dict(queues), {}
    for n in dev_ids:
        st, q, m = backends[n].hybrid_update(
            states[n], queues.get(n), dev_ids[n], grads[n])
        new_states[n], new_queues[n] = st, q
        _tag(metrics, n, m)
    return new_states, new_queues, metrics
