"""Pluggable embedding storage backends — the memory hierarchy behind the PS.

Persia's 100T-parameter capacity claim (paper §4.2.2/§4.2.3) rests on the
embedding tier being *bigger than device memory*: PS nodes keep tables in
host RAM behind an LRU array-list cache and move rows over a compressed
wire. This module makes that a first-class storage choice: every table in an
:class:`~repro.core.collection.EmbeddingCollection` selects its backend via
``EmbeddingSpec.backend``:

* ``DenseBackend`` — the device-sharded PS of :mod:`repro.core.embedding_ps`
  re-housed behind the protocol, numerically unchanged.
* ``HostLRUBackend`` — the out-of-core tier: a device-resident hot-cache of
  ``spec.cache_rows`` slots backed by a host :class:`LRUEmbeddingStore`
  holding all ``spec.rows`` (vectors **and** adagrad accumulators, the
  paper's array-item layout). ``prepare`` faults missing rows host→device
  and writes evicted dirty rows back, so logical ``rows`` can exceed device
  memory.
* ``CompressedWireBackend`` — a decorator over either backend applying the
  paper's §4.2.3 wire compression: lossless unique-id dedup on puts plus
  lossy blockscale fp16 on get/put payloads, surfacing bytes-moved metrics.

The protocol splits host-level from traceable ops:

  host-level (never traced; may mutate backend-owned host state):
    ``init / prepare / queue_init / state_for_checkpoint /
    restore_from_checkpoint``
  traceable (pure, jit-safe, operate on *device ids* — raw ids for dense,
  cache-slot indices for host_lru — produced by ``prepare``):
    ``lookup / apply_put / hybrid_update``

``lookup`` returns ``(acts, metrics)`` and the put ops return their updated
state plus a metrics dict (empty except for the compressed wire), so wire
traffic flows out through the trainer's per-step metrics.
"""
from __future__ import annotations

import dataclasses
import math
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compression as C
from repro.core import embedding_ps as PS
from repro.core.embedding_ps import EmbeddingSpec
from repro.core.lru import LRUEmbeddingStore
from repro.utils import round_up


def _prod(shape) -> int:
    return math.prod(int(s) for s in shape)


def _dedup_cap(n_put: int, n_rows: int) -> int:
    """Mirror of embedding_ps.apply_put's dedup capacity rule, so the
    backends' wire/cache dedups drop rows exactly when the dense PS would."""
    return round_up(min(n_put, n_rows), min(1024, n_put))


def _pow2_bucket(n: int, floor: int = 32) -> int:
    """Smallest power of two >= n (and >= floor). The fault path pads its
    scatter/gather shapes to these buckets: each distinct miss count would
    otherwise dispatch a fresh shape and trigger its own XLA compile,
    turning the per-step prepare into a seconds-long recompile treadmill."""
    b = floor
    while b < n:
        b <<= 1
    return b


# the fault path's device ops, fused and jitted (cached per bucket shape):
# one dispatch per table instead of one per array keeps the host prepare
# phase off the dispatch-overhead treadmill

@jax.jit
def _fault_apply(table, slot_ids, vslots, vecs, ids):
    return (table.at[vslots].set(vecs.astype(table.dtype)),
            slot_ids.at[vslots].set(ids))


@jax.jit
def _fault_apply_acc(table, slot_ids, acc, vslots, vecs, ids, accs):
    return (table.at[vslots].set(vecs.astype(table.dtype)),
            slot_ids.at[vslots].set(ids),
            acc.at[vslots].set(accs))


@jax.jit
def _gather_rows(table, eslots):
    return table[eslots].astype(jnp.float32)


@jax.jit
def _gather_rows_acc(table, acc, eslots):
    return (table[eslots].astype(jnp.float32),
            acc[eslots].astype(jnp.float32))


class EmbeddingBackend:
    """Protocol base. Subclasses own one table's storage (device arrays are
    threaded through as pytrees; anything host-resident lives on ``self``).
    ``requires_prepare`` tells the trainer whether ``prepare`` does real work
    (host fault-in) and therefore must run outside jit every step."""

    spec: EmbeddingSpec
    requires_prepare: bool = False

    # -- host-level ----------------------------------------------------------
    def init(self, key, shards: int = 1, scale: float = 0.02):
        raise NotImplementedError

    def prepare(self, state, ids):
        """(state, ids) -> (state, device_ids). Host-level, once per step."""
        return state, ids

    # slot pinning: a pipelined caller pins a batch's device slots between
    # its prepare and its applied put, so a later batch's fault-in cannot
    # recycle rows still in flight. No-ops for device-resident backends
    # (device ids ARE logical ids — nothing is ever recycled).
    def pin_slots(self, dev_ids):
        pass

    def unpin_slots(self, dev_ids):
        pass

    def reset_pins(self):
        pass

    def queue_init(self, ids_shape):
        raise NotImplementedError

    def state_for_checkpoint(self, state):
        raise NotImplementedError

    def restore_from_checkpoint(self, blob):
        raise NotImplementedError

    # -- traceable -----------------------------------------------------------
    def lookup(self, state, dev_ids):
        raise NotImplementedError

    def apply_put(self, state, dev_ids, grads):
        raise NotImplementedError

    def hybrid_update(self, state, queue, dev_ids, grads):
        raise NotImplementedError

    # -- capacity accounting (benchmarks) ------------------------------------
    def device_bytes(self, state) -> int:
        return sum(int(x.size) * x.dtype.itemsize
                   for x in jax.tree.leaves(state))

    def host_bytes(self) -> int:
        return 0


# ===========================================================================
# DenseBackend — today's device-sharded PS behind the protocol
# ===========================================================================

class DenseBackend(EmbeddingBackend):
    """Device-resident PS shard; every op delegates to embedding_ps with no
    numerical change (device ids ARE the logical ids)."""

    requires_prepare = False

    def __init__(self, spec: EmbeddingSpec):
        self.spec = spec

    def init(self, key, shards: int = 1, scale: float = 0.02):
        return PS.ps_init(key, self.spec, shards, scale)

    def queue_init(self, ids_shape):
        if self.spec.staleness <= 0:
            return None
        return PS.queue_init(self.spec, (_prod(ids_shape),), self.spec.dim)

    def lookup(self, state, dev_ids):
        return PS.lookup(state, self.spec, dev_ids), {}

    def apply_put(self, state, dev_ids, grads):
        return PS.apply_put(state, self.spec, dev_ids.reshape(-1),
                            grads.reshape(-1, self.spec.dim)), {}

    def hybrid_update(self, state, queue, dev_ids, grads):
        st, q = PS.hybrid_emb_update(state, queue, self.spec,
                                     dev_ids.reshape(-1),
                                     grads.reshape(-1, self.spec.dim))
        return st, q, {}

    def state_for_checkpoint(self, state):
        return jax.tree.map(np.asarray, state)

    def restore_from_checkpoint(self, blob):
        spec = self.spec
        table = blob.get("table") if isinstance(blob, dict) else None
        if table is None:
            raise ValueError(
                "checkpoint blob has no 'table' — it was not written by the "
                "dense backend (restoring across backends is not supported)")
        if table.shape[1] != spec.dim or table.shape[0] < spec.rows:
            raise ValueError(
                f"checkpoint table has shape {tuple(table.shape)} but this "
                f"table's spec wants >= ({spec.rows}, {spec.dim}) — "
                "collection changed since the save?")
        return blob


# ===========================================================================
# HostLRUBackend — the out-of-core tier (paper §4.2.2)
# ===========================================================================

class HostLRUBackend(EmbeddingBackend):
    """Device hot-cache of ``spec.cache_rows`` slots over a host
    :class:`LRUEmbeddingStore` holding all ``spec.rows``.

    ``prepare`` is the fault path: it resolves the batch's unique ids
    against the slot map, writes the LRU victims' (vector, acc) back to the
    host store, loads the missing rows device-side, and returns the batch
    translated to cache-slot indices. The traceable ops then run entirely on
    the device cache — lookups gather slots, puts apply the PS-side
    optimizer to slots via the same dedup + row-sparse apply as the dense
    backend, so a working set that fits in cache is bit-exact with dense.

    Staleness queues store ``(slot, logical id)`` pairs; a popped put whose
    slot has been recycled for another id since it was enqueued is dropped
    (the paper's tolerated lost put). Note this includes recycling caused by
    *read-path* fault-ins: an eval/lookup batch near the cache's capacity
    can evict a slot with a put still pending in the queue — unlike the
    dense backend, eval is then not perfectly side-effect-free. Alg.1's
    lock-free semantics tolerate the loss; size ``cache_rows`` above the
    combined train+eval working set where that matters.

    The host tier (slot map, clock, LRU store) is guarded by an RLock:
    ``prepare`` may be called from a pipeline's prepare-stage thread while
    another thread (eval, checkpointing) touches the same backend, and the
    slot bookkeeping must stay a bijection under that interleaving. Callers
    are still responsible for sequencing the *device-array* state they
    thread through prepare/put (the pipeline's table-store lock does this).
    """

    requires_prepare = True

    def __init__(self, spec: EmbeddingSpec):
        if spec.cache_rows <= 0:
            raise ValueError(
                "host_lru backend needs EmbeddingSpec.cache_rows > 0 "
                f"(got {spec.cache_rows})")
        if spec.optimizer not in ("adagrad", "sgd"):
            raise ValueError(spec.optimizer)
        self.spec = spec
        self.cache_rows = int(spec.cache_rows)
        self.store: LRUEmbeddingStore | None = None
        self._lock = threading.RLock()
        self._slot_for_id: dict[int, int] = {}
        self._id_for_slot = np.full(self.cache_rows, -1, np.int64)
        self._slot_clock = np.zeros(self.cache_rows, np.int64)
        self._pin_count = np.zeros(self.cache_rows, np.int32)
        self._tick = 0
        self.faults = 0          # rows moved host -> device
        self.writebacks = 0      # rows moved device -> host

    # -- host-level ----------------------------------------------------------

    def init(self, key, shards: int = 1, scale: float = 0.02):
        if shards != 1:
            raise ValueError("host_lru is a per-host tier: the device cache "
                             "is single-shard (got shards={})".format(shards))
        with self._lock:
            return self._init_locked(key, scale)

    def _init_locked(self, key, scale: float):
        spec = self.spec
        # draw the SAME init values the dense backend would, then park them
        # host-side: host row for id i is what a dense lookup of i would
        # read (table[shuffle_pos(i)]) — this is what makes dense and
        # host_lru bit-exact when the working set fits in cache. The draw is
        # pinned to the CPU backend: threefry is backend-deterministic, and
        # a rows x dim table is exactly what must NOT touch device memory
        with jax.default_device(jax.devices("cpu")[0]):
            dense = PS.ps_init(key,
                               dataclasses.replace(spec, backend="dense"),
                               1, scale)
            table = np.asarray(dense["table"], np.float32)
        pos = np.asarray(PS.shuffle_pos(jnp.arange(spec.rows),
                                        spec.padded_rows(1)))
        self.store = LRUEmbeddingStore(spec.rows, spec.dim)
        self.store.preload(np.arange(spec.rows), table[pos])
        # a re-init starts a fresh run: drop any previous slot bookkeeping
        self._slot_for_id = {}
        self._id_for_slot = np.full(self.cache_rows, -1, np.int64)
        self._slot_clock = np.zeros(self.cache_rows, np.int64)
        self._pin_count = np.zeros(self.cache_rows, np.int32)
        self._tick = 0
        self.faults = self.writebacks = 0
        state = {
            "table": jnp.zeros((self.cache_rows, spec.dim), spec.dtype),
            "slot_ids": jnp.full((self.cache_rows,), -1, jnp.int32),
        }
        if spec.optimizer == "adagrad":
            state["acc"] = jnp.zeros((self.cache_rows,), jnp.float32)
        return state

    def prepare(self, state, ids):
        """Fault the batch's rows into the device cache; translate ids to
        cache-slot indices (-1 for padding / out-of-range). Thread-safe:
        the whole fault-in (slot map + LRU store + clock) is one critical
        section, so concurrent callers see consistent slot bookkeeping."""
        with self._lock:
            return self._prepare_locked(state, ids)

    def _prepare_locked(self, state, ids):
        spec = self.spec
        flat = np.asarray(ids, np.int64).reshape(-1)
        valid = (flat >= 0) & (flat < spec.rows)
        uniq = np.unique(flat[valid])
        if uniq.size > self.cache_rows:
            raise ValueError(
                f"batch working set ({uniq.size} unique ids) exceeds the "
                f"device cache ({self.cache_rows} slots) — raise "
                "EmbeddingSpec.cache_rows or shrink the batch")
        self._tick += 1
        smap = self._slot_for_id
        uslots = np.fromiter((smap.get(k, -1) for k in uniq.tolist()),
                             np.int64, uniq.size)
        hit_slots = uslots[uslots >= 0]
        missing = uniq[uslots < 0]
        if missing.size:
            state = dict(state)
            victims = self._free_slots(hit_slots, missing.size, state)
            vecs, accs = self.store.read_rows(missing)
            self.faults += missing.size
            # bucket the scatter shape (see _pow2_bucket): pad slots index
            # one past the cache — an out-of-bounds scatter update, which
            # JAX drops — so padding never touches a real row
            m, bucket = missing.size, _pow2_bucket(missing.size)
            pad_slots = np.full(bucket, self.cache_rows, np.int64)
            pad_slots[:m] = victims
            pad_vecs = np.zeros((bucket, spec.dim), np.float32)
            pad_vecs[:m] = vecs
            pad_ids = np.full(bucket, -1, np.int64)
            pad_ids[:m] = missing
            vslots = jnp.asarray(pad_slots, jnp.int32)
            vecs_j = jnp.asarray(pad_vecs, jnp.float32)
            ids_j = jnp.asarray(pad_ids, jnp.int32)
            if "acc" in state:
                pad_accs = np.zeros(bucket, np.float32)
                pad_accs[:m] = accs
                state["table"], state["slot_ids"], state["acc"] = \
                    _fault_apply_acc(state["table"], state["slot_ids"],
                                     state["acc"], vslots, vecs_j, ids_j,
                                     jnp.asarray(pad_accs, jnp.float32))
            else:
                state["table"], state["slot_ids"] = _fault_apply(
                    state["table"], state["slot_ids"], vslots, vecs_j, ids_j)
            for k, s in zip(missing.tolist(), victims.tolist()):
                smap[k] = s
            self._id_for_slot[victims] = missing
            touched = np.concatenate([hit_slots, victims])
        else:
            touched = hit_slots
        self._slot_clock[touched] = self._tick
        dev = np.fromiter((smap.get(k, -1) for k in flat.tolist()),
                          np.int64, flat.size)
        dev[~valid] = -1
        return state, jnp.asarray(dev.reshape(np.shape(ids)), jnp.int32)

    def _free_slots(self, protected: np.ndarray, need: int, state):
        """Pick ``need`` victim slots: empty slots first, then the
        least-recently-touched occupied slots outside the current batch
        (never a pinned slot — those hold rows of in-flight pipelined
        batches); evicted rows (vector + acc) are written back to the
        host store."""
        pinned = self._pin_count > 0
        free = np.nonzero((self._id_for_slot < 0) & ~pinned)[0][:need]
        n_evict = need - free.size
        if n_evict <= 0:
            return free
        cand = np.ones(self.cache_rows, bool)
        cand[self._id_for_slot < 0] = False
        cand[protected] = False
        cand[pinned] = False
        cand_slots = np.nonzero(cand)[0]
        if cand_slots.size < n_evict:
            raise ValueError(
                f"fault-in needs {n_evict} eviction victims but only "
                f"{cand_slots.size} unpinned slots are evictable: the "
                f"combined working set of in-flight pipelined batches "
                f"exceeds the device cache ({self.cache_rows} slots, "
                f"{int(pinned.sum())} pinned) — lower max_inflight or "
                "raise EmbeddingSpec.cache_rows")
        order = np.argsort(self._slot_clock[cand_slots], kind="stable")
        evict = cand_slots[order[:n_evict]]
        ev_ids = self._id_for_slot[evict]
        # bucketed gather (see _pow2_bucket); pad rows are sliced back off
        idx = np.zeros(_pow2_bucket(n_evict), np.int64)
        idx[:n_evict] = evict
        eslots = jnp.asarray(idx, jnp.int32)
        if "acc" in state:
            vecs_j, accs_j = _gather_rows_acc(state["table"], state["acc"],
                                              eslots)
            accs = np.asarray(accs_j)[:n_evict]
        else:
            vecs_j, accs = _gather_rows(state["table"], eslots), None
        vecs = np.asarray(vecs_j)[:n_evict]
        self.store.write_rows(ev_ids, vecs, accs)
        self.writebacks += int(evict.size)
        for k in ev_ids.tolist():
            del self._slot_for_id[k]
        self._id_for_slot[evict] = -1
        return np.concatenate([free, evict])

    # -- slot pinning (pipelined callers) ------------------------------------
    #
    # Between a batch's prepare and its applied put, a deep pipeline must
    # keep that batch's cache slots resident: a later batch's fault-in that
    # recycled them would make the pending lookup read the WRONG row (not a
    # stale one) and silently drop the put. Pins are reference counts; a
    # fault-in that cannot find enough unpinned victims raises (the
    # combined in-flight working set must fit the cache).

    def pin_slots(self, dev_ids):
        slots = np.asarray(dev_ids, np.int64).reshape(-1)
        slots = slots[(slots >= 0) & (slots < self.cache_rows)]
        with self._lock:
            np.add.at(self._pin_count, slots, 1)

    def unpin_slots(self, dev_ids):
        slots = np.asarray(dev_ids, np.int64).reshape(-1)
        slots = slots[(slots >= 0) & (slots < self.cache_rows)]
        with self._lock:
            np.subtract.at(self._pin_count, slots, 1)
            np.maximum(self._pin_count, 0, out=self._pin_count)

    def reset_pins(self):
        with self._lock:
            self._pin_count[:] = 0

    def queue_init(self, ids_shape):
        spec = self.spec
        if spec.staleness <= 0:
            return None
        tau, n_ids = spec.staleness, _prod(ids_shape)
        return {
            "slots": jnp.full((tau, n_ids), -1, jnp.int32),
            "ids": jnp.full((tau, n_ids), -1, jnp.int32),
            "grads": jnp.zeros((tau, n_ids, spec.dim), spec.dtype),
            "ptr": jnp.zeros((), jnp.int32),
            "filled": jnp.zeros((), jnp.int32),
        }

    # -- traceable -----------------------------------------------------------

    def lookup(self, state, dev_ids):
        shape = dev_ids.shape
        flat = dev_ids.reshape(-1)
        valid = (flat >= 0) & (flat < self.cache_rows)
        safe = jnp.clip(flat, 0, self.cache_rows - 1)
        out = state["table"][safe] * valid[:, None].astype(
            state["table"].dtype)
        return out.reshape(*shape, self.spec.dim), {}

    def apply_put(self, state, dev_ids, grads):
        spec = self.spec
        flat = dev_ids.reshape(-1)
        grads = grads.reshape(-1, spec.dim)
        valid = (flat >= 0) & (flat < self.cache_rows)
        g = jnp.where(valid[:, None], grads, 0.0).astype(jnp.float32)
        slot_signed = jnp.where(valid, flat.astype(jnp.int32), -1)
        cap = _dedup_cap(int(flat.shape[0]), self.cache_rows)
        uniq, g_u = C.dedup_put(slot_signed, g, cap)
        new = PS._apply_sparse(
            state, spec, jnp.where(uniq >= 0, uniq, self.cache_rows), g_u,
            self.cache_rows)
        return new, {}

    def hybrid_update(self, state, queue, dev_ids, grads):
        spec = self.spec
        flat = dev_ids.reshape(-1)
        g = grads.reshape(-1, spec.dim)
        if spec.staleness <= 0 or queue is None:
            st, m = self.apply_put(state, flat, g)
            return st, queue, m
        valid = (flat >= 0) & (flat < self.cache_rows)
        safe = jnp.clip(flat, 0, self.cache_rows - 1)
        logical = jnp.where(valid, state["slot_ids"][safe], -1)
        ptr = queue["ptr"]
        old_slots = jnp.take(queue["slots"], ptr, axis=0)
        old_ids = jnp.take(queue["ids"], ptr, axis=0)
        old_g = jnp.take(queue["grads"], ptr, axis=0)
        tau = queue["slots"].shape[0]
        queue = {
            "slots": jax.lax.dynamic_update_index_in_dim(
                queue["slots"], jnp.where(valid, flat.astype(jnp.int32), -1),
                ptr, 0),
            "ids": jax.lax.dynamic_update_index_in_dim(
                queue["ids"], logical.astype(jnp.int32), ptr, 0),
            "grads": jax.lax.dynamic_update_index_in_dim(
                queue["grads"], g.astype(queue["grads"].dtype), ptr, 0),
            "ptr": (ptr + 1) % tau,
            "filled": jnp.minimum(queue["filled"] + 1, tau),
        }
        # a tau-stale put only lands if its slot still holds the same row
        old_safe = jnp.clip(old_slots, 0, self.cache_rows - 1)
        still = (old_slots >= 0) & (old_ids >= 0) & \
            (state["slot_ids"][old_safe] == old_ids)
        st, m = self.apply_put(state, jnp.where(still, old_slots, -1), old_g)
        return st, queue, m

    # -- checkpoint ----------------------------------------------------------

    def state_for_checkpoint(self, state):
        """Snapshot BOTH tiers: the device cache (so queued slot references
        stay live across restore) and the host store with its recency
        order, plus the slot map — a restore resumes bit-identically."""
        with self._lock:
            return {
                "cache": jax.tree.map(np.asarray, state),
                "store": self.store.serialize(),
                "cache_meta": {
                    "id_for_slot": self._id_for_slot.copy(),
                    "slot_clock": self._slot_clock.copy(),
                    "scalars": np.array([self._tick, self.faults,
                                         self.writebacks], np.int64),
                },
            }

    def restore_from_checkpoint(self, blob):
        with self._lock:
            return self._restore_locked(blob)

    def _restore_locked(self, blob):
        spec = self.spec
        if not isinstance(blob, dict) or "store" not in blob \
                or "cache" not in blob:
            raise ValueError(
                "checkpoint blob has no host store — it was not written by "
                "the host_lru backend (restoring across backends is not "
                "supported)")
        meta = blob["store"]["meta"]
        cap, dim = int(meta[0]), int(meta[1])
        if cap != spec.rows or dim != spec.dim:
            raise ValueError(
                f"checkpoint host store is ({cap}, {dim}) but this table's "
                f"spec wants ({spec.rows}, {spec.dim}) — collection changed "
                "since the save?")
        cache_tbl = blob["cache"]["table"]
        if cache_tbl.shape[0] != self.cache_rows:
            raise ValueError(
                f"checkpoint device cache has {cache_tbl.shape[0]} slots but "
                f"this table runs cache_rows={self.cache_rows} — rebuild the "
                "trainer with the cache the checkpoint was trained under")
        self.store = LRUEmbeddingStore.deserialize(blob["store"])
        cm = blob["cache_meta"]
        self._pin_count = np.zeros(self.cache_rows, np.int32)
        self._id_for_slot = np.asarray(cm["id_for_slot"], np.int64).copy()
        self._slot_clock = np.asarray(cm["slot_clock"], np.int64).copy()
        self._tick, faults, wbacks = (int(x) for x in cm["scalars"])
        self.faults, self.writebacks = int(faults), int(wbacks)
        self._slot_for_id = {
            int(k): int(s)
            for s, k in enumerate(self._id_for_slot.tolist()) if k >= 0}
        return {k: jnp.asarray(v) for k, v in blob["cache"].items()}

    # -- capacity accounting / inspection ------------------------------------

    def host_bytes(self) -> int:
        s = self.store
        if s is None:
            return 0
        return int(s.vectors.nbytes + s.opt_acc.nbytes + s.prev.nbytes
                   + s.next.nbytes + s.keys.nbytes)

    def recency_order(self) -> list[int]:
        """Host-store ids most- to least-recently used (checkpointed)."""
        return self.store.recency_ids()


# ===========================================================================
# CompressedWireBackend — §4.2.3 wire compression as a decorator
# ===========================================================================

class CompressedWireBackend(EmbeddingBackend):
    """Wraps another backend with the paper's communication compression:
    gradient puts are deduplicated to one row per unique id (lossless) and
    both get and put payloads cross the simulated wire as blockscale fp16
    (lossy, AUC-neutral by design). Per-step bytes-moved metrics surface
    through the trainer's metrics dict as ``wire/<table>/...``."""

    def __init__(self, inner: EmbeddingBackend):
        self.inner = inner
        self.spec = inner.spec
        self._block = int(self.spec.wire_block)
        if self.spec.wire_kernel and self._block != 128:
            raise ValueError("the Pallas blockscale kernel is fixed at "
                             f"block=128 (got wire_block={self._block})")

    @property
    def requires_prepare(self) -> bool:
        return self.inner.requires_prepare

    def _roundtrip(self, v):
        if self.spec.wire_kernel:
            from repro.kernels import ops
            return ops.blockscale_roundtrip(v, block=self._block)
        return C.blockscale_roundtrip(v, block=self._block)

    def _dev_rows(self) -> int:
        if isinstance(self.inner, HostLRUBackend):
            return self.inner.cache_rows
        return self.spec.rows

    # -- host-level: delegate ------------------------------------------------

    def init(self, key, shards: int = 1, scale: float = 0.02):
        return self.inner.init(key, shards, scale)

    def prepare(self, state, ids):
        return self.inner.prepare(state, ids)

    def pin_slots(self, dev_ids):
        self.inner.pin_slots(dev_ids)

    def unpin_slots(self, dev_ids):
        self.inner.unpin_slots(dev_ids)

    def reset_pins(self):
        self.inner.reset_pins()

    def queue_init(self, ids_shape):
        # the queue lives PS-side, AFTER the wire: it holds deduped puts
        if self.spec.staleness <= 0:
            return None
        cap = _dedup_cap(_prod(ids_shape), self._dev_rows())
        return self.inner.queue_init((cap,))

    def state_for_checkpoint(self, state):
        return self.inner.state_for_checkpoint(state)

    def restore_from_checkpoint(self, blob):
        return self.inner.restore_from_checkpoint(blob)

    # -- traceable -----------------------------------------------------------

    def lookup(self, state, dev_ids):
        acts, m = self.inner.lookup(state, dev_ids)
        n_vals = int(acts.size)
        blocks = -(-n_vals // self._block)
        m = dict(m)
        m["get_bytes_raw"] = jnp.float32(n_vals * 4)
        m["get_bytes_wire"] = jnp.float32(blocks * self._block * 2
                                          + blocks * 4)
        return self._roundtrip(acts), m

    def _compress_put(self, dev_ids, grads):
        spec = self.spec
        flat = dev_ids.reshape(-1).astype(jnp.int32)
        g = grads.reshape(-1, spec.dim).astype(jnp.float32)
        n_put = int(flat.shape[0])
        cap = _dedup_cap(n_put, self._dev_rows())
        uniq, g_u = C.dedup_put(flat, g, cap)
        g_u = self._roundtrip(g_u)
        n_uniq = jnp.sum(uniq >= 0).astype(jnp.float32)
        n_vals = n_uniq * spec.dim
        metrics = {
            # raw wire: one (int32 id, fp32 row) per put entry, pre-dedup
            "put_bytes_raw": jnp.float32(n_put * (4 + spec.dim * 4)),
            # compressed wire: unique ids + fp16 values + per-block scales
            "put_bytes_wire": n_uniq * 4 + n_vals * 2
            + jnp.ceil(n_vals / self._block) * 4,
        }
        return uniq, g_u, metrics

    def apply_put(self, state, dev_ids, grads):
        uniq, g_u, m = self._compress_put(dev_ids, grads)
        st, m2 = self.inner.apply_put(state, uniq, g_u)
        return st, {**m, **m2}

    def hybrid_update(self, state, queue, dev_ids, grads):
        uniq, g_u, m = self._compress_put(dev_ids, grads)
        st, q, m2 = self.inner.hybrid_update(state, queue, uniq, g_u)
        return st, q, {**m, **m2}

    # -- capacity accounting -------------------------------------------------

    def device_bytes(self, state) -> int:
        return self.inner.device_bytes(state)

    def host_bytes(self) -> int:
        return self.inner.host_bytes()


# ===========================================================================
# Factory + collection-level drivers
# ===========================================================================

def parse_backend_name(name: str | None) -> tuple[str, bool]:
    """``EmbeddingSpec.backend`` string -> (base, compressed?). Accepted
    forms: ``dense``, ``host_lru``, plus a ``+compressed`` suffix on either
    (``compressed`` alone means ``dense+compressed``)."""
    name = (name or "dense").strip().lower()
    base, sep, suffix = name.partition("+")
    wrap = bool(sep)
    if sep and suffix != "compressed":
        raise ValueError(f"unknown backend decorator {suffix!r} in "
                         f"{name!r} (only '+compressed' exists)")
    if base in ("", "compressed"):
        base, wrap = "dense", True
    if base not in ("dense", "host_lru"):
        raise ValueError(
            f"unknown embedding backend {name!r}: expected 'dense', "
            "'host_lru', optionally with a '+compressed' suffix")
    return base, wrap


def create_backend(spec: EmbeddingSpec) -> EmbeddingBackend:
    """``spec.backend`` -> backend instance (see parse_backend_name)."""
    base, wrap = parse_backend_name(spec.backend)
    if base == "dense":
        backend: EmbeddingBackend = DenseBackend(spec)
    else:
        backend = HostLRUBackend(spec)
    return CompressedWireBackend(backend) if wrap else backend


def make_backends(collection) -> dict[str, EmbeddingBackend]:
    """One backend instance per table (instances own mutable host state, so
    each trainer must build its own set)."""
    return {n: create_backend(s) for n, s in collection.items()}


def any_requires_prepare(backends) -> bool:
    return any(b.requires_prepare for b in backends.values())


def prepare_all(backends, states, ids):
    """Host-level per-table fault-in + id translation (identity for dense)."""
    new_states = dict(states)
    dev_ids = {}
    for n in ids:
        new_states[n], dev_ids[n] = backends[n].prepare(states[n], ids[n])
    return new_states, dev_ids


def _tag(metrics, name, table_metrics):
    for k, v in table_metrics.items():
        metrics[f"wire/{name}/{k}"] = v


def lookup_all(backends, states, dev_ids):
    """Traceable fan-out of per-table lookups -> (acts, wire metrics)."""
    acts, metrics = {}, {}
    for n in dev_ids:
        if n not in backends:
            raise KeyError(f"ids for unknown table {n!r}; collection has "
                           f"{sorted(backends)}")
        acts[n], m = backends[n].lookup(states[n], dev_ids[n])
        _tag(metrics, n, m)
    return acts, metrics


def put_all(backends, states, queues, dev_ids, grads):
    """Traceable fan-out of per-table hybrid updates (push this step's put,
    apply the tau-stale one) -> (states, queues, wire metrics)."""
    queues = queues or {}
    new_states, new_queues, metrics = dict(states), dict(queues), {}
    for n in dev_ids:
        st, q, m = backends[n].hybrid_update(
            states[n], queues.get(n), dev_ids[n], grads[n])
        new_states[n], new_queues[n] = st, q
        _tag(metrics, n, m)
    return new_states, new_queues, metrics
