"""Theorem 1 convergence-bound calculator + empirical alpha estimation.

rate(T) ~ sigma/sqrt(T) + 1/T + tau*alpha/T        (paper eq. 6)

alpha = max over ids of P[sample contains id]: the ID-frequency upper bound
that damps the staleness penalty. For power-law ID distributions (the
realistic recsys regime) alpha << 1 and the hybrid algorithm's rate matches
synchronous SGD — this module makes those terms concrete so the staleness
benchmark can check the *measured* hybrid/sync gap scales like tau*alpha.
"""
from __future__ import annotations

import numpy as np


def hybrid_rate_bound(T: int, sigma: float, tau: int, alpha: float,
                      L: float = 1.0) -> dict:
    sgd_term = sigma * np.sqrt(L) / np.sqrt(T)
    det_term = L / T
    stale_term = tau * min(1.0, alpha) * L / T
    return {
        "sgd_term": sgd_term,
        "deterministic_term": det_term,
        "staleness_term": stale_term,
        "total": sgd_term + det_term + stale_term,
        "stale_fraction": stale_term / max(sgd_term + det_term + stale_term,
                                           1e-30),
    }


def optimal_lr(T: int, sigma: float, tau: int, alpha: float,
               L: float = 1.0) -> float:
    """gamma = 1 / (L + sqrt(T L) sigma + 4 tau L alpha)  (Theorem 1)."""
    return 1.0 / (L + np.sqrt(T * L) * sigma + 4 * tau * L * min(1.0, alpha))


def estimate_alpha(ids_batches: list[np.ndarray], n_rows: int) -> float:
    """Empirical alpha: max over ids of (samples containing id / samples)."""
    counts = np.zeros(n_rows, dtype=np.int64)
    n_samples = 0
    for b in ids_batches:
        B = b.shape[0]
        n_samples += B
        for s in range(B):
            u = np.unique(b[s][b[s] >= 0])
            counts[u] += 1
    return float(counts.max()) / max(n_samples, 1)
