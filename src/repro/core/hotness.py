"""Decayed count-min hotness sketch — the admission filter of the
frequency-aware cache hierarchy (ROADMAP open item 1).

Persia's device cache (paper §4.2.2) is recency-only: every id seen once
claims a slot and can evict a genuinely hot row. ScaleFreeCTR's MixCache
(PAPERS.md) shows the production fix — track per-id access *frequency* in
sublinear space and only admit ids whose estimated hotness clears a
threshold; everything else is served from the lower tier without
disturbing the hot set.

The sketch here is a classic count-min (d hash rows, w counters each,
estimate = min over rows) with two recsys-specific twists:

* counters are float32 and *decayed* by a multiplicative factor every
  ``decay_every`` updates, so hotness is exponentially recent-weighted —
  an id that was hot yesterday but is cold now stops being admitted
  (the "decay forgets stale hotness" property ``tests/test_cache_tiers``
  pins);
* ``update`` takes the per-batch *unique* ids plus their occurrence
  counts (the :class:`~repro.core.dedup.DedupPlan` hands both to the
  backend's prepare), so a once-per-batch update still counts true
  occurrence frequency, not post-dedup frequency.

Pure numpy, O(d) vectorized ops per batch; serializes to flat arrays so
it rides inside the host_lru checkpoint blob.
"""
from __future__ import annotations

import numpy as np

# affine-hash constants: odd multipliers (bijective premix mod 2^64),
# one (mult, add) pair drawn per sketch row from a seeded PCG stream
_MIX_SHIFT = 17


class HotnessSketch:
    """Decayed count-min sketch over int64 ids.

    >>> sk = HotnessSketch(width=1024, depth=4, decay=0.5, decay_every=64)
    >>> sk.update(np.array([3, 7]), counts=np.array([5, 1]))
    >>> sk.estimate(np.array([3, 7, 9]))     # ~[5, 1, 0]
    """

    def __init__(self, width: int = 4096, depth: int = 4,
                 decay: float = 0.5, decay_every: int = 256,
                 seed: int = 0):
        if width < 1 or depth < 1:
            raise ValueError(f"width/depth must be >= 1 "
                             f"(got {width}, {depth})")
        if not (0.0 < decay <= 1.0):
            raise ValueError(f"decay must be in (0, 1] (got {decay})")
        self.width = int(width)
        self.depth = int(depth)
        self.decay = float(decay)
        self.decay_every = max(int(decay_every), 1)
        self.seed = int(seed)
        rng = np.random.default_rng(seed)
        # odd multipliers => each row's premix is a bijection mod 2^64
        self._mult = (rng.integers(1, 2**63, self.depth,
                                   dtype=np.uint64) * 2 + 1)
        self._add = rng.integers(0, 2**63, self.depth, dtype=np.uint64)
        self.counts = np.zeros((self.depth, self.width), np.float32)
        self.updates = 0

    def _cols(self, ids: np.ndarray) -> np.ndarray:
        """(n,) ids -> (depth, n) counter columns."""
        u = np.asarray(ids, np.int64).astype(np.uint64)
        mixed = u[None, :] * self._mult[:, None] + self._add[:, None]
        # fold the high bits down before the mod: low bits of an affine
        # map over sequential ids are themselves sequential
        return ((mixed >> np.uint64(_MIX_SHIFT)) ^ mixed) % \
            np.uint64(self.width)

    # -- updates -------------------------------------------------------------

    def update(self, ids, counts=None) -> None:
        """Add one batch's occurrences: ``ids`` unique int64 ids (negatives
        ignored), ``counts`` their per-id occurrence counts (default 1)."""
        ids = np.asarray(ids, np.int64).reshape(-1)
        keep = ids >= 0
        ids = ids[keep]
        if counts is None:
            c = np.ones(ids.size, np.float32)
        else:
            c = np.asarray(counts, np.float32).reshape(-1)[keep]
        if ids.size:
            cols = self._cols(ids)
            for d in range(self.depth):
                np.add.at(self.counts[d], cols[d], c)
        self.updates += 1
        if self.updates % self.decay_every == 0:
            self.age()

    def age(self) -> None:
        """Apply one decay step (also called automatically every
        ``decay_every`` updates): hotness is exponentially
        recent-weighted, so stale ids fall back below the admission
        threshold instead of staying 'hot' forever."""
        if self.decay < 1.0:
            self.counts *= self.decay
            # flush denormals so a long-idle sketch reads exactly cold
            self.counts[self.counts < 1e-6] = 0.0

    # -- queries -------------------------------------------------------------

    def estimate(self, ids) -> np.ndarray:
        """(n,) float32 count-min estimates (upper bounds; negatives
        estimate 0)."""
        ids = np.asarray(ids, np.int64).reshape(-1)
        if ids.size == 0:
            return np.zeros(0, np.float32)
        cols = self._cols(np.where(ids >= 0, ids, 0))
        est = self.counts[np.arange(self.depth)[:, None], cols].min(axis=0)
        return np.where(ids >= 0, est, 0.0).astype(np.float32)

    # -- (de)serialisation ---------------------------------------------------

    def serialize(self) -> dict[str, np.ndarray]:
        return {
            "counts": self.counts.copy(),
            "meta": np.array([self.width, self.depth, self.decay_every,
                              self.seed, self.updates], np.int64),
            "decay": np.array([self.decay], np.float64),
        }

    @classmethod
    def deserialize(cls, blob) -> "HotnessSketch":
        meta = [int(x) for x in np.asarray(blob["meta"]).reshape(-1)]
        width, depth, decay_every, seed, updates = meta[:5]
        sk = cls(width=width, depth=depth,
                 decay=float(np.asarray(blob["decay"]).reshape(-1)[0]),
                 decay_every=decay_every, seed=seed)
        sk.counts[...] = np.asarray(blob["counts"],
                                    np.float32).reshape(depth, width)
        sk.updates = updates
        return sk
