"""Worker-side batch deduplication — Persia §4.2.3's first communication
optimisation moved to the FRONT of the embedding data path.

A CTR batch's multi-hot ids repeat heavily (hot keys, repeated users/items):
the worker should gather and ship **one row per unique id**, not one per
occurrence. Before this module the repro only deduped *after* the expensive
part — ``embedding_ps.apply_put`` segment-summed on device once full-width
gradients had already been transferred, queued for ``tau`` steps and
(optionally) wire-compressed, while ``lookup`` gathered per-occurrence.

The :class:`DedupPlan` is computed **once per (table, batch) on the host**
(the trainer's prepare phase, outside jit) and carries:

* ``dev``  — the batch's unique ids translated to *device* ids (raw ids for
  dense, cache slots for host_lru, shard-encoded for the router), padded
  with ``-1`` to a power-of-two bucket (same trick as the host-LRU fault
  path: each distinct unique count would otherwise dispatch a fresh jit
  shape and trigger its own XLA compile).
* ``inv``  — occurrence -> unique position (``-1`` for padding/invalid
  occurrences), at the original id shape.

Everything downstream then runs at *unique width*: ``lookup`` gathers
``n_unique`` rows and scatters activations back through ``inv`` inside jit
(:func:`plan_scatter`; the fused Pallas ``unique_bag`` kernel in
``repro.kernels`` does gather + inverse + sum-pool in one pass for pooled
consumers), and the backward pass segment-sums occurrence gradients to
unique width (:func:`plan_segment_sum`) *before* they enter the staleness
queue — so queue memory (``tau`` copies!), device puts and compressed-wire
bytes all shrink by the batch's duplication factor.

Bit-exactness: summing a unique id's occurrence gradients here (in
occurrence order, fp32) produces the same bits as the old post-queue
``dedup_put`` (stable sort keeps equal ids in occurrence order), and
adagrad's row-sparse apply only sees the per-row *sums* — so segment-sum
before vs. after the queue commutes. The one caveat is non-fp32 queue
dtypes: the cast to the queue dtype now happens after the summation instead
of before, so bf16 queues round at a different point (fp32 queues — the
default — are bit-identical).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils import round_up


# ---------------------------------------------------------------------------
# The canonical dedup capacity rule (single source of truth)
# ---------------------------------------------------------------------------

def dedup_cap(n_put: int, n_rows: int) -> int:
    """Capacity of a deduplicated put of ``n_put`` entries over an id space
    of ``n_rows``: at most ``min(n_put, n_rows)`` rows can be distinct,
    rounded up so the deduped arrays still shard over the batch axes on any
    production mesh (up to 1024 batch shards).

    This is THE rule — ``embedding_ps.apply_put``, the storage backends'
    queue sizing and the compressed wire all share it (a drifted mirror
    would make one layer drop rows another layer still ships). It is
    idempotent (``dedup_cap(dedup_cap(n, r), r) == dedup_cap(n, r)``),
    which is what lets checkpointed queue widths be re-derived on restore.
    """
    n_put = int(n_put)
    return round_up(min(n_put, int(n_rows)), min(1024, max(n_put, 1)))


def pow2_bucket(n: int, floor: int = 32) -> int:
    """Smallest power of two >= n (and >= floor) — the jit shape-stability
    bucket shared with the host-LRU fault path."""
    b = floor
    while b < n:
        b <<= 1
    return b


# ---------------------------------------------------------------------------
# The per-(table, batch) dedup plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DedupPlan:
    """One batch's unique-width routing for one table (a jit-able pytree).

    ``dev``: (U,) int32 unique *device* ids, -1 padding (U is the pow2
    bucket of the batch's unique count, capped at the table's dedup cap).
    ``inv``: occurrence-shaped int32, occurrence -> position in ``dev``
    (-1 for padding / out-of-range occurrences).
    """
    dev: jax.Array
    inv: jax.Array


jax.tree_util.register_dataclass(
    DedupPlan, data_fields=("dev", "inv"), meta_fields=())


def is_plan(x) -> bool:
    return isinstance(x, DedupPlan)


def plan_dev(x):
    """The device-id array of a plan, or the array itself (host-side
    callers — pinning, shard routing — that accept either form)."""
    return x.dev if isinstance(x, DedupPlan) else x


def make_plan(ids, n_rows: int, cap: int, floor: int = 32):
    """Host-side dedup of one table's batch ids.

    ids: any-shape int array, -1 (or out-of-range) = padding.
    Returns ``(unique_ids, inverse, counts, info)``:

    * ``unique_ids``: (bucket,) np.int64, sorted uniques padded with -1
      (``bucket = min(pow2_bucket(n_unique, floor), cap)``);
    * ``inverse``: ids-shaped np.int32, occurrence -> unique position
      (-1 for invalid occurrences);
    * ``counts``: (bucket,) np.int64 occurrence count per unique id (0 on
      padding) — the router's traffic/imbalance gauges keep measuring the
      raw id *stream*, not the deduped wire;
    * ``info``: {n_unique, n_occ, dup_factor} host gauges.
    """
    arr = np.asarray(ids, np.int64)
    flat = arr.reshape(-1)
    valid = (flat >= 0) & (flat < int(n_rows))
    uniq, inv_valid, cnt = np.unique(flat[valid], return_inverse=True,
                                     return_counts=True)
    bucket = min(pow2_bucket(max(int(uniq.size), 1), floor), int(cap))
    if uniq.size > bucket:
        # cap follows dedup_cap(n_occ, backend.dedup_rows()); for a
        # host-backed table dedup_rows is bounded by the device cache, so a
        # batch whose working set exceeds the cache lands HERE (before the
        # fault path would have raised its own version of this error)
        raise ValueError(
            f"batch working set ({uniq.size} unique ids) exceeds this "
            f"table's dedup capacity ({bucket} — bounded by the occurrence "
            "count, the table rows and, for host-backed tables, the device "
            "cache) — raise EmbeddingSpec.cache_rows or shrink the batch")
    u_pad = np.full(bucket, -1, np.int64)
    u_pad[: uniq.size] = uniq
    counts = np.zeros(bucket, np.int64)
    counts[: uniq.size] = cnt
    inv = np.full(flat.shape, -1, np.int32)
    inv[valid] = inv_valid.astype(np.int32)
    n_occ = int(valid.sum())
    info = {"n_unique": int(uniq.size), "n_occ": n_occ,
            "dup_factor": n_occ / max(int(uniq.size), 1)}
    return u_pad, inv.reshape(arr.shape), counts, info


# ---------------------------------------------------------------------------
# Traceable unique-width ops (jit-safe, shapes static per plan bucket)
# ---------------------------------------------------------------------------

def plan_scatter(acts_u, inv):
    """Unique-width activations -> occurrence-width activations.

    acts_u: (U, D); inv: occurrence-shaped int32 -> (*inv.shape, D) with
    zero rows for invalid occurrences (inv < 0)."""
    flat = inv.reshape(-1)
    valid = flat >= 0
    safe = jnp.clip(flat, 0, acts_u.shape[0] - 1)
    out = acts_u[safe] * valid[:, None].astype(acts_u.dtype)
    return out.reshape(*inv.shape, acts_u.shape[-1])


def plan_segment_sum(inv, grads, width: int):
    """Occurrence-width gradients -> (width, D) fp32 unique-width sums.

    Sums run in occurrence order — the same order ``dedup_put``'s stable
    sort visits equal ids in — so the per-row sums are bit-identical to the
    old post-queue dedup. Invalid occurrences (inv < 0) contribute nothing
    (scattered to a sacrificial row that is sliced off)."""
    flat = inv.reshape(-1)
    g = grads.reshape(flat.shape[0], -1).astype(jnp.float32)
    safe = jnp.where(flat >= 0, flat, width)
    return jnp.zeros((width + 1, g.shape[1]), jnp.float32).at[safe].add(
        g)[:width]


def pad_axis0(arr, width: int, fill):
    """Pad (n, ...) to (width, ...) along axis 0 with ``fill`` (n <= width)
    — fitting a plan-bucket-width put into the fixed-width staleness
    queue."""
    n = int(arr.shape[0])
    if n == width:
        return arr
    pads = [(0, width - n)] + [(0, 0)] * (arr.ndim - 1)
    return jnp.pad(arr, pads, constant_values=fill)


# ---------------------------------------------------------------------------
# Checkpoint migration: full-width queue blobs -> unique width
# ---------------------------------------------------------------------------

def migrate_queue_blob(q, new_width: int):
    """Re-encode one staleness-queue blob at ``new_width`` by deduplicating
    each of its tau pending puts (numpy, host-side — the restore path).

    Accepts the dense form ({ids, grads, ptr, filled}) and the host-LRU
    form (+ slots; dedup keys on the slot, the id rides along). Summation
    runs in occurrence order per key, so a migrated queue's pops apply the
    exact same fp32 updates the full-width queue would have."""
    ids = np.asarray(q["ids"])
    grads = np.asarray(q["grads"])
    slots = np.asarray(q["slots"]) if "slots" in q else None
    tau, width = ids.shape
    new_width = int(new_width)
    key = slots if slots is not None else ids
    new_ids = np.full((tau, new_width), -1, ids.dtype)
    new_grads = np.zeros((tau, new_width, grads.shape[-1]), grads.dtype)
    new_slots = (None if slots is None
                 else np.full((tau, new_width), -1, slots.dtype))
    for t in range(tau):
        k = key[t]
        valid = k >= 0
        uniq, first, inv = np.unique(k[valid], return_index=True,
                                     return_inverse=True)
        if uniq.size > new_width:
            raise ValueError(
                f"queue slot {t} holds {uniq.size} unique puts but the "
                f"migrated width is only {new_width} — the dedup capacity "
                "rule should make this impossible; was the blob edited?")
        acc = np.zeros((uniq.size, grads.shape[-1]), np.float32)
        np.add.at(acc, inv, grads[t][valid].astype(np.float32))
        new_grads[t, : uniq.size] = acc.astype(grads.dtype)
        if slots is None:
            new_ids[t, : uniq.size] = uniq
        else:
            new_slots[t, : uniq.size] = uniq
            new_ids[t, : uniq.size] = ids[t][valid][first]
    out = {"ids": new_ids, "grads": new_grads,
           "ptr": np.asarray(q["ptr"]), "filled": np.asarray(q["filled"])}
    if new_slots is not None:
        out["slots"] = new_slots
    return out
