"""Tuned host-environment profile (``--tuned-host``).

Large-scale JAX training launchers ship the same three host-side knobs in
their run.sh (see SNIPPETS.md 1-2: HomebrewNLP, olmax):

* ``LD_PRELOAD`` tcmalloc — the host-LRU put path is malloc-heavy (numpy
  gather/scatter temporaries every step); tcmalloc's thread caches beat
  glibc malloc on that churn.
* ``TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD`` — silence the per-allocation
  warnings numpy's big table buffers would otherwise trigger.
* ``TF_CPP_MIN_LOG_LEVEL`` / ``XLA_FLAGS`` — quiet logs and pin the host
  platform device count instead of letting XLA guess from the core count.

``LD_PRELOAD`` only takes effect at process start, so ``apply_tuned_host``
re-execs the interpreter exactly once (guarded by a marker env var). When
libtcmalloc is not installed the profile degrades to the env-var-only
subset — a graceful no-op, never an error.
"""
from __future__ import annotations

import glob
import os
import sys

# marker: set on first application so the re-exec'd process (which inherits
# it) falls straight through instead of exec-looping
_MARKER = "REPRO_TUNED_HOST"

# the exact soname the exemplar launchers preload, then progressively
# looser fallbacks (minimal build, unversioned dev symlink, other arches)
_TCMALLOC_GLOBS = (
    "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4",
    "/usr/lib/*/libtcmalloc.so*",
    "/usr/lib/*/libtcmalloc_minimal.so*",
    "/usr/lib/libtcmalloc*.so*",
    "/usr/local/lib/libtcmalloc*.so*",
)


def find_tcmalloc() -> str | None:
    """Path of the best installed libtcmalloc, or None when absent."""
    for pat in _TCMALLOC_GLOBS:
        hits = sorted(glob.glob(pat))
        if hits:
            return hits[0]
    return None


def tuned_env(host_devices: int = 1, base_xla_flags: str = "") -> dict:
    """The env-var subset of the profile, as a pure dict (no process
    mutation — apply_tuned_host and the benchmark A/B both consume this).
    ``base_xla_flags`` is merged so caller-set XLA flags survive."""
    flag = f"--xla_force_host_platform_device_count={int(host_devices)}"
    flags = base_xla_flags
    if "--xla_force_host_platform_device_count" not in flags:
        flags = f"{flags} {flag}".strip()
    return {
        "TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD": "60000000000",
        "TF_CPP_MIN_LOG_LEVEL": "4",
        "XLA_FLAGS": flags,
    }


def apply_tuned_host(host_devices: int = 1) -> str:
    """Apply the profile to THIS process. Returns a status string:

    * ``"already"``     — marker set (we are the re-exec'd process);
    * ``"no-tcmalloc"`` — env vars applied, libtcmalloc absent (no-op
      degradation: nothing to preload, no re-exec);
    * ``"preloaded"``   — env vars applied, tcmalloc already in LD_PRELOAD.

    When tcmalloc is found and not yet preloaded this re-execs the
    interpreter with LD_PRELOAD set and does NOT return.
    """
    if os.environ.get(_MARKER):
        return "already"
    os.environ.update(tuned_env(host_devices,
                                os.environ.get("XLA_FLAGS", "")))
    os.environ[_MARKER] = "1"
    lib = find_tcmalloc()
    if lib is None:
        return "no-tcmalloc"
    pre = os.environ.get("LD_PRELOAD", "")
    if lib in pre.split(":"):
        return "preloaded"
    os.environ["LD_PRELOAD"] = f"{lib}:{pre}" if pre else lib
    # sys.argv[0] is the script path under both `python x.py` and
    # `python -m pkg.mod`; PYTHONPATH is inherited so imports resolve
    os.execv(sys.executable, [sys.executable] + sys.argv)
    raise AssertionError("unreachable")  # pragma: no cover
