"""Loop-aware cost extraction from post-SPMD optimized HLO text.

XLA's built-in ``cost_analysis()`` counts each while-loop body ONCE, which
under-counts everything inside our scan-over-layers by the trip count. This
walker parses the HLO module into computations, builds the call graph (while
bodies weighted by their trip count — taken from the ``known_trip_count``
backend config XLA attaches, with a condition-constant fallback — and fusions
folded into their caller as single kernels), and accumulates per-device:

  * flops            — 2*out_elems*K for every dot, from local (post-SPMD)
                       shapes, including dots inside fusion computations
  * hbm_bytes        — kernel-boundary traffic: operand + result bytes of
                       every non-fused op in control computations (the
                       standard roofline accounting: one fusion == one kernel)
  * collective bytes — per collective type (all-reduce counted 2x: ring)

Shapes in optimized HLO are per-device, so all numbers are per-device.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "c64": 8, "f32": 4, "s32": 4,
                "u32": 4, "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1,
                "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1,
                "u4": 1}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*?)\s([\w\-]+)\((.*)$")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%([\w.\-]+)\s*\(.*->.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# ops whose operands+results we count as kernel-boundary HBM traffic when
# they appear in a control (non-fusion) computation
_KERNEL_OPS = {
    "dot", "fusion", "convolution", "custom-call", "dynamic-update-slice",
    "dynamic-slice", "copy", "scatter", "gather", "reduce", "transpose",
    "concatenate", "broadcast", "pad", "select", "convert", "sort", "rng",
    "reduce-window", "select-and-scatter", "add", "multiply", "subtract",
    "divide", "exponential", "tanh", "rsqrt", "maximum", "minimum", "slice",
    "reshape", "compare", "iota", "log", "negate", "bitcast-convert",
}
# collectives counted separately for traffic too (they also touch HBM)
_KERNEL_OPS |= set(COLLECTIVES) | {c + "-start" for c in COLLECTIVES}


def _dims(shape_txt: str):
    """All (dtype, dims, bytes) tuples in a (possibly tuple) shape string."""
    out = []
    for dt, dims in _SHAPE_RE.findall(shape_txt):
        if dt not in _DTYPE_BYTES:
            continue
        dd = [int(d) for d in dims.split(",")] if dims else []
        n = 1
        for d in dd:
            n *= d
        out.append((dt, dd, n * _DTYPE_BYTES[dt], n))
    return out


def _shape_bytes_elems(shape_txt: str):
    parts = _dims(shape_txt)
    return sum(p[2] for p in parts), sum(p[3] for p in parts)


@dataclass
class Op:
    name: str
    opcode: str
    shape_txt: str
    rest: str
    out_bytes: int
    out_elems: int


@dataclass
class Comp:
    name: str
    ops: list = field(default_factory=list)
    index: dict = field(default_factory=dict)     # value name -> Op


def parse_module(text: str) -> tuple[dict, str | None]:
    comps: dict[str, Comp] = {}
    cur: Comp | None = None
    entry = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR.match(line)
            if m:
                cur = Comp(m.group(2))
                if m.group(1):
                    entry = m.group(2)
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, shape_txt, opcode, rest = m.groups()
        b, e = _shape_bytes_elems(shape_txt)
        op = Op(name, opcode, shape_txt, rest, b, e)
        cur.index[name] = op
        cur.ops.append(op)
    return comps, entry


def _operand_names(rest: str):
    """Operand value names: everything before the closing paren of args."""
    depth, out, cur_tok = 1, [], None
    # simple scan: take %names until parens balance to 0
    i = 0
    while i < len(rest) and depth > 0:
        ch = rest[i]
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
        elif ch == "%":
            j = i + 1
            while j < len(rest) and (rest[j].isalnum() or rest[j] in "._-"):
                j += 1
            out.append(rest[i + 1: j])
            i = j - 1
        i += 1
    return out


def _dot_flops(op: Op, comp: Comp) -> float:
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    operands = _operand_names(op.rest)
    contract = 1
    if mc and operands:
        cdims = [int(d) for d in mc.group(1).split(",") if d]
        lhs = comp.index.get(operands[0])
        if lhs is not None:
            parts = _dims(lhs.shape_txt)
            if parts:
                shape = parts[0][1]
                for d in cdims:
                    if d < len(shape):
                        contract *= shape[d]
    return 2.0 * op.out_elems * max(contract, 1)


def _called_names(rest: str):
    out = []
    for key in ("calls", "body", "condition", "branch_computations",
                "to_apply"):
        for m in re.finditer(key + r"=(\{[^}]*\}|%[\w.\-]+)", rest):
            out.extend(re.findall(r"%([\w.\-]+)", m.group(1)))
    return out


def _trip_count(op: Op, comps) -> int:
    m = _TRIP_RE.search(op.rest)
    if m:
        return int(m.group(1))
    mc = re.search(r"condition=%([\w.\-]+)", op.rest)
    if mc and mc.group(1) in comps:
        best = 1
        for o in comps[mc.group(1)].ops:
            m2 = re.search(r"constant\((\d+)\)", o.opcode + "(" + o.rest)
            if m2:
                v = int(m2.group(1))
                if 1 < v < 10_000_000:
                    best = max(best, v)
        return best
    return 1


def analyze(text: str) -> dict:
    comps, entry = parse_module(text)
    fusion_targets = set()
    for c in comps.values():
        for op in c.ops:
            if op.opcode == "fusion":
                fusion_targets.update(_called_names(op.rest))

    memo: dict[str, dict] = {}

    def fused_flops(name: str, seen=None) -> float:
        """dots inside a fusion computation (rare but possible via calls)."""
        seen = seen or set()
        if name in seen or name not in comps:
            return 0.0
        seen.add(name)
        c = comps[name]
        total = 0.0
        for op in c.ops:
            if op.opcode == "dot":
                total += _dot_flops(op, c)
            elif op.opcode == "fusion" or op.opcode == "call":
                for n in _called_names(op.rest):
                    total += fused_flops(n, seen)
        return total

    def visit(name: str) -> dict:
        if name in memo:
            return memo[name]
        stats = {"flops": 0.0, "hbm_bytes": 0.0,
                 **{k: 0.0 for k in COLLECTIVES},
                 "counts": defaultdict(float)}
        memo[name] = stats
        c = comps.get(name)
        if c is None:
            return stats
        for op in c.ops:
            oc = op.opcode
            base = oc.replace("-start", "")
            if base in COLLECTIVES and not oc.endswith("-done"):
                factor = 2.0 if base == "all-reduce" else 1.0
                stats[base] += op.out_bytes * factor
                stats["counts"][base] += 1
            if oc in _KERNEL_OPS:
                opnames = _operand_names(op.rest)
                in_bytes = sum(c.index[n].out_bytes for n in opnames
                               if n in c.index)
                stats["hbm_bytes"] += op.out_bytes + in_bytes
            if oc == "dot":
                stats["flops"] += _dot_flops(op, c)
            elif oc == "fusion":
                for n in _called_names(op.rest):
                    stats["flops"] += fused_flops(n)
            elif oc == "while":
                trips = _trip_count(op, comps)
                mb = re.search(r"body=%([\w.\-]+)", op.rest)
                if mb:
                    sub = visit(mb.group(1))
                    for k in ("flops", "hbm_bytes", *COLLECTIVES):
                        stats[k] += sub[k] * trips
                    for k, v in sub["counts"].items():
                        stats["counts"][k] += v * trips
            elif oc in ("call", "conditional", "async-start", "custom-call"):
                for n in _called_names(op.rest):
                    if n in fusion_targets:
                        continue
                    sub = visit(n)
                    for k in ("flops", "hbm_bytes", *COLLECTIVES):
                        stats[k] += sub[k]
                    for k, v in sub["counts"].items():
                        stats["counts"][k] += v
        return stats

    if entry is None:
        entry = max(comps, key=lambda n: len(comps[n].ops)) if comps else ""
    out = dict(visit(entry))
    out["collective_total"] = sum(out[k] for k in COLLECTIVES)
    out["counts"] = dict(out["counts"])
    return out
