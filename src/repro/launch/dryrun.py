import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (architecture x input-shape) on
# the production meshes, print memory/cost analysis, extract roofline terms.
#
# The two lines above MUST stay the first statements in this file — jax locks
# the device count on first init, and the dry-run (and only the dry-run)
# needs 512 placeholder host devices.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3_14b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out f.json]

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
from repro.configs.base import InputShape, ModelConfig
from repro.core import embedding_ps as PS
from repro.core.collection import EmbeddingCollection
from repro.core.hybrid import PersiaTrainer, TrainMode
from repro.launch import input_specs as IS
from repro.launch.mesh import (make_production_mesh, mesh_all_shards,
                               mesh_model_shards)
from repro.launch import hlo_cost
from repro.models import transformer as T
from repro.optim.optimizers import OptConfig
from repro.sharding import partition as PART
from repro.sharding.partition import to_shardings
from repro.core.adapters import lm_adapter

SDS = jax.ShapeDtypeStruct
COMPUTE_DTYPE = jnp.bfloat16

# (arch, shape) pairs that are skipped, with the DESIGN.md rationale.
SKIPS = {
    ("whisper_medium", "long_500k"):
        "enc-dec with learned absolute decoder positions (64k table); "
        "500k-token decode is architecturally out of range for the family",
}

# dense/full-attention archs run long_500k only via the sliding-window
# variant (window 4096) — recorded as 'variant' in the result row.
FULL_ATTN_ARCHS = {"qwen3_14b", "phi3_mini_3_8b", "deepseek_coder_33b",
                   "granite_3_2b", "llama_3_2_vision_90b",
                   "deepseek_v2_lite_16b", "deepseek_v2_236b"}


def arch_shape_plan(arch: str, shape_name: str):
    """Returns (run: bool, cfg_transform, note)."""
    if (arch, shape_name) in SKIPS:
        return False, None, SKIPS[(arch, shape_name)]
    if shape_name == "long_500k" and arch in FULL_ATTN_ARCHS:
        return True, lambda c: c.replace(sliding_window=4096), \
            "sliding-window 4096 variant"
    return True, lambda c: c, ""


# ---------------------------------------------------------------------------
# Case builders: (fn, args, in_shardings, donate) ready for jit().lower()
# ---------------------------------------------------------------------------

def _abstract(fn, *args, **kw):
    return jax.eval_shape(fn, *args, **kw)


def build_train_case(cfg: ModelConfig, shape: InputShape, mesh):
    adapter = lm_adapter(cfg, dtype=COMPUTE_DTYPE)
    mode = TrainMode("hybrid", cfg.emb_staleness, 0)
    trainer = PersiaTrainer(adapter, mode, OptConfig(kind="adam", lr=3e-4))
    batch = IS.train_inputs(cfg, shape, COMPUTE_DTYPE)
    n_model = mesh_model_shards(mesh)

    state_shape = _abstract(
        lambda key: trainer.init(key, batch, emb_shards=n_model),
        jax.random.PRNGKey(0))

    state_specs = PART.train_state_specs(state_shape, trainer.collection)
    state_sh = to_shardings(mesh, state_specs, state_shape)
    batch_sh = to_shardings(mesh, _batch_specs(batch, mesh))
    return trainer.train_step, (state_shape, batch), \
        (state_sh, batch_sh), (0,)


def _batch_specs(batch, mesh):
    from jax.sharding import PartitionSpec as P
    nb = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            nb *= mesh.shape[a]

    def leaf(x):
        if x.shape and x.shape[0] % nb == 0:
            return P(("pod", "data"), *([None] * (x.ndim - 1)))
        return P(*([None] * x.ndim))

    return jax.tree.map(leaf, batch)


def _serve_params(cfg: ModelConfig, mesh):
    n_model = mesh_model_shards(mesh)
    spec = PS.EmbeddingSpec(rows=cfg.vocab_size, dim=cfg.d_model,
                            mode="model", dtype=COMPUTE_DTYPE)
    coll = EmbeddingCollection.single("vocab", spec)
    emb = {"vocab": {"table": SDS((spec.padded_rows(n_model), cfg.d_model),
                                  COMPUTE_DTYPE)}}
    dense = _abstract(lambda k: T.init_dense(cfg, k, COMPUTE_DTYPE),
                      jax.random.PRNGKey(0))
    params = {"emb": emb, "dense": dense}
    specs = {"emb": PART.collection_state_specs(emb, coll),
             "dense": PART.dense_param_specs(dense)}
    return params, specs, coll


def build_prefill_case(cfg: ModelConfig, shape: InputShape, mesh):
    params, pspecs, coll = _serve_params(cfg, mesh)
    batch = IS.prefill_inputs(cfg, shape, COMPUTE_DTYPE)

    def prefill_fn(params, batch):
        acts = coll.lookup(params["emb"],
                           {"vocab": batch["tokens"]})["vocab"]
        return T.prefill(cfg, params["dense"], acts,
                         memory=batch.get("memory"))

    params_sh = to_shardings(mesh, pspecs, params)
    batch_sh = to_shardings(mesh, _batch_specs(batch, mesh))
    return prefill_fn, (params, batch), (params_sh, batch_sh), ()


def build_decode_case(cfg: ModelConfig, shape: InputShape, mesh):
    params, pspecs, coll = _serve_params(cfg, mesh)
    batch = IS.decode_inputs(cfg, shape)
    B, S = shape.global_batch, shape.seq_len
    mlen = IS.memory_len(cfg)

    caches = _abstract(
        lambda: T.cache_init(cfg, B, S, COMPUTE_DTYPE, memory_len=mlen))

    def decode_fn(params, caches, batch):
        acts = coll.lookup(params["emb"],
                           {"vocab": batch["tokens"]})["vocab"]
        return T.decode_step(cfg, params["dense"], acts, caches)

    params_sh = to_shardings(mesh, pspecs, params)
    cache_sh = to_shardings(mesh, _cache_specs_guarded(caches, cfg, mesh))
    batch_sh = to_shardings(mesh, _batch_specs(batch, mesh))
    return decode_fn, (params, caches, batch), \
        (params_sh, cache_sh, batch_sh), (1,)


def _cache_specs_guarded(caches, cfg, mesh):
    """cache_specs + divisibility guards against this mesh."""
    from jax.sharding import PartitionSpec as P
    raw = PART.cache_specs(caches, cfg)
    nb = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            nb *= mesh.shape[a]
    nm = mesh_model_shards(mesh)

    def fix(spec, leaf):
        parts = list(spec)
        # pad spec to ndim
        while len(parts) < leaf.ndim:
            parts.append(None)
        for i, p in enumerate(parts):
            if p is None:
                continue
            size = leaf.shape[i]
            n = nb if p == PART.BATCH or p == ("pod", "data") else None
            if p == "model":
                n = nm
            if isinstance(p, tuple):
                n = nb
            if n is not None and size % n != 0:
                parts[i] = None
        return P(*parts)

    return jax.tree.map(fix, raw, caches,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# HLO collective parsing + roofline
# ---------------------------------------------------------------------------

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8\w*|s64|s32|s16|s8|u64|u32|u16|u8|pred)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
                "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1}
for _k in list(_DTYPE_BYTES):
    if _k.startswith("f8"):
        _DTYPE_BYTES[_k] = 1


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes moved per collective type (ring-model factors)."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        shape_txt, op = m.group(1), m.group(2)
        if op.endswith("-done"):
            continue
        size = _shape_bytes(shape_txt)
        # ring factors (n-1)/n ~ 1; all-reduce moves ~2x
        factor = 2.0 if op == "all-reduce" else 1.0
        out[op] += int(size * factor)
        counts[op] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = counts
    return out


PEAK_FLOPS = 197e12          # bf16 / chip (v5e)
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link (use 1 link as conservative)


def model_flops(cfg: ModelConfig, shape: InputShape) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); decode D=B tokens."""
    n_active = active_params(cfg)
    if shape.kind == "training":
        toks = shape.global_batch * shape.seq_len
        return 6.0 * n_active * toks
    if shape.kind == "prefill":
        toks = shape.global_batch * shape.seq_len
        return 2.0 * n_active * toks
    return 2.0 * n_active * shape.global_batch


def active_params(cfg: ModelConfig) -> float:
    """Forward-activated parameter count (MoE: top-k + shared only)."""
    if cfg.arch_type == "recsys":
        n, dims = 0, (cfg.n_id_fields * cfg.emb_dim + cfg.n_dense_features,) \
            + tuple(cfg.mlp_dims) + (cfg.n_tasks,)
        for i in range(len(dims) - 1):
            n += dims[i] * dims[i + 1]
        return float(n)
    d = cfg.d_model
    total = cfg.vocab_size * d * 2          # embed + head
    for blk in cfg.prologue + cfg.pattern * cfg.pattern_repeats:
        if blk.mixer == "gqa" or blk.mixer == "cross_attn":
            total += d * cfg.n_heads * cfg.head_dim * 2
            total += d * cfg.n_kv_heads * cfg.head_dim * 2
        elif blk.mixer == "mla":
            r, dr = cfg.kv_lora_rank, cfg.rope_head_dim
            H, dn, dv = cfg.n_heads, cfg.head_dim, cfg.v_head_dim
            if cfg.q_lora_rank:
                total += d * cfg.q_lora_rank + cfg.q_lora_rank * H * (dn + dr)
            else:
                total += d * H * (dn + dr)
            total += d * (r + dr) + r * H * (dn + dv) + H * dv * d
        elif blk.mixer == "mamba2":
            d_inner = cfg.ssm_expand * d
            Hh = d_inner // cfg.ssm_head_dim
            total += d * (2 * d_inner + 2 * cfg.ssm_state + Hh)
            total += d_inner * d
        if blk.cross:
            total += d * cfg.n_heads * cfg.head_dim * 2
            total += d * cfg.n_kv_heads * cfg.head_dim * 2
        if blk.ffn == "dense":
            total += 3 * d * cfg.d_ff
        elif blk.ffn == "moe":
            f = cfg.moe_d_ff or cfg.d_ff
            total += 3 * d * f * (cfg.moe_top_k + cfg.n_shared_experts)
            total += d * cfg.n_experts     # router
    if cfg.is_encdec:
        total += active_params(cfg.encoder.replace(vocab_size=0)) \
            - 0 * 2  # encoder params (vocab-free)
    return float(total)


def roofline(stats: dict, cfg, shape, n_chips: int) -> dict:
    flops_dev = stats["flops_per_device"]
    bytes_dev = stats["hbm_bytes_per_device"]
    coll_dev = stats["collectives"]["total"]
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    dominant = max((t_compute, "compute"), (t_memory, "memory"),
                   (t_coll, "collective"))[1]
    mf = model_flops(cfg, shape)
    return {
        "compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "useful_flops_frac": mf / max(flops_dev * n_chips, 1.0),
    }


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------

def run_case(arch: str, shape_name: str, *, multi_pod: bool = False,
             verbose: bool = True) -> dict:
    t0 = time.time()
    shape = INPUT_SHAPES[shape_name]
    run, transform, note = arch_shape_plan(arch, shape_name)
    row = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16", "note": note}
    if not run:
        row["status"] = "skipped"
        return row
    cfg = transform(get_config(arch))
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh_all_shards(mesh)
    try:
        with jax.sharding.set_mesh(mesh):
            if shape.kind == "training":
                fn, args, shardings, donate = build_train_case(cfg, shape, mesh)
            elif shape.kind == "prefill":
                fn, args, shardings, donate = build_prefill_case(cfg, shape, mesh)
            else:
                fn, args, shardings, donate = build_decode_case(cfg, shape, mesh)
            jitted = jax.jit(fn, in_shardings=shardings,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
        walk = hlo_cost.analyze(hlo)
        coll = {k: walk[k] for k in hlo_cost.COLLECTIVES}
        coll["total"] = walk["collective_total"]
        coll["counts"] = walk["counts"]
        stats = {
            "flops_per_device": float(walk["flops"]),
            "hbm_bytes_per_device": float(walk["hbm_bytes"]),
            "xla_flops_static": float(cost.get("flops", 0.0)),
            "collectives": coll,
        }
        rl = roofline(stats, cfg, shape, n_chips)
        row.update({
            "status": "ok",
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "argument_bytes_per_device": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes_per_device": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes_per_device": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes_per_device": getattr(mem, "peak_memory_in_bytes", 0)
                or (getattr(mem, "argument_size_in_bytes", 0)
                    + getattr(mem, "temp_size_in_bytes", 0)),
            **stats, **rl,
        })
        if verbose:
            print(f"[{row['mesh']}] {arch} x {shape_name}: OK "
                  f"(lower {t_lower:.0f}s compile {t_compile:.0f}s) "
                  f"args {row['argument_bytes_per_device']/2**30:.2f}GiB "
                  f"temp {row['temp_bytes_per_device']/2**30:.2f}GiB "
                  f"dominant={rl['dominant']}")
    except Exception as e:  # noqa: BLE001 - report into the matrix
        row["status"] = "error"
        row["error"] = f"{type(e).__name__}: {e}"[:2000]
        if verbose:
            print(f"[{row['mesh']}] {arch} x {shape_name}: FAIL {row['error']}")
            traceback.print_exc()
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cases = []
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for mp in meshes:
        for a in archs:
            for s in shapes:
                cases.append(run_case(a, s, multi_pod=mp))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(cases, f, indent=1)
        print(f"wrote {args.out}")
    ok = sum(1 for c in cases if c["status"] == "ok")
    sk = sum(1 for c in cases if c["status"] == "skipped")
    err = sum(1 for c in cases if c["status"] == "error")
    print(f"== dry-run: {ok} ok / {sk} skipped / {err} failed "
          f"of {len(cases)}")
    return 0 if err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
