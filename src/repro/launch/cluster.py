"""Single-box multi-process PS cluster: spawn one trainer + k embedding-PS
processes, train over the RPC wire, and (optionally) kill a shard mid-run
to exercise the elastic recovery path end to end.

Usage::

    PYTHONPATH=src python -m repro.launch.cluster --steps 20 --ps 2
    PYTHONPATH=src python -m repro.launch.cluster --steps 20 --ps 3 \
        --kill-shard 1 --kill-at 8       # SIGKILL shard 1 before step 8

Each PS process binds port 0 and publishes its actual port through a
``--port-file`` (written atomically by the server once listening), so
parallel launches never race on ports. Every shard spools applied state
next to its port file; when a shard is killed, the trainer reshards its
rows from that spool onto the survivors and keeps stepping — the
membership events and any lost rows land in the end-of-run summary.
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import tempfile
import time

import jax
import jax.numpy as jnp

import repro
from repro.configs.base import ModelConfig
from repro.core import adapters
from repro.core.hybrid import PersiaTrainer, TrainMode
from repro.data.ctr import CTRDataset
from repro.launch.shards import apply_backend_choice
from repro.net.elastic import ElasticPSCluster, PSMember
from repro.optim.optimizers import OptConfig


def wait_for_port_file(port_file: str, proc: subprocess.Popen,
                       timeout: float = 30.0) -> int:
    """Poll for the server's atomically-written port file; fails fast if
    the process died before publishing."""
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if proc.poll() is not None:
            raise RuntimeError(
                f"ps_server exited with {proc.returncode} before "
                f"publishing {port_file}")
        try:
            with open(port_file) as f:
                text = f.read().strip()
            if text:
                return int(text)
        except (OSError, ValueError):
            pass
        time.sleep(0.05)
    raise TimeoutError(f"no port published at {port_file} "
                       f"within {timeout:.0f}s")


def spawn_ps(workdir: str, idx: int, host: str = "127.0.0.1",
             spool_every: int = 1, timeout: float = 30.0,
             reply_delay: float = 0.0) -> PSMember:
    """Launch one PS shard process; returns its member record (endpoint +
    spool dir + process handle). ``reply_delay`` injects a per-op reply
    latency server-side (benchmarks: a synthetic network RTT the
    pipelined transport should overlap, the blocking one pays per op)."""
    port_file = os.path.join(workdir, f"ps{idx}.port")
    spool_dir = os.path.join(workdir, f"ps{idx}.spool")
    log_path = os.path.join(workdir, f"ps{idx}.log")
    env = dict(os.environ)
    # repro may be a namespace package (__file__ is None): locate its
    # parent via __path__ so the child process can import it
    src_root = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    env["PYTHONPATH"] = src_root + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [sys.executable, "-m", "repro.net.ps_server",
           "--host", host, "--port", "0", "--port-file", port_file,
           "--spool-dir", spool_dir, "--spool-every", str(spool_every)]
    if reply_delay > 0:
        cmd += ["--reply-delay", str(reply_delay)]
    log = open(log_path, "w")
    proc = subprocess.Popen(cmd, env=env, stdout=log,
                            stderr=subprocess.STDOUT)
    port = wait_for_port_file(port_file, proc, timeout)
    return PSMember(host, port, spool_dir=spool_dir, proc=proc)


def small_ctr_trainer(mode: str = "hybrid", backend: str = "host_lru",
                      tau: int = 2, fields: int = 2,
                      rows_per_field: int = 64, dim: int = 8,
                      cache_rows: int = 48, seed: int = 0):
    """A small CTR trainer + batch stream (the tests' model, sized so a
    cluster run finishes in seconds on CPU)."""
    cfg = ModelConfig(name="cluster", arch_type="recsys",
                      n_id_fields=fields, ids_per_field=3,
                      emb_dim=dim, emb_rows=fields * rows_per_field,
                      n_dense_features=4, mlp_dims=(16,), n_tasks=1)
    ds = CTRDataset("cluster", n_rows=fields * rows_per_field,
                    n_fields=fields, ids_per_field=3, n_dense=4)
    coll = adapters.ctr_collection(cfg, lr=5e-2, field_rows=ds.field_rows())
    coll = apply_backend_choice(coll, backend, cache_rows)
    ad = adapters.recsys_adapter(cfg, field_rows=ds.field_rows(),
                                 collection=coll)
    tm = {"sync": TrainMode.sync(), "hybrid": TrainMode.hybrid(tau),
          "async": TrainMode.async_(tau, tau)}[mode]
    trainer = PersiaTrainer(ad, tm, OptConfig(kind="adam", lr=5e-3))
    return trainer, ds


def run_cluster(steps: int = 20, n_ps: int = 2, mode: str = "hybrid",
                backend: str = "host_lru", batch: int = 16,
                kill_shard: int | None = None, kill_at: int | None = None,
                lossy: bool | None = None, spool_every: int = 1,
                workdir: str | None = None, seed: int = 0,
                heartbeats: bool = True, pipelined: bool = True,
                put_window: int | None = None,
                reply_delay: float = 0.0) -> dict:
    """Spawn the cluster, train ``steps`` steps, optionally SIGKILL one
    shard mid-run, and return a summary (steps/s, loss, membership
    events, lost rows). ``pipelined=False`` selects the blocking
    per-op-round-trip wire baseline; ``put_window`` overrides the
    outstanding-ack window (default: 1 for sync, min(tau, 8) for
    hybrid); ``reply_delay`` injects per-op reply latency PS-side."""
    workdir = workdir or tempfile.mkdtemp(prefix="ps_cluster_")
    trainer, ds = small_ctr_trainer(mode=mode, backend=backend, seed=seed)
    members, cluster = [], None
    try:
        members = [spawn_ps(workdir, i, spool_every=spool_every,
                            reply_delay=reply_delay)
                   for i in range(n_ps)]
        cluster = ElasticPSCluster(trainer, members)
        cluster.connect(lossy=lossy, pipelined=pipelined,
                        put_window=put_window)
        if heartbeats:
            cluster.start_heartbeats(interval=0.3, miss_threshold=2)
        it = ds.sampler(batch, seed=seed)
        batches = ({k: jnp.asarray(v) for k, v in b.items()}
                   for b in iter(it.__next__, None))
        first = next(batches)
        state = trainer.init(jax.random.PRNGKey(seed), first)
        metrics, t0 = {}, time.monotonic()
        for t in range(steps):
            if kill_shard is not None and t == (kill_at or steps // 2):
                proc = cluster.members[kill_shard].proc
                if proc is not None:
                    proc.kill()
                    proc.wait()
            state, metrics = cluster.step(state, first if t == 0
                                          else next(batches))
        jax.block_until_ready(state.dense)
        dt = time.monotonic() - t0
        return {
            "steps": steps,
            "steps_per_s": steps / max(dt, 1e-9),
            "loss": float(metrics.get("loss", float("nan"))),
            "members": len(cluster.members),
            "events": list(cluster.events)
            + ([] if cluster.monitor is None
               else list(cluster.monitor.events)),
            "lost_rows": {k: v for e in cluster.events
                          if e["kind"] == "reshard"
                          for k, v in e["lost_rows"].items()},
            "workdir": workdir,
        }
    finally:
        if cluster is not None:
            cluster.close()
        for m in members:
            if m.proc is not None and m.proc.poll() is None:
                m.proc.kill()
                m.proc.wait()


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="one-box multi-process embedding-PS training run")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--ps", type=int, default=2,
                    help="number of PS shard processes")
    ap.add_argument("--mode", default="hybrid",
                    choices=["sync", "hybrid", "async"])
    ap.add_argument("--backend", default="host_lru",
                    choices=["dense", "host_lru"])
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--kill-shard", type=int, default=None,
                    help="SIGKILL this shard index mid-run (fault drill)")
    ap.add_argument("--kill-at", type=int, default=None,
                    help="step before which the kill fires (default mid)")
    ap.add_argument("--lossy", action="store_true", default=None,
                    help="blockscale-fp16 wire payloads")
    ap.add_argument("--spool-every", type=int, default=1)
    ap.add_argument("--workdir", default=None)
    ap.add_argument("--transport", default="pipelined",
                    choices=["pipelined", "blocking"],
                    help="wire path: coalesced async (default) or the "
                         "per-op synchronous-round-trip baseline")
    ap.add_argument("--put-window", type=int, default=None,
                    help="outstanding-ack window per table-shard "
                         "(default: 1 sync, min(tau, 8) hybrid)")
    ap.add_argument("--reply-delay", type=float, default=0.0,
                    help="server-side per-op reply latency in seconds "
                         "(synthetic network RTT)")
    args = ap.parse_args(argv)
    res = run_cluster(steps=args.steps, n_ps=args.ps, mode=args.mode,
                      backend=args.backend, batch=args.batch,
                      kill_shard=args.kill_shard, kill_at=args.kill_at,
                      lossy=args.lossy, spool_every=args.spool_every,
                      workdir=args.workdir,
                      pipelined=args.transport == "pipelined",
                      put_window=args.put_window,
                      reply_delay=args.reply_delay)
    print(f"cluster: {res['steps']} steps @ {res['steps_per_s']:.2f} "
          f"steps/s, final loss {res['loss']:.4f}, "
          f"{res['members']} PS members at exit")
    for e in res["events"]:
        print(f"  event: {e}")
    if res["lost_rows"]:
        print(f"  lost rows on reshard: {res['lost_rows']}")
    return res


if __name__ == "__main__":
    main()
