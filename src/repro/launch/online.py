"""Online-learning driver: trainer + serving service over ONE backend.

The paper's headline deployment (§1, §4): the recommender serves live
traffic while the trainer folds the resulting click feedback straight
back into the same embedding state — serve -> train -> serve, with the
hybrid algorithm's staleness bound as the consistency contract between
the two sides. This driver runs that loop on one box:

* a trainer thread stepping the CTR model, preferring fresh feedback
  batches off the :class:`~repro.serving.feedback.FeedbackQueue` and
  falling back to the offline sampler when serving hasn't produced a
  full batch yet (cold start);
* a :class:`~repro.serving.service.ServingService` micro-batching
  concurrent client requests against the live ``StateCell`` snapshot;
* closed-loop client threads replaying Zipf traffic, labeling each
  served impression through the planted click model, and feeding it back.

With ``--ps k`` the embedding tables live in ``k`` PS processes (the
multi-process cluster of launch/cluster.py) and BOTH sides go over the
RPC wire — the serve path reads through the same atomic ``read_rows``
op the trainer's backend exposes in-process.

Usage::

    PYTHONPATH=src python -m repro.launch.online --steps 50 --clients 2
    PYTHONPATH=src python -m repro.launch.online --steps 30 --ps 2 \
        --backend dense --mode sync
"""
from __future__ import annotations

import argparse
import tempfile
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.cluster import small_ctr_trainer, spawn_ps
from repro.serving import (ClickModel, FeedbackQueue, ServingConfig,
                           ServingService, StateCell, TrafficGenerator,
                           TrafficModel)


def logloss(p: np.ndarray, y: np.ndarray) -> float:
    p = np.clip(np.asarray(p, np.float64), 1e-7, 1 - 1e-7)
    y = np.asarray(y, np.float64)
    return float(np.mean(-(y * np.log(p) + (1 - y) * np.log(1 - p))))


def run_online(steps: int = 50, mode: str = "hybrid",
               backend: str = "host_lru", tau: int = 2, batch: int = 16,
               max_batch: int = 8, max_wait_ms: float = 2.0,
               n_clients: int = 2, requests_per_client: int = 64,
               qps: float = 0.0, n_users: int = 10_000, n_ps: int = 0,
               lossy: bool | None = None, seed: int = 0,
               workdir: str | None = None) -> dict:
    """Run the closed serve->train->serve loop; returns a summary with
    trainer throughput, serving latency percentiles, the staleness
    gauges, and the served-traffic logloss trend (first half vs second
    half of impressions — online learning should bend it down)."""
    trainer, ds = small_ctr_trainer(mode=mode, backend=backend, tau=tau,
                                    seed=seed)
    members = []
    try:
        if n_ps > 0:
            workdir = workdir or tempfile.mkdtemp(prefix="online_ps_")
            from repro.net.remote import connect_remote_backends
            members = [spawn_ps(workdir, i) for i in range(n_ps)]
            connect_remote_backends(
                trainer, [(m.host, m.port) for m in members], lossy=lossy)

        sampler = ds.sampler(batch, seed=seed)
        first = {k: jnp.asarray(v) for k, v in next(sampler).items()}
        state = trainer.init(jax.random.PRNGKey(seed), first)
        cell = StateCell(state, 0)

        traffic = TrafficModel.for_dataset(ds, n_users=n_users)
        click = ClickModel.for_dataset(ds)
        feedback = FeedbackQueue(batch_size=batch)
        svc = ServingService(trainer, cell,
                             ServingConfig(max_batch=max_batch,
                                           max_wait_ms=max_wait_ms))

        train_log = {"losses": [], "feedback_batches": 0,
                     "fallback_batches": 0}

        def trainer_loop():
            s = state
            for t in range(steps):
                fb = feedback.next_batch(timeout=0.05)
                if fb is None:
                    fb = next(sampler)
                    train_log["fallback_batches"] += 1
                else:
                    train_log["feedback_batches"] += 1
                b = {k: jnp.asarray(v) for k, v in fb.items()}
                with cell.lock:
                    s, m = trainer.step(s, b)
                    cell.publish(s, t + 1)
                train_log["losses"].append(float(m.get("loss", np.nan)))

        served = []                       # (impression idx, pred, label)
        served_lock = threading.Lock()

        def client_loop(cid: int):
            def serve_one(req):
                pred = svc.predict(req)
                label = click.click(req)
                feedback.put(req, label)
                with served_lock:
                    served.append((float(pred[0]), float(label[0])))

            if qps > 0:
                gen = TrafficGenerator(traffic, qps=qps / max(n_clients, 1),
                                       seed=seed + cid)
                gen.replay(requests_per_client, serve_one)
            else:
                # closed loop: serve the full quota as fast as replies
                # come back — the quota, not the trainer's finish line,
                # bounds the run, so `served` counts are deterministic
                # however fast the training side moves
                for _, req in traffic.requests(requests_per_client,
                                               seed=seed + cid):
                    serve_one(req)

        svc.start()
        t0 = time.monotonic()
        threads = [threading.Thread(target=trainer_loop, name="trainer")]
        threads += [threading.Thread(target=client_loop, args=(c,),
                                     name=f"client{c}")
                    for c in range(n_clients)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        dt = time.monotonic() - t0
        svc.stop()

        half = len(served) // 2
        p = np.asarray([s[0] for s in served], np.float64)
        y = np.asarray([s[1] for s in served], np.float64)
        summary = {
            "steps": steps,
            "steps_per_s": steps / max(dt, 1e-9),
            "loss_first": float(np.nanmean(train_log["losses"][: max(
                steps // 2, 1)])),
            "loss_last": float(np.nanmean(train_log["losses"][steps // 2:])),
            "feedback_batches": train_log["feedback_batches"],
            "fallback_batches": train_log["fallback_batches"],
            "served": len(served),
            "served_logloss_first": logloss(p[:half], y[:half])
            if half else float("nan"),
            "served_logloss_last": logloss(p[half:], y[half:])
            if half else float("nan"),
            "feedback": feedback.stats,
            "serving": svc.metrics(),
        }
        return summary
    finally:
        for m in members:
            if m.proc is not None and m.proc.poll() is None:
                m.proc.kill()
                m.proc.wait()


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="closed-loop online learning: trainer + serving over "
                    "one embedding backend")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--mode", default="hybrid",
                    choices=["sync", "hybrid", "async"])
    ap.add_argument("--backend", default="host_lru",
                    choices=["dense", "host_lru"])
    ap.add_argument("--tau", type=int, default=2)
    ap.add_argument("--batch", type=int, default=16,
                    help="training batch size (feedback batches match)")
    ap.add_argument("--max-batch", type=int, default=8,
                    help="serving micro-batch flush size")
    ap.add_argument("--max-wait-ms", type=float, default=2.0,
                    help="serving micro-batch latency budget")
    ap.add_argument("--clients", type=int, default=2)
    ap.add_argument("--requests", type=int, default=64,
                    help="requests per client thread")
    ap.add_argument("--qps", type=float, default=0.0,
                    help="open-loop target QPS across clients "
                         "(0 = closed loop)")
    ap.add_argument("--users", type=int, default=10_000)
    ap.add_argument("--ps", type=int, default=0,
                    help="embedding-PS processes (0 = in-process backend)")
    ap.add_argument("--lossy", action="store_true", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    res = run_online(steps=args.steps, mode=args.mode, backend=args.backend,
                     tau=args.tau, batch=args.batch,
                     max_batch=args.max_batch, max_wait_ms=args.max_wait_ms,
                     n_clients=args.clients,
                     requests_per_client=args.requests, qps=args.qps,
                     n_users=args.users, n_ps=args.ps, lossy=args.lossy,
                     seed=args.seed)
    sv = res["serving"]
    print(f"online: {res['steps']} steps @ {res['steps_per_s']:.2f} "
          f"steps/s, {res['served']} impressions served "
          f"({res['feedback_batches']} feedback / "
          f"{res['fallback_batches']} fallback batches)")
    print(f"  train loss {res['loss_first']:.4f} -> {res['loss_last']:.4f}")
    print(f"  served logloss {res['served_logloss_first']:.4f} -> "
          f"{res['served_logloss_last']:.4f}")
    print(f"  serving p50 {sv['serving/p50_ms']:.2f}ms "
          f"p99 {sv['serving/p99_ms']:.2f}ms qps {sv['serving/qps']:.1f}")
    stale = {k.split("/")[1]: v for k, v in sv.items()
             if k.endswith("/stale_steps")}
    print(f"  staleness gauges: {stale}")
    return res


if __name__ == "__main__":
    main()
