"""Training driver: runs the Persia hybrid trainer end-to-end on CPU-scale
configs (the production meshes are exercised by dryrun.py).

Usage:
  PYTHONPATH=src python -m repro.launch.train --task ctr --dataset taobao_ad \
      --mode hybrid --steps 300 --batch 512
  PYTHONPATH=src python -m repro.launch.train --task lm --steps 200 --batch 8
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import BlockCfg, ModelConfig
from repro.configs import recsys_configs as RC
from repro.core import adapters, embedding_ps as PS, hybrid
from repro.core.hybrid import TrainMode
from repro.checkpoint import CheckpointManager
from repro.data.ctr import CTR_BENCHMARKS, CTRDataset
from repro.data.lm import lm_batches
from repro.optim.optimizers import OptConfig, make_optimizer


def scaled_recsys_cfg(dataset: str, scale: float = 1.0) -> ModelConfig:
    ds = CTR_BENCHMARKS[dataset]
    return ModelConfig(
        name=f"{dataset}-dlrm", arch_type="recsys",
        n_id_fields=ds.n_fields, ids_per_field=ds.ids_per_field,
        emb_dim=32, emb_rows=ds.n_rows, n_dense_features=ds.n_dense,
        mlp_dims=(256, 128, 64), n_tasks=ds.n_tasks, emb_staleness=3)


def small_lm_cfg() -> ModelConfig:
    """~100M dense params (the end-to-end example scale)."""
    return ModelConfig(
        name="lm-100m", d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
        d_ff=2048, vocab_size=8192,
        pattern=(BlockCfg("gqa", "dense"),), pattern_repeats=20,
        emb_staleness=2)


def mode_from_name(name: str, tau: int) -> TrainMode:
    if name == "sync":
        return TrainMode.sync()
    if name == "hybrid":
        return TrainMode.hybrid(tau)
    if name == "async":
        return TrainMode.async_(tau, tau)
    raise ValueError(name)


def train_ctr(args):
    ds = CTR_BENCHMARKS[args.dataset]
    cfg = scaled_recsys_cfg(args.dataset)
    adapter = adapters.recsys_adapter(cfg, lr=args.emb_lr)
    opt_init, opt_update = make_optimizer(OptConfig(kind="adam", lr=args.lr))
    mode = mode_from_name(args.mode, args.tau)
    it = ds.sampler(args.batch)
    eval_it = ds.sampler(args.batch, seed=999)
    batch = {k: jnp.asarray(v) for k, v in next(it).items()}
    state, spec = hybrid.init_train_state(adapter, mode, opt_init,
                                          jax.random.PRNGKey(args.seed), batch)
    step_fn = jax.jit(hybrid.make_train_step(adapter, spec, mode, opt_update),
                      donate_argnums=(0,))
    mgr = CheckpointManager(args.ckpt_dir, every=args.ckpt_every) \
        if args.ckpt_dir else None

    history = []
    t0 = time.time()
    for step in range(args.steps):
        b = {k: jnp.asarray(v) for k, v in next(it).items()}
        state, metrics = step_fn(state, b)
        if (step + 1) % args.eval_every == 0:
            eb = {k: jnp.asarray(v) for k, v in next(eval_it).items()}
            acts = PS.lookup(state["emb"], spec, eb["ids"])
            preds = adapter.predict(state["dense"], acts, eb)
            a = adapters.auc(np.asarray(eb["labels"]), np.asarray(preds))
            dt = time.time() - t0
            thr = (step + 1) * args.batch / dt
            print(f"step {step+1:5d} loss {float(metrics['loss']):.4f} "
                  f"AUC {a:.4f} thr {thr:,.0f} samples/s")
            history.append({"step": step + 1, "time_s": dt,
                            "loss": float(metrics["loss"]), "auc": a,
                            "throughput": thr})
        if mgr:
            mgr.maybe_save(step + 1, state["dense"],
                           {"table": state["emb"]["table"]})
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"mode": args.mode, "dataset": args.dataset,
                       "history": history}, f, indent=1)
    return history


def train_lm(args):
    cfg = small_lm_cfg()
    adapter = adapters.lm_adapter(cfg, lr=args.emb_lr)
    opt_init, opt_update = make_optimizer(OptConfig(kind="adam", lr=args.lr))
    mode = mode_from_name(args.mode, args.tau)
    it = lm_batches(cfg.vocab_size, args.batch, args.seq_len)
    batch = {k: jnp.asarray(v) for k, v in next(it).items()}
    state, spec = hybrid.init_train_state(adapter, mode, opt_init,
                                          jax.random.PRNGKey(args.seed), batch)
    n_params = sum(x.size for x in jax.tree.leaves(state["dense"]))
    print(f"dense params: {n_params/1e6:.1f}M + emb "
          f"{state['emb']['table'].size/1e6:.1f}M")
    step_fn = jax.jit(hybrid.make_train_step(adapter, spec, mode, opt_update),
                      donate_argnums=(0,))
    history = []
    t0 = time.time()
    for step in range(args.steps):
        b = {k: jnp.asarray(v) for k, v in next(it).items()}
        state, metrics = step_fn(state, b)
        if (step + 1) % args.eval_every == 0:
            dt = time.time() - t0
            tok_s = (step + 1) * args.batch * args.seq_len / dt
            print(f"step {step+1:5d} loss {float(metrics['loss']):.4f} "
                  f"{tok_s:,.0f} tok/s")
            history.append({"step": step + 1, "time_s": dt,
                            "loss": float(metrics["loss"])})
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"mode": args.mode, "history": history}, f, indent=1)
    return history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", choices=["ctr", "lm"], default="ctr")
    ap.add_argument("--dataset", default="taobao_ad")
    ap.add_argument("--mode", choices=["sync", "hybrid", "async"],
                    default="hybrid")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=512)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--tau", type=int, default=3)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--emb-lr", type=float, default=5e-2)
    ap.add_argument("--eval-every", type=int, default=25)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.task == "ctr":
        train_ctr(args)
    else:
        train_lm(args)


if __name__ == "__main__":
    main()
