"""Training driver: runs the Persia hybrid trainer end-to-end on CPU-scale
configs (the production meshes are exercised by dryrun.py).

Usage:
  PYTHONPATH=src python -m repro.launch.train --task ctr --dataset taobao_ad \
      --mode hybrid --steps 300 --batch 512
  PYTHONPATH=src python -m repro.launch.train --task lm --steps 200 --batch 8
  PYTHONPATH=src python -m repro.launch.train --task ctr --pipeline decomposed \
      --ckpt-dir /tmp/ck --resume

Both tasks run through the PersiaTrainer facade: the CTR path trains one
embedding table per ID feature field (the multi-table EmbeddingCollection);
checkpoints carry the FULL train state — dense params, optimizer moments,
every PS table with its adagrad accumulator, and the staleness queues — so
``--resume`` continues bit-identically.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import BlockCfg, ModelConfig
from repro.core import adapters
from repro.core.hybrid import PersiaTrainer, TrainMode
from repro.checkpoint import CheckpointManager
from repro.data.ctr import CTR_BENCHMARKS
from repro.data.lm import lm_batches
from repro.optim.optimizers import OptConfig


def scaled_recsys_cfg(dataset: str, scale: float = 1.0) -> ModelConfig:
    ds = CTR_BENCHMARKS[dataset]
    return ModelConfig(
        name=f"{dataset}-dlrm", arch_type="recsys",
        n_id_fields=ds.n_fields, ids_per_field=ds.ids_per_field,
        emb_dim=32, emb_rows=ds.n_rows, n_dense_features=ds.n_dense,
        mlp_dims=(256, 128, 64), n_tasks=ds.n_tasks, emb_staleness=3)


def small_lm_cfg() -> ModelConfig:
    """~100M dense params (the end-to-end example scale)."""
    return ModelConfig(
        name="lm-100m", d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
        d_ff=2048, vocab_size=8192,
        pattern=(BlockCfg("gqa", "dense"),), pattern_repeats=20,
        emb_staleness=2)


def mode_from_name(name: str, tau: int) -> TrainMode:
    if name == "sync":
        return TrainMode.sync()
    if name == "hybrid":
        return TrainMode.hybrid(tau)
    if name == "async":
        return TrainMode.async_(tau, tau)
    raise ValueError(name)


def _step_fn(trainer: PersiaTrainer, pipeline: str):
    if pipeline == "decomposed":
        return trainer.decomposed_step
    return trainer.step


def _make_engine(trainer: PersiaTrainer, args):
    """--pipeline pipelined: the async five-stage engine (core/pipeline.py)
    carrying up to --max-inflight microbatches."""
    from repro.core.pipeline import PipelinedTrainer
    return PipelinedTrainer(trainer, max_inflight=args.max_inflight)


def _pipelined_span(engine, state, it, n):
    """Run n steps through the engine, pulling batches lazily from ``it``;
    returns (state, last-step metrics)."""
    stream = ({k: jnp.asarray(v) for k, v in next(it).items()}
              for _ in range(n))
    state, ms = engine.run(state, stream)
    return state, (ms[-1] if ms else {})


# the --emb-shards grammar is shared across launchers (train/serve/cluster);
# re-exported here because this was its original home
from repro.launch.shards import (  # noqa: E402,F401
    apply_backend_choice, default_cache_rows, parse_emb_shards)


def _ctr_collection_for(cfg, ds, args):
    """Per-field tables with the CLI-selected storage backend (dense PS,
    host-LRU out-of-core, or either behind the compressed wire) and
    per-table PS shard counts (--emb-shards routes through the sharded
    router of core/backend.py)."""
    coll = adapters.ctr_collection(cfg, lr=args.emb_lr,
                                   field_rows=ds.field_rows())
    coll = apply_backend_choice(
        coll, args.emb_backend,
        default_cache_rows(ds.rows_per_field, args.cache_rows))
    shards = parse_emb_shards(args.emb_shards)
    if shards != 1:
        coll = coll.with_shards(shards)
    return _apply_emb_tuning(coll, args)


def _apply_emb_tuning(coll, args):
    """--store-dtype / --backward-kernel spec overrides (both paper-hot-path
    knobs from kernels/fused_backward.py and the core/lru.py codec)."""
    if args.store_dtype != "fp32":
        coll = coll.with_store_dtype(args.store_dtype)
    if args.backward_kernel:
        coll = coll.with_backward_kernel(True)
    return coll


def train_ctr(args):
    ds = CTR_BENCHMARKS[args.dataset]
    cfg = scaled_recsys_cfg(args.dataset)
    adapter = adapters.recsys_adapter(
        cfg, lr=args.emb_lr, field_rows=ds.field_rows(),
        collection=_ctr_collection_for(cfg, ds, args))
    mode = mode_from_name(args.mode, args.tau)
    trainer = PersiaTrainer(adapter, mode,
                            OptConfig(kind="adam", lr=args.lr),
                            batch_dedup=False if args.no_batch_dedup
                            else None)
    it = ds.sampler(args.batch)
    eval_it = ds.sampler(args.batch, seed=999)
    batch = {k: jnp.asarray(v) for k, v in next(it).items()}
    start = 0
    mgr = CheckpointManager(args.ckpt_dir, every=args.ckpt_every) \
        if args.ckpt_dir else None
    if args.resume and not mgr:
        raise SystemExit("--resume requires --ckpt-dir")
    have_ckpt = mgr and os.path.isdir(args.ckpt_dir) and \
        any(d.startswith("step_") for d in os.listdir(args.ckpt_dir))
    if args.resume and not have_ckpt:
        print(f"--resume: no checkpoints under {args.ckpt_dir!r}, "
              "starting fresh")
    if args.resume and have_ckpt:
        state = trainer.restore(args.ckpt_dir)
        start = int(state.step)
        # fast-forward the deterministic streams to where the run stopped,
        # so resumed training sees the batches an uninterrupted run would
        for _ in range(start):
            next(it)
        for _ in range(start // args.eval_every):
            next(eval_it)
        print(f"resumed full state from step {start}")
    else:
        state = trainer.init(jax.random.PRNGKey(args.seed), batch)
    history = []
    t0 = time.time()
    if args.pipeline == "pipelined":
        # the async engine consumes whole eval_every-sized spans so the
        # five stages overlap across microbatches; eval/ckpt run at the
        # span boundaries on the settled state
        engine = _make_engine(trainer, args)
        step = start
        while step < args.steps:
            # spans stop at every eval AND checkpoint boundary, so
            # --ckpt-every keeps its granularity under the pipeline
            n = min(args.eval_every - step % args.eval_every,
                    args.steps - step)
            if mgr:
                n = min(n, args.ckpt_every - step % args.ckpt_every)
            state, metrics = _pipelined_span(engine, state, it, n)
            step += n
            if step % args.eval_every == 0:
                eb = {k: jnp.asarray(v) for k, v in next(eval_it).items()}
                preds = trainer.predict(state, eb)
                a = adapters.auc(np.asarray(eb["labels"]), np.asarray(preds))
                dt = time.time() - t0
                thr = (step - start) * args.batch / dt
                print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                      f"AUC {a:.4f} thr {thr:,.0f} samples/s")
                history.append({"step": step, "time_s": dt,
                                "loss": float(metrics["loss"]), "auc": a,
                                "throughput": thr})
            if mgr:
                mgr.maybe_save_state(step, trainer, state)
        if args.out:
            with open(args.out, "w") as f:
                json.dump({"mode": args.mode, "dataset": args.dataset,
                           "pipeline": args.pipeline, "history": history,
                           "pipeline_metrics": engine.pipeline_metrics()},
                          f, indent=1)
        return history
    step_fn = _step_fn(trainer, args.pipeline)
    for step in range(start, args.steps):
        b = {k: jnp.asarray(v) for k, v in next(it).items()}
        state, metrics = step_fn(state, b)
        if (step + 1) % args.eval_every == 0:
            eb = {k: jnp.asarray(v) for k, v in next(eval_it).items()}
            preds = trainer.predict(state, eb)
            a = adapters.auc(np.asarray(eb["labels"]), np.asarray(preds))
            dt = time.time() - t0
            thr = (step + 1 - start) * args.batch / dt
            print(f"step {step+1:5d} loss {float(metrics['loss']):.4f} "
                  f"AUC {a:.4f} thr {thr:,.0f} samples/s")
            history.append({"step": step + 1, "time_s": dt,
                            "loss": float(metrics["loss"]), "auc": a,
                            "throughput": thr})
        if mgr:
            mgr.maybe_save_state(step + 1, trainer, state)
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"mode": args.mode, "dataset": args.dataset,
                       "pipeline": args.pipeline, "history": history}, f,
                      indent=1)
    return history


def train_lm(args):
    import dataclasses
    cfg = small_lm_cfg()
    adapter = adapters.lm_adapter(cfg, lr=args.emb_lr)
    coll = apply_backend_choice(
        adapter.collection, args.emb_backend,
        default_cache_rows(cfg.vocab_size, args.cache_rows))
    shards = parse_emb_shards(args.emb_shards)
    if shards != 1:
        coll = coll.with_shards(shards)
    coll = _apply_emb_tuning(coll, args)
    if coll is not adapter.collection:
        adapter = dataclasses.replace(adapter, collection=coll)
    mode = mode_from_name(args.mode, args.tau)
    trainer = PersiaTrainer(adapter, mode,
                            OptConfig(kind="adam", lr=args.lr),
                            batch_dedup=False if args.no_batch_dedup
                            else None)
    it = lm_batches(cfg.vocab_size, args.batch, args.seq_len)
    batch = {k: jnp.asarray(v) for k, v in next(it).items()}
    state = trainer.init(jax.random.PRNGKey(args.seed), batch)
    n_params = sum(x.size for x in jax.tree.leaves(state.dense))
    vocab_spec = trainer.collection["vocab"]
    print(f"dense params: {n_params/1e6:.1f}M + emb "
          f"{vocab_spec.rows * vocab_spec.dim/1e6:.1f}M")
    if args.pipeline == "pipelined":
        engine = _make_engine(trainer, args)
        history = []
        t0 = time.time()
        step = 0
        while step < args.steps:
            n = min(args.eval_every - step % args.eval_every,
                    args.steps - step)
            state, metrics = _pipelined_span(engine, state, it, n)
            step += n
            if step % args.eval_every == 0:
                dt = time.time() - t0
                tok_s = step * args.batch * args.seq_len / dt
                print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                      f"{tok_s:,.0f} tok/s")
                history.append({"step": step, "time_s": dt,
                                "loss": float(metrics["loss"])})
        if args.out:
            with open(args.out, "w") as f:
                json.dump({"mode": args.mode, "history": history,
                           "pipeline_metrics": engine.pipeline_metrics()},
                          f, indent=1)
        return history
    step_fn = _step_fn(trainer, args.pipeline)
    history = []
    t0 = time.time()
    for step in range(args.steps):
        b = {k: jnp.asarray(v) for k, v in next(it).items()}
        state, metrics = step_fn(state, b)
        if (step + 1) % args.eval_every == 0:
            dt = time.time() - t0
            tok_s = (step + 1) * args.batch * args.seq_len / dt
            print(f"step {step+1:5d} loss {float(metrics['loss']):.4f} "
                  f"{tok_s:,.0f} tok/s")
            history.append({"step": step + 1, "time_s": dt,
                            "loss": float(metrics["loss"])})
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"mode": args.mode, "history": history}, f, indent=1)
    return history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", choices=["ctr", "lm"], default="ctr")
    ap.add_argument("--dataset", default="taobao_ad")
    ap.add_argument("--mode", choices=["sync", "hybrid", "async"],
                    default="hybrid")
    ap.add_argument("--pipeline",
                    choices=["fused", "decomposed", "pipelined"],
                    default="fused",
                    help="fused = one jitted program; decomposed = serial "
                         "get/dense/put dispatches; pipelined = the async "
                         "five-stage engine (core/pipeline.py)")
    ap.add_argument("--max-inflight", type=int, default=4,
                    help="pipelined engine: max microbatches in flight "
                         "(1 = bit-exact with --pipeline decomposed)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=512)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--tau", type=int, default=3)
    ap.add_argument("--emb-backend", default="dense",
                    choices=["dense", "host_lru", "host_lru+disk",
                             "dense+compressed", "host_lru+compressed",
                             "host_lru+disk+compressed"],
                    help="embedding storage backend (core/backend.py): "
                         "host_lru keeps tables host-side behind a device "
                         "hot-cache; +disk stacks the mmap tier under the "
                         "host store; +compressed adds the §4.2.3 wire")
    ap.add_argument("--cache-rows", type=int, default=0,
                    help="host_lru device-cache slots per table "
                         "(0 = rows_per_field/8, at least 1024)")
    ap.add_argument("--store-dtype", default="fp32",
                    choices=["fp32", "blockscale16"],
                    help="host/disk cold-row format (core/lru.py): "
                         "blockscale16 halves host bytes via the §4.2.3 "
                         "blockscale fp16 codec (decompress on fault-in, "
                         "compress on write-back)")
    ap.add_argument("--backward-kernel", action="store_true",
                    help="use the fused Pallas embedding backward "
                         "(kernels/fused_backward.py) instead of the "
                         "jitted jnp oracle — one pass for segment-sum + "
                         "adagrad + queue payload")
    ap.add_argument("--tuned-host", action="store_true",
                    help="apply the tuned host profile (launch/hostenv.py): "
                         "tcmalloc LD_PRELOAD (re-execs once; graceful "
                         "no-op when absent) + XLA/TF host env tuning")
    ap.add_argument("--no-batch-dedup", action="store_true",
                    help="disable worker-side batch dedup (core/dedup.py): "
                         "run the pre-dedup occurrence-width lookup/queue/"
                         "put path. Default is ON — one row per unique id "
                         "per batch, staleness queues sized at the dedup "
                         "cap, dedup/<table>/* step metrics")
    ap.add_argument("--emb-shards", default="1",
                    help="embedding-PS shards per table: an int for every "
                         "table, or 'table=k,table=k' pairs. k > 1 routes "
                         "through the sharded router (core/backend.py): "
                         "hash id->shard routing, per-shard stores/locks, "
                         "concurrent fault-in, reshardable checkpoints")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--emb-lr", type=float, default=5e-2)
    ap.add_argument("--eval-every", type=int, default=25)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.tuned_host:
        from repro.launch.hostenv import apply_tuned_host
        status = apply_tuned_host()      # re-execs once when tcmalloc found
        if status == "no-tcmalloc":
            print("--tuned-host: libtcmalloc not installed; "
                  "applying env-only profile")
    if args.task == "ctr":
        train_ctr(args)
    else:
        if args.resume:
            raise SystemExit("--resume is only supported for --task ctr")
        train_lm(args)


if __name__ == "__main__":
    main()
