"""Production mesh builders.

Single pod: (data=16, model=16) — 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) — 512 chips; the 'pod' axis only
carries batch parallelism (gradient psum crosses DCN once per step), while
FSDP/TP stay intra-pod.

Functions, not module-level constants: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    auto = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=auto)


def make_host_mesh():
    """Degenerate 1x1 mesh for CPU smoke testing of the sharded code paths."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def mesh_batch_shards(mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n


def mesh_model_shards(mesh) -> int:
    return mesh.shape.get("model", 1)


def mesh_all_shards(mesh) -> int:
    n = 1
    for a in mesh.axis_names:
        n *= mesh.shape[a]
    return n
