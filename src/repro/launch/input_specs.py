"""ShapeDtypeStruct stand-ins for every model input — weak-type-correct,
shardable, no device allocation. The dry-run lowers against these."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig

SDS = jax.ShapeDtypeStruct


def train_inputs(cfg: ModelConfig, shape: InputShape,
                 compute_dtype=jnp.bfloat16) -> dict:
    B, S = shape.global_batch, shape.seq_len
    if cfg.arch_type == "recsys":
        b = {"ids": SDS((B, cfg.n_id_fields, cfg.ids_per_field), jnp.int32),
             "labels": SDS((B, cfg.n_tasks), jnp.float32)}
        if cfg.n_dense_features:
            b["dense"] = SDS((B, cfg.n_dense_features), jnp.float32)
        return b
    b = {"tokens": SDS((B, S), jnp.int32),
         "targets": SDS((B, S), jnp.int32),
         "mask": SDS((B, S), jnp.float32)}
    if cfg.is_encdec:
        e = cfg.encoder
        b["memory"] = SDS((B, e.n_memory_tokens, e.d_memory), compute_dtype)
    elif cfg.n_memory_tokens:
        b["memory"] = SDS((B, cfg.n_memory_tokens, cfg.d_memory),
                          compute_dtype)
    return b


def prefill_inputs(cfg: ModelConfig, shape: InputShape,
                   compute_dtype=jnp.bfloat16) -> dict:
    B, S = shape.global_batch, shape.seq_len
    b = {"tokens": SDS((B, S), jnp.int32)}
    if cfg.is_encdec:
        e = cfg.encoder
        b["memory"] = SDS((B, e.n_memory_tokens, e.d_memory), compute_dtype)
    elif cfg.n_memory_tokens:
        b["memory"] = SDS((B, cfg.n_memory_tokens, cfg.d_memory),
                          compute_dtype)
    return b


def decode_inputs(cfg: ModelConfig, shape: InputShape) -> dict:
    B = shape.global_batch
    return {"tokens": SDS((B, 1), jnp.int32)}


def memory_len(cfg: ModelConfig) -> int:
    if cfg.is_encdec:
        return cfg.encoder.n_memory_tokens
    return cfg.n_memory_tokens
