"""Batched serving driver: prefill a batch of prompts, then decode tokens
step-by-step against the per-layer caches. CPU-scale models here; the
production decode paths are exercised (and sharded) by dryrun.py.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch granite_3_2b --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.backend import create_backend
from repro.launch.shards import build_embedding_spec
from repro.models import transformer as T

VOCAB_TABLE = "vocab"      # serve's sole table name in --emb-shards pairs


def serve(cfg, batch=4, prompt_len=32, gen=16, seed=0, temperature=0.0,
          emb_backend="dense", cache_rows=0, emb_shards=1):
    key = jax.random.PRNGKey(seed)
    dense = T.init_dense(cfg, key)
    spec = build_embedding_spec(cfg.vocab_size, cfg.d_model,
                                backend=emb_backend, cache_rows=cache_rows,
                                emb_shards=emb_shards, table=VOCAB_TABLE)
    backend = create_backend(spec)
    # same key fan-out as EmbeddingCollection.init (one table -> keys[0])
    emb = backend.init(jax.random.split(key, 1)[0])
    rng = np.random.default_rng(seed)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                       (batch, prompt_len)), jnp.int32)
    memory = None
    if cfg.is_encdec:
        e = cfg.encoder
        memory = jnp.asarray(rng.standard_normal(
            (batch, e.n_memory_tokens, e.d_memory)) * 0.1, jnp.float32)
    elif cfg.n_memory_tokens:
        memory = jnp.asarray(rng.standard_normal(
            (batch, cfg.n_memory_tokens, cfg.d_memory)) * 0.1, jnp.float32)

    @jax.jit
    def prefill_fn(emb_state, dense, dev_ids, memory):
        acts, _ = backend.lookup(emb_state, dev_ids)
        return T.prefill(cfg, dense, acts, memory=memory,
                         max_len=prompt_len + gen)

    @jax.jit
    def decode_fn(emb_state, dense, dev_ids, caches, key):
        acts, _ = backend.lookup(emb_state, dev_ids)
        logits, caches = T.decode_step(cfg, dense, acts, caches)
        logits = logits[:, 0, : cfg.vocab_size]
        if temperature > 0:
            nxt = jax.random.categorical(key, logits / temperature)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        return nxt.astype(jnp.int32)[:, None], caches

    t0 = time.time()
    # host-backed vocab tables fault their rows in before each dispatch
    emb, dev = backend.prepare(emb, prompts)
    logits, caches = prefill_fn(emb, dense, dev, memory)
    tok = jnp.argmax(logits[:, 0, : cfg.vocab_size], -1)[:, None] \
        .astype(jnp.int32)
    jax.block_until_ready(tok)
    t_prefill = time.time() - t0

    out = [tok]
    t1 = time.time()
    for i in range(gen - 1):
        key, sub = jax.random.split(key)
        emb, dev = backend.prepare(emb, tok)
        tok, caches = decode_fn(emb, dense, dev, caches, sub)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t1
    gen_tokens = jnp.concatenate(out, axis=1)
    return {
        "tokens": np.asarray(gen_tokens),
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "decode_tok_per_s": batch * (gen - 1) / max(t_decode, 1e-9),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_3_2b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--emb-backend", default="dense",
                    choices=["dense", "host_lru", "host_lru+disk",
                             "dense+compressed", "host_lru+compressed",
                             "host_lru+disk+compressed"],
                    help="vocab-table storage backend: host_lru serves the "
                         "embedding tier out-of-core from host RAM; +disk "
                         "adds the mmap tier below it")
    ap.add_argument("--cache-rows", type=int, default=0,
                    help="host_lru device-cache slots (0 = vocab/8)")
    ap.add_argument("--emb-shards", default="1",
                    help="embedding-PS shards for the vocab table (> 1 "
                         "routes through the sharded router: hash id->shard "
                         "routing + concurrent per-shard fault-in); same "
                         "grammar as train.py — a bare int or 'table=k' "
                         "pairs (the table here is named 'vocab')")
    args = ap.parse_args()
    cfg = get_config(args.arch, reduced=args.reduced)
    res = serve(cfg, args.batch, args.prompt_len, args.gen,
                temperature=args.temperature,
                emb_backend=args.emb_backend, cache_rows=args.cache_rows,
                emb_shards=args.emb_shards)
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen}")
    print(f"prefill {res['prefill_s']:.2f}s decode {res['decode_s']:.2f}s "
          f"({res['decode_tok_per_s']:.1f} tok/s)")
    print("first sample tokens:", res["tokens"][0][:12])


if __name__ == "__main__":
    main()
