"""Shared ``--emb-shards`` CLI parsing for the launchers (train / serve /
cluster): one grammar — a bare int or comma-separated ``table=k`` pairs —
so every entrypoint spells per-table PS shard counts the same way."""
from __future__ import annotations


def parse_emb_shards(s: str | int | None):
    """``--emb-shards`` value -> int or {table: k} mapping. Accepts a bare
    int ("4") or comma-separated ``table=k`` pairs ("field_00=4,field_02=2");
    table names are validated downstream against the collection."""
    if isinstance(s, int):
        return s
    s = (s or "1").strip()
    if "=" not in s:
        return int(s)
    out = {}
    for part in s.split(","):
        name, _, k = part.partition("=")
        if not name.strip() or not k.strip():
            raise ValueError(
                f"bad --emb-shards entry {part!r}: expected 'table=k'")
        out[name.strip()] = int(k)
    return out


def shards_for_table(shards, name: str, default: int = 1) -> int:
    """Resolve one table's shard count out of a parsed ``--emb-shards``
    value (single-table launchers like serve.py name their sole table and
    pick its entry; unknown names fall back to ``default``)."""
    if isinstance(shards, int):
        return shards
    return int(shards.get(name, default))
