"""Shared spec/backend plumbing for the launchers (train / serve / cluster
/ online): one ``--emb-shards`` grammar — a bare int or comma-separated
``table=k`` pairs — plus one way to build an EmbeddingSpec from CLI knobs
and one way to apply a backend choice to a collection, so every entrypoint
resolves storage the same way."""
from __future__ import annotations


def parse_emb_shards(s: str | int | None):
    """``--emb-shards`` value -> int or {table: k} mapping. Accepts a bare
    int ("4") or comma-separated ``table=k`` pairs ("field_00=4,field_02=2");
    table names are validated downstream against the collection."""
    if isinstance(s, int):
        return s
    s = (s or "1").strip()
    if "=" not in s:
        return int(s)
    out = {}
    for part in s.split(","):
        name, _, k = part.partition("=")
        if not name.strip() or not k.strip():
            raise ValueError(
                f"bad --emb-shards entry {part!r}: expected 'table=k'")
        out[name.strip()] = int(k)
    return out


def shards_for_table(shards, name: str, default: int = 1) -> int:
    """Resolve one table's shard count out of a parsed ``--emb-shards``
    value (single-table launchers like serve.py name their sole table and
    pick its entry; unknown names fall back to ``default``)."""
    if isinstance(shards, int):
        return shards
    return int(shards.get(name, default))


def default_cache_rows(rows: int, cache_rows: int = 0) -> int:
    """The launchers' host_lru device-cache sizing: explicit wins, else an
    eighth of the table (floored so tiny tables still cache something)."""
    return cache_rows or max(1024, rows // 8)


def build_embedding_spec(rows: int, dim: int, backend: str = "dense",
                         cache_rows: int = 0, emb_shards: "str | int" = 1,
                         table: str = "vocab", **spec_kw):
    """One table's EmbeddingSpec from the shared CLI knobs: resolves the
    ``--emb-shards`` grammar against ``table`` and fills the host_lru
    cache-size default. Extra keywords pass through to the spec."""
    import dataclasses

    from repro.core.embedding_ps import EmbeddingSpec

    shards = shards_for_table(parse_emb_shards(emb_shards), table)
    spec = EmbeddingSpec(rows=rows, dim=dim, backend=backend,
                         emb_shards=max(int(shards), 1), **spec_kw)
    if backend.startswith("host_lru"):
        spec = dataclasses.replace(
            spec, cache_rows=default_cache_rows(rows, cache_rows))
    return spec


def apply_backend_choice(coll, backend: str, cache_rows: int | None = None):
    """Override a collection's storage backend from a CLI choice: host-
    backed variants carry the cache size, device-resident variants must
    NOT (dense has no cache; ``dense+compressed`` etc. keep each spec's
    own cache_rows), and plain ``dense`` is the specs' default."""
    if backend.partition("+")[0] != "dense":
        return coll.with_backend(backend, cache_rows)
    if backend != "dense":
        return coll.with_backend(backend, None)
    return coll
