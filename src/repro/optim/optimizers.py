"""Dense-side optimizers (the NN-worker Omega^nn in Alg. 2), from scratch.

State is a pytree mirroring params; everything works on arbitrary pytrees and
under jit/GSPMD (states inherit the params' sharding).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


def _zeros_like_f32(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


# -- SGD (+momentum) ---------------------------------------------------------

def sgd_init(params, momentum=0.0):
    if momentum:
        return {"m": _zeros_like_f32(params), "t": jnp.zeros((), jnp.int32)}
    return {"t": jnp.zeros((), jnp.int32)}


def sgd_update(params, grads, state, *, lr, momentum=0.0, weight_decay=0.0):
    t = state["t"] + 1

    def upd(p, g, m=None):
        g32 = g.astype(jnp.float32)
        if weight_decay:
            g32 = g32 + weight_decay * p.astype(jnp.float32)
        if m is not None:
            m_new = momentum * m + g32
            step = m_new
        else:
            m_new, step = None, g32
        p_new = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return p_new, m_new

    if momentum:
        out = jax.tree.map(upd, params, grads, state["m"])
        new_p = jax.tree.map(lambda o: o[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"m": new_m, "t": t}
    new_p = jax.tree.map(lambda p, g: upd(p, g)[0], params, grads)
    return new_p, {"t": t}


# -- Adam ---------------------------------------------------------------------

def adam_init(params):
    return {"m": _zeros_like_f32(params), "v": _zeros_like_f32(params),
            "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, *, lr, b1=0.9, b2=0.999, eps=1e-8,
                weight_decay=0.0, grad_clip=0.0):
    t = state["t"] + 1
    if grad_clip > 0:
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gn, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    bc1 = 1.0 - jnp.power(jnp.float32(b1), t.astype(jnp.float32))
    bc2 = 1.0 - jnp.power(jnp.float32(b2), t.astype(jnp.float32))

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * jnp.square(g32)
        step = (m_new / bc1) * jax.lax.rsqrt(v_new / bc2 + eps * eps)
        # rsqrt(x + eps^2) ~ 1/(sqrt(x)+eps); cheaper and stable
        p32 = p.astype(jnp.float32)
        if weight_decay:
            step = step + weight_decay * p32
        return (p32 - lr * step).astype(p.dtype), m_new, v_new

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    is3 = lambda x: isinstance(x, tuple)
    new_p = jax.tree.map(lambda o: o[0], out, is_leaf=is3)
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=is3)
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=is3)
    return new_p, {"m": new_m, "v": new_v, "t": t}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


# -- LR schedules --------------------------------------------------------------

def linear_warmup_cosine(step, *, base_lr, warmup, total):
    step = step.astype(jnp.float32)
    warm = base_lr * step / jnp.maximum(warmup, 1)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup, warm, cos)


# -- Factory --------------------------------------------------------------------

@dataclass(frozen=True)
class OptConfig:
    kind: str = "adam"
    lr: float = 3e-4
    momentum: float = 0.0
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0


def make_optimizer(cfg: OptConfig):
    if cfg.kind == "adam":
        def init(params):
            return adam_init(params)

        def update(params, grads, state, lr=None):
            return adam_update(params, grads, state,
                               lr=cfg.lr if lr is None else lr,
                               b1=cfg.b1, b2=cfg.b2, eps=cfg.eps,
                               weight_decay=cfg.weight_decay,
                               grad_clip=cfg.grad_clip)
        return init, update
    if cfg.kind == "sgd":
        def init(params):
            return sgd_init(params, cfg.momentum)

        def update(params, grads, state, lr=None):
            return sgd_update(params, grads, state,
                              lr=cfg.lr if lr is None else lr,
                              momentum=cfg.momentum,
                              weight_decay=cfg.weight_decay)
        return init, update
    raise ValueError(cfg.kind)
