"""The paper's own model family: multi-hot embedding bags + FFNN (§6
"a fully connected feed forward neural network with five hidden layers
4096-2048-1024-512-256"), predicting one or more CTR/behaviour tasks.

The embedding side lives in the Persia PS; this module is the NN-worker view:
it consumes raw looked-up activations (B, F, L, D), pools the multi-hot bags
(the 'embedding worker aggregation' in paper §4.1 step 4), concatenates
Non-ID features and runs the dense MLP.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.utils import shard
from repro.models.layers import dense_init


def recsys_init(cfg, key, dtype=jnp.float32, d_in=None):
    """d_in overrides the pooled-embedding input width (heterogeneous
    per-table dims sum to something other than n_id_fields * emb_dim)."""
    if d_in is None:
        d_in = cfg.n_id_fields * cfg.emb_dim + cfg.n_dense_features
    dims = (d_in,) + tuple(cfg.mlp_dims) + (cfg.n_tasks,)
    ks = jax.random.split(key, len(dims))
    layers = []
    for i in range(len(dims) - 1):
        layers.append({
            "w": dense_init(ks[i], dims[i], dims[i + 1], dtype,
                            scale=math.sqrt(2.0 / dims[i])),
            "b": jnp.zeros((dims[i + 1],), dtype),
        })
    return {"mlp": layers}


def pool_bags(acts, ids):
    """Sum-pool multi-hot bags; padding ids (<0) contribute zero.

    acts: (B, F, L, D) raw per-id embeddings; ids: (B, F, L).
    """
    m = (ids >= 0).astype(acts.dtype)[..., None]
    return jnp.sum(acts * m, axis=2)                                # (B, F, D)


def pool_bag(acts, ids):
    """Sum-pool one table's multi-hot bag: (B, L, D), (B, L) -> (B, D)."""
    m = (ids >= 0).astype(acts.dtype)[..., None]
    return jnp.sum(acts * m, axis=1)


def _mlp(params, x):
    n = len(params["mlp"])
    for i, lyr in enumerate(params["mlp"]):
        x = x @ lyr["w"] + lyr["b"]
        if i < n - 1:
            x = jax.nn.relu(x)
    return x


def recsys_forward_tables(cfg, params, acts, ids, dense_feats):
    """Multi-table forward: per-table pooled bags concatenated in SORTED
    table-name order (dims may differ per table), then the shared FFNN.

    acts: {name: (B, L_t, D_t)}; ids: {name: (B, L_t)} with -1 padding.
    Sorted order is load-bearing: jax rebuilds dict pytrees key-sorted when
    they cross a jit/grad flatten boundary, so iterating insertion order
    would wire the MLP input differently in the train and eval paths.
    """
    pooled = [pool_bag(acts[n], ids[n]) for n in sorted(acts)]  # [(B, D_t)]
    x = jnp.concatenate(pooled, axis=-1)
    if cfg.n_dense_features:
        x = jnp.concatenate([x, dense_feats.astype(x.dtype)], axis=-1)
    x = shard(x, ("pod", "data"), None)
    return _mlp(params, x)                                      # (B,n_tasks)


def recsys_loss_tables(cfg, params, acts, ids, batch):
    """Binary cross-entropy per task (CTR-style), multi-table front-end."""
    logits = recsys_forward_tables(cfg, params, acts, ids,
                                   batch.get("dense"))
    return _bce_loss(logits, batch)


def _bce_loss(logits, batch):
    y = batch["labels"].astype(jnp.float32)
    z = logits.astype(jnp.float32)
    # stable BCE-with-logits
    nll = jnp.maximum(z, 0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z)))
    loss = jnp.mean(nll)
    metrics = {"loss": loss,
               "pred_mean": jnp.mean(jax.nn.sigmoid(z))}
    return loss, metrics


def recsys_forward(cfg, params, emb_acts, ids, dense_feats):
    pooled = pool_bags(emb_acts, ids)                               # (B,F,D)
    B = pooled.shape[0]
    x = pooled.reshape(B, -1)
    if cfg.n_dense_features:
        x = jnp.concatenate([x, dense_feats.astype(x.dtype)], axis=-1)
    x = shard(x, ("pod", "data"), None)
    return _mlp(params, x)                                          # (B,n_tasks)


def recsys_loss(cfg, params, emb_acts, batch):
    """Binary cross-entropy per task (CTR-style)."""
    logits = recsys_forward(cfg, params, emb_acts, batch["ids"],
                            batch.get("dense"))
    return _bce_loss(logits, batch)
