"""Mixture-of-Experts FFN with capacity-based dispatch and explicit expert
parallelism.

Sharding design (the PS idea applied to the FFN's own sparse-access
structure): expert weights are sharded over the ``model`` mesh axis. The MoE
layer runs inside ``shard_map`` over the full mesh — activations arrive
batch-sharded over (pod, data) and *replicated* over ``model``; every model
rank routes the same local tokens but runs only its E/|model| local experts,
then a ``psum`` over ``model`` combines expert contributions. Dispatch inside
a rank is scatter/gather against a fixed-capacity (E_local, C, D) buffer, so
no (T, E, C) one-hot tensor is ever materialised and buffer sizes are static.

Baseline collective cost per MoE layer: one fp32 psum of (T_local, D) over
``model``. §Perf upgrade path: all-to-all token dispatch (send only routed
tokens) instead of replicated-compute + psum.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.utils import cdiv, _mesh_axis_names, bspec_axes, n_batch_shards
from repro.models.layers import dense_init


def moe_init(key, cfg, dtype=jnp.float32):
    d, f, E = cfg.d_model, cfg.moe_d_ff or cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)

    def stack(k, d_in, d_out, scale=None):
        kk = jax.random.split(k, E)
        return jnp.stack([dense_init(kk[i], d_in, d_out, dtype, scale)
                          for i in range(E)])

    p = {
        "router": dense_init(ks[0], d, E, jnp.float32, scale=0.02),
        "wg": stack(ks[1], d, f),
        "wu": stack(ks[2], d, f),
        "wd": stack(ks[3], f, d, scale=1.0 / math.sqrt(f)),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {"wg": dense_init(kk[0], d, fs, dtype),
                       "wu": dense_init(kk[1], d, fs, dtype),
                       "wd": dense_init(kk[2], fs, d, dtype,
                                        scale=1.0 / math.sqrt(fs))}
    return p


def router_topk(logits, k):
    """softmax -> top-k -> renormalise (DeepSeek-V2 style)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    topv, topi = jax.lax.top_k(probs, k)
    topv = topv / jnp.maximum(jnp.sum(topv, -1, keepdims=True), 1e-9)
    return probs, topv, topi


def load_balance_loss(probs, topi, n_experts):
    """Switch-style aux loss: E * sum_e f_e * P_e."""
    counts = jnp.zeros((n_experts,), jnp.float32).at[topi.reshape(-1)].add(1.0)
    frac = counts / jnp.maximum(topi.size, 1)
    mean_p = jnp.mean(probs, axis=0)
    return n_experts * jnp.sum(frac * mean_p)


def _dispatch_positions(topi, n_experts, capacity):
    """Per-(token, choice) slot in a per-expert capacity buffer.

    Loops over the k routing choices so the transient is (T, E) int32 — never
    (T*k, E) or (T, E, C).
    Returns slot (T, k) in [0, E*C] where E*C means 'dropped'.
    """
    T, k = topi.shape
    base = jnp.zeros((n_experts,), jnp.int32)
    slots = []
    for j in range(k):
        e_j = topi[:, j]
        onehot = jax.nn.one_hot(e_j, n_experts, dtype=jnp.int32)
        cum = jnp.cumsum(onehot, axis=0) + base[None, :]
        my_pos = jnp.take_along_axis(cum, e_j[:, None], axis=1)[:, 0] - 1
        keep = my_pos < capacity
        slots.append(jnp.where(keep, e_j * capacity + my_pos,
                               n_experts * capacity))
        base = base + jnp.sum(onehot, axis=0)
    return jnp.stack(slots, axis=1)                                # (T, k)


def _moe_local(p, cfg, xt, *, e_offset, e_local, capacity, out_dtype):
    """Dispatch/compute/combine for the e_local experts owned by this rank.

    xt: (T, D) tokens (replicated across expert shards). Returns the partial
    output (zeros where tokens route to remote experts) plus aux stats.
    """
    T, D = xt.shape
    E, k = cfg.n_experts, cfg.moe_top_k
    logits = xt.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs, topv, topi = router_topk(logits, k)

    slot_all = _dispatch_positions(topi, E, capacity)              # (T, k)
    # localise: keep only slots owned by this shard
    lo, hi = e_offset * capacity, (e_offset + e_local) * capacity
    local = (slot_all >= lo) & (slot_all < hi)
    slot = jnp.where(local, slot_all - lo, e_local * capacity)

    buf = jnp.zeros((e_local * capacity + 1, D), xt.dtype)
    for j in range(k):
        buf = buf.at[slot[:, j]].set(xt)
    buf = buf[: e_local * capacity].reshape(e_local, capacity, D)

    wg, wu, wd = p["wg"], p["wu"], p["wd"]
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg)) * \
        jnp.einsum("ecd,edf->ecf", buf, wu)
    y = jnp.einsum("ecf,efd->ecd", h, wd)                          # (e_loc,C,D)

    flat = jnp.concatenate([y.reshape(e_local * capacity, D),
                            jnp.zeros((1, D), y.dtype)], axis=0)
    out = jnp.zeros((T, D), jnp.float32)
    for j in range(k):
        w = (topv[:, j] * (slot[:, j] < e_local * capacity))[:, None]
        out = out + flat[slot[:, j]].astype(jnp.float32) * w

    aux = {
        "moe_balance": load_balance_loss(probs, topi, E) / jnp.float32(1.0),
        "moe_z": jnp.mean(jax.nn.logsumexp(logits, -1) ** 2),
        "moe_drop_frac": 1.0 - jnp.mean((slot_all < E * capacity)
                                        .astype(jnp.float32)),
    }
    return out.astype(out_dtype), aux


import os

# token dispatch strategy over the 'model' axis:
#   'psum' (baseline) — tokens replicated over model ranks, each rank runs
#       only its local experts, fp-dtype psum combines. One (T_local, D)
#       psum per layer.
#   'a2a' — tokens arrive sequence-sharded (matching the residual stream),
#       routed tokens are all_to_all'd to their expert's owner rank and
#       back. Traffic ~ 2 * k/n-scaled buckets; no psum, no token
#       replication. (EXPERIMENTS.md §Perf I12.)
MOE_DISPATCH = os.environ.get("REPRO_MOE_DISPATCH", "psum")


def moe_forward(p, cfg, x, capacity_factor=None):
    """x: (B, S, D) -> (out, aux dict). Expert-parallel over 'model' if the
    ambient mesh has that axis; plain local compute otherwise."""
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.moe_top_k
    cf = capacity_factor if capacity_factor is not None else cfg.capacity_factor
    names = _mesh_axis_names()
    n_exp_shards = 1
    if "model" in names:
        mesh = jax.sharding.get_abstract_mesh()
        n_exp_shards = mesh.shape["model"]
    assert E % n_exp_shards == 0, (E, n_exp_shards)
    e_local = E // n_exp_shards

    if (MOE_DISPATCH == "a2a" and n_exp_shards > 1
            and S % n_exp_shards == 0 and S > 1):
        return _moe_forward_a2a(p, cfg, x, cf, n_exp_shards, e_local)

    if n_exp_shards == 1:
        xt = x.reshape(B * S, D)
        C = max(1, cdiv(int(B * S * k * cf), E))
        out, aux = _moe_local(p, cfg, xt, e_offset=0, e_local=E,
                              capacity=C, out_dtype=x.dtype)
        out = out.reshape(B, S, D)
    else:
        baxes = bspec_axes(B)
        nb = n_batch_shards() if baxes else 1
        T_local = (B // nb) * S
        C = max(1, cdiv(int(T_local * k * cf), E))

        bspec = P(baxes, None, None)

        @partial(jax.shard_map,
                 in_specs=(_moe_param_specs(cfg), bspec),
                 out_specs=(bspec, P()),
                 check_vma=False)
        def _sharded(p_blk, x_blk):
            idx = jax.lax.axis_index("model")
            Bl, Sl, Dl = x_blk.shape
            out, aux = _moe_local(p_blk, cfg, x_blk.reshape(Bl * Sl, Dl),
                                  e_offset=idx * e_local, e_local=e_local,
                                  capacity=C, out_dtype=x_blk.dtype)
            # combine expert contributions in the activation dtype — the
            # psum is the MoE layer's dominant collective; bf16 halves it
            out = jax.lax.psum(out.astype(x_blk.dtype), "model")
            aux = jax.tree.map(
                lambda a: jax.lax.pmean(a, ("model",) + (baxes or ())), aux)
            if "shared" in p_blk:
                sh = p_blk["shared"]
                xt = x_blk.reshape(Bl * Sl, Dl)
                hs = jax.nn.silu(xt @ sh["wg"]) * (xt @ sh["wu"])
                out = out + (hs @ sh["wd"]).astype(out.dtype)
            return out.reshape(Bl, Sl, Dl), aux

        out, aux = _sharded(p, x)
        return out, aux

    if cfg.n_shared_experts:
        sh = p["shared"]
        xt = x.reshape(B * S, D)
        hs = jax.nn.silu(xt @ sh["wg"]) * (xt @ sh["wu"])
        out = out + (hs @ sh["wd"]).reshape(B, S, D)
    return out, aux


def _moe_forward_a2a(p, cfg, x, cf, n, e_local):
    """All-to-all token dispatch (see MOE_DISPATCH docstring).

    The layer consumes and produces a sequence-sharded residual (matching
    the Megatron-SP stream), so there is no token replication at all: each
    model rank routes its own S/n token slice, ships routed tokens to the
    owning expert rank, and receives the results back.
    """
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.moe_top_k
    baxes = bspec_axes(B)
    nb = n_batch_shards() if baxes else 1
    T_r = (B // nb) * (S // n)                       # tokens per model rank
    # per-destination-rank bucket capacity and per-local-expert capacity
    C = max(1, cdiv(int(T_r * k), n) * 2)
    C2 = max(1, cdiv(int(n * C), e_local))

    bspec = P(baxes, "model", None)

    @partial(jax.shard_map,
             in_specs=(_moe_param_specs(cfg), bspec),
             out_specs=(bspec, P()),
             check_vma=False)
    def _sharded(p_blk, x_blk):
        me = jax.lax.axis_index("model")
        Bl, Sl, Dl = x_blk.shape
        xt = x_blk.reshape(Bl * Sl, Dl)              # (T_r, D)
        logits = xt.astype(jnp.float32) @ p_blk["router"].astype(jnp.float32)
        probs, topv, topi = router_topk(logits, k)

        # ---- dispatch into per-destination-rank buckets -------------------
        dest = topi // e_local                       # (T_r, k)
        base = jnp.zeros((n,), jnp.int32)
        slots, keeps = [], []
        for j in range(k):
            oh = jax.nn.one_hot(dest[:, j], n, dtype=jnp.int32)
            cum = jnp.cumsum(oh, axis=0) + base[None, :]
            pos = jnp.take_along_axis(cum, dest[:, j][:, None], 1)[:, 0] - 1
            keep = pos < C
            slots.append(jnp.where(keep, dest[:, j] * C + pos, n * C))
            keeps.append(keep)
            base = base + jnp.sum(oh, axis=0)
        slot = jnp.stack(slots, 1)                   # (T_r, k) in [0, n*C]
        keep = jnp.stack(keeps, 1)

        buf = jnp.zeros((n * C + 1, Dl), xt.dtype)
        ebuf = jnp.full((n * C + 1,), -1, jnp.int32)
        for j in range(k):
            buf = buf.at[slot[:, j]].set(xt)
            ebuf = ebuf.at[slot[:, j]].set(
                jnp.where(keep[:, j], topi[:, j], -1))
        buf = buf[: n * C].reshape(n, C, Dl)
        ebuf = ebuf[: n * C].reshape(n, C)

        # ---- ship to expert owners ---------------------------------------
        rbuf = jax.lax.all_to_all(buf, "model", 0, 0, tiled=False)
        rexp = jax.lax.all_to_all(ebuf, "model", 0, 0, tiled=False)
        rt = rbuf.reshape(n * C, Dl)
        re = rexp.reshape(n * C) - me * e_local      # local expert index

        # ---- local per-expert capacity buffers + FFN ----------------------
        live = (re >= 0) & (re < e_local)
        oh = jax.nn.one_hot(jnp.where(live, re, e_local), e_local + 1,
                            dtype=jnp.int32)[:, :e_local]
        cum = jnp.cumsum(oh, axis=0)
        pos2 = jnp.take_along_axis(
            cum, jnp.clip(re, 0, e_local - 1)[:, None], 1)[:, 0] - 1
        keep2 = live & (pos2 < C2)
        slot2 = jnp.where(keep2, jnp.clip(re, 0, e_local - 1) * C2 + pos2,
                          e_local * C2)
        ebuf2 = jnp.zeros((e_local * C2 + 1, Dl), rt.dtype).at[slot2].set(rt)
        ebuf2 = ebuf2[: e_local * C2].reshape(e_local, C2, Dl)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", ebuf2, p_blk["wg"])) * \
            jnp.einsum("ecd,edf->ecf", ebuf2, p_blk["wu"])
        y = jnp.einsum("ecf,efd->ecd", h, p_blk["wd"])
        flat_y = jnp.concatenate(
            [y.reshape(e_local * C2, Dl),
             jnp.zeros((1, Dl), y.dtype)], axis=0)
        yt = jnp.where(keep2[:, None], flat_y[slot2], 0.0)   # (n*C, D)

        # ---- ship results back + combine ----------------------------------
        yback = jax.lax.all_to_all(yt.reshape(n, C, Dl), "model", 0, 0,
                                   tiled=False).reshape(n * C, Dl)
        yfull = jnp.concatenate([yback, jnp.zeros((1, Dl), yback.dtype)], 0)
        out = jnp.zeros((Bl * Sl, Dl), jnp.float32)
        for j in range(k):
            w = (topv[:, j] * keep[:, j])[:, None]
            out = out + yfull[slot[:, j]].astype(jnp.float32) * w
        out = out.astype(x_blk.dtype)

        if "shared" in p_blk:
            sh = p_blk["shared"]
            hs = jax.nn.silu(xt @ sh["wg"]) * (xt @ sh["wu"])
            out = out + (hs @ sh["wd"]).astype(out.dtype)

        aux = {
            "moe_balance": load_balance_loss(probs, topi, E),
            "moe_z": jnp.mean(jax.nn.logsumexp(logits, -1) ** 2),
            "moe_drop_frac": 1.0 - jnp.mean(keep.astype(jnp.float32)),
        }
        aux = jax.tree.map(
            lambda a: jax.lax.pmean(a, ("model",) + (baxes or ())), aux)
        return out.reshape(Bl, Sl, Dl), aux

    return _sharded(p, x)


def _moe_param_specs(cfg):
    specs = {
        "router": P(None, None),
        "wg": P("model", None, None),
        "wu": P("model", None, None),
        "wd": P("model", None, None),
    }
    if cfg.n_shared_experts:
        specs["shared"] = {"wg": P(None, None), "wu": P(None, None),
                           "wd": P(None, None)}
    return specs


def moe_aux_total(cfg, aux):
    return (cfg.router_aux_weight * aux["moe_balance"]
            + cfg.router_z_weight * aux["moe_z"])
