"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) mixer.

Training/prefill uses the chunked SSD block decomposition: intra-chunk terms
are quadratic within a chunk (L x L, MXU-friendly) and inter-chunk terms are
carried by a serial ``lax.scan`` over chunk states (B, H, P, N). Decode is the
O(1) recurrence h <- h * exp(dt*A) + dt * B x. Heads shard over ``model``,
batch over (pod, data); the recurrent state never grows with sequence length,
which is what makes the ``long_500k`` shape tractable for SSM archs.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.utils import shard
from repro.models.layers import dense_init


def ssm_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    P_ = cfg.ssm_head_dim
    H = d_inner // P_
    N = cfg.ssm_state
    return d_inner, H, P_, N


def mamba2_init(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    d_inner, H, P_, N = ssm_dims(cfg)
    conv_ch = d_inner + 2 * N                    # x, B, C go through the conv
    ks = jax.random.split(key, 6)
    # in_proj -> [z, x, B, C, dt]
    p = {
        "in_proj": dense_init(ks[0], d, 2 * d_inner + 2 * N + H, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv_width, conv_ch),
                                     jnp.float32) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[2], (H,), jnp.float32,
                                       math.log(1e-3), math.log(1e-1))))),
        "D": jnp.ones((H,), jnp.float32),
        "out_norm": {"w": jnp.ones((d_inner,), jnp.float32)},
        "out_proj": dense_init(ks[3], d_inner, d, dtype,
                               scale=1.0 / math.sqrt(d_inner)),
    }
    return p


def _split_proj(cfg, proj):
    d_inner, H, P_, N = ssm_dims(cfg)
    z, xbc, dt = jnp.split(proj, [d_inner, 2 * d_inner + 2 * N], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc, w, b):
    """Depthwise causal conv, width K. xbc: (B,S,C); w: (K,C)."""
    K = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i: i + xbc.shape[1]] * w[i][None, None, :]
              for i in range(K))
    return jax.nn.silu(out + b[None, None, :])


def _gated_out(p, cfg, y, z, x_in_dtype):
    y = y * jax.nn.silu(z.astype(y.dtype))
    # grouped RMSNorm over d_inner
    y32 = y.astype(jnp.float32)
    var = jnp.mean(jnp.square(y32), axis=-1, keepdims=True)
    y = (y32 * jax.lax.rsqrt(var + cfg.norm_eps)
         * p["out_norm"]["w"]).astype(x_in_dtype)
    return y @ p["out_proj"]


def mamba2_forward(p, cfg, x, *, return_state=False):
    """Chunked SSD over the full sequence. x: (B,S,D)."""
    B, S, D = x.shape
    d_inner, H, P_, N = ssm_dims(cfg)
    L = min(cfg.ssm_chunk, S)
    S0 = S
    proj = x @ p["in_proj"]
    z, xbc, dt_raw = _split_proj(cfg, proj)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    pad = (-S) % L
    if pad:
        # pad to a chunk multiple; padded positions get dt=0 below, which
        # makes them exact no-ops on both outputs and the carried state
        xbc = jnp.pad(xbc, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nC = S // L
    xs, Bmat, Cmat = jnp.split(xbc, [d_inner, d_inner + N], axis=-1)
    xs = xs.reshape(B, S, H, P_)
    dt_raw_p = jnp.pad(dt_raw, ((0, 0), (0, pad), (0, 0))) if pad else dt_raw
    dt = jax.nn.softplus(dt_raw_p.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])             # (B,S,H)
    if pad:
        valid = (jnp.arange(S) < S0).astype(jnp.float32)[None, :, None]
        dt = dt * valid
    A = -jnp.exp(p["A_log"])                                        # (H,)
    dA = dt * A[None, None, :]                                      # (B,S,H) <= 0

    xs = shard(xs, ("pod", "data"), None, "model", None)

    # chunk views — scan over chunks so the quadratic (L, L, H) intra-chunk
    # tensors exist for one chunk at a time, never (nC, L, L, H).
    xs_c = xs.reshape(B, nC, L, H, P_).astype(jnp.float32)
    B_c = Bmat.reshape(B, nC, L, N).astype(jnp.float32)
    C_c = Cmat.reshape(B, nC, L, N).astype(jnp.float32)
    dt_c = dt.reshape(B, nC, L, H)
    dA_c = dA.reshape(B, nC, L, H)
    tri = jnp.tril(jnp.ones((L, L), bool))

    def chunk_step(h, inp):
        x_i, b_i, c_i, dt_i, dA_i = inp      # (B,L,H,P),(B,L,N),(B,L,N),(B,L,H)x2
        cum = jnp.cumsum(dA_i, axis=1)                               # (B,L,H)
        # intra-chunk: decay[i,j] = exp(cum_i - cum_j), i >= j
        seg = cum[:, :, None, :] - cum[:, None, :, :]                # (B,L,L,H)
        decay = jnp.where(tri[None, :, :, None], jnp.exp(seg), 0.0)
        cb = jnp.einsum("bln,bmn->blm", c_i, b_i)                    # (B,L,L)
        xdt = x_i * dt_i[..., None]                                  # (B,L,H,P)
        y_diag = jnp.einsum("blm,blmh,bmhp->blhp", cb, decay, xdt)
        # inter-chunk from carried state
        y_off = jnp.einsum("bln,blh,bhnp->blhp", c_i, jnp.exp(cum), h)
        # state update
        last = cum[:, -1:, :]                                        # (B,1,H)
        w_state = jnp.exp(last - cum) * dt_i                         # (B,L,H)
        S_i = jnp.einsum("bln,blh,blhp->bhnp", b_i, w_state, x_i)
        h_new = h * jnp.exp(last[:, 0])[:, :, None, None] + S_i
        return h_new, y_diag + y_off

    h0 = jnp.zeros((B, H, N, P_), jnp.float32)
    h_last, y_chunks = jax.lax.scan(
        chunk_step, h0,
        (xs_c.transpose(1, 0, 2, 3, 4), B_c.transpose(1, 0, 2, 3),
         C_c.transpose(1, 0, 2, 3), dt_c.transpose(1, 0, 2, 3),
         dA_c.transpose(1, 0, 2, 3)))
    y = y_chunks.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P_)
    y = y + xs.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y[:, :S0].reshape(B, S0, d_inner)
    out = _gated_out(p, cfg, y, z, x.dtype)
    if return_state:
        # conv tail for decode continuation
        conv_state = _conv_tail(cfg, x, p)
        return out, {"h": h_last.astype(jnp.float32), "conv": conv_state}
    return out


def _conv_tail(cfg, x, p):
    K = cfg.ssm_conv_width
    proj = x[:, -(K - 1):] @ p["in_proj"]
    _, xbc, _ = _split_proj(cfg, proj)
    # left-pad if sequence shorter than K-1
    pad = (K - 1) - xbc.shape[1]
    if pad > 0:
        xbc = jnp.pad(xbc, ((0, 0), (pad, 0), (0, 0)))
    return xbc


def mamba2_decode(p, cfg, x, cache):
    """One-token recurrent step.

    cache = {'h': (B,H,N,P) fp32, 'conv': (B,K-1,conv_ch)}
    """
    B = x.shape[0]
    d_inner, H, P_, N = ssm_dims(cfg)
    proj = x @ p["in_proj"]                                          # (B,1,*)
    z, xbc_new, dt_raw = _split_proj(cfg, proj)
    window = jnp.concatenate([cache["conv"], xbc_new], axis=1)       # (B,K,C)
    conv_out = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    conv_out = jax.nn.silu(conv_out)[:, None, :]                     # (B,1,C)
    xs, Bm, Cm = jnp.split(conv_out, [d_inner, d_inner + N], axis=-1)
    xs = xs.reshape(B, H, P_).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"][None, :])
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A[None, :])                                    # (B,H)
    Bx = jnp.einsum("bn,bhp->bhnp", Bm[:, 0].astype(jnp.float32),
                    xs * dt[..., None])
    h = cache["h"] * dA[:, :, None, None] + Bx                       # (B,H,N,P)
    y = jnp.einsum("bn,bhnp->bhp", Cm[:, 0].astype(jnp.float32), h)
    y = y + xs * p["D"][None, :, None]
    y = y.reshape(B, 1, d_inner)
    out = _gated_out(p, cfg, y, z, x.dtype)
    return out, {"h": h, "conv": window[:, 1:]}


def mamba2_cache_init(cfg, batch, dtype):
    d_inner, H, P_, N = ssm_dims(cfg)
    conv_ch = d_inner + 2 * N
    return {"h": jnp.zeros((batch, H, N, P_), jnp.float32),
            "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_ch), dtype)}


# ---------------------------------------------------------------------------
# Naive O(S) recurrence — oracle for tests.
# ---------------------------------------------------------------------------

def mamba2_reference_scan(p, cfg, x):
    """Step-by-step recurrence; numerically equivalent to the chunked path."""
    B, S, D = x.shape
    d_inner, H, P_, N = ssm_dims(cfg)
    proj = x @ p["in_proj"]
    z, xbc, dt_raw = _split_proj(cfg, proj)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs, Bmat, Cmat = jnp.split(xbc, [d_inner, d_inner + N], axis=-1)
    xs = xs.reshape(B, S, H, P_).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])

    def step(h, t):
        dA = jnp.exp(dt[:, t] * A[None, :])                          # (B,H)
        Bx = jnp.einsum("bn,bhp->bhnp", Bmat[:, t].astype(jnp.float32),
                        xs[:, t] * dt[:, t][..., None])
        h = h * dA[:, :, None, None] + Bx
        y = jnp.einsum("bn,bhnp->bhp", Cmat[:, t].astype(jnp.float32), h)
        return h, y

    h0 = jnp.zeros((B, H, N, P_), jnp.float32)
    _, ys = jax.lax.scan(step, h0, jnp.arange(S))
    y = ys.transpose(1, 0, 2, 3) + xs * p["D"][None, None, :, None]
    y = y.reshape(B, S, d_inner)
    return _gated_out(p, cfg, y, z, x.dtype)
