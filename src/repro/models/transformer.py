"""Layer-program transformer: one code path instantiates every assigned
architecture (dense GQA, MLA+MoE, Mamba2 SSM, Jamba-style hybrid, VLM with
interleaved cross-attention, Whisper-style encoder-decoder).

The stack is a short ``pattern`` of heterogeneous blocks repeated
``pattern_repeats`` times and lowered as a single ``lax.scan`` over stacked
parameters, so a 100-layer model compiles with the HLO of one super-block.
Token embeddings are *not* part of the dense parameters — they live in the
Persia embedding PS (core.embedding_ps) and arrive here as activations, which
is exactly the paper's NN-worker view of the world.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.utils import shard
from repro.configs.base import BlockCfg, ModelConfig
from repro.models import layers as L
from repro.models import mamba2 as M2
from repro.models import moe as MOE


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _block_init(key, cfg: ModelConfig, blk: BlockCfg, dtype):
    ks = jax.random.split(key, 8)
    p: dict[str, Any] = {}
    if blk.mixer == "gqa":
        p["mixer_norm"] = L.norm_init(cfg, cfg.d_model)
        p["mixer"] = L.gqa_init(ks[0], cfg, dtype)
    elif blk.mixer == "mla":
        p["mixer_norm"] = L.norm_init(cfg, cfg.d_model)
        p["mixer"] = L.mla_init(ks[0], cfg, dtype)
    elif blk.mixer == "mamba2":
        p["mixer_norm"] = L.norm_init(cfg, cfg.d_model)
        p["mixer"] = M2.mamba2_init(ks[0], cfg, dtype)
    elif blk.mixer == "cross_attn":
        p["mixer_norm"] = L.norm_init(cfg, cfg.d_model)
        p["mixer"] = L.gqa_init(ks[0], cfg, dtype, cross=True)
        p["xgate"] = jnp.zeros((), jnp.float32)
    if getattr(blk, "cross", False):
        p["cross_norm"] = L.norm_init(cfg, cfg.d_model)
        p["cross"] = L.gqa_init(ks[1], cfg, dtype, cross=True)
    if blk.ffn == "dense":
        p["ffn_norm"] = L.norm_init(cfg, cfg.d_model)
        p["ffn"] = L.mlp_init(ks[2], cfg, dtype=dtype)
    elif blk.ffn == "moe":
        p["ffn_norm"] = L.norm_init(cfg, cfg.d_model)
        p["ffn"] = MOE.moe_init(ks[2], cfg, dtype)
    return p


def init_dense(cfg: ModelConfig, key, dtype=jnp.float32):
    """Everything except the embedding table (that's the PS's job)."""
    ks = jax.random.split(key, 8 + len(cfg.prologue))
    params: dict[str, Any] = {}
    for i, blk in enumerate(cfg.prologue):
        params[f"prologue_{i}"] = _block_init(ks[i], cfg, blk, dtype)

    def stack_init(k, blk):
        kk = jax.random.split(k, cfg.pattern_repeats)
        ps = [_block_init(kk[r], cfg, blk, dtype)
              for r in range(cfg.pattern_repeats)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *ps)

    kstack = jax.random.split(ks[-1], len(cfg.pattern))
    params["stack"] = {str(i): stack_init(kstack[i], blk)
                       for i, blk in enumerate(cfg.pattern)}
    params["final_norm"] = L.norm_init(cfg, cfg.d_model)
    params["lm_head"] = L.dense_init(ks[-2], cfg.d_model, cfg.padded_vocab,
                                     dtype, scale=1.0 / math.sqrt(cfg.d_model))
    if cfg.is_encdec:
        params["encoder"] = _init_encoder(cfg.encoder, ks[-3], dtype)
        # learned decoder positions (Whisper style); 64k covers decode_32k
        params["dec_pos_emb"] = L.embed_init(ks[-4], 1 << 16, cfg.d_model,
                                             dtype)
    return params


def _init_encoder(ecfg: ModelConfig, key, dtype):
    ks = jax.random.split(key, 4)
    enc = {"pos_emb": L.embed_init(ks[0], ecfg.n_memory_tokens, ecfg.d_model,
                                   dtype),
           "in_proj": L.dense_init(ks[3], ecfg.d_memory, ecfg.d_model, dtype)}

    def stack_init(k, blk):
        kk = jax.random.split(k, ecfg.pattern_repeats)
        ps = [_block_init(kk[r], ecfg, blk, dtype)
              for r in range(ecfg.pattern_repeats)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *ps)

    kstack = jax.random.split(ks[1], len(ecfg.pattern))
    enc["stack"] = {str(i): stack_init(kstack[i], blk)
                    for i, blk in enumerate(ecfg.pattern)}
    enc["final_norm"] = L.norm_init(ecfg, ecfg.d_model)
    return enc


# ---------------------------------------------------------------------------
# Block application (full sequence)
# ---------------------------------------------------------------------------

def _apply_block(cfg, blk, p, x, positions, memory, *, want_cache):
    aux = {}
    cache = {}
    if blk.mixer == "gqa":
        h = L.apply_norm(cfg, p["mixer_norm"], x)
        o, (k, v) = L.gqa_forward(p["mixer"], cfg, h, positions)
        x = x + o
        if want_cache:
            cache["attn"] = {"k": k, "v": v,
                             "len": jnp.full((x.shape[0],), x.shape[1],
                                             jnp.int32)}
    elif blk.mixer == "mla":
        h = L.apply_norm(cfg, p["mixer_norm"], x)
        o, c = L.mla_forward(p["mixer"], cfg, h, positions)
        x = x + o
        if want_cache:
            cache["attn"] = c
    elif blk.mixer == "mamba2":
        h = L.apply_norm(cfg, p["mixer_norm"], x)
        if want_cache:
            o, c = M2.mamba2_forward(p["mixer"], cfg, h, return_state=True)
            cache["ssm"] = c
        else:
            o = M2.mamba2_forward(p["mixer"], cfg, h)
        x = x + o
    elif blk.mixer == "cross_attn":
        h = L.apply_norm(cfg, p["mixer_norm"], x)
        o, (k, v) = L.cross_attn_forward(p["mixer"], cfg, h, memory)
        x = x + jnp.tanh(p["xgate"]).astype(x.dtype) * o
        if want_cache:
            cache["cross"] = {"k": k, "v": v}
    if getattr(blk, "cross", False):
        h = L.apply_norm(cfg, p["cross_norm"], x)
        o, (k, v) = L.cross_attn_forward(p["cross"], cfg, h, memory)
        x = x + o
        if want_cache:
            cache["cross"] = {"k": k, "v": v}
    if blk.ffn == "dense":
        h = L.apply_norm(cfg, p["ffn_norm"], x)
        x = x + L.mlp_forward(p["ffn"], cfg, h)
    elif blk.ffn == "moe":
        h = L.apply_norm(cfg, p["ffn_norm"], x)
        o, aux = MOE.moe_forward(p["ffn"], cfg, h)
        x = x + o
    return x, cache, aux


def _zero_aux(cfg):
    if any(b.ffn == "moe" for b in cfg.prologue + cfg.pattern):
        z = jnp.zeros((), jnp.float32)
        return {"moe_balance": z, "moe_z": z, "moe_drop_frac": z}
    return {}


def _acc_aux(total, aux):
    if not aux:
        return total
    return {k: total.get(k, jnp.zeros((), jnp.float32)) + aux[k] for k in aux}


def forward(cfg: ModelConfig, params, acts, positions, memory=None,
            *, want_cache=False):
    """acts: (B, S, D) token embeddings from the PS. Returns hidden states
    after final norm (+ caches when want_cache)."""
    x = shard(acts, ("pod", "data"), None, None)
    aux_total: dict = {}
    caches: dict = {}
    if cfg.is_encdec:
        x = x + params["dec_pos_emb"][positions].astype(x.dtype)

    for i, blk in enumerate(cfg.prologue):
        x, c, aux = _apply_block(cfg, blk, params[f"prologue_{i}"], x,
                                 positions, memory, want_cache=want_cache)
        aux_total = _acc_aux(aux_total, aux)
        if want_cache:
            caches[f"prologue_{i}"] = c

    # Remat granularity (A/B-able, see EXPERIMENTS.md §Perf):
    #   'block' — each block rematted separately: smallest live set during
    #             backward, but every block boundary re-gathers weights
    #   'body'  — one checkpoint around the whole scanned super-block:
    #             fewer re-gathers, larger recompute live set
    import os
    gran = os.environ.get("REPRO_REMAT_GRANULARITY", cfg.remat_granularity)

    def one_block(blk):
        def f(x, p):
            return _apply_block(cfg, blk, p, x, positions, memory,
                                want_cache=want_cache)
        if cfg.remat and not want_cache and gran == "block":
            return jax.checkpoint(f)
        return f

    block_fns = [one_block(blk) for blk in cfg.pattern]

    def blocks(x, per_layer):
        aux_layer: dict = {}
        cache_layer = {}
        for i, blk in enumerate(cfg.pattern):
            x, c, aux = block_fns[i](x, per_layer[str(i)])
            aux_layer = _acc_aux(aux_layer, aux)
            cache_layer[str(i)] = c
            if cfg.seq_shard:
                # Megatron-SP style: residual stream seq-sharded over 'model'
                # between blocks (drops to no-op without a mesh)
                if x.shape[1] % 16 == 0:
                    x = shard(x, ("pod", "data"), "model", None)
        out = (cache_layer, aux_layer) if (want_cache or aux_layer) else None
        return x, out

    body = blocks
    if cfg.remat and not want_cache and gran != "block":
        body = jax.checkpoint(blocks)
    x, emitted = jax.lax.scan(body, x, params["stack"])
    if emitted is not None:
        cache_stack, aux_stack = emitted
        if want_cache:
            caches["stack"] = cache_stack
        if aux_stack:
            aux_total = _acc_aux(aux_total,
                                 jax.tree.map(jnp.sum, aux_stack))
    x = L.apply_norm(cfg, params["final_norm"], x)
    if want_cache:
        return x, caches, aux_total
    return x, aux_total


def encode(cfg: ModelConfig, params, frames):
    """Whisper-style encoder over precomputed (stub) frame embeddings.
    frames: (B, M, d_memory) -> (B, M, D)."""
    ecfg = cfg.encoder
    enc = params["encoder"]
    x = frames @ enc["in_proj"]
    x = x + enc["pos_emb"][None, : x.shape[1]].astype(x.dtype)

    def enc_body(x, per_layer):
        for i, blk in enumerate(ecfg.pattern):
            p = per_layer[str(i)]
            h = L.apply_norm(ecfg, p["mixer_norm"], x)
            B, S, _ = h.shape
            q, k, v = L._qkv(p["mixer"], ecfg, h)
            o = L.grouped_attention(q, k, v,
                                    scale=1.0 / math.sqrt(ecfg.head_dim),
                                    causal=False)
            x = x + o.reshape(B, S, -1) @ p["mixer"]["wo"]
            h = L.apply_norm(ecfg, p["ffn_norm"], x)
            x = x + L.mlp_forward(p["ffn"], ecfg, h)
        return x, None

    body = jax.checkpoint(enc_body) if ecfg.remat else enc_body
    x, _ = jax.lax.scan(body, x, enc["stack"])
    return L.apply_norm(ecfg, enc["final_norm"], x)


# ---------------------------------------------------------------------------
# Loss (training): chunk-free CE over the model-sharded vocab
# ---------------------------------------------------------------------------

def lm_loss(cfg: ModelConfig, params, acts, targets, mask, memory=None):
    """acts: (B,S,D) embedding activations; targets: (B,S) int32."""
    B, S = targets.shape
    positions = jnp.arange(S)[None].repeat(B, 0)
    if cfg.is_encdec:
        memory = encode(cfg, params, memory)
    x, aux = forward(cfg, params, acts, positions, memory)
    logits = x @ params["lm_head"]                                 # (B,S,Vp)
    logits = shard(logits, ("pod", "data"), None, "model")
    logits = logits.astype(jnp.float32)
    if cfg.padded_vocab > cfg.vocab_size:                          # mask pads
        logits = jnp.where(jnp.arange(cfg.padded_vocab) < cfg.vocab_size,
                           logits, -1e30)
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.sum(logits * jax.nn.one_hot(targets, cfg.padded_vocab,
                                          dtype=logits.dtype), axis=-1)
    nll = (lse - tgt) * mask
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll) / denom
    metrics = {"loss": loss, "ppl_log": loss}
    if aux:
        loss = loss + MOE.moe_aux_total(cfg, jax.tree.map(
            lambda a: a / max(cfg.n_layers, 1), aux))
        metrics.update({k: v for k, v in aux.items()})
    return loss, metrics


# ---------------------------------------------------------------------------
# Serving: prefill + single-token decode against per-layer caches
# ---------------------------------------------------------------------------

def _block_cache_init(cfg, blk, batch, max_len, dtype, memory_len):
    c = {}
    if blk.mixer in ("gqa",):
        c["attn"] = L.gqa_cache_init(cfg, batch, max_len, dtype)
    elif blk.mixer == "mla":
        c["attn"] = L.mla_cache_init(cfg, batch, max_len, dtype)
    elif blk.mixer == "mamba2":
        c["ssm"] = M2.mamba2_cache_init(cfg, batch, dtype)
    elif blk.mixer == "cross_attn":
        c["cross"] = {"k": jnp.zeros((batch, memory_len, cfg.n_kv_heads,
                                      cfg.head_dim), dtype),
                      "v": jnp.zeros((batch, memory_len, cfg.n_kv_heads,
                                      cfg.head_dim), dtype)}
    if getattr(blk, "cross", False):
        c["cross"] = {"k": jnp.zeros((batch, memory_len, cfg.n_kv_heads,
                                      cfg.head_dim), dtype),
                      "v": jnp.zeros((batch, memory_len, cfg.n_kv_heads,
                                      cfg.head_dim), dtype)}
    return c


def cache_init(cfg: ModelConfig, batch, max_len, dtype, memory_len=0):
    caches = {}
    for i, blk in enumerate(cfg.prologue):
        caches[f"prologue_{i}"] = _block_cache_init(cfg, blk, batch, max_len,
                                                    dtype, memory_len)
    per_pos = {str(i): _block_cache_init(cfg, blk, batch, max_len, dtype,
                                         memory_len)
               for i, blk in enumerate(cfg.pattern)}
    caches["stack"] = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.pattern_repeats,) + x.shape),
        per_pos)
    caches["pos"] = jnp.zeros((batch,), jnp.int32)
    return caches


def _apply_block_decode(cfg, blk, p, x, cache, memory):
    new_cache = dict(cache)
    if blk.mixer == "gqa":
        h = L.apply_norm(cfg, p["mixer_norm"], x)
        o, new_attn = L.gqa_decode(p["mixer"], cfg, h, cache["attn"])
        x = x + o
        new_cache["attn"] = new_attn
    elif blk.mixer == "mla":
        h = L.apply_norm(cfg, p["mixer_norm"], x)
        o, new_attn = L.mla_decode(p["mixer"], cfg, h, cache["attn"])
        x = x + o
        new_cache["attn"] = new_attn
    elif blk.mixer == "mamba2":
        h = L.apply_norm(cfg, p["mixer_norm"], x)
        o, new_ssm = M2.mamba2_decode(p["mixer"], cfg, h, cache["ssm"])
        x = x + o
        new_cache["ssm"] = new_ssm
    elif blk.mixer == "cross_attn":
        h = L.apply_norm(cfg, p["mixer_norm"], x)
        o = _cross_decode(p["mixer"], cfg, h, cache["cross"])
        x = x + jnp.tanh(p["xgate"]).astype(x.dtype) * o
    if getattr(blk, "cross", False):
        h = L.apply_norm(cfg, p["cross_norm"], x)
        x = x + _cross_decode(p["cross"], cfg, h, cache["cross"])
    if blk.ffn == "dense":
        h = L.apply_norm(cfg, p["ffn_norm"], x)
        x = x + L.mlp_forward(p["ffn"], cfg, h)
    elif blk.ffn == "moe":
        h = L.apply_norm(cfg, p["ffn_norm"], x)
        o, _ = MOE.moe_forward(p["ffn"], cfg, h)
        x = x + o
    return x, new_cache


def _cross_decode(p, cfg, x, ckv):
    B = x.shape[0]
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = H // Hkv
    q = (x @ p["wq"]).reshape(B, 1, Hkv, G, Dh)
    if cfg.qk_norm:
        q = L.rmsnorm(q, p["q_norm"]["w"], cfg.norm_eps)
    o = L.grouped_attention(q, ckv["k"], ckv["v"],
                            scale=1.0 / math.sqrt(Dh), causal=False)
    return o.reshape(B, 1, -1) @ p["wo"]


def decode_step(cfg: ModelConfig, params, acts, caches):
    """One-token decode. acts: (B, 1, D) embedding of the new token."""
    x = shard(acts, ("pod", "data"), None, None)
    if cfg.is_encdec:
        x = x + params["dec_pos_emb"][caches["pos"][:, None]].astype(x.dtype)
    new_caches = dict(caches)
    for i, blk in enumerate(cfg.prologue):
        x, c = _apply_block_decode(cfg, blk, params[f"prologue_{i}"], x,
                                   caches[f"prologue_{i}"], None)
        new_caches[f"prologue_{i}"] = c

    # The stacked caches ride in the scan CARRY and are updated in place via
    # dynamic_update_index — passing them as scan xs/ys would allocate BOTH
    # an input and an output copy of the whole KV cache (2x cache temp).
    def body(carry, inp):
        x, cache_stack = carry
        per_layer, li = inp
        new_layer = {}
        for i, blk in enumerate(cfg.pattern):
            layer_cache = jax.tree.map(
                lambda s: jax.lax.dynamic_index_in_dim(s, li, 0,
                                                       keepdims=False),
                cache_stack[str(i)])
            x, c = _apply_block_decode(cfg, blk, per_layer[str(i)], x,
                                       layer_cache, None)
            new_layer[str(i)] = c
        cache_stack = {
            pos: jax.tree.map(
                lambda s, n: jax.lax.dynamic_update_index_in_dim(
                    s, n.astype(s.dtype), li, 0),
                cache_stack[pos], new_layer[pos])
            for pos in cache_stack
        }
        return (x, cache_stack), None

    (x, new_stack), _ = jax.lax.scan(
        body, (x, caches["stack"]),
        (params["stack"], jnp.arange(cfg.pattern_repeats)))
    new_caches["stack"] = new_stack
    new_caches["pos"] = caches["pos"] + 1
    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = (x @ params["lm_head"]).astype(jnp.float32)
    logits = shard(logits, ("pod", "data"), None, "model")
    if cfg.padded_vocab > cfg.vocab_size:
        logits = jnp.where(jnp.arange(cfg.padded_vocab) < cfg.vocab_size,
                           logits, -1e30)
    return logits, new_caches


def _pad_cache_seq(caches, pad_to):
    """Grow attention caches' sequence capacity to pad_to (for decode)."""
    def fix(block_cache):
        c = dict(block_cache)
        if "attn" in c:
            a = dict(c["attn"])
            for key in ("k", "v", "ckv", "k_rope"):
                if key in a:
                    cur = a[key].shape[-3] if key in ("k", "v") else a[key].shape[-2]
                    extra = pad_to - cur
                    if extra > 0:
                        seq_axis = a[key].ndim - (3 if key in ("k", "v") else 2)
                        pads = [(0, 0)] * a[key].ndim
                        pads[seq_axis] = (0, extra)
                        a[key] = jnp.pad(a[key], pads)
            c["attn"] = a
        return c

    out = {}
    for name, c in caches.items():
        if name == "pos":
            out[name] = c
        elif name == "stack":
            out[name] = {pos: fix(blk) for pos, blk in c.items()}
        else:
            out[name] = fix(c)
    return out


def prefill(cfg: ModelConfig, params, acts, memory=None, max_len=None):
    """Full-sequence prefill producing decode caches + last-token logits.
    ``max_len`` pads attention caches so decode can append new tokens."""
    B, S, _ = acts.shape
    positions = jnp.arange(S)[None].repeat(B, 0)
    if cfg.is_encdec:
        memory = encode(cfg, params, memory)
    x, caches, _ = forward(cfg, params, acts, positions, memory,
                           want_cache=True)
    caches["pos"] = jnp.full((B,), S, jnp.int32)
    if max_len is not None and max_len > S:
        caches = _pad_cache_seq(caches, max_len)
    logits = (x[:, -1:] @ params["lm_head"]).astype(jnp.float32)
    return logits, caches
