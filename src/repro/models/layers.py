"""Primitive layers: inits, norms, RoPE, blockwise (flash-style) attention,
GQA / MLA attention blocks, MLPs. Pure-jnp, mesh-agnostic (sharding hints via
``utils.shard``)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.utils import shard, cdiv

# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def dense_init(key, d_in, d_out, dtype=jnp.float32, scale: float | None = None):
    scale = (1.0 / math.sqrt(d_in)) if scale is None else scale
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, rows, dim, dtype=jnp.float32, scale: float = 0.02):
    return (jax.random.normal(key, (rows, dim), jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x, weight, eps=1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(dt)


def layernorm(x, weight, bias, eps=1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def norm_init(cfg, d):
    if cfg.norm == "rmsnorm":
        return {"w": jnp.ones((d,), jnp.float32)}
    return {"w": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}


def apply_norm(cfg, p, x):
    if "b" in p:
        return layernorm(x, p["w"], p["b"], cfg.norm_eps)
    return rmsnorm(x, p["w"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: (..., S) int32."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                                  # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * inv        # (..., S, d/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Blockwise (flash-style) attention — pure jnp with online softmax, so 32k+
# prefill lowers without materializing S^2 score tensors.
#   q: (B, Sq, Hkv, G, Dh)   k: (B, Sk, Hkv, Dh)   v: (B, Sk, Hkv, Dv)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _attn_naive(q, k, v, *, scale, causal, window, q_offset, softcap=0.0):
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    Sq, Sk = q.shape[1], k.shape[1]
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window > 0:
        mask &= qpos[:, None] - kpos[None, :] < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32)).astype(q.dtype)


def _attn_blockwise(q, k, v, *, scale, causal, window, q_offset,
                    qblk=512, kblk=512, softcap=0.0):
    B, Sq, Hkv, G, Dh = q.shape
    Sk, Dv = k.shape[1], v.shape[-1]
    qpad, kpad = cdiv(Sq, qblk) * qblk - Sq, cdiv(Sk, kblk) * kblk - Sk
    qf = jnp.pad(q, ((0, 0), (0, qpad), (0, 0), (0, 0), (0, 0)))
    kf = jnp.pad(k, ((0, 0), (0, kpad), (0, 0), (0, 0)))
    vf = jnp.pad(v, ((0, 0), (0, kpad), (0, 0), (0, 0)))
    nq, nk = qf.shape[1] // qblk, kf.shape[1] // kblk
    qf = qf.reshape(B, nq, qblk, Hkv, G, Dh)
    kf = kf.reshape(B, nk, kblk, Hkv, Dh)
    vf = vf.reshape(B, nk, kblk, Hkv, Dv)
    kpos_all = jnp.arange(nk * kblk).reshape(nk, kblk)
    kvalid = kpos_all < Sk

    def q_step(_, qi):
        qb = qf[:, qi]                                           # (B,qblk,Hkv,G,Dh)
        qpos = qi * qblk + jnp.arange(qblk) + q_offset

        def kv_step(carry, ki):
            m, l, acc = carry
            kb, vb = kf[:, ki], vf[:, ki]
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qb.astype(jnp.float32),
                           kb.astype(jnp.float32)) * scale
            if softcap > 0:
                s = jnp.tanh(s / softcap) * softcap
            kpos = ki * kblk + jnp.arange(kblk)
            msk = kvalid[ki][None, :]
            if causal:
                msk = msk & (qpos[:, None] >= kpos[None, :])
            if window > 0:
                msk = msk & (qpos[:, None] - kpos[None, :] < window)
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vb.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        init = (jnp.full((B, Hkv, G, qblk), NEG_INF, jnp.float32),
                jnp.zeros((B, Hkv, G, qblk), jnp.float32),
                jnp.zeros((B, Hkv, G, qblk, Dv), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(kv_step, init, jnp.arange(nk))
        ob = acc / jnp.maximum(l, 1e-30)[..., None]              # (B,Hkv,G,qblk,Dv)
        return None, ob.transpose(0, 3, 1, 2, 4)                  # (B,qblk,Hkv,G,Dv)

    _, out = jax.lax.scan(q_step, None, jnp.arange(nq))           # (nq,B,qblk,...)
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * qblk, Hkv, G, Dv)
    return out[:, :Sq].astype(q.dtype)


def grouped_attention(q, k, v, *, scale, causal=True, window=0, q_offset=0,
                      softcap=0.0, blockwise_threshold=2048):
    """Dispatch: naive (exact autodiff) for short sequences; flash attention
    (custom-VJP, memory-linear) beyond."""
    if max(q.shape[1], k.shape[1]) <= blockwise_threshold:
        return _attn_naive(q, k, v, scale=scale, causal=causal, window=window,
                           q_offset=q_offset, softcap=softcap)
    if softcap > 0:
        return _attn_blockwise(q, k, v, scale=scale, causal=causal,
                               window=window, q_offset=q_offset,
                               softcap=softcap)
    from repro.models.flash import flash_attention
    return flash_attention(q, k, v, scale=scale, causal=causal, window=window,
                           q_offset=q_offset)


def decode_attention(q, k_cache, v_cache, cache_len, *, scale, window=0,
                     softcap=0.0):
    """Single-token decode. q: (B,1,Hkv,G,Dh); caches: (B,S,Hkv,D*).

    ``cache_len`` is the number of valid entries (new token already written at
    position cache_len-1). Linear in S, no S^2 term.
    """
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    kpos = jnp.arange(k_cache.shape[1])
    msk = kpos[None, :] < cache_len[:, None]                      # (B,S)
    if window > 0:
        msk = msk & (cache_len[:, None] - 1 - kpos[None, :] < window)
    s = jnp.where(msk[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v_cache.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------

def gqa_init(key, cfg, dtype, cross=False):
    d, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dm = cfg.d_memory if cross else d
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], d, H * Dh, dtype),
        "wk": dense_init(ks[1], dm, Hkv * Dh, dtype),
        "wv": dense_init(ks[2], dm, Hkv * Dh, dtype),
        "wo": dense_init(ks[3], H * Dh, d, dtype, scale=1.0 / math.sqrt(H * Dh)),
    }
    if cfg.qk_norm:
        p["q_norm"] = {"w": jnp.ones((Dh,), jnp.float32)}
        p["k_norm"] = {"w": jnp.ones((Dh,), jnp.float32)}
    return p


def _qkv(p, cfg, x, memory=None):
    B, S, _ = x.shape
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = H // Hkv
    src = x if memory is None else memory
    q = (x @ p["wq"]).reshape(B, S, Hkv, G, Dh)
    k = (src @ p["wk"]).reshape(B, src.shape[1], Hkv, Dh)
    v = (src @ p["wv"]).reshape(B, src.shape[1], Hkv, Dh)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"]["w"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"]["w"], cfg.norm_eps)
    return q, k, v


def gqa_forward(p, cfg, x, positions, *, window=None, use_rope=True):
    """Self-attention over full sequence (training / prefill)."""
    B, S, _ = x.shape
    q, k, v = _qkv(p, cfg, x)
    if use_rope:
        q = apply_rope(q.reshape(B, S, -1, cfg.head_dim), positions, cfg.rope_theta
                       ).reshape(q.shape)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = shard(q, ("pod", "data"), None, "model")
    k = shard(k, ("pod", "data"), None, "model")
    w = cfg.sliding_window if window is None else window
    out = grouped_attention(q, k, v, scale=1.0 / math.sqrt(cfg.head_dim),
                            causal=True, window=w, softcap=cfg.attn_logit_softcap)
    out = out.reshape(B, S, -1)
    return out @ p["wo"], (k, v)


def cross_attn_forward(p, cfg, x, memory):
    """Cross-attention to a fixed memory (image patches / encoder frames)."""
    B, S, _ = x.shape
    q, k, v = _qkv(p, cfg, x, memory=memory)
    q = shard(q, ("pod", "data"), None, "model")
    out = grouped_attention(q, k, v, scale=1.0 / math.sqrt(cfg.head_dim),
                            causal=False, window=0)
    return out.reshape(B, S, -1) @ p["wo"], (k, v)


def gqa_decode(p, cfg, x, cache, *, window=None, use_rope=True):
    """One-token decode. cache = {'k': (B,S,Hkv,Dh), 'v': ..., 'len': (B,)}

    Full-length caches are sequence-sharded over 'model' when a mesh is in
    scope (see models.decode_dist); ring-buffer (windowed) caches stay local.
    """
    from repro.models import decode_dist as DD
    B = x.shape[0]
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q, k, v = _qkv(p, cfg, x)
    pos = cache["len"][:, None]                                   # (B,1)
    if use_rope:
        q = apply_rope(q.reshape(B, 1, -1, Dh), pos, cfg.rope_theta).reshape(q.shape)
        k = apply_rope(k, pos, cfg.rope_theta)
    w = cfg.sliding_window if window is None else window
    if w <= 0 and DD.have_model_axis():
        out, new_cache = DD.gqa_decode_dist(
            p, cfg, q, k, v, cache, scale=1.0 / math.sqrt(Dh),
            softcap=cfg.attn_logit_softcap)
        out = out.reshape(B, 1, -1) @ p["wo"]
        return out, new_cache
    if w > 0:
        slot = cache["len"] % cache["k"].shape[1]                 # ring buffer
    else:
        slot = cache["len"]
    kc = jax.vmap(lambda c, s, n: jax.lax.dynamic_update_slice(c, n, (s, 0, 0)))(
        cache["k"], slot, k)
    vc = jax.vmap(lambda c, s, n: jax.lax.dynamic_update_slice(c, n, (s, 0, 0)))(
        cache["v"], slot, v)
    new_len = cache["len"] + 1
    if w > 0:
        out = _decode_ring(q, kc, vc, new_len, w, cfg)
    else:
        out = decode_attention(q, kc, vc, new_len,
                               scale=1.0 / math.sqrt(Dh),
                               softcap=cfg.attn_logit_softcap)
    out = out.reshape(B, 1, -1) @ p["wo"]
    return out, {"k": kc, "v": vc, "len": new_len}


def _decode_ring(q, kc, vc, new_len, window, cfg):
    """Decode attention over a ring-buffer cache of size >= window.

    Positions in the ring: slot s holds absolute position p where
    p % ring == s and p in [new_len - valid, new_len).
    """
    B, ring = kc.shape[0], kc.shape[1]
    slots = jnp.arange(ring)
    # absolute position stored in each slot (for each batch element)
    cur = new_len[:, None]                                        # (B,1)
    abs_pos = cur - 1 - ((cur - 1 - slots[None, :]) % ring)       # (B,ring)
    valid = (abs_pos >= 0) & (abs_pos >= cur - window) & (abs_pos < cur)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32),
                   kc.astype(jnp.float32)) / math.sqrt(cfg.head_dim)
    if cfg.attn_logit_softcap > 0:
        s = jnp.tanh(s / cfg.attn_logit_softcap) * cfg.attn_logit_softcap
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgqk,bkhd->bqhgd", p, vc.astype(jnp.float32)).astype(q.dtype)


def gqa_cache_init(cfg, batch, max_len, dtype, *, window=None):
    w = cfg.sliding_window if window is None else window
    ring = min(max_len, w) if w > 0 else max_len
    Hkv, Dh = cfg.n_kv_heads, cfg.head_dim
    return {"k": jnp.zeros((batch, ring, Hkv, Dh), dtype),
            "v": jnp.zeros((batch, ring, Hkv, Dh), dtype),
            "len": jnp.zeros((batch,), jnp.int32)}


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, DeepSeek-V2) block
# ---------------------------------------------------------------------------

def mla_init(key, cfg, dtype):
    d, H = cfg.d_model, cfg.n_heads
    dn, dr, dv = cfg.head_dim, cfg.rope_head_dim, cfg.v_head_dim
    r_kv, r_q = cfg.kv_lora_rank, cfg.q_lora_rank
    ks = jax.random.split(key, 8)
    p = {}
    if r_q > 0:
        p["wdq"] = dense_init(ks[0], d, r_q, dtype)
        p["q_ln"] = {"w": jnp.ones((r_q,), jnp.float32)}
        p["wuq"] = dense_init(ks[1], r_q, H * (dn + dr), dtype)
    else:
        p["wq"] = dense_init(ks[1], d, H * (dn + dr), dtype)
    p["wdkv"] = dense_init(ks[2], d, r_kv + dr, dtype)
    p["kv_ln"] = {"w": jnp.ones((r_kv,), jnp.float32)}
    p["wuk"] = dense_init(ks[3], r_kv, H * dn, dtype)
    p["wuv"] = dense_init(ks[4], r_kv, H * dv, dtype)
    p["wo"] = dense_init(ks[5], H * dv, d, dtype, scale=1.0 / math.sqrt(H * dv))
    return p


def _mla_q(p, cfg, x):
    B, S, _ = x.shape
    H, dn, dr = cfg.n_heads, cfg.head_dim, cfg.rope_head_dim
    if cfg.q_lora_rank > 0:
        cq = rmsnorm(x @ p["wdq"], p["q_ln"]["w"], cfg.norm_eps)
        q = (cq @ p["wuq"]).reshape(B, S, H, dn + dr)
    else:
        q = (x @ p["wq"]).reshape(B, S, H, dn + dr)
    return q[..., :dn], q[..., dn:]                               # q_nope, q_rope


def mla_forward(p, cfg, x, positions):
    """Full-sequence MLA (training / prefill). Returns latent cache."""
    B, S, _ = x.shape
    H, dn, dr, dv = cfg.n_heads, cfg.head_dim, cfg.rope_head_dim, cfg.v_head_dim
    q_nope, q_rope = _mla_q(p, cfg, x)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    ckv_full = x @ p["wdkv"]                                       # (B,S,r+dr)
    ckv = rmsnorm(ckv_full[..., :cfg.kv_lora_rank], p["kv_ln"]["w"], cfg.norm_eps)
    k_rope = apply_rope(ckv_full[..., None, cfg.kv_lora_rank:], positions,
                        cfg.rope_theta)                            # (B,S,1,dr)
    k_nope = (ckv @ p["wuk"]).reshape(B, S, H, dn)
    v = (ckv @ p["wuv"]).reshape(B, S, H, dv)
    q = jnp.concatenate([q_nope, q_rope], -1)[:, :, :, None, :]    # Hkv=H, G=1
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, H, dr))], -1)
    q = shard(q, ("pod", "data"), None, "model")
    k = shard(k, ("pod", "data"), None, "model")
    out = grouped_attention(q, k, v, scale=1.0 / math.sqrt(dn + dr), causal=True)
    out = out.reshape(B, S, -1) @ p["wo"]
    cache = {"ckv": ckv, "k_rope": k_rope[:, :, 0, :],
             "len": jnp.full((B,), S, jnp.int32)}
    return out, cache


def mla_decode(p, cfg, x, cache):
    """Weight-absorbed single-token MLA decode against the latent cache.

    cache = {'ckv': (B,S,r), 'k_rope': (B,S,dr), 'len': (B,)}
    FLOPs per token are O(S * (r + dr)) per head — the MLA memory/compute win.
    """
    B = x.shape[0]
    H, dn, dr, dv, r = (cfg.n_heads, cfg.head_dim, cfg.rope_head_dim,
                        cfg.v_head_dim, cfg.kv_lora_rank)
    from repro.models import decode_dist as DD
    q_nope, q_rope = _mla_q(p, cfg, x)                             # (B,1,H,*)
    pos = cache["len"][:, None]
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)
    ckv_full = x @ p["wdkv"]
    ckv_new = rmsnorm(ckv_full[..., :r], p["kv_ln"]["w"], cfg.norm_eps)
    kr_new = apply_rope(ckv_full[..., None, r:], pos, cfg.rope_theta)[:, :, 0]
    if DD.have_model_axis():
        wuk = p["wuk"].reshape(r, H, dn)
        q_abs = jnp.einsum("bqhd,rhd->bqhr", q_nope.astype(jnp.float32),
                           wuk.astype(jnp.float32))
        ctx, new_cache = DD.mla_decode_dist(cfg, q_abs, q_rope,
                                            ckv_new, kr_new, cache)
        wuv = p["wuv"].reshape(r, H, dv)
        out = jnp.einsum("bqhr,rhd->bqhd", ctx, wuv.astype(jnp.float32))
        out = out.reshape(B, 1, H * dv).astype(x.dtype) @ p["wo"]
        return out, new_cache
    ckv_c = jax.vmap(lambda c, s, n: jax.lax.dynamic_update_slice(c, n, (s, 0)))(
        cache["ckv"], cache["len"], ckv_new)
    kr_c = jax.vmap(lambda c, s, n: jax.lax.dynamic_update_slice(c, n, (s, 0)))(
        cache["k_rope"], cache["len"], kr_new)
    new_len = cache["len"] + 1
    # absorb W_uk into q:  q_abs[h, r] = sum_dn q_nope[h,dn] * wuk[r, h, dn]
    wuk = p["wuk"].reshape(r, H, dn)
    q_abs = jnp.einsum("bqhd,rhd->bqhr", q_nope.astype(jnp.float32),
                       wuk.astype(jnp.float32))
    s = (jnp.einsum("bqhr,bkr->bhqk", q_abs, ckv_c.astype(jnp.float32))
         + jnp.einsum("bqhd,bkd->bhqk", q_rope.astype(jnp.float32),
                      kr_c.astype(jnp.float32))) / math.sqrt(dn + dr)
    kpos = jnp.arange(ckv_c.shape[1])
    s = jnp.where((kpos[None, :] < new_len[:, None])[:, None, None, :], s, NEG_INF)
    pa = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhqk,bkr->bqhr", pa, ckv_c.astype(jnp.float32))  # (B,1,H,r)
    wuv = p["wuv"].reshape(r, H, dv)
    out = jnp.einsum("bqhr,rhd->bqhd", ctx, wuv.astype(jnp.float32))
    out = out.reshape(B, 1, H * dv).astype(x.dtype) @ p["wo"]
    return out, {"ckv": ckv_c, "k_rope": kr_c, "len": new_len}


def mla_cache_init(cfg, batch, max_len, dtype):
    return {"ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, max_len, cfg.rope_head_dim), dtype),
            "len": jnp.zeros((batch,), jnp.int32)}


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_init(key, cfg, d_ff=None, dtype=jnp.float32):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.ffn_act == "swiglu":
        return {"wg": dense_init(ks[0], d, f, dtype),
                "wu": dense_init(ks[1], d, f, dtype),
                "wd": dense_init(ks[2], f, d, dtype, scale=1.0 / math.sqrt(f))}
    return {"wu": dense_init(ks[1], d, f, dtype),
            "wd": dense_init(ks[2], f, d, dtype, scale=1.0 / math.sqrt(f))}


def mlp_forward(p, cfg, x):
    if "wg" in p:
        h = jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])
    else:
        h = jax.nn.gelu(x @ p["wu"])
    h = shard(h, ("pod", "data"), None, "model")
    return h @ p["wd"]
