"""Distributed single-token decode attention over sequence-sharded KV caches.

Why: decode caches are (B, S, H_kv, D) with H_kv (often 8) smaller than the
``model`` mesh axis (16), so head-sharding cannot absorb the cache. We shard
the *sequence* dimension over ``model`` instead — the PS idea applied to the
KV cache: each model rank owns a contiguous span of positions, the new token
is written by its owning rank only, and attention is a local flash pass plus
a logsumexp-combine ``psum`` (max / corrected sum / corrected weighted
values) over ``model``. Per step the collective traffic is O(B * H * D),
independent of S.

Used when the ambient mesh has a ``model`` axis and the cache is full-length
(ring/window caches are small and stay replicated).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.utils import _mesh_axis_names, bspec_axes

NEG_INF = -1e30


def _bspec_for(batch_size: int):
    def _b(*rest):
        return P(bspec_axes(batch_size), *rest)
    return _b


def have_model_axis() -> bool:
    return "model" in _mesh_axis_names()


def _local_update(c, slot_local, new, in_range):
    """vmap'd conditional dynamic-update at per-batch slots (B, S_loc, ...).

    Always writes one slot (re-writing the existing value when this shard
    does not own the position) — a `where(ok, updated_cache, cache)` on the
    whole cache would materialise a second copy of the KV cache per layer
    and defeat in-place buffer reuse through the layer scan."""
    def one(cb, s, nb, ok):
        idx = (s,) + (0,) * (cb.ndim - 1)
        cur = jax.lax.dynamic_slice(cb, idx, nb.shape)
        val = jnp.where(ok, nb.astype(cb.dtype), cur)
        return jax.lax.dynamic_update_slice(cb, val, idx)
    return jax.vmap(one)(c, slot_local, new, in_range)


def gqa_decode_dist(p, cfg, q, k_new, v_new, cache, *, scale, softcap=0.0):
    """q: (B,1,Hkv,G,Dh); k_new/v_new: (B,1,Hkv,Dh); cache k/v (B,S,Hkv,Dh)
    sequence-sharded over 'model'. Returns (out (B,1,Hkv,G,Dh), new_cache)."""
    S = cache["k"].shape[1]
    mesh = jax.sharding.get_abstract_mesh()
    n = mesh.shape["model"]
    assert S % n == 0, (S, n)
    S_loc = S // n
    _bspec = _bspec_for(q.shape[0])

    cache_spec = {"k": _bspec("model", None, None),
                  "v": _bspec("model", None, None),
                  "len": _bspec()}

    @partial(jax.shard_map,
             in_specs=(_bspec(None, None, None, None),   # q
                       _bspec(None, None, None),          # k_new
                       _bspec(None, None, None),          # v_new
                       cache_spec),
             out_specs=(_bspec(None, None, None, None), cache_spec),
             check_vma=False)
    def _step(qb, knb, vnb, cb):
        me = jax.lax.axis_index("model")
        length = cb["len"]                                 # (B,)
        slot = length                                      # append position
        owner = slot // S_loc
        in_range = owner == me
        slot_local = jnp.clip(slot - me * S_loc, 0, S_loc - 1)
        kc = _local_update(cb["k"], slot_local, knb, in_range)
        vc = _local_update(cb["v"], slot_local, vnb, in_range)
        new_len = length + 1

        s = jnp.einsum("bqhgd,bkhd->bhgqk", qb.astype(jnp.float32),
                       kc.astype(jnp.float32)) * scale
        if softcap > 0:
            s = jnp.tanh(s / softcap) * softcap
        kpos = me * S_loc + jnp.arange(S_loc)
        msk = kpos[None, :] < new_len[:, None]
        s = jnp.where(msk[:, None, None, None, :], s, NEG_INF)
        m = jnp.max(s, axis=-1)                            # (B,h,g,1)
        p_ = jnp.exp(s - m[..., None])
        l = jnp.sum(p_, axis=-1)
        acc = jnp.einsum("bhgqk,bkhd->bhgqd", p_, vc.astype(jnp.float32))
        # logsumexp combine across sequence shards
        m_g = jax.lax.pmax(m, "model")
        corr = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * corr, "model")
        acc_g = jax.lax.psum(acc * corr[..., None], "model")
        out = (acc_g / jnp.maximum(l_g, 1e-30)[..., None])
        out = out.transpose(0, 3, 1, 2, 4)                 # (B,1,h,g,d)
        return out.astype(qb.dtype), {"k": kc, "v": vc, "len": new_len}

    return _step(q, k_new, v_new, cache)


def mla_decode_dist(cfg, q_abs, q_rope, ckv_new, kr_new, cache):
    """Weight-absorbed MLA decode over a sequence-sharded latent cache.

    q_abs: (B,1,H,r) fp32; q_rope: (B,1,H,dr); ckv_new: (B,1,r);
    kr_new: (B,1,dr); cache: {'ckv': (B,S,r), 'k_rope': (B,S,dr), 'len': (B,)}.
    Returns (ctx (B,1,H,r) fp32, new_cache).
    """
    S = cache["ckv"].shape[1]
    mesh = jax.sharding.get_abstract_mesh()
    n = mesh.shape["model"]
    assert S % n == 0, (S, n)
    S_loc = S // n
    _bspec = _bspec_for(q_abs.shape[0])
    scale = 1.0 / math.sqrt(cfg.head_dim + cfg.rope_head_dim)

    cache_spec = {"ckv": _bspec("model", None), "k_rope": _bspec("model", None),
                  "len": _bspec()}

    @partial(jax.shard_map,
             in_specs=(_bspec(None, None, None), _bspec(None, None, None),
                       _bspec(None, None), _bspec(None, None), cache_spec),
             out_specs=(_bspec(None, None, None), cache_spec),
             check_vma=False)
    def _step(qa, qr, cn, krn, cb):
        me = jax.lax.axis_index("model")
        length = cb["len"]
        slot = length
        owner = slot // S_loc
        in_range = owner == me
        slot_local = jnp.clip(slot - me * S_loc, 0, S_loc - 1)
        ckv = _local_update(cb["ckv"], slot_local, cn, in_range)
        krc = _local_update(cb["k_rope"], slot_local, krn, in_range)
        new_len = length + 1

        s = (jnp.einsum("bqhr,bkr->bhqk", qa, ckv.astype(jnp.float32))
             + jnp.einsum("bqhd,bkd->bhqk", qr.astype(jnp.float32),
                          krc.astype(jnp.float32))) * scale
        kpos = me * S_loc + jnp.arange(S_loc)
        msk = kpos[None, :] < new_len[:, None]
        s = jnp.where(msk[:, None, None, :], s, NEG_INF)
        m = jnp.max(s, axis=-1)
        p_ = jnp.exp(s - m[..., None])
        l = jnp.sum(p_, axis=-1)
        acc = jnp.einsum("bhqk,bkr->bhqr", p_, ckv.astype(jnp.float32))
        m_g = jax.lax.pmax(m, "model")
        corr = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * corr, "model")
        acc_g = jax.lax.psum(acc * corr[..., None], "model")
        ctx = (acc_g / jnp.maximum(l_g, 1e-30)[..., None]).transpose(0, 2, 1, 3)
        return ctx, {"ckv": ckv, "k_rope": krc, "len": new_len}

    return _step(q_abs, q_rope, ckv_new, kr_new, cache)
