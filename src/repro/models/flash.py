"""Flash attention in pure jnp with a custom VJP (memory-linear in S).

Naive autodiff of online-softmax blockwise attention saves every (q-block x
kv-block) probability tile — i.e. the full S^2 attention matrix — which is
exactly what flash attention exists to avoid. This implementation:

  forward : scan over q blocks (inner scan over kv blocks), storing only
            out and the per-row logsumexp (LSE);
  backward: two recompute passes (dq over q blocks; dk/dv over kv blocks),
            each rebuilding probability tiles from q, k and the stored LSE.

Layout is the grouped-GQA (B, S, Hkv, G, Dh) used across the model zoo; kv
heads are never materialised G-fold. Pure jnp so it lowers under GSPMD on
any mesh (batch-sharded; heads/seq sharding left to the compiler) — the
Pallas TPU kernel would slot in behind the same interface on real hardware.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.utils import cdiv

NEG_INF = -1e30


def _mask_bias(qpos, kpos, causal, window, kmax):
    """(qblk, kblk) additive f32 bias: 0 where attended, -1e30 where masked.

    Additive-bias masking (instead of a boolean select) keeps any
    XLA-precomputed per-iteration table at (qblk, kblk) f32 — a broadcasted
    select predicate gets tabled at the full (B, heads, ...) operand shape,
    which at one point materialised a 16 GiB pred tensor per layer."""
    m = kpos[None, :] < kmax
    if causal:
        m &= qpos[:, None] >= kpos[None, :]
    if window > 0:
        m &= qpos[:, None] - kpos[None, :] < window
    return jnp.where(m, 0.0, NEG_INF).astype(jnp.float32)


# Triangle-ordered causal scan: iterate only the n(n+1)/2 lower-triangle
# (q-block, kv-block) pairs instead of the full nq x nk grid — a static ~2x
# attention-FLOP reduction for causal shapes. Measured (EXPERIMENTS.md §Perf
# I14): compute term −35%, but the output must ride in the scan carry with
# dynamic scatters, which GSPMD turns into ~20x collective traffic on the
# production mesh — so the jnp path defaults OFF. (In the Pallas kernel the
# same ordering is free: grid iteration order has no carry.)
TRIANGLE = os.environ.get("REPRO_FLASH_TRIANGLE", "0") == "1"


def _tri_pairs(n):
    """Pair lists for the triangle scans (row-major: fixed qi, ki<=qi)."""
    qs, ks = [], []
    for qi in range(n):
        for ki in range(qi + 1):
            qs.append(qi)
            ks.append(ki)
    return jnp.asarray(qs, jnp.int32), jnp.asarray(ks, jnp.int32)


def _tri_pairs_colmajor(n):
    """Fixed ki, qi >= ki — for the dk/dv pass."""
    qs, ks = [], []
    for ki in range(n):
        for qi in range(ki, n):
            qs.append(qi)
            ks.append(ki)
    return jnp.asarray(qs, jnp.int32), jnp.asarray(ks, jnp.int32)


@functools.lru_cache(maxsize=64)
def _make_flash(scale, causal, window, q_offset, qblk, kblk, softcap, sk):
    assert softcap == 0.0, "softcap unsupported in flash path"

    def fwd_blocks(q, k, v):
        B, nq, qb, Hkv, G, Dh = q.shape
        nk, kb, Dv = k.shape[1], k.shape[2], v.shape[-1]

        def q_step(_, qi):
            qb_ = q[:, qi]
            qpos = qi * qblk + jnp.arange(qblk) + q_offset

            def kv_step(carry, ki):
                m, l, acc = carry
                s = jnp.einsum("bqhgd,bkhd->bhgqk", qb_.astype(jnp.float32),
                               k[:, ki].astype(jnp.float32)) * scale
                kpos = ki * kblk + jnp.arange(kblk)
                s = s + _mask_bias(qpos, kpos, causal, window,
                                   sk)[None, None, None]
                m_new = jnp.maximum(m, jnp.max(s, axis=-1))
                p = jnp.exp(s - m_new[..., None])
                corr = jnp.exp(m - m_new)
                l_new = l * corr + jnp.sum(p, axis=-1)
                acc_new = acc * corr[..., None] + jnp.einsum(
                    "bhgqk,bkhd->bhgqd", p, v[:, ki].astype(jnp.float32))
                return (m_new, l_new, acc_new), None

            init = (jnp.full((B, Hkv, G, qblk), NEG_INF, jnp.float32),
                    jnp.zeros((B, Hkv, G, qblk), jnp.float32),
                    jnp.zeros((B, Hkv, G, qblk, Dv), jnp.float32))
            (m, l, acc), _ = jax.lax.scan(kv_step, init, jnp.arange(nk))
            o = acc / jnp.maximum(l, 1e-30)[..., None]
            lse = m + jnp.log(jnp.maximum(l, 1e-30))
            return None, (o.transpose(0, 3, 1, 2, 4), lse)   # (B,qblk,h,g,Dv)

        _, (o, lse) = jax.lax.scan(q_step, None, jnp.arange(nq))
        # o: (nq, B, qblk, h, g, Dv); lse: (nq, B, h, g, qblk)
        return o, lse

    tri = (TRIANGLE and causal and window == 0 and q_offset == 0
           and qblk == kblk)

    def _bias_pair(qi, ki):
        qpos = qi * qblk + jnp.arange(qblk)
        kpos = ki * kblk + jnp.arange(kblk)
        ok = (qpos[:, None] >= kpos[None, :]) & (kpos[None, :] < sk)
        return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)

    def fwd_blocks_tri(q, k, v):
        B, nq, qb, Hkv, G, Dh = q.shape
        Dv = v.shape[-1]
        qs, ks = _tri_pairs(nq)

        def step(carry, pair):
            m, l, acc, o_out, lse_out = carry
            qi, ki = pair
            fresh = ki == 0
            m = jnp.where(fresh, NEG_INF, m)
            l = jnp.where(fresh, 0.0, l)
            acc = jnp.where(fresh, 0.0, acc)
            qb_ = q[:, qi].astype(jnp.float32)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qb_,
                           k[:, ki].astype(jnp.float32)) * scale
            s = s + _bias_pair(qi, ki)[None, None, None]
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, v[:, ki].astype(jnp.float32))
            done = ki == qi
            o_blk = (acc_new / jnp.maximum(l_new, 1e-30)[..., None]) \
                .transpose(0, 3, 1, 2, 4)                 # (B,qblk,h,g,Dv)
            lse_blk = m_new + jnp.log(jnp.maximum(l_new, 1e-30))
            cur_o = o_out[qi]
            o_out = o_out.at[qi].set(jnp.where(done, o_blk, cur_o))
            cur_lse = lse_out[qi]
            lse_out = lse_out.at[qi].set(jnp.where(done, lse_blk, cur_lse))
            return (m_new, l_new, acc_new, o_out, lse_out), None

        init = (jnp.full((B, Hkv, G, qblk), NEG_INF, jnp.float32),
                jnp.zeros((B, Hkv, G, qblk), jnp.float32),
                jnp.zeros((B, Hkv, G, qblk, Dv), jnp.float32),
                jnp.zeros((nq, B, qblk, Hkv, G, Dv), jnp.float32),
                jnp.zeros((nq, B, Hkv, G, qblk), jnp.float32))
        carry, _ = jax.lax.scan(step, init, (qs, ks))
        return carry[3], carry[4]

    def _fwd(q, k, v):
        fb = fwd_blocks_tri if tri else fwd_blocks
        o, lse = fb(q, k, v)
        return o, (q, k, v, o, lse)

    def _bwd(res, do):
        q, k, v, o, lse = res
        B, nq, qb, Hkv, G, Dh = q.shape
        nk, kb, Dv = k.shape[1], k.shape[2], v.shape[-1]
        do = do.astype(jnp.float32)                     # (nq,B,qblk,h,g,Dv)
        # D_i = rowsum(dO * O)
        Drow = jnp.sum(do * o, axis=-1)                 # (nq,B,qblk,h,g)
        Drow = Drow.transpose(0, 1, 3, 4, 2)            # (nq,B,h,g,qblk)

        def dq_step(_, qi):
            qb_ = q[:, qi].astype(jnp.float32)
            dob = do[qi].transpose(0, 2, 3, 1, 4)       # (B,h,g,qblk,Dv)
            qpos = qi * qblk + jnp.arange(qblk) + q_offset

            def kv_step(dq_acc, ki):
                kb_ = k[:, ki].astype(jnp.float32)
                s = jnp.einsum("bqhgd,bkhd->bhgqk", qb_, kb_) * scale
                kpos = ki * kblk + jnp.arange(kblk)
                s = s + _mask_bias(qpos, kpos, causal, window,
                                   sk)[None, None, None]
                p = jnp.exp(s - lse[qi][..., None])
                dp = jnp.einsum("bhgqd,bkhd->bhgqk", dob,
                                v[:, ki].astype(jnp.float32))
                ds = p * (dp - Drow[qi][..., None])
                dq_acc = dq_acc + jnp.einsum("bhgqk,bkhd->bqhgd", ds,
                                             kb_) * scale
                return dq_acc, None

            dq0 = jnp.zeros((B, qblk, Hkv, G, Dh), jnp.float32)
            dq, _ = jax.lax.scan(kv_step, dq0, jnp.arange(nk))
            return None, dq

        _, dq = jax.lax.scan(dq_step, None, jnp.arange(nq))

        def dkv_step(_, ki):
            kb_ = k[:, ki].astype(jnp.float32)
            vb_ = v[:, ki].astype(jnp.float32)
            kpos = ki * kblk + jnp.arange(kblk)

            def q_step(carry, qi):
                dk_acc, dv_acc = carry
                qb_ = q[:, qi].astype(jnp.float32)
                dob = do[qi].transpose(0, 2, 3, 1, 4)
                qpos = qi * qblk + jnp.arange(qblk) + q_offset
                s = jnp.einsum("bqhgd,bkhd->bhgqk", qb_, kb_) * scale
                s = s + _mask_bias(qpos, kpos, causal, window,
                                   sk)[None, None, None]
                p = jnp.exp(s - lse[qi][..., None])
                dv_acc = dv_acc + jnp.einsum("bhgqk,bhgqd->bkhd", p, dob)
                dp = jnp.einsum("bhgqd,bkhd->bhgqk", dob, vb_)
                ds = p * (dp - Drow[qi][..., None])
                dk_acc = dk_acc + jnp.einsum("bhgqk,bqhgd->bkhd", ds,
                                             qb_) * scale
                return (dk_acc, dv_acc), None

            init = (jnp.zeros((B, kblk, Hkv, Dh), jnp.float32),
                    jnp.zeros((B, kblk, Hkv, Dv), jnp.float32))
            (dk, dv), _ = jax.lax.scan(q_step, init, jnp.arange(nq))
            return None, (dk, dv)

        _, (dk, dv) = jax.lax.scan(dkv_step, None, jnp.arange(nk))
        # emit layouts: dq (nq,B,qblk,h,g,d), dk/dv (nk,B,kblk,h,d)
        # -> input layouts (B,nq,qblk,...), (B,nk,kblk,...)
        return (dq.transpose(1, 0, 2, 3, 4, 5).astype(q.dtype),
                dk.transpose(1, 0, 2, 3, 4).astype(k.dtype),
                dv.transpose(1, 0, 2, 3, 4).astype(v.dtype))

    def _bwd_tri(res, do):
        """Triangle-ordered backward: only lower-triangle pairs computed."""
        q, k, v, o, lse = res
        B, nq, qb, Hkv, G, Dh = q.shape
        nk, kb, Dv = k.shape[1], k.shape[2], v.shape[-1]
        do = do.astype(jnp.float32)
        Drow = jnp.sum(do * o, axis=-1).transpose(0, 1, 3, 4, 2)

        def _tile(qi, ki):
            qb_ = q[:, qi].astype(jnp.float32)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qb_,
                           k[:, ki].astype(jnp.float32)) * scale
            s = s + _bias_pair(qi, ki)[None, None, None]
            p = jnp.exp(s - lse[qi][..., None])
            dob = do[qi].transpose(0, 2, 3, 1, 4)
            dp = jnp.einsum("bhgqd,bkhd->bhgqk", dob,
                            v[:, ki].astype(jnp.float32))
            ds = p * (dp - Drow[qi][..., None])
            return qb_, p, ds, dob

        qs, ks = _tri_pairs(nq)

        def dq_step(carry, pair):
            dq_acc, dq_out = carry
            qi, ki = pair
            dq_acc = jnp.where(ki == 0, 0.0, dq_acc)
            _, p, ds, _ = _tile(qi, ki)
            dq_acc = dq_acc + jnp.einsum(
                "bhgqk,bkhd->bqhgd", ds, k[:, ki].astype(jnp.float32)) * scale
            cur = dq_out[qi]
            dq_out = dq_out.at[qi].set(jnp.where(ki == qi, dq_acc, cur))
            return (dq_acc, dq_out), None

        dq0 = (jnp.zeros((B, qblk, Hkv, G, Dh), jnp.float32),
               jnp.zeros((nq, B, qblk, Hkv, G, Dh), jnp.float32))
        (_, dq), _ = jax.lax.scan(dq_step, dq0, (qs, ks))

        qs2, ks2 = _tri_pairs_colmajor(nq)

        def dkv_step(carry, pair):
            dk_acc, dv_acc, dk_out, dv_out = carry
            qi, ki = pair
            fresh = qi == ki
            dk_acc = jnp.where(fresh, 0.0, dk_acc)
            dv_acc = jnp.where(fresh, 0.0, dv_acc)
            qb_, p, ds, dob = _tile(qi, ki)
            dv_acc = dv_acc + jnp.einsum("bhgqk,bhgqd->bkhd", p, dob)
            dk_acc = dk_acc + jnp.einsum("bhgqk,bqhgd->bkhd", ds, qb_) * scale
            done = qi == nq - 1
            dk_out = dk_out.at[ki].set(jnp.where(done, dk_acc, dk_out[ki]))
            dv_out = dv_out.at[ki].set(jnp.where(done, dv_acc, dv_out[ki]))
            return (dk_acc, dv_acc, dk_out, dv_out), None

        dkv0 = (jnp.zeros((B, kblk, Hkv, Dh), jnp.float32),
                jnp.zeros((B, kblk, Hkv, Dv), jnp.float32),
                jnp.zeros((nk, B, kblk, Hkv, Dh), jnp.float32),
                jnp.zeros((nk, B, kblk, Hkv, Dv), jnp.float32))
        (_, _, dk, dv), _ = jax.lax.scan(dkv_step, dkv0, (qs2, ks2))
        return (dq.transpose(1, 0, 2, 3, 4, 5).astype(q.dtype),
                dk.transpose(1, 0, 2, 3, 4).astype(k.dtype),
                dv.transpose(1, 0, 2, 3, 4).astype(v.dtype))

    @jax.custom_vjp
    def flash(q, k, v):
        o, _ = (fwd_blocks_tri if tri else fwd_blocks)(q, k, v)
        return o

    flash.defvjp(_fwd, _bwd_tri if tri else _bwd)
    return flash


# kv-block length: the q-pass carry (B,H,G,qblk,Dv f32) is rewritten once per
# kv block, so HBM carry traffic scales ~ S/kblk — bigger kblk is cheaper
# until the (qblk x kblk) tile stops fitting near-memory (VMEM on TPU).
DEFAULT_KBLK = int(os.environ.get("REPRO_FLASH_KBLK", "512"))
DEFAULT_QBLK = int(os.environ.get("REPRO_FLASH_QBLK", "256"))


def _aligned(blk: int, S: int) -> int:
    """Cap the block so it divides the per-shard sequence span (the residual
    stream is seq-sharded 16-way; a block spanning shards forces GSPMD to
    all-gather the whole K/V per step — measured 4x collective blowup)."""
    from repro.utils import _mesh_axis_names
    if "model" not in _mesh_axis_names():
        return min(blk, max(S, 128))
    shard_span = max(S // 16, 128)
    return min(blk, shard_span)


def flash_attention(q, k, v, *, scale, causal=True, window=0, q_offset=0,
                    qblk=None, kblk=None, softcap=0.0):
    qblk = _aligned(DEFAULT_QBLK if qblk is None else qblk, q.shape[1])
    kblk = _aligned(DEFAULT_KBLK if kblk is None else kblk, k.shape[1])
    """q: (B,Sq,Hkv,G,Dh); k: (B,Sk,Hkv,Dh); v: (B,Sk,Hkv,Dv) -> (B,Sq,...).

    Memory: O(S * D) activations + one (qblk x kblk) tile per head in
    flight; the S^2 matrix is never stored.
    """
    B, Sq, Hkv, G, Dh = q.shape
    Sk, Dv = k.shape[1], v.shape[-1]
    qpad, kpad = cdiv(Sq, qblk) * qblk - Sq, cdiv(Sk, kblk) * kblk - Sk
    qf = jnp.pad(q, ((0, 0), (0, qpad), (0, 0), (0, 0), (0, 0)))
    kf = jnp.pad(k, ((0, 0), (0, kpad), (0, 0), (0, 0)))
    vf = jnp.pad(v, ((0, 0), (0, kpad), (0, 0), (0, 0)))
    # padded kv columns must be masked: represent via causal+window bounds —
    # padded KEYS sit at positions >= Sk; padded QUERIES beyond Sq are
    # discarded after the slice. Mask pad keys by giving them positions
    # beyond any query: with causal=True they are already excluded for
    # q < Sk; for non-causal we mask explicitly below.
    nq, nk = qf.shape[1] // qblk, kf.shape[1] // kblk
    qf = qf.reshape(B, nq, qblk, Hkv, G, Dh)
    kf = kf.reshape(B, nk, kblk, Hkv, Dh)
    vf = vf.reshape(B, nk, kblk, Hkv, Dv)
    fn = _make_flash(float(scale), bool(causal), int(window), int(q_offset),
                     int(qblk), int(kblk), float(softcap), int(Sk))
    o = fn(qf, kf, vf)                                  # (nq,B,qblk,h,g,Dv)
    o = o.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * qblk, Hkv, G, Dv)
    return o[:, :Sq].astype(q.dtype)
