from repro.sharding.partition import (dense_param_specs, state_specs,
                                      batch_specs, cache_specs, to_shardings)
