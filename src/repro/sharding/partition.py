"""PartitionSpec rules for every pytree the launcher ships to devices.

Scheme (DESIGN.md §5): Megatron-style tensor parallelism over ``model`` x
FSDP over ``data`` for the dense backbone; embedding PS tables row-sharded
per their EmbeddingSpec mode; expert stacks over ``model`` (expert
parallelism); decode caches sequence-sharded over ``model``; batch over
(pod, data). Multi-pod: weights are replicated across pods (FSDP stays
intra-pod — DCN-crossing all-gathers per layer would dominate), while the
batch also shards over ``pod``.
"""
from __future__ import annotations

import re
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.embedding_ps import EmbeddingSpec, table_spec

BATCH = ("pod", "data")

# ZeRO stage for the dense stack:
#   3 (default) — params sharded ('data', 'model'): min memory, but every
#       layer all-gathers its weights over 'data' in fwd + bwd (+ remat)
#   2 — params replicated over 'data' (still TP over 'model'); optimizer
#       m/v stay 'data'-sharded. Kills the per-layer weight all-gathers at
#       the cost of one param-update broadcast per step + replicated storage.
import os
ZERO_STAGE = int(os.environ.get("REPRO_ZERO_STAGE", "3"))

# param-name -> (spec for 2D (d_in, d_out)) rules
_COL_PARALLEL = {"wq", "wk", "wv", "wg", "wu", "wuq", "wuk", "wuv", "wq_b",
                 "lm_head"}
_ROW_PARALLEL = {"wo", "wd", "out_proj"}
_FSDP_ONLY = {"in_proj", "wdq", "wdkv", "w"}          # mixed/ragged out dims
_REPLICATED = {"router", "conv_w", "b"}


def _dense_leaf_spec(path: str, leaf, stage=None) -> P:
    stage = ZERO_STAGE if stage is None else stage
    name = re.findall(r"\['([^']+)'\]", path)[-1]
    in_stack = "['stack']" in path
    nd = leaf.ndim

    def wrap(*spec):
        # stacked (scan) params carry a leading repeats dim
        return P(None, *spec) if in_stack else P(*spec)

    base_nd = nd - 1 if in_stack else nd
    # MoE expert stacks: (E, d_in, d_out) -> experts over model (expert
    # parallelism) x FSDP over data on d_in; the MoE shard_map's in_spec
    # (P('model', None, None)) makes the per-layer all-gather over 'data'
    # explicit — ZeRO-3 on the expert weights.
    if base_nd == 3 and name in ("wg", "wu", "wd"):
        return wrap("model", "data" if stage >= 3 else None, None)
    if base_nd == 2:
        fsdp = "data" if stage >= 3 else None
        if name in _COL_PARALLEL:
            return wrap(fsdp, "model")
        if name in _ROW_PARALLEL:
            return wrap("model", fsdp)
        if name in _FSDP_ONLY:
            return wrap("data", None)
        if name in ("pos_emb", "dec_pos_emb", "in_proj"):
            return wrap("data", None)
        return wrap(None, None)
    if base_nd == 1 or base_nd == 0:
        return wrap(*([None] * base_nd))
    return wrap(*([None] * base_nd))


def dense_param_specs(params, stage=None) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda p, x: _dense_leaf_spec(jax.tree_util.keystr(p), x, stage),
        params)


def emb_state_specs(emb_state, spec: EmbeddingSpec):
    """Dense PS shards row-shard per their mode; a host_lru device cache
    (table + acc + slot_ids over cache_rows slots) row-shards the same way
    (the hot set is what lives device-side). A ShardedBackend router state
    ({"s0": sub_state, ...}) gets one spec tree per PS shard — each shard's
    device arrays shard like a table of its own."""
    if "table" not in emb_state:         # sharded router: per-shard states
        return {k: emb_state_specs(v, spec) for k, v in emb_state.items()}
    t = table_spec(spec)
    out = {"table": t}
    if "acc" in emb_state:
        out["acc"] = P(t[0])
    if "slot_ids" in emb_state:
        out["slot_ids"] = P(t[0])
    return out


def queue_specs(queue):
    """Staleness-queue specs: (tau, W[, dim]) arrays shard their width over
    the batch axes. W is the *unique-width* dedup cap under worker-side
    batch dedup (core/dedup.py) — dedup_cap rounds W up to a multiple of
    min(1024, n_occurrences), so the narrowed queues keep dividing over up
    to 1024 batch shards (and ``_guard`` drops the axis if a custom width
    ever doesn't)."""
    if queue is None:
        return None
    if "ids" not in queue:               # sharded router: per-shard queues
        return {k: queue_specs(v) for k, v in queue.items()}
    out = {"ids": P(None, BATCH), "grads": P(None, BATCH, None),
           "ptr": P(), "filled": P()}
    if "slots" in queue:                 # host_lru queues carry (slot, id)
        out["slots"] = P(None, BATCH)
    return out


def state_specs(state, emb_spec: EmbeddingSpec):
    """Spec tree for the legacy (dict, single-table) hybrid train state."""
    dense = dense_param_specs(state["dense"])
    return {
        "dense": dense,
        "opt": _opt_specs(state["opt"], dense),
        "emb": emb_state_specs(state["emb"], emb_spec),
        "emb_queue": queue_specs(state["emb_queue"]),
        "dense_queue": None if state["dense_queue"] is None else {
            "grads": jax.tree.map(lambda s: P(None, *s), dense),
            "ptr": P(), "filled": P()},
        "step": P(),
    }


def collection_state_specs(emb_states, collection):
    """Per-table PS-state specs for an EmbeddingCollection's state dict."""
    return {n: emb_state_specs(emb_states[n], collection[n])
            for n in emb_states}


def collection_queue_specs(queues):
    return {n: queue_specs(q) for n, q in queues.items()}


def train_state_specs(state, collection):
    """Spec tree for a PersiaTrainer TrainState (mirrors its pytree)."""
    from repro.core.hybrid import TrainState
    dense = dense_param_specs(state.dense)
    return TrainState(
        dense=dense,
        opt=_opt_specs(state.opt, dense),
        emb=collection_state_specs(state.emb, collection),
        emb_queue=collection_queue_specs(state.emb_queue),
        dense_queue=None if state.dense_queue is None else {
            "grads": jax.tree.map(lambda s: P(None, *s), dense),
            "ptr": P(), "filled": P()},
        step=P(),
    )


def _opt_specs(opt_state, dense_specs):
    out = {}
    for k, v in opt_state.items():
        if k in ("m", "v"):
            # optimizer moments always ZeRO-sharded over 'data' (stage >= 2)
            out[k] = jax.tree_util.tree_map_with_path(
                lambda p, x: _dense_leaf_spec(jax.tree_util.keystr(p), x, 3),
                v)
        else:
            out[k] = P()
    return out


def batch_specs(batch) -> Any:
    def leaf(path, x):
        return P(BATCH, *([None] * (x.ndim - 1)))
    return jax.tree_util.tree_map_with_path(
        lambda p, x: leaf(jax.tree_util.keystr(p), x), batch)


# ---------------------------------------------------------------------------
# Decode caches
# ---------------------------------------------------------------------------

def _cache_leaf_spec(path: str, leaf, cfg) -> P:
    name = re.findall(r"\['([^']+)'\]", path)[-1]
    in_stack = "['stack']" in path
    nd = leaf.ndim

    def wrap(*spec):
        return P(None, *spec) if in_stack else P(*spec)

    base_nd = nd - 1 if in_stack else nd
    if name == "pos":
        return P(BATCH)
    if name in ("len", "filled", "ptr"):
        return wrap(BATCH) if base_nd else wrap()
    if name in ("k", "v"):
        # (B, S_or_ring_or_M, Hkv, Dh): shard seq over model when full-length
        S = leaf.shape[-3]
        seq_shardable = (cfg.sliding_window <= 0 or S > cfg.sliding_window) \
            and S % 16 == 0
        # ring buffers & short memories stay replicated over model
        if "cross" in path:
            seq_shardable = S % 16 == 0
        if cfg.sliding_window > 0 and S <= max(cfg.sliding_window, 8192):
            seq_shardable = False
        return wrap(BATCH, "model" if seq_shardable else None, None, None)
    if name in ("ckv", "k_rope"):
        return wrap(BATCH, "model", None)
    if name == "h":                                   # SSM state (B,H,N,P)
        return wrap(BATCH, "model", None, None)
    if name == "conv":                                # (B, K-1, C)
        return wrap(BATCH, None, None)
    return wrap(*([None] * base_nd))


def cache_specs(caches, cfg) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda p, x: _cache_leaf_spec(jax.tree_util.keystr(p), x, cfg), caches)


def to_shardings(mesh, spec_tree, shape_tree=None):
    """NamedShardings from a spec tree; unknown axes dropped, and (when
    shape_tree is given) axes that don't divide the dim are dropped too."""
    if shape_tree is None:
        return jax.tree.map(
            lambda s: NamedSharding(mesh, _strip(s, mesh)), spec_tree,
            is_leaf=lambda x: isinstance(x, P))
    return jax.tree.map(
        lambda s, x: NamedSharding(mesh, _guard(_strip(s, mesh), mesh, x)),
        spec_tree, shape_tree, is_leaf=lambda x: isinstance(x, P))


def _axis_n(mesh, e) -> int:
    if e is None:
        return 1
    if isinstance(e, (tuple, list)):
        n = 1
        for a in e:
            n *= mesh.shape[a]
        return n
    return mesh.shape[e]


def _guard(spec: P, mesh, leaf) -> P:
    parts = list(spec) + [None] * (leaf.ndim - len(spec))
    for i, e in enumerate(parts):
        if e is not None and leaf.shape[i] % _axis_n(mesh, e) != 0:
            parts[i] = None
    return P(*parts)


def _strip(spec: P, mesh) -> P:
    """Drop axis names the mesh doesn't have (e.g. 'pod' on single-pod)."""
    names = set(mesh.axis_names)

    def fix(e):
        if e is None:
            return None
        if isinstance(e, (tuple, list)):
            kept = tuple(x for x in e if x in names)
            return kept if kept else None
        return e if e in names else None

    return P(*(fix(e) for e in spec))
