"""Checkpointing with Persia's fault-tolerance policy (paper §4.2.4):

* embedding PS shards are saved *independently* (an in-flight put lost on
  restore is tolerable — Alg.1 is lock-free anyway), each shard a flat
  zero-copy-style arrays blob (the array-list LRU design makes serialisation
  a memory copy; here: raw little-endian buffers + a json manifest);
* the dense model + optimizer state is saved *atomically* (write to a temp
  dir, fsync, rename) because any drop of dense synchronisation is vital;
* the embedding-worker sample buffers are NOT checkpointed (paper: abandoned
  on failure, no recovery attempted).

Sharded tables (``EmbeddingSpec.emb_shards > 1``) write *shard-tagged*
blobs: ``emb/<table>/shard_meta`` ([n_shards, rows, dim]) plus one
independent two-tier sub-blob per shard under ``emb/<table>/shards/s<k>/``.
Restore reshards row-exactly when the trainer's shard count differs (see
``repro.core.backend.extract_logical_rows``); ``checkpoint_shard_layout``
below inspects a checkpoint's per-table shard counts without a trainer.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    elif tree is None:
        pass
    else:
        out[prefix.rstrip("/")] = np.asarray(tree)
    return out


def _unflatten(flat: dict):
    root: dict = {}
    for path, arr in flat.items():
        keys = path.split("/")
        node = root
        for k in keys[:-1]:
            node = node.setdefault(k, {})
        node[keys[-1]] = arr
    return _listify(root)


def _listify(node):
    if isinstance(node, dict):
        keys = list(node.keys())
        if keys and all(k.isdigit() for k in keys):
            return [_listify(node[str(i)]) for i in range(len(keys))]
        return {k: _listify(v) for k, v in node.items()}
    return node


def _write_blob(path: str, tree):
    flat = _flatten(tree)
    manifest = {}
    with open(os.path.join(path, "data.bin"), "wb") as f:
        off = 0
        for k in sorted(flat):
            a = np.asarray(flat[k])
            shape = list(a.shape)                  # before ascontiguousarray
            raw = np.ascontiguousarray(a).tobytes()   # zero-copy layout
            f.write(raw)
            manifest[k] = {"dtype": str(a.dtype), "shape": shape,
                           "offset": off, "nbytes": len(raw)}
            off += len(raw)
        f.flush()
        os.fsync(f.fileno())
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f)


def _read_blob(path: str):
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    buf = np.memmap(os.path.join(path, "data.bin"), dtype=np.uint8, mode="r")
    flat = {}
    for k, m in manifest.items():
        raw = buf[m["offset"]: m["offset"] + m["nbytes"]]
        flat[k] = np.frombuffer(raw.tobytes(), dtype=m["dtype"]) \
            .reshape(m["shape"])
    return _unflatten(flat)


def save_checkpoint(directory: str, step: int, dense_tree, emb_tree=None):
    """Atomic dense save + independent embedding shard save."""
    os.makedirs(directory, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_")
    try:
        dense_dir = os.path.join(tmp, "dense")
        os.makedirs(dense_dir)
        _write_blob(dense_dir, {"state": dense_tree,
                                "step": np.int64(step)})
        if emb_tree is not None:
            emb_dir = os.path.join(tmp, "emb")
            os.makedirs(emb_dir)
            _write_blob(emb_dir, emb_tree)
        final = os.path.join(directory, f"step_{step:08d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                      # atomic publish
        return final
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def load_checkpoint(directory: str, step: int | None = None):
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(directory)
                   if d.startswith("step_"))
    if not steps:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    step = steps[-1] if step is None else step
    path = os.path.join(directory, f"step_{step:08d}")
    dense = _read_blob(os.path.join(path, "dense"))
    emb = None
    if os.path.isdir(os.path.join(path, "emb")):
        emb = _read_blob(os.path.join(path, "emb"))
    return int(dense["step"]), dense["state"], emb


def checkpoint_shard_layout(directory: str, step: int | None = None
                            ) -> dict[str, int]:
    """Per-table embedding-PS shard counts of a saved full-state
    checkpoint: 1 for plain (unsharded) table blobs, N for shard-tagged
    router blobs. Raises if the checkpoint has no embedding blob."""
    _, _, emb = load_checkpoint(directory, step)
    if not emb or "emb" not in emb:
        raise ValueError(
            f"checkpoint at {directory!r} carries no per-table embedding "
            "blob (legacy save_checkpoint format?)")
    out = {}
    for name, blob in emb["emb"].items():
        if not isinstance(blob, dict) or \
                ("shard_meta" not in blob and "shards" not in blob):
            out[name] = 1                       # plain (unsharded) table blob
            continue
        if "shard_meta" not in blob or "shards" not in blob:
            missing = "shard_meta" if "shard_meta" not in blob else "shards"
            raise ValueError(
                f"table {name!r}: sharded checkpoint blob is missing its "
                f"{missing!r} entry — corrupt or truncated save")
        meta = np.asarray(blob["shard_meta"]).reshape(-1)
        if meta.size != 3 or not np.issubdtype(meta.dtype, np.integer) \
                or int(meta[0]) < 1:
            raise ValueError(
                f"table {name!r}: corrupt shard_meta {meta!r} (expected "
                "3 ints [n_shards, rows, dim] with n_shards >= 1)")
        k = int(meta[0])
        have = sorted(blob["shards"])
        want = [f"s{s}" for s in range(k)]
        if have != sorted(want):
            raise ValueError(
                f"table {name!r}: shard_meta declares {k} shards but the "
                f"blob holds {have} (expected {want})")
        out[name] = k
    return out


class CheckpointManager:
    """Periodic saver with the paper's policy baked in."""

    def __init__(self, directory: str, every: int = 100, keep: int = 3):
        self.directory = directory
        self.every = every
        self.keep = keep

    def maybe_save(self, step: int, dense_tree, emb_tree=None):
        if step % self.every != 0:
            return None
        path = save_checkpoint(self.directory, step,
                               jax.tree.map(np.asarray, dense_tree),
                               jax.tree.map(np.asarray, emb_tree)
                               if emb_tree is not None else None)
        self._gc()
        return path

    def maybe_save_state(self, step: int, trainer, state):
        """Full-state periodic save through PersiaTrainer.save: dense params
        + optimizer moments, every PS table with its adagrad accumulator,
        and the staleness queues — so a restore resumes bit-identically."""
        if step % self.every != 0:
            return None
        path = trainer.save(self.directory, state, step=step)
        self._gc()
        return path

    def _gc(self):
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.directory)
                       if d.startswith("step_"))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
